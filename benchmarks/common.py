"""Shared benchmark scaffolding.

Simulations reproduce the paper's *structure* at CPU-tractable scale: the
paper's 20 GB guests with 2 MB/4 KB pages become ``n_logical`` base pages with
``hp_ratio`` subpages per huge page; each workload's skew shape comes from
``repro.data.traces`` (calibrated against Fig. 2/16). Near-memory sizes,
CLs and near:far ratios scale proportionally. Results are written to
experiments/benchmarks/<name>.json and summarized by benchmarks/run.py.
"""
from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core import GpacConfig, gpac, init_state, metrics, start_all_far
from repro.core import address_space as asp
from repro.core import telemetry as tele
from repro.data import traces as tr

OUT_DIR = os.path.join("experiments", "benchmarks")

# CPU-scale stand-in for the paper's 2 MB / 4 KB geometry
HP_RATIO = 64
N_LOGICAL = 32 * 1024  # base pages per guest (-> 512 huge pages)
WINDOWS = 24
ACCESSES = 16 * 1024

# paper CL values scaled by (HP_RATIO / 512)
def scaled_cl(workload: str) -> int:
    cl512 = tr.PAPER_CL.get(workload, 64)
    return max(2, int(round(cl512 * HP_RATIO / 512)))


def guest_config(near_fraction: float = 0.5, cl: int | None = None,
                 n_logical: int = N_LOGICAL) -> GpacConfig:
    need_hp = -(-n_logical // HP_RATIO)
    # 100% GPA slack: the paper's far tier (1.6 TB NVMM vs 20 GB guests) never
    # starves demotion of free blocks; a tight GPA space would cap demotions
    n_hp = need_hp + max(4, need_hp)
    return GpacConfig(
        n_logical=n_logical,
        hp_ratio=HP_RATIO,
        n_gpa_hp=n_hp,
        n_near=max(1, int(near_fraction * need_hp)),
        base_elems=2,
        cl=cl or HP_RATIO // 2,
        ipt_min_hits=1,
    )


def workload_trace(workload: str, n_windows: int = WINDOWS,
                   accesses: int = ACCESSES, seed: int = 0,
                   n_logical: int = N_LOGICAL) -> np.ndarray:
    return tr.generate(tr.TraceSpec(
        workload, n_logical=n_logical, hp_ratio=HP_RATIO,
        n_windows=n_windows, accesses_per_window=accesses, seed=seed))


def run_single_guest(workload: str, use_gpac: bool, policy: str = "memtierd",
                     near_fraction: float = 0.5, cl: int | None = None,
                     start_far: bool = True, seed: int = 0,
                     n_windows: int = WINDOWS, tier_pair: str = "dram_nvmm"):
    """Paper §5.2 setting: one guest, tiering active, optional GPAC.

    Returns (final state snapshot, per-window series dict).
    """
    cfg = guest_config(near_fraction, cl or scaled_cl(workload))
    state = init_state(cfg)
    if start_far:
        state = start_all_far(cfg, state)
    trace = workload_trace(workload, n_windows=n_windows, seed=seed)
    series = dict(near_usage=[], near_capacity=[], hit_rate=[], tput=[],
                  promoted=[], demoted=[])
    for w in range(trace.shape[0]):
        state = gpac.window_step(
            cfg, state, jnp.asarray(trace[w]), policy=policy,
            use_gpac=use_gpac, max_batches=16, budget=256)
        series["near_usage"].append(float(metrics.near_usage(cfg, state)))
        series["near_capacity"].append(
            float(metrics.near_capacity_used(cfg, state)))
        series["hit_rate"].append(float(metrics.hit_rate(state)))
        series["tput"].append(
            float(metrics.modeled_throughput(state, tier_pair)))
        series["promoted"].append(int(state.stats["promoted_blocks"]))
        series["demoted"].append(int(state.stats["demoted_blocks"]))
    return cfg, state, series


def steady(xs: list, tail: int = 6) -> float:
    return float(np.mean(xs[-tail:]))


def save(name: str, payload: dict):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return payload


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.ms = (time.perf_counter() - self.t0) * 1e3
