"""Shared benchmark scaffolding.

Simulations reproduce the paper's *structure* at CPU-tractable scale: the
paper's 20 GB guests with 2 MB/4 KB pages become ``n_logical`` base pages with
``hp_ratio`` subpages per huge page; each workload's skew shape comes from
``repro.data.traces`` (calibrated against Fig. 2/16). Near-memory sizes,
CLs and near:far ratios scale proportionally. Results are written to
experiments/benchmarks/<name>.json and summarized by benchmarks/run.py.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import GpacConfig, engine, init_state, metrics, start_all_far
from repro.data import traces as tr

OUT_DIR = os.path.join("experiments", "benchmarks")

# CPU-scale stand-in for the paper's 2 MB / 4 KB geometry
HP_RATIO = 64
N_LOGICAL = 32 * 1024  # base pages per guest (-> 512 huge pages)
WINDOWS = 24
ACCESSES = 16 * 1024

# paper CL values scaled by (HP_RATIO / 512)
def scaled_cl(workload: str) -> int:
    cl512 = tr.PAPER_CL.get(workload, 64)
    return max(2, int(round(cl512 * HP_RATIO / 512)))


def guest_config(near_fraction: float = 0.5, cl: int | None = None,
                 n_logical: int = N_LOGICAL) -> GpacConfig:
    need_hp = -(-n_logical // HP_RATIO)
    # 100% GPA slack: the paper's far tier (1.6 TB NVMM vs 20 GB guests) never
    # starves demotion of free blocks; a tight GPA space would cap demotions
    n_hp = need_hp + max(4, need_hp)
    return GpacConfig(
        n_logical=n_logical,
        hp_ratio=HP_RATIO,
        n_gpa_hp=n_hp,
        n_near=max(1, int(near_fraction * need_hp)),
        base_elems=2,
        cl=cl or HP_RATIO // 2,
        ipt_min_hits=1,
    )


def workload_trace(workload: str, n_windows: int = WINDOWS,
                   accesses: int = ACCESSES, seed: int = 0,
                   n_logical: int = N_LOGICAL) -> np.ndarray:
    return tr.generate(tr.TraceSpec(
        workload, n_logical=n_logical, hp_ratio=HP_RATIO,
        n_windows=n_windows, accesses_per_window=accesses, seed=seed))


def run_single_guest(workload: str, use_gpac: bool, policy: str = "memtierd",
                     near_fraction: float = 0.5, cl: int | None = None,
                     start_far: bool = True, seed: int = 0,
                     n_windows: int = WINDOWS, tier_pair: str = "dram_nvmm",
                     windows_per_step: int = 0):
    """Paper §5.2 setting: one guest, tiering active, optional GPAC.

    Runs on the shared scan-fused engine driver (``n_guests=1``): the whole
    window loop is one device-side scan with the ``snapshot`` collector, and
    metric series cross to the host once per ``windows_per_step`` chunk
    (0 = once for the whole run) instead of once per window.

    Returns (config, final state, per-window series dict).
    """
    cfg = guest_config(near_fraction, cl or scaled_cl(workload))
    state = init_state(cfg)
    if start_far:
        state = start_all_far(cfg, state)
    if n_windows == 0:
        return cfg, state, {k: [] for k in (
            "near_usage", "near_capacity", "hit_rate", "tput",
            "promoted", "demoted")}
    trace = workload_trace(workload, n_windows=n_windows, seed=seed)
    spec = engine.spec_from_config(cfg, workload=workload, seed=seed)
    state, snap = engine.run(
        spec, state, trace[None], policy=policy, use_gpac=use_gpac,
        max_batches=16, budget=256, windows_per_step=windows_per_step,
        collect=("snapshot",))
    # modeled throughput from the cumulative hit counters, same calibration
    # as metrics.modeled_throughput (the per-window loop used to pull it
    # from the device one window at a time)
    _, tput = metrics.throughput_from_hits(
        snap["near_hits"].astype(np.float64),
        snap["far_hits"].astype(np.float64), tier_pair)
    series = dict(
        near_usage=[float(x) for x in snap["near_usage"]],
        near_capacity=[float(x) for x in snap["near_capacity_used"]],
        hit_rate=[float(x) for x in snap["hit_rate"]],
        tput=[float(x) for x in tput],
        promoted=[int(x) for x in snap["promoted_blocks"]],
        demoted=[int(x) for x in snap["demoted_blocks"]],
    )
    return cfg, state, series


def make_symmetric_engine(n_guests: int, logical_per_guest: int,
                          near_fraction: float, workload: str = "redis",
                          gpa_slack: float = 1.0, cl: int | None = None):
    """N equal guests of one workload on the shared engine (the multi-guest
    fig benchmarks' common geometry: per-guest seeds, benchmark base_elems,
    CL scaled from the paper's per-workload values)."""
    cl = cl or scaled_cl(workload)
    guests = tuple(
        engine.GuestSpec(n_logical=logical_per_guest, cl=cl,
                         gpa_slack=gpa_slack, workload=workload, seed=g)
        for g in range(n_guests))
    host = engine.HostSpec(hp_ratio=HP_RATIO, near_fraction=near_fraction,
                           base_elems=2, cl=cl, ipt_min_hits=1)
    return engine.build(guests, host)


def default_guest_mesh():
    """Mesh over every local device along the engine's ``"guest"`` axis, or
    ``None`` on a single-device host (``engine.run_series(mesh=None)`` then
    degrades to the unsharded driver). The at-scale benchmarks thread this
    through so a multi-device host (or CI's forced
    ``--xla_force_host_platform_device_count``) runs sharded end-to-end.
    Delegates to the launch layer's shared constructor, which spans *global*
    devices -- under ``repro.launch.multihost`` the benchmarks see the
    multi-process mesh automatically."""
    from repro.launch import mesh as launch_mesh

    return launch_mesh.guest_mesh()


def host_state_report(spec, mesh) -> dict:
    """Per-device host-state bytes: the replicated path vs the
    host-partitioned carry (DESIGN.md §11). ``scaling`` is the measured
    per-device fraction -- ~1/n_devices for balanced guests."""
    from repro.core import sharding

    replicated = sharding.host_state_bytes(spec.cfg)
    if mesh is None:
        return dict(n_devices=1, replicated_bytes_per_device=replicated,
                    sharded_bytes_per_device=replicated, scaling=1.0)
    n_devices = mesh.shape["guest"]
    part = sharding.host_partition(spec, n_devices)
    per_dev = sharding.host_state_bytes_sharded(spec.cfg, part)
    return dict(
        n_devices=n_devices,
        replicated_bytes_per_device=replicated,
        sharded_bytes_per_device=per_dev,
        scaling=per_dev / replicated,
    )


def steady(xs: list, tail: int = 6) -> float:
    return float(np.mean(xs[-tail:]))


def save(name: str, payload: dict):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return payload


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.ms = (time.perf_counter() - self.t0) * 1e3
