"""Fig. 17: benefit of GPAC under varying near:far capacity ratios.

Paper: big wins at 10:90 / 20:80 / 30:70, shrinking as near memory grows
(at 70:30 nearly everything fits near and GPAC's edge vanishes).
"""
from __future__ import annotations

from benchmarks import common
from repro.core import engine

N_GUESTS = 6
LOGICAL_PER_GUEST = 8 * 1024
RATIOS = (0.1, 0.2, 0.3, 0.5, 0.7)
# scan-fuse the window loop in chunks (see repro.core.engine.run)
WINDOWS_PER_STEP = 10


def make_engine(near_fraction):
    return common.make_symmetric_engine(N_GUESTS, LOGICAL_PER_GUEST,
                                        near_fraction=near_fraction)


def run():
    spec, _ = make_engine(RATIOS[0])
    traces = engine.guest_traces(spec, n_windows=20, accesses_per_window=8192)
    out = {}
    for ratio in RATIOS:
        res = {}
        for use_gpac in (False, True):
            spec, state = make_engine(ratio)
            _, series = engine.run_series(
                spec, state, traces, policy="memtierd", use_gpac=use_gpac,
                windows_per_step=WINDOWS_PER_STEP)
            res["gpac" if use_gpac else "baseline"] = float(
                series["throughput"][-5:].mean())
        res["delta"] = res["gpac"] / res["baseline"] - 1
        out[f"{int(ratio*100)}:{100-int(ratio*100)}"] = res
    deltas = [out[k]["delta"] for k in out]
    out["benefit_shrinks_with_more_near"] = bool(deltas[0] > deltas[-1])
    return common.save("fig17_pressure", out)


if __name__ == "__main__":
    r = run()
    for k, d in r.items():
        if isinstance(d, dict):
            print(f"near:far {k:6s} delta {d['delta']:+.1%}")
    print("benefit shrinks as near grows:", r["benefit_shrinks_with_more_near"])
