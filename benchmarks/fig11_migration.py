"""Fig. 11: promotion/demotion traffic under TPP, with and without GPAC.

Paper: TPP+GPAC cuts promoted data ~64% and demoted data ~87% -- GPAC's
consolidation means far fewer (dense) blocks carry the hot set.
"""
from __future__ import annotations

from benchmarks import common
from repro.core import engine

N_GUESTS = 4
LOGICAL_PER_GUEST = 8 * 1024


def make_engine():
    # near fraction sized so the CONSOLIDATED hot set fits (the paper's
    # "DRAM space for actual hot huge pages") while the scattered
    # baseline set (~3x larger) does not
    return common.make_symmetric_engine(N_GUESTS, LOGICAL_PER_GUEST,
                                        near_fraction=0.4)


def run():
    spec, _ = make_engine()
    traces = engine.guest_traces(spec, n_windows=24, accesses_per_window=8192)
    out = {}
    for use_gpac in (False, True):
        spec, state = make_engine()
        state, _ = engine.run_series(spec, state, traces, policy="tpp",
                                     use_gpac=use_gpac, budget=256)
        out["gpac" if use_gpac else "baseline"] = dict(
            promoted=int(state.stats["promoted_blocks"]),
            demoted=int(state.stats["demoted_blocks"]),
        )
    b, g = out["baseline"], out["gpac"]
    res = dict(
        **out,
        promoted_reduction=1 - g["promoted"] / max(b["promoted"], 1),
        demoted_reduction=1 - g["demoted"] / max(b["demoted"], 1),
        paper_target=dict(promoted=0.64, demoted=0.87),
    )
    return common.save("fig11_migration", res)


if __name__ == "__main__":
    r = run()
    print(f"promoted: {r['baseline']['promoted']} -> {r['gpac']['promoted']} "
          f"({r['promoted_reduction']:.1%} less; paper 64%)")
    print(f"demoted:  {r['baseline']['demoted']} -> {r['gpac']['demoted']} "
          f"({r['demoted_reduction']:.1%} less; paper 87%)")
