"""Fig. 16 at multi-tenant scale: per-guest hot-subpage histograms under
MIXED workloads on one shared host.

The single-guest fig16 suite characterizes each workload's skew in
isolation; here a ragged fleet of heterogeneous tenants (one
:class:`engine.SynthTrace` with per-guest ``GuestSpec.workload``s -- each
window synthesized on device, DESIGN.md §12) shares one engine run, and the
per-huge-page hot-subpage histogram is sliced per guest from the shared
telemetry. GPAC stays off so the histograms characterize the raw workload
skew (the paper's Fig. 16 is measured pre-consolidation), and the skew
ordering the paper reports (masim << redis < memcached < hash < ocean <<
liblinear) must survive the tenants being interleaved on one host.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import engine, telemetry

# (workload, n_logical): ragged on purpose -- sizes differ per tenant
TENANTS = (
    ("masim", 4 * 1024),
    ("redis", 8 * 1024),
    ("memcached", 6 * 1024),
    ("hash", 6 * 1024),
    ("ocean_ncp", 4 * 1024),
    ("liblinear", 4 * 1024),
)
WINDOWS = 8
ACCESSES = 8 * 1024


def make_engine():
    guests = tuple(
        engine.GuestSpec(n_logical=n, cl=common.scaled_cl(w), workload=w,
                         seed=g)
        for g, (w, n) in enumerate(TENANTS))
    host = engine.HostSpec(hp_ratio=common.HP_RATIO, near_fraction=0.5,
                           base_elems=2, ipt_min_hits=1)
    return engine.build(guests, host)


def run():
    spec, state = make_engine()
    synth = engine.SynthTrace(n_windows=WINDOWS, accesses_per_window=ACCESSES)
    state, _ = engine.run(spec, state, synth, use_gpac=False, collect=())
    cfg = spec.cfg
    hot = telemetry.hot_mask(cfg, state, "ipt")
    per_hp = np.asarray(telemetry.hot_subpages_per_hp(cfg, state, hot))
    out = {}
    for g, (workload, _) in enumerate(TENANTS):
        lo, hi = spec.hp_range(g)
        seg = per_hp[lo:hi]
        seg = seg[seg > 0]
        hist = np.bincount(seg, minlength=cfg.hp_ratio + 1)
        out[workload] = dict(
            hist=hist.tolist(),
            mode=int(np.argmax(hist[1:]) + 1) if seg.size else 0,
            median=float(np.median(seg)) if seg.size else 0.0,
            hot_hps=int(seg.size),
        )
    medians = [out[w]["median"] for w, _ in TENANTS]
    res = dict(
        **out,
        n_guests=len(TENANTS),
        hp_ratio=cfg.hp_ratio,
        # the paper's skew ordering, measured across interleaved tenants
        skew_order_holds=bool(
            out["masim"]["median"] <= out["redis"]["median"]
            <= out["hash"]["median"] <= out["liblinear"]["median"]),
        medians=dict(zip([w for w, _ in TENANTS], medians)),
    )
    return common.save("fig16_mixed_tenants", res)


if __name__ == "__main__":
    r = run()
    for w, _ in TENANTS:
        print(f"{w:10s} mode={r[w]['mode']:3d}/{common.HP_RATIO} "
              f"median={r[w]['median']:5.1f} hot_hps={r[w]['hot_hps']}")
    print("skew order masim <= redis <= hash <= liblinear:",
          r["skew_order_holds"])
