"""TCO/performance frontier across tier hierarchies (ISSUE 7, DESIGN.md §14).

Sweeps tier vectors over one skewed multi-guest mix: 2-tier DRAM/NVMM at
several near fractions (the paper's geometry, memtierd) against 3-tier
hierarchies with a software-compressed middle tier (dram + zram + nvmm,
``compressed`` policy). Each point reports the steady-state TCO objective
($/GB-weighted resident blocks, compression divides the middle tier's
cost), the modeled AMAT from the per-tier hit split, and the tier-0 hit
rate.

The acceptance check: at least one compressed 3-tier point must cut TCO
versus the 2-tier reference while giving up at most 5% (relative) tier-0
hit rate -- trading expensive DRAM for cheap compressed capacity without
losing the hot set. ``pareto`` marks the (tco, amat) non-dominated points;
sorted by TCO the frontier's AMAT is monotone non-increasing by
construction, which the check asserts as a sanity bound.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import engine, tiers

N_GUESTS = 4
LOGICAL_PER_GUEST = 2048
N_WINDOWS = 16
ACCESSES = 4096
TAIL = 6  # steady-state window tail

# the sweep: label -> (near_fraction | None, tiers | None, policy)
CONFIGS = (
    ("2tier_nf0.15", 0.15, None, "memtierd"),
    ("2tier_nf0.30", 0.30, None, "memtierd"),  # the reference point
    ("2tier_nf0.45", 0.45, None, "memtierd"),
    # the adaptive (hybridtier) policy drives the 3-tier points: its moving
    # per-tier hot threshold fills tier 0 as well as memtierd fills a 2-tier
    # near tier, so the sweep isolates the *hierarchy*, not the policy
    ("3tier_z3_nf0.15", None,
     tiers.compressed_specs(near_fraction=0.15, mid_fraction=0.25,
                            compression=3.0), "hybridtier"),
    ("3tier_z3_nf0.30", None,
     tiers.compressed_specs(near_fraction=0.30, mid_fraction=0.20,
                            compression=3.0), "hybridtier"),
    # the conservative demote-into-compressed policy on the same hierarchy,
    # for the policy-vs-policy contrast on one frontier
    ("3tier_z3_nf0.30_c", None,
     tiers.compressed_specs(near_fraction=0.30, mid_fraction=0.20,
                            compression=3.0), "compressed"),
)
REFERENCE = "2tier_nf0.30"
MAX_HIT_LOSS = 0.05  # relative tier-0 hit-rate loss the acceptance allows


def make_engine(near_fraction, tier_specs):
    guests = tuple(
        engine.GuestSpec(n_logical=LOGICAL_PER_GUEST, cl=8, gpa_slack=1.0,
                         workload=["redis", "redis", "masim", "hash"][g % 4],
                         seed=g)
        for g in range(N_GUESTS))
    host = engine.HostSpec(
        hp_ratio=common.HP_RATIO, base_elems=2, cl=8, ipt_min_hits=1,
        near_fraction=near_fraction if tier_specs is None else 0.5,
        tiers=tier_specs)
    return engine.build(guests, host)


def _point(label, near_fraction, tier_specs, policy):
    spec, state = make_engine(near_fraction, tier_specs)
    synth = engine.SynthTrace(n_windows=N_WINDOWS,
                              accesses_per_window=ACCESSES)
    _, se = engine.run(spec, state, synth, policy=policy,
                       collect=("hits", "tco"))
    hits = np.asarray(se["tier_hits"], np.float64)
    total = hits.sum(axis=1)
    hit0 = hits[:, 0] / np.maximum(total, 1.0)
    tv = spec.tier_vector
    return dict(
        label=label,
        policy=policy,
        n_tiers=tv.n_tiers,
        boundaries=list(tv.boundaries),
        tco=common.steady(list(np.asarray(se["tco"])), TAIL),
        amat_ns=common.steady(list(np.asarray(se["amat_ns"])), TAIL),
        hit_rate=common.steady(list(hit0), TAIL),
        tier_blocks=[int(x) for x in np.asarray(se["tier_blocks"])[-1]],
    )


def _mark_pareto(points):
    """Non-dominated on (tco, amat_ns), both minimized."""
    for p in points:
        p["pareto"] = not any(
            (q["tco"] <= p["tco"] and q["amat_ns"] <= p["amat_ns"]
             and (q["tco"] < p["tco"] or q["amat_ns"] < p["amat_ns"]))
            for q in points)
    return points


def run():
    points = _mark_pareto(
        [_point(*cfg) for cfg in CONFIGS])
    ref = next(p for p in points if p["label"] == REFERENCE)
    # acceptance: a compressed middle tier cuts TCO at <= 5% hit-rate loss
    winners = [
        p["label"] for p in points
        if p["n_tiers"] == 3 and p["tco"] < ref["tco"]
        and p["hit_rate"] >= (1.0 - MAX_HIT_LOSS) * ref["hit_rate"]]
    frontier = sorted((p for p in points if p["pareto"]),
                      key=lambda p: p["tco"])
    amats = [p["amat_ns"] for p in frontier]
    out = dict(
        points=points,
        reference=REFERENCE,
        max_hit_loss=MAX_HIT_LOSS,
        winners=winners,
        compressed_wins=bool(winners),
        frontier=[p["label"] for p in frontier],
        frontier_monotone=all(a >= b for a, b in zip(amats, amats[1:])),
    )
    return common.save("fig_tco_curve", out)


if __name__ == "__main__":
    r = run()
    for p in r["points"]:
        star = "*" if p["pareto"] else " "
        print(f" {star} {p['label']:16s} tco {p['tco']:.5f} "
              f"amat {p['amat_ns']:6.1f} ns hit0 {p['hit_rate']:.3f} "
              f"blocks {p['tier_blocks']}")
    print(f"frontier (by tco): {r['frontier']} "
          f"monotone={r['frontier_monotone']}")
    print(f"compressed middle tier beats {r['reference']} at <= "
          f"{r['max_hit_loss']:.0%} hit loss: "
          f"{'OK ' + str(r['winners']) if r['compressed_wins'] else 'MISS'}")
