"""The single registry of benchmark suites.

One ordered table of ``name -> (one-line description)``; modules are imported
lazily by :func:`load`. ``benchmarks.run`` drives the whole table (or a
``--only`` subset) and ``--list`` prints it; individual modules (e.g.
``bench_engine``) reference their own entry instead of hard-coding names, so
the table never gets out of sync with the suite.
"""
from __future__ import annotations

import importlib

SUITES: dict[str, str] = {
    "fig2_skew_cdf": "CDF of accessed subpages per huge page (paper Fig. 2)",
    "table3_consolidation": "consolidation work per workload (paper Table 3)",
    "fig6_heatmap": "access heatmap before/after consolidation (Fig. 6)",
    "fig7_memdist": "near/far memory distribution over time (Fig. 7)",
    "fig8_dram_reduction": "near-memory reduction per workload (Fig. 8)",
    "fig9_at_scale": "multi-tenant at-scale throughput (Figs. 9/10/12)",
    "fig11_migration": "promotion/demotion traffic under TPP (Fig. 11)",
    "fig13_tier_pairs": "GPAC across DRAM/CXL and HBM/DRAM pairs (Figs. 13-14)",
    "fig15_cl_sensitivity": "Consolidation-Limit sweep (Fig. 15)",
    "fig16_scatter_hist": "hot-subpage histograms (Fig. 16)",
    "fig16_mixed_tenants": "per-guest skew histograms, mixed ragged tenants "
                           "on one host (Fig. 16 at scale, SynthTrace)",
    "fig17_pressure": "benefit vs near:far capacity ratio (Fig. 17)",
    "fig_tco_curve": "TCO/performance frontier: 2-tier vs compressed 3-tier "
                     "hierarchies under the $/GB objective (ISSUE 7)",
    "bench_engine": "engine vs seed-reference wall-clock (BENCH_engine.json)",
    "bench_kernels": "registered kernel pairs, jnp ref vs Pallas interpret "
                     "(DESIGN.md §16, registry-driven)",
    "bench_churn": "steady-state churn: Poisson guest arrival/departure with "
                   "faults and pressure-aware degradation (ISSUE 6 headline)",
}


def names() -> tuple[str, ...]:
    return tuple(SUITES)


def describe(name: str) -> str:
    return SUITES[name]


def load(name: str):
    """Import and return the suite module (must expose ``run()``)."""
    if name not in SUITES:
        raise KeyError(f"unknown benchmark {name!r}; have {sorted(SUITES)}")
    return importlib.import_module(f"benchmarks.{name}")
