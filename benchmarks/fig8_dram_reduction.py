"""Fig. 8: near-memory usage reduction + performance impact per workload
(Memtierd at host, single guest, no pressure).

Paper claims: average ~72% reduction in near-memory use at ~0.86% perf loss
(excluding masim). Dense workloads (liblinear) should see no reduction.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common

WORKLOADS = ("masim", "redis", "memcached", "hash", "ocean_ncp", "liblinear")


def run():
    out = {}
    for w in WORKLOADS:
        res = {}
        for use_gpac in (False, True):
            _, _, series = common.run_single_guest(
                w, use_gpac=use_gpac, policy="memtierd", near_fraction=0.9)
            res["gpac" if use_gpac else "baseline"] = dict(
                near=common.steady(series["near_usage"]),
                hit=common.steady(series["hit_rate"]),
                tput=common.steady(series["tput"]),
            )
        b, g = res["baseline"], res["gpac"]
        out[w] = dict(
            **res,
            near_reduction=1 - g["near"] / max(b["near"], 1e-9),
            perf_delta=(g["tput"] - b["tput"]) / max(b["tput"], 1e-9),
        )
    skewed = [w for w in WORKLOADS if w not in ("liblinear", "masim")]
    avg_red = float(np.mean([out[w]["near_reduction"] for w in skewed]))
    avg_perf = float(np.mean([out[w]["perf_delta"] for w in skewed]))
    res = dict(
        workloads=out,
        avg_near_reduction_skewed=avg_red,
        avg_perf_delta_skewed=avg_perf,
        paper_target=dict(near_reduction=0.72, perf_delta=-0.0086),
    )
    return common.save("fig8_dram_reduction", res)


if __name__ == "__main__":
    r = run()
    for w, d in r["workloads"].items():
        print(f"{w:10s} near: {d['baseline']['near']:.2f} -> {d['gpac']['near']:.2f} "
              f"({d['near_reduction']:+.1%})  perf {d['perf_delta']:+.2%}")
    print(f"avg (skewed workloads): reduction {r['avg_near_reduction_skewed']:.1%}, "
          f"perf {r['avg_perf_delta_skewed']:+.2%} "
          f"(paper: 72% reduction, -0.86% perf)")
