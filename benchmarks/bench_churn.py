"""Steady-state churn headline figure (ISSUE 6).

A Poisson arrival/departure fleet over mixed SynthTrace workloads --
including the phase-shifting drift variants (``redis_drift`` /
``hash_drift``), whose hot sets rotate wholesale and stress the pressure
controller's coldest-first demotion -- runs through ``engine.run_churn``
with a fixed, replayable fault schedule: guest crashes and restarts from
``faults.poisson_churn``, a mid-run near-capacity shrink, a grow-back, and
a telemetry-dropout window. The figure tracks, per window:

* fleet occupancy (active lanes) and near-tier usage vs the effective cap,
* the pressure controller's backoff signal (consecutive breach windows),
* the fleet-aggregate near-hit rate (the paper's headline metric, now under
  churn instead of steady tenancy).

The run is asserted, not just measured: INV-CRASH-RECLAIM-COMPLETE on the
final carry (no allocated huge page in a departed guest's segment), the
pressure controller never overcommitting the physical near tier, and the
no-fault control run staying bit-identical to ``engine.run``
(INV-CHURN-NOOP-EXACT). When more than one device is visible the same
faulted run also executes on the guest-sharded mesh and is checked
bit-identical to the unsharded stepper.

Writes ``experiments/benchmarks/bench_churn.json``.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common, registry
from repro.core import engine, faults
from repro.core.types import allocated_hp_mask

NAME = "bench_churn"
assert NAME in registry.SUITES, "suite must be registered in benchmarks.registry"

N_GUESTS = 24
LOGICAL_PER_GUEST = 512
N_WINDOWS = 20
ACCESSES = 2048
HP_RATIO = 32
WORKLOADS = ("redis_drift", "hash_drift", "redis", "masim", "hash", "memcached")


def _fleet():
    guests = tuple(
        engine.GuestSpec(n_logical=LOGICAL_PER_GUEST, cl=8, gpa_slack=1.0,
                         workload=WORKLOADS[g % len(WORKLOADS)], seed=g)
        for g in range(N_GUESTS))
    host = engine.HostSpec(hp_ratio=HP_RATIO, near_fraction=0.25,
                           base_elems=2, cl=8, ipt_min_hits=1)
    return engine.build(guests, host)


def _schedule(spec) -> faults.FaultSchedule:
    n_near = spec.cfg.n_near
    return (faults.poisson_churn(N_GUESTS, N_WINDOWS, arrival_rate=0.8,
                                 departure_rate=0.06, seed=0)
            .shrink(N_WINDOWS // 3, max(1, int(n_near * 0.7)))
            .shrink(2 * N_WINDOWS // 3, n_near)
            .dropout(N_WINDOWS // 2))


def _reclaim_complete(spec, cs) -> bool:
    _, hp_owner, _, _ = faults.segment_tables(spec.canonical())
    owner = np.asarray(hp_owner)
    active = np.asarray(cs.active)
    alloc = np.asarray(allocated_hp_mask(spec.cfg, cs.state))
    orphans = alloc & (owner >= 0) & ~active[np.clip(owner, 0, None)]
    return not bool(orphans.any())


def run() -> dict:
    spec, s0 = _fleet()
    synth = engine.SynthTrace(n_windows=N_WINDOWS,
                              accesses_per_window=ACCESSES)
    sched = _schedule(spec)

    # the headline faulted run
    with common.Timer() as t:
        cs, se = engine.run_churn(spec, engine.init_churn(spec), synth,
                                  faults=sched)
        jax.block_until_ready(cs.state.block_table)
    near = np.asarray(se["near_hits"]).sum(axis=1)
    far = np.asarray(se["far_hits"]).sum(axis=1)
    hit_rate = near / np.maximum(near + far, 1)
    usage = np.asarray(se["near_blocks"]).sum(axis=1)
    occupancy = np.asarray(se["active"]).sum(axis=1)
    reclaim = _reclaim_complete(spec, cs)
    overcommit = bool((usage > spec.cfg.n_near).any())

    # the no-fault control run must stay bit-identical to engine.run
    ref_state, _ = engine.run(spec, s0, synth)
    ctrl, _ = engine.run_churn(spec, engine.init_churn(spec), synth)
    noop_exact = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(ref_state),
                        jax.tree_util.tree_leaves(ctrl.state)))

    mesh = common.default_guest_mesh()
    sharded_exact = None
    if mesh is not None:
        sh, sh_se = engine.run_churn(spec, engine.init_churn(spec), synth,
                                     faults=sched, mesh=mesh)
        sharded_exact = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree_util.tree_leaves(cs),
                            jax.tree_util.tree_leaves(sh))
        ) and all(np.array_equal(se[k], sh_se[k]) for k in se)

    payload = dict(
        suite=NAME,
        description=registry.describe(NAME),
        backend=jax.default_backend(),
        n_guests=N_GUESTS,
        logical_per_guest=LOGICAL_PER_GUEST,
        n_windows=N_WINDOWS,
        accesses_per_window=ACCESSES,
        hp_ratio=HP_RATIO,
        workloads=list(WORKLOADS),
        n_fault_events=sched.n_events,
        n_near=int(spec.cfg.n_near),
        wall_s=t.ms / 1e3,
        occupancy=occupancy.tolist(),
        near_usage=usage.tolist(),
        near_cap=np.asarray(se["near_cap"]).tolist(),
        pressure=np.asarray(se["pressure"]).tolist(),
        hit_rate=hit_rate.tolist(),
        mean_hit_rate=float(hit_rate.mean()),
        reclaim_complete=reclaim,
        never_overcommits=not overcommit,
        noop_exact=noop_exact,
        sharded_exact=sharded_exact,
    )
    ok = reclaim and not overcommit and noop_exact and sharded_exact in (None, True)
    print(f"  {N_GUESTS} guests x {N_WINDOWS} windows, "
          f"{sched.n_events} fault events: mean occupancy "
          f"{occupancy.mean():.1f}, mean hit rate {hit_rate.mean():.2f}, "
          f"peak pressure {max(payload['pressure'])}, "
          f"reclaim {'OK' if reclaim else 'INCOMPLETE'}, "
          f"noop {'exact' if noop_exact else 'DIVERGED'}"
          + ("" if sharded_exact is None else
             f", sharded {'exact' if sharded_exact else 'DIVERGED'}"))
    if not ok:
        raise SystemExit("bench_churn invariant violation (see payload)")
    return common.save(NAME, payload)


if __name__ == "__main__":
    run()
