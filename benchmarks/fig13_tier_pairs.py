"""Figs. 13-14: GPAC across memory technologies (tier-agnosticism).

Same simulation, different (near, far) latency pairs: DRAM/CXL and HBM/DRAM.
Paper: +6.3% (CXL) and +5.3% (HBM) average throughput with Memtierd+GPAC.
A third row runs a 3-level hierarchy (DRAM + compressed zram + NVMM,
DESIGN.md §14) under the adaptive policy -- GPAC is tier-structure-agnostic
too, not just latency-pair-agnostic.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import engine, tiers

N_GUESTS = 6
LOGICAL_PER_GUEST = 8 * 1024


def make_engine():
    return common.make_symmetric_engine(N_GUESTS, LOGICAL_PER_GUEST,
                                        near_fraction=0.3)


def make_engine3():
    """Same guests on a dram + zram + nvmm hierarchy (ISSUE 7)."""
    cl = common.scaled_cl("redis")
    guests = tuple(
        engine.GuestSpec(n_logical=LOGICAL_PER_GUEST, cl=cl, gpa_slack=1.0,
                         workload="redis", seed=g)
        for g in range(N_GUESTS))
    host = engine.HostSpec(
        hp_ratio=common.HP_RATIO, base_elems=2, cl=cl, ipt_min_hits=1,
        tiers=tiers.compressed_specs(near_fraction=0.3, mid_fraction=0.2,
                                     compression=3.0))
    return engine.build(guests, host)


def run(tier_pairs=("dram_cxl", "hbm_dram")):
    spec, _ = make_engine()
    traces = engine.guest_traces(spec, n_windows=24, accesses_per_window=8192)
    out = {}
    for pair in tier_pairs:
        res = {}
        for use_gpac in (False, True):
            spec, state = make_engine()
            _, series = engine.run_series(
                spec, state, traces, tier_pair=pair, policy="memtierd",
                use_gpac=use_gpac)
            res["gpac" if use_gpac else "baseline"] = float(
                series["throughput"][-6:].mean())
        res["delta"] = res["gpac"] / res["baseline"] - 1
        out[pair] = res
    # 3-tier row: the tier_pair calibration has no middle tier, so modeled
    # throughput comes from the TCO collector's per-tier AMAT instead
    res = {}
    for use_gpac in (False, True):
        spec, state = make_engine3()
        _, se = engine.run(spec, state, traces, policy="hybridtier",
                           use_gpac=use_gpac, collect=("hits", "tco"))
        amat = np.asarray(se["amat_ns"], np.float64)
        res["gpac" if use_gpac else "baseline"] = float(
            (1e3 / amat[-6:]).mean())  # accesses / us
    res["delta"] = res["gpac"] / res["baseline"] - 1
    out["dram_zram_nvmm"] = res
    out["paper_target"] = dict(dram_cxl=0.063, hbm_dram=0.053)
    return common.save("fig13_tier_pairs", out)


if __name__ == "__main__":
    r = run()
    for pair in ("dram_cxl", "hbm_dram"):
        print(f"{pair:9s} tput delta {r[pair]['delta']:+.1%} "
              f"(paper {r['paper_target'][pair]:+.1%})")
    print(f"dram_zram_nvmm (3-tier, adaptive) tput delta "
          f"{r['dram_zram_nvmm']['delta']:+.1%}")
