"""Figs. 13-14: GPAC across memory technologies (tier-agnosticism).

Same simulation, different (near, far) latency pairs: DRAM/CXL and HBM/DRAM.
Paper: +6.3% (CXL) and +5.3% (HBM) average throughput with Memtierd+GPAC.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.simulate import make_multi_guest, run_multi_guest
from repro.data import traces as tr

N_GUESTS = 6
LOGICAL_PER_GUEST = 8 * 1024


def run(tier_pairs=("dram_cxl", "hbm_dram")):
    traces = np.stack([
        tr.generate(tr.TraceSpec(
            "redis", n_logical=LOGICAL_PER_GUEST, hp_ratio=common.HP_RATIO,
            n_windows=24, accesses_per_window=8192, seed=g))
        for g in range(N_GUESTS)])
    out = {}
    for pair in tier_pairs:
        res = {}
        for use_gpac in (False, True):
            mg, state = make_multi_guest(
                n_guests=N_GUESTS, logical_per_guest=LOGICAL_PER_GUEST,
                hp_ratio=common.HP_RATIO, near_fraction=0.3,
                base_elems=2, cl=common.scaled_cl("redis"), ipt_min_hits=1,
                gpa_slack=1.0)
            _, series = run_multi_guest(
                mg, state, traces, tier_pair=pair, policy="memtierd",
                use_gpac=use_gpac, cl=common.scaled_cl("redis"))
            res["gpac" if use_gpac else "baseline"] = float(
                series["throughput"][-6:].mean())
        res["delta"] = res["gpac"] / res["baseline"] - 1
        out[pair] = res
    out["paper_target"] = dict(dram_cxl=0.063, hbm_dram=0.053)
    return common.save("fig13_tier_pairs", out)


if __name__ == "__main__":
    r = run()
    for pair in ("dram_cxl", "hbm_dram"):
        print(f"{pair:9s} tput delta {r[pair]['delta']:+.1%} "
              f"(paper {r['paper_target'][pair]:+.1%})")
