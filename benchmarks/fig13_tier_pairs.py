"""Figs. 13-14: GPAC across memory technologies (tier-agnosticism).

Same simulation, different (near, far) latency pairs: DRAM/CXL and HBM/DRAM.
Paper: +6.3% (CXL) and +5.3% (HBM) average throughput with Memtierd+GPAC.
"""
from __future__ import annotations

from benchmarks import common
from repro.core import engine

N_GUESTS = 6
LOGICAL_PER_GUEST = 8 * 1024


def make_engine():
    return common.make_symmetric_engine(N_GUESTS, LOGICAL_PER_GUEST,
                                        near_fraction=0.3)


def run(tier_pairs=("dram_cxl", "hbm_dram")):
    spec, _ = make_engine()
    traces = engine.guest_traces(spec, n_windows=24, accesses_per_window=8192)
    out = {}
    for pair in tier_pairs:
        res = {}
        for use_gpac in (False, True):
            spec, state = make_engine()
            _, series = engine.run_series(
                spec, state, traces, tier_pair=pair, policy="memtierd",
                use_gpac=use_gpac)
            res["gpac" if use_gpac else "baseline"] = float(
                series["throughput"][-6:].mean())
        res["delta"] = res["gpac"] / res["baseline"] - 1
        out[pair] = res
    out["paper_target"] = dict(dram_cxl=0.063, hbm_dram=0.053)
    return common.save("fig13_tier_pairs", out)


if __name__ == "__main__":
    r = run()
    for pair in ("dram_cxl", "hbm_dram"):
        print(f"{pair:9s} tput delta {r[pair]['delta']:+.1%} "
              f"(paper {r['paper_target'][pair]:+.1%})")
