"""Fig. 16: histogram of hot base pages per huge page, per workload.

Redis: mode at small counts (heavily skewed); Hash: mode around ~30% of
subpages (the paper's ~150/512).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import init_state, telemetry
from repro.core import address_space as asp


def run():
    out = {}
    for w in ("redis", "hash"):
        cfg = common.guest_config()
        state = init_state(cfg)
        trace = common.workload_trace(w, n_windows=4)
        for win in range(trace.shape[0]):
            state = asp.record_accesses(cfg, state, jnp.asarray(trace[win]))
        hot = telemetry.hot_mask(cfg, state, "ipt")
        per_hp = np.asarray(telemetry.hot_subpages_per_hp(cfg, state, hot))
        per_hp = per_hp[per_hp > 0]
        hist = np.bincount(per_hp, minlength=cfg.hp_ratio + 1)
        out[w] = dict(hist=hist.tolist(),
                      mode=int(np.argmax(hist[1:]) + 1),
                      median=float(np.median(per_hp)))
    res = dict(
        **out,
        redis_more_skewed_than_hash=out["redis"]["median"] < out["hash"]["median"],
    )
    return common.save("fig16_scatter_hist", res)


if __name__ == "__main__":
    r = run()
    for w in ("redis", "hash"):
        print(f"{w:6s} mode={r[w]['mode']:3d}/{common.HP_RATIO} "
              f"median={r[w]['median']:.0f}")
    print("redis more skewed than hash:", r["redis_more_skewed_than_hash"])
