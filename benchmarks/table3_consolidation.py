"""Table 3: per-workload CL, #selected hot 4K pages, consolidation time.

The paper consolidates 4k-950k pages in 36ms-7.3s (kernel memcpy path). Here
we report (a) simulation-scale selected pages + wall time of the jitted
consolidation pass, and (b) the *projected* device time of the data copy at
paper scale from the consolidate kernel's bytes / HBM bandwidth -- the TPU
analogue of Table 3's cost.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import filter as pfilter
from repro.core import gpac, init_state, telemetry
from repro.core import address_space as asp
from repro.data import traces as tr

HBM_BW = 819e9
PAGE_BYTES = 4096


def run():
    out = {}
    for w in ("masim", "redis", "memcached", "hash", "ocean_ncp"):
        cfg = common.guest_config(cl=common.scaled_cl(w))
        state = init_state(cfg)
        trace = common.workload_trace(w, n_windows=2)
        for win in range(trace.shape[0]):
            state = asp.record_accesses(cfg, state, jnp.asarray(trace[win]))
        hot = telemetry.hot_mask(cfg, state, "ipt")
        cand = int(np.asarray(
            pfilter.candidate_mask(cfg, state, hot)).sum())
        max_batches = max(1, -(-cand // cfg.hp_ratio))
        # measure the jitted consolidation pass (compile excluded)
        st2 = gpac.gpac_maintenance(cfg, state, "ipt", max_batches)
        with common.Timer() as t:
            st2 = gpac.gpac_maintenance(cfg, state, "ipt", max_batches)
            jnp.asarray(st2.gpt).block_until_ready()
        moved = int(st2.stats["consolidated_pages"]) - int(
            state.stats["consolidated_pages"])
        # projected copy time at paper scale: selected_pages x 4 KB / HBM BW
        paper_pages = tr.PAPER_SELECTED_PAGES[w]
        projected_ms = paper_pages * PAGE_BYTES / HBM_BW * 1e3
        out[w] = dict(
            cl=cfg.cl, candidates=cand, consolidated=moved,
            sim_wall_ms=round(t.ms, 1),
            paper_selected_pages=paper_pages,
            paper_time_ms=dict(masim=36, redis=840, memcached=1220,
                               hash=3363, ocean_ncp=7329)[w],
            projected_tpu_copy_ms=round(projected_ms, 3),
        )
    return common.save("table3_consolidation", out)


if __name__ == "__main__":
    for w, d in run().items():
        print(f"{w:10s} CL={d['cl']:3d} cand={d['candidates']:6d} "
              f"moved={d['consolidated']:6d} sim={d['sim_wall_ms']:8.1f}ms "
              f"projected_tpu_copy={d['projected_tpu_copy_ms']:7.3f}ms "
              f"(paper {d['paper_time_ms']}ms)")
