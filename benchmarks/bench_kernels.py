"""Registry-driven kernel micro-benchmark (DESIGN.md §4, §16).

Walks every :class:`repro.kernels.registry.KernelSpec` that carries an
``example()`` thunk and times its two backends on that exact input: the
jitted jnp reference (the engine's production path off-TPU) and the Pallas
kernel in ``interpret=True`` mode (what CI correctness-tests; native
lowering needs real TPU hardware). The interpret ratio is **informational**
-- it bounds nothing about TPU performance -- but it catches two real
regressions: a kernel whose example stops running at all, and a reference
whose compiled wall clock drifts by orders of magnitude.

One warmup call per backend (compile/trace), then best-of-``REPEATS`` wall
clock, same discipline as ``bench_engine``. Writes
``experiments/benchmarks/bench_kernels.json``.
"""
from __future__ import annotations

import time

import jax

from benchmarks import common, registry as suites
from repro.kernels import registry

NAME = "bench_kernels"
REPEATS = 5


def _time_call(fn, args, kwargs) -> float:
    jax.block_until_ready(fn(*args, **kwargs))  # warmup (compile/trace)
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> dict:
    rows = []
    skipped = []
    for spec in registry.all_kernels():
        if spec.example is None:
            skipped.append(spec.name)
            continue
        args, kwargs = spec.example()

        def ref_call(*a, **kw):
            return registry.dispatch(spec.name, "xla", *a, **kw)

        def pallas_call(*a, **kw):
            return registry.dispatch(spec.name, "pallas", *a, **kw)

        # both timed through the one dispatch site, eagerly: the examples
        # carry static Python ints (n_bins, k) a bare jit would trace
        ref_s = _time_call(ref_call, args, kwargs)
        pallas_s = _time_call(pallas_call, args, kwargs)
        row = dict(
            kernel=spec.name,
            description=spec.description,
            ref_s=ref_s,
            pallas_interpret_s=pallas_s,
            interpret_ratio=pallas_s / ref_s,
        )
        rows.append(row)
        print(f"  {spec.name:<20} ref {ref_s*1e3:8.2f} ms  "
              f"pallas(interpret) {pallas_s*1e3:8.2f} ms  "
              f"ratio {row['interpret_ratio']:8.1f}x")
    for name in skipped:
        print(f"  {name:<20} skipped: no example() registered")
    payload = dict(
        suite=NAME,
        description=suites.describe(NAME),
        backend=jax.default_backend(),
        repeats=REPEATS,
        interpret_mode=True,
        kernels=rows,
        skipped=skipped,
    )
    common.save(NAME, payload)
    return payload


if __name__ == "__main__":
    run()
