"""Fig. 6: hot-region view of the (host) address space before/after GPAC.

DAMON-style dump: per huge page, the host-visible access count, before and
after consolidation. The paper's observation: scattered warm regions collapse
into a few intensely hot regions after GPAC.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import gpac, init_state
from repro.core import address_space as asp


def hot_region_stats(host_counts: np.ndarray, hot_thresh: float = 0.5):
    """Contiguous runs of huge pages above hot_thresh x max count."""
    hot = host_counts > hot_thresh * max(host_counts.max(), 1)
    runs, run = [], 0
    for h in hot:
        if h:
            run += 1
        elif run:
            runs.append(run)
            run = 0
    if run:
        runs.append(run)
    return dict(n_hot_pages=int(hot.sum()), n_regions=len(runs),
                max_run=max(runs, default=0))


def run():
    cfg = common.guest_config(cl=common.scaled_cl("redis"))
    trace = common.workload_trace("redis", n_windows=8)
    dumps = {}
    for use_gpac in (False, True):
        state = init_state(cfg)
        for w in range(trace.shape[0]):
            state = asp.record_accesses(cfg, state, jnp.asarray(trace[w]))
            if use_gpac:
                state = gpac.gpac_maintenance(cfg, state, "ipt", 16)
        counts = np.asarray(state.host_counts)
        dumps["gpac" if use_gpac else "baseline"] = dict(
            host_counts=counts.tolist(), **hot_region_stats(counts))
    res = dict(
        **dumps,
        consolidated=dumps["gpac"]["n_hot_pages"]
        < dumps["baseline"]["n_hot_pages"],
    )
    return common.save("fig6_heatmap", res)


if __name__ == "__main__":
    r = run()
    for k in ("baseline", "gpac"):
        d = r[k]
        print(f"{k:9s} hot_hp={d['n_hot_pages']:4d} regions={d['n_regions']:3d} "
              f"max_run={d['max_run']}")
    print("hotness consolidated:", r["consolidated"])
