"""Fig. 2: CDF of accessed base pages per huge page, per workload.

Paper claim to match: Memcached has ~85% of huge pages with <100/512 (~20%)
subpages accessed; Masim is maximally skewed; Liblinear/Roms are dense.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import init_state, metrics
from repro.core import address_space as asp
from repro.core import telemetry as tele
import jax.numpy as jnp

WORKLOADS = ("masim", "redis", "memcached", "hash", "ocean_ncp", "liblinear")


def run():
    out = {}
    for w in WORKLOADS:
        cfg = common.guest_config()
        state = init_state(cfg)
        trace = common.workload_trace(w, n_windows=4)
        for win in range(trace.shape[0]):
            state = asp.record_accesses(cfg, state, jnp.asarray(trace[win]))
        per_hp = np.asarray(tele.accessed_subpages_per_hp(cfg, state))
        cdf = metrics.skew_cdf(per_hp, cfg.hp_ratio)
        # fraction of huge pages with < 20% of subpages accessed (the paper's
        # "<100 of 512" line, scaled)
        thresh = max(1, int(0.2 * cfg.hp_ratio))
        out[w] = dict(
            cdf=cdf.tolist(),
            skewed_fraction_20pct=float(cdf[thresh]),
            median_accessed=float(np.median(per_hp[per_hp > 0]))
            if (per_hp > 0).any() else 0.0,
        )
    checks = dict(
        memcached_mostly_skewed=out["memcached"]["skewed_fraction_20pct"] > 0.6,
        masim_maximal=out["masim"]["median_accessed"] <= 1.0,
        liblinear_dense=out["liblinear"]["skewed_fraction_20pct"] < 0.1,
    )
    return common.save("fig2_skew_cdf", dict(workloads=out, checks=checks))


if __name__ == "__main__":
    r = run()
    for w, d in r["workloads"].items():
        print(f"{w:12s} skewed(<20%)={d['skewed_fraction_20pct']:.2f} "
              f"median={d['median_accessed']:.0f}/{common.HP_RATIO}")
    print("checks:", r["checks"])
