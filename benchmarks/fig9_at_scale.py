"""Figs. 9/10/12: multi-tenant at-scale benchmark.

Six guests (each a Redis instance) share one host under near-memory pressure;
Memtierd / TPP / AutoNUMA at the host, GPAC optionally in every guest.
Reports per-VM throughput delta (Fig. 9), near-memory distribution (Fig. 10),
and modeled far-memory accesses / stalls (Fig. 12's counters).

Paper: Memtierd+GPAC ~ +13% avg, TPP+GPAC ~ +11%, AutoNUMA+GPAC ~ +1.6%.

:func:`run_pod` is the pod-size variant (ISSUE 5): hundreds of guests on the
host-partitioned engine, driven by an on-device :class:`engine.SynthTrace`
-- each window's accesses are generated inside the scan, so no
``[n_guests, n_windows, k]`` trace is ever host-materialized (at 256 guests
x 24 windows x 8192 accesses that array alone would be ~192 MB, growing
linearly with the fleet).
"""
from __future__ import annotations

import os
import pathlib
import time

import numpy as np

from benchmarks import common
from repro.core import engine

ROOT = pathlib.Path(__file__).resolve().parent.parent

N_GUESTS = 6
LOGICAL_PER_GUEST = 8 * 1024
WINDOWS = 24
ACCESSES = 8192
# scan-fuse the window loop in chunks of this many windows (one device->host
# metric transfer per chunk; see repro.core.engine.run)
WINDOWS_PER_STEP = 12

# pod-size defaults (run_pod): kept CPU-tractable per guest so the fleet
# dimension dominates
POD_GUESTS = 256
POD_LOGICAL_PER_GUEST = 512
POD_WINDOWS = 12
POD_ACCESSES = 1024


def make_engine():
    return common.make_symmetric_engine(N_GUESTS, LOGICAL_PER_GUEST,
                                        near_fraction=0.25)


def run(policies=("memtierd", "tpp", "autonuma"), mesh="auto"):
    """``mesh="auto"`` shards the guest axis over every local device (the
    sharded driver is bit-for-bit equal to the unsharded one, so the figure
    is identical either way); ``mesh=None`` forces single-device."""
    if mesh == "auto":
        mesh = common.default_guest_mesh()
    spec, _ = make_engine()
    traces = engine.guest_traces(spec, n_windows=WINDOWS,
                                 accesses_per_window=ACCESSES)
    out = {}
    for policy in policies:
        res = {}
        for use_gpac in (False, True):
            spec, state = make_engine()
            state, series = engine.run_series(
                spec, state, traces, policy=policy, use_gpac=use_gpac,
                windows_per_step=WINDOWS_PER_STEP, mesh=mesh)
            res["gpac" if use_gpac else "baseline"] = dict(
                tput=series["throughput"][-6:].mean(axis=0).tolist(),
                near_blocks=series["near_blocks"][-1].tolist(),
                hit=series["hit_rate"][-6:].mean(axis=0).tolist(),
            )
        b = np.asarray(res["baseline"]["tput"])
        g = np.asarray(res["gpac"]["tput"])
        res["per_vm_delta"] = ((g - b) / b).tolist()
        res["avg_delta"] = float(((g - b) / b).mean())
        # Fig. 12 counters: far accesses ~ (1-hit) share, stall proxy
        bh = np.asarray(res["baseline"]["hit"])
        gh = np.asarray(res["gpac"]["hit"])
        res["far_access_reduction"] = float(
            1 - (1 - gh).sum() / max((1 - bh).sum(), 1e-9))
        out[policy] = res
    out["paper_target"] = dict(memtierd=0.13, tpp=0.11, autonuma=0.016)
    out["n_devices"] = 1 if mesh is None else mesh.shape["guest"]
    # host-state footprint of this run: the sharded driver partitions the
    # host near tier by block ranges (DESIGN.md §11), so per-device bytes
    # scale ~1/n_devices -- the lever that takes this figure to hundreds of
    # guests on a pod
    out["host_state"] = common.host_state_report(spec, mesh)
    return common.save("fig9_at_scale", out)


def _pod_fleet(n_lanes: int, logical_per_guest: int):
    guests = tuple(
        engine.GuestSpec(n_logical=logical_per_guest, cl=8, gpa_slack=1.0,
                         workload="redis", seed=g)
        for g in range(n_lanes))
    host = engine.HostSpec(hp_ratio=common.HP_RATIO, near_fraction=0.25,
                           base_elems=2, cl=8, ipt_min_hits=1)
    return engine.build(guests, host)


def _pod_migration_run(spec, n_guests: int, migrations: int,
                       n_windows: int, accesses: int,
                       policy: str, mesh) -> dict:
    """Two churn segments with ``migrations`` live handoffs between them.

    Lanes ``n_guests .. n_guests+migrations-1`` boot vacant (crash-style
    reclaim at init); mid-run, guest ``i`` hands off into spare
    ``n_guests + i``. Sources sit at the head of the lane range and spares
    at the tail, so on a sharded mesh every handoff crosses guest shards.
    """
    from repro.launch import migration

    active = np.ones((spec.n_guests,), bool)
    active[n_guests:] = False
    cs = engine.init_churn(spec, active=active)
    half = max(1, n_windows // 2)
    seg = engine.SynthTrace(n_windows=half, accesses_per_window=accesses)
    cs, s1 = engine.run_churn(spec, cs, seg, mesh=mesh, policy=policy,
                              use_gpac=True, windows_per_step=half)
    manifests = []
    for i in range(migrations):
        cs, man = migration.migrate_guest(spec, cs, src=i, dst=n_guests + i)
        manifests.append(dict(src=i, dst=n_guests + i, **man))
    seg2 = engine.SynthTrace(n_windows=n_windows - half,
                             accesses_per_window=accesses)
    cs, s2 = engine.run_churn(spec, cs, seg2, mesh=mesh, policy=policy,
                              use_gpac=True,
                              windows_per_step=n_windows - half)
    nh = np.concatenate([s1["near_hits"], s2["near_hits"]])
    fh = np.concatenate([s1["far_hits"], s2["far_hits"]])
    act = np.concatenate([s1["active"], s2["active"]])
    tail = max(1, n_windows // 4)
    hit = nh.sum(axis=1) / np.maximum((nh + fh).sum(axis=1), 1)
    return dict(
        migrations=manifests,
        migration_window=int(half),
        hit_rate_tail=float(hit[-tail:].mean()),
        active_per_window=act.sum(axis=1).astype(int).tolist(),
        active_final=int(np.asarray(cs.active).sum()),
    )


def run_pod(n_guests: int = POD_GUESTS,
            logical_per_guest: int = POD_LOGICAL_PER_GUEST,
            n_windows: int = POD_WINDOWS,
            accesses: int = POD_ACCESSES,
            policy: str = "memtierd",
            mesh="auto",
            migrations: int = 0):
    """Fig. 9 at pod scale: ``n_guests`` Redis-like guests on the
    host-partitioned engine with on-device trace synthesis.

    Returns the same per-policy delta structure as :func:`run` (one policy,
    GPAC off/on) plus the trace-residency accounting: per-device synthesis
    state is O(n_local_guests * accesses_per_window), vs the
    O(n_guests * n_windows * k) host array the packed path would need.

    ``migrations > 0`` switches to the live-migration protocol (DESIGN.md
    §17): that many vacant spare lanes join the fleet at the tail, the run
    goes through the churn engine in two segments, and between them each
    of the first ``migrations`` guests is handed off live into a spare.
    The payload then reports the per-handoff byte manifests instead of the
    GPAC off/on delta. Either way the payload carries the host-state
    footprint and the collective-volume accounting of the run
    (:func:`repro.core.sharding.collective_bytes`).
    """
    from repro.core import sharding

    if mesh == "auto":
        mesh = common.default_guest_mesh()
    n_shards = 1 if mesh is None else mesh.shape["guest"]
    spec, _ = _pod_fleet(n_guests + migrations, logical_per_guest)
    sharding.reset_collective_bytes()
    if migrations:
        res = _pod_migration_run(spec, n_guests, migrations, n_windows,
                                 accesses, policy, mesh)
        name = "fig9_at_pod_scale_migration"
    else:
        synth = engine.SynthTrace(n_windows=n_windows,
                                  accesses_per_window=accesses)
        res = {}
        for use_gpac in (False, True):
            state = engine.init_engine_state(spec)
            state, series = engine.run_series(
                spec, state, synth, policy=policy, use_gpac=use_gpac,
                windows_per_step=max(1, n_windows // 2), mesh=mesh)
            tail = max(1, n_windows // 4)
            res["gpac" if use_gpac else "baseline"] = dict(
                tput=series["throughput"][-tail:].mean(axis=0).tolist(),
                near_blocks=series["near_blocks"][-1].tolist(),
                hit=series["hit_rate"][-tail:].mean(axis=0).tolist(),
            )
        b = np.asarray(res["baseline"]["tput"])
        g = np.asarray(res["gpac"]["tput"])
        res["avg_delta"] = float(((g - b) / b).mean())
        name = "fig9_at_pod_scale"
    coll = sharding.collective_bytes()
    # exact per-psum payload bytes, recorded at trace time; merge_window /
    # host_exchange fire once per window (stride 1), host_chunk_exit once
    # per scan chunk
    per_window = coll.get("merge_window", 0) + coll.get("host_exchange", 0)
    n_chunks = -(-n_windows // max(1, n_windows // 2))
    out = {
        policy: res,
        "n_guests": n_guests,
        "n_migrations": migrations,
        "n_devices": n_shards,
        "host_state": common.host_state_report(spec, mesh),
        "collective": dict(
            per_site_bytes=coll,
            per_window_bytes=per_window,
            bytes_per_run=per_window * n_windows
            + coll.get("host_chunk_exit", 0) * n_chunks,
        ),
        # no [n_guests, n_windows, k] array exists anywhere on this path
        "synth_trace_bytes_per_device_window":
            -(-n_guests // n_shards) * accesses * 4,
        "array_trace_bytes_avoided": n_guests * n_windows * accesses * 4,
    }
    import jax

    if jax.process_count() > 1 and jax.process_index() != 0:
        return out  # one writer: only the coordinator saves the artifact
    return common.save(name, out)


def run_pod_multihost(n_guests: int = 1024, migrations: int = 2,
                      num_processes: int = 2, devices_per_process: int = 2,
                      timeout: float = 3600.0):
    """:func:`run_pod` under a coordinated multi-process mesh (§17).

    Spawns ``num_processes`` coordinated workers (each pinned to
    ``devices_per_process`` CPU devices) running
    ``scripts/pod_multihost_worker.py`` -- a dedicated entry because
    ``jax.distributed.initialize`` must precede the first jax computation,
    and importing this module already builds ``jnp`` constants. Returns the
    coordinator-saved payload plus the launch wall time.
    """
    from repro.launch import multihost

    t0 = time.perf_counter()
    multihost.launch_check(
        str(ROOT / "scripts" / "pod_multihost_worker.py"),
        marker="POD MULTIHOST OK",
        args=(str(n_guests), str(migrations)),
        num_processes=num_processes,
        devices_per_process=devices_per_process, timeout=timeout,
        cwd=str(ROOT))
    dt = time.perf_counter() - t0
    import json

    with open(os.path.join(str(ROOT), common.OUT_DIR,
                           "fig9_at_pod_scale_migration.json")) as f:
        out = json.load(f)
    out["multihost"] = dict(num_processes=num_processes,
                            devices_per_process=devices_per_process,
                            wall_s=dt)
    return common.save("fig9_at_pod_scale_migration", out)


if __name__ == "__main__":
    r = run()
    for p in ("memtierd", "tpp", "autonuma"):
        d = r[p]
        print(f"{p:9s} avg tput delta {d['avg_delta']:+.1%} "
              f"(paper {r['paper_target'][p]:+.1%}); "
              f"far-access reduction {d['far_access_reduction']:.1%}")
        print(f"          near blocks baseline {d['baseline']['near_blocks']}"
              f" -> gpac {d['gpac']['near_blocks']}")
    p = run_pod()
    print(f"pod scale ({p['n_guests']} guests, {p['n_devices']} device(s)): "
          f"memtierd avg tput delta {p['memtierd']['avg_delta']:+.1%}; "
          f"synth residency/device/window "
          f"{p['synth_trace_bytes_per_device_window']/2**20:.2f} MB vs "
          f"{p['array_trace_bytes_avoided']/2**20:.0f} MB host array avoided")
