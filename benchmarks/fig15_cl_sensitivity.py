"""Fig. 15: Consolidation-Limit sensitivity (Hash workload, Memtierd).

Paper: DRAM savings grow with CL and saturate past the workload's hot-subpage
mode (~150/512 for hash -> savings saturate at CL ~250), with slight perf
cost at aggressive CL.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common


def run():
    cls = [max(2, int(c * common.HP_RATIO / 512))
           for c in (50, 100, 150, 250, 350, 500)]
    out = {"baseline": {}, "sweep": {}}
    _, _, base = common.run_single_guest(
        "hash", use_gpac=False, policy="memtierd", near_fraction=0.9)
    out["baseline"] = dict(near=common.steady(base["near_usage"]),
                           tput=common.steady(base["tput"]))
    for cl in cls:
        _, _, s = common.run_single_guest(
            "hash", use_gpac=True, policy="memtierd", near_fraction=0.9,
            cl=cl)
        out["sweep"][cl] = dict(
            near=common.steady(s["near_usage"]),
            tput=common.steady(s["tput"]),
            saving=1 - common.steady(s["near_usage"])
            / max(out["baseline"]["near"], 1e-9),
        )
    savings = [out["sweep"][c]["saving"] for c in cls]
    out["monotone_then_saturating"] = bool(
        savings[-1] >= savings[0] and
        abs(savings[-1] - savings[-2]) < 0.1)
    return common.save("fig15_cl_sensitivity", out)


if __name__ == "__main__":
    r = run()
    print(f"baseline near={r['baseline']['near']:.2f}")
    for cl, d in r["sweep"].items():
        print(f"CL={cl:3} near={d['near']:.2f} saving={d['saving']:+.1%} "
              f"tput={d['tput']:.0f}")
    print("monotone then saturating:", r["monotone_then_saturating"])
