"""Run the full benchmark suite (one module per paper table/figure) and print
a summary against the paper's claims. ``python -m benchmarks.run``.

The suite table lives in :mod:`benchmarks.registry` (one registry shared by
this driver and the individual modules). ``--list`` enumerates the registered
suites; ``--only <name>`` (repeatable) runs a subset -- e.g. CI's fast lane is
``--only bench_engine --only fig2_skew_cdf``; ``--json <path>`` dumps a
machine-readable summary (per-benchmark results, timings, failures) so CI can
archive it alongside ``BENCH_engine.json``."""
from __future__ import annotations

import argparse
import json
import sys
import time

from benchmarks import registry


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--list", action="store_true",
        help="list registered benchmark suites and exit")
    ap.add_argument(
        "--only", action="append", metavar="NAME",
        help="run only this benchmark (repeatable); see --list for names")
    ap.add_argument(
        "--json", metavar="PATH",
        help="write a machine-readable run summary to PATH")
    args = ap.parse_args(argv)
    if args.list:
        width = max(map(len, registry.names()))
        for name in registry.names():
            print(f"{name:<{width}}  {registry.describe(name)}")
        return 0
    if args.only:
        unknown = sorted(set(args.only) - set(registry.names()))
        if unknown:
            ap.error(
                f"unknown benchmark(s) {unknown}; have {sorted(registry.names())}")
    suite = [n for n in registry.names() if not args.only or n in args.only]
    if args.json:
        try:  # fail before the suite runs, not minutes after -- append mode
            open(args.json, "a").close()  # checks writability w/o truncating
        except OSError as e:
            ap.error(f"cannot write --json path {args.json!r}: {e}")

    results = {}
    timings = {}
    t_total = time.time()
    failures = []
    for name in suite:
        t0 = time.time()
        print(f"\n=== {name} " + "=" * (60 - len(name)))
        try:
            results[name] = registry.load(name).run()
            timings[name] = time.time() - t0
            print(f"    ok ({timings[name]:.1f}s)")
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            timings[name] = time.time() - t0
            print(f"    FAILED: {e!r}")

    print("\n" + "=" * 70)
    print("SUMMARY vs paper claims")
    print("=" * 70)
    r = results
    if "fig8_dram_reduction" in r:
        d = r["fig8_dram_reduction"]
        print(f"Fig 8  near-memory reduction (skewed avg): "
              f"{d['avg_near_reduction_skewed']:.1%} (paper ~72%), "
              f"perf {d['avg_perf_delta_skewed']:+.2%} (paper -0.86%)")
    if "fig9_at_scale" in r:
        d = r["fig9_at_scale"]
        for p in ("memtierd", "tpp", "autonuma"):
            print(f"Fig 9  {p}+GPAC throughput: {d[p]['avg_delta']:+.1%} "
                  f"(paper {d['paper_target'][p]:+.1%})")
    if "fig11_migration" in r:
        d = r["fig11_migration"]
        print(f"Fig 11 promoted {d['promoted_reduction']:.1%} less "
              f"(paper 64%), demoted {d['demoted_reduction']:.1%} less "
              f"(paper 87%)")
    if "fig13_tier_pairs" in r:
        d = r["fig13_tier_pairs"]
        print(f"Fig 13 DRAM/CXL {d['dram_cxl']['delta']:+.1%} (paper +6.3%); "
              f"Fig 14 HBM/DRAM {d['hbm_dram']['delta']:+.1%} (paper +5.3%)")
    if "fig17_pressure" in r:
        d = r["fig17_pressure"]
        print(f"Fig 17 benefit shrinks with more near memory: "
              f"{d['benefit_shrinks_with_more_near']}")
    if "bench_engine" in r:
        d = r["bench_engine"]
        print(f"Engine  speedup at n_guests>=8: "
              f"{d['min_speedup_at_scale']:.2f}x "
              f"(target >= {d['target_speedup_at_scale']}x)")
    total_s = time.time() - t_total
    print(f"\ntotal {total_s:.1f}s; "
          f"{len(suite)-len(failures)}/{len(suite)} benchmarks ok")
    for name, err in failures:
        print(f"  FAILED {name}: {err}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(
                dict(
                    results=results,
                    timings_s=timings,
                    failures=dict(failures),
                    total_s=total_s,
                    ran=suite,
                ),
                f, indent=1, default=float,
            )
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
