"""Run the full benchmark suite (one module per paper table/figure) and print
a summary against the paper's claims. ``python -m benchmarks.run``."""
from __future__ import annotations

import sys
import time

from benchmarks import (
    fig2_skew_cdf,
    fig6_heatmap,
    fig7_memdist,
    fig8_dram_reduction,
    fig9_at_scale,
    fig11_migration,
    fig13_tier_pairs,
    fig15_cl_sensitivity,
    fig16_scatter_hist,
    fig17_pressure,
    table3_consolidation,
)

SUITE = [
    ("fig2_skew_cdf", fig2_skew_cdf),
    ("table3_consolidation", table3_consolidation),
    ("fig6_heatmap", fig6_heatmap),
    ("fig7_memdist", fig7_memdist),
    ("fig8_dram_reduction", fig8_dram_reduction),
    ("fig9_at_scale", fig9_at_scale),
    ("fig11_migration", fig11_migration),
    ("fig13_tier_pairs", fig13_tier_pairs),
    ("fig15_cl_sensitivity", fig15_cl_sensitivity),
    ("fig16_scatter_hist", fig16_scatter_hist),
    ("fig17_pressure", fig17_pressure),
]


def main():
    results = {}
    t_total = time.time()
    failures = []
    for name, mod in SUITE:
        t0 = time.time()
        print(f"\n=== {name} " + "=" * (60 - len(name)))
        try:
            results[name] = mod.run()
            print(f"    ok ({time.time()-t0:.1f}s)")
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"    FAILED: {e!r}")

    print("\n" + "=" * 70)
    print("SUMMARY vs paper claims")
    print("=" * 70)
    r = results
    if "fig8_dram_reduction" in r:
        d = r["fig8_dram_reduction"]
        print(f"Fig 8  near-memory reduction (skewed avg): "
              f"{d['avg_near_reduction_skewed']:.1%} (paper ~72%), "
              f"perf {d['avg_perf_delta_skewed']:+.2%} (paper -0.86%)")
    if "fig9_at_scale" in r:
        d = r["fig9_at_scale"]
        for p in ("memtierd", "tpp", "autonuma"):
            print(f"Fig 9  {p}+GPAC throughput: {d[p]['avg_delta']:+.1%} "
                  f"(paper {d['paper_target'][p]:+.1%})")
    if "fig11_migration" in r:
        d = r["fig11_migration"]
        print(f"Fig 11 promoted {d['promoted_reduction']:.1%} less "
              f"(paper 64%), demoted {d['demoted_reduction']:.1%} less "
              f"(paper 87%)")
    if "fig13_tier_pairs" in r:
        d = r["fig13_tier_pairs"]
        print(f"Fig 13 DRAM/CXL {d['dram_cxl']['delta']:+.1%} (paper +6.3%); "
              f"Fig 14 HBM/DRAM {d['hbm_dram']['delta']:+.1%} (paper +5.3%)")
    if "fig17_pressure" in r:
        d = r["fig17_pressure"]
        print(f"Fig 17 benefit shrinks with more near memory: "
              f"{d['benefit_shrinks_with_more_near']}")
    print(f"\ntotal {time.time()-t_total:.1f}s; "
          f"{len(SUITE)-len(failures)}/{len(SUITE)} benchmarks ok")
    for name, err in failures:
        print(f"  FAILED {name}: {err}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
