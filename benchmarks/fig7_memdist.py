"""Fig. 7: memory distribution (near vs far, % of guest RSS) over time for
Redis under Memtierd, with and without GPAC.

Paper: Memtierd migrates ~85% of RSS to near memory; with GPAC only ~33%
moves near at equal performance.
"""
from __future__ import annotations

from benchmarks import common


def run():
    out = {}
    for use_gpac in (False, True):
        _, _, series = common.run_single_guest(
            "redis", use_gpac=use_gpac, policy="memtierd",
            near_fraction=0.9,  # §5.2: no near-memory pressure
        )
        out["gpac" if use_gpac else "baseline"] = dict(
            near_usage=series["near_usage"],
            hit_rate=series["hit_rate"],
            steady_near=common.steady(series["near_usage"]),
            steady_hit=common.steady(series["hit_rate"]),
        )
    b, g = out["baseline"], out["gpac"]
    res = dict(
        **out,
        near_reduction=1 - g["steady_near"] / max(b["steady_near"], 1e-9),
        hit_delta=g["steady_hit"] - b["steady_hit"],
    )
    return common.save("fig7_memdist", res)


if __name__ == "__main__":
    r = run()
    print(f"baseline steady near usage: {r['baseline']['steady_near']:.2%} "
          f"hit {r['baseline']['steady_hit']:.3f}")
    print(f"gpac     steady near usage: {r['gpac']['steady_near']:.2%} "
          f"hit {r['gpac']['steady_hit']:.3f}")
    print(f"near-memory reduction: {r['near_reduction']:.1%} "
          f"(paper: 85% -> 33% of RSS)")
