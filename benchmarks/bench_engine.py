"""Engine perf benchmark: the shared scan-fused engine driver vs the seed
per-guest/per-window reference path.

Times ``simulate.run_multi_guest`` (now a shim over the unified
``repro.core.engine.run``: guest-batched windows, scan-fused window loop,
chunked host transfer) against ``simulate.run_multi_guest_reference``
(unrolled per-guest ops, one host sync per window) across an
(n_guests, n_logical, n_windows) grid. Trace generation and jit compilation
are excluded (one warmup run per path, then best-of-``REPEATS`` wall clock).

Writes ``BENCH_engine.json`` at the repo root (the perf-trajectory artifact
CI archives) and ``experiments/benchmarks/<NAME>.json`` (``NAME`` comes from
the shared suite registry, ``benchmarks.registry``).
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks import common, registry
from repro.core import simulate
from repro.data import traces as tr

NAME = "bench_engine"
assert NAME in registry.SUITES, "suite must be registered in benchmarks.registry"

REPEATS = 3
HP_RATIO = 32
ACCESSES = 2048

# (n_guests, logical_per_guest, n_windows) -- n_guests >= 8 rows are the
# at-scale regime the acceptance criterion targets
GRID = (
    (2, 1024, 12),
    (4, 1024, 12),
    (8, 1024, 12),
    (12, 512, 12),
)


def _bench_case(n_guests: int, logical_per_guest: int, n_windows: int) -> dict:
    traces = np.stack([
        tr.generate(tr.TraceSpec(
            "redis", n_logical=logical_per_guest, hp_ratio=HP_RATIO,
            n_windows=n_windows, accesses_per_window=ACCESSES, seed=g))
        for g in range(n_guests)])

    def make():
        return simulate.make_multi_guest(
            n_guests=n_guests, logical_per_guest=logical_per_guest,
            hp_ratio=HP_RATIO, near_fraction=0.25, base_elems=2, cl=8)

    case = dict(
        n_guests=n_guests, logical_per_guest=logical_per_guest,
        n_logical=n_guests * logical_per_guest, n_windows=n_windows,
        hp_ratio=HP_RATIO, accesses_per_window=ACCESSES)
    for name, runner in (
        ("reference", simulate.run_multi_guest_reference),
        ("engine", simulate.run_multi_guest),
    ):
        mg, state = make()
        t0 = time.perf_counter()
        runner(mg, state, traces)  # warmup: trace + compile, excluded
        case[f"{name}_warmup_s"] = time.perf_counter() - t0
        best = float("inf")
        for _ in range(REPEATS):
            mg, state = make()
            t0 = time.perf_counter()
            _, series = runner(mg, state, traces)
            best = min(best, time.perf_counter() - t0)
        case[f"{name}_s"] = best
    case["speedup"] = case["reference_s"] / case["engine_s"]
    return case


def run() -> dict:
    cases = []
    for n_guests, logical_per_guest, n_windows in GRID:
        case = _bench_case(n_guests, logical_per_guest, n_windows)
        cases.append(case)
        print(f"  n_guests={n_guests:3d} n_logical={case['n_logical']:6d} "
              f"windows={n_windows:3d}: reference {case['reference_s']*1e3:8.1f} ms"
              f" engine {case['engine_s']*1e3:8.1f} ms"
              f" speedup {case['speedup']:5.2f}x")
    at_scale = [c["speedup"] for c in cases if c["n_guests"] >= 8]
    payload = dict(
        suite=NAME,
        description=registry.describe(NAME),
        backend=jax.default_backend(),
        repeats=REPEATS,
        cases=cases,
        min_speedup_at_scale=min(at_scale),
        target_speedup_at_scale=3.0,
        meets_target=min(at_scale) >= 3.0,
    )
    with open("BENCH_engine.json", "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return common.save(NAME, payload)


if __name__ == "__main__":
    r = run()
    print(f"min speedup at n_guests>=8: {r['min_speedup_at_scale']:.2f}x "
          f"(target >= {r['target_speedup_at_scale']}x) "
          f"-> {'OK' if r['meets_target'] else 'MISS'}")
