"""Engine perf benchmark: the shared scan-fused engine driver vs the seed
per-guest/per-window reference path, plus the guest-axis device-sharded
driver (``engine.run_sharded``).

Times ``simulate.run_multi_guest`` (now a shim over the unified
``repro.core.engine.run``: guest-batched windows, scan-fused window loop,
chunked host transfer) against ``simulate.run_multi_guest_reference``
(unrolled per-guest ops, one host sync per window) across an
(n_guests, n_logical, n_windows) grid, and -- when more than one device is
visible -- ``engine.run_series(mesh=...)`` sharded over the guest axis, on
both host paths: replicated host state (``engine_sharded_s``) and the
host-partitioned near tier (``host_sharded_s``, DESIGN.md §11, with the
measured per-device host-state bytes).
``n_devices`` comes from ``jax.local_device_count()``; CI forces 8 simulated
CPU devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``. Trace
generation and jit compilation are excluded (one warmup run per path, then
best-of-``REPEATS`` wall clock).

Every grid row also times the on-device ``SynthTrace`` source (``synth_s``:
the same guest identities generated inside the scan, DESIGN.md §12), and a
pod-size row (``POD``, n_guests >= 128) runs the synth path alone -- the
array path is skipped with a logged reason, since its host trace would be
O(n_guests * n_windows * k).

Every timed (case, runner) pair runs in a FRESH SUBPROCESS (``--worker``
mode): on a small shared-CPU container the in-process sequence let earlier
runners pollute later ones (allocator state, XLA autotuning, thermal
throttle), which made the ``sharded_no_slower_at_scale`` ratio flap. A
worker times exactly one runner and prints its JSON on stdout; the parent
merges and computes the ratios. Set ``BENCH_ENGINE_IN_PROCESS=1`` to fall
back to in-process timing (debugging, or environments where spawning is
expensive).

A steady-state churn case (ISSUE 6) times ``engine.run_churn`` under a
Poisson arrival/departure fault schedule over mixed drift workloads against
the plain driver on the same fleet (``churn_s`` / ``churn_vs_engine``), and
asserts INV-CRASH-RECLAIM-COMPLETE on the final state
(``reclaim_complete``).

Multi-host columns (ISSUE 10, DESIGN.md §17): every at-scale grid row with
``n_windows % 4 == 0`` also times the host-partitioned driver under a
stride-4 overlapped arbitration exchange (``overlap_s`` /
``overlap_speedup`` -- 4 windows ride one psum, with trace synthesis
prefetched behind the in-flight collective), and the payload carries the
wall clock of a small coordinated 2-process launch (``multihost_s``,
informational).

Writes ``BENCH_engine.json`` at the repo root (the perf-trajectory artifact
CI archives) and ``experiments/benchmarks/<NAME>.json`` (``NAME`` comes from
the shared suite registry, ``benchmarks.registry``).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
import warnings

import jax
import numpy as np

from benchmarks import common, registry
from repro.core import engine, faults, simulate
from repro.core.types import allocated_hp_mask
from repro.data import traces as tr

NAME = "bench_engine"
assert NAME in registry.SUITES, "suite must be registered in benchmarks.registry"

REPEATS = 5  # wall clock is noisy on small shared-CPU containers
HP_RATIO = 32
ACCESSES = 2048

# (n_guests, logical_per_guest, n_windows) -- n_guests >= 8 rows are the
# at-scale regime the acceptance criterion targets
GRID = (
    (2, 1024, 12),
    (4, 1024, 12),
    (8, 1024, 12),
    (12, 512, 12),
)

# pod-size configuration (ISSUE 5): only the on-device SynthTrace path runs
# here -- the array path would need a host [n_guests, n_windows, k] trace
# and is skipped with a logged reason
POD = (128, 256, 8)  # (n_guests, logical_per_guest, n_windows)

# steady-state churn fleet (ISSUE 6): Poisson arrival/departure over mixed
# drift workloads with a capacity shrink and a telemetry dropout
CHURN = (8, 512, 12)  # (n_guests, logical_per_guest, n_windows)


def _best_of(make, runner, traces, case, key) -> None:
    # block on the returned *state*, not just the host series: the drivers
    # dispatch asynchronously, and un-awaited final states would credit the
    # engine paths with work still in flight
    mg, state = make()
    t0 = time.perf_counter()
    jax.block_until_ready(runner(mg, state, traces)[0])  # warmup (compile)
    case[f"{key}_warmup_s"] = time.perf_counter() - t0
    best = float("inf")
    for _ in range(REPEATS):
        mg, state = make()
        t0 = time.perf_counter()
        jax.block_until_ready(runner(mg, state, traces)[0])
        best = min(best, time.perf_counter() - t0)
    case[f"{key}_s"] = best


def _bench_case(n_guests: int, logical_per_guest: int, n_windows: int,
                mesh, only: str | None = None) -> dict:
    traces = np.stack([
        tr.generate(tr.TraceSpec(
            "redis", n_logical=logical_per_guest, hp_ratio=HP_RATIO,
            n_windows=n_windows, accesses_per_window=ACCESSES, seed=g))
        for g in range(n_guests)])

    def make():
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            return simulate.make_multi_guest(
                n_guests=n_guests, logical_per_guest=logical_per_guest,
                hp_ratio=HP_RATIO, near_fraction=0.25, base_elems=2, cl=8)

    # one spec for every engine runner and the host-state report: the
    # geometry is static, so rebuilding pools/mappings per reader is waste
    spec = make()[0].spec()

    def run_engine(mg, state, t):
        return engine.run_series(spec, state, t)

    def run_sharded(mg, state, t):
        # replicated host state on every device (DESIGN.md §9)
        return engine.run_series(spec, state, t, mesh=mesh,
                                 host_sharded=False)

    def run_host_sharded(mg, state, t):
        # host state partitioned by block ranges (DESIGN.md §11)
        return engine.run_series(spec, state, t, mesh=mesh,
                                 host_sharded=True)

    def run_overlap(mg, state, t):
        # host-partitioned near tier + stride-4 overlapped arbitration
        # exchange (DESIGN.md §17): 4 windows ride ONE psum, and the next
        # group's trace synthesis issues behind the in-flight collective
        return engine.run_series(spec, state, t, mesh=mesh,
                                 host_sharded=True, arbitration_stride=4)

    # on-device synthesis (DESIGN.md §12): no [n_guests, n_windows, k]
    # array anywhere. Same redis workload at the same shapes as the array
    # rows (symmetric_spec guests all carry seed=0; decorrelation comes
    # from the global-gid key fold), timed on the SAME single-device driver
    # as engine_s so synth_vs_engine isolates the trace-source cost
    synth = engine.SynthTrace(n_windows=n_windows, accesses_per_window=ACCESSES)

    def run_synth(mg, state, t):
        return engine.run_series(spec, state, synth)

    # Pallas hot-path kernels (DESIGN.md §16), interpret mode on CPU: timed
    # on the smallest grid row only (interpretation is orders of magnitude
    # slower than compiled XLA and the ratio is informational -- the
    # bit-exactness pin lives in INV-KERNEL-BACKEND-EXACT, not here)
    def run_pallas(mg, state, t):
        return engine.run_series(spec, state, t, kernel_backend="pallas")

    case = dict(
        n_guests=n_guests, logical_per_guest=logical_per_guest,
        n_logical=n_guests * logical_per_guest, n_windows=n_windows,
        hp_ratio=HP_RATIO, accesses_per_window=ACCESSES,
        n_devices=1 if mesh is None else mesh.shape["guest"])
    if mesh is not None:
        report = common.host_state_report(spec, mesh)
        case["host_state_bytes_replicated"] = report["replicated_bytes_per_device"]
        case["host_state_bytes_per_device"] = report["sharded_bytes_per_device"]
        case["host_state_scaling"] = report["scaling"]
    runners = [
        ("reference", simulate.run_multi_guest_reference),
        ("engine", run_engine),
        ("synth", run_synth),
        ("pallas", run_pallas),
    ]
    if mesh is not None:
        runners.append(("engine_sharded", run_sharded))
        runners.append(("host_sharded", run_host_sharded))
        if n_windows % 4 == 0:  # host-sharded stride must divide the chunk
            runners.append(("overlap", run_overlap))
    if only is not None:
        runners = [(n, r) for n, r in runners if n == only]
        if not runners:
            raise ValueError(f"unknown runner {only!r}")
    for name, runner in runners:
        _best_of(make, runner, traces, case, name)
    if only is None:
        _finalize_case(case)
    return case


def _finalize_case(case: dict) -> None:
    """The cross-runner ratios, computed once every timing key is present
    (in one process, or merged from the per-runner worker subprocesses)."""
    case["speedup"] = case["reference_s"] / case["engine_s"]
    case["synth_vs_engine"] = case["engine_s"] / case["synth_s"]
    if "pallas_s" in case:
        # > 1 means the pallas-interpret path cost that much more than the
        # compiled XLA engine (expected on CPU; informational, never gated)
        case["pallas_vs_engine"] = case["pallas_s"] / case["engine_s"]
    if "engine_sharded_s" in case:
        # > 1 means the sharded driver beat the single-device engine
        case["sharded_speedup"] = case["engine_s"] / case["engine_sharded_s"]
        case["host_sharded_speedup"] = case["engine_s"] / case["host_sharded_s"]
    if "overlap_s" in case:
        # stride-4 overlapped exchange vs the single-device engine (§17);
        # vs host_sharded_speedup this isolates what batching 4 windows
        # into one psum buys back
        case["overlap_speedup"] = case["engine_s"] / case["overlap_s"]


def _pod_case(mesh) -> dict:
    """The >= 128-guest configuration only the SynthTrace path can run:
    each window's accesses are generated inside the scan (per-device
    residency O(n_local_guests * accesses_per_window)), while the array
    path would have to host-materialize the full trace first."""
    n_guests, logical_per_guest, n_windows = POD
    guests = tuple(
        engine.GuestSpec(n_logical=logical_per_guest, cl=8, gpa_slack=1.0,
                         workload="redis", seed=g)
        for g in range(n_guests))
    host = engine.HostSpec(hp_ratio=HP_RATIO, near_fraction=0.25,
                           base_elems=2, cl=8, ipt_min_hits=1)
    spec, _ = engine.build(guests, host)
    synth = engine.SynthTrace(n_windows=n_windows,
                              accesses_per_window=ACCESSES)
    array_mb = n_guests * n_windows * ACCESSES * 4 / 2**20
    skip_reason = (
        f"array path skipped: host-materializing int32[{n_guests}, "
        f"{n_windows}, {ACCESSES}] would allocate {array_mb:.0f} MB and "
        f"ship it through pad_guest_rows every sharded run")
    print(f"  pod row ({n_guests} guests): {skip_reason}")

    def make():
        return None, engine.init_engine_state(spec)

    def run_synth(_, state, t):
        return engine.run_series(spec, state, synth, mesh=mesh)

    case = dict(
        n_guests=n_guests, logical_per_guest=logical_per_guest,
        n_logical=n_guests * logical_per_guest, n_windows=n_windows,
        hp_ratio=HP_RATIO, accesses_per_window=ACCESSES,
        n_devices=1 if mesh is None else mesh.shape["guest"],
        pod=True, array_path=skip_reason,
        # the residency the synth path actually carries per window, vs the
        # host array the array path would need
        trace_bytes_per_window=n_guests * ACCESSES * 4,
        array_trace_bytes=n_guests * n_windows * ACCESSES * 4,
    )
    _best_of(make, run_synth, None, case, "synth")
    return case


def _churn_case() -> dict:
    """The steady-state churn benchmark (ISSUE 6): a Poisson
    arrival/departure fleet over mixed drift workloads, with a mid-run
    capacity shrink and a telemetry dropout, timed against the plain scan
    driver on the same fleet and trace source. ``churn_vs_engine`` isolates
    the fault machinery's overhead; ``reclaim_complete`` asserts
    INV-CRASH-RECLAIM-COMPLETE (no allocated huge page left in a departed
    guest's segment) on the final carry."""
    n_guests, logical_per_guest, n_windows = CHURN
    workloads = ("redis_drift", "hash_drift", "redis", "masim")
    guests = tuple(
        engine.GuestSpec(n_logical=logical_per_guest, cl=8, gpa_slack=1.0,
                         workload=workloads[g % len(workloads)], seed=g)
        for g in range(n_guests))
    host = engine.HostSpec(hp_ratio=HP_RATIO, near_fraction=0.25,
                           base_elems=2, cl=8, ipt_min_hits=1)
    spec, _ = engine.build(guests, host)
    synth = engine.SynthTrace(n_windows=n_windows,
                              accesses_per_window=ACCESSES)
    sched = (faults.poisson_churn(n_guests, n_windows, arrival_rate=0.5,
                                  departure_rate=0.08, seed=0)
             .shrink(n_windows // 2, max(1, int(spec.cfg.n_near * 0.75)))
             .dropout(n_windows // 3))
    case = dict(
        n_guests=n_guests, logical_per_guest=logical_per_guest,
        n_logical=n_guests * logical_per_guest, n_windows=n_windows,
        hp_ratio=HP_RATIO, accesses_per_window=ACCESSES, n_devices=1,
        churn=True, workloads=list(workloads), n_fault_events=sched.n_events)

    def make_plain():
        return None, engine.init_engine_state(spec)

    def run_plain(_, state, t):
        return engine.run(spec, state, synth)

    def make_churn():
        return None, engine.init_churn(spec)

    def run_churned(_, cs, t):
        return engine.run_churn(spec, cs, synth, faults=sched)

    _best_of(make_plain, run_plain, None, case, "engine")
    _best_of(make_churn, run_churned, None, case, "churn")
    case["churn_vs_engine"] = case["engine_s"] / case["churn_s"]
    # INV-CRASH-RECLAIM-COMPLETE on the final carry of an untimed run; the
    # same run carries the TCO collector (ISSUE 7) so the perf-trajectory
    # artifact tracks the fleet's steady-state $-weighted placement
    cs, se = engine.run_churn(spec, engine.init_churn(spec), synth,
                              faults=sched, collect=("hits", "tco"))
    case["tco"] = float(np.asarray(se["tco"])[-3:].mean())
    case["amat_ns"] = float(np.asarray(se["amat_ns"])[-3:].mean())
    _, hp_owner, _, _ = faults.segment_tables(spec.canonical())
    owner = np.asarray(hp_owner)
    active = np.asarray(cs.active)
    alloc = np.asarray(allocated_hp_mask(spec.cfg, cs.state))
    orphans = alloc & (owner >= 0) & ~active[np.clip(owner, 0, None)]
    case["reclaim_complete"] = not bool(orphans.any())
    return case


def _multihost_wall() -> dict:
    """Wall clock of a small coordinated multi-process pod job (DESIGN.md
    §17): 2 processes x 2 CPU devices running
    ``scripts/pod_multihost_worker.py`` (32 guests, one live migration).
    Informational, never gated -- the number is dominated by the two
    workers' cold jit compiles, but its trajectory catches a broken or
    pathologically slow distributed launch path."""
    from repro.launch import multihost

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    worker = os.path.join(root, "scripts", "pod_multihost_worker.py")
    t0 = time.perf_counter()
    multihost.launch_check(worker, marker="POD MULTIHOST OK",
                           args=("32", "1"), num_processes=2,
                           devices_per_process=2, timeout=900.0, cwd=root)
    return dict(multihost_s=time.perf_counter() - t0,
                multihost_processes=2, multihost_devices_per_process=2,
                multihost_pod_guests=32, multihost_migrations=1)


# --------------------------------------------------------------------------
# per-runner worker subprocesses
# --------------------------------------------------------------------------
_WORKER_TAG = "BENCH_WORKER_RESULT "


def _worker_main(req: dict) -> dict:
    mesh = common.default_guest_mesh()
    if req["kind"] == "grid":
        n_guests, logical_per_guest, n_windows = GRID[req["index"]]
        return _bench_case(n_guests, logical_per_guest, n_windows, mesh,
                           only=req["runner"])
    if req["kind"] == "pod":
        return _pod_case(mesh)
    if req["kind"] == "churn":
        return _churn_case()
    raise ValueError(f"unknown worker request {req!r}")


def _run_worker(req: dict) -> dict:
    """Time one (case, runner) pair in a fresh subprocess so runners cannot
    pollute each other's wall clock. ``BENCH_ENGINE_IN_PROCESS=1`` falls
    back to in-process timing."""
    if os.environ.get("BENCH_ENGINE_IN_PROCESS"):
        return _worker_main(req)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_engine", "--worker",
         json.dumps(req)],
        capture_output=True, text=True, env=env, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench worker {req} failed:\nstdout:\n{proc.stdout}\n"
            f"stderr:\n{proc.stderr}")
    for line in reversed(proc.stdout.splitlines()):
        if line.startswith(_WORKER_TAG):
            return json.loads(line[len(_WORKER_TAG):])
    raise RuntimeError(
        f"bench worker {req} printed no result:\nstdout:\n{proc.stdout}\n"
        f"stderr:\n{proc.stderr}")


def run() -> dict:
    mesh = common.default_guest_mesh()
    n_devices = 1 if mesh is None else mesh.shape["guest"]
    runner_names = ["reference", "engine", "synth"]
    if mesh is not None:
        runner_names += ["engine_sharded", "host_sharded"]
    cases = []
    for i, (n_guests, logical_per_guest, n_windows) in enumerate(GRID):
        case: dict = {}
        # pallas-interpret is timed on the smallest row only (§16): the
        # interpreter's constant factor would dominate every larger row
        # without adding information
        row_runners = runner_names + (["pallas"] if i == 0 else [])
        if mesh is not None and n_windows % 4 == 0:
            row_runners = row_runners + ["overlap"]
        for runner in row_runners:
            case.update(_run_worker(dict(kind="grid", index=i, runner=runner)))
        _finalize_case(case)
        cases.append(case)
        sharded = (f" sharded[{n_devices}d] {case['engine_sharded_s']*1e3:8.1f} ms"
                   if "engine_sharded_s" in case else "")
        host = (f" host_sharded {case['host_sharded_s']*1e3:8.1f} ms"
                f" (state/dev {case['host_state_scaling']:.2f}x)"
                if "host_sharded_s" in case else "")
        overlap = (f" overlap[stride4] {case['overlap_s']*1e3:8.1f} ms"
                   f" ({case['overlap_speedup']:.2f}x engine)"
                   if "overlap_s" in case else "")
        pallas = (f" pallas {case['pallas_s']*1e3:8.1f} ms"
                  f" ({case['pallas_vs_engine']:.0f}x engine, interpret)"
                  if "pallas_s" in case else "")
        print(f"  n_guests={n_guests:3d} n_logical={case['n_logical']:6d} "
              f"windows={n_windows:3d}: reference {case['reference_s']*1e3:8.1f} ms"
              f" engine {case['engine_s']*1e3:8.1f} ms"
              f" synth {case['synth_s']*1e3:8.1f} ms"
              f" speedup {case['speedup']:5.2f}x{sharded}{host}{overlap}"
              f"{pallas}")
    pod = _run_worker(dict(kind="pod"))
    cases.append(pod)
    print(f"  n_guests={pod['n_guests']:3d} n_logical={pod['n_logical']:6d} "
          f"windows={pod['n_windows']:3d}: synth {pod['synth_s']*1e3:8.1f} ms "
          f"(pod row; array path skipped)")
    churn = _run_worker(dict(kind="churn"))
    cases.append(churn)
    print(f"  churn fleet {churn['n_guests']:3d} guests x "
          f"{churn['n_windows']} windows ({churn['n_fault_events']} fault "
          f"events): engine {churn['engine_s']*1e3:8.1f} ms churn "
          f"{churn['churn_s']*1e3:8.1f} ms ratio "
          f"{churn['churn_vs_engine']:.2f} reclaim "
          f"{'OK' if churn['reclaim_complete'] else 'INCOMPLETE'}")
    at_scale = [
        c["speedup"] for c in cases if c["n_guests"] >= 8 and "speedup" in c]
    sharded_at_scale = [
        c["sharded_speedup"] for c in cases
        if c["n_guests"] >= 8 and "sharded_speedup" in c]
    host_sharded_at_scale = [
        c["host_sharded_speedup"] for c in cases
        if c["n_guests"] >= 8 and "host_sharded_speedup" in c]
    overlap_at_scale = [
        c["overlap_speedup"] for c in cases
        if c["n_guests"] >= 8 and "overlap_speedup" in c]
    payload = dict(
        suite=NAME,
        description=registry.describe(NAME),
        backend=jax.default_backend(),
        n_devices=n_devices,
        repeats=REPEATS,
        cases=cases,
        min_speedup_at_scale=min(at_scale),
        target_speedup_at_scale=3.0,
        meets_target=min(at_scale) >= 3.0,
        pod_guests=pod["n_guests"],
        pod_synth_s=pod["synth_s"],
        churn_vs_engine=churn["churn_vs_engine"],
        reclaim_complete=churn["reclaim_complete"],
        tco=churn["tco"],
        amat_ns=churn["amat_ns"],
    )
    pallas_rows = [c for c in cases if "pallas_vs_engine" in c]
    if pallas_rows:
        # §16 informational column: pallas-interpret cost on the smallest row
        payload["pallas_vs_engine"] = pallas_rows[0]["pallas_vs_engine"]
    if sharded_at_scale:
        # acceptance: the sharded path is no slower than the single-device
        # engine at n_guests >= 8 (wall clock is noisy on shared CPU
        # "devices"; allow 5%)
        payload["min_sharded_speedup_at_scale"] = min(sharded_at_scale)
        payload["sharded_no_slower_at_scale"] = min(sharded_at_scale) >= 0.95
    if host_sharded_at_scale:
        payload["min_host_sharded_speedup_at_scale"] = min(host_sharded_at_scale)
        # the memory-scaling acceptance: per-device host-state bytes of the
        # partitioned carry vs the replicated path (~1/n_devices)
        payload["host_state_scaling"] = max(
            c["host_state_scaling"] for c in cases if "host_state_scaling" in c)
    if overlap_at_scale:
        # §17 acceptance: the stride-4 overlapped exchange recovering the
        # at-scale sharded gap (>= 1.0 means it beats the single-device
        # engine outright; see ROADMAP for the shared-container caveat)
        payload["min_overlap_speedup_at_scale"] = min(overlap_at_scale)
        payload["overlap_recovers_at_scale"] = min(overlap_at_scale) >= 1.0
    if mesh is not None:
        payload.update(_multihost_wall())
        print(f"  multihost launch (2 proc x 2 dev, 32-guest pod + 1 "
              f"migration): {payload['multihost_s']:.1f} s wall")
    with open("BENCH_engine.json", "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return common.save(NAME, payload)


if __name__ == "__main__":
    if len(sys.argv) >= 2 and sys.argv[1] == "--worker":
        result = _worker_main(json.loads(sys.argv[2]))
        print(_WORKER_TAG + json.dumps(result, default=float), flush=True)
        sys.exit(0)
    r = run()
    print(f"min speedup at n_guests>=8: {r['min_speedup_at_scale']:.2f}x "
          f"(target >= {r['target_speedup_at_scale']}x) "
          f"-> {'OK' if r['meets_target'] else 'MISS'}")
    if "min_sharded_speedup_at_scale" in r:
        print(f"sharded vs engine at n_guests>=8: "
              f"{r['min_sharded_speedup_at_scale']:.2f}x on "
              f"{r['n_devices']} devices -> "
              f"{'OK' if r['sharded_no_slower_at_scale'] else 'MISS'}")
    if "min_host_sharded_speedup_at_scale" in r:
        print(f"host-sharded vs engine at n_guests>=8: "
              f"{r['min_host_sharded_speedup_at_scale']:.2f}x; per-device "
              f"host state {r['host_state_scaling']:.2f}x of replicated on "
              f"{r['n_devices']} devices")
    if "min_overlap_speedup_at_scale" in r:
        print(f"overlapped exchange (stride 4) vs engine at n_guests>=8: "
              f"{r['min_overlap_speedup_at_scale']:.2f}x -> "
              f"{'recovered' if r['overlap_recovers_at_scale'] else 'gap'}")
    print(f"churn vs engine: {r['churn_vs_engine']:.2f}x; crash reclaim "
          f"{'complete' if r['reclaim_complete'] else 'INCOMPLETE'}")
