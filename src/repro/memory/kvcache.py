"""Tiered, paged KV cache -- the paper's technique as a first-class serving
feature (DESIGN.md §3.1).

Mapping onto the GPAC core (one ``TieredState`` instance per model):

  * logical base page  = one **token group** (``group_tokens`` tokens) of one
    sequence slot; payload = that group's K+V across all layers/kv-heads,
    flattened to ``base_elems`` floats.
  * huge page          = ``hp_ratio`` groups = the tier-placement granule
    (what the host-analogue daemon moves between HBM and host memory).
  * guest telemetry    = per-group attention mass (softmax weight sums) --
    heavy-tailed in long-context decode, i.e. *scattered hot base pages*.
  * GPAC               = consolidates hot token groups of any sequence into
    dense huge pages, so the near tier holds attention mass, not dead tokens.

The serving engine reads K/V *through* the two-level translation
(``read_groups``), so consolidation + migration are invisible to the model --
exactly the paper's host-agnosticism, with the tier manager playing host.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import GpacConfig, TieredState, gpac, init_state, telemetry, tiering
from repro.core import address_space as asp


@dataclasses.dataclass(frozen=True)
class KVSpec:
    """Geometry of the tiered KV store for one model + serving budget."""

    arch: ArchConfig
    max_seqs: int  # sequence slots
    max_seq_len: int  # tokens per slot
    group_tokens: int = 16  # base granule (tokens per group)
    hp_ratio: int = 8  # groups per tier block (8*16 = 128-token blocks)
    near_fraction: float = 0.5  # HBM budget as fraction of total blocks
    cl: int = 4  # consolidation limit (hot groups per block)
    gpa_slack: float = 0.5  # spare GPA blocks (fresh regions + demotion room)

    @property
    def groups_per_seq(self) -> int:
        return -(-self.max_seq_len // self.group_tokens)

    @property
    def n_logical(self) -> int:
        return self.max_seqs * self.groups_per_seq

    @property
    def elems_per_group(self) -> int:
        a = self.arch
        return 2 * a.n_attn_layers * a.n_kv_heads * self.group_tokens * a.hd

    def gpac_config(self) -> GpacConfig:
        need_hp = -(-self.n_logical // self.hp_ratio)
        n_hp = need_hp + max(2, int(need_hp * self.gpa_slack))
        return GpacConfig(
            n_logical=self.n_logical,
            hp_ratio=self.hp_ratio,
            n_gpa_hp=n_hp,
            n_near=min(max(1, int(self.near_fraction * n_hp)), n_hp - 1),
            base_elems=self.elems_per_group,
            cl=self.cl,
            dtype=jnp.float32,
        )


class TieredKVCache:
    """Stateful wrapper (engine-side, python control plane; all data-plane
    ops are jitted core functions)."""

    def __init__(self, spec: KVSpec):
        self.spec = spec
        self.cfg = spec.gpac_config()
        self.state: TieredState = init_state(self.cfg)
        self.seq_lens = np.zeros((spec.max_seqs,), np.int64)

    # ---- addressing ------------------------------------------------------
    def group_id(self, seq: int, group: int) -> int:
        return seq * self.spec.groups_per_seq + group

    def seq_groups(self, seq: int, n_tokens: int | None = None) -> np.ndarray:
        n = self.seq_lens[seq] if n_tokens is None else n_tokens
        n_groups = -(-int(n) // self.spec.group_tokens)
        base = seq * self.spec.groups_per_seq
        return base + np.arange(n_groups)

    # ---- data plane --------------------------------------------------------
    def _pack(self, k: jax.Array, v: jax.Array) -> jax.Array:
        """k/v (n_groups, L_attn, KVH, group_tokens, hd) -> (n_groups, elems)."""
        n = k.shape[0]
        return jnp.concatenate(
            [k.reshape(n, -1), v.reshape(n, -1)], axis=1
        ).astype(jnp.float32)

    def _unpack(self, rows: jax.Array):
        a, s = self.spec.arch, self.spec
        n = rows.shape[0]
        half = rows.shape[1] // 2
        shape = (n, a.n_attn_layers, a.n_kv_heads, s.group_tokens, a.hd)
        return rows[:, :half].reshape(shape), rows[:, half:].reshape(shape)

    def append_groups(self, seq: int, k: jax.Array, v: jax.Array):
        """Append whole groups for sequence ``seq`` (prefill path).
        k/v: (n_groups, L_attn, KVH, group_tokens, hd)."""
        n = k.shape[0]
        start_group = -(-int(self.seq_lens[seq]) // self.spec.group_tokens)
        ids = jnp.asarray(
            self.group_id(seq, start_group) + np.arange(n), jnp.int32
        )
        self.state = asp.write_logical(self.cfg, self.state, ids, self._pack(k, v))
        self.seq_lens[seq] += n * self.spec.group_tokens

    def read_groups(self, ids: jax.Array):
        """Gather K/V groups through the full two-level translation."""
        rows = asp.read_logical(self.cfg, self.state, ids.astype(jnp.int32))
        return self._unpack(rows)

    # ---- telemetry + maintenance (the GPAC loop) ---------------------------
    def record_attention_mass(self, ids: np.ndarray, mass: np.ndarray,
                              quantum: float = 0.01):
        """Charge attention mass as access counts (1 count per ``quantum``
        of softmax weight, so cold tail groups round to zero)."""
        counts = np.minimum((mass / quantum).astype(np.int64), 2**20)
        keep = counts > 0
        if not keep.any():
            return
        self.state = asp.record_accesses(
            self.cfg, self.state,
            jnp.asarray(ids[keep], jnp.int32),
            jnp.asarray(counts[keep], jnp.int32),
        )

    def maintenance(self, policy: str = "memtierd", use_gpac: bool = True,
                    max_batches: int = 4, budget: int = 64):
        """One window: GPAC consolidation (guest side) + tier tick (host side)
        + window roll. Call every N decode steps."""
        if use_gpac:
            self.state = gpac.gpac_maintenance(
                self.cfg, self.state, "ipt", max_batches
            )
        self.state = tiering.tick(self.cfg, self.state, policy, budget=budget)
        self.state = telemetry.end_window(self.cfg, self.state)

    # ---- metrics -----------------------------------------------------------
    def near_usage(self) -> float:
        from repro.core import metrics
        return float(metrics.near_usage(self.cfg, self.state))

    def hit_rate(self) -> float:
        from repro.core import metrics
        return float(metrics.hit_rate(self.state))

    def stats(self) -> dict:
        from repro.core import metrics
        return metrics.snapshot(self.cfg, self.state)
