"""Tiered MoE expert store (DESIGN.md §3.3, §Arch-applicability).

Expert slabs are *dense by construction* (one expert = one contiguous weight
slab far larger than a tier block), so GPAC's intra-block consolidation is
**inapplicable** -- this is the paper's own observation about dense-hot pages
(Liblinear/Roms need no consolidation). What remains valuable is the
block-granular tier layer: routing frequency is Zipf-skewed, so hot experts'
slabs belong in HBM and the cold tail in host memory.

Implemented as a thin tier manager over expert slabs: telemetry = router
selections per expert; policy = any of the core's host policies at slab
granularity (one expert spans multiple blocks; all of an expert's blocks are
charged together, so placement decisions stay slab-coherent).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import GpacConfig, init_state, telemetry, tiering
from repro.core import address_space as asp


@dataclasses.dataclass(frozen=True)
class ExpertStoreSpec:
    arch: ArchConfig
    blocks_per_expert: int = 4  # tier granule: expert slab / 4
    near_fraction: float = 0.25  # HBM budget (fraction of experts resident)

    @property
    def n_experts(self) -> int:
        return self.arch.e_pad

    def gpac_config(self) -> GpacConfig:
        n_logical = self.n_experts * self.blocks_per_expert
        n_hp = n_logical + 2
        return GpacConfig(
            n_logical=n_logical,
            hp_ratio=1,  # block == base granule: no sub-block structure
            n_gpa_hp=n_hp,
            n_near=min(max(1, int(self.near_fraction * n_hp)), n_hp - 1),
            base_elems=8,  # placement bookkeeping only (slabs stay in params)
            cl=1,
            dtype=jnp.float32,
        )


class TieredExpertStore:
    def __init__(self, spec: ExpertStoreSpec):
        self.spec = spec
        self.cfg = spec.gpac_config()
        self.state = init_state(self.cfg)

    def _expert_blocks(self, e: np.ndarray) -> np.ndarray:
        b = self.spec.blocks_per_expert
        return (e[:, None] * b + np.arange(b)[None]).reshape(-1)

    def record_routing(self, expert_ids: np.ndarray):
        """Charge router selections: every block of a selected expert."""
        experts, counts = np.unique(np.asarray(expert_ids).reshape(-1),
                                    return_counts=True)
        blocks = self._expert_blocks(experts)
        counts = np.repeat(np.minimum(counts, 2**20),
                           self.spec.blocks_per_expert)
        self.state = asp.record_accesses(
            self.cfg, self.state,
            jnp.asarray(blocks, jnp.int32), jnp.asarray(counts, jnp.int32))

    def maintenance(self, policy: str = "memtierd"):
        # NOTE: no gpac_maintenance call -- consolidation is inapplicable to
        # dense slabs (every block of a hot expert is hot: never < CL=1).
        self.state = tiering.tick(self.cfg, self.state, policy, budget=64)
        self.state = telemetry.end_window(self.cfg, self.state)

    def near_experts(self) -> np.ndarray:
        """Experts fully resident in the near tier right now."""
        bt = np.asarray(self.state.block_table)
        gpt = np.asarray(self.state.gpt)
        b = self.spec.blocks_per_expert
        in_near = bt[gpt // self.cfg.hp_ratio] < self.cfg.n_near
        per_e = in_near[: self.spec.n_experts * b].reshape(-1, b)
        return np.nonzero(per_e.all(axis=1))[0]

    def hit_rate(self) -> float:
        from repro.core import metrics
        return float(metrics.hit_rate(self.state))
