"""Tiered embedding store (DESIGN.md §3.2): Zipfian token frequency makes hot
vocab rows *scattered* across a 49k-256k-row table -- the paper's scattered
hot base pages, verbatim. GPAC consolidates hot row groups into dense blocks
so the HBM-resident fraction of the table tracks the head of the Zipf curve.

Serving-side feature: lookups go through ``kernels.tiered_lookup`` with the
precomposed translation (the beyond-paper 'fused TLB'), recomputed only after
a maintenance tick. (Training keeps embeddings as ordinary sharded params;
placement stats from this store inform static cold-row offload.)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import GpacConfig, gpac, init_state, telemetry, tiering
from repro.core import address_space as asp
from repro.kernels.tiered_lookup import tiered_lookup


@dataclasses.dataclass(frozen=True)
class EmbedSpec:
    arch: ArchConfig
    rows_per_page: int = 8  # vocab rows per base granule
    hp_ratio: int = 64  # granules per tier block (8*64=512 rows/block)
    near_fraction: float = 0.25
    cl: int = 16

    @property
    def n_logical(self) -> int:
        return -(-self.arch.vocab // self.rows_per_page)

    def gpac_config(self) -> GpacConfig:
        need = -(-self.n_logical // self.hp_ratio)
        n_hp = need + max(2, need // 4)
        return GpacConfig(
            n_logical=self.n_logical,
            hp_ratio=self.hp_ratio,
            n_gpa_hp=n_hp,
            n_near=min(max(1, int(self.near_fraction * n_hp)), n_hp - 1),
            base_elems=self.rows_per_page * self.arch.d_model,
            cl=self.cl,
            dtype=jnp.float32,
        )


class TieredEmbeddingStore:
    def __init__(self, spec: EmbedSpec, table: jax.Array):
        """``table``: (vocab, d_model) weights to load into the paged pools."""
        self.spec = spec
        self.cfg = spec.gpac_config()
        v, d = table.shape
        pad_rows = spec.n_logical * spec.rows_per_page - v
        t = jnp.pad(table.astype(jnp.float32), ((0, pad_rows), (0, 0)))
        fill = t.reshape(spec.n_logical, spec.rows_per_page * d)
        self.state = init_state(self.cfg, fill=fill)
        self._fused = None  # cached fused translation (invalidated on ticks)

    def _fused_rows(self):
        """Flat physical row space + per-vocab-row fused translation."""
        if self._fused is None:
            page_of = asp.fused_translation(self.cfg, self.state)  # per granule
            self._fused = page_of
        return self._fused

    def lookup(self, token_ids: jax.Array) -> jax.Array:
        """(…,) int32 token ids -> (…, d_model) rows via two-level gather."""
        s, d = self.spec, self.spec.arch.d_model
        granule = token_ids // s.rows_per_page
        offset = token_ids % s.rows_per_page
        fused = self._fused_rows()
        rows = jnp.concatenate(
            [self.state.near_pool.reshape(-1, self.cfg.base_elems),
             self.state.far_pool.reshape(-1, self.cfg.base_elems)], axis=0)
        granule_rows = tiered_lookup(rows, fused, granule)  # (..., base_elems)
        granule_rows = granule_rows.reshape(*token_ids.shape, s.rows_per_page, d)
        return jnp.take_along_axis(
            granule_rows, offset[..., None, None], axis=-2
        )[..., 0, :]

    def record_batch(self, token_ids: np.ndarray):
        """Telemetry: charge one access per token occurrence to its granule."""
        granules, counts = np.unique(
            np.asarray(token_ids).reshape(-1) // self.spec.rows_per_page,
            return_counts=True,
        )
        self.state = asp.record_accesses(
            self.cfg, self.state,
            jnp.asarray(granules, jnp.int32),
            jnp.asarray(np.minimum(counts, 2**20), jnp.int32),
        )

    def maintenance(self, policy: str = "memtierd", use_gpac: bool = True):
        if use_gpac:
            self.state = gpac.gpac_maintenance(self.cfg, self.state, "ipt", 4)
        self.state = tiering.tick(self.cfg, self.state, policy, budget=64)
        self.state = telemetry.end_window(self.cfg, self.state)
        self._fused = None  # translation cache shootdown (paper's TLB flush)

    def near_usage(self) -> float:
        from repro.core import metrics
        return float(metrics.near_usage(self.cfg, self.state))

    def hit_rate(self) -> float:
        from repro.core import metrics
        return float(metrics.hit_rate(self.state))
