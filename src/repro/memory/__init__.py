from repro.memory import embedding, kvcache, moe_store  # noqa: F401
