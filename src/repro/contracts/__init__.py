"""Invariant contract registry (DESIGN.md §15).

Importing this package registers the builtin contracts (mirroring how
``repro.core.tiering`` registers its builtin policies): the ledger
generator, the test harness, and user code all see the same live set.
"""
from repro.contracts.registry import (
    Contract,
    all_contracts,
    contract_names,
    get_contract,
    register_contract,
)
from repro.contracts.draws import ContractDraw, GuestDraw, build_engine
from repro.contracts import invariants as _invariants  # noqa: F401  (registers)

__all__ = [
    "Contract",
    "ContractDraw",
    "GuestDraw",
    "all_contracts",
    "build_engine",
    "contract_names",
    "get_contract",
    "register_contract",
]
