"""Concrete parameter draws for contract ``check_fn``s (DESIGN.md §15).

A :class:`ContractDraw` is a plain-python bundle of the knobs the engine
contracts range over: ragged guest geometry, host shape, policy, gpac
on/off, trace source kind, chunking, host path, and the pressure-controller
knobs. ``tests/strategies.py`` builds these with hypothesis; keeping the
dataclasses here (src, not tests) lets ``check_fn``s consume them without
importing test code, and keeps one canonical definition of "random
geometry" shared by every contract.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class GuestDraw:
    """One guest's drawn geometry/identity (mirrors engine.GuestSpec)."""

    n_logical: int
    cl: int | None
    gpa_slack: float
    workload: str
    seed: int


@dataclasses.dataclass(frozen=True)
class ContractDraw:
    """One concrete point in the contract parameter space.

    Contracts read only the fields they range over; the shared strategy
    draws all of them so every contract sees the same geometry
    distribution (ragged guests, non-dividing chunk sizes, tie-heavy
    telemetry seeds).
    """

    guests: tuple[GuestDraw, ...]
    hp_ratio: int
    near_fraction: float
    host_cl: int
    policy: str
    use_gpac: bool
    synth: bool              # SynthTrace vs ArrayTrace source
    n_windows: int
    accesses_per_window: int
    windows_per_step: int    # alternative chunking to pin against wps=0
    host_sharded: bool       # which run_sharded host path to exercise
    cap: int                 # pressure-controller near_cap draw
    budget: int              # pressure-controller / tick budget draw
    slack: int               # pressure-controller low-watermark slack
    seed: int                # telemetry/state randomization seed

    @property
    def n_guests(self) -> int:
        return len(self.guests)


def fallback_draws() -> tuple[ContractDraw, ...]:
    """Two fixed smoke draws for environments without hypothesis.

    CI treats hypothesis as a hard dependency (requirements-ci.txt) and the
    harness in ``tests/test_contracts.py`` ranges over the shared
    strategies; when the dep is absent the harness runs every contract once
    per draw here instead of skipping, so tier-1 never loses contract
    coverage. The two points deliberately straddle the big booleans:
    synth/array source, gpac on/off, both run_sharded host paths, and a
    non-dividing chunk size.
    """
    return (
        ContractDraw(
            guests=(
                GuestDraw(n_logical=10, cl=None, gpa_slack=0.25,
                          workload="redis", seed=0),
                GuestDraw(n_logical=7, cl=2, gpa_slack=0.5,
                          workload="masim", seed=1),
            ),
            hp_ratio=4, near_fraction=0.5, host_cl=2, policy="memtierd",
            use_gpac=True, synth=True, n_windows=4, accesses_per_window=16,
            windows_per_step=3, host_sharded=True, cap=2, budget=4, slack=1,
            seed=5,
        ),
        ContractDraw(
            guests=(
                GuestDraw(n_logical=12, cl=4, gpa_slack=0.25,
                          workload="hash", seed=2),
            ),
            hp_ratio=8, near_fraction=0.25, host_cl=8, policy="tpp",
            use_gpac=False, synth=False, n_windows=5, accesses_per_window=24,
            windows_per_step=2, host_sharded=False, cap=0, budget=2, slack=0,
            seed=11,
        ),
    )


def build_engine(draw: ContractDraw):
    """``engine.build`` for a draw: ``(spec, state)`` with base_elems=2."""
    from repro.core import engine

    guests = tuple(
        engine.GuestSpec(
            n_logical=g.n_logical, cl=g.cl, gpa_slack=g.gpa_slack,
            workload=g.workload, seed=g.seed,
        )
        for g in draw.guests
    )
    host = engine.HostSpec(
        hp_ratio=draw.hp_ratio, near_fraction=draw.near_fraction,
        base_elems=2, cl=draw.host_cl,
    )
    return engine.build(guests, host)


def trace_source(draw: ContractDraw, spec):
    """The draw's trace source: on-device synthesis or a packed replay."""
    from repro.core import engine

    if draw.synth:
        return engine.SynthTrace(
            n_windows=draw.n_windows,
            accesses_per_window=draw.accesses_per_window,
        )
    return engine.ArrayTrace(
        engine.guest_traces(
            spec, n_windows=draw.n_windows,
            accesses_per_window=draw.accesses_per_window,
        )
    )
