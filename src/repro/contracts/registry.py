"""Named invariant contracts (DESIGN.md §15).

A *contract* is one of the repo's bit-for-bit / safety invariants as a
first-class registered object: a stable ``INV-*`` name, the DESIGN.md
section that states it, the drivers it covers, and an executable
``check_fn`` that raises ``AssertionError`` on violation for any concrete
parameter draw. The registry mirrors the PR-2 policy/telemetry/collector
registries: duplicates raise, unknown names raise listing the live set.

The generic harness in ``tests/test_contracts.py`` runs every registered
contract's ``check_fn`` under hypothesis over the shared strategies in
``tests/strategies.py`` (``pytest -m contracts``), and
``scripts/gen_invariant_ledger.py`` renders the registry into the
drift-checked ledger ``docs/contracts/INVARIANTS.md`` — so a new
equivalence pin is one ``register_contract`` call, not a bespoke test
file.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable

_NAME_RE = re.compile(r"^INV-[A-Z0-9]+(?:-[A-Z0-9]+)+$")


@dataclasses.dataclass(frozen=True)
class Contract:
    """One named invariant: where it is stated, what it covers, how it is
    checked.

    ``check_fn(draw)`` takes a :class:`repro.contracts.draws.ContractDraw`
    (concrete geometry/policy/seed parameters — hypothesis draws them in
    the test harness) and raises ``AssertionError`` on violation.
    ``pins`` are the bespoke tier-1 tests/smokes that also enforce the
    invariant (the ledger lists them next to the property harness).
    ``max_examples`` is the per-contract hypothesis budget: engine-level
    contracts recompile per drawn geometry, so they run fewer examples
    than tick-level ones.
    """

    name: str
    design_section: str
    drivers: tuple[str, ...]
    check_fn: Callable
    description: str
    pins: tuple[str, ...] = ()
    max_examples: int = 10

    @property
    def harness_id(self) -> str:
        """The generated property-test node for this contract."""
        return f"tests/test_contracts.py::test_contract_property[{self.name}]"


_CONTRACTS: dict[str, Contract] = {}


def register_contract(
    name: str,
    design_section: str,
    drivers: tuple[str, ...],
    check_fn: Callable | None = None,
    *,
    description: str = "",
    pins: tuple[str, ...] = (),
    max_examples: int = 10,
):
    """Register an invariant contract; usable as a decorator::

        @register_contract("INV-MY-PIN", "§9", drivers=("run",))
        def check_my_pin(draw): ...

    Names must match ``INV-[A-Z0-9-]+`` (they are cross-checked against
    DESIGN.md by the ledger generator). Duplicates raise. The description
    defaults to the check_fn's first docstring line.
    """
    if check_fn is None:
        return lambda f: register_contract(
            name, design_section, drivers, f,
            description=description, pins=pins, max_examples=max_examples,
        )
    if not _NAME_RE.match(name):
        raise ValueError(
            f"contract name {name!r} must match {_NAME_RE.pattern}"
        )
    if name in _CONTRACTS:
        raise ValueError(f"contract {name!r} already registered")
    if not drivers:
        raise ValueError(f"contract {name!r} must name the drivers it covers")
    doc_lines = (check_fn.__doc__ or "").strip().splitlines()
    desc = description or (doc_lines[0] if doc_lines else "")
    if not desc:
        raise ValueError(f"contract {name!r} needs a description or docstring")
    _CONTRACTS[name] = Contract(
        name=name,
        design_section=design_section,
        drivers=tuple(drivers),
        check_fn=check_fn,
        description=desc,
        pins=tuple(pins),
        max_examples=max_examples,
    )
    return check_fn


def get_contract(name: str) -> Contract:
    try:
        return _CONTRACTS[name]
    except KeyError:
        raise ValueError(
            f"unknown contract {name!r} (have {contract_names()})"
        ) from None


def contract_names() -> tuple[str, ...]:
    """Names of all registered contracts, sorted for stable ledgers."""
    return tuple(sorted(_CONTRACTS))


def all_contracts() -> tuple[Contract, ...]:
    return tuple(_CONTRACTS[n] for n in contract_names())
