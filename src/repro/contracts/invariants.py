"""The registered invariant contracts (DESIGN.md §15, ledger in
docs/contracts/INVARIANTS.md).

Ten contracts distilled from eight PRs of equivalence pins: the four the
DESIGN.md §10 ledger already named (churn no-op, crash reclaim, 2-tier
special case, pressure no-overcommit), the four that until now lived
only as bespoke test files (ownership merge, chunking invariance, synth
determinism, arbitration tie-break), the kernel-backend exactness
pin of the Pallas hot path (DESIGN.md §16), plus the multi-host
exactness pin of the distributed runtime and its overlapped arbitration
exchange (DESIGN.md §17). Each ``check_fn`` takes one
:class:`~repro.contracts.draws.ContractDraw` and raises ``AssertionError``
on violation; the harness in ``tests/test_contracts.py`` drives them under
hypothesis over the shared strategies.

Engine-level contracts keep their drawn geometry small (each distinct
geometry is a fresh XLA compile) and run fewer hypothesis examples
(``max_examples``); tick-level contracts are cheap and run more.
"""
from __future__ import annotations

import numpy as np

from repro.contracts.draws import ContractDraw, build_engine, trace_source
from repro.contracts.registry import register_contract


# --------------------------------------------------------------------------
# shared assertion helpers
# --------------------------------------------------------------------------
def assert_states_equal(a, b, msg: str = ""):
    """Bit-for-bit equality of two pytrees (the §10 exactness discipline)."""
    import jax

    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), f"{msg}: leaf count {len(la)} != {len(lb)}"
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=msg)


def assert_series_equal(a: dict, b: dict, msg: str = ""):
    assert set(a) == set(b), f"{msg}: keys {sorted(a)} != {sorted(b)}"
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=f"{msg}:{k}")


# --------------------------------------------------------------------------
# §9/§11 — ownership merge
# --------------------------------------------------------------------------
@register_contract(
    "INV-OWNERSHIP-MERGE-EXACT", "§9/§11",
    drivers=("run", "run_sharded", "run_sharded(host_sharded=True)"),
    pins=(
        "tests/test_engine_sharded.py::TestShardedSingleDevice",
        "tests/test_host_sharding.py",
        "scripts/ci_smoke_sharded.py",
    ),
    max_examples=3,
)
def check_ownership_merge_exact(draw: ContractDraw):
    """Segment/slot-ownership psums reconstruct every array exactly:
    ``run_sharded`` (full shard_map path, both host paths) is bit-identical
    to ``run`` for any geometry/policy/gpac draw."""
    from repro.core import engine, sharding

    spec, s0 = build_engine(draw)
    source = trace_source(draw, spec)
    mesh = sharding.guest_mesh(1)  # full shard_map path on one device
    ref_state, ref = engine.run(
        spec, s0, source, policy=draw.policy, use_gpac=draw.use_gpac)
    sh_state, sh = engine.run_sharded(
        spec, s0, source, mesh=mesh, policy=draw.policy,
        use_gpac=draw.use_gpac, host_sharded=draw.host_sharded)
    assert_states_equal(ref_state, sh_state, "run_sharded state diverged")
    assert_series_equal(ref, sh, "run_sharded series diverged")


# --------------------------------------------------------------------------
# §7/§9 — chunking invariance (replay path)
# --------------------------------------------------------------------------
@register_contract(
    "INV-CHUNKING-INVARIANT", "§7/§9",
    drivers=("run", "run_sharded", "run_churn"),
    pins=(
        "tests/test_engine_api.py::TestEquivalence",
        "tests/test_engine_equivalence.py",
    ),
    max_examples=3,
)
def check_chunking_invariant(draw: ContractDraw):
    """``windows_per_step`` is a pure batching knob: any chunking of the
    window scan — including non-dividing strict sizes with a shorter
    trailing chunk — yields the bit-identical final state and series."""
    from repro.core import engine

    spec, s0 = build_engine(draw)
    traces = engine.guest_traces(
        spec, n_windows=draw.n_windows,
        accesses_per_window=draw.accesses_per_window)
    ref_state, ref = engine.run(
        spec, s0, traces, policy=draw.policy, use_gpac=draw.use_gpac)
    ch_state, ch = engine.run(
        spec, s0, traces, policy=draw.policy, use_gpac=draw.use_gpac,
        windows_per_step=draw.windows_per_step, strict_wps=True)
    assert_states_equal(ref_state, ch_state, "chunked state diverged")
    assert_series_equal(ref, ch, "chunked series diverged")


# --------------------------------------------------------------------------
# §12 — on-device synthesis determinism
# --------------------------------------------------------------------------
@register_contract(
    "INV-SYNTH-DETERMINISM", "§12",
    drivers=("run", "run_sharded", "run_churn"),
    pins=(
        "tests/test_trace_source.py::TestSynthEngine",
        "tests/test_trace_source.py::TestSynthDistributionalEquivalence",
    ),
    max_examples=2,
)
def check_synth_determinism(draw: ContractDraw):
    """Counter-based synthesis depends only on ``(workload, seed, gid, w)``:
    re-running and re-chunking a SynthTrace run is bit-identical, and the
    host-side materializer is deterministic per spec."""
    from repro.core import engine
    from repro.data import traces as tr

    spec, s0 = build_engine(draw)
    synth = engine.SynthTrace(
        n_windows=draw.n_windows,
        accesses_per_window=draw.accesses_per_window)
    a_state, a = engine.run(
        spec, s0, synth, policy=draw.policy, use_gpac=draw.use_gpac)
    b_state, b = engine.run(  # identical second run
        spec, s0, synth, policy=draw.policy, use_gpac=draw.use_gpac)
    assert_states_equal(a_state, b_state, "synth rerun diverged")
    assert_series_equal(a, b, "synth rerun series diverged")
    c_state, c = engine.run(  # any chunking re-derives identical windows
        spec, s0, synth, policy=draw.policy, use_gpac=draw.use_gpac,
        windows_per_step=draw.windows_per_step, strict_wps=True)
    assert_states_equal(a_state, c_state, "synth chunking diverged")
    assert_series_equal(a, c, "synth chunking series diverged")
    g = draw.guests[draw.seed % len(draw.guests)]
    ts = tr.TraceSpec(
        workload=g.workload, n_logical=g.n_logical, hp_ratio=draw.hp_ratio,
        n_windows=2, accesses_per_window=draw.accesses_per_window,
        seed=g.seed)
    np.testing.assert_array_equal(
        tr.synth_generate(ts, gid=3), tr.synth_generate(ts, gid=3),
        err_msg="synth_generate not deterministic per (workload, seed, gid)")


# --------------------------------------------------------------------------
# §11 — arbitration tie-break
# --------------------------------------------------------------------------
@register_contract(
    "INV-ARBITRATION-TIEBREAK", "§11",
    drivers=("run_sharded(host_sharded=True)",),
    pins=("tests/test_host_partition_edges.py::TestArbitrationTies",),
    max_examples=75,
)
def check_arbitration_tiebreak(draw: ContractDraw):
    """Per-partition ``nominate`` + replicated ``rank_select`` reproduces
    ``jax.lax.top_k`` over the full per-block score array bit-for-bit —
    ties resolve to the lowest block id — for any partition layout,
    including empty ranges and mass-tie score fields."""
    import jax
    import jax.numpy as jnp

    from repro.core import tiering

    rng = np.random.default_rng(draw.seed)
    n_blocks = int(rng.integers(6, 40))
    b = min(draw.budget, n_blocks)
    # small value range -> heavy cross-partition tie pressure
    val = rng.integers(0, 4, n_blocks).astype(np.int32)
    mask = rng.random(n_blocks) < 0.7
    parts = min(draw.n_guests + 1, n_blocks)
    cuts = np.linspace(0, n_blocks, parts + 1).astype(int)
    h_loc = max(1, int(max(hi - lo for lo, hi in zip(cuts[:-1], cuts[1:]))))

    noms = []
    for lo, hi in zip(cuts[:-1], cuts[1:]):
        hp_ids = np.full(h_loc, -1, np.int32)
        hp_ids[: hi - lo] = np.arange(lo, hi, dtype=np.int32)
        take = np.clip(hp_ids, 0, None)
        noms.append(tiering.nominate(
            jnp.asarray(np.where(hp_ids >= 0, mask[take], False)),
            jnp.asarray(np.where(hp_ids >= 0, val[take], 0).astype(np.int32)),
            b,
            hp_ids=jnp.asarray(hp_ids),
            slot=jnp.asarray(take),
            alloc=jnp.asarray(np.ones(h_loc, np.int32)),
            cnt=jnp.asarray(np.where(hp_ids >= 0, val[take], 0).astype(np.int32)),
        ))
    merged = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *noms)
    picked = tiering.rank_select(
        {f: x.reshape(-1) for f, x in merged.items()}, b)

    full = jnp.where(jnp.asarray(mask), jnp.asarray(val), tiering.NEG)
    ref_v, ref_i = jax.lax.top_k(full, b)
    ref_ids = np.where(np.asarray(ref_v) > int(tiering.NEG),
                       np.asarray(ref_i), -1)
    ref_vals = np.where(ref_ids >= 0, np.asarray(ref_v), int(tiering.NEG))
    np.testing.assert_array_equal(
        np.asarray(picked["id"]), ref_ids,
        err_msg="rank_select ids diverge from full-array top_k tie-break")
    np.testing.assert_array_equal(
        np.asarray(picked["val"]), ref_vals,
        err_msg="rank_select vals diverge from full-array top_k")


# --------------------------------------------------------------------------
# §13 — churn no-op exactness
# --------------------------------------------------------------------------
@register_contract(
    "INV-CHURN-NOOP-EXACT", "§13",
    drivers=("run", "run_churn"),
    pins=(
        "tests/test_churn.py::TestNoFaultExact",
        "scripts/ci_smoke_churn.py",
    ),
    max_examples=3,
)
def check_churn_noop_exact(draw: ContractDraw):
    """With no faults scheduled the §13 stepper is a provable no-op:
    ``run_churn`` is bit-identical to ``run`` in the final state and every
    collector series, with all lanes active and zero pressure."""
    from repro.core import engine

    spec, s0 = build_engine(draw)
    source = trace_source(draw, spec)
    ref_state, ref = engine.run(
        spec, s0, source, policy=draw.policy, use_gpac=draw.use_gpac)
    cs, se = engine.run_churn(
        spec, engine.init_churn(spec), source, policy=draw.policy,
        use_gpac=draw.use_gpac)
    assert_states_equal(ref_state, cs.state, "idle churn state diverged")
    assert_series_equal(
        ref, {k: v for k, v in se.items() if k not in engine._CHURN_SERIES},
        "idle churn series diverged")
    assert np.asarray(se["active"]).all(), "idle churn deactivated a lane"
    np.testing.assert_array_equal(
        se["pressure"], 0, err_msg="idle churn reported pressure")


# --------------------------------------------------------------------------
# §13 — crash reclaim completeness
# --------------------------------------------------------------------------
@register_contract(
    "INV-CRASH-RECLAIM-COMPLETE", "§13",
    drivers=("run_churn",),
    pins=(
        "tests/test_churn.py::TestCrashReclaim",
        "scripts/check_bench_regression.py",
    ),
    max_examples=3,
)
def check_crash_reclaim_complete(draw: ContractDraw):
    """Within the window a guest crashes its whole GPA segment is FREE with
    no allocated huge pages, its near blocks return to the pool (the crash
    window already reports zero), and the block table stays a permutation
    with ``slot_owner`` its inverse."""
    from repro.core import engine, faults
    from repro.core.types import FREE, allocated_hp_mask

    spec, s0 = build_engine(draw)
    victim = draw.seed % draw.n_guests
    crash_w = draw.n_windows // 2
    sched = faults.FaultSchedule(draw.n_guests).crash(crash_w, victim)
    cs, se = engine.run_churn(
        spec, engine.init_churn(spec), trace_source(draw, spec),
        faults=sched, policy=draw.policy, use_gpac=draw.use_gpac)
    blocks = np.asarray(se["near_blocks"])
    assert (blocks[crash_w:, victim] == 0).all(), (
        "crashed guest still holds near blocks after its crash window")
    hp_lo, hp_hi = spec.hp_range(victim)
    r = spec.cfg.hp_ratio
    rmap = np.asarray(cs.state.rmap)
    assert (rmap[hp_lo * r: hp_hi * r] == int(FREE)).all(), (
        "crashed guest's GPA segment is not fully FREE")
    alloc = np.asarray(allocated_hp_mask(spec.cfg, cs.state))
    assert not alloc[hp_lo:hp_hi].any(), (
        "allocated huge pages orphaned in the crashed guest's segment")
    bt = np.asarray(cs.state.block_table)
    assert len(np.unique(bt)) == bt.size, "block table lost permutation"
    so = np.asarray(cs.state.slot_owner)
    np.testing.assert_array_equal(
        so[bt], np.arange(bt.size),
        err_msg="slot_owner is no longer the block table's inverse")


# --------------------------------------------------------------------------
# §14 — 2-tier special case of the flow generalization
# --------------------------------------------------------------------------
@register_contract(
    "INV-TIER-2SPECIALCASE-EXACT", "§14",
    drivers=("run", "run_sharded", "run_sharded(host_sharded=True)",
             "run_churn"),
    pins=(
        "tests/test_tiers.py::TestTwoTierSpecialCase",
        "tests/test_tiers_properties.py::test_inv_tier_2specialcase_exact",
        "scripts/ci_smoke_tiers.py",
    ),
    max_examples=40,
)
def check_tier_2specialcase_exact(draw: ContractDraw):
    """Every legacy policy tick equals its ``two_tier`` flow
    parameterization bit-for-bit for any config/telemetry: the extra
    tier-range predicates are tautologies on ``(0, n_near, n_slots)``."""
    import jax.numpy as jnp

    from repro.core import address_space as asp
    from repro.core import init_state, start_all_far, tiering, tiers

    spec, _ = build_engine(draw)
    cfg = spec.cfg
    rng = np.random.default_rng(draw.seed)
    state = start_all_far(cfg, init_state(cfg))
    ids = jnp.asarray(rng.integers(0, cfg.n_logical, size=64), jnp.int32)
    state = asp.record_accesses(cfg, state, ids)
    legacy = tiering.tick(cfg, state, draw.policy, budget=draw.budget)
    flow = tiering.tick(cfg, state, draw.policy, budget=draw.budget,
                        tiers=tiers.two_tier(cfg))
    assert_states_equal(legacy, flow, f"{draw.policy} two_tier flow diverged")


# --------------------------------------------------------------------------
# §13/§14 — pressure controller bounds
# --------------------------------------------------------------------------
@register_contract(
    "INV-PRESSURE-NO-OVERCOMMIT", "§13/§14",
    drivers=("run_churn",),
    pins=("tests/test_tiers_properties.py::test_inv_pressure_no_overcommit",),
    max_examples=40,
)
def check_pressure_no_overcommit(draw: ContractDraw):
    """The pressure controller never promotes, demotes at most ``budget``
    blocks, reports ``engaged == (usage > cap)``, and lands exactly on the
    low watermark whenever enough cold candidates, free far slots and
    budget exist."""
    import jax.numpy as jnp

    from repro.core import address_space as asp
    from repro.core import init_state, start_all_far, tiering
    from repro.core.types import allocated_hp_mask

    spec, _ = build_engine(draw)
    cfg = spec.cfg
    rng = np.random.default_rng(draw.seed)
    state = start_all_far(cfg, init_state(cfg))
    ids = jnp.asarray(rng.integers(0, cfg.n_logical, size=64), jnp.int32)
    state = asp.record_accesses(cfg, state, ids)
    state = tiering.tick(cfg, state, "memtierd")  # promote some blocks near

    def near_used(s):
        alloc = np.asarray(allocated_hp_mask(cfg, s))
        return int((alloc & (np.asarray(s.block_table) < cfg.n_near)).sum())

    used = near_used(state)
    out, engaged, _ = tiering.pressure_tick(
        cfg, state, jnp.asarray(draw.cap, jnp.int32), jnp.zeros((), bool),
        jnp.zeros((), jnp.int32), budget=draw.budget, slack=draw.slack)
    used2 = near_used(out)
    bt = np.asarray(out.block_table)
    assert sorted(bt) == list(range(cfg.n_slots)), "lost slot permutation"
    assert bool(engaged) == (used > draw.cap), "engaged != (usage > cap)"
    assert used2 <= used, "pressure tick promoted"
    assert used - used2 <= draw.budget, "demoted more than the budget"
    target = max(draw.cap - draw.slack, 0)
    free_far = (cfg.n_slots - cfg.n_near) - (
        int(np.asarray(allocated_hp_mask(cfg, state)).sum()) - used)
    if used > draw.cap and used - target <= draw.budget \
            and free_far >= used - target:
        assert used2 == target, "did not land on the low watermark"


# --------------------------------------------------------------------------
# §16 — kernel backend exactness
# --------------------------------------------------------------------------
@register_contract(
    "INV-KERNEL-BACKEND-EXACT", "§16",
    drivers=("run", "run_sharded", "run_sharded(host_sharded=True)",
             "run_churn"),
    pins=(
        "tests/test_kernels.py::TestEngineBackendEquivalence",
        "tests/test_kernels.py::TestRegisteredKernelEquivalence",
    ),
    max_examples=2,
)
def check_kernel_backend_exact(draw: ContractDraw):
    """The engine's hot-path kernels are backend-transparent: running any
    driver with ``kernel_backend="pallas"`` (interpret mode on CPU) is
    bit-identical to ``kernel_backend="xla"`` in the final state and every
    collector series, for any geometry/policy/gpac draw."""
    from repro.core import engine, sharding

    spec, s0 = build_engine(draw)
    source = trace_source(draw, spec)
    ref_state, ref = engine.run(
        spec, s0, source, policy=draw.policy, use_gpac=draw.use_gpac,
        kernel_backend="xla")
    pl_state, pl = engine.run(
        spec, s0, source, policy=draw.policy, use_gpac=draw.use_gpac,
        kernel_backend="pallas")
    assert_states_equal(ref_state, pl_state, "pallas run state diverged")
    assert_series_equal(ref, pl, "pallas run series diverged")
    mesh = sharding.guest_mesh(1)  # full shard_map path on one device
    sh_state, sh = engine.run_sharded(
        spec, s0, source, mesh=mesh, policy=draw.policy,
        use_gpac=draw.use_gpac, host_sharded=draw.host_sharded,
        kernel_backend="pallas")
    assert_states_equal(ref_state, sh_state, "pallas run_sharded diverged")
    assert_series_equal(ref, sh, "pallas run_sharded series diverged")
    cs, se = engine.run_churn(
        spec, engine.init_churn(spec), source, policy=draw.policy,
        use_gpac=draw.use_gpac, kernel_backend="pallas")
    assert_states_equal(ref_state, cs.state, "pallas run_churn diverged")
    assert_series_equal(
        ref, {k: v for k, v in se.items() if k not in engine._CHURN_SERIES},
        "pallas run_churn series diverged")


# --------------------------------------------------------------------------
# §17 — multi-host exactness
# --------------------------------------------------------------------------
_MULTIHOST_JOB_VERIFIED = False


@register_contract(
    "INV-MULTIHOST-EXACT", "§17",
    drivers=("run", "run_sharded", "run_sharded(host_sharded=True)",
             "run_churn"),
    pins=(
        "tests/test_multihost.py::TestMultiprocessMatrix",
        "scripts/ci_smoke_multihost.py",
    ),
    max_examples=2,
)
def check_multihost_exact(draw: ContractDraw):
    """An engine run spanning OS processes, and any ``arbitration_stride``
    batching of its exchange, is bit-identical to the single-process
    default: stride=1 compiles to the pre-knob program, a dividing
    stride>1 matches across ``run``/``run_sharded`` on both host paths,
    and a coordinated 2-process job reproduces the in-process run."""
    from repro.core import engine, sharding

    spec, s0 = build_engine(draw)
    source = trace_source(draw, spec)
    ref_state, ref = engine.run(
        spec, s0, source, policy=draw.policy, use_gpac=draw.use_gpac)
    s1_state, s1 = engine.run(  # stride=1 is the exact pre-knob program
        spec, s0, source, policy=draw.policy, use_gpac=draw.use_gpac,
        arbitration_stride=1)
    assert_states_equal(ref_state, s1_state, "stride=1 state diverged")
    assert_series_equal(ref, s1, "stride=1 series diverged")

    # smallest prime factor of n_windows: a dividing stride > 1 when one
    # exists (prime window counts only get the stride=1 pin above)
    stride = next((d for d in range(2, draw.n_windows + 1)
                   if draw.n_windows % d == 0), 1)
    if stride > 1:
        st_state, st = engine.run(
            spec, s0, source, policy=draw.policy, use_gpac=draw.use_gpac,
            arbitration_stride=stride)
        mesh = sharding.guest_mesh(1)  # full shard_map path on one device
        sh_state, sh = engine.run_sharded(
            spec, s0, source, mesh=mesh, policy=draw.policy,
            use_gpac=draw.use_gpac, host_sharded=draw.host_sharded,
            arbitration_stride=stride)
        assert_states_equal(
            st_state, sh_state, f"stride={stride} sharded state diverged")
        assert_series_equal(
            st, sh, f"stride={stride} sharded series diverged")

    # the coordinated 2-process x 2-device job, once per test process (it
    # pays two jax inits + compiles; the launched matrix itself asserts
    # bit-equality against each worker's own single-process run)
    global _MULTIHOST_JOB_VERIFIED
    if not _MULTIHOST_JOB_VERIFIED:
        import pathlib

        from repro.launch import multihost

        root = pathlib.Path(__file__).resolve().parents[3]
        smoke = root / "scripts" / "ci_smoke_multihost.py"
        multihost.launch_check(str(smoke), marker="MULTIHOST SMOKE OK",
                               num_processes=2, devices_per_process=2,
                               cwd=str(root))
        _MULTIHOST_JOB_VERIFIED = True
