"""Unified transformer assembly for all 10 assigned architectures.

One code path handles dense / MoE / hybrid (attn+mamba) / ssm (xLSTM) /
enc-dec (whisper) / vlm (M-RoPE) via ``ArchConfig`` flags:

  * layers are grouped into identical super-blocks of ``cfg.group_size``
    (jamba: 8 = 1 attn + 7 mamba; xlstm: 8 = 7 mLSTM + 1 sLSTM); parameters
    are stacked over groups and the stack is ``lax.scan``-ed (small HLO,
    constant compile time in depth);
  * ``cfg.remat == "block"`` checkpoints each super-block (activation memory
    ~ depth/group_size checkpoints);
  * the decode path reads/writes KV through the **two-level paged cache** --
    the paper's indirection that GPAC consolidates (DESIGN.md §3.1);
  * cross-entropy is computed in sequence chunks so the (B, S, vocab) logits
    tensor is never materialized (vocab up to 256k).

Modes: ``train`` (loss), ``prefill`` (logits for last position + cache),
``decode`` (one token through the cache).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import xlstm as X
from repro.models.dist import NO_DIST, Dist

AUX_WEIGHT = 0.01  # MoE load-balance loss weight


def scan_or_unroll(body, carry, xs, unroll: bool, length: int | None = None):
    """lax.scan, or a python unroll of it (identical semantics) when the
    dry-run needs XLA cost analysis to see every iteration."""
    if not unroll:
        return jax.lax.scan(body, carry, xs, length=length)
    n = length if xs is None else jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


# ===========================================================================
# init
# ===========================================================================
def _init_layer(cfg: ArchConfig, key, j: int, cross: bool) -> dict:
    """One layer's params; ``j`` is the position within the super-block."""
    kind = cfg.layer_kind(j)
    ks = L.split(key, 4)
    p = {"norm1": L.init_norm(cfg)}
    if kind == "attn":
        p["attn"] = L.init_attention(cfg, ks[0])
    elif kind == "mamba":
        p["mamba"] = M.init_mamba(cfg, ks[0])
    elif kind == "mlstm":
        p["mlstm"] = X.init_mlstm(cfg, ks[0])
    elif kind == "slstm":
        p["slstm"] = X.init_slstm(cfg, ks[0])
    if cross:
        p["norm_x"] = L.init_norm(cfg)
        p["xattn"] = L.init_cross_attention(cfg, ks[1])
    if cfg.d_ff or cfg.layer_is_moe(j):
        p["norm2"] = L.init_norm(cfg)
        p["ffn"] = (
            MOE.init_moe(cfg, ks[2]) if cfg.layer_is_moe(j)
            else L.init_mlp(cfg, ks[2])
        )
    return p


def _init_group(cfg: ArchConfig, key, cross: bool) -> dict:
    ks = L.split(key, cfg.group_size)
    return {f"layer{j}": _init_layer(cfg, ks[j], j, cross) for j in range(cfg.group_size)}


def _enc_cfg(cfg: ArchConfig) -> ArchConfig:
    """Encoder uses plain attention + gelu MLP (whisper)."""
    return cfg.replace(activation="gelu", n_experts=0, attn_period=0,
                       slstm_period=0, encdec=False, family="dense",
                       n_layers=cfg.n_enc_layers)


def init_params(cfg: ArchConfig, key) -> dict:
    ks = L.split(key, 4)
    params = {
        "embed": L.init_embedding(cfg, ks[0]),
        "final_norm": L.init_norm(cfg),
    }
    gkeys = L.split(ks[1], cfg.n_groups)
    params["groups"] = jax.vmap(
        lambda k: _init_group(cfg, k, cross=cfg.encdec)
    )(gkeys)
    if cfg.encdec:
        ecfg = _enc_cfg(cfg)
        ekeys = L.split(ks[2], ecfg.n_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _init_group(ecfg, k, cross=False))(ekeys),
            "final_norm": L.init_norm(cfg),
        }
    return params


# ===========================================================================
# layer application
# ===========================================================================
def _apply_mixer_train(cfg, lp, h, positions, j, dist, causal=True):
    kind = cfg.layer_kind(j)
    x = L.apply_norm(cfg, lp["norm1"], h)
    if kind == "attn":
        q, k, v = L.qkv(cfg, lp["attn"], x, positions, rope=not cfg.encdec)
        o = L.chunked_gqa_attention(q, k, v, causal=causal, unroll=cfg.unroll,
                                    causal_skip=cfg.causal_skip)
        B, S = x.shape[:2]
        mix = L._proj(o.reshape(B, S, cfg.n_heads * cfg.hd), lp["attn"]["wo"])
    elif kind == "mamba":
        mix = M.mamba_train(cfg, lp["mamba"], x)
    elif kind == "mlstm":
        mix = X.mlstm_train(cfg, lp["mlstm"], x)
    else:
        mix = X.slstm_train(cfg, lp["slstm"], x)
    return h + mix


def _apply_ffn(cfg, lp, h, j, dist):
    """FFN sub-block; returns (h, aux_loss)."""
    if "ffn" not in lp:
        return h, jnp.zeros((), jnp.float32)
    x = L.apply_norm(cfg, lp["norm2"], h)
    if cfg.layer_is_moe(j):
        out = MOE.apply_moe(cfg, lp["ffn"], x, dist)
        aux = MOE.aux_loss(cfg, lp["ffn"], x)
    else:
        out = L.apply_mlp(cfg, lp["ffn"], x)
        aux = jnp.zeros((), jnp.float32)
    return h + out, aux


def _apply_group_train(cfg, gp, h, positions, enc_kv, dist, causal=True):
    aux_total = jnp.zeros((), jnp.float32)
    for j in range(cfg.group_size):
        lp = gp[f"layer{j}"]
        h = _apply_mixer_train(cfg, lp, h, positions, j, dist, causal)
        if "xattn" in lp:
            xh = L.apply_norm(cfg, lp["norm_x"], h)
            h = h + L.cross_attention(cfg, lp["xattn"], xh, *enc_kv(lp))
        h, aux = _apply_ffn(cfg, lp, h, j, dist)
        aux_total = aux_total + aux
        h = dist.constrain(h, dist.dp, None, None)
    return h, aux_total


# ===========================================================================
# forward (train / prefill)
# ===========================================================================
def _encode(cfg: ArchConfig, params, frames: jax.Array, dist) -> jax.Array:
    """Whisper encoder over stubbed frame embeddings (B, F, d)."""
    ecfg = _enc_cfg(cfg)
    h = frames + params["embed"]["pos_enc"][None, : frames.shape[1]]
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1])[None], frames.shape[:2])

    def body(carry, ep):
        h = carry
        h, _ = _apply_group_train(ecfg, ep, h, pos, None, dist, causal=False)
        return h, None

    h, _ = scan_or_unroll(body, h, params["encoder"]["layers"], cfg.unroll)
    return L.apply_norm(cfg, params["encoder"]["final_norm"], h)


def _embed_tokens(cfg, params, tokens, positions, lens=None):
    h = L.embed(cfg, params["embed"], tokens)
    if cfg.encdec:  # learned positions (whisper decoder)
        if lens is None:
            h = h + params["embed"]["pos_dec"][None, : tokens.shape[1]]
        else:
            h = h + params["embed"]["pos_dec"][lens][:, None]
    return h


def forward_train(cfg: ArchConfig, params, batch: dict, dist: Dist = NO_DIST):
    """-> (hidden (B,S,d), aux_loss). ``batch``: tokens + optional positions
    (3,B,S mrope) / frames (whisper)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    h = _embed_tokens(cfg, params, tokens, positions)
    h = dist.constrain(h, dist.dp, None, None)

    enc_out = None
    if cfg.encdec:
        enc_out = _encode(cfg, params, batch["frames"], dist)

    def body(carry, gp):
        h, aux = carry
        enc_kv = (lambda lp: L.encoder_kv(cfg, lp["xattn"], enc_out)) if cfg.encdec else None
        h, a = _apply_group_train(cfg, gp, h, positions, enc_kv, dist)
        return (h, aux + a), None

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    (h, aux), _ = scan_or_unroll(
        body, (h, jnp.zeros((), jnp.float32)), params["groups"], cfg.unroll)
    h = L.apply_norm(cfg, params["final_norm"], h)
    return h, aux


def chunked_ce_loss(cfg: ArchConfig, params, h, labels, chunk: int = 512):
    """Cross-entropy without materializing (B, S, vocab): scan over S chunks.
    labels < 0 are masked out (padding)."""
    B, S, d = h.shape
    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = hp.reshape(B, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = lp.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        total, count = carry
        hb, lb = xs  # (B, chunk, d), (B, chunk)
        logits = L.unembed(cfg, params["embed"], hb)  # f32 (B, chunk, V)
        logz = jax.nn.logsumexp(logits, axis=-1)
        mask = lb >= 0
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], axis=-1
        )[..., 0]
        ce = jnp.where(mask, logz - tgt, 0.0)
        return (total + ce.sum(), count + mask.sum()), None

    (total, count), _ = scan_or_unroll(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (hc, lc),
        cfg.unroll,
    )
    return total / jnp.maximum(count, 1)


def loss_fn(cfg: ArchConfig, params, batch: dict, dist: Dist = NO_DIST):
    h, aux = forward_train(cfg, params, batch, dist)
    ce = chunked_ce_loss(cfg, params, h, batch["labels"])
    return ce + AUX_WEIGHT * aux, {"ce": ce, "aux": aux}


# ===========================================================================
# caches
# ===========================================================================
def n_pool_pages(cfg: ArchConfig, seq_len: int, slack: int = 8) -> int:
    return -(-seq_len // cfg.page_size) + slack


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               n_pool: int | None = None) -> dict:
    """Empty decode cache for ``max_seq`` context (paged KV + mixer states).
    ``n_pool`` overrides the physical page pool size (the serving engine
    sizes it to the placement manager's GPA space, slack included)."""
    n_pool = n_pool or n_pool_pages(cfg, max_seq)
    page, KVH, hd = cfg.page_size, cfg.n_kv_heads, cfg.hd
    G = cfg.n_groups

    def per_layer(j):
        kind = cfg.layer_kind(j)
        if kind == "attn":
            return {
                "k_pages": jnp.zeros((G, batch, KVH, n_pool, page, hd), cfg.dtype),
                "v_pages": jnp.zeros((G, batch, KVH, n_pool, page, hd), cfg.dtype),
            }
        if kind == "mamba":
            c = M.init_mamba_cache(cfg, batch)
            return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (G, *x.shape)).copy(), c)
        if kind == "mlstm":
            c = X.init_mlstm_cache(cfg, batch)
            return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (G, *x.shape)).copy(), c)
        c = X.init_slstm_cache(cfg, batch)
        return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (G, *x.shape)).copy(), c)

    cache = {
        "layers": {f"layer{j}": per_layer(j) for j in range(cfg.group_size)},
        "btab": jnp.broadcast_to(
            jnp.arange(n_pool, dtype=jnp.int32)[None], (batch, n_pool)
        ).copy(),
        "lens": jnp.zeros((batch,), jnp.int32),
    }
    if cfg.encdec:
        cache["enc_k"] = jnp.zeros((G, batch, cfg.n_frames, KVH, hd), cfg.dtype)
        cache["enc_v"] = jnp.zeros((G, batch, cfg.n_frames, KVH, hd), cfg.dtype)
    return cache


def cache_seq_capacity(cfg: ArchConfig, cache: dict) -> int:
    """Max context the cache can hold (pages * page_size)."""
    return cache["btab"].shape[1] * cfg.page_size


# ===========================================================================
# decode
# ===========================================================================
def _apply_layer_decode(cfg, lp, lc, h, lens, btab, enc_kv, dist, j):
    """One layer, one token. lc: this layer's cache slice (no group dim)."""
    kind = cfg.layer_kind(j)
    x = L.apply_norm(cfg, lp["norm1"], h)
    new_lc = dict(lc)
    if kind == "attn":
        mix, k_pages, v_pages = L.attention_decode_paged(
            cfg, lp["attn"], x, lc["k_pages"], lc["v_pages"], btab, lens
        )
        new_lc["k_pages"] = k_pages
        new_lc["v_pages"] = v_pages
    elif kind == "mamba":
        mix, st = M.mamba_decode(cfg, lp["mamba"], x, lc)
        new_lc = st
    elif kind == "mlstm":
        mix, st = X.mlstm_decode(cfg, lp["mlstm"], x, lc)
        new_lc = st
    else:
        mix, st = X.slstm_decode(cfg, lp["slstm"], x, lc)
        new_lc = st
    h = h + mix
    if "xattn" in lp:
        xh = L.apply_norm(cfg, lp["norm_x"], h)
        h = h + L.cross_attention_decode(cfg, lp["xattn"], xh, *enc_kv)
    h, _ = _apply_ffn(cfg, lp, h, j, dist)
    return h, new_lc


def decode_step(cfg: ArchConfig, params, cache: dict, tokens: jax.Array,
                dist: Dist = NO_DIST):
    """tokens (B, 1) -> (logits (B, vocab), new cache). Position = lens."""
    lens = cache["lens"]
    positions = lens[:, None]
    h = _embed_tokens(cfg, params, tokens, positions, lens=lens)
    btab = cache["btab"]

    def body(h, xs):
        if cfg.encdec:
            gp, gc, ek, ev = xs
            enc_kv = (ek, ev)
        else:
            gp, gc = xs
            enc_kv = None
        new_gc = {}
        for j in range(cfg.group_size):
            h, new_gc[f"layer{j}"] = _apply_layer_decode(
                cfg, gp[f"layer{j}"], gc[f"layer{j}"], h, lens, btab,
                enc_kv, dist, j,
            )
        return h, new_gc

    if cfg.encdec:
        xs = (params["groups"], cache["layers"], cache["enc_k"], cache["enc_v"])
    else:
        xs = (params["groups"], cache["layers"])
    h, new_layers = scan_or_unroll(body, h, xs, cfg.unroll)
    h = L.apply_norm(cfg, params["final_norm"], h)
    logits = L.unembed(cfg, params["embed"], h[:, 0:1])[:, 0]
    new_cache = {**cache, "layers": new_layers, "lens": lens + 1}
    return logits, new_cache


# ===========================================================================
# prefill
# ===========================================================================
def _pack_pages(cfg: ArchConfig, kv: jax.Array, n_pool: int) -> jax.Array:
    """(B, S, KVH, hd) -> (B, KVH, n_pool, page, hd) identity-paged."""
    B, S, KVH, hd = kv.shape
    page = cfg.page_size
    pad = n_pool * page - S
    kv = jnp.pad(kv, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kv = kv.reshape(B, n_pool, page, KVH, hd)
    return kv.transpose(0, 3, 1, 2, 4)


def prefill(cfg: ArchConfig, params, batch: dict, max_seq: int | None = None,
            dist: Dist = NO_DIST, n_pool: int | None = None):
    """Full-sequence forward that returns (last-token logits, decode cache)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_seq = max_seq or S
    n_pool = n_pool or n_pool_pages(cfg, max_seq)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    h = _embed_tokens(cfg, params, tokens, positions)
    enc_out = _encode(cfg, params, batch["frames"], dist) if cfg.encdec else None

    def body(h, gp):
        new_gc = {}
        for j in range(cfg.group_size):
            lp = gp[f"layer{j}"]
            kind = cfg.layer_kind(j)
            x = L.apply_norm(cfg, lp["norm1"], h)
            if kind == "attn":
                q, k, v = L.qkv(cfg, lp["attn"], x, positions, rope=not cfg.encdec)
                o = L.chunked_gqa_attention(q, k, v, causal=True, unroll=cfg.unroll,
                                            causal_skip=cfg.causal_skip)
                mix = L._proj(o.reshape(B, S, cfg.n_heads * cfg.hd), lp["attn"]["wo"])
                new_gc[f"layer{j}"] = {
                    "k_pages": _pack_pages(cfg, k, n_pool),
                    "v_pages": _pack_pages(cfg, v, n_pool),
                }
            elif kind == "mamba":
                mix, st = M.mamba_prefill(cfg, lp["mamba"], x)
                new_gc[f"layer{j}"] = st
            elif kind == "mlstm":
                mix, st = X.mlstm_prefill(cfg, lp["mlstm"], x)
                new_gc[f"layer{j}"] = st
            else:
                mix, st = X.slstm_prefill(cfg, lp["slstm"], x)
                new_gc[f"layer{j}"] = st
            h = h + mix
            if "xattn" in lp:
                xh = L.apply_norm(cfg, lp["norm_x"], h)
                ek, ev = L.encoder_kv(cfg, lp["xattn"], enc_out)
                h = h + L.cross_attention(cfg, lp["xattn"], xh, ek, ev)
                new_gc[f"layer{j}"]["_enc_k"] = ek
                new_gc[f"layer{j}"]["_enc_v"] = ev
            h, _ = _apply_ffn(cfg, lp, h, j, dist)
        return h, new_gc

    h, layers = scan_or_unroll(body, h, params["groups"], cfg.unroll)
    h = L.apply_norm(cfg, params["final_norm"], h)
    logits = L.unembed(cfg, params["embed"], h[:, -1:])[:, 0]

    cache = {
        "layers": layers,
        "btab": jnp.broadcast_to(
            jnp.arange(n_pool, dtype=jnp.int32)[None], (B, n_pool)).copy(),
        "lens": jnp.full((B,), S, jnp.int32),
    }
    if cfg.encdec:
        cache["enc_k"] = layers["layer0"]["_enc_k"]
        cache["enc_v"] = layers["layer0"]["_enc_v"]
        for j in range(cfg.group_size):
            layers[f"layer{j}"].pop("_enc_k", None)
            layers[f"layer{j}"].pop("_enc_v", None)
    return logits, cache
