"""Mamba (S6 selective-scan) mixer for the Jamba hybrid architecture.

TPU adaptation: the CUDA selective-scan kernel becomes a two-level scan --
``lax.scan`` over sequence chunks with a parallel ``associative_scan`` inside
each chunk, so peak memory is (B, chunk, d_inner, d_state) instead of
(B, S, d_inner, d_state) and the HLO stays one while-loop. The depthwise
causal conv is hoisted out of the scan (it is parallel over seq).

Decode carries (ssm_state h, conv tail) -- constant-size state, which is why
jamba runs the ``long_500k`` cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L


def dt_rank(cfg: ArchConfig) -> int:
    return -(-cfg.d_model // 16)


def init_mamba(cfg: ArchConfig, key) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    ds = cfg.ssm_state
    dr = dt_rank(cfg)
    ks = L.split(key, 6)
    return {
        "in_proj": L.dense_init(ks[0], d, 2 * di, cfg.dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di), jnp.float32)
                   * (cfg.ssm_conv ** -0.5)).astype(cfg.dtype),
        "conv_b": jnp.zeros((di,), cfg.dtype),
        "x_proj": L.dense_init(ks[2], di, dr + 2 * ds, cfg.dtype),
        "dt_proj": L.dense_init(ks[3], dr, di, cfg.dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds)).copy()),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": L.dense_init(ks[4], di, d, cfg.dtype),
    }


def _causal_conv(p: dict, x: jax.Array, tail: jax.Array | None = None):
    """Depthwise causal conv over seq. x (B, S, di); tail (B, dc-1, di) from
    the previous segment (decode) or zeros (train). Returns (y, new_tail)."""
    dc = p["conv_w"].shape[0]
    B, S, di = x.shape
    if tail is None:
        tail = jnp.zeros((B, dc - 1, di), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)  # (B, S+dc-1, di)
    # depthwise conv as a sum of shifted scalings (dc is 4: unrolled adds)
    y = sum(
        xp[:, i : i + S] * p["conv_w"][i][None, None, :] for i in range(dc)
    ) + p["conv_b"]
    new_tail = jax.lax.dynamic_slice_in_dim(xp, xp.shape[1] - (dc - 1), dc - 1, 1)
    return y, new_tail


def _ssm_params(cfg: ArchConfig, p: dict, xc: jax.Array):
    """xc (..., di) -> dA (..., di, ds), dBx (..., di, ds), Cs (..., ds).

    §Perf: ``cfg.ssm_bf16`` stores the (di, ds) state-expansion tensors in
    bf16 (the recurrence carry stays f32 in the scan), halving the dominant
    HBM traffic of the chunked selective scan."""
    dr = dt_rank(cfg)
    ds = cfg.ssm_state
    dbc = L._proj(xc, p["x_proj"]).astype(jnp.float32)
    dt, Bs, Cs = jnp.split(dbc, [dr, dr + ds], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])  # (di, ds)
    dA = jnp.exp(dt[..., None] * A)  # (..., di, ds)
    dBx = (dt * xc.astype(jnp.float32))[..., None] * Bs[..., None, :]
    if cfg.ssm_bf16:
        dA = dA.astype(jnp.bfloat16)
        dBx = dBx.astype(jnp.bfloat16)
    return dA, dBx, Cs


def mamba_train(cfg: ArchConfig, p: dict, x: jax.Array, chunk: int = 16):
    """x (B, S, d) -> (B, S, d); returns output only (no state)."""
    out, _ = _mamba_forward(cfg, p, x, h0=None, tail0=None, chunk=chunk)
    return out


def _mamba_forward(cfg, p, x, h0, tail0, chunk=16):
    B, S, d = x.shape
    di = cfg.ssm_expand * d
    ds = cfg.ssm_state
    xz = L._proj(x, p["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc, tail = _causal_conv(p, x_in, tail0)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)

    if h0 is None:
        h0 = jnp.zeros((B, di, ds), jnp.float32)
    # pad S to chunk multiple; padded steps must be identity updates or the
    # final state handed to decode would keep evolving past the sequence end
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    xcp = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
    valid = (jnp.arange(n_chunks * chunk) < S).reshape(n_chunks, chunk)

    def step(h, xs):
        xchunk, vchunk = xs  # (B, chunk, di), (chunk,)
        dA, dBx, Cs = _ssm_params(cfg, p, xchunk)  # (B,c,di,ds) x2, (B,c,ds)
        v = vchunk[None, :, None, None]
        dA = jnp.where(v, dA, 1.0)
        dBx = jnp.where(v, dBx, 0.0)

        def combine(a, b):
            (a1, b1), (a2, b2) = a, b
            return a2 * a1, a2 * b1 + b2

        dA_all = jnp.concatenate([jnp.ones_like(dA[:, :1]), dA], axis=1)
        # carry enters the chunk in the scan dtype (bf16 when cfg.ssm_bf16;
        # the inter-chunk carry returned below is always f32)
        dBx_all = jnp.concatenate([h[:, None].astype(dBx.dtype), dBx], axis=1)
        accA, hs = jax.lax.associative_scan(combine, (dA_all, dBx_all), axis=1)
        hs = hs[:, 1:]  # (B, c, di, ds)
        y = (hs.astype(jnp.float32) * Cs[:, :, None, :]).sum(-1)  # (B, c, di)
        return hs[:, -1].astype(jnp.float32), y

    xchunks = xcp.reshape(B, n_chunks, chunk, di).transpose(1, 0, 2, 3)
    h_final, ys = jax.lax.scan(step, h0, (xchunks, valid))
    y = ys.transpose(1, 0, 2, 3).reshape(B, n_chunks * chunk, di)[:, :S]
    y = y + p["D"] * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = L._proj(y.astype(x.dtype), p["out_proj"])
    return out, (h_final, tail)


def init_mamba_cache(cfg: ArchConfig, batch: int) -> dict:
    di = cfg.ssm_expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
        "conv_tail": jnp.zeros((batch, cfg.ssm_conv - 1, di), cfg.dtype),
    }


def mamba_decode(cfg: ArchConfig, p: dict, x: jax.Array, cache: dict):
    """x (B, 1, d); single-step recurrence."""
    out, (h, tail) = _mamba_forward(
        cfg, p, x, h0=cache["h"], tail0=cache["conv_tail"], chunk=1
    )
    return out, {"h": h, "conv_tail": tail}


def mamba_prefill(cfg: ArchConfig, p: dict, x: jax.Array, chunk: int = 16):
    """Full-sequence forward that also returns the final decode cache."""
    out, (h, tail) = _mamba_forward(cfg, p, x, h0=None, tail0=None, chunk=chunk)
    return out, {"h": h, "conv_tail": tail}
