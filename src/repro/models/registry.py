"""Model registry: build(config) -> Model handle with init / loss / prefill /
decode plus ShapeDtypeStruct input specs for every assigned shape cell.

``input_specs(cfg, shape)`` is the dry-run contract (system prompt): weak-type
correct, shardable stand-ins, no device allocation. ``decode`` cells spec the
*cache* too (the KV pages are inputs to ``serve_step``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import configs as config_lib
from repro.configs.base import SHAPE_SPECS, ArchConfig
from repro.models import transformer as T
from repro.models.dist import NO_DIST, Dist


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable  # (key) -> params
    loss_fn: Callable  # (params, batch, dist) -> (loss, metrics)
    prefill: Callable  # (params, batch, max_seq, dist) -> (logits, cache)
    decode: Callable  # (params, cache, tokens, dist) -> (logits, cache)
    init_cache: Callable  # (batch, max_seq) -> cache


def build(cfg: ArchConfig | str) -> Model:
    if isinstance(cfg, str):
        cfg = config_lib.get(cfg)
    return Model(
        cfg=cfg,
        init=lambda key: T.init_params(cfg, key),
        loss_fn=lambda params, batch, dist=NO_DIST: T.loss_fn(cfg, params, batch, dist),
        prefill=lambda params, batch, max_seq=None, dist=NO_DIST, n_pool=None:
            T.prefill(cfg, params, batch, max_seq, dist, n_pool),
        decode=lambda params, cache, tokens, dist=NO_DIST: T.decode_step(
            cfg, params, cache, tokens, dist),
        init_cache=lambda batch, max_seq, n_pool=None: T.init_cache(
            cfg, batch, max_seq, n_pool),
    )


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct only -- never allocates)
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, B: int, S: int) -> dict:
    """Training/prefill batch stand-ins (tokens + modality stubs)."""
    batch = {
        "tokens": _sds((B, S), jnp.int32),
        "labels": _sds((B, S), jnp.int32),
    }
    if cfg.mrope:
        batch["positions"] = _sds((3, B, S), jnp.int32)
    if cfg.encdec:
        batch["frames"] = _sds((B, cfg.n_frames, cfg.d_model), cfg.dtype)
    return batch


def cache_specs(cfg: ArchConfig, B: int, max_seq: int) -> dict:
    """Decode-cache stand-ins mirroring transformer.init_cache's pytree."""
    shapes = jax.eval_shape(lambda: T.init_cache(cfg, B, max_seq))
    return jax.tree.map(lambda x: _sds(x.shape, x.dtype), shapes)


def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """All inputs of the step function the cell lowers:
    train -> kwargs of loss; prefill -> kwargs of prefill;
    decode -> dict(cache=..., tokens=...)."""
    spec = SHAPE_SPECS[shape_name]
    B, S = spec["global_batch"], spec["seq_len"]
    kind = spec["kind"]
    if kind == "train":
        batch = batch_specs(cfg, B, S)
        return {"batch": batch}
    if kind == "prefill":
        batch = batch_specs(cfg, B, S)
        batch.pop("labels")
        return {"batch": batch}
    # decode: one new token against an S-token cache
    return {
        "cache": cache_specs(cfg, B, S),
        "tokens": _sds((B, 1), jnp.int32),
    }
