"""Shared model layers: norms, RoPE/M-RoPE, GQA attention (train / dense
decode / paged decode), gated MLPs, embeddings.

All layers are functional: ``init_*`` returns a param dict, ``apply`` is a
pure function. Param dict keys are stable path names -- the sharding layer
(launch/sharding.py) assigns PartitionSpecs by key pattern + shape.

Numerics: params in ``cfg.dtype`` (bf16 in production), norms and softmax in
f32, matmuls accumulate f32 via ``preferred_element_type``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = in_dim ** -0.5
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def split(key, n):
    return jax.random.split(key, n)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_norm(cfg: ArchConfig, d: int | None = None) -> dict:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), cfg.dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), cfg.dtype)
    return p


def apply_norm(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = (xf ** 2).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def _rope_angles(positions: jax.Array, hd: int, theta: float) -> tuple:
    """positions (..., S) -> cos/sin (..., S, hd/2) in f32."""
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (B, S, H, hd), positions (B, S). Rotate-half convention."""
    hd = x.shape[-1]
    cos, sin = _rope_angles(positions, hd, theta)  # (B, S, hd/2)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions3: jax.Array, theta: float, sections: tuple
) -> jax.Array:
    """Qwen2-VL M-RoPE: positions3 (3, B, S); head_dim/2 split into
    (t, h, w) sections, each rotated by its own position stream."""
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    cos_parts, sin_parts = [], []
    start = 0
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    for sec, pos in zip(sections, positions3):
        f = freqs[start : start + sec]
        ang = pos.astype(jnp.float32)[..., None] * f  # (B, S, sec)
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        start += sec
    cos = jnp.concatenate(cos_parts, -1)[:, :, None, :]  # (B, S, 1, half)
    sin = jnp.concatenate(sin_parts, -1)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def rotate(cfg: ArchConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    """Dispatch rope vs mrope; ``positions`` is (B,S) or (3,B,S) for mrope."""
    if cfg.mrope:
        if positions.ndim == 2:  # text-only fallback: all three streams equal
            positions = jnp.broadcast_to(positions[None], (3, *positions.shape))
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def init_attention(cfg: ArchConfig, key) -> dict:
    d, hd, H, KVH = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, H * hd, cfg.dtype),
        "wk": dense_init(ks[1], d, KVH * hd, cfg.dtype),
        "wv": dense_init(ks[2], d, KVH * hd, cfg.dtype),
        "wo": dense_init(ks[3], H * hd, d, cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), cfg.dtype)
        p["bk"] = jnp.zeros((KVH * hd,), cfg.dtype)
        p["bv"] = jnp.zeros((KVH * hd,), cfg.dtype)
    return p


def _proj(x, w, b=None):
    out = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    if b is not None:
        out = out + b
    return out


def qkv(cfg: ArchConfig, p: dict, x: jax.Array, positions: jax.Array, rope=True):
    """x (B, S, d) -> q (B,S,H,hd), k/v (B,S,KVH,hd), rotated."""
    B, S, _ = x.shape
    q = _proj(x, p["wq"], p.get("bq")).reshape(B, S, cfg.n_heads, cfg.hd)
    k = _proj(x, p["wk"], p.get("bk")).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = _proj(x, p["wv"], p.get("bv")).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    if rope:
        q = rotate(cfg, q, positions)
        k = rotate(cfg, k, positions)
    return q, k, v


def chunked_gqa_attention(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, Sk, KVH, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    q_chunk: int = 512,
    kv_offset: int = 0,
    unroll: bool = False,
    causal_skip: bool = False,
) -> jax.Array:
    """Memory-safe jnp attention: scan over query chunks so peak score memory
    is (B, H, q_chunk, Sk) f32, never (S, S). This is the lowering-path used
    for the dry-run (the Pallas flash kernel replaces it on real TPU).
    ``kv_offset``: absolute position of k[0] (cross-chunk causal alignment).
    """
    B, S, H, hd = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = hd ** -0.5
    q_chunk = min(q_chunk, S)
    n_chunks = -(-S // q_chunk)
    pad = n_chunks * q_chunk - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qc = q.reshape(B, n_chunks, q_chunk, H, hd)
    kq = k.transpose(0, 2, 1, 3)  # (B, KVH, Sk, hd)
    vq = v.transpose(0, 2, 1, 3)
    k_pos = kv_offset + jnp.arange(Sk)

    def chunk(carry, inputs):
        ci, qb = inputs  # qb (B, q_chunk, H, hd)
        qb = qb.reshape(B, q_chunk, KVH, G, hd).transpose(0, 2, 3, 1, 4)
        s = jnp.einsum(
            "bkgqd,bksd->bkgqs", qb.astype(jnp.float32), kq.astype(jnp.float32)
        ) * scale
        if causal:
            q_pos = ci * q_chunk + jnp.arange(q_chunk)
            mask = k_pos[None, :] <= q_pos[:, None]
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bksd->bkgqd", p, vq.astype(jnp.float32))
        o = o.transpose(0, 3, 1, 2, 4).reshape(B, q_chunk, H, hd)
        return carry, o.astype(q.dtype)

    xs = (jnp.arange(n_chunks), qc.transpose(1, 0, 2, 3, 4))
    if unroll:
        # Causal skip (§Perf): with static per-chunk shapes, query chunk ci
        # only reads K/V up to (ci+1)*q_chunk -- halves attention FLOPs and
        # score-tensor HBM traffic vs. the full rectangle (the lax.scan path
        # needs uniform shapes and keeps the rectangle; the Pallas flash
        # kernel does the equivalent block skip on real TPU).
        outs = []
        for i in range(n_chunks):
            if causal and causal_skip and kv_offset == 0:
                hi = min((i + 1) * q_chunk, Sk)
                sub_k, sub_v, sub_pos = kq[:, :, :hi], vq[:, :, :hi], k_pos[:hi]
            else:
                sub_k, sub_v, sub_pos = kq, vq, k_pos

            def chunk_i(inputs, kqi=sub_k, vqi=sub_v, k_posi=sub_pos):
                ci, qb = inputs
                qb = qb.reshape(B, q_chunk, KVH, G, hd).transpose(0, 2, 3, 1, 4)
                # scores tensor stored bf16 (§Perf iteration 5): the f32
                # softmax math reads it through a fused convert, so the only
                # f32 HBM traffic left is inside the softmax reduction
                s = jax.lax.dot_general(
                    qb, kqi, (((4,), (3,)), ((0, 1), (0, 1))),
                    preferred_element_type=jnp.float32,
                ).astype(q.dtype) * jnp.asarray(scale, q.dtype)
                if causal:
                    q_pos = ci * q_chunk + jnp.arange(q_chunk)
                    mask = k_posi[None, :] <= q_pos[:, None]
                    s = jnp.where(mask[None, None, None],
                                  s, jnp.asarray(-jnp.inf, s.dtype))
                # softmax stats in f32; weights stored at model dtype for the
                # PV matmul (flash-kernel numerics; §Perf iteration 4 --
                # halves the second pass over the (q_chunk, S) tensor)
                p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
                o = jax.lax.dot_general(
                    p, vqi, (((4,), (2,)), ((0, 1), (0, 1))),
                    preferred_element_type=jnp.float32,
                )  # batched (b,k); contraction over s -> (b,k,g,q,d)
                return o.transpose(0, 3, 1, 2, 4).reshape(B, q_chunk, H, hd).astype(q.dtype)

            outs.append(chunk_i(jax.tree.map(lambda a: a[i], xs)))
        out = jnp.stack(outs)
    else:
        _, out = jax.lax.scan(chunk, None, xs)
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * q_chunk, H, hd)
    return out[:, :S]


def attention_train(
    cfg: ArchConfig, p: dict, x: jax.Array, positions: jax.Array, causal=True
) -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    q, k, v = qkv(cfg, p, x, positions)
    o = chunked_gqa_attention(q, k, v, causal=causal, unroll=cfg.unroll)
    B, S = x.shape[:2]
    return _proj(o.reshape(B, S, cfg.n_heads * cfg.hd), p["wo"])


def attention_decode_dense(
    cfg: ArchConfig,
    p: dict,
    x: jax.Array,  # (B, 1, d)
    k_cache: jax.Array,  # (B, S_max, KVH, hd)
    v_cache: jax.Array,
    lens: jax.Array,  # int32 (B,) tokens already cached
):
    """One decode step against a dense contiguous KV cache."""
    B = x.shape[0]
    pos = lens[:, None]  # (B, 1) position of the new token
    q, k_new, v_new = qkv(cfg, p, x, pos, rope=not cfg.encdec)
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, lens].set(k_new[:, 0])
    v_cache = v_cache.at[bidx, lens].set(v_new[:, 0])
    S_max = k_cache.shape[1]
    KVH, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    qh = q.reshape(B, KVH, G, cfg.hd)
    s = jnp.einsum(
        "bkgd,bskd->bkgs", qh.astype(jnp.float32),
        k_cache.astype(jnp.float32),
    ) * (cfg.hd ** -0.5)
    mask = jnp.arange(S_max)[None] <= lens[:, None]  # include new token
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", pr, v_cache.astype(jnp.float32))
    o = o.reshape(B, 1, cfg.n_heads * cfg.hd).astype(x.dtype)
    return _proj(o, p["wo"]), k_cache, v_cache


def attention_decode_paged(
    cfg: ArchConfig,
    p: dict,
    x: jax.Array,  # (B, 1, d)
    k_pages: jax.Array,  # (B, KVH, n_pool, page, hd) per-sequence page pool
    v_pages: jax.Array,
    btab: jax.Array,  # int32 (B, pages_per_seq) logical slot -> pool page
    lens: jax.Array,  # int32 (B,)
):
    """One decode step through the two-level paged KV cache (the paper's
    technique as a first-class serving feature: the block table is the
    GPA-level indirection GPAC consolidates; page granules are tier-placed).

    The new token's K/V are scattered into the page the block table assigns
    to slot lens//page; attention gathers K/V *through* the block table.
    """
    from repro.kernels.paged_attention import ops as pa_ops

    B = x.shape[0]
    page = cfg.page_size
    pos = lens[:, None]
    q, k_new, v_new = qkv(cfg, p, x, pos, rope=not cfg.encdec)
    # write the new token through the block table
    slot = lens // page
    phys = jnp.take_along_axis(btab, slot[:, None], axis=1)[:, 0]  # (B,)
    off = lens % page
    bidx = jnp.arange(B)
    # advanced-index result layout: (B, KVH, hd) -- matches k_new[:, 0]
    k_pages = k_pages.at[bidx, :, phys, off].set(k_new[:, 0])
    v_pages = v_pages.at[bidx, :, phys, off].set(v_new[:, 0])
    KVH, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    n_pool = k_pages.shape[2]
    qh = q.reshape(B, KVH, G, cfg.hd)
    from repro.kernels import runtime

    if runtime.on_tpu():
        # kernel layout: flatten per-sequence pools into one global pool
        kf = k_pages.transpose(1, 0, 2, 3, 4).reshape(
            KVH, B * n_pool, page, cfg.hd)
        vf = v_pages.transpose(1, 0, 2, 3, 4).reshape(
            KVH, B * n_pool, page, cfg.hd)
        flat_btab = btab + (jnp.arange(B) * n_pool)[:, None]
        o = pa_ops.paged_attention(qh, kf, vf, flat_btab, lens + 1)
    else:
        # GSPMD lowering path: gather THROUGH the block table per sequence,
        # never reshaping the sharded batch dim into the pool dim (§Perf
        # iteration 2: that reshape forced a near-full KV re-layout --
        # 'involuntary full rematerialization' -- every decode step).
        pps = btab.shape[1]
        idx = btab[:, None, :, None, None]  # (B,1,pps,1,1)
        k = jnp.take_along_axis(k_pages, idx, axis=2)  # (B,KVH,pps,page,hd)
        v = jnp.take_along_axis(v_pages, idx, axis=2)
        s = jnp.einsum("bkgd,bkpsd->bkgps",
                       qh.astype(jnp.float32), k.astype(jnp.float32))
        s = s * (cfg.hd ** -0.5)  # (B,KVH,G,pps,page)
        pos = (jnp.arange(pps * page).reshape(pps, page))[None, None, None]
        mask = pos <= lens[:, None, None, None, None]
        s = jnp.where(mask, s, -jnp.inf)
        m = s.max(axis=(3, 4), keepdims=True)
        e = jnp.exp(s - m)
        e = jnp.where(mask, e, 0.0)
        num = jnp.einsum("bkgps,bkpsd->bkgd", e, v.astype(jnp.float32))
        den = e.sum(axis=(3, 4))
        o = (num / jnp.maximum(den, 1e-30)[..., None]).astype(x.dtype)
    o = o.reshape(B, 1, cfg.n_heads * cfg.hd).astype(x.dtype)
    return _proj(o, p["wo"]), k_pages, v_pages


def init_cross_attention(cfg: ArchConfig, key) -> dict:
    return init_attention(cfg, key)


def cross_attention(
    cfg: ArchConfig, p: dict, x: jax.Array, enc_k: jax.Array, enc_v: jax.Array
) -> jax.Array:
    """Decoder cross-attention against precomputed encoder K/V
    (enc_k/v: (B, F, KVH, hd))."""
    B, S, _ = x.shape
    q = _proj(x, p["wq"], p.get("bq")).reshape(B, S, cfg.n_heads, cfg.hd)
    o = chunked_gqa_attention(q, enc_k, enc_v, causal=False, unroll=cfg.unroll)
    return _proj(o.reshape(B, S, cfg.n_heads * cfg.hd), p["wo"])


def encoder_kv(cfg: ArchConfig, p: dict, enc_out: jax.Array):
    """Precompute cross-attention K/V from encoder output (B, F, d)."""
    B, F, _ = enc_out.shape
    k = _proj(enc_out, p["wk"], p.get("bk")).reshape(B, F, cfg.n_kv_heads, cfg.hd)
    v = _proj(enc_out, p["wv"], p.get("bv")).reshape(B, F, cfg.n_kv_heads, cfg.hd)
    return k, v


def cross_attention_decode(
    cfg: ArchConfig, p: dict, x: jax.Array, enc_k: jax.Array, enc_v: jax.Array
) -> jax.Array:
    """Single-token cross-attention (decode): same math, S=1, no mask."""
    return cross_attention(cfg, p, x, enc_k, enc_v)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def init_mlp(cfg: ArchConfig, key, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = split(key, 3)
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "wi_gate": dense_init(ks[0], d, ff, cfg.dtype),
            "wi_up": dense_init(ks[1], d, ff, cfg.dtype),
            "wo": dense_init(ks[2], ff, d, cfg.dtype),
        }
    return {  # plain gelu (whisper)
        "wi": dense_init(ks[0], d, ff, cfg.dtype),
        "wo": dense_init(ks[1], ff, d, cfg.dtype),
    }


def apply_mlp(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.activation == "swiglu":
        h = jax.nn.silu(_proj(x, p["wi_gate"]).astype(jnp.float32))
        h = (h * _proj(x, p["wi_up"]).astype(jnp.float32)).astype(x.dtype)
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(_proj(x, p["wi_gate"]).astype(jnp.float32))
        h = (h * _proj(x, p["wi_up"]).astype(jnp.float32)).astype(x.dtype)
    else:
        h = jax.nn.gelu(_proj(x, p["wi"]).astype(jnp.float32)).astype(x.dtype)
    return _proj(h, p["wo"])


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------
def init_embedding(cfg: ArchConfig, key) -> dict:
    ks = split(key, 2)
    p = {"tok": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), jnp.float32)
                 * 0.02).astype(cfg.dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], cfg.d_model, cfg.vocab, cfg.dtype)
    if cfg.encdec:  # learned positions for whisper
        p["pos_dec"] = (jax.random.normal(ks[1], (cfg.max_seq, cfg.d_model),
                                          jnp.float32) * 0.02).astype(cfg.dtype)
        p["pos_enc"] = (jax.random.normal(ks[0], (cfg.n_frames, cfg.d_model),
                                          jnp.float32) * 0.02).astype(cfg.dtype)
    return p


def embed(cfg: ArchConfig, p: dict, tokens: jax.Array) -> jax.Array:
    return p["tok"][tokens]


def unembed(cfg: ArchConfig, p: dict, h: jax.Array) -> jax.Array:
    w = p["tok"].T if cfg.tie_embeddings else p["unembed"]
    return jax.lax.dot_general(
        h, w, (((h.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
