"""Distribution context threaded through model apply functions.

``Dist`` carries the mesh and axis names so layers can place sharding
constraints on large intermediates (activations, MoE buffers) without the
model code knowing mesh geometry. All helpers degrade to no-ops with no mesh
(single-device smoke tests) and silently drop mesh axes that do not divide the
corresponding dim (e.g. batch=1 decode cells, 15-head attention).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Dist:
    mesh: Any = None
    dp: tuple = ("data",)  # batch/token axes ("pod","data") multi-pod
    tp: str = "model"  # heads / d_ff / vocab / experts axis

    def axis_size(self, axes) -> int:
        if self.mesh is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def fit_spec(self, shape, spec: P) -> P:
        """Drop spec axes that don't divide the dim (divisibility fallback)."""
        fixed = []
        for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
            if ax is None:
                fixed.append(None)
            elif dim % self.axis_size(ax) == 0:
                fixed.append(ax)
            else:
                fixed.append(None)
        return P(*fixed)

    def constrain(self, x: jax.Array, *spec) -> jax.Array:
        """with_sharding_constraint(x, spec) if a mesh is present."""
        if self.mesh is None:
            return x
        s = self.fit_spec(x.shape, P(*spec))
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, s))

    def sharding(self, shape, spec: P) -> Any:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.fit_spec(shape, spec))


NO_DIST = Dist()
