from repro.models import registry  # noqa: F401
