"""Mixture-of-Experts FFN: top-k routing with capacity-based dispatch.

Dispatch is the scatter/gather formulation (Switch/GShard style) rather than
dense one-hot einsum so the (E, C, d) expert buffer -- not a (T, E, C) dispatch
tensor -- is the largest intermediate; the buffer shards over the expert axis
("model" mesh axis = expert parallelism). Shared experts are always-on experts
computed densely and summed.

Capacity C = ceil(T * top_k / E * capacity_factor); overflowing (token, choice)
pairs are dropped (their combine weight contributes nothing), standard for
capacity-based MoE training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L


def pad_experts(cfg: ArchConfig) -> int:
    """Expert-bank size after EP padding (config-driven: qwen2-moe sets
    n_experts_padded=64 so the bank splits over the 16-way model axis).
    Padded experts get -inf router logits and are never selected."""
    return cfg.e_pad


def init_moe(cfg: ArchConfig, key) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    E = pad_experts(cfg)
    ks = L.split(key, 5)

    def expert_bank(k, i, o):
        keys = jax.random.split(k, E)
        return jax.vmap(lambda kk: L.dense_init(kk, i, o, cfg.dtype))(keys)

    p = {
        "router": L.dense_init(ks[0], d, E, jnp.float32),
        "experts": {
            "wi_gate": expert_bank(ks[1], d, ff),
            "wi_up": expert_bank(ks[2], d, ff),
            "wo": expert_bank(ks[3], ff, d),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(
            cfg, ks[4], d_ff=ff * cfg.n_shared_experts
        )
    return p


def apply_moe(cfg: ArchConfig, p: dict, x: jax.Array, dist=None) -> jax.Array:
    """x (B, S, d) -> (B, S, d). ``dist`` places sharding constraints on the
    (E, C, d) expert buffer: experts over the model axis (EP), capacity over
    the data axes, so the buffer never replicates."""
    from repro.models.dist import NO_DIST

    dist = dist or NO_DIST
    B, S, d = x.shape
    E = p["experts"]["wi_gate"].shape[0]
    T = B * S
    k = cfg.top_k
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T, E)
    if E > cfg.n_experts:  # padded experts never win
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask[None], -jnp.inf, logits)
    weights, experts = jax.lax.top_k(logits, k)  # (T, k)
    weights = jax.nn.softmax(weights, axis=-1)

    # capacity never exceeds T*k (beyond that no expert can overflow)
    capacity = min(max(1, int(T * k / cfg.n_experts * cfg.capacity_factor)), T * k)
    # position of each (token, choice) inside its expert's buffer
    flat_e = experts.reshape(-1)  # (T*k,) row-major: token-major order
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos = (pos * onehot).sum(-1)  # (T*k,)
    keep = pos < capacity
    safe_pos = jnp.where(keep, pos, capacity)  # drop row

    # scatter tokens into (E, C, d). The operand/updates are constrained
    # BEFORE the scatter: without this GSPMD replicates the whole scatter
    # (a ~10 GB u32 index buffer per device was observed in the jamba HLO --
    # §Perf iteration 7).
    buf = jnp.zeros((E, capacity + 1, d), x.dtype)
    buf = dist.constrain(buf, dist.tp, None, None)  # EP-sharded operand
    xk = jnp.repeat(xt, k, axis=0)  # (T*k, d) token-major like flat_e
    xk = dist.constrain(xk, dist.dp, None)
    buf = buf.at[flat_e, safe_pos].set(xk)  # duplicates impossible by pos
    buf = buf[:, :capacity]
    buf = dist.constrain(buf, dist.tp, None, None)  # EP x replicated C

    # expert FFN: batched over E
    def ffn(b, wg, wu, wo):
        h = jax.nn.silu(
            jax.lax.dot_general(b, wg, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32))
        h = h * jax.lax.dot_general(b, wu, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        return jax.lax.dot_general(h.astype(b.dtype), wo, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32).astype(b.dtype)

    out_buf = jax.vmap(ffn)(
        buf, p["experts"]["wi_gate"], p["experts"]["wi_up"], p["experts"]["wo"]
    )  # (E, C, d)

    # gather back and combine
    gathered = out_buf[flat_e, jnp.minimum(safe_pos, capacity - 1)]  # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    combined = (
        gathered.reshape(T, k, d).astype(jnp.float32)
        * weights[..., None]
    ).sum(axis=1)
    out = combined.astype(x.dtype)

    if "shared" in p:
        out = out + L.apply_mlp(cfg, p["shared"], xt)
    return out.reshape(B, S, d)


def aux_loss(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    """Load-balance auxiliary loss (Switch): E * sum(f_e * p_e)."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    E = logits.shape[-1]
    if E > cfg.n_experts:
        logits = jnp.where(jnp.arange(E) >= cfg.n_experts, -jnp.inf, logits)
    probs = jax.nn.softmax(logits, -1)
    top1 = jnp.argmax(logits, -1)
    f = jnp.mean(jax.nn.one_hot(top1, E), axis=0)
    pbar = probs.mean(0)
    return cfg.n_experts * jnp.sum(f * pbar)
