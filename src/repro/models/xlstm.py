"""xLSTM mixers: mLSTM (matrix memory, 7 of 8 blocks) and sLSTM (scalar
memory, every 8th block), following arXiv:2405.04517 with exponential gating
and the max-stabilizer.

Both mixers carry constant-size decode state (no KV cache), which is why
xlstm-1.3b runs the ``long_500k`` cell: a 524288-token context costs the same
state as a 1-token one.

Training lowers as ``lax.scan`` over time -- one while-loop per layer group in
the HLO. (The chunkwise-parallel mLSTM formulation is the known further
optimization; recorded as a §Perf candidate.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L


def _di(cfg: ArchConfig) -> int:
    return 2 * cfg.d_model


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def init_mlstm(cfg: ArchConfig, key) -> dict:
    d, di, H = cfg.d_model, _di(cfg), cfg.n_heads
    hd = di // H
    ks = L.split(key, 7)

    def bd(k):  # block-diagonal per-head projection (paper's layout)
        keys = jax.random.split(k, H)
        return jax.vmap(lambda kk: L.dense_init(kk, hd, hd, cfg.dtype))(keys)

    return {
        "up_proj": L.dense_init(ks[0], d, 2 * di, cfg.dtype),  # x, z-gate
        "wq": bd(ks[1]),  # (H, hd, hd)
        "wk": bd(ks[2]),
        "wv": bd(ks[3]),
        "w_if": L.dense_init(ks[4], di, 2 * H, jnp.float32),  # i/f gate logits
        "b_if": jnp.zeros((2 * H,), jnp.float32),
        "down_proj": L.dense_init(ks[5], di, d, cfg.dtype),
    }


def init_mlstm_cache(cfg: ArchConfig, batch: int) -> dict:
    H = cfg.n_heads
    hd = _di(cfg) // H
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def _mlstm_step(q, k, v, i_log, f_log, state):
    """One timestep. q/k/v (B, H, hd); i_log/f_log (B, H) log-space gates."""
    C, n, m = state["C"], state["n"], state["m"]
    hd = q.shape[-1]
    m_new = jnp.maximum(f_log + m, i_log)
    i_g = jnp.exp(i_log - m_new)  # (B, H)
    f_g = jnp.exp(f_log + m - m_new)
    kv = jnp.einsum("bhd,bhe->bhde", k, v)  # outer product
    C = f_g[..., None, None] * C + i_g[..., None, None] * kv
    n = f_g[..., None] * n + i_g[..., None] * k
    num = jnp.einsum("bhde,bhd->bhe", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)), 1.0)
    h = num / den[..., None]  # (B, H, hd)
    return h, {"C": C, "n": n, "m": m_new}


def _mlstm_qkvif(cfg, p, x_in):
    """x_in (..., di) -> q,k,v (..., H, hd) and i/f gate logits (..., H).
    q/k/v are block-diagonal per head (xLSTM paper layout)."""
    H = cfg.n_heads
    di = x_in.shape[-1]
    hd = di // H
    xh = x_in.reshape(*x_in.shape[:-1], H, hd)

    def bdproj(w):  # (..., H, hd) @ (H, hd, hd) -> (..., H, hd)
        return jnp.einsum("...hd,hde->...he", xh.astype(jnp.float32),
                          w.astype(jnp.float32))

    q = bdproj(p["wq"])
    k = bdproj(p["wk"]) * (hd ** -0.5)
    v = bdproj(p["wv"])
    gates = x_in.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    i_log, f_log = jnp.split(gates, 2, axis=-1)  # (..., H)
    f_log = jax.nn.log_sigmoid(f_log)
    return q, k, v, i_log, f_log


def _mlstm_forward(cfg, p, x, state0):
    B, S, d = x.shape
    xz = L._proj(x, p["up_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)  # (B, S, di)
    q, k, v, i_log, f_log = _mlstm_qkvif(cfg, p, x_in)

    def step(state, t):
        h, state = _mlstm_step(
            q[:, t], k[:, t], v[:, t], i_log[:, t], f_log[:, t], state
        )
        return state, h

    state, hs = jax.lax.scan(step, state0, jnp.arange(S))
    hs = hs.transpose(1, 0, 2, 3).reshape(B, S, -1)  # (B, S, di)
    y = hs * jax.nn.silu(z.astype(jnp.float32))
    return L._proj(y.astype(x.dtype), p["down_proj"]), state


def mlstm_train(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    out, _ = _mlstm_forward(cfg, p, x, init_mlstm_cache(cfg, x.shape[0]))
    return out


def mlstm_prefill(cfg: ArchConfig, p: dict, x: jax.Array):
    return _mlstm_forward(cfg, p, x, init_mlstm_cache(cfg, x.shape[0]))


def mlstm_decode(cfg: ArchConfig, p: dict, x: jax.Array, cache: dict):
    return _mlstm_forward(cfg, p, x, cache)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm(cfg: ArchConfig, key) -> dict:
    d, di, H = cfg.d_model, _di(cfg), cfg.n_heads
    hd = di // H
    ks = L.split(key, 4)
    gkeys = jax.random.split(ks[1], 4 * H)
    # gates are block-diagonal per head (sLSTM's head-wise recurrence)
    w_gates = jax.vmap(lambda kk: L.dense_init(kk, hd, hd, jnp.float32))(gkeys)
    return {
        "up_proj": L.dense_init(ks[0], d, 2 * di, cfg.dtype),
        "w_gates": w_gates.reshape(4, H, hd, hd),  # i,f,z,o
        "r_gates": (jax.random.normal(ks[2], (4, di), jnp.float32) * 0.1),
        "b_gates": jnp.zeros((4 * di,), jnp.float32),
        "down_proj": L.dense_init(ks[3], di, d, cfg.dtype),
    }


def init_slstm_cache(cfg: ArchConfig, batch: int) -> dict:
    di = _di(cfg)
    return {
        "c": jnp.zeros((batch, di), jnp.float32),
        "n": jnp.ones((batch, di), jnp.float32),
        "h": jnp.zeros((batch, di), jnp.float32),
        "m": jnp.full((batch, di), -1e30, jnp.float32),
    }


def _slstm_step(gx, state, r):
    """gx (B, 4*di) input-gate preactivations; diagonal recurrence via r."""
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    di = c.shape[-1]
    gi, gf, gz, go = jnp.split(gx, 4, axis=-1)
    gi = gi + r[0] * h
    gf = gf + r[1] * h
    gz = gz + r[2] * h
    go = go + r[3] * h
    f_log = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(f_log + m, gi)
    i_g = jnp.exp(gi - m_new)
    f_g = jnp.exp(f_log + m - m_new)
    c = f_g * c + i_g * jnp.tanh(gz)
    n = f_g * n + i_g
    h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h, "m": m_new}


def _slstm_forward(cfg, p, x, state0):
    B, S, d = x.shape
    H = cfg.n_heads
    xz = L._proj(x, p["up_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    di = x_in.shape[-1]
    hd = di // H
    xh = x_in.reshape(B, S, H, hd).astype(jnp.float32)
    gx = jnp.einsum("bshd,ghde->gbshe", xh, p["w_gates"])  # (4, B, S, H, hd)
    gx = gx.reshape(4, B, S, di).transpose(1, 2, 0, 3).reshape(B, S, 4 * di)
    gx = gx + p["b_gates"]

    def step(state, t):
        state = _slstm_step(gx[:, t], state, p["r_gates"])
        return state, state["h"]

    state, hs = jax.lax.scan(step, state0, jnp.arange(S))
    hs = hs.transpose(1, 0, 2)  # (B, S, di)
    y = hs * jax.nn.silu(z.astype(jnp.float32))
    return L._proj(y.astype(x.dtype), p["down_proj"]), state


def slstm_train(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    out, _ = _slstm_forward(cfg, p, x, init_slstm_cache(cfg, x.shape[0]))
    return out


def slstm_prefill(cfg: ArchConfig, p: dict, x: jax.Array):
    return _slstm_forward(cfg, p, x, init_slstm_cache(cfg, x.shape[0]))


def slstm_decode(cfg: ArchConfig, p: dict, x: jax.Array, cache: dict):
    return _slstm_forward(cfg, p, x, cache)
