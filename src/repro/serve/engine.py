"""Continuous-batching serving engine over the GPAC-tiered paged KV cache.

The engine is the paper's full loop running against a real model:

  * the model decodes through its **block table** (GVA->GPA analogue) --
    placement-agnostic, exactly the guest;
  * a placement manager (one ``core.TieredState`` whose logical pages are the
    model's KV page slots) plays guest-daemon + host: per-page **attention
    mass** is the telemetry, GPAC consolidates hot pages into dense tier
    blocks *within each sequence's pool segment* (the multi-guest pattern),
    and a host policy places blocks near/far;
  * consolidation is applied **physically** to the model cache (pages copied,
    block table rewritten), so generation must be bit-unchanged -- tested.

On CPU the near/far split is bookkeeping (metrics); on TPU the two pools map
to ``memory_kind`` device/host and ``swap_blocks`` is a real migration. The
per-page attention-mass probe uses layer 0's projections (telemetry is
pluggable; paper §4.1 scopes it out).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GpacConfig, gpac, init_state, telemetry, tiering
from repro.core import address_space as asp
from repro.core import metrics as core_metrics
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.registry import Model
from repro.serve.scheduler import (
    AdmissionQueue, Request, Scheduler, SchedulerConfig,
)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_seqs: int = 4
    max_seq_len: int = 256
    pages_per_block: int = 4  # tier-block granule (hp_ratio)
    near_fraction: float = 0.4
    gpa_slack: float = 0.5
    sched: SchedulerConfig = dataclasses.field(default_factory=SchedulerConfig)


class Engine:
    def __init__(self, model: Model, params, ecfg: EngineConfig):
        self.model = model
        self.params = params
        self.ecfg = ecfg
        self.sched = Scheduler(dataclasses.replace(
            ecfg.sched, max_seqs=ecfg.max_seqs))
        self.page = model.cfg.page_size
        # ---- placement manager: logical page-slot space over all seqs -----
        # The physical page pool covers the whole per-seq GPA segment
        # (logical pages + slack blocks): consolidation allocates fresh
        # regions in the slack, so those pages must physically exist --
        # the paper's guests likewise keep spare GPA for huge regions.
        B = ecfg.max_seqs
        pps = -(-ecfg.max_seq_len // self.page) + 8  # logical page slots/seq
        per_seq_hp = -(-pps // ecfg.pages_per_block)
        slack_hp = max(1, int(per_seq_hp * ecfg.gpa_slack))
        self.seq_hp = per_seq_hp + slack_hp  # gpa blocks per seq segment
        self.n_pool = pps
        self.n_phys = self.seq_hp * ecfg.pages_per_block  # pages per seq pool
        self.cache = model.init_cache(ecfg.max_seqs, ecfg.max_seq_len,
                                      n_pool=self.n_phys)
        # btab is logical-slot indexed (pps entries), backed by n_phys pages
        self.cache = {**self.cache,
                      "btab": self.cache["btab"][:, :pps]}
        n_hp = B * self.seq_hp
        self.pcfg = GpacConfig(
            n_logical=B * pps,
            hp_ratio=ecfg.pages_per_block,
            n_gpa_hp=n_hp,
            n_near=min(max(1, int(ecfg.near_fraction * n_hp)), n_hp - 1),
            base_elems=2,  # placement bookkeeping only (KV lives in cache)
            # CL must be >= 2: a CL of 1 can never match (paper's rule is
            # "< CL hot subpages" and a hot block has at least one)
            cl=max(2, ecfg.pages_per_block // 2 + 1),
            ipt_min_hits=1,
        )
        # identity layout per segment: logical slot (b, s) -> gpa block
        # segment of seq b
        gpt = np.full((self.pcfg.n_logical,), -1, np.int64)
        rmap = np.full((self.pcfg.n_gpa,), -1, np.int64)
        for b in range(B):
            gpa = (b * self.seq_hp * self.pcfg.hp_ratio) + np.arange(pps)
            gpt[b * pps : (b + 1) * pps] = gpa
            rmap[gpa] = b * pps + np.arange(pps)
        st = init_state(self.pcfg)
        self.pstate = asp.dataclasses_replace(
            st, gpt=jnp.asarray(gpt, jnp.int32), rmap=jnp.asarray(rmap, jnp.int32))
        self._sync_btab()
        self.decode_fn = jax.jit(
            lambda p, c, t: model.decode(p, c, t))
        self.generated = {}

    # ------------------------------------------------------------------
    # placement <-> model-cache coherence
    # ------------------------------------------------------------------
    def _model_btab_from_gpt(self) -> np.ndarray:
        """gpt (B*pps,) global gpa -> per-seq physical page index."""
        B, pps = self.ecfg.max_seqs, self.n_pool
        gpt = np.asarray(self.pstate.gpt).reshape(B, pps)
        seg = (np.arange(B) * self.seq_hp * self.pcfg.hp_ratio)[:, None]
        return (gpt - seg).astype(np.int32)

    def _sync_btab(self):
        self.cache = {**self.cache,
                      "btab": jnp.asarray(self._model_btab_from_gpt())}

    def _apply_page_moves(self, old_btab: np.ndarray, new_btab: np.ndarray):
        """Physically copy moved pages in the model cache (Algorithm 1's
        memcpy, at page granularity, on the model's own arrays)."""
        moved = old_btab != new_btab
        if not moved.any():
            return
        b_idx, s_idx = np.nonzero(moved)
        src = old_btab[b_idx, s_idx]
        dst = new_btab[b_idx, s_idx]
        layers = dict(self.cache["layers"])
        for name, lc in layers.items():
            if "k_pages" not in lc:
                continue
            new_lc = dict(lc)
            for key in ("k_pages", "v_pages"):
                arr = lc[key]  # (G, B, KVH, n_pool, page, hd)
                # advanced-index result: (n_moved, G, KVH, page, hd); dst
                # pages are freshly-allocated regions, so src/dst disjoint
                data = arr[:, b_idx, :, src]
                new_lc[key] = arr.at[:, b_idx, :, dst].set(data)
            layers[name] = new_lc
        self.cache = {**self.cache, "layers": layers}

    def maintenance(self):
        """One GPAC + tier window over the placement state, applied to the
        model cache."""
        old_btab = self._model_btab_from_gpt()
        if self.sched.cfg.use_gpac:
            B, pps = self.ecfg.max_seqs, self.n_pool
            logical = jnp.arange(self.pcfg.n_logical)
            for b in range(B):
                allow = (logical >= b * pps) & (logical < (b + 1) * pps)
                hp_lo = b * self.seq_hp
                self.pstate = gpac.gpac_maintenance(
                    self.pcfg, self.pstate, "ipt", 2,
                    allow=allow, hp_range=(hp_lo, hp_lo + self.seq_hp))
        self.pstate = tiering.tick(
            self.pcfg, self.pstate, self.sched.cfg.tier_policy, budget=32)
        self.pstate = telemetry.end_window(self.pcfg, self.pstate)
        new_btab = self._model_btab_from_gpt()
        self._apply_page_moves(old_btab, new_btab)
        self._sync_btab()

    # ------------------------------------------------------------------
    # telemetry: per-page attention mass (layer-0 probe)
    # ------------------------------------------------------------------
    def _attention_mass(self, tokens: jax.Array) -> np.ndarray:
        cfg = self.model.cfg
        if not cfg.attn_layers:
            return np.zeros((self.ecfg.max_seqs, self.n_pool))
        j = cfg.attn_layers[0] % cfg.group_size
        lp = jax.tree.map(lambda x: x[0], self.params["groups"])[f"layer{j}"]
        lc = jax.tree.map(lambda x: x[0], self.cache["layers"])[f"layer{j}"]
        lens = self.cache["lens"]
        h = L.embed(cfg, self.params["embed"], tokens)
        x = L.apply_norm(cfg, lp["norm1"], h)
        q, _, _ = L.qkv(cfg, lp["attn"], x, lens[:, None], rope=not cfg.encdec)
        B = tokens.shape[0]
        KVH, hd, page = cfg.n_kv_heads, cfg.hd, cfg.page_size
        k = lc["k_pages"]  # (B, KVH, n_pool, page, hd)
        btab = self.cache["btab"]
        k = jnp.take_along_axis(
            k, btab[:, None, :, None, None], axis=2)  # logical order
        kf = k.reshape(B, KVH, self.n_pool * page, hd)
        qh = q.reshape(B, KVH, cfg.n_heads // KVH, hd)
        s = jnp.einsum("bkgd,bksd->bkgs", qh.astype(jnp.float32),
                       kf.astype(jnp.float32)) * (hd ** -0.5)
        pos = jnp.arange(self.n_pool * page)[None, None, None]
        s = jnp.where(pos <= lens[:, None, None, None], s, -jnp.inf)
        pr = jax.nn.softmax(s, axis=-1)
        pr = jnp.where(jnp.isfinite(pr), pr, 0.0)
        mass = pr.mean(axis=(1, 2)).reshape(B, self.n_pool, page).sum(-1)
        return np.asarray(mass)

    def _record_mass(self, mass: np.ndarray, quantum: float = 0.02):
        B, pps = mass.shape
        counts = np.minimum((mass / quantum).astype(np.int64), 1 << 20)
        slots = np.arange(B * pps).reshape(B, pps)
        keep = counts > 0
        if not keep.any():
            return
        self.pstate = asp.record_accesses(
            self.pcfg, self.pstate,
            jnp.asarray(slots[keep], jnp.int32),
            jnp.asarray(counts[keep], jnp.int32))

    # ------------------------------------------------------------------
    # request lifecycle
    # ------------------------------------------------------------------
    def _reset_slot_placement(self, b: int):
        """Guest-reboot slot b: identity gpt over its segment, telemetry
        cleared (prefill writes pages at identity physical positions)."""
        pps, hp = self.n_pool, self.pcfg.hp_ratio
        seg_page0 = b * self.seq_hp * hp
        gpt = np.asarray(self.pstate.gpt).copy()
        rmap = np.asarray(self.pstate.rmap).copy()
        counts = np.asarray(self.pstate.guest_counts).copy()
        hist = np.asarray(self.pstate.ipt_hist).copy()
        repoch = np.asarray(self.pstate.region_epoch).copy()
        rmap[seg_page0 : seg_page0 + self.seq_hp * hp] = -1
        gpt[b * pps : (b + 1) * pps] = seg_page0 + np.arange(pps)
        rmap[seg_page0 : seg_page0 + pps] = b * pps + np.arange(pps)
        counts[b * pps : (b + 1) * pps] = 0
        hist[b * pps : (b + 1) * pps] = 0
        repoch[b * self.seq_hp : (b + 1) * self.seq_hp] = -1
        self.pstate = asp.dataclasses_replace(
            self.pstate,
            gpt=jnp.asarray(gpt, jnp.int32), rmap=jnp.asarray(rmap, jnp.int32),
            guest_counts=jnp.asarray(counts, jnp.int32),
            ipt_hist=jnp.asarray(hist, jnp.uint8),
            region_epoch=jnp.asarray(repoch, jnp.int32))
        self._sync_btab()

    def _prefill_into_slot(self, req: Request):
        self._reset_slot_placement(req.seq_slot)
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        batch = {"tokens": toks}
        cfg = self.model.cfg
        if cfg.mrope:
            S = toks.shape[1]
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32), (3, 1, S))
        if cfg.encdec:
            batch["frames"] = jnp.zeros((1, cfg.n_frames, cfg.d_model), cfg.dtype)
        logits, rcache = self.model.prefill(
            self.params, batch, max_seq=self.ecfg.max_seq_len,
            n_pool=self.n_phys)
        b = req.seq_slot

        def put(dst, src):
            if dst.ndim >= 2 and dst.shape[1] == self.ecfg.max_seqs:
                return dst.at[:, b].set(src[:, 0])
            return dst  # btab/lens handled below

        layers = jax.tree.map(put, self.cache["layers"], rcache["layers"])
        cache = {**self.cache, "layers": layers}
        cache["lens"] = cache["lens"].at[b].set(len(req.prompt))
        if cfg.encdec:
            cache["enc_k"] = cache["enc_k"].at[:, b].set(rcache["enc_k"][:, 0])
            cache["enc_v"] = cache["enc_v"].at[:, b].set(rcache["enc_v"][:, 0])
        self.cache = cache
        req.out.append(int(jnp.argmax(logits[0])))

    def step(self) -> dict:
        """One engine iteration: admit -> prefill -> batched decode ->
        telemetry -> cadenced maintenance."""
        for req in self.sched.admit(self.ecfg.max_seq_len - 1):
            self._prefill_into_slot(req)
        if not self.sched.running:
            return {}
        tokens = np.zeros((self.ecfg.max_seqs, 1), np.int32)
        for b, req in self.sched.running.items():
            tokens[b, 0] = req.out[-1] if req.out else 0
        tokens = jnp.asarray(tokens)
        mass = np.array(self._attention_mass(tokens))
        mass[[b for b in range(self.ecfg.max_seqs)
              if b not in self.sched.running]] = 0.0  # idle slots are silent
        logits, self.cache = self.decode_fn(self.params, self.cache, tokens)
        self._record_mass(mass)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for b, req in list(self.sched.running.items()):
            req.out.append(int(nxt[b]))
            if len(req.out) >= req.max_new:
                self.sched.finish(req)
        if self.sched.should_maintain():
            self.maintenance()
        return self.stats()

    def run(self, max_steps: int = 10_000) -> list:
        hist = []
        steps = 0
        while self.sched.has_work and steps < max_steps:
            hist.append(self.step())
            steps += 1
        return hist

    def stats(self) -> dict:
        return core_metrics.snapshot(self.pcfg, self.pstate)


# --------------------------------------------------------------------------
# steady-state tiering service (the churn engine's serving front, §13)
# --------------------------------------------------------------------------
class TieringService:
    """Tenants arriving and departing on the churn engine's guest lanes.

    The dormant half of the serving story: where :class:`Engine` runs a
    real model over the tiered KV cache, the service runs the *fleet* --
    each admitted tenant occupies one guest lane of an
    ``engine.EngineSpec`` fleet (its accesses synthesized on device from
    the lane's workload identity), admission goes through the
    pressure-aware :class:`repro.serve.scheduler.AdmissionQueue` (retries
    with exponential backoff while ``ChurnState.pressure`` is up, instead
    of failing), a departure is a crash fault (the lane's near blocks are
    reclaimed within the same window), and per-tenant QoS counters
    (admission latency, evictions, hit-rate) accumulate from the churn
    series. The compiled geometry never changes across the whole tenant
    lifecycle -- lanes just flip active/inactive.
    """

    def __init__(
        self,
        spec,
        queue: AdmissionQueue | None = None,
        accesses_per_window: int = 512,
        policy: str = "memtierd",
        use_gpac: bool = True,
        budget: int = 64,
        slack: int = 1,
    ):
        from repro.core import engine as ce
        from repro.data import traces as tr

        self.spec = spec
        self.queue = queue if queue is not None else AdmissionQueue()
        self.knobs = dict(
            policy=policy, use_gpac=use_gpac, budget=budget, slack=slack)
        n_g = spec.n_guests
        self.cs = ce.init_churn(spec, active=np.zeros((n_g,), bool))
        self.lane_tenant = np.full((n_g,), -1, np.int64)  # lane -> tenant
        self._departing: set[int] = set()  # tenants crashing next tick
        self._near_cap_req: int | None = None
        plan, tables = ce._bind_synth(
            spec, ce.SynthTrace(1, accesses_per_window))
        self._plan = plan
        self._setup = tr.synth_setup(
            plan, {k: jnp.asarray(v) for k, v in tables.items()})
        self._prev_near = np.zeros((n_g,), np.int64)

    # ---- tenant lifecycle ----------------------------------------------
    @property
    def window(self) -> int:
        return int(np.asarray(self.cs.window))

    def submit(self, tenant: int, tier_floor: int = 0):
        """Queue a tenant; ``tier_floor`` names the deepest tier index its
        SLO tolerates (0 = near only; ``n_tiers - 1`` accepts any
        placement). Floors are accounted against the spec's tier vector:
        a floor at the last tier counts every hit in-SLO, a floor of 0
        counts near hits only, and intermediate floors are scored
        conservatively from the near/far split (near hits are always at or
        above any floor)."""
        n_tiers = self.spec.tier_vector.n_tiers
        self.queue.submit(
            tenant, now=self.window, tier_floor=min(tier_floor, n_tiers - 1))

    def depart(self, tenant: int):
        """Tenant leaves: its lane crashes on the next :meth:`tick` (blocks
        reclaimed inside that window)."""
        if tenant not in self.lane_tenant:
            raise ValueError(f"tenant {tenant} is not resident")
        self._departing.add(tenant)

    def set_near_cap(self, near_cap: int | None):
        """Inject an effective near-capacity (None restores the physical
        tier) from the next :meth:`tick` on."""
        self._near_cap_req = (
            self.spec.cfg.n_near if near_cap is None else int(near_cap))

    def lane_of(self, tenant: int) -> int:
        lanes = np.nonzero(self.lane_tenant == tenant)[0]
        return int(lanes[0]) if lanes.size else -1

    # ---- the window loop ------------------------------------------------
    def tick(self) -> dict:
        """One serving window: admit (pressure-aware) -> crash departures /
        restart admissions -> one churn engine step -> QoS accounting."""
        from repro.core import engine as ce
        from repro.data import traces as tr

        now = self.window
        pressure = int(np.asarray(self.cs.pressure))
        n_g = self.spec.n_guests
        free = [int(l) for l in np.nonzero(self.lane_tenant < 0)[0]]
        crash = np.zeros((n_g,), bool)
        for tenant in self._departing:
            lane = self.lane_of(tenant)
            if lane >= 0:
                crash[lane] = True
                self.lane_tenant[lane] = -1
        self._departing.clear()
        free = [int(l) for l in np.nonzero(self.lane_tenant < 0)[0]]
        restart = np.zeros((n_g,), bool)
        for tenant in self.queue.admit(now, pressure, len(free)):
            lane = free.pop(0)
            restart[lane] = True
            self.lane_tenant[lane] = tenant
            self._prev_near[lane] = 0
        row = dict(crash=crash, restart=restart)
        if self._near_cap_req is not None:
            row["near_cap"] = self._near_cap_req
            self._near_cap_req = None
        acc = tr.synth_accesses(
            self._plan, self._setup, jnp.asarray(now, jnp.int32))
        self.cs, out = ce.step(
            self.spec, self.cs, acc, faults_row=row, **self.knobs)
        # ---- per-tenant QoS accounting ---------------------------------
        near = np.asarray(out["near_hits"])
        far = np.asarray(out["far_hits"])
        blocks = np.asarray(out["near_blocks"]).astype(np.int64)
        for lane in range(n_g):
            tenant = int(self.lane_tenant[lane])
            if tenant < 0:
                continue
            q = self.queue.qos[tenant]
            q.near_hits += int(near[lane])
            q.far_hits += int(far[lane])
            # SLO floor: near hits always satisfy the floor; a floor at
            # the deepest tier accepts everything (per-tenant hits only
            # resolve the near/far split, so middle floors score near-only)
            q.floor_hits += int(near[lane])
            if q.tier_floor >= self.spec.tier_vector.n_tiers - 1:
                q.floor_hits += int(far[lane])
            if not restart[lane]:  # eviction = resident near blocks lost
                q.evictions += int(max(self._prev_near[lane] - blocks[lane], 0))
        self._prev_near = blocks
        return out

    def stats(self) -> dict:
        """Service-level snapshot: pressure/backoff state plus every
        tenant's QoS counters."""
        return dict(
            window=self.window,
            pressure=int(np.asarray(self.cs.pressure)),
            engaged=bool(np.asarray(self.cs.engaged)),
            near_cap=int(np.asarray(self.cs.near_cap)),
            resident=int((self.lane_tenant >= 0).sum()),
            waiting=self.queue.n_waiting,
            tenants={
                t: dict(
                    admission_latency=q.admission_latency,
                    attempts=q.attempts,
                    evictions=q.evictions,
                    hit_rate=q.hit_rate,
                    tier_floor=q.tier_floor,
                    floor_hit_rate=q.floor_hit_rate,
                )
                for t, q in self.queue.qos.items()
            },
        )
