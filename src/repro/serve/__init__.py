from repro.serve import engine, scheduler  # noqa: F401
