"""Request scheduler for the continuous-batching engine.

Admission is page-budget-aware: a request is admitted only if its prompt plus
``reserve_tokens`` of generation headroom fit the free logical-group budget of
the tiered KV store. GPAC/tier maintenance runs on a fixed decode-step cadence
(the paper's telemetry window).
"""
from __future__ import annotations

import dataclasses
from collections import deque


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list  # token ids
    max_new: int = 32
    out: list = dataclasses.field(default_factory=list)
    seq_slot: int = -1
    done: bool = False


@dataclasses.dataclass
class SchedulerConfig:
    max_seqs: int = 4
    reserve_tokens: int = 32
    maintenance_every: int = 8  # decode steps per GPAC/tier window
    tier_policy: str = "memtierd"
    use_gpac: bool = True


class Scheduler:
    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.waiting: deque = deque()
        self.running: dict = {}  # slot -> Request
        self.free_slots = list(range(cfg.max_seqs))
        self.steps_since_maintenance = 0

    def submit(self, req: Request):
        self.waiting.append(req)

    def admit(self, seq_capacity_tokens: int) -> list:
        """Admit waiting requests into free slots while they fit."""
        admitted = []
        while self.waiting and self.free_slots:
            req = self.waiting[0]
            need = len(req.prompt) + req.max_new + self.cfg.reserve_tokens
            if need > seq_capacity_tokens:
                raise ValueError(
                    f"request {req.rid} needs {need} tokens > slot capacity "
                    f"{seq_capacity_tokens}")
            self.waiting.popleft()
            req.seq_slot = self.free_slots.pop(0)
            self.running[req.seq_slot] = req
            admitted.append(req)
        return admitted

    def finish(self, req: Request):
        req.done = True
        self.running.pop(req.seq_slot, None)
        self.free_slots.append(req.seq_slot)
        req.seq_slot = -1

    def should_maintain(self) -> bool:
        self.steps_since_maintenance += 1
        if self.steps_since_maintenance >= self.cfg.maintenance_every:
            self.steps_since_maintenance = 0
            return True
        return False

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)
