"""Request scheduler for the continuous-batching engine.

Admission is page-budget-aware: a request is admitted only if its prompt plus
``reserve_tokens`` of generation headroom fit the free logical-group budget of
the tiered KV store. GPAC/tier maintenance runs on a fixed decode-step cadence
(the paper's telemetry window).
"""
from __future__ import annotations

import dataclasses
from collections import deque


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list  # token ids
    max_new: int = 32
    out: list = dataclasses.field(default_factory=list)
    seq_slot: int = -1
    done: bool = False


@dataclasses.dataclass
class SchedulerConfig:
    max_seqs: int = 4
    reserve_tokens: int = 32
    maintenance_every: int = 8  # decode steps per GPAC/tier window
    tier_policy: str = "memtierd"
    use_gpac: bool = True


class Scheduler:
    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.waiting: deque = deque()
        self.running: dict = {}  # slot -> Request
        self.free_slots = list(range(cfg.max_seqs))
        self.steps_since_maintenance = 0

    def submit(self, req: Request):
        self.waiting.append(req)

    def admit(self, seq_capacity_tokens: int) -> list:
        """Admit waiting requests into free slots while they fit."""
        admitted = []
        while self.waiting and self.free_slots:
            req = self.waiting[0]
            need = len(req.prompt) + req.max_new + self.cfg.reserve_tokens
            if need > seq_capacity_tokens:
                raise ValueError(
                    f"request {req.rid} needs {need} tokens > slot capacity "
                    f"{seq_capacity_tokens}")
            self.waiting.popleft()
            req.seq_slot = self.free_slots.pop(0)
            self.running[req.seq_slot] = req
            admitted.append(req)
        return admitted

    def finish(self, req: Request):
        req.done = True
        self.running.pop(req.seq_slot, None)
        self.free_slots.append(req.seq_slot)
        req.seq_slot = -1

    def should_maintain(self) -> bool:
        self.steps_since_maintenance += 1
        if self.steps_since_maintenance >= self.cfg.maintenance_every:
            self.steps_since_maintenance = 0
            return True
        return False

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)


# --------------------------------------------------------------------------
# pressure-aware admission (the churn engine's serving front, DESIGN.md §13)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BackoffConfig:
    """Exponential-backoff knobs for admission under near-memory pressure:
    the n-th rejected attempt retries after ``min(base * 2**n, cap)``
    windows."""

    base: int = 1
    cap: int = 16

    def delay(self, attempts: int) -> int:
        return min(self.base * (2 ** min(attempts, 30)), self.cap)


@dataclasses.dataclass
class TenantQoS:
    """Per-tenant quality-of-service counters (the churn benchmark's
    per-tenant figure): admission latency in windows, blocks evicted from
    the near tier while resident, and the tenant's cumulative hit split.

    ``tier_floor`` is the deepest tier index this tenant's SLO tolerates
    (0 = near-tier only, ``n_tiers - 1`` = any placement is fine);
    ``floor_hits`` accumulates the accesses that landed at or above the
    floor, so ``floor_hit_rate`` is the fraction of traffic inside SLO.
    """

    tenant: int
    submitted_at: int = -1
    admitted_at: int = -1
    attempts: int = 0  # admissions denied under pressure so far
    retry_at: int = 0  # next window this tenant may be considered
    evictions: int = 0  # near blocks lost while resident
    near_hits: int = 0
    far_hits: int = 0
    tier_floor: int = 0  # deepest acceptable tier index (SLO)
    floor_hits: int = 0  # accesses that landed at or above the floor

    @property
    def admission_latency(self) -> int:
        """Windows from submit to admit (-1 while still waiting)."""
        if self.admitted_at < 0:
            return -1
        return self.admitted_at - self.submitted_at

    @property
    def hit_rate(self) -> float:
        total = self.near_hits + self.far_hits
        return self.near_hits / total if total else 0.0

    @property
    def floor_hit_rate(self) -> float:
        """Fraction of this tenant's accesses served inside its SLO floor."""
        total = self.near_hits + self.far_hits
        return self.floor_hits / total if total else 0.0


class AdmissionQueue:
    """FIFO admission that retries with exponential backoff under pressure
    instead of failing.

    Each window the service calls :meth:`admit` with the pressure
    controller's backoff signal (``ChurnState.pressure``) and the number of
    free guest lanes. Under pressure every *due* waiting tenant is pushed
    out by :class:`BackoffConfig`'s exponential schedule (its ``attempts``
    counter grows); with pressure clear, due tenants admit FIFO into the
    free lanes. Tenants backed off earlier stay waiting until their
    ``retry_at`` window even if pressure has cleared -- that is the backoff
    doing its job: post-shrink stampedes are spread out instead of
    re-spiking the near tier.
    """

    def __init__(self, backoff: BackoffConfig = BackoffConfig()):
        self.backoff = backoff
        self.waiting: deque = deque()  # tenant ids, FIFO
        self.qos: dict[int, TenantQoS] = {}

    def submit(self, tenant: int, now: int, tier_floor: int = 0) -> TenantQoS:
        if tenant in self.qos:
            raise ValueError(f"tenant {tenant} already submitted")
        if tier_floor < 0:
            raise ValueError(
                f"tenant {tenant}: tier_floor must be >= 0, got {tier_floor}")
        q = TenantQoS(tenant=tenant, submitted_at=now, retry_at=now,
                      tier_floor=tier_floor)
        self.qos[tenant] = q
        self.waiting.append(tenant)
        return q

    def admit(self, now: int, pressure: int, free_lanes: int) -> list[int]:
        """Tenants to admit this window (at most ``free_lanes``)."""
        admitted: list[int] = []
        still_waiting: deque = deque()
        for tenant in self.waiting:
            q = self.qos[tenant]
            due = now >= q.retry_at
            if due and pressure > 0:
                q.retry_at = now + self.backoff.delay(q.attempts)
                q.attempts += 1
                still_waiting.append(tenant)
            elif due and len(admitted) < free_lanes:
                q.admitted_at = now
                admitted.append(tenant)
            else:
                still_waiting.append(tenant)
        self.waiting = still_waiting
        return admitted

    @property
    def n_waiting(self) -> int:
        return len(self.waiting)
