from repro.data import traces  # noqa: F401
