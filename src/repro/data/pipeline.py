"""Deterministic, resumable, shard-aware token pipeline.

Production shape without external deps: a seeded synthetic corpus (mixture of
Zipfian unigram draws and repeated n-gram 'documents' so the LM loss actually
decreases) packed into fixed (B, S) batches. The pipeline state is one
integer (``step``) plus the immutable spec -- checkpointing the state and
restoring elsewhere reproduces the exact sample sequence, on any host count
(each DP shard slices its rows deterministically from the global batch).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataSpec:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    ngram: int = 8  # repeated-structure length (gives the model signal)


@dataclasses.dataclass
class DataState:
    step: int = 0


def _batch_rng(spec: DataSpec, step: int) -> np.random.Generator:
    return np.random.default_rng((spec.seed, step))


def next_batch(spec: DataSpec, state: DataState,
               dp_rank: int = 0, dp_size: int = 1) -> tuple:
    """-> (batch dict {tokens, labels}, new state). Labels are next-token."""
    rng = _batch_rng(spec, state.step)
    B, S = spec.global_batch, spec.seq_len
    # Zipf unigrams, with every other ngram-block a repeat of its predecessor
    # (compressible structure => learnable)
    toks = (rng.zipf(spec.zipf_a, size=(B, S + 1)) - 1) % spec.vocab
    n = spec.ngram
    blocks = (S + 1) // (2 * n)
    for b in range(blocks):
        lo = b * 2 * n
        toks[:, lo + n : lo + 2 * n] = toks[:, lo : lo + n]
    toks = toks.astype(np.int32)
    assert B % dp_size == 0, (B, dp_size)
    rows = slice(dp_rank * (B // dp_size), (dp_rank + 1) * (B // dp_size))
    batch = {"tokens": toks[rows, :S], "labels": toks[rows, 1 : S + 1]}
    return batch, DataState(step=state.step + 1)


def batches(spec: DataSpec, state: DataState, n: int, **kw):
    for _ in range(n):
        b, state = next_batch(spec, state, **kw)
        yield b, state
