"""Access-trace generators reproducing the paper's workload access shapes.

Each generator emits ``int32[n_windows, accesses_per_window]`` logical page ids
(-1 padded) whose *skew structure* matches the paper's Fig. 2 / Fig. 16
characterization of that workload:

  * ``masim``     -- exactly 1 hot 4 KB page per 2 MB huge-page boundary
                     (paper §5.1 configures Masim this way; maximal skew).
  * ``redis``     -- Memtier-over-Redis: Gaussian key popularity over a large
                     keyspace with 1 KB values -> hot pages scattered widely;
                     Fig. 16a shows most huge pages with < 50 hot subpages.
  * ``memcached`` -- like redis but flatter tail: ~85% of huge pages have
                     < 100/512 subpages accessed (Fig. 2).
  * ``hash``      -- bucketized uniform: buckets hash pointers across the
                     space; Fig. 16b peaks around 150 hot subpages/huge page.
  * ``ocean_ncp`` -- dense grid sweeps: most huge pages densely accessed
                     (Fig. 2 shows Roms/Liblinear-like density; ocean is the
                     moderately dense one with CL 290 in Table 3).
  * ``liblinear`` -- fully dense streaming (no skew; GPAC should be a no-op).
  * generic ``zipf`` / ``gauss`` / ``uniform`` parametric generators.

Every workload exists in two forms, tied together by the
:func:`register_workload` registry (the trace-side sibling of the PR-2
policy/telemetry/collector registries):

* a **numpy generator** ``fn(TraceSpec, rng) -> int32[n_windows, k]`` --
  deterministic (numpy Generator seeded per call), host-side, the
  *reference* distribution; and
* a **pure-JAX window function** ``fn(WindowCtx) -> int32[k]`` that
  synthesizes ONE window's accesses *on device*, inside the engine's scan
  (``engine.SynthTrace``). JAX windows use counter-based RNG only
  (``jax.random.fold_in`` of a per-guest key with the absolute window
  index), so they are chunking-invariant and bit-identical whether a guest
  is synthesized on one device or on its own shard of a mesh. They match
  the numpy reference *distributionally* (same skew structure per Fig.
  2/16), not bit-for-bit -- the numpy path stays the oracle.

RNG-key discipline (DESIGN.md §12): a guest's base key is
``fold_in(PRNGKey(seed), gid)`` with the *global* guest id, then stream 0
(folded again with the window index) drives per-window sampling and stream 1
derives the fixed scatter permutation -- nothing depends on device count,
local row position, or chunk boundaries.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

WORKLOADS = ("masim", "redis", "memcached", "hash", "ocean_ncp", "liblinear")


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    workload: str
    n_logical: int
    hp_ratio: int = 512
    n_windows: int = 32
    accesses_per_window: int = 4096
    seed: int = 0


def _trim(ids: np.ndarray, lo: int, hi: int) -> np.ndarray:
    return np.clip(ids, lo, hi - 1).astype(np.int32)


def _perm(n: int, rng: np.random.Generator) -> np.ndarray:
    """Fixed scatter permutation: maps a compact hot set onto pages spread
    across the whole logical space (what malloc fragmentation does)."""
    return rng.permutation(n).astype(np.int32)


def masim(spec: TraceSpec, rng: np.random.Generator) -> np.ndarray:
    """One hot page per huge-page boundary, round-robin over them."""
    n_hp = max(1, spec.n_logical // spec.hp_ratio)
    hot = (np.arange(n_hp, dtype=np.int32) * spec.hp_ratio) % spec.n_logical
    k = spec.accesses_per_window
    out = np.empty((spec.n_windows, k), np.int32)
    for w in range(spec.n_windows):
        out[w] = hot[(np.arange(k) + w) % n_hp]
    return out


def _popularity_trace(
    spec: TraceSpec,
    rng: np.random.Generator,
    sampler,
    hot_fraction: float,
    drift: float = 0.0,
) -> np.ndarray:
    """Common shape for kv-store workloads: a popularity distribution over a
    compact key space, scattered over the logical space by a permutation.
    ``drift``: popularity center moves by this fraction of the hot range per
    window (key-popularity churn -- what drives the paper's Fig. 11
    promotion/demotion traffic)."""
    n_hot = max(1, int(spec.n_logical * hot_fraction))
    scatter = _perm(spec.n_logical, rng)[:n_hot]
    out = np.empty((spec.n_windows, spec.accesses_per_window), np.int32)
    for w in range(spec.n_windows):
        keys = sampler(rng, spec.accesses_per_window)
        if drift:
            keys = keys + int(w * drift * n_hot)
        out[w] = scatter[_trim(keys % n_hot, 0, n_hot)]
    return out


def redis(spec: TraceSpec, rng: np.random.Generator) -> np.ndarray:
    """Gaussian key popularity (the paper's Memtier config), ~8% of pages
    hot, with slow popularity drift (Fig. 6's moving hot region)."""
    def sampler(r, k):
        n_hot = max(1, int(spec.n_logical * 0.08))
        return np.abs(r.normal(0.0, n_hot / 3.0, size=k)).astype(np.int64)

    # drift ~3 pages/window: slow churn relative to the maintenance cadence
    # (the paper's daemons converge faster than key-popularity drift)
    return _popularity_trace(spec, rng, sampler, hot_fraction=0.08,
                             drift=0.005)


def memcached(spec: TraceSpec, rng: np.random.Generator) -> np.ndarray:
    """Wider Gaussian: ~15% of pages touched, <100/512 per huge page hot."""
    def sampler(r, k):
        n_hot = max(1, int(spec.n_logical * 0.15))
        return np.abs(r.normal(0.0, n_hot / 2.5, size=k)).astype(np.int64)

    return _popularity_trace(spec, rng, sampler, hot_fraction=0.15)


def hash_workload(spec: TraceSpec, rng: np.random.Generator) -> np.ndarray:
    """hash_bkt_rcu: uniform over ~30% of pages (bucket arrays + nodes),
    giving the Fig. 16b ~150-hot-subpages-per-huge-page mode."""
    def sampler(r, k):
        n_hot = max(1, int(spec.n_logical * 0.30))
        return r.integers(0, n_hot, size=k)

    return _popularity_trace(spec, rng, sampler, hot_fraction=0.30)


def _drift_trace(
    spec: TraceSpec,
    rng: np.random.Generator,
    sampler,
    hot_fraction: float,
    period: int,
    rotate: float,
) -> np.ndarray:
    """Phase-shifting variant of :func:`_popularity_trace`: every ``period``
    windows the hot set jumps by ``rotate * n_hot`` positions along the full
    scatter permutation, so the *set of hot pages itself* turns over (the
    churn benchmark's drifting tenants), not just the popularity center
    within a fixed hot set (the ``drift=`` knob above). Promotions made for
    one phase go cold wholesale at the next shift -- worst case for the
    pressure controller's coldest-first demotion."""
    n_hot = max(1, int(spec.n_logical * hot_fraction))
    scatter = _perm(spec.n_logical, rng)
    step = max(1, int(n_hot * rotate))
    out = np.empty((spec.n_windows, spec.accesses_per_window), np.int32)
    for w in range(spec.n_windows):
        keys = sampler(rng, spec.accesses_per_window)
        shift = ((w // period) * step) % spec.n_logical
        out[w] = scatter[(_trim(keys % n_hot, 0, n_hot) + shift) % spec.n_logical]
    return out


def redis_drift(spec: TraceSpec, rng: np.random.Generator) -> np.ndarray:
    """redis whose hot set rotates by half its width every 2 windows:
    Gaussian popularity over a compact window that slides along the scatter
    permutation (phase-change churn rather than slow center drift)."""
    def sampler(r, k):
        n_hot = max(1, int(spec.n_logical * 0.08))
        return np.abs(r.normal(0.0, n_hot / 3.0, size=k)).astype(np.int64)

    return _drift_trace(spec, rng, sampler, hot_fraction=0.08,
                        period=2, rotate=0.5)


def hash_drift(spec: TraceSpec, rng: np.random.Generator) -> np.ndarray:
    """hash_bkt_rcu under rehashing: the uniform ~30% hot set jumps by half
    its width every 4 windows (bucket array reallocated elsewhere)."""
    def sampler(r, k):
        n_hot = max(1, int(spec.n_logical * 0.30))
        return r.integers(0, n_hot, size=k)

    return _drift_trace(spec, rng, sampler, hot_fraction=0.30,
                        period=4, rotate=0.5)


def ocean_ncp(spec: TraceSpec, rng: np.random.Generator) -> np.ndarray:
    """Grid sweeps touching every other page of ~60%-of-space runs: the
    W-cycle multigrid stencil reads alternate rows at each level, so huge
    pages are ~50% internally hot -- dense-ish but still under ocean's high
    CL (290/512 in Table 3; Table 3 selects 950k of its pages)."""
    out = np.empty((spec.n_windows, spec.accesses_per_window), np.int32)
    span = max(1, int(spec.n_logical * 0.6))
    for w in range(spec.n_windows):
        start = rng.integers(0, max(1, spec.n_logical - span))
        idx = (np.arange(spec.accesses_per_window, dtype=np.int64)
               * (span // 2)) // spec.accesses_per_window * 2  # stride-2
        out[w] = _trim((start // 2) * 2 + idx, 0, spec.n_logical)
    return out


def liblinear(spec: TraceSpec, rng: np.random.Generator) -> np.ndarray:
    """Dense streaming over the full working set: every page hot (no skew)."""
    out = np.empty((spec.n_windows, spec.accesses_per_window), np.int32)
    for w in range(spec.n_windows):
        out[w] = _trim(
            (np.arange(spec.accesses_per_window, dtype=np.int64)
             * spec.n_logical) // spec.accesses_per_window,
            0, spec.n_logical)
    return out


def zipf(spec: TraceSpec, rng: np.random.Generator, a: float = 1.2) -> np.ndarray:
    def sampler(r, k):
        return r.zipf(a, size=k) - 1

    return _popularity_trace(spec, rng, sampler, hot_fraction=1.0)


def uniform(spec: TraceSpec, rng: np.random.Generator) -> np.ndarray:
    def sampler(r, k):
        return r.integers(0, spec.n_logical, size=k)

    return _popularity_trace(spec, rng, sampler, hot_fraction=1.0)


def gauss(spec: TraceSpec, rng: np.random.Generator, rel_sigma: float = 0.05):
    def sampler(r, k):
        return np.abs(r.normal(0, spec.n_logical * rel_sigma, size=k)).astype(np.int64)

    return _popularity_trace(spec, rng, sampler, hot_fraction=1.0)


# --------------------------------------------------------------------------
# workload registry (numpy reference + on-device JAX window function)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Workload:
    """One registered workload: the host-side numpy reference generator and
    (optionally) its on-device JAX window function.

    ``needs_scatter``: the window function reads ``WindowCtx.scatter`` (the
    fixed per-guest hot-set permutation); synthesis setup only builds the
    scatter tables when some bound workload asks for them.
    """

    name: str
    numpy_fn: Callable
    window_fn: Callable | None = None
    needs_scatter: bool = False


_WORKLOADS: dict[str, Workload] = {}


def register_workload(
    name: str,
    numpy_fn: Callable,
    window_fn: Callable | None = None,
    needs_scatter: bool = False,
) -> Workload:
    """Register a workload's numpy reference generator and (optionally) its
    pure-JAX window function (see the module docstring for both contracts).
    Mirrors the policy/telemetry/collector registries: duplicates raise,
    unknown names raise listing the live set."""
    if name in _WORKLOADS:
        raise ValueError(f"workload {name!r} already registered")
    wl = Workload(name, numpy_fn, window_fn, needs_scatter)
    _WORKLOADS[name] = wl
    return wl


def get_workload(name: str) -> Workload:
    try:
        return _WORKLOADS[name]
    except KeyError:
        raise ValueError(
            f"unknown workload {name!r} (have {workloads()})"
        ) from None


def workloads() -> tuple[str, ...]:
    return tuple(_WORKLOADS)


def generate(spec: TraceSpec, **kw) -> np.ndarray:
    """int32[n_windows, accesses_per_window] logical page ids (numpy
    reference path)."""
    return get_workload(spec.workload).numpy_fn(
        spec, np.random.default_rng(spec.seed), **kw
    )


# --------------------------------------------------------------------------
# on-device synthesis (pure-JAX window functions, engine.SynthTrace)
# --------------------------------------------------------------------------
# Key streams off a guest's base key (see the module docstring): stream 0 is
# folded again with the absolute window index for per-window draws; stream 1
# seeds the guest's fixed scatter permutation.
_WINDOW_STREAM = 0
_SCATTER_STREAM = 1


@dataclasses.dataclass
class WindowCtx:
    """Inputs of one JAX window function (all per ONE guest).

    ``key`` is already folded with the absolute window index; ``n_logical``
    is a *traced* int32 scalar (guests of different sizes share one compiled
    window body via vmap); ``scatter`` is the guest's fixed scatter table --
    a uniform permutation of ``[0, n_logical)``, so a prefix
    ``scatter[:n_hot]`` is ``n_hot`` *distinct pages spread uniformly over
    the whole logical space* (the numpy ``_perm(n_logical)[:n_hot]`` hot-set
    scatter), NOT a permutation of ``[0, n_hot)`` -- or ``None`` when no
    bound workload needs it. ``k`` / ``hp_ratio`` are static.
    """

    key: "jax.Array"
    w: "jax.Array"
    n_logical: "jax.Array"
    scatter: "jax.Array | None"
    k: int
    hp_ratio: int


@dataclasses.dataclass(frozen=True)
class SynthPlan:
    """Static (hashable) half of a bound on-device synthesis: the distinct
    workload set picks the compiled window bodies; everything per-guest
    (seed, global id, workload index, size) rides in traced tables so
    seed/workload-assignment sweeps never recompile. Deliberately excludes
    ``n_windows`` (that lives on ``engine.SynthTrace``): no window body
    reads it, and keeping it out of the jit key lets trace-length sweeps
    reuse compiled chunks of the same shape."""

    workload_set: tuple[str, ...]
    accesses_per_window: int
    hp_ratio: int
    max_logical: int

    def __post_init__(self):
        for name in self.workload_set:
            if get_workload(name).window_fn is None:
                raise ValueError(
                    f"workload {name!r} has no on-device window function; "
                    f"generate it host-side (engine.ArrayTrace) instead"
                )


def guest_base_key(seed: "jax.Array", gid: "jax.Array") -> "jax.Array":
    """The per-guest base key: global guest id folded into the seed key, so
    sharded synthesis (each device holding only its own guests' rows) is
    bit-identical to single-device synthesis."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), jnp.maximum(gid, 0))


def guest_scatter(key: "jax.Array", n_logical: "jax.Array", max_logical: int):
    """int32[max_logical]: a uniform permutation of ``[0, n_logical)`` in the
    first ``n_logical`` entries (static-shape trick: permute the padded range
    and stably compact the in-range values to the front -- a subsequence of a
    uniform permutation restricted to ``< n`` is a uniform permutation of
    ``[0, n)``)."""
    p = jax.random.permutation(key, max_logical)
    order = jnp.argsort(p >= n_logical, stable=True)
    return p[order].astype(jnp.int32)


def _j_popularity(ctx: WindowCtx, sample, hot_fraction: float, drift: float = 0.0):
    """JAX port of :func:`_popularity_trace`'s window body: sample keys from
    the popularity distribution, optionally drift the center, scatter onto
    the guest's fixed hot-set permutation."""
    n_hot = jnp.maximum(1, (ctx.n_logical * hot_fraction).astype(jnp.int32))
    keys = sample(ctx, n_hot)
    if drift:
        keys = keys + (ctx.w.astype(jnp.float32) * drift
                       * n_hot.astype(jnp.float32)).astype(keys.dtype)
    idx = jnp.clip(keys % n_hot, 0, n_hot - 1)
    return ctx.scatter[idx].astype(jnp.int32)


def masim_window(ctx: WindowCtx):
    n_hp = jnp.maximum(1, ctx.n_logical // ctx.hp_ratio)
    idx = (jnp.arange(ctx.k, dtype=jnp.int32) + ctx.w) % n_hp
    return ((idx * ctx.hp_ratio) % jnp.maximum(ctx.n_logical, 1)).astype(jnp.int32)


def redis_window(ctx: WindowCtx):
    def sample(c, n_hot):
        sigma = n_hot.astype(jnp.float32) / 3.0
        return jnp.abs(jax.random.normal(c.key, (c.k,)) * sigma).astype(jnp.int32)

    return _j_popularity(ctx, sample, hot_fraction=0.08, drift=0.005)


def memcached_window(ctx: WindowCtx):
    def sample(c, n_hot):
        sigma = n_hot.astype(jnp.float32) / 2.5
        return jnp.abs(jax.random.normal(c.key, (c.k,)) * sigma).astype(jnp.int32)

    return _j_popularity(ctx, sample, hot_fraction=0.15)


def hash_window(ctx: WindowCtx):
    def sample(c, n_hot):
        return jax.random.randint(c.key, (c.k,), 0, n_hot)

    return _j_popularity(ctx, sample, hot_fraction=0.30)


def _j_drift(ctx: WindowCtx, sample, hot_fraction: float, period: int,
             rotate: float):
    """JAX port of :func:`_drift_trace`'s window body. The shift depends
    only on the absolute window index, so it is chunking- and
    mesh-invariant like every other window input. The pre-mod product
    ``(w // period) * step`` stays well under int32 for any realistic run
    length (windows in the thousands, step <= n_logical/2)."""
    n_hot = jnp.maximum(1, (ctx.n_logical * hot_fraction).astype(jnp.int32))
    keys = sample(ctx, n_hot)
    n = jnp.maximum(ctx.n_logical, 1)
    step = jnp.maximum(
        1, (n_hot.astype(jnp.float32) * rotate).astype(jnp.int32))
    shift = ((ctx.w // period) * step) % n
    idx = (jnp.clip(keys % n_hot, 0, n_hot - 1) + shift) % n
    return ctx.scatter[idx].astype(jnp.int32)


def redis_drift_window(ctx: WindowCtx):
    def sample(c, n_hot):
        sigma = n_hot.astype(jnp.float32) / 3.0
        return jnp.abs(jax.random.normal(c.key, (c.k,)) * sigma).astype(jnp.int32)

    return _j_drift(ctx, sample, hot_fraction=0.08, period=2, rotate=0.5)


def hash_drift_window(ctx: WindowCtx):
    def sample(c, n_hot):
        return jax.random.randint(c.key, (c.k,), 0, n_hot)

    return _j_drift(ctx, sample, hot_fraction=0.30, period=4, rotate=0.5)


def _stride_positions(k: int, n: "jax.Array") -> "jax.Array":
    """int32[k]: ``floor(i * n / k)`` for ``i in [0, k)`` without the int32
    overflow of the direct product (x64 is disabled, so there is no int64 to
    widen into): ``i*n//k == i*(n//k) + i*(n%k)//k``, and both partial
    products stay under 2**31 for any ``n < 2**31`` as long as ``k**2`` does
    (k <= 46340; the engine's accesses_per_window is far below that)."""
    i = jnp.arange(k, dtype=jnp.int32)
    return i * (n // k) + (i * (n % k)) // k


def ocean_ncp_window(ctx: WindowCtx):
    span = jnp.maximum(1, (ctx.n_logical * 0.6).astype(jnp.int32))
    start = jax.random.randint(
        ctx.key, (), 0, jnp.maximum(1, ctx.n_logical - span))
    idx = _stride_positions(ctx.k, span // 2) * 2
    return jnp.clip((start // 2) * 2 + idx, 0, ctx.n_logical - 1).astype(jnp.int32)


def liblinear_window(ctx: WindowCtx):
    idx = _stride_positions(ctx.k, ctx.n_logical)
    return jnp.clip(idx, 0, ctx.n_logical - 1).astype(jnp.int32)


def zipf_window(ctx: WindowCtx, a: float = 1.2):
    def sample(c, n_hot):
        u = jax.random.uniform(c.key, (c.k,), minval=1e-7, maxval=1.0)
        # inverse-power transform: P(X = x) ~ x^-a asymptotically (the
        # numpy reference uses rejection sampling; equivalence is
        # distributional). Clip in float before the int cast -- the tail
        # of u**(-1/(a-1)) overflows int32.
        x = jnp.clip(u ** (-1.0 / (a - 1.0)), 1.0, 2.0**30)
        return x.astype(jnp.int32) - 1

    return _j_popularity(ctx, sample, hot_fraction=1.0)


def uniform_window(ctx: WindowCtx):
    def sample(c, n_hot):
        return jax.random.randint(c.key, (c.k,), 0, jnp.maximum(c.n_logical, 1))

    return _j_popularity(ctx, sample, hot_fraction=1.0)


def gauss_window(ctx: WindowCtx, rel_sigma: float = 0.05):
    def sample(c, n_hot):
        sigma = c.n_logical.astype(jnp.float32) * rel_sigma
        return jnp.abs(jax.random.normal(c.key, (c.k,)) * sigma).astype(jnp.int32)

    return _j_popularity(ctx, sample, hot_fraction=1.0)


def synth_setup(plan: SynthPlan, tables: dict) -> dict:
    """Per-chunk device-side setup of a bound synthesis: per-guest window
    stream keys and (when some workload needs one) the fixed scatter
    permutations. ``tables`` holds the traced per-guest rows (``seeds``,
    ``gids``, ``wid``, ``n_logical``) -- on a mesh each device passes only
    its local rows, and every derived value depends only on (seed, global
    gid), never on row position or device count. Deterministic, so chunks
    recompute it identically."""
    base = jax.vmap(guest_base_key)(tables["seeds"], tables["gids"])
    win_base = jax.vmap(lambda b: jax.random.fold_in(b, _WINDOW_STREAM))(base)
    scatter = None
    if any(get_workload(n).needs_scatter for n in plan.workload_set):
        sc_keys = jax.vmap(lambda b: jax.random.fold_in(b, _SCATTER_STREAM))(base)
        scatter = jax.vmap(guest_scatter, in_axes=(0, 0, None))(
            sc_keys, tables["n_logical"], plan.max_logical)
    return dict(
        win_base=win_base, scatter=scatter, wid=tables["wid"],
        gids=tables["gids"], n_logical=tables["n_logical"],
    )


def synth_accesses(plan: SynthPlan, setup: dict, w: "jax.Array"):
    """int32[n_rows, k] guest-local accesses of window ``w``, generated on
    device. SPMD-safe mixed tenancy: every workload in the (static) bound
    set runs for every row and a traced per-row workload-id table selects --
    cost scales with the number of *distinct* workloads, not guests. Rows
    with ``gid < 0`` (mesh padding) emit all ``-1`` no-ops."""
    n_rows = setup["win_base"].shape[0]
    out = jnp.full((n_rows, plan.accesses_per_window), -1, jnp.int32)
    for j, name in enumerate(plan.workload_set):
        fn = get_workload(name).window_fn

        def row(key, nl, sc, fn=fn):
            ctx = WindowCtx(
                key=jax.random.fold_in(key, w), w=w, n_logical=nl,
                scatter=sc, k=plan.accesses_per_window,
                hp_ratio=plan.hp_ratio,
            )
            return fn(ctx)

        if setup["scatter"] is None:
            rows = jax.vmap(lambda key, nl: row(key, nl, None))(
                setup["win_base"], setup["n_logical"])
        else:
            rows = jax.vmap(row)(
                setup["win_base"], setup["n_logical"], setup["scatter"])
        out = jnp.where(setup["wid"][:, None] == j, rows, out)
    return jnp.where(setup["gids"][:, None] >= 0, out, -1)


def synth_generate(spec: TraceSpec, gid: int = 0) -> np.ndarray:
    """Materialize the JAX generator's full trace ``int32[n_windows, k]`` on
    host -- the testing/calibration entry point for distributional
    comparison against :func:`generate` (the engine never materializes this;
    ``engine.SynthTrace`` generates each window inside the scan)."""
    plan = SynthPlan(
        workload_set=(spec.workload,),
        accesses_per_window=spec.accesses_per_window,
        hp_ratio=spec.hp_ratio,
        max_logical=spec.n_logical,
    )
    tables = dict(
        seeds=jnp.asarray([spec.seed], jnp.int32),
        gids=jnp.asarray([gid], jnp.int32),
        wid=jnp.asarray([0], jnp.int32),
        n_logical=jnp.asarray([spec.n_logical], jnp.int32),
    )
    setup = synth_setup(plan, tables)
    rows = [
        np.asarray(synth_accesses(plan, setup, jnp.int32(w))[0])
        for w in range(spec.n_windows)
    ]
    return np.stack(rows) if rows else np.zeros(
        (0, spec.accesses_per_window), np.int32)


register_workload("masim", masim, masim_window)
register_workload("redis", redis, redis_window, needs_scatter=True)
register_workload("memcached", memcached, memcached_window, needs_scatter=True)
register_workload("hash", hash_workload, hash_window, needs_scatter=True)
register_workload("ocean_ncp", ocean_ncp, ocean_ncp_window)
register_workload("liblinear", liblinear, liblinear_window)
register_workload("redis_drift", redis_drift, redis_drift_window,
                  needs_scatter=True)
register_workload("hash_drift", hash_drift, hash_drift_window,
                  needs_scatter=True)
register_workload("zipf", zipf, zipf_window, needs_scatter=True)
register_workload("uniform", uniform, uniform_window, needs_scatter=True)
register_workload("gauss", gauss, gauss_window, needs_scatter=True)


# Paper Table 2 guest RSS (GB) and Table 3 CL per workload -- used by the
# benchmarks to scale simulations proportionally.
PAPER_RSS_GB = dict(masim=9.8, redis=12.5, memcached=11.0, hash=8.8, ocean_ncp=5.5)
PAPER_CL = dict(masim=10, redis=50, memcached=100, hash=250, ocean_ncp=290)
PAPER_SELECTED_PAGES = dict(
    masim=4_142, redis=93_896, memcached=174_068, hash=307_484, ocean_ncp=950_758
)
