"""Access-trace generators reproducing the paper's workload access shapes.

Each generator emits ``int32[n_windows, accesses_per_window]`` logical page ids
(-1 padded) whose *skew structure* matches the paper's Fig. 2 / Fig. 16
characterization of that workload:

  * ``masim``     -- exactly 1 hot 4 KB page per 2 MB huge-page boundary
                     (paper §5.1 configures Masim this way; maximal skew).
  * ``redis``     -- Memtier-over-Redis: Gaussian key popularity over a large
                     keyspace with 1 KB values -> hot pages scattered widely;
                     Fig. 16a shows most huge pages with < 50 hot subpages.
  * ``memcached`` -- like redis but flatter tail: ~85% of huge pages have
                     < 100/512 subpages accessed (Fig. 2).
  * ``hash``      -- bucketized uniform: buckets hash pointers across the
                     space; Fig. 16b peaks around 150 hot subpages/huge page.
  * ``ocean_ncp`` -- dense grid sweeps: most huge pages densely accessed
                     (Fig. 2 shows Roms/Liblinear-like density; ocean is the
                     moderately dense one with CL 290 in Table 3).
  * ``liblinear`` -- fully dense streaming (no skew; GPAC should be a no-op).
  * generic ``zipf`` / ``gauss`` / ``uniform`` parametric generators.

The generators are deterministic (numpy Generator seeded per call) and
host-side: traces are inputs to the jitted simulator, not traced computation.
"""
from __future__ import annotations

import dataclasses

import numpy as np

WORKLOADS = ("masim", "redis", "memcached", "hash", "ocean_ncp", "liblinear")


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    workload: str
    n_logical: int
    hp_ratio: int = 512
    n_windows: int = 32
    accesses_per_window: int = 4096
    seed: int = 0


def _trim(ids: np.ndarray, lo: int, hi: int) -> np.ndarray:
    return np.clip(ids, lo, hi - 1).astype(np.int32)


def _perm(n: int, rng: np.random.Generator) -> np.ndarray:
    """Fixed scatter permutation: maps a compact hot set onto pages spread
    across the whole logical space (what malloc fragmentation does)."""
    return rng.permutation(n).astype(np.int32)


def masim(spec: TraceSpec, rng: np.random.Generator) -> np.ndarray:
    """One hot page per huge-page boundary, round-robin over them."""
    n_hp = max(1, spec.n_logical // spec.hp_ratio)
    hot = (np.arange(n_hp, dtype=np.int32) * spec.hp_ratio) % spec.n_logical
    k = spec.accesses_per_window
    out = np.empty((spec.n_windows, k), np.int32)
    for w in range(spec.n_windows):
        out[w] = hot[(np.arange(k) + w) % n_hp]
    return out


def _popularity_trace(
    spec: TraceSpec,
    rng: np.random.Generator,
    sampler,
    hot_fraction: float,
    drift: float = 0.0,
) -> np.ndarray:
    """Common shape for kv-store workloads: a popularity distribution over a
    compact key space, scattered over the logical space by a permutation.
    ``drift``: popularity center moves by this fraction of the hot range per
    window (key-popularity churn -- what drives the paper's Fig. 11
    promotion/demotion traffic)."""
    n_hot = max(1, int(spec.n_logical * hot_fraction))
    scatter = _perm(spec.n_logical, rng)[:n_hot]
    out = np.empty((spec.n_windows, spec.accesses_per_window), np.int32)
    for w in range(spec.n_windows):
        keys = sampler(rng, spec.accesses_per_window)
        if drift:
            keys = keys + int(w * drift * n_hot)
        out[w] = scatter[_trim(keys % n_hot, 0, n_hot)]
    return out


def redis(spec: TraceSpec, rng: np.random.Generator) -> np.ndarray:
    """Gaussian key popularity (the paper's Memtier config), ~8% of pages
    hot, with slow popularity drift (Fig. 6's moving hot region)."""
    def sampler(r, k):
        n_hot = max(1, int(spec.n_logical * 0.08))
        return np.abs(r.normal(0.0, n_hot / 3.0, size=k)).astype(np.int64)

    # drift ~3 pages/window: slow churn relative to the maintenance cadence
    # (the paper's daemons converge faster than key-popularity drift)
    return _popularity_trace(spec, rng, sampler, hot_fraction=0.08,
                             drift=0.005)


def memcached(spec: TraceSpec, rng: np.random.Generator) -> np.ndarray:
    """Wider Gaussian: ~15% of pages touched, <100/512 per huge page hot."""
    def sampler(r, k):
        n_hot = max(1, int(spec.n_logical * 0.15))
        return np.abs(r.normal(0.0, n_hot / 2.5, size=k)).astype(np.int64)

    return _popularity_trace(spec, rng, sampler, hot_fraction=0.15)


def hash_workload(spec: TraceSpec, rng: np.random.Generator) -> np.ndarray:
    """hash_bkt_rcu: uniform over ~30% of pages (bucket arrays + nodes),
    giving the Fig. 16b ~150-hot-subpages-per-huge-page mode."""
    def sampler(r, k):
        n_hot = max(1, int(spec.n_logical * 0.30))
        return r.integers(0, n_hot, size=k)

    return _popularity_trace(spec, rng, sampler, hot_fraction=0.30)


def ocean_ncp(spec: TraceSpec, rng: np.random.Generator) -> np.ndarray:
    """Grid sweeps touching every other page of ~60%-of-space runs: the
    W-cycle multigrid stencil reads alternate rows at each level, so huge
    pages are ~50% internally hot -- dense-ish but still under ocean's high
    CL (290/512 in Table 3; Table 3 selects 950k of its pages)."""
    out = np.empty((spec.n_windows, spec.accesses_per_window), np.int32)
    span = max(1, int(spec.n_logical * 0.6))
    for w in range(spec.n_windows):
        start = rng.integers(0, max(1, spec.n_logical - span))
        idx = (np.arange(spec.accesses_per_window, dtype=np.int64)
               * (span // 2)) // spec.accesses_per_window * 2  # stride-2
        out[w] = _trim((start // 2) * 2 + idx, 0, spec.n_logical)
    return out


def liblinear(spec: TraceSpec, rng: np.random.Generator) -> np.ndarray:
    """Dense streaming over the full working set: every page hot (no skew)."""
    out = np.empty((spec.n_windows, spec.accesses_per_window), np.int32)
    for w in range(spec.n_windows):
        out[w] = _trim(
            (np.arange(spec.accesses_per_window, dtype=np.int64)
             * spec.n_logical) // spec.accesses_per_window,
            0, spec.n_logical)
    return out


def zipf(spec: TraceSpec, rng: np.random.Generator, a: float = 1.2) -> np.ndarray:
    def sampler(r, k):
        return r.zipf(a, size=k) - 1

    return _popularity_trace(spec, rng, sampler, hot_fraction=1.0)


def uniform(spec: TraceSpec, rng: np.random.Generator) -> np.ndarray:
    def sampler(r, k):
        return r.integers(0, spec.n_logical, size=k)

    return _popularity_trace(spec, rng, sampler, hot_fraction=1.0)


def gauss(spec: TraceSpec, rng: np.random.Generator, rel_sigma: float = 0.05):
    def sampler(r, k):
        return np.abs(r.normal(0, spec.n_logical * rel_sigma, size=k)).astype(np.int64)

    return _popularity_trace(spec, rng, sampler, hot_fraction=1.0)


_GENERATORS = dict(
    masim=masim,
    redis=redis,
    memcached=memcached,
    hash=hash_workload,
    ocean_ncp=ocean_ncp,
    liblinear=liblinear,
    zipf=zipf,
    uniform=uniform,
    gauss=gauss,
)


def generate(spec: TraceSpec, **kw) -> np.ndarray:
    """int32[n_windows, accesses_per_window] logical page ids."""
    gen = _GENERATORS.get(spec.workload)
    if gen is None:
        raise ValueError(f"unknown workload {spec.workload!r} (have {sorted(_GENERATORS)})")
    return gen(spec, np.random.default_rng(spec.seed), **kw)


# Paper Table 2 guest RSS (GB) and Table 3 CL per workload -- used by the
# benchmarks to scale simulations proportionally.
PAPER_RSS_GB = dict(masim=9.8, redis=12.5, memcached=11.0, hash=8.8, ocean_ncp=5.5)
PAPER_CL = dict(masim=10, redis=50, memcached=100, hash=250, ocean_ncp=290)
PAPER_SELECTED_PAGES = dict(
    masim=4_142, redis=93_896, memcached=174_068, hash=307_484, ocean_ncp=950_758
)
