"""Mesh construction for launch entry points (system prompt contract).

FUNCTIONS, not module-level constants: importing this module never touches
jax device state. Two families:

* :func:`guest_mesh` -- the engine's 1-D ``"guest"``-axis mesh (DESIGN.md
  §9/§17). The shared constructor behind ``benchmarks.common.
  default_guest_mesh`` and the multi-host workers: single-process it spans
  every local device (``None`` on a 1-device host, the no-mesh
  degradation); after ``launch.multihost.initialize`` it spans every
  process's devices, making ``engine.run_sharded``/``run_churn`` a
  cross-host SPMD program whose only collective is the per-window
  candidate-exchange psum.
* :func:`make_production_mesh` -- train-style pod/data/model geometry for
  the model-layer recipes, now a thin special case of :func:`train_mesh`:
  single-pod ``(data=16, model=16)`` = 256 chips; multi-pod adds a leading
  pod axis -> ``(pod=2, data=16, model=16)`` = 512 chips. DP runs over
  ``("pod", "data")``; TP/EP over ``"model"`` (DESIGN.md §5).
"""
from __future__ import annotations

import jax

DEFAULT_DATA = 16
DEFAULT_MODEL = 16
DEFAULT_PODS = 2


def guest_mesh(n_devices: int | None = None):
    """1-D ``"guest"``-axis mesh over ``n_devices`` devices (every device in
    the job when ``None``; ``None`` result on a single-device host). In a
    multi-process job the mesh must span all global devices -- see
    ``repro.core.sharding.guest_mesh``, which this delegates to."""
    from repro.core import sharding

    return sharding.guest_mesh(n_devices)


def train_mesh(data: int = DEFAULT_DATA, model: int = DEFAULT_MODEL,
               pods: int | None = None):
    """Train-style mesh of ``data x model`` chips per pod, with an optional
    leading ``pod`` axis when ``pods`` is given (``pods=1`` still carries the
    axis -- callers that want the flat 2-D geometry pass ``pods=None``)."""
    if data < 1 or model < 1 or (pods is not None and pods < 1):
        raise ValueError(
            f"train_mesh: axis sizes must be >= 1, got "
            f"data={data}, model={model}, pods={pods}")
    if pods is None:
        return jax.make_mesh((data, model), ("data", "model"))
    return jax.make_mesh((pods, data, model), ("pod", "data", "model"))


def make_production_mesh(*, multi_pod: bool = False):
    """The production geometry as a thin :func:`train_mesh` special case."""
    return train_mesh(pods=DEFAULT_PODS if multi_pod else None)


def dp_axes(mesh) -> tuple:
    """Batch/token axes of a mesh made by make_production_mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_dist(mesh):
    from repro.models.dist import Dist

    return Dist(mesh=mesh, dp=dp_axes(mesh), tp="model")
