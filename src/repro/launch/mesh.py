"""Production mesh construction (system prompt contract).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state. Geometry: single-pod (data=16, model=16) = 256 chips;
multi-pod adds a leading pod axis -> (pod=2, data=16, model=16) = 512 chips.
DP runs over ("pod", "data"); TP/EP over "model" (DESIGN.md §5).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    """Batch/token axes of a mesh made by make_production_mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_dist(mesh):
    from repro.models.dist import Dist

    return Dist(mesh=mesh, dp=dp_axes(mesh), tp="model")
