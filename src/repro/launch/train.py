"""Production training driver.

On a real TPU slice this builds the production mesh, shards params/optimizer
state per launch/sharding.py, and runs the fault-tolerant loop (checkpoint/
restart via Supervisor, straggler observation hooks). On this CPU container
it runs the same code path with ``--mesh none`` (single device) -- the mesh
path is exercised structurally by the dry-run.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --reduced --steps 100 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as config_lib
from repro.data import pipeline
from repro.launch import sharding
from repro.launch.mesh import make_dist, make_production_mesh
from repro.models import registry
from repro.models.dist import NO_DIST
from repro.train import checkpoint, fault, optimizer, trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--micro-batches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--mesh", default="none", choices=("none", "single", "multi"))
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (config_lib.reduced(args.arch) if args.reduced
           else config_lib.get(args.arch))
    model = registry.build(cfg)
    dist = NO_DIST
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        dist = make_dist(mesh)

    tcfg = trainer.TrainConfig(
        micro_batches=args.micro_batches,
        compress_grads=args.compress_grads,
        opt=optimizer.OptConfig(lr=args.lr, warmup_steps=min(100, args.steps // 10),
                                total_steps=args.steps),
    )
    spec = pipeline.DataSpec(vocab=cfg.vocab, seq_len=args.seq_len,
                             global_batch=args.global_batch)

    key = jax.random.PRNGKey(0)
    params = model.init(key)
    train_state = trainer.init_train_state(tcfg, params)
    data_state = pipeline.DataState()
    supervisor = None
    if args.ckpt_dir:
        supervisor = fault.Supervisor(args.ckpt_dir, save_every=args.save_every)
        start = supervisor.resume_step()
        if start:
            like = {"params": params, "train_state": train_state,
                    "data_step": jnp.asarray(0)}
            restored, man = checkpoint.restore(args.ckpt_dir, like)
            params = restored["params"]
            train_state = restored["train_state"]
            data_state = pipeline.DataState(step=int(restored["data_step"]))
            print(f"[train] resumed from step {man['step']}")

    step_fn = jax.jit(trainer.make_train_step(model, tcfg, dist))
    n_params = sum(int(jnp.size(l)) for l in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.global_batch}x{args.seq_len}")

    t0 = time.time()
    start = int(train_state["opt"]["step"])
    for step in range(start, args.steps):
        batch, data_state = pipeline.next_batch(spec, data_state)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, train_state, mets = step_fn(params, train_state, batch)
        if supervisor:
            supervisor.maybe_save(
                step + 1,
                {"params": params, "train_state": train_state,
                 "data_step": jnp.asarray(data_state.step)})
        if (step + 1) % args.log_every == 0 or step == start:
            tps = (step + 1 - start) * args.global_batch * args.seq_len \
                / (time.time() - t0)
            print(f"[train] step {step+1:5d} loss {float(mets['loss']):.4f} "
                  f"lr {float(mets['lr']):.2e} gnorm "
                  f"{float(mets['grad_norm']):.2f} ({tps:.0f} tok/s)")
    print(f"[train] done in {time.time()-t0:.1f}s; "
          f"final loss {float(mets['loss']):.4f}")
    return float(mets["loss"])


if __name__ == "__main__":
    main()
