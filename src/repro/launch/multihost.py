"""Multi-host runtime: distributed init, global guest mesh, process launcher.

DESIGN.md §17. Three pieces turn the single-process engine into a
multi-host SPMD program:

* :func:`initialize` -- a ``jax.distributed`` wrapper driven by arguments or
  the ``REPRO_*`` environment the launcher exports. It must run **before any
  jax computation** (the CPU client is created on first device query): it
  selects the cross-process CPU collectives implementation (gloo TCP -- the
  stock CPU backend refuses multi-process programs outright) and joins the
  coordination service. A no-op when ``num_processes <= 1``, so worker
  entry points run unchanged standalone.
* :func:`global_guest_mesh` -- the engine's ``"guest"``-axis mesh over every
  process's devices. ``engine.run_sharded``/``run_churn`` on this mesh span
  hosts with the per-window candidate-exchange psum as the only cross-host
  collective (host state is range-partitioned and traces synthesize
  on-device, PR 4/5), bit-identical to the single-process run on the same
  global mesh (INV-MULTIHOST-EXACT).
* :func:`launch` -- a subprocess launcher for tests/CI: spawns N coordinated
  CPU processes, each with ``--xla_force_host_platform_device_count=K``
  forced local devices and the rendezvous environment, and collects their
  output. ``launch_check`` is the assertion form the smoke script and the
  contract harness share.
"""
from __future__ import annotations

import dataclasses
import os
import socket
import subprocess
import sys
import time

ENV_COORDINATOR = "REPRO_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"
ENV_CPU_COLLECTIVES = "REPRO_CPU_COLLECTIVES"

DEFAULT_CPU_COLLECTIVES = "gloo"


@dataclasses.dataclass(frozen=True)
class ProcessInfo:
    """What :func:`initialize` resolved: this process's slot in the job."""

    process_id: int
    num_processes: int
    coordinator_address: str | None
    local_devices: int
    global_devices: int

    @property
    def is_coordinator(self) -> bool:
        return self.process_id == 0


def initialize(coordinator_address: str | None = None,
               num_processes: int | None = None,
               process_id: int | None = None,
               *, cpu_collectives: str | None = None) -> ProcessInfo:
    """Join (or skip) the distributed job; returns the resolved slot.

    Arguments default to the ``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES``
    / ``REPRO_PROCESS_ID`` / ``REPRO_CPU_COLLECTIVES`` environment the
    launcher exports, so a worker entry point is just
    ``info = multihost.initialize()`` before its first jax call -- run
    standalone (no environment), that is a no-op and the worker stays a
    normal single-process program.
    """
    env = os.environ
    if coordinator_address is None:
        coordinator_address = env.get(ENV_COORDINATOR)
    if num_processes is None:
        num_processes = int(env.get(ENV_NUM_PROCESSES, "1"))
    if process_id is None:
        process_id = int(env.get(ENV_PROCESS_ID, "0"))
    if cpu_collectives is None:
        cpu_collectives = env.get(ENV_CPU_COLLECTIVES,
                                  DEFAULT_CPU_COLLECTIVES)

    import jax

    if num_processes <= 1:
        return ProcessInfo(0, 1, None, jax.local_device_count(),
                           jax.device_count())
    if coordinator_address is None:
        raise ValueError(
            f"multihost.initialize: num_processes={num_processes} needs a "
            f"coordinator address (argument or ${ENV_COORDINATOR})")
    if not 0 <= process_id < num_processes:
        raise ValueError(
            f"multihost.initialize: process_id={process_id} outside "
            f"[0, {num_processes})")
    # The stock XLA CPU client refuses cross-process programs
    # ("Multiprocess computations aren't implemented on the CPU backend");
    # the gloo TCP collectives implementation must be selected before the
    # client exists. Env-var spelling is not read by this flag -- only
    # config.update works, which is why initialize() must precede any jax
    # device query.
    jax.config.update("jax_cpu_collectives_implementation", cpu_collectives)
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)
    return ProcessInfo(process_id, num_processes, coordinator_address,
                       jax.local_device_count(), jax.device_count())


def global_guest_mesh(n_devices: int | None = None):
    """The engine's ``"guest"``-axis mesh over every device of every process
    in the job (after :func:`initialize`). Single-process this is exactly
    ``sharding.guest_mesh``; multi-process it must span all global devices
    (partial meshes are rejected there -- a process holding no shard cannot
    participate in the SPMD program)."""
    from repro.core import sharding

    return sharding.guest_mesh(n_devices)


# --------------------------------------------------------------------------
# subprocess launcher (tests / CI: N coordinated CPU processes on one box)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LaunchResult:
    process_id: int
    returncode: int
    stdout: str


def free_port() -> int:
    s = socket.socket()
    try:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]
    finally:
        s.close()


def worker_env(base: dict | None = None, *, coordinator: str,
               num_processes: int, process_id: int,
               devices_per_process: int,
               cpu_collectives: str = DEFAULT_CPU_COLLECTIVES,
               pythonpath: str = "src") -> dict:
    """Environment for one coordinated CPU worker: rendezvous variables for
    :func:`initialize`, forced local device count (fixed at jax init, hence
    subprocesses), CPU platform pin, and ``src`` on PYTHONPATH."""
    env = dict(os.environ if base is None else base)
    env[ENV_COORDINATOR] = coordinator
    env[ENV_NUM_PROCESSES] = str(num_processes)
    env[ENV_PROCESS_ID] = str(process_id)
    env[ENV_CPU_COLLECTIVES] = cpu_collectives
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices_per_process}")
    env["JAX_PLATFORMS"] = "cpu"
    prev = env.get("PYTHONPATH")
    env["PYTHONPATH"] = f"{pythonpath}:{prev}" if prev else pythonpath
    return env


def launch(worker: str, *, num_processes: int = 2,
           devices_per_process: int = 2, args: tuple = (),
           timeout: float = 600.0, cwd: str | None = None,
           env: dict | None = None,
           cpu_collectives: str = DEFAULT_CPU_COLLECTIVES,
           ) -> list[LaunchResult]:
    """Spawn ``num_processes`` coordinated CPU workers running
    ``python worker *args`` and wait for all of them.

    Every worker gets the same argv; its slot arrives via the ``REPRO_*``
    environment (:func:`worker_env`), consumed by :func:`initialize` at the
    top of the worker. Stdout+stderr are captured per process. On timeout
    every worker is killed and ``TimeoutError`` raised -- a hung collective
    in one process would otherwise hang the whole launch.
    """
    if num_processes < 1:
        raise ValueError(f"launch: num_processes must be >= 1, "
                         f"got {num_processes}")
    coordinator = f"127.0.0.1:{free_port()}"
    procs = []
    for i in range(num_processes):
        wenv = worker_env(env, coordinator=coordinator,
                          num_processes=num_processes, process_id=i,
                          devices_per_process=devices_per_process,
                          cpu_collectives=cpu_collectives)
        procs.append(subprocess.Popen(
            [sys.executable, worker, *map(str, args)],
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True, env=wenv, cwd=cwd))
    deadline = time.monotonic() + timeout
    results = []
    try:
        for i, p in enumerate(procs):
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise subprocess.TimeoutExpired(p.args, timeout)
            out, _ = p.communicate(timeout=remaining)
            results.append(LaunchResult(i, p.returncode, out))
    except subprocess.TimeoutExpired:
        for p in procs:
            p.kill()
        raise TimeoutError(
            f"multihost.launch: {num_processes}-process job exceeded "
            f"{timeout}s") from None
    return results


def launch_check(worker: str, *, marker: str, **kw) -> list[LaunchResult]:
    """:func:`launch` + assert every worker exited 0 with ``marker`` in its
    output; failures re-raise with the failing worker's full output."""
    results = launch(worker, **kw)
    for r in results:
        if r.returncode != 0 or marker not in r.stdout:
            raise AssertionError(
                f"multihost worker {r.process_id} "
                f"{'failed' if r.returncode else 'missing marker'} "
                f"(rc={r.returncode}, marker={marker!r}):\n{r.stdout}")
    return results
