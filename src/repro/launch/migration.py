"""Live guest migration: mid-flight state handoff between host partitions.

DESIGN.md §17. A guest is migrated by re-homing its *lane*: the compiled
geometry never changes (the same static-shape discipline as the churn
engine's crash/restart faults), the guest's state simply moves from its
source lane to a vacant destination lane -- on a sharded mesh, a lane in a
different device partition. The protocol is four host-side phases on the
replicated state, run between driver calls:

  1. **quiesce** -- flip the source lane inactive in the :class:`~repro.
     core.engine.ChurnState` activity mask, optionally drive drain windows
     so in-flight telemetry rolls out (the stepper masks a quiesced lane's
     accesses to -1, the same value-exact silencing churn uses).
  2. **extract** -- package the lane's segment-relative state: mappings
     (``gpt``/``rmap``), guest + host telemetry rows, and the hp-owned
     payload read through the block table (``data[h] = pools[bt[h]]``, the
     partitioned path's layout invariant).
  3. **release** -- crash-style reclaim of the source lane
     (:func:`repro.core.faults.apply_guest_faults`): its blocks read
     unallocated the same window, so the tier policies treat them as
     victims immediately (INV-CRASH-RECLAIM-COMPLETE).
  4. **inject + resume** -- write the package into the destination lane's
     existing block-table slots and flip it active. ``block_table`` is a
     permutation (every huge page owns a slot, allocated or not), so
     injection needs NO slot allocation; placement restarts wherever the
     destination's slots sit, while the migrated access histories let the
     policies re-promote the hot set within an ``ipt_windows`` horizon.

All edits are row copies on the replicated state, so a migration composes
with any mesh (the next chunk sees the same replicated state regardless of
how it is driven), with fault schedules, and with the pressure controller
(whose scalars ride the ChurnState untouched).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import faults as faults_mod
from repro.core.types import FREE, TieredState


@dataclasses.dataclass(frozen=True)
class GuestPackage:
    """One extracted lane, segment-relative: every index is rebased to the
    lane's own segment start, so injection into any geometry-compatible
    lane is a pure offset add. ``manifest`` is the bytes accounting the
    at-scale harness reports (payload vs mapping vs telemetry)."""

    source: int
    n_logical: int
    hp_size: int
    gpt: np.ndarray  # int32[n_logical]    segment-relative gpa page ids
    rmap: np.ndarray  # int32[hp_size*ratio] segment-relative logical | FREE
    guest_counts: np.ndarray  # int32[n_logical]
    ipt_hist: np.ndarray  # uint8[n_logical]
    host_counts: np.ndarray  # int32[hp_size]
    host_hist: np.ndarray  # uint8[hp_size]
    last_touch_epoch: np.ndarray  # int32[hp_size]
    region_epoch: np.ndarray  # int32[hp_size]
    payload: np.ndarray  # dtype[hp_size, hp_ratio, base_elems]

    @property
    def manifest(self) -> dict:
        mapping = self.gpt.nbytes + self.rmap.nbytes
        telemetry = (
            self.guest_counts.nbytes + self.ipt_hist.nbytes
            + self.host_counts.nbytes + self.host_hist.nbytes
            + self.last_touch_epoch.nbytes + self.region_epoch.nbytes
        )
        return dict(
            payload_bytes=int(self.payload.nbytes),
            mapping_bytes=int(mapping),
            telemetry_bytes=int(telemetry),
            total_bytes=int(self.payload.nbytes + mapping + telemetry),
        )


def _check_lane(spec, g: int, what: str) -> None:
    if not 0 <= g < spec.n_guests:
        raise ValueError(
            f"{what} lane {g} outside [0, {spec.n_guests})")


def _compatible(spec, src: int, dst: int) -> None:
    if src == dst:
        raise ValueError(f"migration source and destination are both lane {src}")
    s, d = spec.guests[src], spec.guests[dst]
    if s.n_logical != d.n_logical:
        raise ValueError(
            f"lane geometry mismatch: source lane {src} has n_logical="
            f"{s.n_logical}, destination lane {dst} has {d.n_logical}")
    if spec.guest_cl(src) != spec.guest_cl(dst):
        raise ValueError(
            f"lane CL mismatch: source lane {src} has cl="
            f"{spec.guest_cl(src)}, destination lane {dst} has "
            f"{spec.guest_cl(dst)}")


def _pool_rows(cfg, state: TieredState, slots: jnp.ndarray) -> jnp.ndarray:
    """Payload rows of the given slots, whichever pool they live in."""
    is_near = slots < cfg.n_near
    near = state.near_pool[jnp.where(is_near, slots, 0)]
    far = state.far_pool[jnp.where(is_near, 0, slots - cfg.n_near)]
    return jnp.where(is_near[:, None, None], near, far)


def extract_guest(spec, state: TieredState, g: int) -> GuestPackage:
    """Package lane ``g``'s state, segment-relative (phase 2)."""
    _check_lane(spec, g, "source")
    cfg = spec.cfg
    lo, hi = spec.logical_range(g)
    hp_lo, hp_hi = spec.hp_range(g)
    gpa_lo, gpa_hi = hp_lo * cfg.hp_ratio, hp_hi * cfg.hp_ratio
    rmap = np.asarray(state.rmap[gpa_lo:gpa_hi])
    slots = state.block_table[hp_lo:hp_hi]
    return GuestPackage(
        source=g,
        n_logical=hi - lo,
        hp_size=hp_hi - hp_lo,
        gpt=np.asarray(state.gpt[lo:hi]) - gpa_lo,
        rmap=np.where(rmap == int(FREE), int(FREE), rmap - lo).astype(np.int32),
        guest_counts=np.asarray(state.guest_counts[lo:hi]),
        ipt_hist=np.asarray(state.ipt_hist[lo:hi]),
        host_counts=np.asarray(state.host_counts[hp_lo:hp_hi]),
        host_hist=np.asarray(state.host_hist[hp_lo:hp_hi]),
        last_touch_epoch=np.asarray(state.last_touch_epoch[hp_lo:hp_hi]),
        region_epoch=np.asarray(state.region_epoch[hp_lo:hp_hi]),
        payload=np.asarray(_pool_rows(cfg, state, slots)),
    )


def release_guest(spec, state: TieredState, g: int) -> TieredState:
    """Crash-style reclaim of lane ``g`` (phase 3): segment freed, telemetry
    cleared, payload wiped -- the exact fault-engine transition, so the
    reclaim-completeness contract carries over."""
    _check_lane(spec, g, "source")
    n_g = spec.n_guests
    one_hot = jnp.zeros((n_g,), bool).at[g].set(True)
    state, _ = faults_mod.apply_guest_faults(
        spec.canonical(), state, jnp.ones((n_g,), bool), one_hot,
        jnp.zeros((n_g,), bool),
    )
    return state


def inject_guest(
    spec, state: TieredState, g: int, pkg: GuestPackage,
) -> TieredState:
    """Re-home a package into vacant lane ``g`` (phase 4): offset-translated
    mapping/telemetry row writes, payload written through the lane's
    *existing* block-table slots (the permutation means every huge page
    already owns one -- no allocation step exists)."""
    _check_lane(spec, g, "destination")
    if pkg.source != g:
        _compatible(spec, pkg.source, g)
    cfg = spec.cfg
    lo, hi = spec.logical_range(g)
    hp_lo, hp_hi = spec.hp_range(g)
    if hi - lo != pkg.n_logical or hp_hi - hp_lo != pkg.hp_size:
        raise ValueError(
            f"package geometry ({pkg.n_logical} logical, {pkg.hp_size} hp) "
            f"does not fit lane {g} ({hi - lo} logical, "
            f"{hp_hi - hp_lo} hp)")
    gpa_lo = hp_lo * cfg.hp_ratio
    vacant = np.asarray(
        state.rmap[gpa_lo: hp_hi * cfg.hp_ratio] == FREE).all()
    if not vacant:
        raise ValueError(
            f"destination lane {g} still holds allocated pages; release or "
            f"crash it before injecting")
    rmap_abs = jnp.where(
        jnp.asarray(pkg.rmap) == FREE, FREE, jnp.asarray(pkg.rmap) + lo)
    slots = state.block_table[hp_lo:hp_hi]
    is_near = slots < cfg.n_near
    payload = jnp.asarray(pkg.payload)
    near_pool = state.near_pool.at[
        jnp.where(is_near, slots, cfg.n_near)
    ].set(payload, mode="drop")
    far_pool = state.far_pool.at[
        jnp.where(is_near, cfg.n_far, slots - cfg.n_near)
    ].set(payload, mode="drop")
    return dataclasses.replace(
        state,
        gpt=state.gpt.at[lo:hi].set(jnp.asarray(pkg.gpt) + gpa_lo),
        rmap=state.rmap.at[gpa_lo: hp_hi * cfg.hp_ratio].set(rmap_abs),
        guest_counts=state.guest_counts.at[lo:hi].set(
            jnp.asarray(pkg.guest_counts)),
        ipt_hist=state.ipt_hist.at[lo:hi].set(jnp.asarray(pkg.ipt_hist)),
        host_counts=state.host_counts.at[hp_lo:hp_hi].set(
            jnp.asarray(pkg.host_counts)),
        host_hist=state.host_hist.at[hp_lo:hp_hi].set(
            jnp.asarray(pkg.host_hist)),
        last_touch_epoch=state.last_touch_epoch.at[hp_lo:hp_hi].set(
            jnp.asarray(pkg.last_touch_epoch)),
        region_epoch=state.region_epoch.at[hp_lo:hp_hi].set(
            jnp.asarray(pkg.region_epoch)),
        near_pool=near_pool,
        far_pool=far_pool,
    )


def quiesce(cs, g: int):
    """Flip lane ``g`` inactive in a ChurnState (phase 1). The state is
    untouched: drive drain windows afterwards if in-flight telemetry should
    roll out before extraction."""
    return dataclasses.replace(
        cs, active=cs.active.at[g].set(False))


def resume(cs, g: int):
    """Flip lane ``g`` active again (end of phase 4)."""
    return dataclasses.replace(
        cs, active=cs.active.at[g].set(True))


def migrate_guest(spec, cs, src: int, dst: int):
    """The full live-migration protocol on a ChurnState carry:
    quiesce(src) -> extract -> release(src) -> inject(dst) -> resume(dst).

    Returns ``(cs, manifest)``: the carry with the guest re-homed and the
    bytes accounting of the handoff. The destination lane must be vacant
    (inactive -- a spare lane from ``init_churn(active=...)`` or a crashed
    one); the source must be active. Runs between driver calls; the next
    ``run_churn``/``step`` continues with the migrated lane live, on any
    mesh.
    """
    from repro.core.engine import ChurnState

    if not isinstance(cs, ChurnState):
        raise TypeError(
            f"migrate_guest needs a ChurnState carry, got {type(cs).__name__}")
    _check_lane(spec, src, "source")
    _check_lane(spec, dst, "destination")
    _compatible(spec, src, dst)
    active = np.asarray(cs.active)
    if not active[src]:
        raise ValueError(f"source lane {src} is not active")
    if active[dst]:
        raise ValueError(
            f"destination lane {dst} is active; migrate into a vacant "
            f"(inactive) lane")
    cs = quiesce(cs, src)
    pkg = extract_guest(spec, cs.state, src)
    state = release_guest(spec, cs.state, src)
    state = inject_guest(spec, state, dst, pkg)
    cs = dataclasses.replace(
        cs, state=state, active=cs.active.at[dst].set(True))
    return cs, pkg.manifest
