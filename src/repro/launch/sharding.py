"""PartitionSpec assignment for params / optimizer state / batches / caches.

Policy (DESIGN.md §5):
  * TP over "model": attention heads (iff n_heads % tp == 0, respecting head
    boundaries), KV heads likewise, d_ff, vocab, MoE experts (padded), mamba/
    xLSTM inner dims.
  * DP over ("pod","data"): batch rows, token dims of activations.
  * FSDP: any param leaf bigger than ``fsdp_threshold`` bytes additionally
    shards its largest still-unsharded divisible dim over the DP axes
    (ZeRO-3-style weight sharding; GSPMD all-gathers at use sites).
  * ZeRO-1: optimizer moments inherit the param spec + the same FSDP rule at
    threshold 0 (always shard something if divisible) -- each DP rank owns a
    slice of m/v.
  * Divisibility fallback everywhere: an axis that does not divide a dim is
    dropped (15-head attention replicates, batch=1 decode replicates).
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.dist import Dist

FSDP_THRESHOLD = 8 * 1024 * 1024  # bytes; leaves above this get FSDP


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------
def _base_param_spec(cfg: ArchConfig, path: str, shape: tuple,
                     dist: Dist) -> list:
    """TP spec for the *trailing* dims (callers left-pad for the stacked
    group axis). Returns a list of axis names / None."""
    tp = dist.tp
    n = dist.axis_size(tp)
    heads_ok = cfg.n_heads % n == 0
    kv_ok = cfg.n_kv_heads % n == 0
    r = len(shape)
    spec = [None] * r

    def last(*axes):
        for i, a in enumerate(axes):
            spec[r - len(axes) + i] = a
        return spec

    if re.search(r"embed/tok$", path):
        return last(tp, None)  # (vocab, d)
    if re.search(r"embed/unembed$", path):
        return last(None, tp)  # (d, vocab)
    if re.search(r"embed/pos_(dec|enc)$", path):
        return spec
    if re.search(r"(norm\w*|final_norm)/(scale|bias)$", path):
        return spec
    if re.search(r"attn/wq$", path):
        return last(None, tp if heads_ok else None)
    if re.search(r"attn/w[kv]$", path):
        return last(None, tp if kv_ok else None)
    if re.search(r"attn/wo$", path):
        return last(tp if heads_ok else None, None)
    if re.search(r"attn/bq$", path):
        return last(tp if heads_ok else None)
    if re.search(r"attn/b[kv]$", path):
        return last(tp if kv_ok else None)
    if re.search(r"(xattn)/wq$", path):
        return last(None, tp if heads_ok else None)
    if re.search(r"(xattn)/w[kv]$", path):
        return last(None, tp if kv_ok else None)
    if re.search(r"(xattn)/wo$", path):
        return last(tp if heads_ok else None, None)
    if re.search(r"(xattn)/b[qkv]$", path):
        return spec
    if re.search(r"ffn/(wi_gate|wi_up|wi)$", path):
        return last(None, tp)  # (d, ff)
    if re.search(r"ffn/wo$", path):
        return last(tp, None)  # (ff, d)
    if re.search(r"ffn/router$", path):
        return last(None, tp)  # (d, E_pad)
    if re.search(r"ffn/experts/(wi_gate|wi_up|wo)$", path):
        return last(tp, None, None)  # (E_pad, d, ff) -- EP
    if re.search(r"ffn/shared/(wi_gate|wi_up|wi)$", path):
        return last(None, tp)
    if re.search(r"ffn/shared/wo$", path):
        return last(tp, None)
    if re.search(r"mamba/in_proj$", path):
        return last(None, tp)  # (d, 2*di)
    if re.search(r"mamba/conv_[wb]$", path):
        return last(tp) if len(shape) == 1 else last(None, tp)
    if re.search(r"mamba/x_proj$", path):
        return last(tp, None)  # (di, dr+2ds)
    if re.search(r"mamba/dt_proj$", path):
        return last(None, tp)  # (dr, di)
    if re.search(r"mamba/(dt_bias|D)$", path):
        return last(tp)
    if re.search(r"mamba/A_log$", path):
        return last(tp, None)  # (di, ds)
    if re.search(r"mamba/out_proj$", path):
        return last(tp, None)  # (di, d)
    if re.search(r"(mlstm|slstm)/up_proj$", path):
        return last(None, tp)  # (d, 2*di)
    if re.search(r"mlstm/w[qkv]$", path):
        return last(None, tp, None)  # (H, hd, hd): shard hd_in
    if re.search(r"mlstm/w_if$", path):
        return last(tp, None)  # (di, 2H)
    if re.search(r"mlstm/b_if$", path):
        return spec
    if re.search(r"slstm/w_gates$", path):
        return last(None, None, tp, None)  # (4, H, hd, hd)
    if re.search(r"slstm/r_gates$", path):
        return last(None, tp)  # (4, di)
    if re.search(r"slstm/b_gates$", path):
        return spec
    if re.search(r"(mlstm|slstm)/down_proj$", path):
        return last(tp, None)
    return spec  # default replicate


def _fsdp_extend(spec: list, shape: tuple, dist: Dist, threshold: int | None,
                 itemsize: int = 2) -> list:
    """Shard the largest unsharded divisible dim over the DP axes when the
    leaf exceeds ``threshold`` bytes. ``threshold=None`` disables FSDP
    (inference cells: read-only weights live TP-only)."""
    if threshold is None:
        return spec
    size = int(np.prod(shape)) * itemsize
    if size <= threshold:
        return spec
    dp = dist.dp if isinstance(dist.dp, tuple) else (dist.dp,)
    n_dp = dist.axis_size(dp)
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if spec[i] is None and shape[i] % n_dp == 0:
            spec[i] = dp
            return spec
    return spec


def param_specs(cfg: ArchConfig, params_shapes, dist: Dist,
                fsdp_threshold: int | None = FSDP_THRESHOLD):
    """Pytree of PartitionSpec matching ``params_shapes`` (a tree of
    ShapeDtypeStruct or arrays). Handles the stacked group axis."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = []
    for path, leaf in flat:
        pstr = _path_str(path)
        shape = leaf.shape
        stacked = pstr.startswith("groups/") or "/layers/" in pstr
        core_shape = shape[1:] if stacked else shape
        spec = _base_param_spec(cfg, pstr, core_shape, dist)
        if stacked:
            spec = [None] + spec
        itemsize = getattr(np.dtype(leaf.dtype), "itemsize", 2)
        spec = _fsdp_extend(spec, shape, dist, fsdp_threshold, itemsize)
        specs.append(dist.fit_spec(shape, P(*spec)))
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_specs(cfg: ArchConfig, opt_shapes, p_specs, dist: Dist):
    """ZeRO-1: optimizer moments follow the param spec, then always try to
    shard one more dim over DP (threshold 0). Scalars replicate."""
    flat_p, _ = jax.tree_util.tree_flatten(p_specs)

    def assign(path, leaf):
        pstr = _path_str(path)
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        # find the param this moment mirrors: same trailing path under m/v/f
        m = re.match(r"^(m|v|f|err)/(.*)$", pstr)
        core = m.group(2) if m else pstr
        core = re.sub(r"/(vr|vc|v)$", "", core)
        stacked = core.startswith("groups/")
        core_shape = shape[1:] if stacked else shape
        spec = _base_param_spec(cfg, core, core_shape, dist)
        if stacked:
            spec = [None] + spec
        spec = spec[: len(shape)]  # adafactor factored dims may be shorter
        spec += [None] * (len(shape) - len(spec))
        spec = _fsdp_extend(spec, shape, dist, threshold=0, itemsize=4)
        return dist.fit_spec(shape, P(*spec))

    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [assign(p, l) for p, l in flat])


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------
def batch_specs(batch_shapes, dist: Dist):
    def assign(path, leaf):
        pstr = _path_str(path)
        if pstr == "positions":  # (3, B, S)
            return dist.fit_spec(leaf.shape, P(None, dist.dp, None))
        return dist.fit_spec(leaf.shape, P(dist.dp, *([None] * (len(leaf.shape) - 1))))

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [assign(p, l) for p, l in flat])


def cache_specs(cfg: ArchConfig, cache_shapes, dist: Dist):
    """Decode-cache specs: batch over DP; KV heads over model if divisible,
    else head_dim over model; mixer states shard their inner dim."""
    tp = dist.tp
    n = dist.axis_size(tp)
    kv_ok = cfg.n_kv_heads % n == 0

    def assign(path, leaf):
        pstr = _path_str(path)
        shape = leaf.shape
        if pstr in ("btab", "lens"):
            return dist.fit_spec(shape, P(dist.dp))
        if re.search(r"(k|v)_pages$", pstr):  # (G, B, KVH, n_pool, page, hd)
            # KV heads over model when divisible; otherwise the page-token
            # dim (sequence parallelism: softmax stats + tiny PV psums,
            # instead of head_dim contractions that all-reduce full scores
            # -- §Perf iteration 2)
            kv_axis = tp if kv_ok else None
            page_axis = None if kv_ok else tp
            return dist.fit_spec(
                shape, P(None, dist.dp, kv_axis, None, page_axis, None))
        if re.search(r"enc_[kv]$", pstr):  # (G, B, F, KVH, hd)
            kv_axis = tp if kv_ok else None
            hd_axis = None if kv_ok else tp
            return dist.fit_spec(shape, P(None, dist.dp, None, kv_axis, hd_axis))
        if re.search(r"/h$", pstr):  # mamba h (G, B, di, ds)
            return dist.fit_spec(shape, P(None, dist.dp, tp, None))
        if re.search(r"/conv_tail$", pstr):  # (G, B, dc-1, di)
            return dist.fit_spec(shape, P(None, dist.dp, None, tp))
        if re.search(r"/C$", pstr):  # mlstm (G, B, H, hd, hd)
            return dist.fit_spec(shape, P(None, dist.dp, None, tp, None))
        if re.search(r"/(n|m|c)$", pstr):  # (G, B, H, hd) or (G, B, di)
            spec = [None, dist.dp] + [None] * (len(shape) - 2)
            if len(shape) >= 3:
                spec[-1] = tp
            return dist.fit_spec(shape, P(*spec))
        # fallback: batch over DP on dim 1 (stacked) if present
        spec = [None] * len(shape)
        if len(shape) >= 2:
            spec[1] = dist.dp
        return dist.fit_spec(shape, P(*spec))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [assign(p, l) for p, l in flat])


def to_shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
