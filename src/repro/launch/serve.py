"""Serving driver: continuous batching over the GPAC-tiered paged KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs as config_lib
from repro.models import registry
from repro.serve.engine import Engine, EngineConfig
from repro.serve.scheduler import Request, SchedulerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seqs", type=int, default=4)
    ap.add_argument("--max-seq-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--near-fraction", type=float, default=0.4)
    ap.add_argument("--no-gpac", action="store_true")
    args = ap.parse_args(argv)

    cfg = (config_lib.reduced(args.arch) if args.reduced
           else config_lib.get(args.arch))
    cfg = cfg.replace(page_size=args.page_size)
    model = registry.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(
        max_seqs=args.max_seqs, max_seq_len=args.max_seq_len,
        pages_per_block=4, near_fraction=args.near_fraction,
        sched=SchedulerConfig(max_seqs=args.max_seqs, maintenance_every=8,
                              use_gpac=not args.no_gpac, reserve_tokens=8))
    eng = Engine(model, params, ecfg)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, args.prompt_len).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        eng.sched.submit(r)

    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in reqs)
    stats = eng.stats()
    print(f"[serve] {cfg.name}: {len(reqs)} requests, {toks} tokens "
          f"in {dt:.1f}s ({toks/dt:.1f} tok/s)")
    print(f"[serve] near capacity used {stats['near_capacity_used']:.1%}, "
          f"KV hit rate {stats['hit_rate']:.3f}, "
          f"consolidated pages {stats['consolidated_pages']}, "
          f"blocks promoted/demoted {stats['promoted_blocks']}/"
          f"{stats['demoted_blocks']}")
    for r in reqs[:3]:
        print(f"[serve] req {r.rid}: {r.out[:8]}...")
    return stats


if __name__ == "__main__":
    main()
