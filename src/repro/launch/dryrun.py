import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede any jax-importing module: jax locks the device count on
# first backend init. 512 placeholder host devices back both production
# meshes (single-pod 16x16 uses the first 256).
#
# REPRO_FAST_COMPILE=1 drops the XLA backend optimization level: used for the
# multi-pod duplicate of each cell, which only needs to PROVE the 512-chip
# sharding compiles (the roofline reads single-pod cells, compiled at full
# optimization so fusion-dependent byte counts stay realistic).
if os.environ.get("REPRO_FAST_COMPILE"):
    os.environ["XLA_FLAGS"] += " --xla_backend_optimization_level=0"
"""Multi-pod dry-run (system prompt deliverable e).

For every (architecture x input shape x mesh) cell:
    jit(step).lower(**input_specs).compile()
with full production shardings, then record
  * compiled.memory_analysis()  -- proves the cell fits per-device HBM,
  * compiled.cost_analysis()    -- HLO FLOPs / bytes for the roofline,
  * per-collective byte totals parsed from the optimized HLO,
into experiments/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
    python -m repro.launch.dryrun --arch smollm-360m --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both]
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs as config_lib
from repro.configs.base import SHAPE_SPECS
from repro.launch import sharding
from repro.launch.mesh import make_dist, make_production_mesh
from repro.models import registry
from repro.train import optimizer, trainer

OUT_DIR = os.path.join("experiments", "dryrun")

# per-arch training recipe (gradient accumulation for the giants; factored
# optimizer where AdamW's f32 moments cannot fit even ZeRO-1-sharded)
TRAIN_RECIPE = {
    "kimi-k2-1t-a32b": dict(micro_batches=8, opt="adafactor"),
    "jamba-1.5-large-398b": dict(micro_batches=8, opt="adafactor"),
    "internlm2-20b": dict(micro_batches=2, opt="adamw"),
    "gemma-7b": dict(micro_batches=2, opt="adamw"),
}


def train_cfg_for(arch: str) -> trainer.TrainConfig:
    r = TRAIN_RECIPE.get(arch, dict(micro_batches=1, opt="adamw"))
    return trainer.TrainConfig(
        micro_batches=r["micro_batches"],
        opt=optimizer.OptConfig(name=r["opt"]),
    )


# ---------------------------------------------------------------------------
# collective parsing (optimized HLO, post-SPMD)
# ---------------------------------------------------------------------------
_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s8|u8|s16|s32|u32|s64|pred)\[([\d,]*)\]")
_DTYPE_BYTES = dict(bf16=2, f16=2, f32=4, f64=8, s8=1, u8=1, s16=2, s32=4,
                    u32=4, s64=8, pred=1)
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _first_shape_bytes(line: str) -> int:
    """Bytes of the result shape(s) on an HLO op line (tuple -> sum)."""
    total = 0
    eq = line.find(" = ")
    head = line[:eq] if eq >= 0 else line
    # result shapes appear before '='; fall back to whole line
    src = line[: line.index("(")] if "(" in line else line
    for m in _SHAPE_RE.finditer(src):
        dims = [int(d) for d in m.group(2).split(",") if d]
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes per collective op kind over the optimized HLO."""
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        for kind in COLLECTIVES:
            # matches '%x = bf16[...] all-reduce(...)' and '-start' variants
            if re.search(rf"= [^=]*\b{kind}(-start)?\(", s):
                out[kind] += _first_shape_bytes(s)
                counts[kind] += 1
                break
    return {"bytes": out, "counts": counts}


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------
def build_cell(arch: str, shape_name: str, mesh, unroll: bool = True,
               cfg=None, variant: str = "baseline"):
    """-> (jitted fn, example args pytree of ShapeDtypeStruct).

    ``unroll=True`` unrolls structural scans so XLA cost analysis counts
    every layer (scan bodies are otherwise costed once; see EXPERIMENTS.md
    §Roofline methodology). ``cfg`` overrides the arch config (depth-reduced
    extrapolation passes). ``variant='opt'`` enables the beyond-paper §Perf
    toggles (attention causal skip, bf16 SSM state expansion)."""
    cfg = (cfg or config_lib.get(arch)).replace(unroll=unroll)
    if variant == "opt":
        cfg = cfg.replace(causal_skip=True, ssm_bf16=True)
    model = registry.build(cfg)
    dist = make_dist(mesh)
    specs = registry.input_specs(cfg, shape_name)
    kind = SHAPE_SPECS[shape_name]["kind"]

    if kind == "train":
        tcfg = train_cfg_for(arch)
        params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        state_sds = jax.eval_shape(
            lambda p: trainer.init_train_state(tcfg, p), params_sds)
        step = trainer.make_train_step(model, tcfg, dist)
        p_spec = sharding.param_specs(cfg, params_sds, dist)
        s_spec = sharding.opt_specs(cfg, state_sds, p_spec, dist)
        b_spec = sharding.batch_specs(specs["batch"], dist)
        jitted = jax.jit(
            step,
            in_shardings=(
                sharding.to_shardings(mesh, p_spec),
                sharding.to_shardings(mesh, s_spec),
                sharding.to_shardings(mesh, b_spec),
            ),
            donate_argnums=(0, 1),
        )
        return jitted, (params_sds, state_sds, specs["batch"])

    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    # Inference cells: TP-only params (FSDP would all-gather weights every
    # step -- §Perf iteration 1 showed the collective term is dominated by
    # those gathers at decode). Weights are read-only at inference; the
    # "model" axis alone holds them.
    p_spec = sharding.param_specs(cfg, params_sds, dist,
                                  fsdp_threshold=None)
    if kind == "prefill":
        def step(params, batch):
            return model.prefill(params, batch, dist=dist)

        b_spec = sharding.batch_specs(specs["batch"], dist)
        jitted = jax.jit(step, in_shardings=(
            sharding.to_shardings(mesh, p_spec),
            sharding.to_shardings(mesh, b_spec)))
        return jitted, (params_sds, specs["batch"])

    # decode: serve_step(params, cache, tokens)
    def step(params, cache, tokens):
        return model.decode(params, cache, tokens, dist=dist)

    c_spec = sharding.cache_specs(cfg, specs["cache"], dist)
    t_spec = sharding.batch_specs({"tokens": specs["tokens"]}, dist)["tokens"]
    jitted = jax.jit(
        step,
        in_shardings=(
            sharding.to_shardings(mesh, p_spec),
            sharding.to_shardings(mesh, c_spec),
            sharding.to_shardings(mesh, t_spec)),
        donate_argnums=(1,),
    )
    return jitted, (params_sds, specs["cache"], specs["tokens"])


def lower_stats(arch: str, shape_name: str, mesh, unroll: bool,
                cfg=None, variant: str = "baseline") -> dict:
    """Lower + compile one variant; return memory/cost/collective stats."""
    t0 = time.time()
    jitted, args = build_cell(arch, shape_name, mesh, unroll=unroll, cfg=cfg,
                              variant=variant)
    lowered = jitted.lower(*args)
    t_lower = time.time()
    compiled = lowered.compile()
    t_compile = time.time()

    mem = compiled.memory_analysis()
    mem_fields = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, f, None)
        if v is not None:
            mem_fields[f] = int(v)
    cost = compiled.cost_analysis()
    cost = {k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float))} if cost else {}
    coll = collective_bytes(compiled.as_text())
    return dict(
        lower_s=round(t_lower - t0, 2),
        compile_s=round(t_compile - t_lower, 2),
        memory_analysis=mem_fields,
        cost_analysis={k: cost[k] for k in sorted(cost)
                       if k in ("flops", "bytes accessed", "transcendentals")
                       or k.startswith("bytes accessed")},
        collectives=coll,
    )


def _lerp_stats(s1: dict, s2: dict, l1: int, l2: int, target: int) -> dict:
    """Linear depth extrapolation of flops/bytes/collective counts:
    f(L) = f(l1) + (f(l2) - f(l1)) / (l2 - l1) * (L - l1). Exact for uniform
    layer stacks (every super-block identical)."""
    def lerp(a, b):
        return a + (b - a) / (l2 - l1) * (target - l1)

    out = dict(s1)
    out["cost_analysis"] = {
        k: lerp(s1["cost_analysis"].get(k, 0.0), s2["cost_analysis"].get(k, 0.0))
        for k in set(s1["cost_analysis"]) | set(s2["cost_analysis"])}
    out["collectives"] = {
        "bytes": {k: lerp(s1["collectives"]["bytes"][k],
                          s2["collectives"]["bytes"][k])
                  for k in s1["collectives"]["bytes"]},
        "counts": {k: lerp(s1["collectives"]["counts"][k],
                           s2["collectives"]["counts"][k])
                   for k in s1["collectives"]["counts"]},
    }
    return out


# MoE training/prefill cells: the unrolled expert-dispatch graph is too heavy
# for the SPMD partitioner at full depth -> lower a (L, 2L)-group shallow pair
# unrolled (exact per-layer costs), extrapolate linearly to full depth, and
# take the memory analysis from a full-depth scan-form compile.
def needs_extrapolation(arch: str, shape_name: str) -> bool:
    cfg = config_lib.get(arch)
    return cfg.is_moe and SHAPE_SPECS[shape_name]["kind"] in ("train", "prefill")


def run_cell(arch: str, shape_name: str, mesh_name: str,
             out_dir: str = OUT_DIR, unroll: bool = True,
             variant: str = "baseline") -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    record = dict(arch=arch, shape=shape_name, mesh=mesh_name,
                  n_devices=mesh.size, unroll=unroll, variant=variant,
                  status="error")
    try:
        if unroll and needs_extrapolation(arch, shape_name):
            cfg = config_lib.get(arch)
            g = cfg.group_size
            l1, l2 = g, 2 * g
            full = lower_stats(arch, shape_name, mesh, unroll=False,
                               variant=variant)
            s1 = lower_stats(arch, shape_name, mesh, unroll=True,
                             cfg=cfg.replace(n_layers=l1), variant=variant)
            s2 = lower_stats(arch, shape_name, mesh, unroll=True,
                             cfg=cfg.replace(n_layers=l2), variant=variant)
            stats = _lerp_stats(s1, s2, l1, l2, cfg.n_layers)
            stats["memory_analysis"] = full["memory_analysis"]
            stats["method"] = (
                f"cost: unrolled depth-{l1}/{l2} linear extrapolation to "
                f"{cfg.n_layers}; memory: full-depth scan compile")
            stats["compile_s"] = round(
                full["compile_s"] + s1["compile_s"] + s2["compile_s"], 2)
        else:
            stats = lower_stats(arch, shape_name, mesh, unroll=unroll,
                                variant=variant)
        record.update(status="ok", **stats)
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
              f"(compile {record['compile_s']}s, "
              f"flops={record['cost_analysis'].get('flops', 0):.3e})")
    except Exception as e:  # noqa: BLE001 -- record the failure, keep going
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: FAIL {e}")
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{mesh_name}.json".replace("/", "_")
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(record, f, indent=1)
    return record


def all_cells():
    for arch in config_lib.all_archs():
        for shape_name in config_lib.get(arch).shapes():
            yield arch, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--no-unroll", action="store_true",
                    help="keep lax.scan over layer groups (faster compile, "
                         "scan bodies costed once)")
    ap.add_argument("--variant", default="baseline",
                    choices=("baseline", "opt"),
                    help="'opt' enables §Perf toggles (causal skip, bf16 SSM)")
    args = ap.parse_args()

    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    if args.all:
        cells = list(all_cells())
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    ok = fail = 0
    for arch, shape_name in cells:
        for m in meshes:
            rec = run_cell(arch, shape_name, m, args.out,
                           unroll=not args.no_unroll, variant=args.variant)
            ok += rec["status"] == "ok"
            fail += rec["status"] != "ok"
    print(f"[dryrun] done: {ok} ok / {fail} failed")
    return 0 if fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
