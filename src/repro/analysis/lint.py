"""Repo-specific AST lints (DESIGN.md §15).

Each lint encodes one architectural rule the PRs fought for and the next
PRs could silently regress:

* ``REPRO-L001`` — no materialized ``[n_guests, n_windows, k]`` trace
  arrays on the synth path (PR 5's whole point);
* ``REPRO-L002`` — no string-``if`` policy/telemetry/workload/collector
  dispatch outside the registries (PR 2 converted these);
* ``REPRO-L003`` — no Python-level branching on traced values inside
  ``lax.scan`` bodies (the §13 no-op discipline: idle arithmetic must be
  the same arithmetic, not a branch);
* ``REPRO-L004`` — no full-pool ``jnp.concatenate`` in ``core/`` (PR 1
  replaced it with the predicated dual-pool gather);
* ``REPRO-L005`` — no direct numpy calls on the engine hot path (scan
  bodies and window functions must stay traceable);
* ``REPRO-L006`` — no direct ``kernel.py`` imports outside the kernels
  subpackage (PR 9: the registry in ``repro.kernels.registry`` is the only
  sanctioned dispatch surface; ``ops.py`` wraps each raw kernel).

The lint registry mirrors the PR-2 registries (duplicates raise, unknown
names raise listing the live set). Every lint carries a seeded violation
*fixture* — a minimal source file that must trip it — so the self-test
(``tests/test_lint.py``, ``scripts/lint_repro.py --self-test``) proves
each lint actually fires. Deliberate exceptions go in :data:`ALLOWLIST`
with a reason; unused allowlist entries are themselves an error (the list
is tracked, not a dumping ground).
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Callable, Iterable

# --------------------------------------------------------------------------
# violations, lint registry
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Violation:
    lint: str
    path: str  # repo-relative posix path
    line: int
    message: str
    source_line: str = ""

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.lint}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Lint:
    """One registered lint: ``fn(tree, rel_path, lines) -> Iterable[Violation]``.

    ``fixture`` is a minimal source snippet that MUST trip the lint when
    written at ``fixture_path`` (repo-relative) — the self-test runs every
    fixture and fails if its lint stays silent.
    """

    name: str
    description: str
    fn: Callable
    fixture: str
    fixture_path: str


_LINTS: dict[str, Lint] = {}


def register_lint(name: str, description: str, fixture: str, fixture_path: str):
    """Decorator: register an AST lint. Duplicates raise."""

    def deco(fn: Callable) -> Callable:
        if name in _LINTS:
            raise ValueError(f"lint {name!r} already registered")
        if not fixture.strip() or not fixture_path:
            raise ValueError(f"lint {name!r} needs a violation fixture")
        _LINTS[name] = Lint(name, description, fn, fixture, fixture_path)
        return fn

    return deco


def get_lint(name: str) -> Lint:
    try:
        return _LINTS[name]
    except KeyError:
        raise ValueError(f"unknown lint {name!r} (have {lint_names()})") from None


def lint_names() -> tuple[str, ...]:
    return tuple(sorted(_LINTS))


def all_lints() -> tuple[Lint, ...]:
    return tuple(_LINTS[n] for n in lint_names())


# --------------------------------------------------------------------------
# allowlist: deliberate, reasoned exceptions
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AllowlistEntry:
    """Suppresses violations of ``lint`` in ``path`` whose flagged source
    line contains ``match``. ``reason`` is mandatory and human-facing."""

    lint: str
    path: str  # repo-relative posix path
    match: str  # substring of the flagged source line
    reason: str

    def __post_init__(self):
        if not self.reason.strip():
            raise ValueError(
                f"allowlist entry ({self.lint}, {self.path}) needs a reason")


ALLOWLIST: tuple[AllowlistEntry, ...] = (
    AllowlistEntry(
        lint="REPRO-L004",
        path="src/repro/core/address_space.py",
        match="jnp.concatenate([near, far]",
        reason="_flat_rows backs the host-side read_logical/write_logical "
               "debug/data path, never the traced engine scan; the engine "
               "hot path uses the predicated dual-pool gather instead "
               "(consolidator, PR 1).",
    ),
    AllowlistEntry(
        lint="REPRO-L002",
        path="src/repro/core/engine.py",
        match='"tco" in collect',
        reason="static membership test on the jit-static collect tuple "
               "gates an optional per-window metric; the collector itself "
               "is registry-dispatched (run_collectors).",
    ),
    AllowlistEntry(
        lint="REPRO-L002",
        path="src/repro/core/sharding.py",
        match='"tco" in collect',
        reason="same jit-static collect gating as engine.py: membership "
               "decides which extras ride the ownership-merge psum, not "
               "which implementation runs (collectors stay registry-"
               "dispatched).",
    ),
    AllowlistEntry(
        lint="REPRO-L002",
        path="src/repro/core/sharding.py",
        match='"near_blocks" in collect',
        reason="jit-static collect gating for the sharded near_blocks "
               "exchange payload (PR 6); registry-dispatched collector "
               "consumes the merged rows.",
    ),
    AllowlistEntry(
        lint="REPRO-L002",
        path="src/repro/core/sharding.py",
        match='"snapshot" in collect',
        reason="jit-static collect gating: the snapshot collector needs "
               "gstats in the scan carry, so the carry layout is chosen "
               "before tracing.",
    ),
    AllowlistEntry(
        lint="REPRO-L005",
        path="src/repro/core/engine.py",
        match="np.concatenate([np.asarray(c[k])",
        reason="_drive_chunks stitches per-chunk collected series on the "
               "host AFTER the jitted scan returns — one transfer per "
               "chunk is the designed device/host boundary (PR 3), not a "
               "hot-path numpy detour.",
    ),
    AllowlistEntry(
        lint="REPRO-L001",
        path="src/repro/contracts/invariants.py",
        match="tr.synth_generate(ts, gid=3)",
        reason="INV-SYNTH-DETERMINISM must materialize the same synthesized "
               "guest twice to assert bit-equality; the contract verifies "
               "the synth path rather than being on it.",
    ),
)


# --------------------------------------------------------------------------
# AST helpers
# --------------------------------------------------------------------------


def _call_name(node: ast.Call) -> str:
    """Dotted name of a call target: ``jnp.concatenate``, ``pack_traces``."""
    parts = []
    t = node.func
    while isinstance(t, ast.Attribute):
        parts.append(t.attr)
        t = t.value
    if isinstance(t, ast.Name):
        parts.append(t.id)
        return ".".join(reversed(parts))
    return ""


def _names_in(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _attrs_in(node: ast.AST) -> set[str]:
    return {n.attr for n in ast.walk(node) if isinstance(n, ast.Attribute)}


def _functions(tree: ast.AST):
    """Every (fn_node, qualname_parts) in the module, nested included."""
    out = []

    def visit(node, stack):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.append((child, stack + [child.name]))
                visit(child, stack + [child.name])
            else:
                visit(child, stack)

    visit(tree, [])
    return out


def _src(lines: list[str], lineno: int) -> str:
    return lines[lineno - 1].strip() if 0 < lineno <= len(lines) else ""


def _v(name: str, rel: str, lines: list[str], node: ast.AST, msg: str) -> Violation:
    return Violation(name, rel, node.lineno, msg, _src(lines, node.lineno))


# --------------------------------------------------------------------------
# REPRO-L001: no materialized trace arrays on the synth path
# --------------------------------------------------------------------------
_L001_BANNED_CALLS = {"guest_traces", "pack_traces", "synth_generate", "ArrayTrace"}
_L001_ALLOC = {"zeros", "full", "empty", "ones"}

_L001_FIXTURE = '''\
import numpy as np
from repro.core import engine


def _run_chunk_synth(spec, state, widx):
    # BAD: the synth path exists so this array never does
    traces = engine.guest_traces(spec, n_windows=8, accesses_per_window=64)
    buf = np.zeros((4, 8, 64), np.int32)
    return traces, buf
'''


@register_lint(
    "REPRO-L001",
    "no materialized [n_guests, n_windows, k] trace arrays on the synth "
    "path (functions named *synth*): no guest_traces/pack_traces/"
    "synth_generate/ArrayTrace calls, no rank-3 array allocation",
    _L001_FIXTURE,
    "src/repro/core/engine.py",
)
def _lint_no_materialized_trace(tree, rel, lines) -> Iterable[Violation]:
    if not rel.startswith("src/repro/"):
        return []
    out = []
    for fn, stack in _functions(tree):
        if not any("synth" in part.lower() for part in stack):
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node)
            leaf = name.rsplit(".", 1)[-1]
            if leaf in _L001_BANNED_CALLS:
                out.append(_v(
                    "REPRO-L001", rel, lines, node,
                    f"{name}() inside synth-path function "
                    f"{'.'.join(stack)} materializes a host trace array"))
            elif leaf in _L001_ALLOC and name.split(".")[0] in ("np", "jnp", "numpy"):
                shape = node.args[0] if node.args else None
                if isinstance(shape, ast.Tuple) and len(shape.elts) >= 3:
                    out.append(_v(
                        "REPRO-L001", rel, lines, node,
                        f"rank-{len(shape.elts)} {name}() allocation inside "
                        f"synth-path function {'.'.join(stack)} — the synth "
                        "path must stay O(n_local_guests * k) per window"))
    return out


# --------------------------------------------------------------------------
# REPRO-L002: no string-if dispatch outside the registries
# --------------------------------------------------------------------------
_L002_SUBJECTS = ("policy", "backend", "workload", "collect")

_L002_FIXTURE = '''\
def tick(cfg, state, policy):
    # BAD: PR 2 turned exactly this into tiering.register_policy
    if policy == "memtierd":
        return state
    elif policy == "autonuma":
        return state
    raise ValueError(policy)
'''


@register_lint(
    "REPRO-L002",
    "no string-compare policy/telemetry/workload/collector dispatch "
    "outside the registries: register and look up by name instead",
    _L002_FIXTURE,
    "src/repro/core/tiering.py",
)
def _lint_no_string_dispatch(tree, rel, lines) -> Iterable[Violation]:
    if not rel.startswith("src/repro/"):
        return []
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Compare):
            continue
        sides = [node.left] + list(node.comparators)
        subj = [
            s for s in sides
            if isinstance(s, ast.Name)
            and any(t in s.id.lower() for t in _L002_SUBJECTS)
        ]
        strs = [
            s for s in sides
            if (isinstance(s, ast.Constant) and isinstance(s.value, str))
            or (isinstance(s, (ast.Tuple, ast.List, ast.Set)) and s.elts and all(
                isinstance(e, ast.Constant) and isinstance(e.value, str)
                for e in s.elts))
        ]
        if subj and strs:
            out.append(_v(
                "REPRO-L002", rel, lines, node,
                f"string comparison against {subj[0].id!r} looks like "
                "name dispatch — use the registries (§8/§12)"))
    return out


# --------------------------------------------------------------------------
# REPRO-L003: no Python-level branching on traced values in scan bodies
# --------------------------------------------------------------------------
_L003_FIXTURE = '''\
import jax


def _run_chunk(spec, state, chunk):
    def body(st, acc):
        # BAD: `acc` is traced inside the scan; Python `if` can't see it
        if acc.sum() > 0:
            st = st + 1
        return st, acc

    return jax.lax.scan(body, state, chunk)
'''


@register_lint(
    "REPRO-L003",
    "no Python-level if/while/assert on a scan body's traced arguments "
    "(carry/xs): use lax.cond/jnp.where — idle arithmetic must be the "
    "same arithmetic",
    _L003_FIXTURE,
    "src/repro/core/engine.py",
)
def _lint_no_traced_branch_in_scan(tree, rel, lines) -> Iterable[Violation]:
    if not rel.startswith("src/repro/"):
        return []
    # map function name -> def node per enclosing scope, then find scan calls
    out = []
    for fn, stack in _functions(tree):
        local_defs = {
            child.name: child
            for child in ast.walk(fn)
            if isinstance(child, ast.FunctionDef)
        }
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if _call_name(node).rsplit(".", 1)[-1] != "scan":
                continue
            if not node.args or not isinstance(node.args[0], ast.Name):
                continue
            body_fn = local_defs.get(node.args[0].id)
            if body_fn is None:
                continue
            params = {a.arg for a in body_fn.args.args}
            for stmt in ast.walk(body_fn):
                if not isinstance(stmt, (ast.If, ast.While, ast.Assert)):
                    continue
                used = _names_in(stmt.test) & params
                if used:
                    out.append(_v(
                        "REPRO-L003", rel, lines, stmt,
                        f"Python {type(stmt).__name__.lower()} on traced "
                        f"scan-body argument(s) {sorted(used)} in "
                        f"{'.'.join(stack + [body_fn.name])}"))
    return out


# --------------------------------------------------------------------------
# REPRO-L004: no full-pool concatenate in core/
# --------------------------------------------------------------------------
_L004_FIXTURE = '''\
import jax.numpy as jnp


def consolidate(cfg, state, batch):
    near = state.near_pool.reshape(-1, cfg.base_elems)
    far = state.far_pool.reshape(-1, cfg.base_elems)
    # BAD: the seed's O(n_slots) copy PR 1 removed
    rows = jnp.concatenate([near, far], axis=0)
    return rows
'''


@register_lint(
    "REPRO-L004",
    "no full-pool jnp.concatenate in core/ (O(n_slots * hp_ratio) "
    "materialization every call): use the predicated dual-pool gather",
    _L004_FIXTURE,
    "src/repro/core/consolidator.py",
)
def _lint_no_full_pool_concat(tree, rel, lines) -> Iterable[Violation]:
    if "src/repro/core/" not in rel:
        return []
    out = []
    for fn, stack in _functions(tree):
        # one-pass taint: names assigned from expressions touching *_pool
        tainted: set[str] = set()
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Assign):
                refs = _names_in(stmt.value) | _attrs_in(stmt.value)
                if any("pool" in r for r in refs) or (refs & tainted):
                    for tgt in stmt.targets:
                        if isinstance(tgt, ast.Name):
                            tainted.add(tgt.id)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if _call_name(node) not in ("jnp.concatenate", "jnp.concat"):
                continue
            refs = set()
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                refs |= _names_in(arg) | _attrs_in(arg)
            if any("pool" in r for r in refs) or (refs & tainted):
                out.append(_v(
                    "REPRO-L004", rel, lines, node,
                    f"pool concatenate in core function {'.'.join(stack)} "
                    "materializes the full slot space"))
    return out


# --------------------------------------------------------------------------
# REPRO-L005: no direct numpy on the engine hot path
# --------------------------------------------------------------------------
_L005_FILES = ("src/repro/core/engine.py", "src/repro/core/sharding.py")
_L005_HOT = ("_window", "_churn_window", "_step_impl")

_L005_FIXTURE = '''\
import numpy as np


def _window(spec, state, accesses):
    # BAD: numpy executes at trace time on host data, breaking the jit
    hist = np.bincount(accesses, minlength=spec.cfg.n_logical)
    return state, hist
'''


@register_lint(
    "REPRO-L005",
    "no direct numpy calls inside the engine hot-path functions (_window/"
    "_churn_window/_step_impl and scan chunk bodies): traced code must "
    "stay jnp/lax",
    _L005_FIXTURE,
    "src/repro/core/engine.py",
)
def _lint_no_numpy_hot_path(tree, rel, lines) -> Iterable[Violation]:
    if rel not in _L005_FILES:
        return []
    out = []
    for fn, stack in _functions(tree):
        hot = (
            stack[0] in _L005_HOT
            or "_chunk" in stack[0]
            or any(part == "body" for part in stack)
        )
        if not hot:
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node)
            if name.split(".")[0] in ("np", "numpy"):
                out.append(_v(
                    "REPRO-L005", rel, lines, node,
                    f"numpy call {name}() inside hot-path function "
                    f"{'.'.join(stack)}"))
    return out


# --------------------------------------------------------------------------
# REPRO-L006: no raw kernel.py imports outside the kernels subpackage
# --------------------------------------------------------------------------
_L006_FIXTURE = '''\
from repro.kernels.hotness_scan import kernel as _k


def hot_subpages_per_hp(cfg, state, hot):
    # BAD: core code must dispatch through repro.kernels.registry, never
    # import a raw Pallas kernel module directly
    return _k.hot_count(hot, cfg.hp_ratio, interpret=True)
'''


@register_lint(
    "REPRO-L006",
    "no direct repro.kernels.*.kernel imports outside the kernels "
    "subpackage: core code dispatches through repro.kernels.registry "
    "(the ops.py wrappers own the raw kernels)",
    _L006_FIXTURE,
    "src/repro/core/telemetry.py",
)
def _lint_no_raw_kernel_import(tree, rel, lines) -> Iterable[Violation]:
    if not rel.startswith("src/repro/") or rel.startswith("src/repro/kernels/"):
        return []
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            bad = (
                mod.startswith("repro.kernels.") and (
                    mod.endswith(".kernel")
                    or any(a.name == "kernel" for a in node.names)
                )
            )
        elif isinstance(node, ast.Import):
            bad = any(
                a.name.startswith("repro.kernels.") and a.name.endswith(".kernel")
                for a in node.names
            )
        else:
            continue
        if bad:
            out.append(_v(
                "REPRO-L006", rel, lines, node,
                "raw Pallas kernel module imported outside repro.kernels — "
                "dispatch through repro.kernels.registry instead"))
    return out


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------


def lint_file(path: Path, root: Path, lints=None) -> list[Violation]:
    rel = path.relative_to(root).as_posix()
    source = path.read_text()
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        return [Violation("SYNTAX", rel, e.lineno or 0, str(e))]
    lines = source.splitlines()
    out: list[Violation] = []
    for lint in lints or all_lints():
        out.extend(lint.fn(tree, rel, lines))
    return out


def default_targets(root: Path) -> list[Path]:
    """The linted set: everything under src/repro/."""
    return sorted((root / "src" / "repro").rglob("*.py"))


def apply_allowlist(
    violations: list[Violation],
    allowlist: tuple[AllowlistEntry, ...] = ALLOWLIST,
) -> tuple[list[Violation], list[AllowlistEntry]]:
    """Returns (kept violations, UNUSED allowlist entries). Both must be
    empty for a clean run: stale allowlist entries are drift too."""
    used: set[int] = set()
    kept = []
    for v in violations:
        hit = None
        for i, e in enumerate(allowlist):
            if e.lint == v.lint and e.path == v.path and e.match in v.source_line:
                hit = i
                break
        if hit is None:
            kept.append(v)
        else:
            used.add(hit)
    unused = [e for i, e in enumerate(allowlist) if i not in used]
    return kept, unused


def run(root: Path, files: list[Path] | None = None):
    """Lint ``files`` (default: src/repro/**) against the allowlist.

    Returns ``(violations, unused_allowlist_entries)``.
    """
    files = files if files is not None else default_targets(root)
    violations: list[Violation] = []
    for f in files:
        violations.extend(lint_file(f, root))
    return apply_allowlist(violations)


def self_test(tmp_root: Path) -> list[str]:
    """Write every lint's seeded violation fixture under ``tmp_root`` and
    verify the lint fires on it. Returns a list of failure messages."""
    failures = []
    for lint in all_lints():
        target = tmp_root / lint.fixture_path
        target.parent.mkdir(parents=True, exist_ok=True)
        target.write_text(lint.fixture)
        hits = [
            v for v in lint_file(target, tmp_root, lints=[lint])
            if v.lint == lint.name
        ]
        if not hits:
            failures.append(
                f"{lint.name}: seeded violation fixture at "
                f"{lint.fixture_path} did not trip the lint")
        target.unlink()
    return failures
