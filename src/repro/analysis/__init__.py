"""Repo-specific static analysis (DESIGN.md §15): AST lints + allowlist."""
from repro.analysis.lint import (  # noqa: F401
    ALLOWLIST,
    AllowlistEntry,
    Lint,
    Violation,
    all_lints,
    get_lint,
    lint_names,
    register_lint,
    run,
    self_test,
)
