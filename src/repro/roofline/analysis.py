"""Roofline analysis over the dry-run artifacts (system prompt deliverable g).

Per (arch x shape x mesh) cell, three per-device time lower bounds:

    compute term    = HLO_FLOPs_per_device            / 197e12  FLOP/s (bf16)
    memory term     = HLO_bytes_per_device            / 819e9   B/s (HBM)
    collective term = collective_bytes_per_device     / 50e9    B/s (ICI link)

Sources & corrections (all recorded per cell):
  * ``compiled.cost_analysis()`` is **per-device** under SPMD (verified
    empirically) and counts a scan body ONCE -- the dry-run therefore unrolls
    every structural scan (layers, CE chunks, attention q-chunks). Two
    corrections remain:
      - gradient-accumulation: flops/bytes inside the microbatch scan are
        multiplied by ``micro_batches`` (collective grad-reduce sits outside
        the scan and is counted once, correctly);
      - mixer time-scans (mamba chunk scan, xLSTM step scan) cannot be
        unrolled; their per-trip body cost is added analytically
        (``time_scan_correction``).
  * collective bytes are parsed from the optimized post-SPMD HLO; per op kind
    the ring-transfer factor is applied (all-gather/reduce-scatter move
    (n-1)/n of the result bytes per device; all-reduce 2(n-1)/n; all-to-all
    and collective-permute (n-1)/n and 1x respectively).
  * MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) for training cells;
    2*N*D_new (+ attention KV reads) for decode. The ratio
    MODEL_FLOPS / HLO_FLOPs_global flags remat/redundancy waste.
"""
from __future__ import annotations

import json
import os

from repro import configs as config_lib
from repro.configs.base import SHAPE_SPECS

PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip (TPU v5e)
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link

RING = {  # effective bytes-on-link per result byte, ring algorithms
    "all-gather": 1.0,  # (n-1)/n ~ 1
    "all-reduce": 2.0,  # reduce-scatter + all-gather
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


# ---------------------------------------------------------------------------
# analytic model FLOPs
# ---------------------------------------------------------------------------
def model_flops(arch: str, shape_name: str) -> float:
    """6*N_active*D for train; 2*N_active per generated token for decode;
    2*N_active*D for prefill. Attention's quadratic term is excluded by
    convention (it is what the ratio column exposes)."""
    cfg = config_lib.get(arch)
    spec = SHAPE_SPECS[shape_name]
    n = cfg.active_param_count()
    if spec["kind"] == "train":
        d = spec["global_batch"] * spec["seq_len"]
        return 6.0 * n * d
    if spec["kind"] == "prefill":
        d = spec["global_batch"] * spec["seq_len"]
        return 2.0 * n * d
    # decode: one token per sequence
    return 2.0 * n * spec["global_batch"]


def time_scan_correction(arch: str, shape_name: str) -> float:
    """Global FLOPs hidden inside non-unrollable mixer time-scans:
    (trips - 1) x analytic per-trip body cost x (1 fwd + 2 bwd [+1 remat])."""
    cfg = config_lib.get(arch)
    spec = SHAPE_SPECS[shape_name]
    if spec["kind"] == "decode":
        return 0.0  # decode does exactly one time step (counted)
    B, S = spec["global_batch"], spec["seq_len"]
    grad_mult = 4.0 if spec["kind"] == "train" else 1.0  # fwd+bwd+remat
    total = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if kind == "mamba":
            di = cfg.ssm_expand * cfg.d_model
            ds = cfg.ssm_state
            chunk = 16
            trips = -(-S // chunk)
            body = 10.0 * B * chunk * di * ds  # recurrence arithmetic
            total += (trips - 1) * body * grad_mult
        elif kind == "mlstm":
            di = 2 * cfg.d_model
            hd = di // cfg.n_heads
            body = 7.0 * B * cfg.n_heads * hd * hd  # outer products + Cq
            total += (S - 1) * body * grad_mult
        elif kind == "slstm":
            di = 2 * cfg.d_model
            body = 30.0 * B * di  # elementwise gates
            total += (S - 1) * body * grad_mult
    return total


# ---------------------------------------------------------------------------
# per-cell roofline
# ---------------------------------------------------------------------------
def micro_batches_of(arch: str, shape_name: str) -> int:
    from repro.launch.dryrun import TRAIN_RECIPE

    if SHAPE_SPECS[shape_name]["kind"] != "train":
        return 1
    return TRAIN_RECIPE.get(arch, {"micro_batches": 1})["micro_batches"]


def analyze_cell(record: dict) -> dict:
    """Dry-run JSON record -> roofline terms (seconds) + diagnosis."""
    arch, shape_name = record["arch"], record["shape"]
    n_dev = record["n_devices"]
    micro = micro_batches_of(arch, shape_name)
    cost = record.get("cost_analysis", {})
    flops_dev = cost.get("flops", 0.0) * micro
    bytes_dev = cost.get("bytes accessed", 0.0) * micro
    flops_dev += time_scan_correction(arch, shape_name) / n_dev

    coll = record.get("collectives", {}).get("bytes", {})
    coll_bytes_dev = sum(RING[k] * v for k, v in coll.items())
    # collectives inside the microbatch scan body are counted once; the grad
    # all-reduce dominates and is outside, so no micro multiplier (documented)

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_bytes_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(arch, shape_name)
    hlo_global = flops_dev * n_dev
    return dict(
        arch=arch, shape=shape_name, mesh=record["mesh"], n_devices=n_dev,
        micro_batches=micro,
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        collective_bytes_per_device=coll_bytes_dev,
        collective_detail=coll,
        t_compute_s=t_compute, t_memory_s=t_memory, t_collective_s=t_coll,
        dominant=dominant,
        step_lower_bound_s=bound,
        model_flops_global=mf,
        useful_flops_ratio=(mf / hlo_global) if hlo_global else 0.0,
        roofline_fraction=(t_compute / bound) if bound else 0.0,
        memory_analysis=record.get("memory_analysis", {}),
    )


def load_records(dryrun_dir: str = "experiments/dryrun") -> list:
    out = []
    for f in sorted(os.listdir(dryrun_dir)):
        if f.endswith(".json"):
            with open(os.path.join(dryrun_dir, f)) as fh:
                out.append(json.load(fh))
    return out


def table(dryrun_dir: str = "experiments/dryrun", mesh: str = "single") -> list:
    rows = []
    for rec in load_records(dryrun_dir):
        if rec.get("status") == "ok" and rec.get("mesh") == mesh:
            rows.append(analyze_cell(rec))
    return rows


def format_markdown(rows: list) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| 6ND/HLO | roofline frac |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4g} "
            f"| {r['t_memory_s']:.4g} | {r['t_collective_s']:.4g} "
            f"| {r['dominant']} | {r['useful_flops_ratio']:.3f} "
            f"| {r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    print(format_markdown(table(args.dir, args.mesh)))


if __name__ == "__main__":
    main()
