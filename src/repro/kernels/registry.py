"""The kernel-backend registry (DESIGN.md §4/§16).

The eighth string-keyed registry (§8): one table of :class:`KernelSpec`
entries, each naming a Pallas implementation, the pure-jnp reference it is
pinned against, and (where one exists) an independent numpy oracle for
tests plus a nullary ``example`` for the micro-benchmark suite. Mirrors the
PR-2 registry idiom: duplicates raise, unknown names raise listing the live
set.

Dispatch discipline: core modules never compare backend strings themselves
(that is REPRO-L002 territory) — they pass the engine's ``kernel_backend``
knob down to :func:`dispatch`, which resolves the tri-state here:

* ``"xla"``    — run the jnp reference (the pre-registry engine path).
* ``"pallas"`` — run the Pallas kernel; interpret mode off-TPU
  (``runtime.interpret()``), native lowering on TPU.
* ``"auto"``   — honor ``REPRO_KERNEL_BACKEND`` if set (read once at import
  so jit caches cannot go stale mid-process), else Pallas on TPU and the
  reference elsewhere — interpretation is slower than XLA on CPU, and the
  two are bit-identical (INV-KERNEL-BACKEND-EXACT).
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Callable

from repro.kernels import runtime

BACKENDS = ("xla", "pallas", "auto")

# Read once at import: the resolved backend is baked into jit cache keys via
# EngineSpec, so a mid-process env flip must not silently change dispatch.
_ENV_BACKEND = os.environ.get("REPRO_KERNEL_BACKEND", "")


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One registered kernel: the Pallas impl, its jnp reference, and the
    test/bench metadata.

    ``pallas`` must accept an ``interpret=`` keyword (forwarded from
    ``runtime.interpret()``); ``ref`` is the pure-jnp function the engine ran
    before the registry existed and stays the ``"xla"`` backend verbatim.
    ``oracle`` (optional) is an independent numpy implementation for tests;
    ``example`` (optional) is a nullary callable returning ``(args, kwargs)``
    for generic micro-benchmarks (``benchmarks/bench_kernels.py``).
    """

    name: str
    pallas: Callable
    ref: Callable
    oracle: Callable | None = None
    example: Callable | None = None
    description: str = ""


_KERNELS: dict[str, KernelSpec] = {}


def register_kernel(
    name: str,
    pallas: Callable,
    ref: Callable,
    *,
    oracle: Callable | None = None,
    example: Callable | None = None,
    description: str = "",
) -> KernelSpec:
    """Register a kernel under a unique name; duplicates raise."""
    if name in _KERNELS:
        raise ValueError(f"kernel {name!r} already registered")
    spec = KernelSpec(
        name=name, pallas=pallas, ref=ref, oracle=oracle,
        example=example, description=description,
    )
    _KERNELS[name] = spec
    return spec


def get_kernel(name: str) -> KernelSpec:
    try:
        return _KERNELS[name]
    except KeyError:
        raise ValueError(
            f"unknown kernel {name!r} (have {kernel_names()})"
        ) from None


def kernel_names() -> tuple[str, ...]:
    """Names of all registered kernels, sorted for stable listings."""
    return tuple(sorted(_KERNELS))


def all_kernels() -> tuple[KernelSpec, ...]:
    return tuple(_KERNELS[n] for n in kernel_names())


def resolve_backend(choice: str = "auto") -> str:
    """Resolve a backend knob to a concrete ``"xla"`` or ``"pallas"``."""
    if choice not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {choice!r} (have {BACKENDS})"
        )
    if choice != "auto":
        return choice
    env = _ENV_BACKEND
    if env:
        if env not in BACKENDS or env == "auto":
            raise ValueError(
                f"REPRO_KERNEL_BACKEND={env!r} must be 'xla' or 'pallas'"
            )
        return env
    return "pallas" if runtime.on_tpu() else "xla"


def dispatch(name: str, choice: str, *args, **kwargs):
    """Run the named kernel on the resolved backend.

    This is the only place backend strings are compared; core modules thread
    the engine's ``kernel_backend`` knob here untouched. Called inside jit:
    the branch is a trace-time python decision, so each resolved backend gets
    its own cached executable (the knob rides EngineSpec, a static argument).
    """
    spec = get_kernel(name)
    resolved = resolve_backend(choice)
    if resolved == "pallas":
        return spec.pallas(*args, interpret=runtime.interpret(), **kwargs)
    return spec.ref(*args, **kwargs)


_UNSET = object()  # sentinel: distinguishes "not passed" from use_pallas=None


def backend_from_use_pallas(use_pallas, *, stacklevel: int = 3) -> str:
    """Map the deprecated ``use_pallas`` tri-state onto a backend name.

    Emits ``DeprecationWarning`` at python call time (the public wrappers
    resolve the shim before entering jit, so the warning always fires).
    """
    warnings.warn(
        "use_pallas= is deprecated; pass kernel_backend='xla'|'pallas'|"
        "'auto' instead (see repro.kernels.registry)",
        DeprecationWarning, stacklevel=stacklevel,
    )
    if use_pallas is None:
        return "auto"
    return "pallas" if use_pallas else "xla"
