"""Pallas TPU kernels for the compute hot spots (DESIGN.md §4/§16).

Each subpackage ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jitted
wrapper + ``registry.register_kernel`` entry) and ref.py (the pure-jnp
reference the ``"xla"`` backend runs); tests sweep shapes/dtypes in
interpret mode against the oracle. Importing this package populates the
kernel registry — core modules dispatch by name through
``repro.kernels.registry`` and never import a ``kernel.py`` directly
(lint REPRO-L006).
"""
# runtime/registry first: the subpackage ops modules import them while this
# package is still initializing
from repro.kernels import runtime  # noqa: F401
from repro.kernels import registry  # noqa: F401
from repro.kernels import (  # noqa: F401  (registration side effects)
    consolidate,
    flash_attention,
    histogram,
    hotness_scan,
    paged_attention,
    tiered_lookup,
    topk,
)
