"""Pallas TPU kernels for the compute hot spots (DESIGN.md §4).

Each subpackage ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jitted
wrapper with backend dispatch) and ref.py (pure-jnp oracle); tests sweep
shapes/dtypes in interpret mode against the oracle.
"""
from repro.kernels import runtime  # noqa: F401
