"""Backend dispatch shared by every kernel wrapper.

On TPU the Pallas kernels compile natively; on CPU (this container) they run
in ``interpret=True`` mode for correctness tests, while the default production
path on non-TPU backends is the pure-jnp reference (faster than interpretation
and numerically identical -- the tests enforce that).
"""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interpret() -> bool:
    """Pallas interpret mode everywhere except real TPU."""
    return not on_tpu()
