"""Pallas TPU kernel: weighted bincount (the per-window access histogram).

Both engine histograms are this one primitive: the per-window access
histogram (unit weights over flattened logical ids,
``address_space.access_histogram``) and the huge-page roll-up (guest hit
counts summed by ``gpt // hp_ratio``, ``address_space.host_histogram``).
XLA lowers them as serialized scatter-adds over the id stream; here the
histogram is computed bin-major instead: the grid tiles the bin axis, each
step streams the full id/weight vectors through VMEM in ``chunk``-sized
slabs and reduces a one-hot match ``(ids == bins) * w`` over the chunk. That
turns a data-dependent scatter into dense VREG compares + integer adds —
the shape Pallas pipelines well — at ``O(n_ids * n_bins)`` work, which is
the right trade at the engine's bin counts (thousands) where the scatter's
serialization dominates.

Bit-exactness: each id matches at most one bin and int32 addition is
associative/commutative mod 2^32, so any accumulation order equals the
scatter-add result exactly. Ids must be pre-wrapped/pre-masked by the ops
wrapper; ids outside ``[0, n_bins)`` (e.g. the ``-1`` chunk padding) match
no bin and drop out, mirroring XLA's drop semantics.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bincount_kernel(ids_ref, w_ref, o_ref, *, blk: int, chunk: int):
    base = pl.program_id(0) * blk
    bins = base + jax.lax.broadcasted_iota(jnp.int32, (1, blk), 1)
    n_chunks = ids_ref.shape[1] // chunk

    def body(c, acc):
        ids = jax.lax.dynamic_slice(ids_ref[...], (0, c * chunk), (1, chunk))
        w = jax.lax.dynamic_slice(w_ref[...], (0, c * chunk), (1, chunk))
        hit = (ids.reshape(chunk, 1) == bins) * w.reshape(chunk, 1)
        return acc + hit.sum(axis=0, dtype=jnp.int32).reshape(1, blk)

    o_ref[...] = jax.lax.fori_loop(
        0, n_chunks, body, jnp.zeros((1, blk), jnp.int32))


def bincount(
    ids: jax.Array,      # int32[k] bin id per sample; OOB ids are dropped
    weights: jax.Array,  # int32[k] weight per sample
    n_bins: int,
    blk: int = 128,
    chunk: int = 512,
    *,
    interpret: bool = False,
) -> jax.Array:
    """int32[n_bins]: sum of ``weights`` per bin (OOB ids contribute nothing)."""
    k = ids.shape[0]
    ids = ids.astype(jnp.int32)
    weights = weights.astype(jnp.int32)
    pad_k = (-k) % chunk
    if pad_k:
        # -1 never matches a bin in [0, n_bins), so padding is weightless
        ids = jnp.pad(ids, (0, pad_k), constant_values=-1)
        weights = jnp.pad(weights, (0, pad_k))
    pad_b = (-n_bins) % blk
    out = pl.pallas_call(
        partial(_bincount_kernel, blk=blk, chunk=chunk),
        grid=((n_bins + pad_b) // blk,),
        in_specs=[
            pl.BlockSpec((1, k + pad_k), lambda i: (0, 0)),
            pl.BlockSpec((1, k + pad_k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_bins + pad_b), jnp.int32),
        interpret=interpret,
    )(ids.reshape(1, -1), weights.reshape(1, -1))
    return out[0, :n_bins]
