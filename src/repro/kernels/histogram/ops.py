"""Jitted wrapper + registry entry for the weighted bincount kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import registry, runtime  # noqa: F401  (runtime re-export)
from repro.kernels.histogram import kernel as _k
from repro.kernels.histogram import ref as _ref


def _bincount_pallas(
    ids: jax.Array, weights: jax.Array, n_bins: int, *, interpret: bool = False
) -> jax.Array:
    """Kernel entry with the reference's indexing semantics.

    XLA's ``.at[ids].add`` wraps negative ids numpy-style (once) and drops
    anything still out of range; mirror that here so the two backends agree
    bit-for-bit on any input, not just the engine's in-range ids.
    """
    flat = ids.reshape(-1).astype(jnp.int32)
    flat = jnp.where(flat < 0, flat + n_bins, flat)
    return _k.bincount(
        flat, weights.reshape(-1), n_bins, interpret=interpret)


def _oracle(ids, weights, n_bins):
    import numpy as np

    out = np.zeros(n_bins, np.int64)
    for i, w in zip(np.asarray(ids).reshape(-1), np.asarray(weights).reshape(-1)):
        i = i + n_bins if i < 0 else i
        if 0 <= i < n_bins:
            out[i] += int(w)
    return out.astype(np.int32)


def _example():
    import numpy as np

    rng = np.random.default_rng(0)
    n_bins = 4096
    ids = rng.integers(0, n_bins, size=16384).astype(np.int32)
    w = rng.integers(0, 8, size=16384).astype(np.int32)
    return (jnp.asarray(ids), jnp.asarray(w), n_bins), {}


registry.register_kernel(
    "bincount", pallas=_bincount_pallas, ref=_ref.bincount_ref,
    oracle=_oracle, example=_example,
    description="weighted bincount (per-window access/host histograms)",
)


@partial(jax.jit, static_argnames=("n_bins", "kernel_backend"))
def bincount(
    ids: jax.Array,
    weights: jax.Array,
    n_bins: int,
    *,
    kernel_backend: str = "auto",
) -> jax.Array:
    """int32[n_bins] weighted histogram of ``ids`` (XLA scatter-add semantics)."""
    return registry.dispatch("bincount", kernel_backend, ids, weights, n_bins)
