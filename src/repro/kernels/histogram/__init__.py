from repro.kernels.histogram.ops import bincount  # noqa: F401
