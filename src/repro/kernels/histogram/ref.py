"""Pure-jnp oracle for the weighted bincount: XLA's scatter-add.

This is verbatim what ``address_space.access_histogram`` /
``host_histogram`` lowered to before the kernel registry, so the ``"xla"``
backend stays the pre-registry engine path bit-for-bit: negative ids wrap
numpy-style (``.at[]`` semantics), ids ``>= n_bins`` are dropped.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bincount_ref(ids: jax.Array, weights: jax.Array, n_bins: int) -> jax.Array:
    return jnp.zeros((n_bins,), jnp.int32).at[ids].add(
        weights.astype(jnp.int32))
