"""Pure-jnp oracle for the consolidation copy kernel (Algorithm 1's memcpy)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def consolidate_region_ref(src_rows: jax.Array, ids: jax.Array) -> jax.Array:
    """Gather ``src_rows[ids]`` into a dense region; ids < 0 produce zeros."""
    valid = ids >= 0
    safe = jnp.where(valid, ids, 0)
    out = src_rows[safe]
    return jnp.where(valid[:, None], out, 0).astype(src_rows.dtype)


def scatter_region_ref(
    dst_rows: jax.Array, region: jax.Array, ids: jax.Array
) -> jax.Array:
    """Scatter region rows back to ``dst_rows[ids]``; ids < 0 are dropped."""
    n = dst_rows.shape[0]
    idx = jnp.where(ids >= 0, ids, n)
    return dst_rows.at[idx].set(region, mode="drop")
