"""Pallas TPU kernel for Algorithm 1's data copy: gather scattered base pages
into one dense huge-page region.

TPU adaptation (DESIGN.md §2): the paper's per-page ``memcpy`` loop becomes a
scalar-prefetched grid -- the source page index feeds the *index map*, so the
DMA engine streams each scattered page HBM->VMEM->HBM while the next page's
descriptor is already formed (double-buffered by the Pallas pipeline). The
block is one base page: ``(1, base_elems)`` with ``base_elems`` a multiple of
128 lanes in production (a 4 KB page of f32 = 1024 elems = 8 x 128, exactly
one VREG tile per sublane group).

Grid: ``(hp_ratio,)`` -- one step per destination slot of the huge region.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _copy_kernel(ids_ref, src_ref, out_ref):
    # src block was selected by the index map; plain VMEM->VMEM move here.
    out_ref[...] = src_ref[...]


def consolidate_gather(
    src_rows: jax.Array,  # (n_rows, base_elems) flat [near;far] row space
    ids: jax.Array,  # int32 (hp_ratio,) source row per region slot (clamped)
    *,
    interpret: bool = False,
) -> jax.Array:
    """Return dtype[hp_ratio, base_elems]: the dense region payload.

    ``ids`` must be pre-clamped to [0, n_rows); masking of padded slots is the
    wrapper's job (ops.consolidate_region), keeping the kernel branch-free.
    """
    hp_ratio = ids.shape[0]
    base_elems = src_rows.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(hp_ratio,),
        in_specs=[
            pl.BlockSpec((1, base_elems), lambda i, ids_ref: (ids_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, base_elems), lambda i, ids_ref: (i, 0)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((hp_ratio, base_elems), src_rows.dtype),
        interpret=interpret,
    )(ids, src_rows)


def consolidate_scatter(
    dst_rows: jax.Array,  # (n_rows, base_elems) flat row space to update
    region: jax.Array,  # (hp_ratio, base_elems) dense payload
    ids: jax.Array,  # int32 (hp_ratio,) destination row per region slot
    *,
    interpret: bool = False,
) -> jax.Array:
    """Scatter a dense region's rows back out to ``ids`` (the reverse move,
    used when a consolidated region is broken up again). Input/output aliased
    so the update is in-place on TPU."""
    hp_ratio, base_elems = region.shape

    def kernel(ids_ref, region_ref, dst_ref, out_ref):
        out_ref[...] = region_ref[...]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(hp_ratio,),
        in_specs=[
            pl.BlockSpec((1, base_elems), lambda i, ids_ref: (i, 0)),
            pl.BlockSpec((1, base_elems), lambda i, ids_ref: (ids_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, base_elems), lambda i, ids_ref: (ids_ref[i], 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_rows.shape, dst_rows.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(ids, region, dst_rows)
