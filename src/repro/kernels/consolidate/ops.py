"""Jitted wrappers over the consolidation-copy Pallas kernel.

The wrapper owns masking semantics (padded ids produce zero rows / dropped
writes) so the kernel stays branch-free; on non-TPU backends it runs the
kernel in interpret mode, on TPU it compiles to a scalar-prefetched DMA
pipeline (see kernel.py docstring).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from repro.kernels.consolidate import kernel as _k
from repro.kernels.consolidate import ref as _ref


@partial(jax.jit, static_argnames=("use_pallas",))
def consolidate_region(
    src_rows: jax.Array,  # (n_rows, base_elems)
    ids: jax.Array,  # int32 (hp_ratio,) source row per region slot, -1 padded
    use_pallas: bool | None = None,
) -> jax.Array:
    """dtype[hp_ratio, base_elems]: dense region payload, zeros at padded slots."""
    if runtime.pick(use_pallas):
        valid = ids >= 0
        clamped = jnp.where(valid, ids, 0).astype(jnp.int32)
        out = _k.consolidate_gather(
            src_rows, clamped, interpret=runtime.interpret()
        )
        return jnp.where(valid[:, None], out, 0)
    return _ref.consolidate_region_ref(src_rows, ids)


@partial(jax.jit, static_argnames=("use_pallas",))
def scatter_region(
    dst_rows: jax.Array,
    region: jax.Array,
    ids: jax.Array,
    use_pallas: bool | None = None,
) -> jax.Array:
    """Write region rows to ``dst_rows[ids]`` (ids -1 dropped)."""
    if runtime.pick(use_pallas):
        valid = ids >= 0
        # Padded slots are redirected to row 0 carrying row 0's original data.
        # Sorting padded-first makes any *real* write to row 0 land last in
        # the sequential grid, so it wins (writer order = grid order).
        order = jnp.argsort(valid)
        clamped = jnp.where(valid, ids, 0).astype(jnp.int32)[order]
        keep = dst_rows[0]
        payload = jnp.where(valid[order][:, None], region[order], keep)
        return _k.consolidate_scatter(
            dst_rows, payload, clamped, interpret=runtime.interpret()
        )
    return _ref.scatter_region_ref(dst_rows, region, ids)
