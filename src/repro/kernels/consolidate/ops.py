"""Jitted wrappers + registry entries for the consolidation-copy kernels.

The pallas entries own the masking semantics (padded ids produce zero rows /
dropped writes) so the kernels stay branch-free; the refs are the pure-jnp
gather/scatter the engine ran before the registry existed.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.consolidate import kernel as _k
from repro.kernels.consolidate import ref as _ref


def _consolidate_region_pallas(
    src_rows: jax.Array, ids: jax.Array, *, interpret: bool = False
) -> jax.Array:
    valid = ids >= 0
    clamped = jnp.where(valid, ids, 0).astype(jnp.int32)
    out = _k.consolidate_gather(src_rows, clamped, interpret=interpret)
    return jnp.where(valid[:, None], out, 0)


def _scatter_region_pallas(
    dst_rows: jax.Array, region: jax.Array, ids: jax.Array,
    *, interpret: bool = False,
) -> jax.Array:
    valid = ids >= 0
    # Padded slots are redirected to row 0 carrying row 0's original data.
    # Sorting padded-first makes any *real* write to row 0 land last in
    # the sequential grid, so it wins (writer order = grid order).
    order = jnp.argsort(valid)
    clamped = jnp.where(valid, ids, 0).astype(jnp.int32)[order]
    keep = dst_rows[0]
    payload = jnp.where(valid[order][:, None], region[order], keep)
    return _k.consolidate_scatter(dst_rows, payload, clamped,
                                  interpret=interpret)


def _region_oracle(src_rows, ids):
    import numpy as np

    src, ids = np.asarray(src_rows), np.asarray(ids)
    out = np.zeros((ids.shape[0], src.shape[1]), src.dtype)
    for slot, i in enumerate(ids):
        if i >= 0:
            out[slot] = src[i]
    return out


def _scatter_oracle(dst_rows, region, ids):
    import numpy as np

    out = np.asarray(dst_rows).copy()
    for slot, i in enumerate(np.asarray(ids)):
        if 0 <= i < out.shape[0]:
            out[i] = np.asarray(region)[slot]
    return out


def _region_example():
    import numpy as np

    rng = np.random.default_rng(0)
    src = rng.standard_normal((8192, 8)).astype(np.float32)
    ids = rng.integers(-1, 8192, size=512).astype(np.int32)
    return (jnp.asarray(src), jnp.asarray(ids)), {}


def _scatter_example():
    import numpy as np

    rng = np.random.default_rng(0)
    dst = rng.standard_normal((8192, 8)).astype(np.float32)
    region = rng.standard_normal((512, 8)).astype(np.float32)
    ids = rng.permutation(8192)[:512].astype(np.int32)
    return (jnp.asarray(dst), jnp.asarray(region), jnp.asarray(ids)), {}


registry.register_kernel(
    "consolidate_region", pallas=_consolidate_region_pallas,
    ref=_ref.consolidate_region_ref,
    oracle=_region_oracle, example=_region_example,
    description="dense region gather for Algorithm-1 consolidation",
)
registry.register_kernel(
    "scatter_region", pallas=_scatter_region_pallas,
    ref=_ref.scatter_region_ref,
    oracle=_scatter_oracle, example=_scatter_example,
    description="region write-back scatter (padded ids dropped)",
)


def consolidate_region(
    src_rows: jax.Array,  # (n_rows, base_elems)
    ids: jax.Array,  # int32 (hp_ratio,) source row per region slot, -1 padded
    use_pallas=registry._UNSET,
    *,
    kernel_backend: str = "auto",
) -> jax.Array:
    """dtype[hp_ratio, base_elems]: dense region payload, zeros at padded slots."""
    if use_pallas is not registry._UNSET:
        kernel_backend = registry.backend_from_use_pallas(use_pallas)
    return _consolidate_region(src_rows, ids, kernel_backend)


@partial(jax.jit, static_argnames=("kernel_backend",))
def _consolidate_region(src_rows, ids, kernel_backend):
    return registry.dispatch(
        "consolidate_region", kernel_backend, src_rows, ids)


def scatter_region(
    dst_rows: jax.Array,
    region: jax.Array,
    ids: jax.Array,
    use_pallas=registry._UNSET,
    *,
    kernel_backend: str = "auto",
) -> jax.Array:
    """Write region rows to ``dst_rows[ids]`` (ids -1 dropped)."""
    if use_pallas is not registry._UNSET:
        kernel_backend = registry.backend_from_use_pallas(use_pallas)
    return _scatter_region(dst_rows, region, ids, kernel_backend)


@partial(jax.jit, static_argnames=("kernel_backend",))
def _scatter_region(dst_rows, region, ids, kernel_backend):
    return registry.dispatch(
        "scatter_region", kernel_backend, dst_rows, region, ids)
