from repro.kernels.consolidate.ops import consolidate_region, scatter_region  # noqa: F401
