"""Pure-jnp oracle for causal flash attention (GQA-folded layout)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,  # (BH, Sq, hd) with Sq = G * S
    k: jax.Array,  # (BH, S, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    group: int = 1,
    scale: float | None = None,
) -> jax.Array:
    hd = q.shape[-1]
    scale = (hd ** -0.5) if scale is None else scale
    s = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        q_pos = jnp.arange(q.shape[1])[:, None] // group
        k_pos = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(k_pos <= q_pos, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
