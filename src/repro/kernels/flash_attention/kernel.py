"""Pallas TPU kernel: causal flash attention (training / prefill hot path).

Standard online-softmax tiling adapted for the MXU: ``(block_q, hd)`` query
tiles resident in VMEM while ``(block_k, hd)`` K/V tiles stream; the score
tile ``(block_q, block_k)`` hits the MXU twice per step (QK^T and PV). Blocks
default to 128 to match the 128x128 systolic array; f32 accumulation.

Causal handling: K-blocks entirely above the diagonal are masked to -inf and
contribute nothing. (A grid-skip via index rewriting is the classic further
optimization; masked blocks still cost MXU cycles. Recorded as a §Perf
candidate rather than done here -- correctness first.)

GQA: the wrapper folds the query-head group into the q rows, so K/V are never
materialized per-query-head: q (B, KVH, G*S, hd) against k (B, KVH, S, hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, block_q, hd)
    k_ref,  # (1, block_k, hd)
    v_ref,  # (1, block_k, hd)
    o_ref,  # (1, block_q, hd)
    m_ref,  # scratch (block_q, 1) f32
    l_ref,  # scratch (block_q, 1) f32
    acc_ref,  # scratch (block_q, hd) f32
    *,
    scale: float,
    block_q: int,
    block_k: int,
    n_k_blocks: int,
    causal: bool,
    group: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (block_q, block_k)

    if causal:
        # q rows are G interleaved copies of the sequence: logical position
        # of row r is (qi*block_q + r) // group.
        rows = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0
        )
        q_pos = rows // group
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(s - m_new)
    if causal:
        pexp = jnp.where(s <= NEG_INF / 2, 0.0, pexp)
    l_ref[...] = l_ref[...] * alpha + pexp.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        pexp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == n_k_blocks - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (BH, Sq, hd)  -- Sq = G * S for GQA-folded queries
    k: jax.Array,  # (BH, Sk, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    group: int = 1,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, "pad seq to block multiple"
    scale = (hd ** -0.5) if scale is None else scale
    n_k_blocks = Sk // block_k

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        n_k_blocks=n_k_blocks,
        causal=causal,
        group=group,
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, Sq // block_q, n_k_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
