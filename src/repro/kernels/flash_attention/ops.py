"""Jitted wrapper: standard GQA (B, H, S, hd) -> folded flash attention.

The GQA fold maps query head ``kvh*G+g`` at position ``s`` to folded row
``s*G+g`` of batch-slab ``b*KVH+kvh`` -- K/V stay one copy per kv head (no
head broadcast in HBM), which is the point of GQA.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from einops import rearrange

from repro.kernels import runtime
from repro.kernels.flash_attention import kernel as _k
from repro.kernels.flash_attention import ref as _ref


@partial(jax.jit, static_argnames=("causal", "use_pallas", "block_q", "block_k"))
def gqa_attention(
    q: jax.Array,  # (B, H, S, hd)
    k: jax.Array,  # (B, KVH, S, hd)
    v: jax.Array,
    causal: bool = True,
    use_pallas: bool | None = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    B, H, S, hd = q.shape
    KVH = k.shape[1]
    assert H % KVH == 0
    G = H // KVH
    qf = rearrange(q, "b (kv g) s d -> (b kv) (s g) d", g=G)
    kf = rearrange(k, "b kv s d -> (b kv) s d")
    vf = rearrange(v, "b kv s d -> (b kv) s d")
    if runtime.pick(use_pallas):
        of = _k.flash_attention(
            qf, kf, vf, causal=causal, group=G,
            block_q=block_q, block_k=block_k, interpret=runtime.interpret(),
        )
    else:
        of = _ref.flash_attention_ref(qf, kf, vf, causal=causal, group=G)
    return rearrange(of, "(b kv) (s g) d -> b (kv g) s d", b=B, g=G)
