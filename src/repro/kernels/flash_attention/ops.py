"""Jitted wrapper + registry entry: standard GQA -> folded flash attention.

The GQA fold maps query head ``kvh*G+g`` at position ``s`` to folded row
``s*G+g`` of batch-slab ``b*KVH+kvh`` -- K/V stay one copy per kv head (no
head broadcast in HBM), which is the point of GQA.
"""
from __future__ import annotations

from functools import partial

import jax
from einops import rearrange

from repro.kernels import registry
from repro.kernels.flash_attention import kernel as _k
from repro.kernels.flash_attention import ref as _ref


def _fold(q, k, v):
    B, H, S, hd = q.shape
    KVH = k.shape[1]
    assert H % KVH == 0
    G = H // KVH
    qf = rearrange(q, "b (kv g) s d -> (b kv) (s g) d", g=G)
    kf = rearrange(k, "b kv s d -> (b kv) s d")
    vf = rearrange(v, "b kv s d -> (b kv) s d")
    return qf, kf, vf, B, G


def _gqa_pallas(q, k, v, *, causal=True, block_q=128, block_k=128,
                interpret=False):
    qf, kf, vf, B, G = _fold(q, k, v)
    of = _k.flash_attention(
        qf, kf, vf, causal=causal, group=G,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return rearrange(of, "(b kv) (s g) d -> b (kv g) s d", b=B, g=G)


def _gqa_ref(q, k, v, *, causal=True, block_q=128, block_k=128):
    # block sizes are a pallas tiling detail; the reference ignores them
    qf, kf, vf, B, G = _fold(q, k, v)
    of = _ref.flash_attention_ref(qf, kf, vf, causal=causal, group=G)
    return rearrange(of, "(b kv) (s g) d -> b (kv g) s d", b=B, g=G)


def _example():
    import numpy as np

    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    B, H, KVH, S, hd = 2, 8, 2, 256, 64
    q = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, KVH, S, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, KVH, S, hd)), jnp.float32)
    return (q, k, v), dict(causal=True)


registry.register_kernel(
    "gqa_attention", pallas=_gqa_pallas, ref=_gqa_ref,
    example=_example,
    description="GQA flash attention (folded heads, one K/V copy per kv head)",
)


def gqa_attention(
    q: jax.Array,  # (B, H, S, hd)
    k: jax.Array,  # (B, KVH, S, hd)
    v: jax.Array,
    causal: bool = True,
    use_pallas=registry._UNSET,
    block_q: int = 128,
    block_k: int = 128,
    *,
    kernel_backend: str = "auto",
) -> jax.Array:
    if use_pallas is not registry._UNSET:
        kernel_backend = registry.backend_from_use_pallas(use_pallas)
    return _gqa_attention(q, k, v, causal, block_q, block_k, kernel_backend)


@partial(jax.jit,
         static_argnames=("causal", "block_q", "block_k", "kernel_backend"))
def _gqa_attention(q, k, v, causal, block_q, block_k, kernel_backend):
    return registry.dispatch(
        "gqa_attention", kernel_backend, q, k, v,
        causal=causal, block_q=block_q, block_k=block_k)
