from repro.kernels.flash_attention.ops import gqa_attention  # noqa: F401
