from repro.kernels.topk.ops import topk_rows  # noqa: F401
