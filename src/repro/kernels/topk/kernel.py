"""Pallas TPU kernel: row-wise top-k with ``jax.lax.top_k`` tie-breaking.

The ragged batch filter (``filter.select_batches_from_rows``) ranks every
guest's candidate subpages each maintenance tick — a row-wise top-k over an
int32 score matrix. The kernel runs one grid step per row with the whole
row resident in VMEM and peels the maximum ``k`` times: take the row max,
find its *first* position (min index among ties — exactly ``lax.top_k``'s
tie-break), record ``(val, idx)``, mask that lane to INT32_MIN, repeat.
``k`` is small (``max_batches * hp_ratio`` capped by the row length) so the
serial peel stays cheap next to streaming the row once.

Bit-exactness precondition: inputs must be > INT32_MIN (the mask value).
Engine scores are ``>= -1`` by construction, and column padding (also
INT32_MIN) then loses every comparison, so real lanes always win while
``k <= row_len``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = jnp.iinfo(jnp.int32).min


def _topk_kernel(x_ref, vals_ref, idx_ref, *, k: int, width: int):
    row = x_ref[...].astype(jnp.int32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, width), 1)

    def body(j, carry):
        row, vals, idx = carry
        m = row.max()
        i = jnp.where(row == m, iota, width).min()
        vals = jax.lax.dynamic_update_slice(vals, m.reshape(1, 1), (0, j))
        idx = jax.lax.dynamic_update_slice(idx, i.reshape(1, 1), (0, j))
        row = jnp.where(iota == i, _NEG, row)
        return row, vals, idx

    _, vals, idx = jax.lax.fori_loop(
        0, k, body,
        (row, jnp.zeros((1, k), jnp.int32), jnp.zeros((1, k), jnp.int32)))
    vals_ref[...] = vals
    idx_ref[...] = idx


def topk_rows(
    mat: jax.Array,  # int32[rows, width], entries > INT32_MIN
    k: int,
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """(int32[rows, k] values desc, int32[rows, k] first-index ties)."""
    rows, width = mat.shape
    assert 0 < k <= width, (k, width)
    pad = (-width) % 128
    x = mat.astype(jnp.int32)
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)), constant_values=_NEG)
    vals, idx = pl.pallas_call(
        partial(_topk_kernel, k=k, width=width + pad),
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, width + pad), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, k), jnp.int32),
            jax.ShapeDtypeStruct((rows, k), jnp.int32),
        ],
        interpret=interpret,
    )(x)
    return vals, idx
