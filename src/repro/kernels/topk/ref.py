"""Pure-jnp oracle for the row-wise top-k: ``jax.lax.top_k`` itself.

The filter ran this before the kernel registry, so the ``"xla"`` backend is
the pre-registry engine path verbatim (values descending, ties resolved to
the lowest index).
"""
from __future__ import annotations

import jax


def topk_rows_ref(mat: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    return jax.lax.top_k(mat, k)
