"""Jitted wrapper + registry entry for the row-wise top-k kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.topk import kernel as _k
from repro.kernels.topk import ref as _ref


def _topk_pallas(mat: jax.Array, k: int, *, interpret: bool = False):
    return _k.topk_rows(mat, k, interpret=interpret)


def _oracle(mat, k):
    import numpy as np

    m = np.asarray(mat)
    # stable descending sort == lax.top_k tie-break (lowest index first)
    order = np.argsort(-m, axis=1, kind="stable")[:, :k]
    return np.take_along_axis(m, order, axis=1), order.astype(np.int32)


def _example():
    import numpy as np

    rng = np.random.default_rng(0)
    mat = rng.integers(-1, 64, size=(64, 1024)).astype(np.int32)
    return (jnp.asarray(mat), 128), {}


registry.register_kernel(
    "topk_rows", pallas=_topk_pallas, ref=_ref.topk_rows_ref,
    oracle=_oracle, example=_example,
    description="row-wise top-k, lax.top_k tie-break (ragged batch filter)",
)


@partial(jax.jit, static_argnames=("k", "kernel_backend"))
def topk_rows(
    mat: jax.Array, k: int, *, kernel_backend: str = "auto"
) -> tuple[jax.Array, jax.Array]:
    """Row-wise ``(values, indices)`` top-k; entries must be > INT32_MIN."""
    return registry.dispatch("topk_rows", kernel_backend, mat, k)
