"""Pure-jnp oracle for the tiered embedding lookup."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_rows_ref(rows: jax.Array, ids: jax.Array) -> jax.Array:
    return rows[ids]


def tiered_lookup_ref(
    rows: jax.Array,  # (n_rows, d) flat [near; far] row space
    fused: jax.Array,  # int32 (n_logical,) precomposed translation
    token_ids: jax.Array,  # int32 (k,) logical row ids (may be any shape)
) -> jax.Array:
    shape = token_ids.shape
    flat = token_ids.reshape(-1)
    valid = (flat >= 0) & (flat < fused.shape[0])
    rows_out = rows[fused[jnp.where(valid, flat, 0)]]
    rows_out = jnp.where(valid[:, None], rows_out, 0)
    return rows_out.reshape(*shape, rows.shape[1])
