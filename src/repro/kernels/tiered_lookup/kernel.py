"""Pallas TPU kernel: embedding-row gather through the GPAC translation.

The tiered embedding store keeps vocab rows in paged pools behind the
``gpt ∘ block_table`` two-level translation. At lookup time the *translation*
is two tiny int32 gathers (done in the wrapper, fused by XLA); the *payload*
gather is the hot spot: ``batch*seq`` rows of ``d_model`` floats streamed from
scattered HBM rows. The row index is scalar-prefetched so each grid step's DMA
descriptor is formed before the previous copy retires (double buffering), and
a ``(1, d)`` block keeps rows lane-aligned (d is a multiple of 128 for every
assigned architecture).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _gather_kernel(ids_ref, rows_ref, o_ref):
    o_ref[...] = rows_ref[...]


def gather_rows(
    rows: jax.Array,  # (n_rows, d)
    ids: jax.Array,  # int32 (k,) pre-clamped to [0, n_rows)
    *,
    interpret: bool = False,
) -> jax.Array:
    """dtype[k, d] = rows[ids] via scalar-prefetched per-row DMA."""
    k = ids.shape[0]
    d = rows.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[pl.BlockSpec((1, d), lambda i, ids_ref: (ids_ref[i], 0))],
        out_specs=pl.BlockSpec((1, d), lambda i, ids_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k, d), rows.dtype),
        interpret=interpret,
    )(ids, rows)
