from repro.kernels.tiered_lookup.ops import tiered_lookup  # noqa: F401
