"""Jitted wrappers + registry entries: row gather and two-level translation.

``gather_rows`` is the raw scalar-prefetched row gather (ids must be
in-range) — the primitive the consolidator's payload copies dispatch to.
``tiered_lookup`` composes it with the precomposed gpt∘block_table
translation and the -1/OOB masking the serving path needs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.tiered_lookup import kernel as _k
from repro.kernels.tiered_lookup import ref as _ref


def _gather_rows_pallas(
    rows: jax.Array, ids: jax.Array, *, interpret: bool = False
) -> jax.Array:
    flat = ids.reshape(-1).astype(jnp.int32)
    out = _k.gather_rows(rows, flat, interpret=interpret)
    return out.reshape(*ids.shape, rows.shape[1])


def _gather_rows_ref(rows: jax.Array, ids: jax.Array) -> jax.Array:
    return rows[ids]


def _gather_oracle(rows, ids):
    import numpy as np

    return np.asarray(rows)[np.asarray(ids)]


def _gather_example():
    import numpy as np

    rng = np.random.default_rng(0)
    rows = rng.standard_normal((16384, 8)).astype(np.float32)
    ids = rng.integers(0, 16384, size=4096).astype(np.int32)
    return (jnp.asarray(rows), jnp.asarray(ids)), {}


def _tiered_lookup_pallas(
    rows: jax.Array, fused: jax.Array, token_ids: jax.Array,
    *, interpret: bool = False,
) -> jax.Array:
    shape = token_ids.shape
    flat = token_ids.reshape(-1)
    valid = (flat >= 0) & (flat < fused.shape[0])
    phys = fused[jnp.where(valid, flat, 0)].astype(jnp.int32)
    out = _k.gather_rows(rows, phys, interpret=interpret)
    out = jnp.where(valid[:, None], out, 0)
    return out.reshape(*shape, rows.shape[1])


def _lookup_oracle(rows, fused, token_ids):
    import numpy as np

    rows, fused = np.asarray(rows), np.asarray(fused)
    flat = np.asarray(token_ids).reshape(-1)
    out = np.zeros((flat.shape[0], rows.shape[1]), rows.dtype)
    for i, t in enumerate(flat):
        if 0 <= t < fused.shape[0]:
            out[i] = rows[fused[t]]
    return out.reshape(*np.asarray(token_ids).shape, rows.shape[1])


def _lookup_example():
    import numpy as np

    rng = np.random.default_rng(0)
    rows = rng.standard_normal((8192, 8)).astype(np.float32)
    fused = rng.permutation(8192).astype(np.int32)
    tokens = rng.integers(-1, 8192, size=2048).astype(np.int32)
    return (jnp.asarray(rows), jnp.asarray(fused), jnp.asarray(tokens)), {}


registry.register_kernel(
    "gather_rows", pallas=_gather_rows_pallas, ref=_gather_rows_ref,
    oracle=_gather_oracle, example=_gather_example,
    description="scalar-prefetched row gather (consolidation payload copy)",
)
registry.register_kernel(
    "tiered_lookup", pallas=_tiered_lookup_pallas,
    ref=_ref.tiered_lookup_ref,
    oracle=_lookup_oracle, example=_lookup_example,
    description="two-level translation + payload gather (fused TLB)",
)


def tiered_lookup(
    rows: jax.Array,
    fused: jax.Array,
    token_ids: jax.Array,
    use_pallas=registry._UNSET,
    *,
    kernel_backend: str = "auto",
) -> jax.Array:
    """rows[fused[token_ids]] with -1/-OOB ids producing zero rows.

    ``fused`` is the precomposed gpt∘block_table translation (see
    ``repro.core.address_space.fused_translation``); recomputed only after a
    consolidation/migration tick -- the beyond-paper 'fused TLB' optimization.
    """
    if use_pallas is not registry._UNSET:
        kernel_backend = registry.backend_from_use_pallas(use_pallas)
    return _tiered_lookup(rows, fused, token_ids, kernel_backend)


@partial(jax.jit, static_argnames=("kernel_backend",))
def _tiered_lookup(rows, fused, token_ids, kernel_backend):
    return registry.dispatch(
        "tiered_lookup", kernel_backend, rows, fused, token_ids)


def gather_rows(
    rows: jax.Array,
    ids: jax.Array,
    *,
    kernel_backend: str = "auto",
) -> jax.Array:
    """rows[ids] for in-range ids (any id shape; trailing row axis appended)."""
    return _gather_rows(rows, ids, kernel_backend)


@partial(jax.jit, static_argnames=("kernel_backend",))
def _gather_rows(rows, ids, kernel_backend):
    return registry.dispatch("gather_rows", kernel_backend, rows, ids)
