"""Jitted wrapper: two-level translation (int32 gathers) + payload gather."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from repro.kernels.tiered_lookup import kernel as _k
from repro.kernels.tiered_lookup import ref as _ref


@partial(jax.jit, static_argnames=("use_pallas",))
def tiered_lookup(
    rows: jax.Array,
    fused: jax.Array,
    token_ids: jax.Array,
    use_pallas: bool | None = None,
) -> jax.Array:
    """rows[fused[token_ids]] with -1/-OOB ids producing zero rows.

    ``fused`` is the precomposed gpt∘block_table translation (see
    ``repro.core.address_space.fused_translation``); recomputed only after a
    consolidation/migration tick -- the beyond-paper 'fused TLB' optimization.
    """
    if runtime.pick(use_pallas):
        shape = token_ids.shape
        flat = token_ids.reshape(-1)
        valid = (flat >= 0) & (flat < fused.shape[0])
        phys = fused[jnp.where(valid, flat, 0)].astype(jnp.int32)
        out = _k.gather_rows(rows, phys, interpret=runtime.interpret())
        out = jnp.where(valid[:, None], out, 0)
        return out.reshape(*shape, rows.shape[1])
    return _ref.tiered_lookup_ref(rows, fused, token_ids)
