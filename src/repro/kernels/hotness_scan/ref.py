"""Pure-jnp oracle for the hotness scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hot_count_ref(hot_gpa: jax.Array, hp_ratio: int) -> jax.Array:
    n_hp = hot_gpa.shape[0] // hp_ratio
    return hot_gpa.reshape(n_hp, hp_ratio).astype(jnp.int32).sum(axis=1)
