"""Pallas TPU kernel: per-huge-page hot-subpage count (telemetry aggregation).

The Scattered Page Filter needs ``sum(hot bits) per huge page`` over the whole
GPA space every maintenance tick -- at production scale (TBs of far memory,
millions of base pages) this is a bandwidth-bound strided reduction, so it is
tiled explicitly: each grid step streams a ``(blk_hp, hp_ratio)`` tile of the
hot-bit matrix HBM->VMEM and reduces along lanes. ``hp_ratio`` is 512 in the
paper = 4 x 128 lanes, a perfectly aligned VREG tile row.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _count_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].sum(axis=1, keepdims=True, dtype=jnp.int32)


def hot_count(
    hot_gpa: jax.Array,  # int32/bool[n_hp * hp_ratio] hot bit per gpa page
    hp_ratio: int,
    blk_hp: int = 8,
    *,
    interpret: bool = False,
) -> jax.Array:
    """int32[n_hp]: number of hot base pages inside each huge page."""
    n = hot_gpa.shape[0]
    assert n % hp_ratio == 0
    n_hp = n // hp_ratio
    pad = (-n_hp) % blk_hp
    x = hot_gpa.reshape(n_hp, hp_ratio).astype(jnp.int32)
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _count_kernel,
        grid=((n_hp + pad) // blk_hp,),
        in_specs=[pl.BlockSpec((blk_hp, hp_ratio), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((blk_hp, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_hp + pad, 1), jnp.int32),
        interpret=interpret,
    )(x)
    return out[:n_hp, 0]
