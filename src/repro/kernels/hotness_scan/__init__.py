from repro.kernels.hotness_scan.ops import hot_count  # noqa: F401
