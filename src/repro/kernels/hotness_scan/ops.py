"""Jitted wrapper for the hotness scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import runtime
from repro.kernels.hotness_scan import kernel as _k
from repro.kernels.hotness_scan import ref as _ref


@partial(jax.jit, static_argnames=("hp_ratio", "use_pallas"))
def hot_count(
    hot_gpa: jax.Array, hp_ratio: int, use_pallas: bool | None = None
) -> jax.Array:
    """int32[n_hp] hot-subpage count per huge page."""
    if runtime.pick(use_pallas):
        return _k.hot_count(hot_gpa, hp_ratio, interpret=runtime.interpret())
    return _ref.hot_count_ref(hot_gpa, hp_ratio)
