"""Jitted wrapper + registry entry for the hotness scan kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.hotness_scan import kernel as _k
from repro.kernels.hotness_scan import ref as _ref


def _hot_count_pallas(
    hot_gpa: jax.Array, hp_ratio: int, *, interpret: bool = False
) -> jax.Array:
    return _k.hot_count(hot_gpa, hp_ratio, interpret=interpret)


def _oracle(hot_gpa, hp_ratio):
    import numpy as np

    x = np.asarray(hot_gpa).astype(np.int32)
    return x.reshape(-1, hp_ratio).sum(axis=1).astype(np.int32)


def _example():
    import numpy as np

    rng = np.random.default_rng(0)
    hot = rng.random(4096 * 32) < 0.1
    return (jnp.asarray(hot), 32), {}


registry.register_kernel(
    "hot_count", pallas=_hot_count_pallas, ref=_ref.hot_count_ref,
    oracle=_oracle, example=_example,
    description="per-huge-page hot-subpage count (scattered page filter)",
)


def hot_count(
    hot_gpa: jax.Array,
    hp_ratio: int,
    use_pallas=registry._UNSET,
    *,
    kernel_backend: str = "auto",
) -> jax.Array:
    """int32[n_hp] hot-subpage count per huge page.

    ``use_pallas=`` is a deprecated shim over ``kernel_backend=``.
    """
    if use_pallas is not registry._UNSET:
        kernel_backend = registry.backend_from_use_pallas(use_pallas)
    return _hot_count(hot_gpa, hp_ratio, kernel_backend)


@partial(jax.jit, static_argnames=("hp_ratio", "kernel_backend"))
def _hot_count(hot_gpa, hp_ratio, kernel_backend):
    return registry.dispatch("hot_count", kernel_backend, hot_gpa, hp_ratio)
