from repro.kernels.paged_attention.ops import paged_attention  # noqa: F401
