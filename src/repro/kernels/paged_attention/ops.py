"""Jitted wrapper for paged decode attention (clamps the block table)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from repro.kernels.paged_attention import kernel as _k
from repro.kernels.paged_attention import ref as _ref


@partial(jax.jit, static_argnames=("use_pallas",))
def paged_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    btab: jax.Array,
    lens: jax.Array,
    use_pallas: bool | None = None,
) -> jax.Array:
    """GQA decode attention over paged KV; see kernel.py for layouts."""
    n_pages = k_pages.shape[1]
    safe_btab = jnp.clip(btab, 0, n_pages - 1).astype(jnp.int32)
    if runtime.pick(use_pallas):
        return _k.paged_attention(
            q, k_pages, v_pages, safe_btab, lens.astype(jnp.int32),
            interpret=runtime.interpret(),
        )
    return _ref.paged_attention_ref(q, k_pages, v_pages, safe_btab, lens)
