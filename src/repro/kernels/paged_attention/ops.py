"""Jitted wrapper + registry entry for paged decode attention."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.paged_attention import kernel as _k
from repro.kernels.paged_attention import ref as _ref


def _clamp(k_pages, btab, lens):
    n_pages = k_pages.shape[1]
    return jnp.clip(btab, 0, n_pages - 1).astype(jnp.int32), \
        lens.astype(jnp.int32)


def _paged_pallas(q, k_pages, v_pages, btab, lens, *, interpret=False):
    safe_btab, lens = _clamp(k_pages, btab, lens)
    return _k.paged_attention(q, k_pages, v_pages, safe_btab, lens,
                              interpret=interpret)


def _paged_ref(q, k_pages, v_pages, btab, lens):
    safe_btab, lens = _clamp(k_pages, btab, lens)
    return _ref.paged_attention_ref(q, k_pages, v_pages, safe_btab, lens)


def _example():
    import numpy as np

    rng = np.random.default_rng(0)
    B, KVH, G, n_pages, page, hd, pages_per_seq = 4, 2, 4, 64, 16, 64, 8
    q = jnp.asarray(rng.standard_normal((B, KVH, G, hd)), jnp.float32)
    kp = jnp.asarray(
        rng.standard_normal((KVH, n_pages, page, hd)), jnp.float32)
    vp = jnp.asarray(
        rng.standard_normal((KVH, n_pages, page, hd)), jnp.float32)
    btab = jnp.asarray(
        rng.integers(0, n_pages, size=(B, pages_per_seq)), jnp.int32)
    lens = jnp.asarray(
        rng.integers(1, pages_per_seq * page, size=(B,)), jnp.int32)
    return (q, kp, vp, btab, lens), {}


registry.register_kernel(
    "paged_attention", pallas=_paged_pallas, ref=_paged_ref,
    example=_example,
    description="GQA decode attention over paged KV (clamped block table)",
)


def paged_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    btab: jax.Array,
    lens: jax.Array,
    use_pallas=registry._UNSET,
    *,
    kernel_backend: str = "auto",
) -> jax.Array:
    """GQA decode attention over paged KV; see kernel.py for layouts."""
    if use_pallas is not registry._UNSET:
        kernel_backend = registry.backend_from_use_pallas(use_pallas)
    return _paged_attention(q, k_pages, v_pages, btab, lens, kernel_backend)


@partial(jax.jit, static_argnames=("kernel_backend",))
def _paged_attention(q, k_pages, v_pages, btab, lens, kernel_backend):
    return registry.dispatch(
        "paged_attention", kernel_backend, q, k_pages, v_pages, btab, lens)
