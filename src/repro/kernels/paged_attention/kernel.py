"""Pallas TPU kernel: GQA decode attention over the two-level paged KV cache.

This is the serving hot path the paper's technique feeds: KV pages live in the
GPAC-managed tiered store, and decode gathers them *through the block table*.
The physical page id is scalar-prefetched into the K/V index maps, so the page
walk (the paper's EPT analogue) costs one SMEM read per grid step while the
page payload streams HBM->VMEM double-buffered.

Layouts (chosen so the page dimension is contiguous for one-DMA-per-page):
    q:        (B, KVH, G, hd)    G = n_q_heads // n_kv_heads
    k_pages:  (KVH, n_pages, page_size, hd)
    v_pages:  (KVH, n_pages, page_size, hd)
    btab:     int32 (B, pages_per_seq)   physical page per sequence slot
    lens:     int32 (B,)                 current KV length per sequence

Grid: (B, KVH, pages_per_seq); the page axis is sequential ("arbitrary") and
accumulates online softmax in VMEM scratch. Fully padded pages (slot beyond
ceil(len/page_size)) are masked; their btab entries are clamped to 0 by the
wrapper so the index map stays in range.

On real TPU, ``hd`` is 64-256 (lane-aligned) and ``G`` lands in sublanes; the
scratch carries (G, 1) running max / denominator per kv-head group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(
    lens_ref,  # SMEM int32 (B,)
    btab_ref,  # SMEM int32 (B, pages_per_seq)
    q_ref,  # (1, 1, G, hd)
    k_ref,  # (1, 1, page_size, hd)
    v_ref,  # (1, 1, page_size, hd)
    o_ref,  # (1, 1, G, hd)
    m_ref,  # scratch (G, 1) f32
    l_ref,  # scratch (G, 1) f32
    acc_ref,  # scratch (G, hd) f32
    *,
    page_size: int,
    pages_per_seq: int,
    scale: float,
):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq_len = lens_ref[b]
    pos = p * page_size + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
    in_seq = pos < seq_len  # (1, page_size)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (page_size, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (G, page_size)
    s = jnp.where(in_seq, s, NEG_INF)

    m_prev = m_ref[...]  # (G, 1)
    m_cur = s.max(axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    # exp of fully-masked lanes underflows to 0 (NEG_INF - m_new <= 0)
    pexp = jnp.exp(s - m_new)
    pexp = jnp.where(in_seq, pexp, 0.0)
    l_new = l_ref[...] * alpha + pexp.sum(axis=1, keepdims=True)
    acc_new = acc_ref[...] * alpha + jax.lax.dot_general(
        pexp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(p == pages_per_seq - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def paged_attention(
    q: jax.Array,  # (B, KVH, G, hd)
    k_pages: jax.Array,  # (KVH, n_pages, page_size, hd)
    v_pages: jax.Array,
    btab: jax.Array,  # int32 (B, pages_per_seq), pre-clamped to [0, n_pages)
    lens: jax.Array,  # int32 (B,)
    *,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Returns (B, KVH, G, hd) attention output."""
    B, KVH, G, hd = q.shape
    _, n_pages, page_size, _ = k_pages.shape
    pages_per_seq = btab.shape[1]
    scale = (hd ** -0.5) if scale is None else scale

    kernel = functools.partial(
        _paged_attn_kernel,
        page_size=page_size,
        pages_per_seq=pages_per_seq,
        scale=scale,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, pages_per_seq),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, p, lens, bt: (b, h, 0, 0)),
            pl.BlockSpec(
                (1, 1, page_size, hd), lambda b, h, p, lens, bt: (h, bt[b, p], 0, 0)
            ),
            pl.BlockSpec(
                (1, 1, page_size, hd), lambda b, h, p, lens, bt: (h, bt[b, p], 0, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, p, lens, bt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, hd), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lens, btab, q, k_pages, v_pages)
