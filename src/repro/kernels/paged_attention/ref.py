"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention_ref(
    q: jax.Array,  # (B, KVH, G, hd)
    k_pages: jax.Array,  # (KVH, n_pages, page_size, hd)
    v_pages: jax.Array,
    btab: jax.Array,  # int32 (B, pages_per_seq)
    lens: jax.Array,  # int32 (B,)
    *,
    scale: float | None = None,
) -> jax.Array:
    B, KVH, G, hd = q.shape
    _, n_pages, page_size, _ = k_pages.shape
    pages_per_seq = btab.shape[1]
    scale = (hd ** -0.5) if scale is None else scale

    safe = jnp.clip(btab, 0, n_pages - 1)
    # (B, KVH, pages_per_seq, page_size, hd) -> (B, KVH, S, hd)
    k = k_pages[:, safe]  # (KVH, B, pages, page, hd)
    v = v_pages[:, safe]
    k = jnp.moveaxis(k, 0, 1).reshape(B, KVH, pages_per_seq * page_size, hd)
    v = jnp.moveaxis(v, 0, 1).reshape(B, KVH, pages_per_seq * page_size, hd)

    s = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    pos = jnp.arange(pages_per_seq * page_size)[None, None, None, :]
    mask = pos < lens[:, None, None, None]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)  # rows with len=0 would be NaN otherwise
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
