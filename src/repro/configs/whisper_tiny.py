"""Whisper-tiny [arXiv:2212.04356; unverified].

Enc-dec: 4 encoder + 4 decoder layers, d_model=384 6H (kv=6) d_ff=1536
vocab=51865, LayerNorm, learned positions. The conv audio frontend is a STUB:
``input_specs`` provides precomputed (batch, 1500, d_model) frame embeddings.

NOTE: Whisper's native decoder context is 448 tokens; the assigned
prefill_32k/decode_32k shapes exceed it, so ``max_seq`` is a 40960-entry
learned-position capacity stand-in (the arch is exercised at the assigned
shapes as the pool requires; the context mismatch is a property of the
assignment, recorded in DESIGN.md §6).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    norm="layernorm",
    activation="gelu",
    encdec=True,
    n_enc_layers=4,
    n_frames=1500,
    max_seq=40960,  # learned-position capacity covering the 32k cells
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        name="whisper-reduced", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, n_enc_layers=2, n_frames=16, max_seq=64, head_dim=16,
    )
