"""Qwen2-VL-2B backbone [arXiv:2409.12191; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936. M-RoPE (3-section
t/h/w rotary over head_dim=128), QKV bias (Qwen2 style). The vision frontend
is a STUB: ``input_specs`` provides token ids plus 3D position ids as the
dynamic-resolution patch layout would produce them.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),  # head_dim/2 = 64 = 16+24+24
    rope_theta=1_000_000.0,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        name="qwen2-vl-2b-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
        mrope_sections=(2, 3, 3),
    )
