"""Kimi K2 1T-A32B [arXiv:2501.kimi2; unverified, paper-table config].

61L d_model=7168 64H (GQA kv=8) vocab=163840; MoE with 384 routed experts
top-8 + 1 shared expert, per-expert d_ff=2048. ~1.0T total params, ~32B
active -- the trillion-parameter MoE stress cell of the assignment.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    n_experts=384,
    n_shared_experts=1,
    top_k=8,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        name="kimi-k2-reduced", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=32, vocab=256, n_experts=8, n_shared_experts=1, top_k=2, head_dim=16,
        capacity_factor=8.0,
    )
