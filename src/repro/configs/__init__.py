"""Assigned architecture configs (system prompt pool).

``get(name)`` returns the exact published config (CLI id or module name);
``reduced(name)`` returns the small same-family smoke-test variant.
"""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, SHAPE_SPECS, SHAPES  # noqa: F401

# canonical CLI ids (--arch <id>), in assignment order
CLI_IDS = (
    "qwen2-vl-2b",
    "jamba-1.5-large-398b",
    "kimi-k2-1t-a32b",
    "qwen2-moe-a2.7b",
    "internlm2-20b",
    "gemma-7b",
    "smollm-360m",
    "qwen2-0.5b",
    "whisper-tiny",
    "xlstm-1.3b",
)

_MODULES = {
    "qwen2-vl-2b": "qwen2_vl_2b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "internlm2-20b": "internlm2_20b",
    "gemma-7b": "gemma_7b",
    "smollm-360m": "smollm_360m",
    "qwen2-0.5b": "qwen2_0_5b",
    "whisper-tiny": "whisper_tiny",
    "xlstm-1.3b": "xlstm_1_3b",
}
# also accept module-style names
_MODULES.update({v: v for v in list(_MODULES.values())})


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(set(_MODULES))}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get(name: str) -> ArchConfig:
    """Exact assigned config."""
    return _module(name).CONFIG


def reduced(name: str) -> ArchConfig:
    """Small same-family config for CPU smoke tests."""
    return _module(name).reduced()


def all_archs() -> tuple:
    return CLI_IDS
