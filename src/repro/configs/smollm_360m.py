"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-360M] (llama-arch small).

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152. 15 heads do not divide
the 16-way model axis: attention projections replicate under TP, MLP + vocab
still shard (DESIGN.md §5 head-divisibility rule).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab=49152,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        name="smollm-reduced", n_layers=2, d_model=60, n_heads=3, n_kv_heads=1,
        d_ff=128, vocab=256, head_dim=16,
    )
