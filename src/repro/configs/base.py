"""Unified architecture config covering all 10 assigned architectures.

Every field that differs across the assigned pool is explicit; per-arch files
instantiate the exact published numbers and a ``reduced()`` smoke variant of
the same family shape (system prompt requirement).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

FAMILIES = ("dense", "moe", "hybrid", "ssm", "audio", "vlm")
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

# Assigned input shapes (system prompt): seq_len x global_batch.
SHAPE_SPECS = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads (gemma overrides to 256)
    activation: str = "swiglu"  # swiglu | geglu
    qkv_bias: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    mrope: bool = False  # qwen2-vl 3-section M-RoPE
    mrope_sections: tuple = (16, 24, 24)  # t/h/w split of head_dim/2
    tie_embeddings: bool = False
    # ---- MoE ------------------------------------------------------------
    n_experts: int = 0  # routed experts (0 = dense FFN)
    n_experts_padded: int = 0  # 0 -> n_experts; qwen2-moe pads 60 -> 64 for EP
    n_shared_experts: int = 0  # always-on experts
    top_k: int = 0
    moe_period: int = 1  # MoE FFN every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    # ---- hybrid (jamba) --------------------------------------------------
    attn_period: int = 0  # attention every k-th layer (jamba: 8); 0 = all
    ssm_state: int = 16  # mamba d_state
    ssm_expand: int = 2  # d_inner = expand * d_model
    ssm_conv: int = 4
    # ---- xlstm -----------------------------------------------------------
    slstm_period: int = 0  # every k-th block is sLSTM (xlstm: 8); 0 = none
    # ---- enc-dec (whisper) ------------------------------------------------
    encdec: bool = False
    n_enc_layers: int = 0
    n_frames: int = 1500  # stubbed modality frontend output length
    max_seq: int = 8192  # learned-positions capacity (whisper)
    # ---- numerics ---------------------------------------------------------
    dtype: Any = jnp.bfloat16
    remat: str = "block"  # none | block (checkpoint each layer group)
    # unroll structural scans (layer groups, CE/attention chunks) so XLA's
    # cost analysis counts every iteration -- the dry-run sets this; training
    # keeps scans for compile-time. Mixer time-scans are never unrolled
    # (roofline applies their analytic trip correction instead).
    unroll: bool = False
    # §Perf toggles (beyond-paper optimizations; baseline lowers with all off)
    causal_skip: bool = False  # attention: skip K/V blocks above the diagonal
    ssm_bf16: bool = False  # mamba: bf16 dA/dBx state expansion (f32 carry)
    # ---- serving ----------------------------------------------------------
    page_size: int = 64  # KV tokens per page (base granule for GPAC = page)

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"bad family {self.family}")
        if self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError("n_heads must be a multiple of n_kv_heads")
        if self.attn_period and self.n_layers % self.attn_period:
            raise ValueError("n_layers must divide into attn_period groups")
        if self.slstm_period and self.n_layers % self.slstm_period:
            raise ValueError("n_layers must divide into slstm_period groups")

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def e_pad(self) -> int:
        """Expert-bank size after EP padding."""
        return self.n_experts_padded or self.n_experts

    @property
    def group_size(self) -> int:
        """Layers per scanned super-block (heterogeneous stacks scan groups)."""
        if self.attn_period:
            return self.attn_period
        if self.slstm_period:
            return self.slstm_period
        if self.is_moe and self.moe_period > 1:
            return self.moe_period
        return 1

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.group_size

    def layer_kind(self, i: int) -> str:
        """Mixer kind of layer i: attn | mamba | mlstm | slstm."""
        if self.family == "ssm":
            return "slstm" if (self.slstm_period and i % self.slstm_period == self.slstm_period - 1) else "mlstm"
        if self.attn_period:
            return "attn" if i % self.attn_period == 0 else "mamba"
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        return self.is_moe and (i % self.moe_period == self.moe_period - 1)

    @property
    def attn_layers(self) -> list:
        return [i for i in range(self.n_layers) if self.layer_kind(i) == "attn"]

    @property
    def n_attn_layers(self) -> int:
        return len(self.attn_layers)

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (SSM/hybrid families; see DESIGN.md §6)."""
        return self.family in ("ssm", "hybrid")

    def shapes(self) -> list:
        """Assigned shape cells for this arch (long_500k only if subquadratic)."""
        out = ["train_4k", "prefill_32k", "decode_32k"]
        if self.subquadratic:
            out.append("long_500k")
        return out

    def param_count(self) -> int:
        """Analytical parameter count, exact vs. the model's init (tested):
        used for MODEL_FLOPS = 6*N*D in the roofline."""
        d, hd = self.d_model, self.hd
        H, KVH = self.n_heads, self.n_kv_heads
        gates = 1 if self.activation == "gelu" else 2
        norm = 2 * d if self.norm == "layernorm" else d

        def attn_p():
            p = d * H * hd + 2 * d * KVH * hd + H * hd * d
            if self.qkv_bias:
                p += (H + 2 * KVH) * hd
            return p

        def mamba_p():
            di = self.ssm_expand * d
            dr = -(-d // 16)  # dt_rank
            p = d * 2 * di  # in_proj
            p += self.ssm_conv * di + di  # conv_w, conv_b
            p += di * (dr + 2 * self.ssm_state)  # x_proj
            p += dr * di + di  # dt_proj, dt_bias
            p += di * self.ssm_state + di  # A_log, D
            p += di * d  # out_proj
            return p

        def mlstm_p():
            di = 2 * d  # q/k/v block-diagonal per head: 3 * di^2 / H
            return (d * 2 * di + 3 * di * di // H + di * 2 * H + 2 * H + di * d)

        def slstm_p():
            di = 2 * d  # gates block-diagonal per head: 4 * di^2 / H
            return d * 2 * di + 4 * di * di // H + 4 * di + 4 * di + di * d

        def mlp_p(ff):
            return (gates + 1) * d * ff

        def moe_p():
            p = d * self.e_pad  # router
            p += self.e_pad * 3 * d * self.d_ff  # expert banks (swiglu)
            if self.n_shared_experts:
                p += 3 * d * self.d_ff * self.n_shared_experts
            return p

        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.encdec:
            total += self.max_seq * d + self.n_frames * d  # learned positions
        mixer = dict(attn=attn_p, mamba=mamba_p, mlstm=mlstm_p, slstm=slstm_p)
        for i in range(self.n_layers):
            total += norm + mixer[self.layer_kind(i)]()
            if self.layer_is_moe(i):
                total += norm + moe_p()
            elif self.d_ff:
                total += norm + mlp_p(self.d_ff)
            if self.encdec:  # cross attention + its norm
                total += norm + attn_p()
        total += norm  # final norm
        if self.encdec:
            for _ in range(self.n_enc_layers):
                total += 2 * norm + attn_p() + mlp_p(self.d_ff)
            total += norm  # encoder final norm
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared instead of all)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        n_moe_layers = sum(self.layer_is_moe(i) for i in range(self.n_layers))
        all_e = n_moe_layers * self.e_pad * 3 * d * self.d_ff
        act_e = n_moe_layers * self.top_k * 3 * d * self.d_ff
        return full - all_e + act_e

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)
