"""Qwen2-0.5B [arXiv:2407.10671; hf].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936, QKV bias.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151936,
    qkv_bias=True,
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        name="qwen2-0.5b-reduced", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16,
    )
