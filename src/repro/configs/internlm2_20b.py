"""InternLM2-20B [arXiv:2403.17297; hf].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92544,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        name="internlm2-reduced", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16,
    )
