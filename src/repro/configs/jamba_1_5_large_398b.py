"""Jamba-1.5-Large 398B [arXiv:2403.19887; hf].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536; hybrid Mamba:attn 7:1
interleave (attention every 8th layer), MoE 16 experts top-2 on every other
layer. Runs ``long_500k`` (sub-quadratic: decode state is SSM + 9 attention
layers' paged KV).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    n_experts=16,
    top_k=2,
    moe_period=2,
    attn_period=8,
    ssm_state=16,
    ssm_expand=2,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        name="jamba-reduced", n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, n_experts=4, top_k=2, moe_period=2, attn_period=4,
        ssm_state=4, head_dim=16, capacity_factor=8.0,
    )
