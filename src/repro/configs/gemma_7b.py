"""Gemma-7B [arXiv:2403.08295].

28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000; GeGLU activation,
head_dim=256 (> d_model/n_heads), tied embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    d_ff=24576,
    vocab=256000,
    head_dim=256,
    activation="geglu",
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        name="gemma-reduced", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, head_dim=32,
    )
