"""xLSTM-1.3B [arXiv:2405.04517; unverified].

48 blocks d_model=2048 4H vocab=50304; mLSTM:sLSTM 7:1 (every 8th block is
sLSTM), d_ff=0 (mixers carry their own up/down projections). Decode state is
constant-size matrix memory -- runs ``long_500k`` with no KV cache at all.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    slstm_period=8,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        name="xlstm-reduced", n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
        vocab=256, slstm_period=2, head_dim=32,
    )
