"""Qwen1.5/2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16) vocab=151936; 60 routed experts top-4 + 4 shared
experts, per-expert d_ff=1408. Experts are padded 60->64 at sharding time so
the expert axis splits over the 16-way model axis (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    n_experts=60,
    n_experts_padded=64,
    n_shared_experts=4,
    top_k=4,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        name="qwen2-moe-reduced", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=32, vocab=256, n_experts=6, n_experts_padded=8, n_shared_experts=2,
        top_k=2, head_dim=16, capacity_factor=8.0,
    )
