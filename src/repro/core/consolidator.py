"""Page Consolidator (paper §4.3.2, Algorithm 1) -- guest kernel-space layer.

``consolidate_pages(cfg, state, pages)`` is the functional analogue of the
paper's custom syscall: it moves up to ``hp_ratio`` (512 in the paper) base
pages into one freshly allocated, fully free huge-page-sized GPA region and
rewrites the logical->gpa mapping. Multiple invocations consolidate more
pages, exactly as in the paper. Returns -ENOMEM behaviour as a no-op +
``consolidation_enomem`` counter when no fully free huge region exists.

The data copy is the compute hot-spot; ``repro.kernels.consolidate`` provides
the Pallas TPU kernel for the common near->near path, and this module is the
general (mixed-tier, predicated) reference path used under jit on any backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import address_space as asp
from repro.core.address_space import dataclasses_replace
from repro.core.types import FREE, GpacConfig, TieredState


def consolidate_pages(
    cfg: GpacConfig,
    state: TieredState,
    pages: jax.Array,
    hp_range: tuple | None = None,
) -> TieredState:
    """One Algorithm-1 invocation.

    ``pages``: int32[hp_ratio] logical page ids, padded with -1. Pages are
    packed into slots 0..k-1 of the fresh region in the given order.
    ``hp_range`` optionally confines the fresh region to one guest's GPA
    segment (multi-tenant simulation).

    Steps (mirroring Algorithm 1):
      1. huge_region <- alloc(HPAGE_SIZE)             (fully free GPA region)
      2. for each old_page i: copy payload old -> region[i]
      3. set_pte_at: gpt[logical] = region*hp_ratio+i ; rmap updates
      4. flush_tlb_mm_range: fused-translation caches are invalidated by
         bumping stats['tlb_shootdowns'] (callers drop cached fused tables)
      5. free(old_page): old gpa rmap entries -> FREE
    """
    pages = pages.astype(jnp.int32)
    if pages.shape != (cfg.hp_ratio,):
        raise ValueError(f"pages must be int32[{cfg.hp_ratio}]")

    valid = (pages >= 0) & (pages < cfg.n_logical)
    # a page already sitting in a fully-free... (cannot be: it's mapped)
    region = asp.alloc_free_huge_region(cfg, state, hp_range)
    ok = region >= 0
    n_sel = valid.sum()

    safe_pages = jnp.where(valid, pages, 0)
    old_gpa = state.gpt[safe_pages]
    # never move a page onto itself (possible if caller passes a page that
    # already lives in `region`, which alloc guarantees not to happen)
    new_gpa = region * cfg.hp_ratio + jnp.arange(cfg.hp_ratio, dtype=jnp.int32)
    do_move = valid & ok

    # ---- 2. data copy (predicated dual-pool gather/scatter) -------------
    src_slot = state.block_table[old_gpa // cfg.hp_ratio]
    src_off = old_gpa % cfg.hp_ratio
    rows = jnp.concatenate(
        [
            state.near_pool.reshape(-1, cfg.base_elems),
            state.far_pool.reshape(-1, cfg.base_elems),
        ],
        axis=0,
    )
    payload = rows[jnp.where(do_move, src_slot * cfg.hp_ratio + src_off, 0)]

    dst_slot = state.block_table[jnp.maximum(region, 0)]
    dst_off = jnp.arange(cfg.hp_ratio, dtype=jnp.int32)
    near_idx = jnp.where(do_move & (dst_slot < cfg.n_near), dst_slot, cfg.n_near)
    far_idx = jnp.where(
        do_move & (dst_slot >= cfg.n_near), dst_slot - cfg.n_near, cfg.n_far
    )
    near_pool = state.near_pool.at[near_idx, dst_off].set(payload, mode="drop")
    far_pool = state.far_pool.at[far_idx, dst_off].set(payload, mode="drop")

    # ---- 3/5. mapping updates -------------------------------------------
    gpt = state.gpt.at[jnp.where(do_move, pages, cfg.n_logical)].set(
        new_gpa, mode="drop"
    )
    rmap = state.rmap.at[jnp.where(do_move, old_gpa, cfg.n_gpa)].set(FREE, mode="drop")
    rmap = rmap.at[jnp.where(do_move, new_gpa, cfg.n_gpa)].set(
        safe_pages, mode="drop"
    )
    region_epoch = state.region_epoch.at[jnp.maximum(region, 0)].set(
        jnp.where(ok, state.epoch, state.region_epoch[jnp.maximum(region, 0)])
    )

    moved = do_move.sum()
    stats = dict(state.stats)
    stats["consolidated_pages"] = stats["consolidated_pages"] + moved.astype(jnp.int32)
    stats["consolidation_calls"] = stats["consolidation_calls"] + jnp.where(
        n_sel > 0, 1, 0
    ).astype(jnp.int32)
    stats["consolidation_enomem"] = stats["consolidation_enomem"] + jnp.where(
        (n_sel > 0) & ~ok, 1, 0
    ).astype(jnp.int32)
    stats["copied_bytes"] = stats["copied_bytes"] + (
        moved.astype(jnp.int32) * cfg.base_bytes
    )
    stats["tlb_shootdowns"] = stats["tlb_shootdowns"] + jnp.where(moved > 0, 1, 0).astype(
        jnp.int32
    )
    return dataclasses_replace(
        state,
        gpt=gpt,
        rmap=rmap,
        near_pool=near_pool,
        far_pool=far_pool,
        region_epoch=region_epoch,
        stats=stats,
    )


def consolidate_batches(
    cfg: GpacConfig, state: TieredState, batches: jax.Array, hp_range: tuple | None = None
) -> TieredState:
    """Invoke Algorithm 1 once per batch row (lax.scan over invocations --
    the paper's 'multiple invocations are required' loop)."""

    def body(st, row):
        return consolidate_pages(cfg, st, row, hp_range), None

    state, _ = jax.lax.scan(body, state, batches)
    return state
