"""Page Consolidator (paper §4.3.2, Algorithm 1) -- guest kernel-space layer.

``consolidate_pages(cfg, state, pages)`` is the functional analogue of the
paper's custom syscall: it moves up to ``hp_ratio`` (512 in the paper) base
pages into one freshly allocated, fully free huge-page-sized GPA region and
rewrites the logical->gpa mapping. Multiple invocations consolidate more
pages, exactly as in the paper. Returns -ENOMEM behaviour as a no-op +
``consolidation_enomem`` counter when no fully free huge region exists.

The data copy is the compute hot-spot; ``repro.kernels.consolidate`` provides
the Pallas TPU kernel for the common near->near path, and this module is the
general (mixed-tier, predicated) reference path used under jit on any backend.
The copy gathers straight out of whichever pool holds each source page
(zero-copy consolidation: a batch touches only ``hp_ratio`` rows, never a
materialized [near_pool; far_pool] concatenation), and
``consolidate_pages_ragged`` / ``consolidate_batches_ragged`` execute one
Algorithm-1 invocation *per guest* at once for the batched multi-tenant
engine, driven by the engine's segment-offset tables so guests may be
asymmetric (guests' GPA segments are disjoint, so rounds vectorize exactly).
Both entry points share ``_apply_consolidation`` -- the single-guest call is
the n=1 row of the batched one.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import address_space as asp
from repro.core.address_space import dataclasses_replace
from repro.core.types import FREE, GpacConfig, TieredState


def _mapping_and_stats(
    cfg: GpacConfig,
    gpt: jax.Array,
    rmap: jax.Array,
    stats: dict,
    pages: jax.Array,
    safe_pages: jax.Array,
    old_gpa: jax.Array,
    new_gpa: jax.Array,
    do_move: jax.Array,
    ok: jax.Array,
    n_sel: jax.Array,
):
    """Algorithm-1 steps 3/5 + the stats counters, shared bit-for-bit by the
    replicated (:func:`_apply_consolidation`) and host-partitioned
    (:func:`_apply_consolidation_local`) data-copy paths -- one definition,
    so the two paths cannot drift."""
    gpt = gpt.at[jnp.where(do_move, pages, cfg.n_logical)].set(
        new_gpa, mode="drop"
    )
    rmap = rmap.at[jnp.where(do_move, old_gpa, cfg.n_gpa)].set(FREE, mode="drop")
    rmap = rmap.at[jnp.where(do_move, new_gpa, cfg.n_gpa)].set(
        safe_pages, mode="drop"
    )
    moved_per_row = do_move.sum(axis=1)
    moved = moved_per_row.sum()
    stats = dict(stats)
    stats["consolidated_pages"] = stats["consolidated_pages"] + moved.astype(jnp.int32)
    stats["consolidation_calls"] = stats["consolidation_calls"] + (
        n_sel > 0
    ).sum().astype(jnp.int32)
    stats["consolidation_enomem"] = stats["consolidation_enomem"] + (
        (n_sel > 0) & ~ok
    ).sum().astype(jnp.int32)
    stats["copied_bytes"] = stats["copied_bytes"] + (
        moved.astype(jnp.int32) * cfg.base_bytes
    )
    stats["tlb_shootdowns"] = stats["tlb_shootdowns"] + (
        moved_per_row > 0
    ).sum().astype(jnp.int32)
    return gpt, rmap, stats


def _apply_consolidation(
    cfg: GpacConfig,
    state: TieredState,
    pages: jax.Array,  # int32[n, hp_ratio] logical ids, -1 padded
    region: jax.Array,  # int32[n] fresh region per row, -1 = -ENOMEM
    kernel_backend: str = "auto",
) -> TieredState:
    """Shared core of Algorithm 1: execute ``n`` independent invocations at
    once (rows must touch disjoint pages/regions -- one row, or one row per
    guest segment).

    Steps (mirroring Algorithm 1; step 1, the region allocation, is done by
    the callers):
      2. for each old_page i: copy payload old -> region[i]
      3. set_pte_at: gpt[logical] = region*hp_ratio+i ; rmap updates
      4. flush_tlb_mm_range: fused-translation caches are invalidated by
         bumping stats['tlb_shootdowns'] (callers drop cached fused tables)
      5. free(old_page): old gpa rmap entries -> FREE
    """
    valid = (pages >= 0) & (pages < cfg.n_logical)
    ok = region >= 0
    n_sel = valid.sum(axis=1)

    safe_pages = jnp.where(valid, pages, 0)
    old_gpa = state.gpt[safe_pages]  # [n, hp_ratio]
    off = jnp.arange(cfg.hp_ratio, dtype=jnp.int32)
    new_gpa = region[:, None] * cfg.hp_ratio + off
    do_move = valid & ok[:, None]

    # ---- 2. data copy (predicated dual-pool gather/scatter) -------------
    # Gather hp_ratio rows straight out of whichever pool holds each source
    # page -- no [near_pool; far_pool] concatenation, which would materialize
    # every slot's payload inside each lax.scan invocation (the same idiom
    # tiering.swap_blocks uses). The per-pool row gathers dispatch to the
    # scalar-prefetched gather_rows kernel (DESIGN.md §16) -- gathers are
    # pure copies, so both backends are bitwise identical for any dtype.
    from repro.kernels import registry as kernels

    src_slot = state.block_table[old_gpa // cfg.hp_ratio]
    src_off = old_gpa % cfg.hp_ratio
    src_flat = jnp.where(do_move, src_slot * cfg.hp_ratio + src_off, 0)
    src_is_near = src_flat < cfg.n_near * cfg.hp_ratio
    near_rows = state.near_pool.reshape(-1, cfg.base_elems)
    far_rows = state.far_pool.reshape(-1, cfg.base_elems)
    payload = jnp.where(
        src_is_near[..., None],
        kernels.dispatch("gather_rows", kernel_backend, near_rows,
                         jnp.where(src_is_near, src_flat, 0)),
        kernels.dispatch(
            "gather_rows", kernel_backend, far_rows,
            jnp.where(src_is_near, 0, src_flat - cfg.n_near * cfg.hp_ratio)),
    )  # [n, hp_ratio, base_elems]

    dst_slot = state.block_table[jnp.maximum(region, 0)][:, None]  # [n, 1]
    near_idx = jnp.where(do_move & (dst_slot < cfg.n_near), dst_slot, cfg.n_near)
    far_idx = jnp.where(
        do_move & (dst_slot >= cfg.n_near), dst_slot - cfg.n_near, cfg.n_far
    )
    dst_off = jnp.broadcast_to(off, pages.shape)
    near_pool = state.near_pool.at[near_idx, dst_off].set(payload, mode="drop")
    far_pool = state.far_pool.at[far_idx, dst_off].set(payload, mode="drop")

    # ---- 3/5. mapping updates (row-disjoint scatters) --------------------
    region_epoch = state.region_epoch.at[
        jnp.where(ok, region, cfg.n_gpa_hp)
    ].set(state.epoch, mode="drop")
    gpt, rmap, stats = _mapping_and_stats(
        cfg, state.gpt, state.rmap, state.stats, pages, safe_pages, old_gpa,
        new_gpa, do_move, ok, n_sel,
    )
    return dataclasses_replace(
        state,
        gpt=gpt,
        rmap=rmap,
        near_pool=near_pool,
        far_pool=far_pool,
        region_epoch=region_epoch,
        stats=stats,
    )


def consolidate_pages(
    cfg: GpacConfig,
    state: TieredState,
    pages: jax.Array,
    hp_range: tuple | None = None,
) -> TieredState:
    """One Algorithm-1 invocation.

    ``pages``: int32[hp_ratio] logical page ids, padded with -1. Pages are
    packed into slots 0..k-1 of the fresh region in the given order.
    ``hp_range`` optionally confines the fresh region to one guest's GPA
    segment (multi-tenant simulation).
    """
    pages = pages.astype(jnp.int32)
    if pages.shape != (cfg.hp_ratio,):
        raise ValueError(f"pages must be int32[{cfg.hp_ratio}]")
    # 1. huge_region <- alloc(HPAGE_SIZE)              (fully free GPA region)
    region = asp.alloc_free_huge_region(cfg, state, hp_range)
    return _apply_consolidation(cfg, state, pages[None, :], region[None])


def consolidate_batches(
    cfg: GpacConfig, state: TieredState, batches: jax.Array, hp_range: tuple | None = None
) -> TieredState:
    """Invoke Algorithm 1 once per batch row (lax.scan over invocations --
    the paper's 'multiple invocations are required' loop)."""

    def body(st, row):
        return consolidate_pages(cfg, st, row, hp_range), None

    state, _ = jax.lax.scan(body, state, batches)
    return state


# --------------------------------------------------------------------------
# multi-tenant batched rounds (one Algorithm-1 invocation per guest at once)
# --------------------------------------------------------------------------
def _alloc_regions_ragged(
    cfg: GpacConfig, rmap: jax.Array, hp_pad_idx: jax.Array
) -> jax.Array:
    """Per-guest fresh region: the first fully-free huge page of each guest's
    GPA segment, found through the padded segment table ``hp_pad_idx``
    (``int32[n_guests, max_hp]``, -1 past each segment). -1 = -ENOMEM.
    Takes the raw ``rmap`` so the host-partitioned engine (which carries no
    full ``TieredState``) can share it."""
    free = (rmap.reshape(cfg.n_gpa_hp, cfg.hp_ratio) == FREE).all(axis=1)
    fp = (hp_pad_idx >= 0) & free[jnp.maximum(hp_pad_idx, 0)]
    first = jnp.argmax(fp, axis=1)
    region = jnp.take_along_axis(hp_pad_idx, first[:, None], axis=1)[:, 0]
    return jnp.where(fp.any(axis=1), region, jnp.int32(-1))


def consolidate_pages_ragged(
    spec,  # repro.core.engine.EngineSpec
    state: TieredState,
    pages: jax.Array,  # int32[n_guests, hp_ratio] logical ids, -1 padded
) -> TieredState:
    """One *round*: every guest's Algorithm-1 invocation executed at once.

    Guests may be ragged; their GPA segments (the spec's offset tables) are
    disjoint and tile ``[0, n_gpa_hp)``. Guest g's fresh region comes from
    its own segment and its pages live in its own segment, so the per-guest
    invocations touch disjoint mapping/pool regions and one vectorized
    gather/scatter reproduces N sequential :func:`consolidate_pages` calls
    bit-for-bit.
    """
    cfg = spec.cfg
    pages = pages.astype(jnp.int32)
    if pages.shape != (spec.n_guests, cfg.hp_ratio):
        raise ValueError(
            f"pages must be int32[{spec.n_guests}, {cfg.hp_ratio}], got {pages.shape}"
        )
    region = _alloc_regions_ragged(
        cfg, state.rmap, jnp.asarray(spec.hp_pad_index())
    )
    return _apply_consolidation(cfg, state, pages, region,
                                spec.kernel_backend)


def consolidate_rounds(
    cfg: GpacConfig,
    state: TieredState,
    batches: jax.Array,  # int32[n_rows, max_batches, hp_ratio]
    hp_pad_idx: jax.Array,  # int32[n_rows, max_hp] GPA segment table rows
    kernel_backend: str = "auto",
) -> TieredState:
    """Round-major consolidation over any slice of guest segment rows:
    round b allocates each row's fresh region from its own GPA segment
    (``hp_pad_idx``) and executes every row's b-th Algorithm-1 invocation at
    once. Shared by :func:`consolidate_batches_ragged` (all guests),
    the deprecated symmetric wrappers, and the device-sharded engine (each
    device passes only its own guests' rows)."""

    def body(st, round_pages):
        region = _alloc_regions_ragged(cfg, st.rmap, hp_pad_idx)
        return _apply_consolidation(
            cfg, st, round_pages.astype(jnp.int32), region, kernel_backend
        ), None

    state, _ = jax.lax.scan(body, state, jnp.swapaxes(batches, 0, 1))
    return state


def consolidate_batches_ragged(
    spec,
    state: TieredState,
    batches: jax.Array,  # int32[n_guests, max_batches, hp_ratio]
) -> TieredState:
    """lax.scan over consolidation *rounds*: round b executes every guest's
    b-th Algorithm-1 invocation at once. Guests' invocation sequences are
    independent (disjoint segments), so round-major order produces exactly the
    guest-major sequential result while shortening the scan from
    ``n_guests * max_batches`` steps to ``max_batches``."""
    return consolidate_rounds(
        spec.cfg, state, batches, jnp.asarray(spec.hp_pad_index()),
        spec.kernel_backend,
    )


def _uniform_hp_pad(cfg: GpacConfig, n_guests: int, hp_per_guest: int):
    """Segment table for N equal GPA segments (the old ``*_multi`` contract:
    only the GPA space must tile; the logical space is unconstrained)."""
    import numpy as np

    if n_guests * hp_per_guest != cfg.n_gpa_hp:
        raise ValueError("guest GPA segments must tile the GPA space")
    return jnp.asarray(
        np.arange(cfg.n_gpa_hp, dtype=np.int32).reshape(n_guests, hp_per_guest)
    )


def consolidate_pages_multi(
    cfg: GpacConfig,
    state: TieredState,
    pages: jax.Array,  # int32[n_guests, hp_ratio]
    hp_per_guest: int,
) -> TieredState:
    """Deprecated symmetric wrapper: one round over N equal GPA segments."""
    hp_pad = _uniform_hp_pad(cfg, pages.shape[0], hp_per_guest)
    region = _alloc_regions_ragged(cfg, state.rmap, hp_pad)
    return _apply_consolidation(cfg, state, pages.astype(jnp.int32), region)


def consolidate_batches_multi(
    cfg: GpacConfig,
    state: TieredState,
    batches: jax.Array,  # int32[n_guests, max_batches, hp_ratio]
    hp_per_guest: int,
) -> TieredState:
    """Deprecated symmetric wrapper: scanned rounds over N equal GPA
    segments."""
    hp_pad = _uniform_hp_pad(cfg, batches.shape[0], hp_per_guest)
    return consolidate_rounds(cfg, state, batches, hp_pad)


# --------------------------------------------------------------------------
# host-partitioned rounds (DESIGN.md §11: hp-owned payload, no slot pools)
# --------------------------------------------------------------------------
def _apply_consolidation_local(
    cfg: GpacConfig,
    gpt: jax.Array,
    rmap: jax.Array,
    data: jax.Array,  # dtype[h_loc, hp_ratio, base_elems] hp-owned payload
    re_loc: jax.Array,  # int32[h_loc] local region_epoch rows
    epoch: jax.Array,
    stats: dict,
    pages: jax.Array,  # int32[n, hp_ratio] logical ids, -1 padded
    region: jax.Array,  # int32[n] fresh region per row, -1 = -ENOMEM
    hp_lo: jax.Array,  # first huge page of this device's block range
    kernel_backend: str = "auto",
):
    """:func:`_apply_consolidation` on the host-partitioned layout.

    The mapping updates are byte-identical; the data copy runs on the
    device's hp-owned payload rows -- huge page ``h`` lives at
    ``data[h - hp_lo]``, which equals the slot-indexed pool row
    ``pools[block_table[h]]`` of the replicated state, so gathering source
    pages by huge page and scattering into the fresh region's row is
    bit-for-bit the replicated dual-pool copy. Sources and regions both sit
    in the calling guest's own GPA segment, hence inside this device's range.
    """
    valid = (pages >= 0) & (pages < cfg.n_logical)
    ok = region >= 0
    n_sel = valid.sum(axis=1)

    safe_pages = jnp.where(valid, pages, 0)
    old_gpa = gpt[safe_pages]  # [n, hp_ratio]
    off = jnp.arange(cfg.hp_ratio, dtype=jnp.int32)
    new_gpa = region[:, None] * cfg.hp_ratio + off
    do_move = valid & ok[:, None]

    h_loc = data.shape[0]
    src_row = jnp.clip(
        jnp.where(do_move, old_gpa // cfg.hp_ratio - hp_lo, 0), 0, h_loc - 1
    )
    # the 2-D fancy index data[src_row, off] as a flat row gather so it can
    # dispatch to the gather_rows kernel (a gather is a pure copy -- both
    # backends are bitwise identical); src_row is clipped and the offset is
    # jnp's python-style modulo, so every flat id is in range
    from repro.kernels import registry as kernels

    flat_rows = data.reshape(h_loc * cfg.hp_ratio, cfg.base_elems)
    flat_src = src_row * cfg.hp_ratio + old_gpa % cfg.hp_ratio
    payload = kernels.dispatch(
        "gather_rows", kernel_backend, flat_rows, flat_src
    )  # [n, hp_ratio, elems]
    dst_row = jnp.where(do_move, region[:, None] - hp_lo, h_loc)
    data = data.at[dst_row, jnp.broadcast_to(off, pages.shape)].set(
        payload, mode="drop"
    )
    re_loc = re_loc.at[jnp.where(ok, region - hp_lo, h_loc)].set(
        epoch, mode="drop"
    )
    gpt, rmap, stats = _mapping_and_stats(
        cfg, gpt, rmap, stats, pages, safe_pages, old_gpa, new_gpa, do_move,
        ok, n_sel,
    )
    return gpt, rmap, data, re_loc, stats


def consolidate_rounds_local(
    cfg: GpacConfig,
    gpt: jax.Array,
    rmap: jax.Array,
    data: jax.Array,
    re_loc: jax.Array,
    epoch: jax.Array,
    stats: dict,
    batches: jax.Array,  # int32[n_rows, max_batches, hp_ratio]
    hp_pad_idx: jax.Array,  # int32[n_rows, max_hp] this device's GPA rows
    hp_lo: jax.Array,
    kernel_backend: str = "auto",
):
    """:func:`consolidate_rounds` for the host-partitioned engine: round-major
    Algorithm-1 invocations over this device's own guest rows, with the data
    copy on the hp-owned payload (``data``) instead of the slot pools."""

    def body(carry, round_pages):
        gpt, rmap, data, re_loc, stats = carry
        region = _alloc_regions_ragged(cfg, rmap, hp_pad_idx)
        return _apply_consolidation_local(
            cfg, gpt, rmap, data, re_loc, epoch, stats,
            round_pages.astype(jnp.int32), region, hp_lo, kernel_backend,
        ), None

    carry, _ = jax.lax.scan(
        body, (gpt, rmap, data, re_loc, stats), jnp.swapaxes(batches, 0, 1)
    )
    return carry
