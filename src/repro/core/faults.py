"""Deterministic fault injection for the steady-state churn engine.

Generalizes the pattern of ``repro.train.fault`` (plain-dataclass schedules,
pure policy functions, numpy host side) to the tiering engine: a
:class:`FaultSchedule` is an *injectable, replayable* list of events --

  * ``crash(w, g)``    -- guest ``g`` dies at window ``w``: its lane goes
    inactive, every block it holds is reclaimed (rmap freed, telemetry
    cleared, payload wiped) inside that same window;
  * ``restart(w, g)``  -- an inactive lane comes (back) up at window ``w``
    with a fresh identity mapping (``engine.init_engine_state``'s layout),
    modelling a VM boot/reboot;
  * ``shrink(w, cap)`` -- the effective near-tier capacity becomes ``cap``
    blocks from window ``w`` on (the pressure controller in
    ``tiering.pressure_tick`` demotes down to it with hysteresis);
  * ``dropout(w)``     -- the telemetry of window ``w`` is lost (accesses
    still hit memory -- the per-window hit collectors see them -- but no
    counters/histories are charged, like a dropped PEBS buffer).

Schedules compile (:meth:`FaultSchedule.tables`) into dense per-window
:class:`FaultTables` that ride the engine scan as ordinary ``xs`` arrays.
``near_cap`` is a precomputed absolute step function (not per-window deltas),
so slicing the tables at any chunk boundary yields the same per-window values
-- fault scenarios are bit-reproducible across chunkings and meshes.

The device side is one traceable function, :func:`apply_guest_faults`: with
all-``False`` rows it is value-exact identity (the churn engine's no-fault
runs stay bit-identical to ``engine.run`` -- DESIGN.md INV-CHURN-NOOP-EXACT).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import FREE, TieredState


# --------------------------------------------------------------------------
# host-side schedule
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FaultTables:
    """Dense per-window fault rows for ``n_windows`` absolute windows
    starting at ``start`` (the engine scan's xs)."""

    start: int
    crash: np.ndarray  # bool[n_windows, n_guests]
    restart: np.ndarray  # bool[n_windows, n_guests]
    near_cap: np.ndarray  # int32[n_windows] absolute effective capacity
    drop: np.ndarray  # bool[n_windows] telemetry dropout

    @property
    def n_windows(self) -> int:
        return self.crash.shape[0]

    @property
    def n_guests(self) -> int:
        return self.crash.shape[1]


@dataclasses.dataclass
class FaultSchedule:
    """An ordered, replayable set of fault events over absolute windows.

    Builder methods chain: ``FaultSchedule(4).crash(2, 1).restart(5, 1)``.
    Events are sparse and window-addressed; :meth:`tables` densifies them
    for the scan. ``shrink`` events before the compiled range still apply
    (the capacity step function is cumulative), so resuming a stepper at
    window ``w`` sees the same ``near_cap`` it would mid-run.
    """

    n_guests: int
    crashes: list = dataclasses.field(default_factory=list)  # (window, guest)
    restarts: list = dataclasses.field(default_factory=list)  # (window, guest)
    shrinks: list = dataclasses.field(default_factory=list)  # (window, cap)
    dropouts: list = dataclasses.field(default_factory=list)  # window

    def _check(self, window: int, guest: int | None = None):
        if window < 0:
            raise ValueError(f"fault window must be >= 0, got {window}")
        if guest is not None and not 0 <= guest < self.n_guests:
            raise ValueError(
                f"guest {guest} out of range [0, {self.n_guests})")

    def crash(self, window: int, guest: int) -> "FaultSchedule":
        self._check(window, guest)
        self.crashes.append((window, guest))
        return self

    def restart(self, window: int, guest: int) -> "FaultSchedule":
        self._check(window, guest)
        self.restarts.append((window, guest))
        return self

    def shrink(self, window: int, near_cap: int) -> "FaultSchedule":
        """Effective near capacity becomes ``near_cap`` blocks from
        ``window`` on (clamped to ``[0, cfg.n_near]`` at compile time; a
        later shrink event overrides -- growing back is allowed)."""
        self._check(window)
        if near_cap < 0:
            raise ValueError(f"near_cap must be >= 0, got {near_cap}")
        self.shrinks.append((window, near_cap))
        return self

    def dropout(self, window: int, n_windows: int = 1) -> "FaultSchedule":
        self._check(window)
        self.dropouts.extend(range(window, window + n_windows))
        return self

    @property
    def n_events(self) -> int:
        return (len(self.crashes) + len(self.restarts)
                + len(self.shrinks) + len(self.dropouts))

    def tables(self, n_windows: int, n_near: int, start: int = 0) -> FaultTables:
        """Compile to dense rows for absolute windows
        ``[start, start + n_windows)``. Guest events outside the range are
        dropped; ``shrink`` events at or before a window apply to it."""
        crash = np.zeros((n_windows, self.n_guests), bool)
        restart = np.zeros((n_windows, self.n_guests), bool)
        drop = np.zeros((n_windows,), bool)
        for w, g in self.crashes:
            if start <= w < start + n_windows:
                crash[w - start, g] = True
        for w, g in self.restarts:
            if start <= w < start + n_windows:
                restart[w - start, g] = True
        for w in self.dropouts:
            if start <= w < start + n_windows:
                drop[w - start] = True
        near_cap = np.full((n_windows,), n_near, np.int32)
        for w, cap in sorted(self.shrinks):  # later events override earlier
            lo = max(w - start, 0)
            if lo < n_windows:
                near_cap[lo:] = min(cap, n_near)
        return FaultTables(
            start=start, crash=crash, restart=restart,
            near_cap=near_cap, drop=drop,
        )


def no_faults(n_guests: int) -> FaultSchedule:
    """An empty schedule (compiles to all-no-op tables)."""
    return FaultSchedule(n_guests)


def poisson_churn(
    n_guests: int,
    n_windows: int,
    arrival_rate: float = 0.2,
    departure_rate: float = 0.02,
    seed: int = 0,
    initially_active: np.ndarray | None = None,
    start: int = 0,
) -> FaultSchedule:
    """A deterministic Poisson arrival/departure mix (the churn benchmark's
    driver): per window, each active guest departs (crashes) with
    probability ``departure_rate`` and ``Poisson(arrival_rate)`` waiting
    lanes boot (restart), capped by the free lanes. Seeded numpy, so the
    same arguments always produce the same schedule."""
    rng = np.random.default_rng(seed)
    active = (np.ones(n_guests, bool) if initially_active is None
              else np.asarray(initially_active, bool).copy())
    sched = FaultSchedule(n_guests)
    for w in range(start, start + n_windows):
        leaving = np.nonzero(active & (rng.random(n_guests) < departure_rate))[0]
        for g in leaving:
            sched.crash(w, int(g))
            active[g] = False
        idle = np.nonzero(~active)[0]
        n_arrive = min(int(rng.poisson(arrival_rate)), idle.size)
        for g in rng.choice(idle, size=n_arrive, replace=False):
            sched.restart(w, int(g))
            active[g] = True
    return sched


# --------------------------------------------------------------------------
# device side
# --------------------------------------------------------------------------
@lru_cache(maxsize=None)
def segment_tables(spec) -> tuple:
    """Per-spec numpy constants for vectorized fault application (baked in
    at trace time, like the engine's segment-offset tables):

    ``logical_owner`` int32[n_logical] / ``hp_owner`` int32[n_gpa_hp`` --
    owning guest of each logical page / GPA huge page (-1 unowned);
    ``ident_gpt`` int32[n_logical] / ``ident_rmap`` int32[n_gpa] -- the
    fresh identity mapping of ``engine.init_engine_state`` (what a restart
    rewrites a guest's segment to).
    """
    cfg = spec.cfg
    logical_owner = np.full((cfg.n_logical,), -1, np.int32)
    hp_owner = np.full((cfg.n_gpa_hp,), -1, np.int32)
    ident_gpt = np.full((cfg.n_logical,), -1, np.int64)
    ident_rmap = np.full((cfg.n_gpa,), -1, np.int64)
    for g, guest in enumerate(spec.guests):
        lo, hi = spec.logical_range(g)
        hp_lo, hp_hi = spec.hp_range(g)
        logical_owner[lo:hi] = g
        hp_owner[hp_lo:hp_hi] = g
        gpa = hp_lo * cfg.hp_ratio + np.arange(guest.n_logical)
        ident_gpt[lo:hi] = gpa
        ident_rmap[gpa] = np.arange(lo, hi)
    return (
        logical_owner,
        hp_owner,
        ident_gpt.astype(np.int32),
        ident_rmap.astype(np.int32),
    )


def _guest_mask(owner: np.ndarray, per_guest: jax.Array) -> jax.Array:
    """Lift a per-guest bool vector onto a segment-owner index table
    (unowned rows -> False)."""
    own = jnp.asarray(owner)
    return jnp.where(own >= 0, per_guest[jnp.maximum(own, 0)], False)


def apply_guest_faults(
    spec,
    state: TieredState,
    active: jax.Array,  # bool[n_guests]
    crash: jax.Array,  # bool[n_guests] this window's crash row
    restart: jax.Array,  # bool[n_guests] this window's restart row
) -> tuple[TieredState, jax.Array]:
    """Apply one window's guest crash/restart row. Traceable; value-exact
    identity when both rows are all-False.

    Crash (active lanes only): the guest's whole GPA segment is freed
    (``rmap = FREE`` -> every block it held reads unallocated, so the
    ``near_blocks`` collector reports 0 **this same window** and the tier
    policies treat its slots as preferred victims -- INV-CRASH-RECLAIM-
    COMPLETE), its telemetry is cleared and its payload wiped. ``gpt`` keeps
    its stale entries: an inactive lane is never translated (the stepper
    masks its accesses to -1) and a restart rewrites them.

    Restart (inactive lanes only): fresh identity mapping per
    ``engine.init_engine_state`` / ``serve.Engine._reset_slot_placement``.
    A crash and restart of the same guest in one window is a reboot (crash
    applies first, freeing the lane the restart then claims).
    """
    cfg = spec.cfg
    logical_owner, hp_owner, ident_gpt, ident_rmap = segment_tables(spec)

    crash_eff = crash & active
    active = active & ~crash_eff
    restart_eff = restart & ~active
    active = active | restart_eff
    reset = crash_eff | restart_eff

    reset_l = _guest_mask(logical_owner, reset)
    reset_hp = _guest_mask(hp_owner, reset)
    crash_gpa = jnp.repeat(_guest_mask(hp_owner, crash_eff), cfg.hp_ratio)
    restart_l = _guest_mask(logical_owner, restart_eff)
    restart_gpa = jnp.repeat(_guest_mask(hp_owner, restart_eff), cfg.hp_ratio)

    # mappings: crash frees the segment, restart rewrites it to identity
    # (ident_rmap is already FREE in the slack, so restart fully defines it)
    rmap = jnp.where(crash_gpa, FREE, state.rmap)
    rmap = jnp.where(restart_gpa, jnp.asarray(ident_rmap), rmap)
    gpt = jnp.where(restart_l, jnp.asarray(ident_gpt), state.gpt)

    # telemetry: both transitions clear the guest's counters/histories
    zero_l = jnp.zeros((), jnp.int32)
    guest_counts = jnp.where(reset_l, zero_l, state.guest_counts)
    ipt_hist = jnp.where(reset_l, jnp.zeros((), jnp.uint8), state.ipt_hist)
    host_counts = jnp.where(reset_hp, zero_l, state.host_counts)
    host_hist = jnp.where(reset_hp, jnp.zeros((), jnp.uint8), state.host_hist)
    last_touch = jnp.where(reset_hp, zero_l, state.last_touch_epoch)
    region_epoch = jnp.where(reset_hp, jnp.int32(-1), state.region_epoch)

    # payload: wipe the pool rows of every slot holding a reset guest's
    # huge page (slot_owner is the block_table inverse, maintained by
    # swap_blocks, so this reaches the blocks wherever they live now)
    reset_slot = reset_hp[state.slot_owner]
    near_pool = jnp.where(
        reset_slot[: cfg.n_near][:, None, None], 0, state.near_pool)
    far_pool = jnp.where(
        reset_slot[cfg.n_near :][:, None, None], 0, state.far_pool)

    state = dataclasses.replace(
        state,
        gpt=gpt,
        rmap=rmap,
        guest_counts=guest_counts,
        ipt_hist=ipt_hist,
        host_counts=host_counts,
        host_hist=host_hist,
        last_touch_epoch=last_touch,
        region_epoch=region_epoch,
        near_pool=near_pool,
        far_pool=far_pool,
    )
    return state, active
