"""Unified simulation engine: ragged multi-tenant guests on one shared driver.

This module is the single entry point for every paper-figure simulation,
single- or multi-guest (DESIGN.md §7). It replaces the old symmetric-only
``simulate.MultiGuest`` surface with explicit geometry specs:

* :class:`GuestSpec` -- one guest's shape: ``n_logical`` base pages, an
  optional per-guest Consolidation Limit, GPA slack, and the trace
  workload/seed the helpers use to synthesize its accesses.
* :class:`HostSpec` -- the shared host: huge-page ratio, near-tier sizing,
  telemetry/policy knobs that fill the combined :class:`GpacConfig`.
* :class:`EngineSpec` -- the compiled-in geometry: the combined config plus
  **segment-offset tables** mapping each guest to its logical and GPA huge
  page ranges. Guests may be *ragged* (distinct sizes, slacks and CLs);
  nothing assumes the uniform tiling the old reshape-based reductions needed.

On top of the geometry sits **one** scan-fused driver, :func:`run`: the
window loop of the old ``gpac.run_windows`` and ``simulate.run_multi_guest``
(both now thin deprecation shims over this function) runs as a device-side
``lax.scan`` chunked by ``windows_per_step``, with one host transfer per
chunk. Per-window measurement is pluggable: on-device **metric collectors**
registered via :func:`register_collector` run inside the scan and their
stacked outputs cross to the host once per chunk.

Equivalence: :func:`run_reference` preserves the sequential per-guest /
per-window formulation (guest g's GPAC daemon confined to its own segment
via ``allow``/``hp_range``); tests pin the ragged engine bit-for-bit against
it across every registered policy.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import address_space as asp
from repro.core import faults as faults_mod
from repro.core import gpac, metrics, telemetry, tiering
from repro.core import tiers as tiers_mod
from repro.core.types import GpacConfig, TieredState, allocated_hp_mask, init_state
from repro.kernels import registry as kernels_registry


# --------------------------------------------------------------------------
# geometry specs
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GuestSpec:
    """One guest's geometry and trace identity.

    ``cl=None`` inherits the host default (``GpacConfig.cl``); a value gives
    this guest its own Consolidation Limit (paper §4.3.1 -- Table 3 tunes CL
    per workload, so heterogeneous tenants need per-guest CLs).
    ``gpa_slack`` is the extra GPA huge-page headroom beyond the minimum
    ``ceil(n_logical / hp_ratio)`` (the paper's far tier is much larger than
    the guests, so consolidation never starves for free regions).
    """

    n_logical: int
    cl: int | None = None
    gpa_slack: float = 0.25
    workload: str = "redis"
    seed: int = 0

    def hp_need(self, hp_ratio: int) -> int:
        return -(-self.n_logical // hp_ratio)

    def hp_size(self, hp_ratio: int) -> int:
        need = self.hp_need(hp_ratio)
        return need + max(2, int(need * self.gpa_slack))


@dataclasses.dataclass(frozen=True)
class HostSpec:
    """Shared host geometry + default policy knobs for the combined config.

    ``near_fraction`` sizes the near tier as a fraction of the guests' total
    *needed* huge pages (the paper's DRAM:NVMM ratio knob, Fig. 17);
    ``n_near`` overrides it with an explicit block count. ``tiers`` replaces
    both with an N-tier hierarchy: a tuple of ``core.tiers.TierSpec`` whose
    capacity fractions ``build`` resolves into slot boundaries (tier 0
    becomes the near pool); it is mutually exclusive with ``n_near``.
    """

    hp_ratio: int = 512
    near_fraction: float = 0.5
    n_near: int = 0
    base_elems: int = 8
    cl: int = 64
    hot_threshold: int = 1
    ipt_windows: int = 8
    ipt_min_hits: int = 1
    reconsolidate_cooldown: int = 2
    dtype: Any = jnp.float32
    tiers: tuple | None = None

    def __post_init__(self):
        if self.hp_ratio < 1:
            raise ValueError(
                f"HostSpec: hp_ratio must be >= 1, got {self.hp_ratio}")
        if not 0.0 < self.near_fraction <= 1.0:
            raise ValueError(
                f"HostSpec: near_fraction must be in (0, 1], got "
                f"{self.near_fraction}")
        if self.n_near < 0:
            raise ValueError(
                f"HostSpec: n_near must be >= 0 (0 means derive from "
                f"near_fraction), got {self.n_near}")
        if self.base_elems < 1:
            raise ValueError(
                f"HostSpec: base_elems must be >= 1, got {self.base_elems}")
        if not 1 <= self.cl <= self.hp_ratio:
            raise ValueError(
                f"HostSpec: Consolidation Limit must be in [1, hp_ratio="
                f"{self.hp_ratio}], got cl={self.cl}")
        if self.tiers is not None:
            if self.n_near:
                raise ValueError(
                    f"HostSpec: tiers and n_near are mutually exclusive "
                    f"(tier 0's capacity sizes the near pool), got n_near="
                    f"{self.n_near} with {len(self.tiers)} tiers")
            object.__setattr__(self, "tiers", tuple(self.tiers))
            if len(self.tiers) < 2:
                raise ValueError(
                    f"HostSpec: tiers needs >= 2 entries, got "
                    f"{len(self.tiers)}")
            for t in self.tiers:
                if not isinstance(t, tiers_mod.TierSpec):
                    raise ValueError(
                        f"HostSpec: tiers entries must be TierSpec, got "
                        f"{type(t).__name__}: {t!r}")


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Static engine geometry: combined config + per-guest segment offsets.

    ``logical_offsets`` / ``hp_offsets`` are cumulative: guest ``g`` owns
    logical pages ``[logical_offsets[g], logical_offsets[g+1])`` and GPA huge
    pages ``[hp_offsets[g], hp_offsets[g+1])``. Segments are disjoint and
    tile their spaces, which is what lets N per-guest GPAC daemons run as one
    batched pass bit-for-bit (DESIGN.md §7). Hashable, so it jits as a static
    argument; the padded index tables below are numpy constants baked in at
    trace time.
    """

    cfg: GpacConfig
    guests: tuple[GuestSpec, ...]
    logical_offsets: tuple[int, ...]  # len n_guests+1
    hp_offsets: tuple[int, ...]  # len n_guests+1
    # resolved core.tiers.TierVector when built from HostSpec.tiers; None
    # keeps every legacy path on the 2-tier near/far special case
    tiers: Any = None
    # hot-path kernel dispatch ("xla" | "pallas" | "auto", DESIGN.md §16);
    # static, so it rides every jit cache key with the rest of the spec
    kernel_backend: str = "auto"
    # run the host arbitration tick only every this-many windows (DESIGN.md
    # §17): telemetry, GPAC and the pressure controller still run every
    # window, but promotion/demotion arbitration -- and on the
    # host-partitioned path its candidate-exchange collective -- is batched
    # over the stride. 1 (the default) is the paper's per-window tick,
    # bit-identical to the pre-knob engine on every driver; >1 trades
    # arbitration latency for collective count (the HybridTier-style
    # coarse-signal trade-off). Static, like kernel_backend.
    arbitration_stride: int = 1

    @property
    def n_guests(self) -> int:
        return len(self.guests)

    @property
    def tier_vector(self):
        """The resolved hierarchy, defaulting to the legacy 2-tier split."""
        return tiers_mod.as_vector(self.cfg, self.tiers)

    def logical_range(self, g: int) -> tuple[int, int]:
        return self.logical_offsets[g], self.logical_offsets[g + 1]

    def hp_range(self, g: int) -> tuple[int, int]:
        return self.hp_offsets[g], self.hp_offsets[g + 1]

    def guest_cl(self, g: int) -> int:
        cl = self.guests[g].cl
        return self.cfg.cl if cl is None else cl

    @property
    def max_logical(self) -> int:
        return max(hi - lo for lo, hi in zip(self.logical_offsets, self.logical_offsets[1:]))

    @property
    def max_hp(self) -> int:
        return max(hi - lo for lo, hi in zip(self.hp_offsets, self.hp_offsets[1:]))

    # ---- segment-offset tables (numpy: trace-time constants) ------------
    def logical_pad_index(self) -> np.ndarray:
        """int32[n_guests, max_logical]: row g = guest g's global logical ids,
        -1 padded past its segment (the ragged replacement for the old
        ``score.reshape(n_guests, logical_per_guest)``)."""
        out = np.full((self.n_guests, self.max_logical), -1, np.int32)
        for g in range(self.n_guests):
            lo, hi = self.logical_range(g)
            out[g, : hi - lo] = np.arange(lo, hi, dtype=np.int32)
        return out

    def hp_pad_index(self) -> np.ndarray:
        """int32[n_guests, max_hp]: row g = guest g's global GPA huge-page
        ids, -1 padded."""
        out = np.full((self.n_guests, self.max_hp), -1, np.int32)
        for g in range(self.n_guests):
            lo, hi = self.hp_range(g)
            out[g, : hi - lo] = np.arange(lo, hi, dtype=np.int32)
        return out

    def cl_per_logical(self) -> np.ndarray:
        """int32[n_logical]: the effective CL of the guest owning each
        logical page (lets one global candidate mask honour per-guest CLs)."""
        out = np.empty((self.cfg.n_logical,), np.int32)
        for g in range(self.n_guests):
            lo, hi = self.logical_range(g)
            out[lo:hi] = self.guest_cl(g)
        return out

    def localize(self, local_ids: jax.Array) -> jax.Array:
        """Guest-local page ids ``int32[n_guests, k]`` -> combined-space ids
        (-1 padding passes through), via the per-guest segment offsets."""
        lo = jnp.asarray(
            np.asarray(self.logical_offsets[:-1], np.int32)
        )[:, None]
        return jnp.where(local_ids >= 0, local_ids + lo, -1)

    def canonical(self) -> "EngineSpec":
        """The spec with trace-identity fields (workload, seed) normalized
        away. Those fields never enter traced computation, but as part of the
        static jit key they would force a full recompile per seed/workload
        sweep -- the drivers dispatch on this canonical form instead."""
        guests = tuple(
            dataclasses.replace(g, workload="", seed=0) for g in self.guests
        )
        return dataclasses.replace(self, guests=guests)


# --------------------------------------------------------------------------
# construction
# --------------------------------------------------------------------------
def build(
    guests: tuple[GuestSpec, ...] | list,
    host: HostSpec = HostSpec(),
) -> tuple[EngineSpec, TieredState]:
    """Build N (possibly ragged) guests over one shared host space.

    Returns the static :class:`EngineSpec` and the initial state: guest g's
    logical pages are identity-placed at the start of its own GPA segment
    (same layout the old ``make_multi_guest`` produced for symmetric guests).
    """
    guests = tuple(
        GuestSpec(n_logical=g) if isinstance(g, int) else g for g in guests
    )
    if not guests:
        raise ValueError("need at least one GuestSpec")
    hp_sizes = [g.hp_size(host.hp_ratio) for g in guests]
    logical_offsets = tuple(np.cumsum([0] + [g.n_logical for g in guests]).tolist())
    hp_offsets = tuple(np.cumsum([0] + hp_sizes).tolist())
    n_hp = hp_offsets[-1]
    total_need = sum(g.hp_need(host.hp_ratio) for g in guests)
    tv = None
    if host.tiers is not None:
        tv = tiers_mod.resolve(host.tiers, n_slots=n_hp, total_need=total_need)
        n_near = tv.boundaries[1]
    else:
        n_near = host.n_near or max(1, int(host.near_fraction * total_need))
    cfg = GpacConfig(
        n_logical=logical_offsets[-1],
        hp_ratio=host.hp_ratio,
        n_gpa_hp=n_hp,
        n_near=min(n_near, n_hp - 1),
        base_elems=host.base_elems,
        cl=host.cl,
        hot_threshold=host.hot_threshold,
        ipt_windows=host.ipt_windows,
        ipt_min_hits=host.ipt_min_hits,
        reconsolidate_cooldown=host.reconsolidate_cooldown,
        dtype=host.dtype,
    )
    spec = EngineSpec(cfg, guests, logical_offsets, hp_offsets, tiers=tv)
    return spec, init_engine_state(spec)


def init_engine_state(spec: EngineSpec) -> TieredState:
    """Identity-map each guest's logical pages into its own GPA segment."""
    cfg = spec.cfg
    gpt = np.full((cfg.n_logical,), -1, np.int64)
    rmap = np.full((cfg.n_gpa,), -1, np.int64)
    for g, guest in enumerate(spec.guests):
        lo, hi = spec.logical_range(g)
        hp_lo, _ = spec.hp_range(g)
        gpa = hp_lo * cfg.hp_ratio + np.arange(guest.n_logical)
        gpt[lo:hi] = gpa
        rmap[gpa] = np.arange(lo, hi)
    state = init_state(cfg)
    return asp.dataclasses_replace(
        state,
        gpt=jnp.asarray(gpt, jnp.int32),
        rmap=jnp.asarray(rmap, jnp.int32),
    )


def spec_from_config(
    cfg: GpacConfig, workload: str = "redis", seed: int = 0
) -> EngineSpec:
    """Single-guest spec spanning an existing config's whole space (the
    ``n_guests=1`` port of the old ``gpac.window_step`` callers)."""
    guest = GuestSpec(
        n_logical=cfg.n_logical, cl=cfg.cl, workload=workload, seed=seed
    )
    return EngineSpec(cfg, (guest,), (0, cfg.n_logical), (0, cfg.n_gpa_hp))


def symmetric_spec(
    cfg: GpacConfig, n_guests: int, cl: int | None = None
) -> EngineSpec:
    """Spec for N equal guests tiling an existing combined config (backs the
    deprecated ``MultiGuest``-era entry points)."""
    if cfg.n_logical % n_guests or cfg.n_gpa_hp % n_guests:
        raise ValueError(
            f"symmetric_spec: n_logical={cfg.n_logical} / n_gpa_hp="
            f"{cfg.n_gpa_hp} not divisible by n_guests={n_guests}"
        )
    lpg = cfg.n_logical // n_guests
    hpg = cfg.n_gpa_hp // n_guests
    guests = tuple(GuestSpec(n_logical=lpg, cl=cl) for _ in range(n_guests))
    return EngineSpec(
        cfg,
        guests,
        tuple(range(0, cfg.n_logical + 1, lpg)),
        tuple(range(0, cfg.n_gpa_hp + 1, hpg)),
    )


# --------------------------------------------------------------------------
# trace sources (the engine's input API, DESIGN.md §12)
# --------------------------------------------------------------------------
class TraceSource:
    """What drives the engine's windows. Two implementations:

    * :class:`ArrayTrace` -- a host-materialized packed trace
      ``int32[n_guests, n_windows, k]`` (the original input form; raw
      ndarrays passed to the drivers are wrapped in one automatically).
    * :class:`SynthTrace` -- on-device workload synthesis: each window's
      accesses are generated *inside* the scan body from the guests'
      (workload, seed) identities via ``repro.data.traces``' JAX window
      functions, so no ``[n_guests, n_windows, k]`` array ever exists --
      host or device. Per-device residency on a mesh is
      O(n_local_guests * accesses_per_window) plus the per-guest scatter
      tables, which is what lets pod-size guest counts run at all.

    Every source exposes ``n_windows`` (attribute or property).
    """


@dataclasses.dataclass(frozen=True)
class ArrayTrace(TraceSource):
    """A packed per-guest trace array (``pack_traces`` / ``guest_traces``
    output): ``int32[n_guests, n_windows, k]`` guest-local ids, -1 padded."""

    traces: np.ndarray

    def __post_init__(self):
        object.__setattr__(self, "traces", np.asarray(self.traces))

    @property
    def n_windows(self) -> int:
        return self.traces.shape[1]


@dataclasses.dataclass(frozen=True)
class SynthTrace(TraceSource):
    """On-device workload synthesis for ``n_windows`` windows of
    ``accesses_per_window`` accesses each.

    ``workloads`` / ``seeds`` default to the guests' own
    :class:`GuestSpec` identities at bind time; pass explicit tuples (one
    entry per guest) to override without rebuilding the spec. The distinct
    workload *set* is a static compile key (it selects the generator code);
    seeds and the per-guest workload assignment are traced, so sweeping
    them never recompiles.
    """

    n_windows: int
    accesses_per_window: int
    workloads: tuple[str, ...] | None = None
    seeds: tuple[int, ...] | None = None

    def __post_init__(self):
        if self.n_windows < 0:
            raise ValueError(f"n_windows must be >= 0, got {self.n_windows}")
        if self.accesses_per_window < 1:
            raise ValueError(
                f"accesses_per_window must be >= 1, got "
                f"{self.accesses_per_window}"
            )
        if self.workloads is not None:
            object.__setattr__(self, "workloads", tuple(self.workloads))
        if self.seeds is not None:
            object.__setattr__(self, "seeds", tuple(self.seeds))


def as_trace_source(x) -> TraceSource:
    """Coerce a driver input to a :class:`TraceSource` (arrays/lists wrap as
    :class:`ArrayTrace`)."""
    if isinstance(x, TraceSource):
        return x
    if isinstance(x, (np.ndarray, list, tuple)) or hasattr(x, "__array__"):
        return ArrayTrace(np.asarray(x))
    raise TypeError(
        f"expected a TraceSource or a packed trace array, got {type(x).__name__}"
    )


def _coerce_source(source, traces) -> TraceSource:
    """Resolve the driver input: the ``source`` positional (TraceSource or
    raw array) or the deprecated ``traces=`` keyword (warns and wraps)."""
    if traces is not None:
        if source is not None:
            raise TypeError("pass either a source or traces=, not both")
        import warnings

        warnings.warn(
            "the traces= keyword is deprecated; pass the trace source "
            "positionally (ArrayTrace(traces) or a SynthTrace)",
            DeprecationWarning,
            stacklevel=3,
        )
        return ArrayTrace(np.asarray(traces))
    if source is None:
        raise TypeError("run() needs a trace source (ArrayTrace / SynthTrace)")
    return as_trace_source(source)


def _bind_synth(spec: EngineSpec, synth: SynthTrace, n_shards: int = 1):
    """Bind a :class:`SynthTrace` to a spec's guests: the static
    :class:`repro.data.traces.SynthPlan` (distinct workload set + shapes)
    and the traced per-guest tables (seed, global guest id, workload index,
    size), padded to the mesh with no-op rows (``gid=-1`` emits -1
    accesses). Must run on the *pre-canonical* spec -- ``canonical()``
    blanks the workload/seed identities this reads."""
    from repro.data import traces as tr

    n_g = spec.n_guests
    workloads = synth.workloads or tuple(g.workload for g in spec.guests)
    seeds = synth.seeds if synth.seeds is not None else tuple(
        g.seed for g in spec.guests)
    if len(workloads) != n_g or len(seeds) != n_g:
        raise ValueError(
            f"SynthTrace workloads/seeds must have one entry per guest "
            f"(n_guests={n_g}), got {len(workloads)}/{len(seeds)}"
        )
    for name in workloads:
        tr.get_workload(name)  # fail fast, listing the live set
    wset = tuple(sorted(set(workloads)))
    plan = tr.SynthPlan(
        workload_set=wset,
        accesses_per_window=synth.accesses_per_window,
        hp_ratio=spec.cfg.hp_ratio,
        max_logical=spec.max_logical,
    )
    tables = dict(
        seeds=np.asarray(seeds, np.int32),
        gids=np.arange(n_g, dtype=np.int32),
        wid=np.asarray([wset.index(w) for w in workloads], np.int32),
        n_logical=np.asarray([g.n_logical for g in spec.guests], np.int32),
    )
    if n_shards > 1:
        from repro.core import sharding

        fills = dict(seeds=0, gids=-1, wid=-1, n_logical=1)
        tables = {
            k: sharding.pad_guest_rows(v, n_shards, fill=fills[k])
            for k, v in tables.items()
        }
    return plan, tables


# --------------------------------------------------------------------------
# trace helpers
# --------------------------------------------------------------------------
def pack_traces(per_guest: list[np.ndarray]) -> np.ndarray:
    """Stack ragged per-guest traces ``[n_windows, k_g]`` into one padded
    ``int32[n_guests, n_windows, k_max]`` array (-1 padding -- the engine
    treats negative ids as no-ops end to end)."""
    n_w = {t.shape[0] for t in per_guest}
    if len(n_w) != 1:
        raise ValueError(f"guests disagree on n_windows: {sorted(n_w)}")
    k = max(t.shape[1] for t in per_guest)
    out = np.full((len(per_guest), n_w.pop(), k), -1, np.int32)
    for g, t in enumerate(per_guest):
        out[g, :, : t.shape[1]] = t
    return out


def guest_traces(
    spec: EngineSpec,
    n_windows: int,
    accesses_per_window: int,
) -> np.ndarray:
    """Synthesize each guest's trace from its :class:`GuestSpec`
    workload/seed and pack them (``repro.data.traces`` numpy generators).

    Memoized over identical ``(workload, seed, n_logical)`` guests within
    the call: a symmetric fleet of N clones generates its trace once, not N
    times (the generators are deterministic per :class:`TraceSpec`, so
    sharing the array is exact). For pod-size fleets prefer
    :class:`SynthTrace` -- this host array is O(n_guests * n_windows * k).
    """
    from repro.data import traces as tr

    cache: dict[tr.TraceSpec, np.ndarray] = {}

    def one(g: GuestSpec) -> np.ndarray:
        ts = tr.TraceSpec(
            g.workload, n_logical=g.n_logical, hp_ratio=spec.cfg.hp_ratio,
            n_windows=n_windows, accesses_per_window=accesses_per_window,
            seed=g.seed)
        if ts not in cache:
            cache[ts] = tr.generate(ts)
        return cache[ts]

    return pack_traces([one(g) for g in spec.guests])


# --------------------------------------------------------------------------
# metric collector registry (on-device, runs inside the scan)
# --------------------------------------------------------------------------
_COLLECTORS: dict[str, Callable] = {}


def register_collector(name: str, fn: Callable | None = None):
    """Register an on-device metric collector ``fn(spec, state, window) ->
    dict[str, jax.Array]``; usable as ``@register_collector("name")``.

    ``window`` carries access-time values (``near_hits``/``far_hits`` per
    guest, resolved against the placement in effect when the access happened,
    like PEBS); ``state`` is the post-window state. Outputs are stacked along
    the window axis on device and cross to the host once per chunk.
    """
    if fn is None:
        return lambda f: register_collector(name, f)
    if name in _COLLECTORS:
        raise ValueError(f"metric collector {name!r} already registered")
    _COLLECTORS[name] = fn
    return fn


def get_collector(name: str) -> Callable:
    try:
        return _COLLECTORS[name]
    except KeyError:
        raise ValueError(
            f"unknown metric collector {name!r} (have {collectors()})"
        ) from None


def collectors() -> tuple[str, ...]:
    return tuple(_COLLECTORS)


def run_collectors(
    spec: "EngineSpec", state: "TieredState", window: dict,
    collect: tuple[str, ...],
) -> dict:
    """Run the requested collectors on a post-window state, rejecting
    colliding output keys (shared by the unsharded and sharded window
    bodies so both emit identical series and errors)."""
    out = {}
    for name in collect:
        emitted = get_collector(name)(spec, state, window)
        clash = set(emitted) & set(out)
        if clash:
            raise ValueError(
                f"collector {name!r} emits keys {sorted(clash)} already "
                f"produced by an earlier collector in {collect}"
            )
        out.update(emitted)
    return out


@register_collector("hits")
def _collect_hits(spec: EngineSpec, state: TieredState, window: dict) -> dict:
    """Per-guest near/far hit counts for this window (access-time tiers)."""
    return dict(near_hits=window["near_hits"], far_hits=window["far_hits"])


@register_collector("near_blocks")
def _collect_near_blocks(spec, state, window) -> dict:
    """Per-guest allocated blocks currently in the near tier: one padded
    segment gather-reduce (ragged replacement for the old uniform
    ``reshape(n_guests, hp_per_guest)`` sum)."""
    cfg = spec.cfg
    alloc = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    hp_pad = jnp.asarray(spec.hp_pad_index())
    seg = (hp_pad >= 0) & (alloc & in_near)[jnp.maximum(hp_pad, 0)]
    return dict(near_blocks=seg.sum(axis=1))


@register_collector("snapshot")
def _collect_snapshot(spec, state, window) -> dict:
    """Host-space scalar metrics (``metrics.device_snapshot``): near usage,
    cumulative hit rate, and every running stats counter.

    Not composable with the ``hits`` collector: both emit ``near_hits`` /
    ``far_hits`` (cumulative host-wide here, per-guest per-window there) and
    the driver rejects colliding keys rather than silently overwrite. The
    key names are pinned by the ``gpac.run_windows`` shim's bit-for-bit
    contract with ``metrics.snapshot``; register a custom collector with
    prefixed names to combine both views.
    """
    return metrics.device_snapshot(spec.cfg, state)


@register_collector("tco")
def _collect_tco(spec, state, window) -> dict:
    """The TCO objective per window (``core.tiers.tco_metrics``): $-weighted
    resident GB of the post-tick placement, the per-tier AMAT of this
    window's accesses, and the raw per-tier block/hit vectors. Works on any
    spec -- without ``HostSpec.tiers`` it prices the legacy near/far split
    as a DRAM/NVMM pair."""
    tv = spec.tier_vector
    blocks = tiers_mod.tier_alloc_counts(spec.cfg, state, tv)
    return tiers_mod.tco_metrics(spec.cfg, tv, blocks, window["tier_hits"])


# --------------------------------------------------------------------------
# the one shared driver
# --------------------------------------------------------------------------
def _window(
    spec: EngineSpec,
    state: TieredState,
    accesses: jax.Array,  # int32[n_guests, k] guest-local ids, -1 padded
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    collect: tuple[str, ...],
) -> tuple[TieredState, dict]:
    """Traceable body of one engine window: batched translate/record over all
    guests, one ragged batched GPAC pass, one host tier tick, window roll,
    then the requested collectors."""
    cfg = spec.cfg
    ids = spec.localize(accesses)
    slot, _, valid = asp.translate(cfg, state, ids)
    window = dict(
        near_hits=(valid & (slot < cfg.n_near)).sum(axis=1),
        far_hits=(valid & (slot >= cfg.n_near)).sum(axis=1),
    )
    if "tco" in collect:
        window["tier_hits"] = tiers_mod.tier_hit_counts(
            spec.tier_vector, slot, valid)
    state = asp.record_accesses(
        cfg, state, ids.reshape(-1), kernel_backend=spec.kernel_backend)
    if use_gpac:
        # all N guest daemons in one batched pass over the segment-offset
        # tables; disjoint segments make this bit-equal to N sequential
        # per-guest gpac_maintenance calls (see run_reference)
        state = gpac.gpac_maintenance_ragged(spec, state, backend, max_batches)
    state = tiering.strided_tick(
        cfg, state, policy, stride=spec.arbitration_stride, budget=budget,
        tiers=spec.tiers,
    )
    state = telemetry.end_window(cfg, state)
    return state, run_collectors(spec, state, window, collect)


@partial(
    jax.jit,
    static_argnames=(
        "spec", "policy", "backend", "use_gpac", "max_batches", "budget", "collect",
    ),
)
def _step_impl(
    spec: EngineSpec,
    state: TieredState,
    accesses: jax.Array,
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    collect: tuple[str, ...],
) -> tuple[TieredState, dict]:
    return _window(
        spec, state, accesses, policy, backend, use_gpac, max_batches, budget, collect
    )


def step(
    spec: EngineSpec,
    state,  # TieredState, or a ChurnState for the steady-state stepper
    accesses: jax.Array,
    policy: str = "memtierd",
    backend: str = "ipt",
    use_gpac: bool = True,
    max_batches: int = 4,
    budget: int = 64,
    collect: tuple[str, ...] = ("hits", "near_blocks"),
    *,
    faults_row: dict | None = None,
    mesh=None,
    slack: int = 1,
    arbitration_stride: int | None = None,
) -> tuple:
    """One engine window (jitted single-window entry point).

    Handed a :class:`ChurnState` (from :func:`init_churn`) this dispatches
    to the steady-state stepper :func:`step_churn`: the carry persists the
    activity mask and pressure-controller state between calls, and
    ``faults_row`` injects this window's faults. A no-fault step loop over a
    ChurnState reproduces :func:`run` bit-for-bit."""
    if isinstance(state, ChurnState):
        return step_churn(
            spec, state, accesses, faults_row=faults_row, mesh=mesh,
            policy=policy, backend=backend, use_gpac=use_gpac,
            max_batches=max_batches, budget=budget, slack=slack,
            collect=tuple(collect), arbitration_stride=arbitration_stride,
        )
    if faults_row is not None or mesh is not None:
        raise TypeError(
            "faults_row/mesh need the steady-state stepper: pass a "
            "ChurnState carry (engine.init_churn)"
        )
    spec = _with_arbitration_stride(spec, arbitration_stride)
    return _step_impl(
        spec.canonical(), state, accesses, policy, backend, use_gpac,
        max_batches, budget, tuple(collect),
    )


@partial(
    jax.jit,
    static_argnames=(
        "spec", "policy", "backend", "use_gpac", "max_batches", "budget", "collect",
    ),
)
def _run_chunk(
    spec: EngineSpec,
    state: TieredState,
    chunk: jax.Array,  # int32[n_windows, n_guests, k]
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    collect: tuple[str, ...],
) -> tuple[TieredState, dict]:
    def body(st, acc):
        return _window(
            spec, st, acc, policy, backend, use_gpac, max_batches, budget, collect
        )

    return jax.lax.scan(body, state, chunk)


@partial(
    jax.jit,
    static_argnames=(
        "spec", "plan", "policy", "backend", "use_gpac", "max_batches",
        "budget", "collect",
    ),
)
def _run_chunk_synth(
    spec: EngineSpec,
    plan,  # repro.data.traces.SynthPlan (static)
    state: TieredState,
    widx: jax.Array,  # int32[n_windows] absolute window indices
    tables: dict,  # traced per-guest rows (seeds/gids/wid/n_logical)
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    collect: tuple[str, ...],
) -> tuple[TieredState, dict]:
    """Scan-fused chunk with on-device synthesis: the scan carries window
    *indices*, and each window's accesses are generated inside the body
    (counter-based RNG keyed on the absolute index, so any chunking yields
    identical streams). No trace array exists at any scope wider than one
    window."""
    from repro.data import traces as tr

    setup = tr.synth_setup(plan, tables)

    def body(st, w):
        acc = tr.synth_accesses(plan, setup, w)
        return _window(
            spec, st, acc, policy, backend, use_gpac, max_batches, budget,
            collect,
        )

    return jax.lax.scan(body, state, widx)


def _round_wps(n_windows: int, windows_per_step: int, strict: bool) -> int:
    """Effective chunk size: ``windows_per_step`` rounded *down* to the
    nearest divisor of ``n_windows`` (0 or oversized = the whole run). A
    non-dividing chunk size would leave a shorter trailing chunk whose scan
    has a different shape -- one silent extra trace/compile per fresh
    process; ``strict=True`` keeps the requested size and pays it.

    Guard rail: when the best divisor is so small that rounding would more
    than double the number of chunks (worst case ``n_windows`` prime ->
    divisor 1 -> one dispatch/transfer per window), the requested size is
    kept instead -- the one extra compile is far cheaper than per-window
    host round-trips."""
    wps = n_windows if windows_per_step <= 0 else min(windows_per_step, n_windows)
    if strict:
        return wps
    div = wps
    while n_windows % div:
        div -= 1
    if n_windows // div > 2 * (-(-n_windows // wps)):
        return wps
    return div


def _validate_run_args(spec: EngineSpec, source: TraceSource, collect) -> tuple:
    if isinstance(source, ArrayTrace):
        traces = source.traces
        if traces.ndim != 3 or traces.shape[0] != spec.n_guests:
            raise ValueError(
                f"traces must be [n_guests={spec.n_guests}, n_windows, k], "
                f"got {traces.shape}"
            )
    collect = tuple(collect)
    for name in collect:
        get_collector(name)  # fail fast on unknown collectors
    return collect


def _drive_chunks(
    chunk_fn, state: TieredState, by_window: np.ndarray, wps: int,
    collect: tuple[str, ...],
) -> tuple[TieredState, dict]:
    """Shared chunk loop of :func:`run` / :func:`run_sharded`: one jitted
    scan per chunk, one host transfer per chunk, concatenated host series.
    ``collect=()`` is explicit: the simulation still runs (the state
    advances) but no collectors execute and the series is ``{}``."""
    n_w = by_window.shape[0]
    chunks = []
    for s in range(0, n_w, wps):
        state, out = chunk_fn(state, jnp.asarray(by_window[s : s + wps]))
        chunks.append(out)
    if not collect:
        return state, {}
    series = {
        k: np.concatenate([np.asarray(c[k]) for c in chunks]) for k in chunks[0]
    }
    return state, series


def _with_kernel_backend(spec: EngineSpec, kernel_backend: str | None) -> EngineSpec:
    """Fold a driver-level ``kernel_backend=`` override into the spec (the
    field is static, so the override keys its own jit cache entries).
    ``None`` keeps the spec's own choice; names validate eagerly."""
    if kernel_backend is None:
        return spec
    kernels_registry.resolve_backend(kernel_backend)  # fail fast on typos
    return dataclasses.replace(spec, kernel_backend=kernel_backend)


def _with_arbitration_stride(
    spec: EngineSpec, arbitration_stride: int | None,
) -> EngineSpec:
    """Fold a driver-level ``arbitration_stride=`` override into the spec
    (static field -> its own jit cache entries, like ``kernel_backend``).
    ``None`` keeps the spec's own stride; the result always validates, so a
    spec hand-built with a bad stride fails fast at any driver."""
    if arbitration_stride is not None:
        spec = dataclasses.replace(
            spec, arbitration_stride=int(arbitration_stride))
    s = spec.arbitration_stride
    if not isinstance(s, int) or isinstance(s, bool) or s < 1:
        raise ValueError(
            f"arbitration_stride must be an int >= 1, got {s!r}")
    return spec


def run(
    spec: EngineSpec,
    state: TieredState,
    source: TraceSource | np.ndarray | None = None,
    *,
    traces: np.ndarray | None = None,  # deprecated keyword (warns and wraps)
    policy: str = "memtierd",
    backend: str = "ipt",
    use_gpac: bool = True,
    max_batches: int = 4,
    budget: int = 64,
    windows_per_step: int = 0,
    strict_wps: bool = False,
    collect: tuple[str, ...] = ("hits", "near_blocks"),
    kernel_backend: str | None = None,
    arbitration_stride: int | None = None,
) -> tuple[TieredState, dict]:
    """Drive every window through the scan-fused engine.

    ``source`` is a :class:`TraceSource`: an :class:`ArrayTrace` (raw packed
    arrays are wrapped automatically) replays a host-materialized trace; a
    :class:`SynthTrace` generates each window's accesses on device inside
    the scan, so nothing of shape ``[n_guests, n_windows, k]`` ever exists.
    The deprecated ``traces=`` keyword still works (warns and wraps).

    The window loop is a device-side ``lax.scan``; ``windows_per_step``
    bounds how many windows each jitted step fuses (0 = the whole run in one
    step) and the stacked collector series cross to the host **once per
    chunk**. A ``windows_per_step`` that does not divide ``n_windows`` is
    rounded down to the nearest divisor, so every chunk shares one scan
    shape and one compilation (unless that would more than double the chunk
    count -- e.g. a prime ``n_windows`` -- where the requested size wins);
    pass ``strict_wps=True`` to always keep the exact requested size (the
    shorter trailing chunk then pays one extra trace/compile per fresh
    process).

    Returns ``(state, series)`` where ``series[k]`` is a host numpy array of
    shape ``[n_windows, ...]`` per collector output; empty dict when the
    source has no windows or ``collect`` is empty.
    """
    source = _coerce_source(source, traces)
    spec = _with_kernel_backend(spec, kernel_backend)
    spec = _with_arbitration_stride(spec, arbitration_stride)
    collect = _validate_run_args(spec, source, collect)
    n_w = source.n_windows
    if n_w == 0:
        return state, {}
    if isinstance(source, SynthTrace):
        plan, tables = _bind_synth(spec, source)  # pre-canonical: reads ids
        spec = spec.canonical()
        jt = {k: jnp.asarray(v) for k, v in tables.items()}
        by_window = np.arange(n_w, dtype=np.int32)

        def chunk_fn(st, widx):
            return _run_chunk_synth(
                spec, plan, st, widx, jt, policy, backend, use_gpac,
                max_batches, budget, collect,
            )
    else:
        spec = spec.canonical()  # don't recompile across seed/workload sweeps
        by_window = np.ascontiguousarray(
            np.transpose(source.traces, (1, 0, 2)))

        def chunk_fn(st, chunk):
            return _run_chunk(
                spec, st, chunk, policy, backend, use_gpac, max_batches,
                budget, collect,
            )

    wps = _round_wps(n_w, windows_per_step, strict_wps)
    return _drive_chunks(chunk_fn, state, by_window, wps, collect)


# collectors with a host-partitioned implementation (repro.core.sharding
# computes them from the per-window candidate exchange without ever
# materializing the replicated host state)
HOST_SHARDED_COLLECTORS = ("hits", "near_blocks", "snapshot", "tco")


def run_sharded(
    spec: EngineSpec,
    state: TieredState,
    source: TraceSource | np.ndarray | None = None,
    *,
    traces: np.ndarray | None = None,  # deprecated keyword (warns and wraps)
    mesh=None,
    host_sharded: bool = True,
    policy: str = "memtierd",
    backend: str = "ipt",
    use_gpac: bool = True,
    max_batches: int = 4,
    budget: int = 64,
    windows_per_step: int = 0,
    strict_wps: bool = False,
    collect: tuple[str, ...] = ("hits", "near_blocks"),
    kernel_backend: str | None = None,
    arbitration_stride: int | None = None,
) -> tuple[TieredState, dict]:
    """:func:`run`, device-sharded over the guest axis (DESIGN.md §9, §11).

    ``mesh`` is a 1-D ``"guest"`` mesh (:func:`repro.core.sharding.
    guest_mesh`); ``None`` builds one over every local device and **falls
    back to** :func:`run` on a single-device host -- the same no-mesh
    degradation as ``models.dist.Dist``. Guest counts that do not divide the
    mesh are padded with no-op segment rows. Results are bit-for-bit equal
    to :func:`run` on any mesh size: per-guest phases shard over disjoint
    segments, the access histograms and GPAC writes merge through exact
    integer / bit-pattern collectives, and the host near-tier tick is
    deterministic either way.

    ``host_sharded=True`` (the default) additionally partitions the host
    state itself by contiguous block ranges (DESIGN.md §11): each device
    carries only its own range of the block table, host telemetry and
    payload, scores promotion/demotion locally, and one arbitration
    exchange per window resolves cross-partition contention bit-for-bit
    against the replicated tick -- per-device host-state bytes scale
    ~1/n_devices (``sharding.host_state_bytes_sharded``). It requires a
    host-partitioned tick for ``policy`` (``tiering.sharded_ticks()``) and
    host-sharded collectors (:data:`HOST_SHARDED_COLLECTORS`);
    ``host_sharded=False`` keeps the replicated host state and supports any
    registered policy/collector.

    Accepts any :class:`TraceSource`. A :class:`SynthTrace` synthesizes each
    device's *local* guests' accesses on that device (keys fold in the
    global guest id, so the streams are bit-identical to the single-device
    driver and mesh-padding rows emit -1 no-ops); an :class:`ArrayTrace` is
    padded and sharded over the guest axis as before.
    """
    from repro.core import sharding

    source = _coerce_source(source, traces)
    spec = _with_kernel_backend(spec, kernel_backend)
    spec = _with_arbitration_stride(spec, arbitration_stride)
    if mesh is None:
        mesh = sharding.guest_mesh()
    if mesh is None:
        return run(
            spec, state, source, policy=policy, backend=backend,
            use_gpac=use_gpac, max_batches=max_batches, budget=budget,
            windows_per_step=windows_per_step, strict_wps=strict_wps,
            collect=collect,
        )
    collect = _validate_run_args(spec, source, collect)
    n_w = source.n_windows
    if n_w == 0:
        return state, {}
    n_shards = sharding.mesh_size(mesh)
    if isinstance(source, SynthTrace):
        plan, synth_tables = _bind_synth(spec, source, n_shards)
        by_window = np.arange(n_w, dtype=np.int32)
    else:
        plan, synth_tables = None, None
        padded = sharding.pad_guest_rows(source.traces, n_shards)
        by_window = np.ascontiguousarray(np.transpose(padded, (1, 0, 2)))
    spec = spec.canonical()

    if host_sharded:
        unsupported = tuple(
            c for c in collect if c not in HOST_SHARDED_COLLECTORS
        )
        if unsupported:
            raise ValueError(
                f"collectors {unsupported} have no host-sharded "
                f"implementation (host-sharded collectors: "
                f"{HOST_SHARDED_COLLECTORS}); pass host_sharded=False to "
                f"run them on the replicated host state"
            )
        tiering.sharded_tick_fns(policy)  # fail fast on unsupported policies
        stride = spec.arbitration_stride
        if stride > 1:
            # the host-partitioned driver batches the candidate exchange to
            # one collective per stride *group*, so groups must tile every
            # chunk and start on an arbitration boundary (fresh states do:
            # epoch 0); the replicated paths gate on the carried epoch and
            # have no such constraint
            if n_w % stride:
                raise ValueError(
                    f"host_sharded arbitration_stride={stride} must divide "
                    f"the run's n_windows={n_w}")
            if int(np.asarray(state.epoch)) % stride:
                raise ValueError(
                    f"host_sharded arbitration_stride={stride} needs the "
                    f"state's epoch ({int(np.asarray(state.epoch))}) on an "
                    f"arbitration boundary (epoch % stride == 0); pass "
                    f"host_sharded=False to resume mid-stride")
        _, tables = sharding.host_tables(spec, n_shards)

        def chunk_fn(st, chunk):
            return sharding.run_chunk_host_sharded(
                spec, mesh, st, chunk, tables, policy=policy,
                backend=backend, use_gpac=use_gpac, max_batches=max_batches,
                budget=budget, collect=collect, plan=plan,
                synth_tables=synth_tables,
            )
    else:
        tables = sharding.guest_tables(spec, n_shards)

        def chunk_fn(st, chunk):
            return sharding.run_chunk_sharded(
                spec, mesh, st, chunk, tables, policy=policy,
                backend=backend, use_gpac=use_gpac, max_batches=max_batches,
                budget=budget, collect=collect, plan=plan,
                synth_tables=synth_tables,
            )

    wps = _round_wps(n_w, windows_per_step, strict_wps)
    if host_sharded and spec.arbitration_stride > 1 and (
            wps % spec.arbitration_stride):
        raise ValueError(
            f"host_sharded arbitration_stride={spec.arbitration_stride} "
            f"must divide the chunk size (windows_per_step resolved to "
            f"{wps}); pick a multiple of the stride")
    return _drive_chunks(chunk_fn, state, by_window, wps, collect)


def run_series(
    spec: EngineSpec,
    state: TieredState,
    source: TraceSource | np.ndarray | None = None,
    tier_pair: str = "dram_nvmm",
    mesh=None,
    *,
    traces: np.ndarray | None = None,  # deprecated keyword (warns and wraps)
    **kw,
) -> tuple[TieredState, dict]:
    """:func:`run` + the per-VM time series the at-scale figures plot
    (near blocks, per-window hit rate, modeled throughput). Accepts any
    :class:`TraceSource` (raw packed arrays wrap as :class:`ArrayTrace`;
    the deprecated ``traces=`` keyword warns and wraps, as in :func:`run`).
    Passing a ``mesh`` drives the windows through :func:`run_sharded`
    instead (the at-scale figures shard their guest axis end-to-end this
    way; ``host_sharded=`` threads through and is dropped on the no-mesh
    path)."""
    n_g = spec.n_guests
    source = _coerce_source(source, traces)
    _validate_run_args(spec, source, ())  # shape errors before n_windows
    host_sharded = kw.pop("host_sharded", True)
    if source.n_windows == 0:
        return state, dict(
            near_blocks=np.zeros((0, n_g), np.int64),
            hit_rate=np.zeros((0, n_g)),
            throughput=np.zeros((0, n_g)),
        )
    driver = (
        run if mesh is None
        else partial(run_sharded, mesh=mesh, host_sharded=host_sharded)
    )
    state, out = driver(
        spec, state, source, collect=("hits", "near_blocks"), **kw
    )
    nh = out["near_hits"].astype(np.float64)
    fh = out["far_hits"].astype(np.float64)
    hit_rate, throughput = metrics.throughput_from_hits(nh, fh, tier_pair)
    return state, dict(
        near_blocks=out["near_blocks"].astype(np.int64),
        hit_rate=hit_rate,
        throughput=throughput,
    )


# --------------------------------------------------------------------------
# steady-state churn engine (DESIGN.md §13)
# --------------------------------------------------------------------------
# per-window series every churn driver emits alongside the collectors
_CHURN_SERIES = ("active", "near_cap", "pressure")


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("state", "active", "window", "near_cap", "pressure", "engaged"),
    meta_fields=(),
)
@dataclasses.dataclass
class ChurnState:
    """The steady-state stepper's carry: the tiered state plus the ring of
    churn bookkeeping that persists *between* driver calls (DESIGN.md §13).

    ``active`` is the guest-axis activity mask: the compiled geometry never
    changes, lanes just flip active/inactive -- an inactive lane contributes
    zero accesses, holds zero blocks and is excluded from arbitration.
    ``window`` is the absolute index of the next window to run (the synth
    RNG and fault schedules are keyed on it, so a stepper resumed at window
    ``w`` continues the exact streams a straight run would produce).
    ``near_cap`` / ``pressure`` / ``engaged`` carry the pressure controller
    (``tiering.pressure_tick``) across windows.
    """

    state: TieredState
    active: jax.Array  # bool[n_guests] lane activity mask
    window: jax.Array  # int32[] absolute index of the next window
    near_cap: jax.Array  # int32[] effective near capacity in force
    pressure: jax.Array  # int32[] consecutive pressure-engaged windows
    engaged: jax.Array  # bool[] pressure-controller hysteresis latch


def init_churn(
    spec: EngineSpec,
    state: TieredState | None = None,
    active: np.ndarray | None = None,
    window: int = 0,
) -> ChurnState:
    """Wrap an engine state for the steady-state stepper.

    With the defaults (fresh identity state, all lanes active, window 0)
    a no-fault churn run is bit-identical to :func:`run` from the same
    state (INV-CHURN-NOOP-EXACT). ``active`` may mark lanes inactive at
    boot -- their segments are reclaimed immediately (crash semantics), so
    they hold no blocks until a restart fault boots them.
    """
    if state is None:
        state = init_engine_state(spec)
    n_g = spec.n_guests
    act = (np.ones((n_g,), bool) if active is None
           else np.asarray(active, bool))
    if act.shape != (n_g,):
        raise ValueError(
            f"active mask must be bool[n_guests={n_g}], got shape {act.shape}"
        )
    cs = ChurnState(
        state=state,
        active=jnp.asarray(act),
        window=jnp.asarray(int(window), jnp.int32),
        near_cap=jnp.asarray(spec.cfg.n_near, jnp.int32),
        pressure=jnp.zeros((), jnp.int32),
        engaged=jnp.zeros((), bool),
    )
    if not act.all():
        st, act2 = faults_mod.apply_guest_faults(
            spec.canonical(), cs.state, jnp.ones((n_g,), bool),
            jnp.asarray(~act), jnp.zeros((n_g,), bool),
        )
        cs = dataclasses.replace(cs, state=st, active=act2)
    return cs


def _churn_window(
    spec: EngineSpec,
    cs: ChurnState,
    accesses: jax.Array,  # int32[n_guests, k] guest-local ids, -1 padded
    frow: dict,  # this window's fault row (crash/restart/near_cap/drop)
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    slack: int,
    collect: tuple[str, ...],
) -> tuple[ChurnState, dict]:
    """Traceable body of one churn window: :func:`_window` with the fault
    row applied first, inactive lanes' accesses masked to -1 (value-exact:
    the engine treats negative ids as no-ops end to end), telemetry gated by
    the dropout bit, and the pressure controller run after the policy tick.

    With an all-no-op fault row and an all-active mask every extra operation
    is value-exact identity, so the scan over windows stays bit-identical to
    :func:`run` (INV-CHURN-NOOP-EXACT). The telemetry write uses the
    histogram formulation unconditionally (``asp.access_histogram`` +
    ``asp.apply_access_histogram``), the same bit-identical path the sharded
    driver always takes, so the dropout gate is a single integer multiply.
    """
    cfg = spec.cfg
    state, active = faults_mod.apply_guest_faults(
        spec, cs.state, cs.active, frow["crash"], frow["restart"]
    )
    near_cap = jnp.minimum(frow["near_cap"], jnp.int32(cfg.n_near))
    acc = jnp.where(active[:, None], accesses, -1)
    ids = spec.localize(acc)
    slot, _, valid = asp.translate(cfg, state, ids)
    window = dict(
        near_hits=(valid & (slot < cfg.n_near)).sum(axis=1),
        far_hits=(valid & (slot >= cfg.n_near)).sum(axis=1),
    )
    if "tco" in collect:
        window["tier_hits"] = tiers_mod.tier_hit_counts(
            spec.tier_vector, slot, valid)
    keep = jnp.where(frow["drop"], 0, 1).astype(jnp.int32)
    kb = spec.kernel_backend
    state = asp.apply_access_histogram(
        cfg, state, asp.access_histogram(cfg, ids, valid, kb) * keep, kb
    )
    if use_gpac:
        state = gpac.gpac_maintenance_ragged(spec, state, backend, max_batches)
    state = tiering.strided_tick(
        cfg, state, policy, stride=spec.arbitration_stride, budget=budget,
        tiers=spec.tiers,
    )
    state, engaged, press = tiering.pressure_tick(
        cfg, state, near_cap, cs.engaged, cs.pressure,
        budget=budget, slack=slack, tiers=spec.tiers,
    )
    state = telemetry.end_window(cfg, state)
    out = run_collectors(spec, state, window, collect)
    clash = set(out) & set(_CHURN_SERIES)
    if clash:
        raise ValueError(
            f"collectors {collect} emit keys {sorted(clash)} reserved for "
            f"the churn series {_CHURN_SERIES}"
        )
    out.update(active=active, near_cap=near_cap, pressure=press)
    cs = ChurnState(
        state=state, active=active, window=cs.window + 1,
        near_cap=near_cap, pressure=press, engaged=engaged,
    )
    return cs, out


@partial(
    jax.jit,
    static_argnames=(
        "spec", "policy", "backend", "use_gpac", "max_batches", "budget",
        "slack", "collect",
    ),
)
def _churn_chunk(
    spec: EngineSpec,
    cs: ChurnState,
    chunk: jax.Array,  # int32[n_windows, n_guests, k]
    crash: jax.Array,  # bool[n_windows, n_guests]
    restart: jax.Array,  # bool[n_windows, n_guests]
    near_cap: jax.Array,  # int32[n_windows]
    drop: jax.Array,  # bool[n_windows]
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    slack: int,
    collect: tuple[str, ...],
) -> tuple[ChurnState, dict]:
    def body(c, xs):
        acc, frow = xs
        return _churn_window(
            spec, c, acc, frow, policy, backend, use_gpac, max_batches,
            budget, slack, collect,
        )

    xs = (chunk, dict(crash=crash, restart=restart, near_cap=near_cap, drop=drop))
    return jax.lax.scan(body, cs, xs)


@partial(
    jax.jit,
    static_argnames=(
        "spec", "plan", "policy", "backend", "use_gpac", "max_batches",
        "budget", "slack", "collect",
    ),
)
def _churn_chunk_synth(
    spec: EngineSpec,
    plan,  # repro.data.traces.SynthPlan (static)
    cs: ChurnState,
    widx: jax.Array,  # int32[n_windows] absolute window indices
    tables: dict,  # traced per-guest rows (seeds/gids/wid/n_logical)
    crash: jax.Array,
    restart: jax.Array,
    near_cap: jax.Array,
    drop: jax.Array,
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    slack: int,
    collect: tuple[str, ...],
) -> tuple[ChurnState, dict]:
    """Churn chunk with on-device synthesis: window accesses are generated
    inside the scan from the *absolute* window index, so a stepper resumed
    at any window continues the exact access streams (counter-based RNG)."""
    from repro.data import traces as tr

    setup = tr.synth_setup(plan, tables)

    def body(c, xs):
        w, frow = xs
        acc = tr.synth_accesses(plan, setup, w)
        return _churn_window(
            spec, c, acc, frow, policy, backend, use_gpac, max_batches,
            budget, slack, collect,
        )

    xs = (widx, dict(crash=crash, restart=restart, near_cap=near_cap, drop=drop))
    return jax.lax.scan(body, cs, xs)


def _resolve_fault_tables(
    spec: EngineSpec, cs: ChurnState, faults, n_windows: int, start: int,
):
    """The dense fault rows for this driver call: an explicit schedule
    compiles against the physical ``n_near`` (its capacity step function is
    absolute); ``faults=None`` keeps the carried effective capacity (a
    shrink injected by an earlier call stays in force across no-fault
    calls); precompiled :class:`repro.core.faults.FaultTables` must match
    the run's exact window range (replayability guard)."""
    cfg = spec.cfg
    if faults is None:
        return faults_mod.no_faults(spec.n_guests).tables(
            n_windows, int(np.asarray(cs.near_cap)), start=start
        )
    if isinstance(faults, faults_mod.FaultSchedule):
        if faults.n_guests != spec.n_guests:
            raise ValueError(
                f"fault schedule is for {faults.n_guests} guests, spec has "
                f"{spec.n_guests}"
            )
        return faults.tables(n_windows, cfg.n_near, start=start)
    if isinstance(faults, faults_mod.FaultTables):
        if (faults.n_windows != n_windows or faults.n_guests != spec.n_guests
                or faults.start != start):
            raise ValueError(
                f"fault tables cover windows [{faults.start}, "
                f"{faults.start + faults.n_windows}) x {faults.n_guests} "
                f"guests; this run is windows [{start}, {start + n_windows})"
                f" x {spec.n_guests}"
            )
        return faults
    raise TypeError(
        f"faults must be a FaultSchedule, FaultTables or None, got "
        f"{type(faults).__name__}"
    )


def run_churn(
    spec: EngineSpec,
    cs: ChurnState,
    source: TraceSource | np.ndarray | None = None,
    *,
    faults=None,  # FaultSchedule | FaultTables | None
    mesh=None,
    policy: str = "memtierd",
    backend: str = "ipt",
    use_gpac: bool = True,
    max_batches: int = 4,
    budget: int = 64,
    slack: int = 1,
    windows_per_step: int = 0,
    strict_wps: bool = False,
    collect: tuple[str, ...] = ("hits", "near_blocks"),
    kernel_backend: str | None = None,
    arbitration_stride: int | None = None,
) -> tuple[ChurnState, dict]:
    """Drive ``source.n_windows`` windows of the steady-state churn engine.

    Same scan-fused driver as :func:`run`, but the carry is a
    :class:`ChurnState` and a deterministic fault schedule rides the scan as
    dense per-window rows (``repro.core.faults``): guests crash/restart
    mid-run through the activity mask (no recompile -- the compiled
    geometry is static), the near tier shrinks via the pressure controller,
    and telemetry windows drop. Fault scenarios are bit-reproducible across
    ``windows_per_step`` chunkings and meshes; with ``faults=None`` and an
    all-active mask the run is bit-identical to :func:`run`
    (INV-CHURN-NOOP-EXACT).

    A :class:`SynthTrace` source keys each window's synthesis on the
    *absolute* window index carried in ``cs.window``, so repeated
    ``run_churn`` calls continue the exact access streams of one long run.
    ``mesh`` shards the guest axis exactly like :func:`run_sharded` with
    ``host_sharded=False`` (fault rows are replicated; the host-partitioned
    near tier does not support the churn carry -- pass ``mesh=None`` or a
    plain mesh, never ``host_sharded=True``).

    Returns ``(cs, series)``; beyond the collectors the series always
    carries the churn channels ``active`` (bool[n_windows, n_guests]),
    ``near_cap`` and ``pressure`` (per window).
    """
    if not isinstance(cs, ChurnState):
        raise TypeError(
            f"run_churn needs a ChurnState carry (init_churn), got "
            f"{type(cs).__name__}"
        )
    source = _coerce_source(source, None)
    spec = _with_kernel_backend(spec, kernel_backend)
    spec = _with_arbitration_stride(spec, arbitration_stride)
    collect = _validate_run_args(spec, source, collect)
    n_w = source.n_windows
    if n_w == 0:
        return cs, {}
    w0 = int(np.asarray(cs.window))
    ft = _resolve_fault_tables(spec, cs, faults, n_w, w0)
    if mesh is not None:
        from repro.core import sharding

        n_shards = sharding.mesh_size(mesh)
    if isinstance(source, SynthTrace):
        plan, synth_tables = _bind_synth(
            spec, source, n_shards if mesh is not None else 1
        )
        by_window = np.arange(w0, w0 + n_w, dtype=np.int32)
    else:
        plan, synth_tables = None, None
        traces = source.traces
        if mesh is not None:
            traces = sharding.pad_guest_rows(traces, n_shards)
        by_window = np.ascontiguousarray(np.transpose(traces, (1, 0, 2)))
    spec = spec.canonical()

    if mesh is not None:
        tables = sharding.guest_tables(spec, n_shards)

        def chunk_fn(c, win, crash, restart, cap, drop):
            return sharding.run_chunk_churn_sharded(
                spec, mesh, c, win, tables, crash=crash, restart=restart,
                near_cap=cap, drop=drop, policy=policy, backend=backend,
                use_gpac=use_gpac, max_batches=max_batches, budget=budget,
                slack=slack, collect=collect, plan=plan,
                synth_tables=synth_tables,
            )
    elif plan is not None:
        jt = {k: jnp.asarray(v) for k, v in synth_tables.items()}

        def chunk_fn(c, win, crash, restart, cap, drop):
            return _churn_chunk_synth(
                spec, plan, c, win, jt, crash, restart, cap, drop, policy,
                backend, use_gpac, max_batches, budget, slack, collect,
            )
    else:

        def chunk_fn(c, win, crash, restart, cap, drop):
            return _churn_chunk(
                spec, c, win, crash, restart, cap, drop, policy, backend,
                use_gpac, max_batches, budget, slack, collect,
            )

    wps = _round_wps(n_w, windows_per_step, strict_wps)
    chunks = []
    for s in range(0, n_w, wps):
        sl = slice(s, s + wps)
        cs, out = chunk_fn(
            cs, jnp.asarray(by_window[sl]), jnp.asarray(ft.crash[sl]),
            jnp.asarray(ft.restart[sl]), jnp.asarray(ft.near_cap[sl]),
            jnp.asarray(ft.drop[sl]),
        )
        chunks.append(out)
    series = {
        k: np.concatenate([np.asarray(c[k]) for c in chunks]) for k in chunks[0]
    }
    return cs, series


def step_churn(
    spec: EngineSpec,
    cs: ChurnState,
    accesses: jax.Array,  # int32[n_guests, k] guest-local ids, -1 padded
    *,
    faults_row: dict | None = None,
    mesh=None,
    policy: str = "memtierd",
    backend: str = "ipt",
    use_gpac: bool = True,
    max_batches: int = 4,
    budget: int = 64,
    slack: int = 1,
    collect: tuple[str, ...] = ("hits", "near_blocks"),
    arbitration_stride: int | None = None,
) -> tuple[ChurnState, dict]:
    """One churn window (the steady-state single-step entry point;
    :func:`step` dispatches here when handed a :class:`ChurnState`).

    ``faults_row`` injects this window's faults: optional keys ``crash`` /
    ``restart`` (bool[n_guests]), ``near_cap`` (int; defaults to the
    capacity already in force) and ``drop`` (bool). A no-fault step loop is
    bit-identical to :func:`run` / a single :func:`run_churn` call.
    """
    acc = np.asarray(accesses)
    if acc.ndim != 2 or acc.shape[0] != spec.n_guests:
        raise ValueError(
            f"accesses must be [n_guests={spec.n_guests}, k], got {acc.shape}"
        )
    row = dict(faults_row or {})
    unknown = set(row) - {"crash", "restart", "near_cap", "drop"}
    if unknown:
        raise ValueError(
            f"unknown faults_row keys {sorted(unknown)} (valid: crash, "
            f"restart, near_cap, drop)"
        )
    n_g = spec.n_guests
    crash = np.zeros((1, n_g), bool)
    crash[0] = np.asarray(row.get("crash", False), bool)
    restart = np.zeros((1, n_g), bool)
    restart[0] = np.asarray(row.get("restart", False), bool)
    cap = int(row.get("near_cap", np.asarray(cs.near_cap)))
    ft = faults_mod.FaultTables(
        start=int(np.asarray(cs.window)),
        crash=crash,
        restart=restart,
        near_cap=np.asarray([cap], np.int32),
        drop=np.asarray([bool(row.get("drop", False))]),
    )
    cs, series = run_churn(
        spec, cs, ArrayTrace(acc[:, None, :]), faults=ft, mesh=mesh,
        policy=policy, backend=backend, use_gpac=use_gpac,
        max_batches=max_batches, budget=budget, slack=slack, collect=collect,
        arbitration_stride=arbitration_stride,
    )
    return cs, {k: v[0] for k, v in series.items()}


# --------------------------------------------------------------------------
# sequential per-guest reference (the ragged equivalence oracle)
# --------------------------------------------------------------------------
def step_reference(
    spec: EngineSpec,
    state: TieredState,
    accesses: jax.Array,
    policy: str = "memtierd",
    backend: str = "ipt",
    use_gpac: bool = True,
    max_batches: int = 4,
    budget: int = 64,
) -> tuple[TieredState, dict]:
    """One window in the sequential formulation: each guest translates,
    records and runs its own GPAC daemon (confined via ``allow``/``hp_range``
    and its own CL) one after another. O(n_guests) trace cost -- kept only as
    the equivalence oracle for :func:`step` / :func:`run`."""
    return _step_reference_impl(
        spec.canonical(), state, accesses, policy, backend, use_gpac,
        max_batches, budget,
    )


@partial(
    jax.jit,
    static_argnames=("spec", "policy", "backend", "use_gpac", "max_batches", "budget"),
)
def _step_reference_impl(
    spec: EngineSpec,
    state: TieredState,
    accesses: jax.Array,  # int32[n_guests, k] guest-local ids, -1 padded
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
) -> tuple[TieredState, dict]:
    cfg = spec.cfg
    near_hits, far_hits = [], []
    logical_idx = jnp.arange(cfg.n_logical, dtype=jnp.int32)
    hp_idx = jnp.arange(cfg.n_gpa_hp)
    for g in range(spec.n_guests):
        lo, _ = spec.logical_range(g)
        ids = jnp.where(accesses[g] >= 0, accesses[g] + lo, -1)
        slot, _, valid = asp.translate(cfg, state, ids)
        near_hits.append(jnp.where(valid & (slot < cfg.n_near), 1, 0).sum())
        far_hits.append(jnp.where(valid & (slot >= cfg.n_near), 1, 0).sum())
        state = asp.record_accesses(cfg, state, ids)
    if use_gpac:
        for g in range(spec.n_guests):
            lo, hi = spec.logical_range(g)
            allow = (logical_idx >= lo) & (logical_idx < hi)
            state = gpac.gpac_maintenance(
                cfg, state, backend, max_batches, spec.guest_cl(g),
                allow=allow, hp_range=spec.hp_range(g),
            )
    state = tiering.tick(cfg, state, policy, budget=budget, tiers=spec.tiers)
    alloc = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    near_blocks = []
    for g in range(spec.n_guests):
        hp_lo, hp_hi = spec.hp_range(g)
        seg = (hp_idx >= hp_lo) & (hp_idx < hp_hi)
        near_blocks.append((seg & alloc & in_near).sum())
    out = dict(
        near_hits=jnp.stack(near_hits),
        far_hits=jnp.stack(far_hits),
        near_blocks=jnp.stack(near_blocks),
    )
    state = telemetry.end_window(cfg, state)
    return state, out


def run_reference(
    spec: EngineSpec,
    state: TieredState,
    traces: np.ndarray,
    **kw,
) -> tuple[TieredState, dict]:
    """Per-window python driver over :func:`step_reference` (one host sync
    per window): the equivalence oracle for :func:`run` with the default
    ``("hits", "near_blocks")`` collectors."""
    traces = np.asarray(traces)
    n_g, n_w, _ = traces.shape
    series = dict(
        near_hits=np.zeros((n_w, n_g), np.int32),
        far_hits=np.zeros((n_w, n_g), np.int32),
        near_blocks=np.zeros((n_w, n_g), np.int32),
    )
    for w in range(n_w):
        state, out = step_reference(spec, state, jnp.asarray(traces[:, w]), **kw)
        for k in series:
            series[k][w] = np.asarray(out[k])
    return state, series
