"""GPAC orchestration (paper Fig. 5): telemetry -> filter -> consolidate.

``gpac_maintenance`` is one guest daemon's periodic pass; ``window_step`` is
the full single-guest simulation step: accesses -> (optional GPAC) -> host
tier tick -> window roll. Host and guest layers only communicate through the
address space itself -- there is no API between them (design goal 1).

``gpac_maintenance_ragged`` runs N (possibly asymmetric) guest daemons as one
batched pass over an :class:`repro.core.engine.EngineSpec`'s segment-offset
tables. ``run_windows`` is now a thin shim over the one shared scan-fused
driver, :func:`repro.core.engine.run` (``run_windows_reference`` keeps the
seed per-window loop as the equivalence oracle).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import address_space as asp
from repro.core import consolidator, filter as pfilter, telemetry, tiering
from repro.core.types import GpacConfig, TieredState


@partial(jax.jit, static_argnames=("cfg", "backend", "max_batches", "cl"))
def gpac_maintenance(
    cfg: GpacConfig,
    state: TieredState,
    backend: str = "ipt",
    max_batches: int = 8,
    cl: int | None = None,
    allow: jax.Array | None = None,
    hp_range: tuple | None = None,
) -> TieredState:
    """One guest-side GPAC pass: classify hotness, filter scattered hot pages,
    consolidate them batch-by-batch (<= hp_ratio pages per Algorithm-1 call).

    ``allow``/``hp_range`` confine the pass to one guest's logical pages and
    GPA segment in the multi-tenant simulation (each guest runs its own GPAC
    daemon over its own address space, as in the paper).
    """
    hot = telemetry.hot_mask(cfg, state, backend)
    batches, _ = pfilter.select_batches(cfg, state, hot, max_batches, cl, allow)
    return consolidator.consolidate_batches(cfg, state, batches, hp_range)


@partial(jax.jit, static_argnames=("spec", "backend", "max_batches"))
def gpac_maintenance_ragged(
    spec,  # repro.core.engine.EngineSpec
    state: TieredState,
    backend: str = "ipt",
    max_batches: int = 8,
) -> TieredState:
    """All N guest daemons' GPAC passes in one batched invocation, for
    ragged/asymmetric guests.

    The guests' logical and GPA segments (the spec's segment-offset tables)
    are disjoint and tile their spaces, so one hot-mask classification, one
    row-wise batched filter (:func:`repro.core.filter.select_batches_ragged`,
    honouring per-guest CLs) and ``max_batches`` guest-wide consolidation
    rounds (:func:`repro.core.consolidator.consolidate_batches_ragged`)
    reproduce N sequential :func:`gpac_maintenance` calls bit-for-bit -- with
    O(1) trace cost and ~n_guests x less classification/sort work."""
    cfg = spec.cfg
    return gpac_maintenance_rows(
        cfg,
        state,
        backend,
        max_batches,
        jnp.asarray(spec.cl_per_logical()),
        jnp.asarray(spec.logical_pad_index()),
        jnp.asarray(spec.hp_pad_index()),
        spec.kernel_backend,
    )


def gpac_maintenance_rows(
    cfg: GpacConfig,
    state: TieredState,
    backend: str,
    max_batches: int,
    cl_per_logical: jax.Array,  # int32[n_logical]
    pad_idx: jax.Array,  # int32[n_rows, max_logical] logical segment rows
    hp_pad_idx: jax.Array,  # int32[n_rows, max_hp] GPA segment rows
    kernel_backend: str = "auto",
) -> TieredState:
    """GPAC passes for an arbitrary slice of guest segment rows.

    The hot-mask classification and candidate scoring are cheap elementwise
    passes over the **whole** logical space (in the sharded engine every
    device redoes them -- a deliberate trade: O(n_logical) elementwise work
    vs. an extra collective); only their values inside the given rows are
    ever *read*, and the expensive parts -- the row-wise top-k selection and
    the round-major consolidation -- are confined to those rows. The
    all-guests call (:func:`gpac_maintenance_ragged`) passes every row; the
    device-sharded engine passes only the rows a device owns -- segments are
    disjoint, so each device's pass *writes* disjoint state and the shard
    merge is exact."""
    hot = telemetry.hot_mask(cfg, state, backend)
    score = pfilter.candidate_score(cfg, state, hot, cl_per_logical, kernel_backend)
    batches = pfilter.select_batches_from_rows(
        cfg, score, pad_idx, max_batches, kernel_backend)
    return consolidator.consolidate_rounds(
        cfg, state, batches, hp_pad_idx, kernel_backend)


def gpac_maintenance_batched(
    cfg: GpacConfig,
    state: TieredState,
    backend: str,
    max_batches: int,
    cl: int | None,
    n_guests: int,
    logical_per_guest: int,
    hp_per_guest: int,
) -> TieredState:
    """Deprecated symmetric wrapper over :func:`gpac_maintenance_ragged`."""
    from repro.core.engine import symmetric_spec

    if n_guests * logical_per_guest != cfg.n_logical:
        raise ValueError("guest logical segments must tile the logical space")
    if n_guests * hp_per_guest != cfg.n_gpa_hp:
        raise ValueError("guest GPA segments must tile the GPA space")
    spec = symmetric_spec(cfg, n_guests, cl=cl)
    return gpac_maintenance_ragged(spec, state, backend, max_batches)


@partial(
    jax.jit,
    static_argnames=("cfg", "policy", "backend", "use_gpac", "max_batches", "budget"),
)
def window_step(
    cfg: GpacConfig,
    state: TieredState,
    accesses: jax.Array,
    policy: str = "memtierd",
    backend: str = "ipt",
    use_gpac: bool = True,
    max_batches: int = 8,
    budget: int = 64,
) -> TieredState:
    """One telemetry window: record accesses, run GPAC (guest), run the host
    tiering tick (block-granular, GPAC-oblivious), roll the window."""
    state = asp.record_accesses(cfg, state, accesses)
    if use_gpac:
        state = gpac_maintenance(cfg, state, backend, max_batches)
    state = tiering.tick(cfg, state, policy, budget=budget)
    return telemetry.end_window(cfg, state)


def run_windows(
    cfg: GpacConfig,
    state: TieredState,
    trace: jax.Array,
    policy: str = "memtierd",
    backend: str = "ipt",
    use_gpac: bool = True,
    max_batches: int = 8,
    budget: int = 64,
    windows_per_step: int = 0,
) -> tuple[TieredState, list[dict]]:
    """Drive a (n_windows, accesses_per_window) single-guest trace on the
    shared scan-fused engine driver, collecting per-window metric snapshots.

    Deprecation shim: new code should call :func:`repro.core.engine.run`
    directly (``spec = engine.spec_from_config(cfg)``; the ``snapshot``
    collector reproduces this function's series). Semantics and chunking
    (``windows_per_step``, one host transfer per chunk) are the engine's;
    bit-for-bit equivalent to :func:`run_windows_reference` (the seed
    per-window loop).
    """
    import warnings

    import numpy as np

    from repro.core import engine, metrics

    warnings.warn(
        "gpac.run_windows is deprecated; use repro.core.engine.run with"
        " engine.spec_from_config(cfg) and the 'snapshot' collector",
        DeprecationWarning,
        stacklevel=2,
    )

    trace = np.asarray(trace)
    n_w = trace.shape[0]
    if n_w == 0:
        return state, []
    state, host = engine.run(
        engine.spec_from_config(cfg), state, trace[None],
        policy=policy, backend=backend, use_gpac=use_gpac,
        max_batches=max_batches, budget=budget,
        windows_per_step=windows_per_step, collect=("snapshot",),
    )
    series = [
        {
            k: (float(v[w]) if k in metrics.FLOAT_METRICS else int(v[w]))
            for k, v in host.items()
        }
        for w in range(n_w)
    ]
    return state, series


def run_windows_reference(
    cfg: GpacConfig,
    state: TieredState,
    trace: jax.Array,
    **kw,
) -> tuple[TieredState, list[dict]]:
    """Original python window loop (one host sync per window): the
    equivalence oracle for the scan-fused :func:`run_windows`."""
    from repro.core import metrics

    series = []
    for w in range(trace.shape[0]):
        state = window_step(cfg, state, trace[w], **kw)
        series.append(metrics.snapshot(cfg, state))
    return state, series
