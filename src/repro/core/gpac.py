"""GPAC orchestration (paper Fig. 5): telemetry -> filter -> consolidate.

``gpac_maintenance`` is the guest daemon's periodic pass; ``window_step`` is
the full simulation step the benchmarks drive: accesses -> (optional GPAC) ->
host tier tick -> window roll. Host and guest layers only communicate through
the address space itself -- there is no API between them (design goal 1).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import address_space as asp
from repro.core import consolidator, filter as pfilter, telemetry, tiering
from repro.core.types import GpacConfig, TieredState


@partial(jax.jit, static_argnames=("cfg", "backend", "max_batches", "cl"))
def gpac_maintenance(
    cfg: GpacConfig,
    state: TieredState,
    backend: str = "ipt",
    max_batches: int = 8,
    cl: int | None = None,
    allow: jax.Array | None = None,
    hp_range: tuple | None = None,
) -> TieredState:
    """One guest-side GPAC pass: classify hotness, filter scattered hot pages,
    consolidate them batch-by-batch (<= hp_ratio pages per Algorithm-1 call).

    ``allow``/``hp_range`` confine the pass to one guest's logical pages and
    GPA segment in the multi-tenant simulation (each guest runs its own GPAC
    daemon over its own address space, as in the paper).
    """
    hot = telemetry.hot_mask(cfg, state, backend)
    batches, _ = pfilter.select_batches(cfg, state, hot, max_batches, cl, allow)
    return consolidator.consolidate_batches(cfg, state, batches, hp_range)


@partial(
    jax.jit,
    static_argnames=("cfg", "policy", "backend", "use_gpac", "max_batches", "budget"),
)
def window_step(
    cfg: GpacConfig,
    state: TieredState,
    accesses: jax.Array,
    policy: str = "memtierd",
    backend: str = "ipt",
    use_gpac: bool = True,
    max_batches: int = 8,
    budget: int = 64,
) -> TieredState:
    """One telemetry window: record accesses, run GPAC (guest), run the host
    tiering tick (block-granular, GPAC-oblivious), roll the window."""
    state = asp.record_accesses(cfg, state, accesses)
    if use_gpac:
        state = gpac_maintenance(cfg, state, backend, max_batches)
    state = tiering.tick(cfg, state, policy, budget=budget)
    return telemetry.end_window(cfg, state)


def run_windows(
    cfg: GpacConfig,
    state: TieredState,
    trace: jax.Array,
    **kw,
) -> tuple[TieredState, list[dict]]:
    """Drive ``window_step`` over a (n_windows, accesses_per_window) trace,
    collecting per-window metrics (python loop: benchmarks want the series)."""
    from repro.core import metrics

    series = []
    for w in range(trace.shape[0]):
        state = window_step(cfg, state, trace[w], **kw)
        series.append(metrics.snapshot(cfg, state))
    return state, series
