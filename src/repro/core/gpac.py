"""GPAC orchestration (paper Fig. 5): telemetry -> filter -> consolidate.

``gpac_maintenance`` is the guest daemon's periodic pass; ``window_step`` is
the full simulation step the benchmarks drive: accesses -> (optional GPAC) ->
host tier tick -> window roll. Host and guest layers only communicate through
the address space itself -- there is no API between them (design goal 1).

``run_windows`` is the scan-fused driver: the whole window loop runs as one
device-side ``lax.scan`` with stacked metric snapshots, chunked by a
``windows_per_step`` knob, so the host syncs once per chunk instead of once
per window (see ``run_windows_reference`` for the seed per-window loop).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import address_space as asp
from repro.core import consolidator, filter as pfilter, telemetry, tiering
from repro.core.types import GpacConfig, TieredState


@partial(jax.jit, static_argnames=("cfg", "backend", "max_batches", "cl"))
def gpac_maintenance(
    cfg: GpacConfig,
    state: TieredState,
    backend: str = "ipt",
    max_batches: int = 8,
    cl: int | None = None,
    allow: jax.Array | None = None,
    hp_range: tuple | None = None,
) -> TieredState:
    """One guest-side GPAC pass: classify hotness, filter scattered hot pages,
    consolidate them batch-by-batch (<= hp_ratio pages per Algorithm-1 call).

    ``allow``/``hp_range`` confine the pass to one guest's logical pages and
    GPA segment in the multi-tenant simulation (each guest runs its own GPAC
    daemon over its own address space, as in the paper).
    """
    hot = telemetry.hot_mask(cfg, state, backend)
    batches, _ = pfilter.select_batches(cfg, state, hot, max_batches, cl, allow)
    return consolidator.consolidate_batches(cfg, state, batches, hp_range)


@partial(
    jax.jit,
    static_argnames=(
        "cfg", "backend", "max_batches", "cl", "n_guests",
        "logical_per_guest", "hp_per_guest",
    ),
)
def gpac_maintenance_batched(
    cfg: GpacConfig,
    state: TieredState,
    backend: str,
    max_batches: int,
    cl: int | None,
    n_guests: int,
    logical_per_guest: int,
    hp_per_guest: int,
) -> TieredState:
    """All N guest daemons' GPAC passes in one batched invocation.

    The guests' logical and GPA segments are disjoint and tile their spaces,
    so one hot-mask classification, one row-wise batched filter
    (:func:`repro.core.filter.select_batches_per_guest`) and ``max_batches``
    guest-wide consolidation rounds
    (:func:`repro.core.consolidator.consolidate_batches_multi`) reproduce N
    sequential :func:`gpac_maintenance` calls bit-for-bit -- with O(1) trace
    cost and ~n_guests x less classification/sort work."""
    hot = telemetry.hot_mask(cfg, state, backend)
    batches = pfilter.select_batches_per_guest(
        cfg, state, hot, max_batches, cl, n_guests, logical_per_guest
    )
    return consolidator.consolidate_batches_multi(cfg, state, batches, hp_per_guest)


@partial(
    jax.jit,
    static_argnames=("cfg", "policy", "backend", "use_gpac", "max_batches", "budget"),
)
def window_step(
    cfg: GpacConfig,
    state: TieredState,
    accesses: jax.Array,
    policy: str = "memtierd",
    backend: str = "ipt",
    use_gpac: bool = True,
    max_batches: int = 8,
    budget: int = 64,
) -> TieredState:
    """One telemetry window: record accesses, run GPAC (guest), run the host
    tiering tick (block-granular, GPAC-oblivious), roll the window."""
    state = asp.record_accesses(cfg, state, accesses)
    if use_gpac:
        state = gpac_maintenance(cfg, state, backend, max_batches)
    state = tiering.tick(cfg, state, policy, budget=budget)
    return telemetry.end_window(cfg, state)


@partial(
    jax.jit,
    static_argnames=("cfg", "policy", "backend", "use_gpac", "max_batches", "budget"),
)
def _run_windows_chunk(
    cfg: GpacConfig,
    state: TieredState,
    chunk: jax.Array,  # int32[n_windows, accesses_per_window]
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
) -> tuple[TieredState, dict]:
    """Scan-fused window loop: one traced window step, metric snapshots
    stacked on device (no per-window host sync)."""
    from repro.core import metrics

    def body(st, acc):
        st = window_step(cfg, st, acc, policy, backend, use_gpac, max_batches, budget)
        return st, metrics.device_snapshot(cfg, st)

    return jax.lax.scan(body, state, chunk)


def run_windows(
    cfg: GpacConfig,
    state: TieredState,
    trace: jax.Array,
    policy: str = "memtierd",
    backend: str = "ipt",
    use_gpac: bool = True,
    max_batches: int = 8,
    budget: int = 64,
    windows_per_step: int = 0,
) -> tuple[TieredState, list[dict]]:
    """Drive ``window_step`` over a (n_windows, accesses_per_window) trace,
    collecting per-window metrics.

    The loop is a device-side ``lax.scan``; ``windows_per_step`` bounds how
    many windows each jitted step fuses (0 = the whole trace in one step) and
    the stacked metric series crosses to the host once per chunk. Pick a
    ``windows_per_step`` that divides ``n_windows`` -- a shorter trailing
    chunk has a different scan shape and pays one extra trace/compile per
    fresh process. Bit-for-bit equivalent to :func:`run_windows_reference`
    (the seed per-window loop).
    """
    import numpy as np

    from repro.core import metrics

    n_w = trace.shape[0]
    if n_w == 0:
        return state, []
    wps = n_w if windows_per_step <= 0 else min(windows_per_step, n_w)
    chunks = []
    for s in range(0, n_w, wps):
        state, ys = _run_windows_chunk(
            cfg, state, jnp.asarray(trace[s : s + wps]),
            policy, backend, use_gpac, max_batches, budget,
        )
        chunks.append(ys)
    host = {k: np.concatenate([np.asarray(y[k]) for y in chunks]) for k in chunks[0]}
    series = [
        {
            k: (float(v[w]) if k in metrics.FLOAT_METRICS else int(v[w]))
            for k, v in host.items()
        }
        for w in range(n_w)
    ]
    return state, series


def run_windows_reference(
    cfg: GpacConfig,
    state: TieredState,
    trace: jax.Array,
    **kw,
) -> tuple[TieredState, list[dict]]:
    """Original python window loop (one host sync per window): the
    equivalence oracle for the scan-fused :func:`run_windows`."""
    from repro.core import metrics

    series = []
    for w in range(trace.shape[0]):
        state = window_step(cfg, state, trace[w], **kw)
        series.append(metrics.snapshot(cfg, state))
    return state, series
