"""Measurement layer: near-memory usage, skew CDFs, hit rates, and the
calibrated latency/throughput model that stands in for the paper's hardware
counters (NVMM loads, stall cycles) on this CPU-only container.

Latency constants (ns per cacheline access) follow the paper's tier ordering
(HBM < DRAM < CXL < NVMM) with magnitudes from public measurements; they are
*relative* inputs to a throughput model, not absolute claims.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import GpacConfig, TieredState, allocated_hp_mask

TIER_LATENCY_NS = {
    "hbm": 45.0,
    "dram": 90.0,
    "cxl": 220.0,
    "nvmm": 350.0,
}
# paper tier pairs: (near, far)
TIER_PAIRS = {
    "dram_nvmm": ("dram", "nvmm"),
    "dram_cxl": ("dram", "cxl"),
    "hbm_dram": ("hbm", "dram"),
}


def near_usage(cfg: GpacConfig, state: TieredState) -> jax.Array:
    """Fraction of the guest's resident set currently placed in near memory
    (the paper's 'near memory consumption', Figs. 7-8, normalized to RSS)."""
    alloc = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    rss = jnp.maximum(alloc.sum(), 1)
    return (alloc & in_near).sum() / rss


def near_capacity_used(cfg: GpacConfig, state: TieredState) -> jax.Array:
    """Fraction of near-tier capacity occupied by resident data."""
    alloc = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    return (alloc & in_near).sum() / cfg.n_near


def hit_rate(state: TieredState) -> jax.Array:
    h = state.stats["near_hits"]
    f = state.stats["far_hits"]
    return h / jnp.maximum(h + f, 1)


def skew_cdf(per_hp_accessed: np.ndarray, hp_ratio: int) -> np.ndarray:
    """CDF over huge pages of #accessed subpages (paper Fig. 2). Only counts
    huge pages with at least one accessed subpage."""
    counts = per_hp_accessed[per_hp_accessed > 0]
    if counts.size == 0:
        return np.zeros(hp_ratio + 1)
    hist = np.bincount(counts, minlength=hp_ratio + 1)
    return np.cumsum(hist) / counts.size


def skewed_hot_fraction(per_hp_hot: np.ndarray, cl: int) -> float:
    """Fraction of hot huge pages that are skewed (< cl hot subpages) --
    the quantity GPAC drives toward zero."""
    hot = per_hp_hot[per_hp_hot > 0]
    if hot.size == 0:
        return 0.0
    return float((hot < cl).sum() / hot.size)


def modeled_access_time_ns(
    state: TieredState, tier_pair: str = "dram_nvmm"
) -> jax.Array:
    """Average memory access time under the tier pair's latencies, weighted by
    observed near/far hits -- the stand-in for stall-cycle counters."""
    near_t, far_t = (TIER_LATENCY_NS[t] for t in TIER_PAIRS[tier_pair])
    h = state.stats["near_hits"].astype(jnp.float32)
    f = state.stats["far_hits"].astype(jnp.float32)
    return (h * near_t + f * far_t) / jnp.maximum(h + f, 1)


# one calibration for every figure (see modeled_throughput's docstring)
COMPUTE_NS_PER_OP = 700.0
MEM_ACCESSES_PER_OP = 1.0


def modeled_throughput(
    state: TieredState,
    tier_pair: str = "dram_nvmm",
    compute_ns_per_op: float = COMPUTE_NS_PER_OP,
    mem_accesses_per_op: float = MEM_ACCESSES_PER_OP,
    migration_ns: float = 0.0,
) -> jax.Array:
    """Ops/sec under a simple bottleneck model: op latency = fixed compute +
    memory accesses at the tier-weighted AMAT + amortized migration cost.

    Calibration (one set of constants for every figure): a Redis-like op is
    ~700 ns of CPU/network work + ~1 LLC-missing access. At the paper's
    at-scale hit-rate split this yields ~+13% for Memtierd+GPAC over Memtierd
    (Fig. 9) and ~+6%/+5% for the CXL/HBM pairs (Figs. 13-14), matching the
    reported magnitudes without per-figure tuning.
    """
    amat = modeled_access_time_ns(state, tier_pair)
    op_ns = compute_ns_per_op + mem_accesses_per_op * amat + migration_ns
    return 1e9 / op_ns


def throughput_from_hits(
    nh: np.ndarray, fh: np.ndarray, tier_pair: str = "dram_nvmm"
) -> tuple[np.ndarray, np.ndarray]:
    """Host-side (numpy) per-window hit-rate and modeled-throughput series
    from near/far hit counts -- the same calibration as
    :func:`modeled_throughput`, shared by the multi-guest window drivers."""
    near_ns, far_ns = (TIER_LATENCY_NS[t] for t in TIER_PAIRS[tier_pair])
    tot = np.maximum(nh + fh, 1)
    amat = (nh * near_ns + fh * far_ns) / tot
    return nh / tot, 1e9 / (COMPUTE_NS_PER_OP + MEM_ACCESSES_PER_OP * amat)


# snapshot keys that are float-valued; everything else is an int counter
# (shared by snapshot() and the scan-fused drivers that host-convert series)
FLOAT_METRICS = ("near_usage", "near_capacity_used", "hit_rate")


def device_snapshot(cfg: GpacConfig, state: TieredState) -> dict:
    """Device-side analogue of :func:`snapshot`: a dict of scalar arrays, safe
    to emit from inside jit / ``lax.scan`` (the scan-fused window drivers
    stack these per window and cross to the host once)."""
    return dict(
        epoch=state.epoch,
        near_usage=near_usage(cfg, state),
        near_capacity_used=near_capacity_used(cfg, state),
        hit_rate=hit_rate(state),
        **state.stats,
    )


def snapshot(cfg: GpacConfig, state: TieredState) -> dict:
    """Device->host pull of the metrics a benchmark window records."""
    d = device_snapshot(cfg, state)
    return {
        k: (float(v) if k in FLOAT_METRICS else int(v)) for k, v in d.items()
    }
