"""Guest-axis device sharding for the unified engine driver (DESIGN.md §9).

The batched engine's guest axis is embarrassingly parallel: the
:class:`repro.core.engine.EngineSpec` segment-offset tables give every guest
disjoint logical and GPA segments, so the padded per-guest matrices (access
batches, ragged filter top-k rows, consolidation rounds, metric rows) shard
cleanly over a 1-D ``"guest"`` mesh axis via ``shard_map``. The shared host
state stays **replicated**; per-window phases alternate between sharded and
replicated computation:

1. **access phase** (sharded): each device translates and histograms its own
   guests' accesses and applies the histogram *locally* (guest g's counts,
   huge-page counts and touch epochs all live inside g's own segments).
2. **GPAC phase** (sharded): each device runs the filter top-k and the
   round-major Algorithm-1 consolidation only for its own guests' segment
   rows (``gpac.gpac_maintenance_rows``) on its local state copy. Both
   phases diverge *only inside that device's own segments*: hot masks,
   candidate scores, region allocation and the data copy never read another
   guest's telemetry or mappings.
3. **merge** (one collective): the diverged arrays are recombined by
   ownership: every logical page / GPA page / huge page / host slot is
   owned by exactly one guest, hence written by exactly one device, so
   ``psum(where(own, local, 0))`` reconstructs each array exactly (integer
   sums with one non-zero contributor). Payload pools are combined in their
   *bit patterns* (``bitcast``) so the merge is bit-exact for every dtype.
   Per-guest hit vectors ride in the same psum -- cross-device sync points
   dominate the sharded overhead on CPU meshes, so each window performs
   exactly **one** collective.
4. **host tick** (replicated): the merged state is identical on all devices,
   so the shared near-tier arbitration (``tiering.tick``: global top-k over
   block scores) runs replicated and deterministically -- the paper's single
   host daemon, not N partitioned ones.

Guest counts that do not divide the mesh are padded with empty segment rows
(all ``-1``): padded rows translate nothing, select nothing, allocate
nothing, and own nothing, so they are end-to-end no-ops.

Everything degrades to a no-op without a mesh, as ``repro.models.dist.Dist``
does: :func:`guest_mesh` returns ``None`` on a single-device host and
``engine.run_sharded`` falls back to ``engine.run``.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import address_space as asp
from repro.core import gpac, telemetry, tiering
from repro.core.types import GpacConfig, TieredState

AXIS = "guest"


# --------------------------------------------------------------------------
# mesh + padding helpers
# --------------------------------------------------------------------------
def guest_mesh(n_devices: int | None = None):
    """1-D mesh over ``n_devices`` local devices along the ``"guest"`` axis.

    ``n_devices=None`` uses every local device and returns ``None`` when only
    one is available (the no-mesh degradation: callers fall back to the
    unsharded driver). Pass an explicit count to force a mesh -- including a
    1-device mesh, which exercises the full shard_map path.
    """
    avail = jax.local_device_count()
    if n_devices is None:
        if avail == 1:
            return None
        n_devices = avail
    if n_devices > avail:
        raise ValueError(
            f"guest_mesh: asked for {n_devices} devices, have {avail}"
        )
    return jax.make_mesh((n_devices,), (AXIS,))


def mesh_size(mesh) -> int:
    return mesh.shape[AXIS]


def padded_guest_count(n_guests: int, n_shards: int) -> int:
    """Smallest multiple of ``n_shards`` >= ``n_guests``."""
    return -(-n_guests // n_shards) * n_shards


def pad_guest_rows(rows: np.ndarray, n_shards: int, fill=-1) -> np.ndarray:
    """Pad a per-guest matrix ``[n_guests, ...]`` with ``fill`` rows up to a
    multiple of ``n_shards`` (empty segments: -1 everywhere is a no-op row
    through the whole engine)."""
    n_g = rows.shape[0]
    g_pad = padded_guest_count(n_g, n_shards)
    if g_pad == n_g:
        return rows
    pad = np.full((g_pad - n_g, *rows.shape[1:]), fill, rows.dtype)
    return np.concatenate([rows, pad], axis=0)


def guest_tables(spec, n_shards: int) -> dict[str, np.ndarray]:
    """The spec's per-guest segment tables, padded to the mesh: trace-time
    numpy constants that enter the shard-mapped driver as ``P("guest", ...)``
    sharded arrays (each device sees only its own guests' rows)."""
    return dict(
        logical_lo=pad_guest_rows(
            np.asarray(spec.logical_offsets[:-1], np.int32), n_shards, fill=0
        ),
        logical_pad=pad_guest_rows(spec.logical_pad_index(), n_shards),
        hp_pad=pad_guest_rows(spec.hp_pad_index(), n_shards),
    )


# --------------------------------------------------------------------------
# bit-exact ownership merge
# --------------------------------------------------------------------------
def _own_mask(idx_rows: jax.Array, n: int) -> jax.Array:
    """bool[n]: ids covered by these (padded, -1 filled) segment-table rows."""
    flat = idx_rows.reshape(-1)
    safe = jnp.where(flat >= 0, flat, n)
    return jnp.zeros((n + 1,), bool).at[safe].set(True, mode="drop")[:n]


def _owned_bits(x: jax.Array, own: jax.Array) -> jax.Array:
    """This device's contribution to the bit-exact combine: the owned
    elements' *bit patterns*, 0 elsewhere. Summed across devices, every
    element has exactly one non-zero contributor, so the (integer) psum *is*
    that contributor's bit pattern -- no float rounding, -0.0 survives.
    4-byte dtypes view as int32 directly; anything else goes through the
    uint8 view (one trailing byte axis)."""
    if jnp.issubdtype(x.dtype, jnp.integer) and x.dtype.itemsize <= 4:
        return jnp.where(own, x, 0)
    if x.dtype.itemsize == 4:
        return jnp.where(own, jax.lax.bitcast_convert_type(x, jnp.int32), 0)
    bits = jax.lax.bitcast_convert_type(x, jnp.uint8)  # [..., itemsize]
    return jnp.where(own[..., None], bits, 0)


def _from_bits(bits: jax.Array, like: jax.Array) -> jax.Array:
    if bits.dtype == like.dtype:
        return bits
    return jax.lax.bitcast_convert_type(bits, like.dtype)


def merge_window(
    cfg: GpacConfig,
    base: TieredState,  # replicated pre-window state
    local: TieredState,  # after this device's local access + GPAC phases
    logical_pad: jax.Array,  # int32[G_loc, max_logical] local segment rows
    hp_pad: jax.Array,  # int32[G_loc, max_hp] local segment rows
    extras: tuple[jax.Array, ...],  # per-guest vectors riding the collective
    merged_gpac: bool,
) -> tuple[TieredState, tuple[jax.Array, ...]]:
    """Recombine per-device window phases into one replicated state with a
    **single** psum.

    The access phase writes ``guest_counts`` / ``host_counts`` /
    ``last_touch_epoch``; the GPAC phase writes ``gpt`` / ``rmap`` /
    ``region_epoch`` / the payload pools; both bump ``stats``. Each array is
    recombined by static segment ownership (logical pages, GPA pages, huge
    pages) or dynamic slot ownership (``slot_owner`` is unchanged during
    both phases, so slot ``s`` belongs to the guest owning huge page
    ``slot_owner[s]``). Stats are int32 counters: replicated base + psum of
    per-device deltas is exact. ``merged_gpac=False`` (GPAC off) skips the
    mapping/pool arrays entirely -- they equal ``base``.
    """
    own_logical = _own_mask(logical_pad, cfg.n_logical)
    own_hp = _own_mask(hp_pad, cfg.n_gpa_hp)
    contrib = dict(
        guest_counts=_owned_bits(local.guest_counts, own_logical),
        host_counts=_owned_bits(local.host_counts, own_hp),
        last_touch_epoch=_owned_bits(local.last_touch_epoch, own_hp),
        stats={k: local.stats[k] - base.stats[k] for k in base.stats},
        extras=extras,
    )
    if merged_gpac:
        own_gpa = jnp.repeat(own_hp, cfg.hp_ratio)
        own_slot = own_hp[base.slot_owner]  # slot -> owning hp -> owned here?
        contrib.update(
            gpt=_owned_bits(local.gpt, own_logical),
            rmap=_owned_bits(local.rmap, own_gpa),
            region_epoch=_owned_bits(local.region_epoch, own_hp),
            near_pool=_owned_bits(
                local.near_pool, own_slot[: cfg.n_near][:, None, None]
            ),
            far_pool=_owned_bits(
                local.far_pool, own_slot[cfg.n_near :][:, None, None]
            ),
        )
    merged = jax.lax.psum(contrib, AXIS)
    state = dataclasses.replace(
        base,
        guest_counts=merged["guest_counts"],
        host_counts=merged["host_counts"],
        last_touch_epoch=merged["last_touch_epoch"],
        stats={k: base.stats[k] + merged["stats"][k] for k in base.stats},
    )
    if merged_gpac:
        state = dataclasses.replace(
            state,
            gpt=merged["gpt"],
            rmap=merged["rmap"],
            region_epoch=merged["region_epoch"],
            near_pool=_from_bits(merged["near_pool"], base.near_pool),
            far_pool=_from_bits(merged["far_pool"], base.far_pool),
        )
    return state, merged["extras"]


# --------------------------------------------------------------------------
# the shard-mapped window body
# --------------------------------------------------------------------------
def _spread_rows(x_loc: jax.Array, n_shards: int) -> jax.Array:
    """Place this device's per-local-guest row vector at its global guest
    positions in a zero ``[G_pad]`` vector: rows are contiguous per device,
    so summed across devices (inside an existing psum) this reconstructs the
    full per-guest vector without a separate all-gather."""
    g_loc = x_loc.shape[0]
    pos = jax.lax.axis_index(AXIS) * g_loc + jnp.arange(g_loc)
    return jnp.zeros((g_loc * n_shards,), x_loc.dtype).at[pos].set(x_loc)


def _sharded_window(
    spec,  # repro.core.engine.EngineSpec (static)
    n_shards: int,
    state: TieredState,  # replicated
    accesses: jax.Array,  # int32[G_loc, k] guest-local ids of local guests
    logical_lo: jax.Array,  # int32[G_loc]
    logical_pad: jax.Array,  # int32[G_loc, max_logical]
    hp_pad: jax.Array,  # int32[G_loc, max_hp]
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    collect: tuple[str, ...],
) -> tuple[TieredState, dict]:
    """One engine window on one device: sharded access + GPAC phases around
    the replicated host tick (see the module docstring for the phase plan).
    Bit-for-bit equal to ``engine._window`` on the unpadded guests.

    Collective budget: cross-device sync points dominate the sharded
    overhead (every psum is a device rendezvous), so both sharded phases run
    on the device's *local* state copy -- a guest's telemetry, hot mask,
    candidate scores and consolidation regions all live inside its own
    segments, so the local copy agrees with the would-be merged state
    everywhere the GPAC phase reads it -- and a **single** psum per window
    (:func:`merge_window`) recombines everything, per-guest hit vectors
    included.
    """
    from repro.core.engine import run_collectors

    cfg = spec.cfg
    base = state
    # ---- 1. access phase (sharded, applied locally) ----------------------
    ids = jnp.where(accesses >= 0, accesses + logical_lo[:, None], -1)
    slot, _, valid = asp.translate(cfg, state, ids)
    near_loc = (valid & (slot < cfg.n_near)).sum(axis=1)
    far_loc = (valid & (slot >= cfg.n_near)).sum(axis=1)
    local = asp.apply_access_histogram(
        cfg, state, asp.access_histogram(cfg, ids, valid)
    )
    # ---- 2. GPAC phase (sharded: this device's segment rows only) --------
    if use_gpac:
        local = gpac.gpac_maintenance_rows(
            cfg, local, backend, max_batches,
            jnp.asarray(spec.cl_per_logical()), logical_pad, hp_pad,
        )
    # ---- 3. one-collective ownership merge -------------------------------
    state, (near_all, far_all) = merge_window(
        cfg, base, local, logical_pad, hp_pad,
        (_spread_rows(near_loc, n_shards), _spread_rows(far_loc, n_shards)),
        merged_gpac=use_gpac,
    )
    # ---- 4. host tick + window roll (replicated) ------------------------
    state = tiering.tick(cfg, state, policy, budget=budget)
    state = telemetry.end_window(cfg, state)
    window = dict(
        near_hits=near_all[: spec.n_guests],
        far_hits=far_all[: spec.n_guests],
    )
    return state, run_collectors(spec, state, window, collect)


@lru_cache(maxsize=64)
def _chunk_fn(
    spec,  # canonical EngineSpec
    mesh,
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    collect: tuple[str, ...],
):
    """Compiled sharded chunk driver for one (spec, mesh, knobs) key: a
    ``shard_map`` over the scan of windows. State and series are replicated
    out-specs; the traces and segment tables shard over the guest axis."""

    n_shards = mesh_size(mesh)

    def body(state, chunk, logical_lo, logical_pad, hp_pad):
        def window(st, acc):
            return _sharded_window(
                spec, n_shards, st, acc, logical_lo, logical_pad, hp_pad,
                policy, backend, use_gpac, max_batches, budget, collect,
            )

        return jax.lax.scan(window, state, chunk)

    sharded = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P(None, AXIS, None), P(AXIS), P(AXIS, None), P(AXIS, None)),
        out_specs=P(),
        # psum results are replicated but 0.4.x rep-checking cannot always
        # infer it; correctness is pinned by the equivalence tests
        check_rep=False,
    )
    return jax.jit(sharded)


def run_chunk_sharded(
    spec,
    mesh,
    state: TieredState,
    chunk: jax.Array,  # int32[n_windows, G_pad, k] (guest axis mesh-padded)
    tables: dict,
    *,
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    collect: tuple[str, ...],
) -> tuple[TieredState, dict]:
    """One scan-fused chunk of the sharded engine (``engine.run_sharded``'s
    inner loop)."""
    fn = _chunk_fn(
        spec, mesh, policy, backend, use_gpac, max_batches, budget, collect
    )
    return fn(
        state,
        chunk,
        jnp.asarray(tables["logical_lo"]),
        jnp.asarray(tables["logical_pad"]),
        jnp.asarray(tables["hp_pad"]),
    )
