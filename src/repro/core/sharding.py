"""Guest-axis device sharding for the unified engine driver (DESIGN.md §9).

The batched engine's guest axis is embarrassingly parallel: the
:class:`repro.core.engine.EngineSpec` segment-offset tables give every guest
disjoint logical and GPA segments, so the padded per-guest matrices (access
batches, ragged filter top-k rows, consolidation rounds, metric rows) shard
cleanly over a 1-D ``"guest"`` mesh axis via ``shard_map``. The shared host
state stays **replicated**; per-window phases alternate between sharded and
replicated computation:

1. **access phase** (sharded): each device translates and histograms its own
   guests' accesses and applies the histogram *locally* (guest g's counts,
   huge-page counts and touch epochs all live inside g's own segments).
2. **GPAC phase** (sharded): each device runs the filter top-k and the
   round-major Algorithm-1 consolidation only for its own guests' segment
   rows (``gpac.gpac_maintenance_rows``) on its local state copy. Both
   phases diverge *only inside that device's own segments*: hot masks,
   candidate scores, region allocation and the data copy never read another
   guest's telemetry or mappings.
3. **merge** (one collective): the diverged arrays are recombined by
   ownership: every logical page / GPA page / huge page / host slot is
   owned by exactly one guest, hence written by exactly one device, so
   ``psum(where(own, local, 0))`` reconstructs each array exactly (integer
   sums with one non-zero contributor). Payload pools are combined in their
   *bit patterns* (``bitcast``) so the merge is bit-exact for every dtype.
   Per-guest hit vectors ride in the same psum -- cross-device sync points
   dominate the sharded overhead on CPU meshes, so each window performs
   exactly **one** collective.
4. **host tick** (replicated): the merged state is identical on all devices,
   so the shared near-tier arbitration (``tiering.tick``: global top-k over
   block scores) runs replicated and deterministically -- the paper's single
   host daemon, not N partitioned ones.

Guest counts that do not divide the mesh are padded with empty segment rows
(all ``-1``): padded rows translate nothing, select nothing, allocate
nothing, and own nothing, so they are end-to-end no-ops.

Everything degrades to a no-op without a mesh, as ``repro.models.dist.Dist``
does: :func:`guest_mesh` returns ``None`` on a single-device host and
``engine.run_sharded`` falls back to ``engine.run``.

The second half of this module is the **host-partitioned near tier**
(DESIGN.md §11, ``engine.run_sharded(host_sharded=True)``): instead of
replicating the host state, each device carries only its own contiguous
block range (:class:`HostPartition`) with the payload stored per huge page,
scores promotion/demotion locally, and one arbitration exchange per window
(``repro.core.tiering``'s sharded ticks) resolves cross-partition
contention bit-for-bit against the replicated tick -- per-device host-state
bytes scale ~1/n_devices.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import address_space as asp
from repro.core import faults as faults_mod
from repro.core import gpac, telemetry, tiering
from repro.core import tiers as tiers_mod
from repro.core.types import FREE, GpacConfig, TieredState

AXIS = "guest"


# --------------------------------------------------------------------------
# collective-volume accounting (DESIGN.md §17)
# --------------------------------------------------------------------------
# Per-site psum payload bytes, recorded as a plain-Python side effect while
# the chunk function is *traced* -- tracer shapes/dtypes are concrete, so
# the numbers are the exact per-call payloads of the compiled program.
# Sizes persist until the next reset; a fully cache-hit rerun does not
# retrace and therefore leaves previously recorded sites in place, so reset
# before the run whose volume you want to attribute.
_COLLECTIVE_BYTES: dict[str, int] = {}


def _psum_counted(site: str, tree):
    """``jax.lax.psum`` plus trace-time byte accounting of the payload."""
    _COLLECTIVE_BYTES[site] = sum(
        leaf.size * leaf.dtype.itemsize
        for leaf in jax.tree_util.tree_leaves(tree)
    )
    return jax.lax.psum(tree, AXIS)


def reset_collective_bytes() -> None:
    """Clear the per-site psum payload record (call before the run)."""
    _COLLECTIVE_BYTES.clear()


def collective_bytes() -> dict[str, int]:
    """Per-site psum payload bytes from the most recent trace.

    Sites: ``merge_window`` (replicated-host paths, one psum per window;
    the only collective the churn driver issues), ``host_exchange`` (the
    host-partitioned arbitration exchange, one psum per stride group) and
    ``host_chunk_exit`` (host-partitioned chunk-boundary reconstruction,
    one psum per chunk).
    """
    return dict(_COLLECTIVE_BYTES)


# --------------------------------------------------------------------------
# mesh + padding helpers
# --------------------------------------------------------------------------
def guest_mesh(n_devices: int | None = None):
    """1-D mesh over ``n_devices`` devices along the ``"guest"`` axis.

    ``n_devices=None`` uses every device and returns ``None`` when only one
    is available (the no-mesh degradation: callers fall back to the unsharded
    driver). Pass an explicit count to force a mesh -- including a 1-device
    mesh, which exercises the full shard_map path.

    In a multi-process job (``launch.multihost.initialize``,
    ``jax.process_count() > 1``) the mesh spans every process's devices and
    must cover all of them: a partial mesh would leave some processes holding
    no shard of the SPMD program, so any ``n_devices`` below the global count
    is rejected.
    """
    avail = jax.device_count()
    multiproc = jax.process_count() > 1
    if n_devices is None:
        if avail == 1:
            return None
        n_devices = avail
    if n_devices > avail:
        raise ValueError(
            f"guest_mesh: asked for {n_devices} devices, have {avail}"
        )
    if multiproc and n_devices != avail:
        raise ValueError(
            f"guest_mesh: a multi-process mesh must span all "
            f"{avail} global devices ({jax.process_count()} processes), "
            f"got n_devices={n_devices}"
        )
    return jax.make_mesh((n_devices,), (AXIS,))


def mesh_size(mesh) -> int:
    return mesh.shape[AXIS]


def padded_guest_count(n_guests: int, n_shards: int) -> int:
    """Smallest multiple of ``n_shards`` >= ``n_guests``."""
    return -(-n_guests // n_shards) * n_shards


def pad_guest_rows(rows: np.ndarray, n_shards: int, fill=-1) -> np.ndarray:
    """Pad a per-guest matrix ``[n_guests, ...]`` with ``fill`` rows up to a
    multiple of ``n_shards`` (empty segments: -1 everywhere is a no-op row
    through the whole engine)."""
    n_g = rows.shape[0]
    g_pad = padded_guest_count(n_g, n_shards)
    if g_pad == n_g:
        return rows
    pad = np.full((g_pad - n_g, *rows.shape[1:]), fill, rows.dtype)
    return np.concatenate([rows, pad], axis=0)


def guest_tables(spec, n_shards: int) -> dict[str, np.ndarray]:
    """The spec's per-guest segment tables, padded to the mesh: trace-time
    numpy constants that enter the shard-mapped driver as ``P("guest", ...)``
    sharded arrays (each device sees only its own guests' rows)."""
    return dict(
        logical_lo=pad_guest_rows(
            np.asarray(spec.logical_offsets[:-1], np.int32), n_shards, fill=0
        ),
        logical_pad=pad_guest_rows(spec.logical_pad_index(), n_shards),
        hp_pad=pad_guest_rows(spec.hp_pad_index(), n_shards),
    )


# --------------------------------------------------------------------------
# bit-exact ownership merge
# --------------------------------------------------------------------------
def _own_mask(idx_rows: jax.Array, n: int) -> jax.Array:
    """bool[n]: ids covered by these (padded, -1 filled) segment-table rows."""
    flat = idx_rows.reshape(-1)
    safe = jnp.where(flat >= 0, flat, n)
    return jnp.zeros((n + 1,), bool).at[safe].set(True, mode="drop")[:n]


def _owned_bits(x: jax.Array, own: jax.Array) -> jax.Array:
    """This device's contribution to the bit-exact combine: the owned
    elements' *bit patterns*, 0 elsewhere. Summed across devices, every
    element has exactly one non-zero contributor, so the (integer) psum *is*
    that contributor's bit pattern -- no float rounding, -0.0 survives.
    4-byte dtypes view as int32 directly; anything else goes through the
    uint8 view (one trailing byte axis)."""
    if jnp.issubdtype(x.dtype, jnp.integer) and x.dtype.itemsize <= 4:
        return jnp.where(own, x, 0)
    if x.dtype.itemsize == 4:
        return jnp.where(own, jax.lax.bitcast_convert_type(x, jnp.int32), 0)
    bits = jax.lax.bitcast_convert_type(x, jnp.uint8)  # [..., itemsize]
    return jnp.where(own[..., None], bits, 0)


def _from_bits(bits: jax.Array, like: jax.Array) -> jax.Array:
    if bits.dtype == like.dtype:
        return bits
    return jax.lax.bitcast_convert_type(bits, like.dtype)


def merge_window(
    cfg: GpacConfig,
    base: TieredState,  # replicated pre-window state
    local: TieredState,  # after this device's local access + GPAC phases
    logical_pad: jax.Array,  # int32[G_loc, max_logical] local segment rows
    hp_pad: jax.Array,  # int32[G_loc, max_hp] local segment rows
    extras: tuple[jax.Array, ...],  # per-guest vectors riding the collective
    merged_gpac: bool,
) -> tuple[TieredState, tuple[jax.Array, ...]]:
    """Recombine per-device window phases into one replicated state with a
    **single** psum.

    The access phase writes ``guest_counts`` / ``host_counts`` /
    ``last_touch_epoch``; the GPAC phase writes ``gpt`` / ``rmap`` /
    ``region_epoch`` / the payload pools; both bump ``stats``. Each array is
    recombined by static segment ownership (logical pages, GPA pages, huge
    pages) or dynamic slot ownership (``slot_owner`` is unchanged during
    both phases, so slot ``s`` belongs to the guest owning huge page
    ``slot_owner[s]``). Stats are int32 counters: replicated base + psum of
    per-device deltas is exact. ``merged_gpac=False`` (GPAC off) skips the
    mapping/pool arrays entirely -- they equal ``base``.
    """
    own_logical = _own_mask(logical_pad, cfg.n_logical)
    own_hp = _own_mask(hp_pad, cfg.n_gpa_hp)
    contrib = dict(
        guest_counts=_owned_bits(local.guest_counts, own_logical),
        host_counts=_owned_bits(local.host_counts, own_hp),
        last_touch_epoch=_owned_bits(local.last_touch_epoch, own_hp),
        stats={k: local.stats[k] - base.stats[k] for k in base.stats},
        extras=extras,
    )
    if merged_gpac:
        own_gpa = jnp.repeat(own_hp, cfg.hp_ratio)
        own_slot = own_hp[base.slot_owner]  # slot -> owning hp -> owned here?
        contrib.update(
            gpt=_owned_bits(local.gpt, own_logical),
            rmap=_owned_bits(local.rmap, own_gpa),
            region_epoch=_owned_bits(local.region_epoch, own_hp),
            near_pool=_owned_bits(
                local.near_pool, own_slot[: cfg.n_near][:, None, None]
            ),
            far_pool=_owned_bits(
                local.far_pool, own_slot[cfg.n_near :][:, None, None]
            ),
        )
    merged = _psum_counted("merge_window", contrib)
    state = dataclasses.replace(
        base,
        guest_counts=merged["guest_counts"],
        host_counts=merged["host_counts"],
        last_touch_epoch=merged["last_touch_epoch"],
        stats={k: base.stats[k] + merged["stats"][k] for k in base.stats},
    )
    if merged_gpac:
        state = dataclasses.replace(
            state,
            gpt=merged["gpt"],
            rmap=merged["rmap"],
            region_epoch=merged["region_epoch"],
            near_pool=_from_bits(merged["near_pool"], base.near_pool),
            far_pool=_from_bits(merged["far_pool"], base.far_pool),
        )
    return state, merged["extras"]


# --------------------------------------------------------------------------
# the shard-mapped window body
# --------------------------------------------------------------------------
def _spread_rows(x_loc: jax.Array, n_shards: int) -> jax.Array:
    """Place this device's per-local-guest row vector at its global guest
    positions in a zero ``[G_pad]`` vector: rows are contiguous per device,
    so summed across devices (inside an existing psum) this reconstructs the
    full per-guest vector without a separate all-gather."""
    g_loc = x_loc.shape[0]
    pos = jax.lax.axis_index(AXIS) * g_loc + jnp.arange(g_loc)
    return jnp.zeros((g_loc * n_shards,), x_loc.dtype).at[pos].set(x_loc)


def _sharded_window(
    spec,  # repro.core.engine.EngineSpec (static)
    n_shards: int,
    state: TieredState,  # replicated
    accesses: jax.Array,  # int32[G_loc, k] guest-local ids of local guests
    logical_lo: jax.Array,  # int32[G_loc]
    logical_pad: jax.Array,  # int32[G_loc, max_logical]
    hp_pad: jax.Array,  # int32[G_loc, max_hp]
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    collect: tuple[str, ...],
) -> tuple[TieredState, dict]:
    """One engine window on one device: sharded access + GPAC phases around
    the replicated host tick (see the module docstring for the phase plan).
    Bit-for-bit equal to ``engine._window`` on the unpadded guests.

    Collective budget: cross-device sync points dominate the sharded
    overhead (every psum is a device rendezvous), so both sharded phases run
    on the device's *local* state copy -- a guest's telemetry, hot mask,
    candidate scores and consolidation regions all live inside its own
    segments, so the local copy agrees with the would-be merged state
    everywhere the GPAC phase reads it -- and a **single** psum per window
    (:func:`merge_window`) recombines everything, per-guest hit vectors
    included.
    """
    from repro.core.engine import run_collectors

    cfg = spec.cfg
    base = state
    # ---- 1. access phase (sharded, applied locally) ----------------------
    ids = jnp.where(accesses >= 0, accesses + logical_lo[:, None], -1)
    slot, _, valid = asp.translate(cfg, state, ids)
    near_loc = (valid & (slot < cfg.n_near)).sum(axis=1)
    far_loc = (valid & (slot >= cfg.n_near)).sum(axis=1)
    kb = spec.kernel_backend
    local = asp.apply_access_histogram(
        cfg, state, asp.access_histogram(cfg, ids, valid, kb), kb
    )
    # ---- 2. GPAC phase (sharded: this device's segment rows only) --------
    if use_gpac:
        local = gpac.gpac_maintenance_rows(
            cfg, local, backend, max_batches,
            jnp.asarray(spec.cl_per_logical()), logical_pad, hp_pad, kb,
        )
    # ---- 3. one-collective ownership merge -------------------------------
    extras = [
        _spread_rows(near_loc, n_shards), _spread_rows(far_loc, n_shards),
    ]
    if "tco" in collect:
        # local per-tier hit vector; the psum of int counts reproduces the
        # replicated tier_hit_counts exactly
        extras.append(
            tiers_mod.tier_hit_counts(spec.tier_vector, slot, valid))
    state, merged_extras = merge_window(
        cfg, base, local, logical_pad, hp_pad, tuple(extras),
        merged_gpac=use_gpac,
    )
    near_all, far_all = merged_extras[0], merged_extras[1]
    # ---- 4. host tick + window roll (replicated) ------------------------
    state = tiering.strided_tick(
        cfg, state, policy, stride=spec.arbitration_stride, budget=budget,
        tiers=spec.tiers,
    )
    state = telemetry.end_window(cfg, state)
    window = dict(
        near_hits=near_all[: spec.n_guests],
        far_hits=far_all[: spec.n_guests],
    )
    if "tco" in collect:
        window["tier_hits"] = merged_extras[2]
    return state, run_collectors(spec, state, window, collect)


# per-guest synthesis-table keys, in the order the chunk drivers append
# them as trailing (guest-sharded) arguments
_SYNTH_KEYS = ("seeds", "gids", "wid", "n_logical")


def _synth_args(synth_tables: dict) -> tuple:
    return tuple(jnp.asarray(synth_tables[k]) for k in _SYNTH_KEYS)


@lru_cache(maxsize=64)
def _chunk_fn(
    spec,  # canonical EngineSpec
    mesh,
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    collect: tuple[str, ...],
    plan=None,  # repro.data.traces.SynthPlan for on-device synthesis
):
    """Compiled sharded chunk driver for one (spec, mesh, knobs) key: a
    ``shard_map`` over the scan of windows. State and series are replicated
    out-specs; the traces and segment tables shard over the guest axis.

    With a ``plan`` the scan carries absolute window *indices* (replicated)
    instead of trace slices, and each device synthesizes only its own
    guests' accesses inside the window body from its sharded table rows --
    per-device trace residency is O(local guests x accesses_per_window).
    Per-guest RNG keys fold in the *global* guest id, so the generated
    streams are bit-identical to the unsharded driver's.
    """
    n_shards = mesh_size(mesh)

    def window_body(st, acc, logical_lo, logical_pad, hp_pad):
        return _sharded_window(
            spec, n_shards, st, acc, logical_lo, logical_pad, hp_pad,
            policy, backend, use_gpac, max_batches, budget, collect,
        )

    if plan is None:

        def body(state, chunk, logical_lo, logical_pad, hp_pad):
            def window(st, acc):
                return window_body(st, acc, logical_lo, logical_pad, hp_pad)

            return jax.lax.scan(window, state, chunk)

        in_specs = (
            P(), P(None, AXIS, None), P(AXIS), P(AXIS, None), P(AXIS, None),
        )
    else:
        from repro.data import traces as tr

        def body(state, widx, logical_lo, logical_pad, hp_pad,
                 seeds, gids, wid, n_logical):
            setup = tr.synth_setup(plan, dict(
                seeds=seeds, gids=gids, wid=wid, n_logical=n_logical))

            def window(st, w):
                acc = tr.synth_accesses(plan, setup, w)
                return window_body(st, acc, logical_lo, logical_pad, hp_pad)

            return jax.lax.scan(window, state, widx)

        in_specs = (
            P(), P(None), P(AXIS), P(AXIS, None), P(AXIS, None),
            P(AXIS), P(AXIS), P(AXIS), P(AXIS),
        )

    sharded = shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=P(),
        # psum results are replicated but 0.4.x rep-checking cannot always
        # infer it; correctness is pinned by the equivalence tests
        check_rep=False,
    )
    return jax.jit(sharded)


def run_chunk_sharded(
    spec,
    mesh,
    state: TieredState,
    chunk: jax.Array,  # int32[n_windows, G_pad, k], or int32[n_windows]
    tables: dict,      # window indices when plan is given
    *,
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    collect: tuple[str, ...],
    plan=None,
    synth_tables: dict | None = None,
) -> tuple[TieredState, dict]:
    """One scan-fused chunk of the sharded engine (``engine.run_sharded``'s
    inner loop)."""
    fn = _chunk_fn(
        spec, mesh, policy, backend, use_gpac, max_batches, budget, collect,
        plan,
    )
    args = (
        state,
        chunk,
        jnp.asarray(tables["logical_lo"]),
        jnp.asarray(tables["logical_pad"]),
        jnp.asarray(tables["hp_pad"]),
    )
    if plan is not None:
        args += _synth_args(synth_tables)
    return fn(*args)


# --------------------------------------------------------------------------
# sharded churn window (engine.run_churn's mesh path, DESIGN.md §13)
# --------------------------------------------------------------------------
def _churn_sharded_window(
    spec,  # canonical EngineSpec (static)
    n_shards: int,
    cs,  # repro.core.engine.ChurnState (replicated carry)
    accesses: jax.Array,  # int32[G_loc, k] guest-local ids of local guests
    frow: dict,  # replicated fault row (crash/restart/near_cap/drop)
    logical_lo: jax.Array,
    logical_pad: jax.Array,
    hp_pad: jax.Array,
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    slack: int,
    collect: tuple[str, ...],
):
    """:func:`_sharded_window` with the churn carry: the fault row and the
    activity mask are replicated, so ``apply_guest_faults`` and both
    replicated ticks compute identically on every device; only the access
    masking is per-device (each device silences its own local guests'
    rows). Still exactly **one** collective per window -- bit-for-bit equal
    to ``engine._churn_window`` on the unpadded guests."""
    from repro.core.engine import _CHURN_SERIES, ChurnState, run_collectors

    cfg = spec.cfg
    n_g = spec.n_guests
    # ---- 0. fault row (replicated inputs -> replicated transforms) -------
    state, active = faults_mod.apply_guest_faults(
        spec, cs.state, cs.active, frow["crash"], frow["restart"]
    )
    near_cap = jnp.minimum(frow["near_cap"], jnp.int32(cfg.n_near))
    base = state
    # ---- 1. access phase (sharded; inactive + padding lanes emit -1) -----
    g_loc = accesses.shape[0]
    pos = jax.lax.axis_index(AXIS) * g_loc + jnp.arange(g_loc)
    act_loc = jnp.where(pos < n_g, active[jnp.minimum(pos, n_g - 1)], False)
    acc = jnp.where(act_loc[:, None], accesses, -1)
    ids = jnp.where(acc >= 0, acc + logical_lo[:, None], -1)
    slot, _, valid = asp.translate(cfg, state, ids)
    near_loc = (valid & (slot < cfg.n_near)).sum(axis=1)
    far_loc = (valid & (slot >= cfg.n_near)).sum(axis=1)
    keep = jnp.where(frow["drop"], 0, 1).astype(jnp.int32)
    kb = spec.kernel_backend
    local = asp.apply_access_histogram(
        cfg, state, asp.access_histogram(cfg, ids, valid, kb) * keep, kb
    )
    # ---- 2. GPAC phase (sharded: this device's segment rows only) --------
    if use_gpac:
        local = gpac.gpac_maintenance_rows(
            cfg, local, backend, max_batches,
            jnp.asarray(spec.cl_per_logical()), logical_pad, hp_pad, kb,
        )
    # ---- 3. one-collective ownership merge -------------------------------
    extras = [
        _spread_rows(near_loc, n_shards), _spread_rows(far_loc, n_shards),
    ]
    if "tco" in collect:
        extras.append(
            tiers_mod.tier_hit_counts(spec.tier_vector, slot, valid))
    state, merged_extras = merge_window(
        cfg, base, local, logical_pad, hp_pad, tuple(extras),
        merged_gpac=use_gpac,
    )
    near_all, far_all = merged_extras[0], merged_extras[1]
    # ---- 4. host + pressure ticks, window roll (replicated) --------------
    state = tiering.strided_tick(
        cfg, state, policy, stride=spec.arbitration_stride, budget=budget,
        tiers=spec.tiers,
    )
    state, engaged, press = tiering.pressure_tick(
        cfg, state, near_cap, cs.engaged, cs.pressure,
        budget=budget, slack=slack, tiers=spec.tiers,
    )
    state = telemetry.end_window(cfg, state)
    window = dict(near_hits=near_all[:n_g], far_hits=far_all[:n_g])
    if "tco" in collect:
        window["tier_hits"] = merged_extras[2]
    out = run_collectors(spec, state, window, collect)
    clash = set(out) & set(_CHURN_SERIES)
    if clash:
        raise ValueError(
            f"collectors {collect} emit keys {sorted(clash)} reserved for "
            f"the churn series {_CHURN_SERIES}"
        )
    out.update(active=active, near_cap=near_cap, pressure=press)
    cs = ChurnState(
        state=state, active=active, window=cs.window + 1,
        near_cap=near_cap, pressure=press, engaged=engaged,
    )
    return cs, out


@lru_cache(maxsize=64)
def _churn_chunk_fn(
    spec,  # canonical EngineSpec
    mesh,
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    slack: int,
    collect: tuple[str, ...],
    plan=None,
):
    """Compiled sharded churn chunk driver: :func:`_chunk_fn` with the
    ChurnState carry and the replicated fault rows threaded through the
    scan as extra (window-axis) xs."""
    n_shards = mesh_size(mesh)

    def window_body(c, acc, frow, logical_lo, logical_pad, hp_pad):
        return _churn_sharded_window(
            spec, n_shards, c, acc, frow, logical_lo, logical_pad, hp_pad,
            policy, backend, use_gpac, max_batches, budget, slack, collect,
        )

    if plan is None:

        def body(cs, chunk, crash, restart, near_cap, drop,
                 logical_lo, logical_pad, hp_pad):
            def window(c, xs):
                acc, frow = xs
                return window_body(c, acc, frow, logical_lo, logical_pad, hp_pad)

            xs = (chunk, dict(
                crash=crash, restart=restart, near_cap=near_cap, drop=drop))
            return jax.lax.scan(window, cs, xs)

        in_specs = (
            P(), P(None, AXIS, None), P(None, None), P(None, None), P(None),
            P(None), P(AXIS), P(AXIS, None), P(AXIS, None),
        )
    else:
        from repro.data import traces as tr

        def body(cs, widx, crash, restart, near_cap, drop,
                 logical_lo, logical_pad, hp_pad, seeds, gids, wid, n_logical):
            setup = tr.synth_setup(plan, dict(
                seeds=seeds, gids=gids, wid=wid, n_logical=n_logical))

            def window(c, xs):
                w, frow = xs
                acc = tr.synth_accesses(plan, setup, w)
                return window_body(c, acc, frow, logical_lo, logical_pad, hp_pad)

            xs = (widx, dict(
                crash=crash, restart=restart, near_cap=near_cap, drop=drop))
            return jax.lax.scan(window, cs, xs)

        in_specs = (
            P(), P(None), P(None, None), P(None, None), P(None), P(None),
            P(AXIS), P(AXIS, None), P(AXIS, None),
            P(AXIS), P(AXIS), P(AXIS), P(AXIS),
        )

    sharded = shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=P(),
        check_rep=False,
    )
    return jax.jit(sharded)


def run_chunk_churn_sharded(
    spec,
    mesh,
    cs,
    chunk: jax.Array,  # int32[n_windows, G_pad, k], or int32[n_windows]
    tables: dict,      # window indices when plan is given
    *,
    crash,
    restart,
    near_cap,
    drop,
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    slack: int,
    collect: tuple[str, ...],
    plan=None,
    synth_tables: dict | None = None,
):
    """One scan-fused chunk of the sharded churn engine
    (``engine.run_churn``'s mesh path)."""
    fn = _churn_chunk_fn(
        spec, mesh, policy, backend, use_gpac, max_batches, budget, slack,
        collect, plan,
    )
    args = (
        cs,
        chunk,
        jnp.asarray(crash),
        jnp.asarray(restart),
        jnp.asarray(near_cap),
        jnp.asarray(drop),
        jnp.asarray(tables["logical_lo"]),
        jnp.asarray(tables["logical_pad"]),
        jnp.asarray(tables["hp_pad"]),
    )
    if plan is not None:
        args += _synth_args(synth_tables)
    return fn(*args)


# ==========================================================================
# host-partitioned near tier (DESIGN.md §11)
#
# The replicated-host path above still gives every device the full host
# state (block_table, slot pools, host telemetry), so per-device memory does
# not scale with the mesh. The host-partitioned path carries the host state
# **partitioned by contiguous block ranges**: device d owns exactly the huge
# pages of its own guests' GPA segments (guest blocks are contiguous and
# guests are dealt to devices in contiguous blocks, so guest ownership and
# range ownership coincide), holding only
#
#   * its local rows of block_table / host_counts / host_hist /
#     last_touch_epoch / region_epoch, and
#   * the **hp-owned payload** ``data[h - hp_lo]`` -- huge page h's bytes,
#     which equal the replicated ``pools[block_table[h]]`` row. Data follows
#     the huge page, so an arbitrated promotion/demotion only relabels slots
#     (block_table writes); no payload crosses devices, and ``slot_owner``
#     (the label inverse) is reconstructed once per chunk.
#
# Per arbitration group (``EngineSpec.arbitration_stride`` windows; one
# window by default) there is exactly ONE collective: per-partition tick
# candidate sets (repro.core.tiering's sharded (prepare, apply) pairs), a
# few scalar sums, and the stacked per-window collector rows share one
# psum. The full TieredState
# is materialized only at chunk boundaries (slice on entry, ownership-psum on
# exit), so per-device host-state bytes scale ~1/n_shards for the whole scan.
# ==========================================================================
# replicated host-state bytes per huge page: block_table + slot_owner +
# host_counts + last_touch_epoch + region_epoch (int32) + host_hist (uint8)
HOST_META_BYTES = 4 * 5 + 1
# the partitioned carry drops slot_owner (reconstructed at chunk exit)
LOCAL_META_BYTES = 4 * 4 + 1


@dataclasses.dataclass(frozen=True)
class HostPartition:
    """Contiguous per-device block ranges of the host near-tier state.

    Device ``d`` owns huge pages ``[hp_lo[d], hp_hi[d])`` -- its own guests'
    GPA segments. Ranges tile ``[0, n_gpa_hp)``; devices holding only
    padding guests own an empty range. ``h_loc`` is the widest range (every
    device's local arrays are padded to it with -1 rows)."""

    hp_lo: tuple[int, ...]
    hp_hi: tuple[int, ...]
    h_loc: int

    @property
    def n_shards(self) -> int:
        return len(self.hp_lo)

    def hp_ids(self) -> np.ndarray:
        """int32[n_shards, h_loc]: global block ids per device, -1 padded."""
        out = np.full((self.n_shards, self.h_loc), -1, np.int32)
        for d, (lo, hi) in enumerate(zip(self.hp_lo, self.hp_hi)):
            out[d, : hi - lo] = np.arange(lo, hi, dtype=np.int32)
        return out


def host_partition(spec, n_shards: int) -> HostPartition:
    """Partition the host block space by the device-contiguous guest blocks
    of ``engine.run_sharded``'s guest dealing."""
    g_loc = padded_guest_count(spec.n_guests, n_shards) // n_shards
    lo, hi = [], []
    for d in range(n_shards):
        a = min(d * g_loc, spec.n_guests)
        z = min((d + 1) * g_loc, spec.n_guests)
        lo.append(spec.hp_offsets[a])
        hi.append(spec.hp_offsets[z])
    h_loc = max(1, max(h - l for l, h in zip(lo, hi)))
    return HostPartition(tuple(lo), tuple(hi), h_loc)


def host_state_bytes(cfg: GpacConfig) -> int:
    """Bytes of host near-tier state each device holds on the replicated
    path: block/slot tables, host telemetry, and both payload pools."""
    payload = cfg.hp_ratio * cfg.base_elems * jnp.dtype(cfg.dtype).itemsize
    return cfg.n_gpa_hp * (HOST_META_BYTES + payload)


def host_state_bytes_sharded(cfg: GpacConfig, part: HostPartition) -> int:
    """Bytes of the partitioned host-state carry per device (uniform: every
    device pads its range to the widest one)."""
    payload = cfg.hp_ratio * cfg.base_elems * jnp.dtype(cfg.dtype).itemsize
    return part.h_loc * (LOCAL_META_BYTES + payload)


def host_tables(spec, n_shards: int) -> tuple[HostPartition, dict]:
    """Guest segment tables plus the host-partition tables the partitioned
    chunk driver shards over the mesh."""
    part = host_partition(spec, n_shards)
    tables = guest_tables(spec, n_shards)
    tables.update(
        hp_ids=part.hp_ids(),
        hp_lo=np.asarray(part.hp_lo, np.int32),
        hp_hi=np.asarray(part.hp_hi, np.int32),
    )
    return part, tables


def _slice_host_local(cfg: GpacConfig, state: TieredState, hp_ids: jax.Array) -> dict:
    """Gather this device's host-state rows out of a replicated state.

    Padded rows get inert sentinels (slot ``n_gpa_hp`` classifies as far and
    scatters off every table). The payload row of huge page ``h`` is pulled
    through its current slot -- ``data[row(h)] == pools[block_table[h]]`` is
    the layout invariant the whole partitioned path maintains."""
    v = hp_ids >= 0
    t = jnp.maximum(hp_ids, 0)
    bt = jnp.where(v, state.block_table[t], cfg.n_gpa_hp)
    slot = jnp.where(v, bt, 0)
    flat = slot[:, None] * cfg.hp_ratio + jnp.arange(cfg.hp_ratio)[None, :]
    near_rows = state.near_pool.reshape(-1, cfg.base_elems)
    far_rows = state.far_pool.reshape(-1, cfg.base_elems)
    is_near = flat < cfg.n_near * cfg.hp_ratio
    data = jnp.where(
        is_near[..., None],
        near_rows[jnp.where(is_near, flat, 0)],
        far_rows[jnp.where(is_near, 0, flat - cfg.n_near * cfg.hp_ratio)],
    )
    return dict(
        bt=bt,
        hc=jnp.where(v, state.host_counts[t], 0),
        hh=jnp.where(v, state.host_hist[t], 0).astype(jnp.uint8),
        lt=jnp.where(v, state.last_touch_epoch[t], 0),
        re=jnp.where(v, state.region_epoch[t], -1),
        data=jnp.where(v[:, None, None], data, 0),
    )


def _spread_hp(x_loc: jax.Array, hp_ids: jax.Array, n: int, fill) -> jax.Array:
    """Scatter local block rows into a full-shape view filled with ``fill``
    elsewhere (only ever *read* at this device's own blocks)."""
    safe = jnp.where(hp_ids >= 0, hp_ids, n)
    return jnp.full((n + 1,), fill, x_loc.dtype).at[safe].set(x_loc)[:n]


def _scatter_zero(x_loc: jax.Array, hp_ids: jax.Array, n: int) -> jax.Array:
    """Local rows placed at their global positions in zeros: summed across
    devices (ranges tile the space) this reconstructs the full array."""
    safe = jnp.where(hp_ids >= 0, hp_ids, n)
    return jnp.zeros((n + 1,), x_loc.dtype).at[safe].set(x_loc)[:n]


def _bits(x: jax.Array) -> jax.Array:
    """Bit-pattern view for exact integer collectives (see _owned_bits)."""
    if jnp.issubdtype(x.dtype, jnp.integer) and x.dtype.itemsize <= 4:
        return x
    if x.dtype.itemsize == 4:
        return jax.lax.bitcast_convert_type(x, jnp.int32)
    return jax.lax.bitcast_convert_type(x, jnp.uint8)


def _pool_contrib(cfg: GpacConfig, loc: dict, hp_ids: jax.Array, near: bool) -> jax.Array:
    """This device's bit-pattern contribution to one slot pool: its hp-owned
    payload rows scattered to their current slots. block_table is a
    permutation, so across devices every pool row has exactly one
    contributor and the psum is bit-exact."""
    bits = _bits(loc["data"])
    valid = hp_ids >= 0
    slot = loc["bt"]
    if near:
        row = jnp.where(valid & (slot < cfg.n_near), slot, cfg.n_near)
        n_rows = cfg.n_near
    else:
        row = jnp.where(valid & (slot >= cfg.n_near), slot - cfg.n_near, cfg.n_far)
        n_rows = cfg.n_far
    out = jnp.zeros((n_rows,) + bits.shape[1:], bits.dtype)
    return out.at[row].set(bits, mode="drop")


def _place_block(x: jax.Array, n_shards: int) -> jax.Array:
    """This device's candidate block at its mesh position in zeros: the
    shared psum concatenates all devices' nominations."""
    return jnp.zeros((n_shards,) + x.shape, x.dtype).at[
        jax.lax.axis_index(AXIS)
    ].set(x)


def _view_state(cfg, gpt, rmap, gc, ih, re_view, epoch, stats) -> TieredState:
    """A TieredState view for the guest-side GPAC classifiers: real guest
    arrays + the local region_epoch spread, placeholder host arrays (the
    telemetry/filter path never reads block tables or pools)."""
    z = jnp.zeros((1,), jnp.int32)
    zp = jnp.zeros((1, 1, 1), cfg.dtype)
    return TieredState(
        gpt=gpt, rmap=rmap, block_table=z, slot_owner=z, near_pool=zp,
        far_pool=zp, guest_counts=gc, ipt_hist=ih, host_counts=z,
        host_hist=jnp.zeros((1,), jnp.uint8), last_touch_epoch=z,
        region_epoch=re_view, epoch=epoch, stats=stats,
    )


def _near_blocks_local(cfg: GpacConfig, alloc: jax.Array, bt: jax.Array,
                       hp_lo: jax.Array, hp_pad: jax.Array) -> jax.Array:
    """Per own guest: allocated blocks currently in the near tier, counted
    over this device's local block rows (pre-tick; the arbitrated swap
    deltas correct it to post-tick replicatedly)."""
    h_loc = bt.shape[0]
    row = jnp.clip(jnp.where(hp_pad >= 0, hp_pad - hp_lo, 0), 0, h_loc - 1)
    good = alloc & (bt < cfg.n_near)
    seg = (hp_pad >= 0) & good[row]
    return seg.sum(axis=1).astype(jnp.int32)


def _near_blocks_delta(spec, swaps, g_pad: int) -> jax.Array:
    """Replicated per-guest near-block delta of the arbitrated swap rounds.

    Slot-aware: each committed candidate moves from its own slot to its
    partner's, so its near-count contribution is ``(partner in near) - (self
    in near)``. For the builtin 2-tier rounds that is exactly the old
    +1/-1 per promoted/demoted block; for N-tier flows (the ``compressed``
    policy) a swap deeper than the near boundary contributes 0.
    """
    n_near = spec.cfg.n_near
    hp_off = jnp.asarray(spec.hp_offsets, jnp.int32)
    delta = jnp.zeros((g_pad,), jnp.int32)
    for far, near, ok in swaps:
        for cand, other in ((far, near), (near, far)):
            g = jnp.searchsorted(hp_off, cand["id"], side="right") - 1
            w = jnp.where(
                ok & (cand["alloc"] > 0),
                (other["slot"] < n_near).astype(jnp.int32)
                - (cand["slot"] < n_near).astype(jnp.int32),
                0,
            )
            delta = delta.at[jnp.where(ok, g, g_pad)].add(w, mode="drop")
    return delta


def _near_scalar_delta(cfg: GpacConfig, swaps) -> jax.Array:
    """Replicated host-wide delta of allocated near blocks from the
    arbitrated swaps (the scalar form of :func:`_near_blocks_delta`, for the
    host-sharded ``snapshot`` collector); slot-aware like it."""
    d = jnp.int32(0)
    for far, near, ok in swaps:
        for cand, other in ((far, near), (near, far)):
            d = d + jnp.where(
                ok & (cand["alloc"] > 0),
                (other["slot"] < cfg.n_near).astype(jnp.int32)
                - (cand["slot"] < cfg.n_near).astype(jnp.int32),
                0,
            ).sum()
    return d


def _host_sharded_group(
    spec,
    n_shards: int,
    carry: dict,
    accs: jax.Array,  # int32[stride, G_loc, k]
    logical_lo: jax.Array,
    logical_pad: jax.Array,
    hp_pad: jax.Array,
    hp_ids: jax.Array,
    hp_lo: jax.Array,
    hp_hi: jax.Array,
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    collect: tuple[str, ...],
    prefetch=None,  # SynthTrace overlap: ws -> int32[stride, G_loc, k]
    w_next: jax.Array | None = None,
) -> tuple[dict, dict]:
    """One arbitration *group* -- ``spec.arbitration_stride`` engine windows
    -- on the partitioned host state, with exactly ONE collective for the
    whole group. Bit-for-bit equal to ``engine._window`` at the same stride
    on the unpadded guests (stride 1 is the classic one-window body).

    Every window runs its access + GPAC phases and its telemetry roll
    locally; only the group's last window nominates tick candidates
    (arbitrating on the stride's accumulated telemetry). The per-window
    collector rows -- hits, pre-tick near-block counts, tier vectors,
    snapshot deltas -- are stacked and ride the last window's candidate
    exchange, so ``stride`` windows cost one psum instead of ``stride``.
    The arbitrated swap deltas correct only the last window's emissions:
    the earlier windows ran no tick, so their pre-tick counts *are* their
    post-window placement.

    ``prefetch`` overlaps the collective with trace synthesis (DESIGN.md
    §17): issued right after the psum, the next group's accesses
    (``prefetch(w_next)``) depend only on replicated window indices --
    never on the merged result -- so XLA can schedule the synthesis while
    the exchange is in flight. Streams are counter-based on absolute
    indices, so the overlap is bit-invisible.
    """
    from repro.core import consolidator
    from repro.core import filter as pfilter

    cfg = spec.cfg
    stride = spec.arbitration_stride
    gpt, rmap = carry["gpt"], carry["rmap"]
    gc, ih = carry["guest_counts"], carry["ipt_hist"]
    epoch, stats = carry["epoch"], dict(carry["stats"])
    loc = dict(carry["loc"])
    # replicated cumulative stats for the snapshot collector: per-device
    # deltas ride the arbitration psum, replicated tick deltas add directly
    gstats = dict(carry["gstats"]) if "gstats" in carry else None
    epoch_in = epoch
    kb = spec.kernel_backend
    tv = spec.tier_vector if "tco" in collect else None
    prepare, apply = tiering.sharded_tick_fns(policy)
    if spec.tiers is not None:
        prepare = partial(prepare, tiers=spec.tiers)
        apply = partial(apply, tiers=spec.tiers)

    per_win = []
    L = payload = None
    for j in range(stride):
        stats0 = dict(stats)
        accesses = accs[j]
        # ---- 1. access phase (local: own guests touch own blocks) -------
        ids = jnp.where(accesses >= 0, accesses + logical_lo[:, None], -1)
        valid = (ids >= 0) & (ids < cfg.n_logical)
        hp = gpt[jnp.where(valid, ids, 0)] // cfg.hp_ratio
        bt_view = _spread_hp(
            loc["bt"], hp_ids, cfg.n_gpa_hp, jnp.int32(cfg.n_gpa_hp))
        slot = bt_view[hp]
        near_loc = (valid & (slot < cfg.n_near)).sum(axis=1).astype(jnp.int32)
        far_loc = (valid & (slot >= cfg.n_near)).sum(axis=1).astype(jnp.int32)
        h = asp.access_histogram(cfg, ids, valid, kb)
        gc = gc + h
        inc_full = asp.host_histogram(cfg, gpt, h, kb)
        inc_loc = jnp.where(hp_ids >= 0, inc_full[jnp.maximum(hp_ids, 0)], 0)
        loc["hc"] = loc["hc"] + inc_loc
        loc["lt"] = jnp.where(
            inc_loc > 0, jnp.maximum(loc["lt"], epoch), loc["lt"])
        stats["near_hits"] = stats["near_hits"] + near_loc.sum()
        stats["far_hits"] = stats["far_hits"] + far_loc.sum()

        # ---- 2. GPAC phase (own segment rows, hp-owned payload) ---------
        if use_gpac:
            re_view = _spread_hp(loc["re"], hp_ids, cfg.n_gpa_hp, jnp.int32(-1))
            view = _view_state(cfg, gpt, rmap, gc, ih, re_view, epoch, stats)
            hot = telemetry.hot_mask(cfg, view, backend)
            score = pfilter.candidate_score(
                cfg, view, hot, jnp.asarray(spec.cl_per_logical()), kb
            )
            batches = pfilter.select_batches_from_rows(
                cfg, score, logical_pad, max_batches, kb
            )
            gpt, rmap, loc["data"], loc["re"], stats = (
                consolidator.consolidate_rounds_local(
                    cfg, gpt, rmap, loc["data"], loc["re"], epoch, stats,
                    batches, hp_pad, hp_lo, kb,
                )
            )

        # ---- 3a. this window's share of the group collective ------------
        # local per-tier access and pre-tick block counts ride the group
        # psum; the arbitrated swap deltas correct the last window's blocks
        # to post-tick replicatedly, so the priced placement is
        # bit-identical to the replicated collector's. Snapshot scalars
        # likewise: this device's window stat deltas (access + GPAC phases;
        # the tick's are replicated and added after arbitration) and its
        # local allocated / allocated-near block counts.
        alloc_full = (
            rmap.reshape(cfg.n_gpa_hp, cfg.hp_ratio) != FREE).any(axis=1)
        alloc_loc = jnp.where(
            hp_ids >= 0, alloc_full[jnp.maximum(hp_ids, 0)], False)
        contrib = dict(
            near=_spread_rows(near_loc, n_shards),
            far=_spread_rows(far_loc, n_shards),
        )
        if "near_blocks" in collect:
            contrib["near_blocks"] = _spread_rows(
                _near_blocks_local(cfg, alloc_loc, loc["bt"], hp_lo, hp_pad),
                n_shards,
            )
        if "tco" in collect:
            contrib["tier_hits"] = tiers_mod.tier_hit_counts(tv, slot, valid)
            contrib["tier_blocks"] = tiers_mod.tier_block_counts(
                tv, loc["bt"], alloc_loc)
        if gstats is not None:
            contrib["stat_delta"] = {k: stats[k] - stats0[k] for k in stats}
            contrib["alloc_near"] = (
                alloc_loc & (loc["bt"] < cfg.n_near)).sum()
            contrib["alloc_tot"] = alloc_loc.sum()
        per_win.append(contrib)

        if j < stride - 1:
            # tick-less window roll: arbitration waits for the group's last
            # window, telemetry keeps accumulating across the stride
            ih = ((ih << 1) | (gc > 0).astype(jnp.uint8)).astype(jnp.uint8)
            loc["hh"] = ((loc["hh"] << 1)
                         | (loc["hc"] > 0).astype(jnp.uint8)).astype(jnp.uint8)
            gc = jnp.zeros_like(gc)
            loc["hc"] = jnp.zeros_like(loc["hc"])
            epoch = epoch + 1
        else:
            # ---- 3b. nominate on the stride's accumulated telemetry -----
            L = dict(
                hp_ids=hp_ids, hp_lo=hp_lo, hp_hi=hp_hi, bt=loc["bt"],
                hc=loc["hc"], hh=loc["hh"], lt=loc["lt"], alloc=alloc_loc,
            )
            payload = prepare(cfg, L, budget)

    # ---- 3c. the group's single collective ------------------------------
    exchange = dict(
        cands=jax.tree_util.tree_map(
            lambda x: _place_block(x, n_shards), payload["cands"]
        ),
        sums=payload["sums"],
        win=jax.tree_util.tree_map(lambda *x: jnp.stack(x), *per_win),
    )
    merged = _psum_counted("host_exchange", exchange)
    if prefetch is not None:
        # next group's accesses: no data dependency on ``merged``, so the
        # synthesis can run while the exchange is in flight
        acc_next = prefetch(w_next)
    mwin = merged["win"]

    # ---- 4. arbitration: replicated decisions, local block-table writes -
    loc["bt"], tick_stats, swaps = apply(
        cfg, L, dict(cands=merged["cands"], sums=merged["sums"]), budget
    )
    on_d0 = jax.lax.axis_index(AXIS) == 0
    for s in tick_stats:  # replicated deltas: count them on one device only
        stats[s] = stats[s] + jnp.where(on_d0, tick_stats[s], 0)

    # ---- 5. last window's roll (telemetry.end_window, by residency) -----
    ih = ((ih << 1) | (gc > 0).astype(jnp.uint8)).astype(jnp.uint8)
    loc["hh"] = ((loc["hh"] << 1)
                 | (loc["hc"] > 0).astype(jnp.uint8)).astype(jnp.uint8)
    gc = jnp.zeros_like(gc)
    loc["hc"] = jnp.zeros_like(loc["hc"])
    epoch = epoch + 1

    # ---- 6. per-window collector outputs, stacked [stride, ...] ---------
    n_g = spec.n_guests
    emits = []
    for j in range(stride):
        last = j == stride - 1
        out_j = {}
        for name in collect:
            if name == "hits":
                emitted = dict(
                    near_hits=mwin["near"][j][:n_g],
                    far_hits=mwin["far"][j][:n_g],
                )
            elif name == "near_blocks":
                pre = mwin["near_blocks"][j]
                if last:
                    pre = pre + _near_blocks_delta(spec, swaps, pre.shape[0])
                emitted = dict(near_blocks=pre[:n_g])
            elif name == "snapshot":
                # metrics.device_snapshot reconstructed from the exchange:
                # same int sums -> bit-identical float divisions
                gstats = {
                    k: gstats[k] + mwin["stat_delta"][k][j]
                    + (tick_stats.get(k, 0) if last else 0)
                    for k in gstats
                }
                alloc_near = mwin["alloc_near"][j] + (
                    _near_scalar_delta(cfg, swaps) if last else 0)
                rss = jnp.maximum(mwin["alloc_tot"][j], 1)
                emitted = dict(
                    epoch=epoch_in + j + 1,
                    near_usage=alloc_near / rss,
                    near_capacity_used=alloc_near / cfg.n_near,
                    hit_rate=gstats["near_hits"] / jnp.maximum(
                        gstats["near_hits"] + gstats["far_hits"], 1),
                    **gstats,
                )
            elif name == "tco":
                blocks = mwin["tier_blocks"][j]
                if last:
                    blocks = blocks + tiers_mod.tier_count_delta(tv, swaps)
                emitted = tiers_mod.tco_metrics(cfg, tv, blocks,
                                                mwin["tier_hits"][j])
            else:  # pragma: no cover - engine.run_sharded validates upfront
                raise ValueError(
                    f"collector {name!r} has no host-sharded form")
            clash = set(emitted) & set(out_j)
            if clash:
                raise ValueError(
                    f"collector {name!r} emits keys {sorted(clash)} already "
                    f"produced by an earlier collector in {collect}"
                )
            out_j.update(emitted)
        emits.append(out_j)
    out = jax.tree_util.tree_map(lambda *x: jnp.stack(x), *emits)

    new_carry = dict(
        gpt=gpt, rmap=rmap, guest_counts=gc, ipt_hist=ih, epoch=epoch,
        stats=stats, loc=loc,
    )
    if gstats is not None:
        new_carry["gstats"] = gstats
    if prefetch is not None:
        new_carry["acc"] = acc_next
    return new_carry, out


def _merge_host_final(
    cfg: GpacConfig,
    base: TieredState,
    carry: dict,
    logical_pad: jax.Array,
    hp_pad: jax.Array,
    hp_ids: jax.Array,
) -> TieredState:
    """Chunk-exit reconstruction of the replicated TieredState: one psum of
    ownership-placed contributions (segment rows for guest arrays, block
    ranges for host arrays, bit patterns for the pools), then ``slot_owner``
    recomputed as the merged block table's inverse -- exactly the inverse
    :func:`tiering.swap_blocks` maintains."""
    loc = carry["loc"]
    own_logical = _own_mask(logical_pad, cfg.n_logical)
    own_gpa = jnp.repeat(_own_mask(hp_pad, cfg.n_gpa_hp), cfg.hp_ratio)
    d0 = (jax.lax.axis_index(AXIS) == 0).astype(jnp.int32)
    contrib = dict(
        gpt=_owned_bits(carry["gpt"], own_logical),
        rmap=_owned_bits(carry["rmap"], own_gpa),
        guest_counts=_owned_bits(carry["guest_counts"], own_logical),
        ipt_hist=_owned_bits(carry["ipt_hist"], own_logical),
        bt=_scatter_zero(loc["bt"], hp_ids, cfg.n_gpa_hp),
        hc=_scatter_zero(loc["hc"], hp_ids, cfg.n_gpa_hp),
        hh=_scatter_zero(loc["hh"], hp_ids, cfg.n_gpa_hp),
        lt=_scatter_zero(loc["lt"], hp_ids, cfg.n_gpa_hp),
        re=_scatter_zero(loc["re"], hp_ids, cfg.n_gpa_hp),
        near=_pool_contrib(cfg, loc, hp_ids, near=True),
        far=_pool_contrib(cfg, loc, hp_ids, near=False),
        stats={k: carry["stats"][k] - base.stats[k] for k in base.stats},
        epoch=(carry["epoch"] - base.epoch) * d0,
    )
    m = _psum_counted("host_chunk_exit", contrib)
    slot_owner = jnp.zeros((cfg.n_slots,), jnp.int32).at[m["bt"]].set(
        jnp.arange(cfg.n_gpa_hp, dtype=jnp.int32)
    )
    return dataclasses.replace(
        base,
        gpt=m["gpt"],
        rmap=m["rmap"],
        guest_counts=m["guest_counts"],
        ipt_hist=m["ipt_hist"],
        block_table=m["bt"],
        slot_owner=slot_owner,
        host_counts=m["hc"],
        host_hist=m["hh"],
        last_touch_epoch=m["lt"],
        region_epoch=m["re"],
        near_pool=_from_bits(m["near"], base.near_pool),
        far_pool=_from_bits(m["far"], base.far_pool),
        stats={k: base.stats[k] + m["stats"][k] for k in base.stats},
        epoch=base.epoch + m["epoch"],
    )


@lru_cache(maxsize=64)
def _host_chunk_fn(
    spec,  # canonical EngineSpec
    mesh,
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    collect: tuple[str, ...],
    plan=None,  # repro.data.traces.SynthPlan for on-device synthesis
):
    """Compiled host-partitioned chunk driver: slice the replicated state
    into per-device ranges, scan the *arbitration groups* (``spec.
    arbitration_stride`` windows each; stride 1 = one window per group) on
    the partitioned carry, merge back once at the chunk boundary. With a
    ``plan``, each device synthesizes its local guests' accesses inside the
    group (same gid-folded key discipline as :func:`_chunk_fn`) -- one
    group *ahead*, so the synthesis of the next group's accesses overlaps
    the in-flight candidate exchange (DESIGN.md §17)."""
    n_shards = mesh_size(mesh)
    cfg = spec.cfg
    stride = spec.arbitration_stride

    def scan_chunk(state, xs, window, hp_ids, acc0=None):
        carry = dict(
            gpt=state.gpt, rmap=state.rmap, guest_counts=state.guest_counts,
            ipt_hist=state.ipt_hist, epoch=state.epoch, stats=state.stats,
            loc=_slice_host_local(cfg, state, hp_ids),
        )
        if "snapshot" in collect:
            carry["gstats"] = dict(state.stats)
        if acc0 is not None:
            carry["acc"] = acc0
        carry, ys = jax.lax.scan(window, carry, xs)
        # [n_groups, stride, ...] -> [n_windows, ...]
        ys = jax.tree_util.tree_map(
            lambda y: y.reshape((y.shape[0] * y.shape[1],) + y.shape[2:]), ys)
        return carry, ys

    if plan is None:

        def body(state, chunk, logical_lo, logical_pad, hp_pad,
                 hp_ids, hp_lo, hp_hi):
            hp_ids, hp_lo, hp_hi = hp_ids[0], hp_lo[0], hp_hi[0]
            groups = chunk.reshape(
                (chunk.shape[0] // stride, stride) + chunk.shape[1:])

            def window(c, accs):
                return _host_sharded_group(
                    spec, n_shards, c, accs, logical_lo, logical_pad, hp_pad,
                    hp_ids, hp_lo, hp_hi, policy, backend, use_gpac,
                    max_batches, budget, collect,
                )

            carry, ys = scan_chunk(state, groups, window, hp_ids)
            return (
                _merge_host_final(cfg, state, carry, logical_pad, hp_pad, hp_ids),
                ys,
            )

        in_specs = (
            P(), P(None, AXIS, None), P(AXIS), P(AXIS, None), P(AXIS, None),
            P(AXIS, None), P(AXIS), P(AXIS),
        )
    else:
        from repro.data import traces as tr

        def body(state, widx, logical_lo, logical_pad, hp_pad,
                 hp_ids, hp_lo, hp_hi, seeds, gids, wid, n_logical):
            hp_ids, hp_lo, hp_hi = hp_ids[0], hp_lo[0], hp_hi[0]
            setup = tr.synth_setup(plan, dict(
                seeds=seeds, gids=gids, wid=wid, n_logical=n_logical))
            wg = widx.reshape(widx.shape[0] // stride, stride)

            def synth_group(ws):
                return jnp.stack([
                    tr.synth_accesses(plan, setup, ws[j])
                    for j in range(stride)
                ])

            def window(c, ws_next):
                return _host_sharded_group(
                    spec, n_shards, c, c["acc"], logical_lo, logical_pad,
                    hp_pad, hp_ids, hp_lo, hp_hi, policy, backend, use_gpac,
                    max_batches, budget, collect,
                    prefetch=synth_group, w_next=ws_next,
                )

            # the scan consumes the carry's pre-synthesized group and
            # prefetches the *next* one behind the psum; the trailing dummy
            # indices (last group + stride) synthesize one discarded group
            w_next = jnp.concatenate([wg[1:], wg[-1:] + stride], axis=0)
            carry, ys = scan_chunk(
                state, w_next, window, hp_ids, acc0=synth_group(wg[0]))
            return (
                _merge_host_final(cfg, state, carry, logical_pad, hp_pad, hp_ids),
                ys,
            )

        in_specs = (
            P(), P(None), P(AXIS), P(AXIS, None), P(AXIS, None),
            P(AXIS, None), P(AXIS), P(AXIS),
            P(AXIS), P(AXIS), P(AXIS), P(AXIS),
        )

    sharded = shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=P(),
        check_rep=False,
    )
    return jax.jit(sharded)


def run_chunk_host_sharded(
    spec,
    mesh,
    state: TieredState,
    chunk: jax.Array,  # int32[n_windows, G_pad, k], or int32[n_windows]
    tables: dict,      # window indices when plan is given
    *,
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    collect: tuple[str, ...],
    plan=None,
    synth_tables: dict | None = None,
) -> tuple[TieredState, dict]:
    """One scan-fused chunk of the host-partitioned engine
    (``engine.run_sharded(host_sharded=True)``'s inner loop)."""
    fn = _host_chunk_fn(
        spec, mesh, policy, backend, use_gpac, max_batches, budget, collect,
        plan,
    )
    args = (
        state,
        chunk,
        jnp.asarray(tables["logical_lo"]),
        jnp.asarray(tables["logical_pad"]),
        jnp.asarray(tables["hp_pad"]),
        jnp.asarray(tables["hp_ids"]),
        jnp.asarray(tables["hp_lo"]),
        jnp.asarray(tables["hp_hi"]),
    )
    if plan is not None:
        args += _synth_args(synth_tables)
    return fn(*args)
