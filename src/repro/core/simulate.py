"""Deprecated multi-tenant simulation surface (symmetric guests only).

This module predates :mod:`repro.core.engine`, which is the one simulation
API now: :class:`repro.core.engine.GuestSpec` geometry supports ragged /
asymmetric guests (distinct sizes, slacks, per-guest CLs) and
:func:`repro.core.engine.run` is the single scan-fused driver every
benchmark uses. Everything here is either

* a **thin deprecation shim** (:class:`MultiGuest`, :func:`make_multi_guest`,
  :func:`multi_guest_window`, :func:`run_multi_guest`) that maps the old
  symmetric-tiling API onto an :class:`~repro.core.engine.EngineSpec`, or
* the **seed-equivalent reference path** (``multi_guest_window_reference`` /
  ``run_multi_guest_reference``): the original per-guest / per-window
  formulation that equivalence tests pin the engine against bit-for-bit and
  that ``benchmarks/bench_engine.py`` times the engine's speedup over.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import address_space as asp
from repro.core import engine, gpac, metrics, telemetry, tiering
from repro.core.types import GpacConfig, TieredState, allocated_hp_mask


@dataclasses.dataclass(frozen=True)
class MultiGuest:
    """Geometry of N *symmetric* guests packed into one host block space.

    Deprecated: use :class:`repro.core.engine.GuestSpec` /
    :func:`repro.core.engine.build`, which also cover ragged guests.
    """

    cfg: GpacConfig  # combined space
    n_guests: int
    logical_per_guest: int
    hp_per_guest: int

    def logical_range(self, g: int) -> tuple[int, int]:
        return g * self.logical_per_guest, (g + 1) * self.logical_per_guest

    def hp_range(self, g: int) -> tuple[int, int]:
        return g * self.hp_per_guest, (g + 1) * self.hp_per_guest

    def localize(self, g: int, local_ids: jax.Array) -> jax.Array:
        """Guest-local logical page ids -> combined-space ids (-1 passthrough)."""
        lo, _ = self.logical_range(g)
        return jnp.where(local_ids >= 0, local_ids + lo, -1)

    def localize_all(self, local_ids: jax.Array) -> jax.Array:
        """Batched :meth:`localize`: ``int32[n_guests, k]`` guest-local ids ->
        combined-space ids in one shot (-1 passthrough)."""
        return self.spec().localize(local_ids)

    def spec(self, cl: int | None = None) -> engine.EngineSpec:
        """The equivalent :class:`~repro.core.engine.EngineSpec`."""
        return engine.symmetric_spec(self.cfg, self.n_guests, cl=cl)


def make_multi_guest(
    n_guests: int,
    logical_per_guest: int,
    hp_ratio: int,
    near_fraction: float,
    gpa_slack: float = 0.25,
    **cfg_kw,
) -> tuple[MultiGuest, TieredState]:
    """Build N symmetric guests over one host space (deprecated shim over
    :func:`repro.core.engine.build`).

    ``near_fraction``: near-tier capacity as a fraction of *total allocated*
    huge pages across guests (the paper's DRAM:NVMM ratio knob, Fig. 17).
    """
    warnings.warn(
        "simulate.make_multi_guest is deprecated; use repro.core.engine.build"
        " (GuestSpec/HostSpec geometry, also covers ragged guests)",
        DeprecationWarning,
        stacklevel=2,
    )
    host = engine.HostSpec(
        hp_ratio=hp_ratio,
        near_fraction=near_fraction,
        **{k: cfg_kw.pop(k) for k in tuple(cfg_kw) if k in (
            "base_elems", "cl", "hot_threshold", "ipt_windows", "ipt_min_hits",
            "reconsolidate_cooldown", "dtype",
        )},
    )
    if cfg_kw:
        raise TypeError(f"unknown config keywords {sorted(cfg_kw)}")
    guests = tuple(
        engine.GuestSpec(
            n_logical=logical_per_guest, gpa_slack=gpa_slack, seed=g
        )
        for g in range(n_guests)
    )
    spec, state = engine.build(guests, host)
    mg = MultiGuest(
        spec.cfg, n_guests, logical_per_guest, spec.cfg.n_gpa_hp // n_guests
    )
    return mg, state


# --------------------------------------------------------------------------
# deprecated engine entry points (shims over repro.core.engine)
# --------------------------------------------------------------------------
def multi_guest_window(
    mg: MultiGuest,
    state: TieredState,
    accesses: jax.Array,  # int32[n_guests, k] guest-LOCAL page ids, -1 padded
    policy: str = "memtierd",
    backend: str = "ipt",
    use_gpac: bool = True,
    max_batches: int = 4,
    budget: int = 64,
    cl: int | None = None,
) -> tuple[TieredState, dict]:
    """One telemetry window for all guests + one host tier tick (deprecated
    shim over :func:`repro.core.engine.step`). Bit-for-bit equivalent to
    :func:`multi_guest_window_reference`."""
    warnings.warn(
        "simulate.multi_guest_window is deprecated; use"
        " repro.core.engine.step",
        DeprecationWarning,
        stacklevel=2,
    )
    return engine.step(
        mg.spec(cl), state, accesses,
        policy=policy, backend=backend, use_gpac=use_gpac,
        max_batches=max_batches, budget=budget,
        collect=("hits", "near_blocks"),
    )


def run_multi_guest(
    mg: MultiGuest,
    state: TieredState,
    traces: np.ndarray,  # int32[n_guests, n_windows, k] guest-local ids
    tier_pair: str = "dram_nvmm",
    policy: str = "memtierd",
    backend: str = "ipt",
    use_gpac: bool = True,
    max_batches: int = 4,
    budget: int = 64,
    cl: int | None = None,
    windows_per_step: int = 0,
) -> tuple[TieredState, dict]:
    """Drive all windows on the shared scan-fused engine driver (deprecated
    shim over :func:`repro.core.engine.run_series`); returns the per-guest
    time series the at-scale benchmarks plot. Bit-for-bit equivalent to
    :func:`run_multi_guest_reference`."""
    warnings.warn(
        "simulate.run_multi_guest is deprecated; use"
        " repro.core.engine.run_series",
        DeprecationWarning,
        stacklevel=2,
    )
    return engine.run_series(
        mg.spec(cl), state, traces, tier_pair=tier_pair,
        policy=policy, backend=backend, use_gpac=use_gpac,
        max_batches=max_batches, budget=budget,
        windows_per_step=windows_per_step,
    )


# --------------------------------------------------------------------------
# seed-equivalent reference path (per-guest / per-window formulation)
# --------------------------------------------------------------------------
@partial(
    jax.jit,
    static_argnames=("mg", "policy", "backend", "use_gpac", "max_batches", "budget", "cl"),
)
def multi_guest_window_reference(
    mg: MultiGuest,
    state: TieredState,
    accesses: jax.Array,  # int32[n_guests, k] guest-LOCAL page ids, -1 padded
    policy: str = "memtierd",
    backend: str = "ipt",
    use_gpac: bool = True,
    max_batches: int = 4,
    budget: int = 64,
    cl: int | None = None,
) -> tuple[TieredState, dict]:
    """Original per-guest-loop window (the seed semantics): the equivalence
    oracle for :func:`multi_guest_window` and the baseline that
    ``benchmarks/bench_engine.py`` times the engine against. Its trace cost
    is O(n_guests) -- every guest's translate/record/GPAC pass is unrolled."""
    cfg = mg.cfg
    n_g = mg.n_guests
    per_guest_near = []
    per_guest_far = []
    logical_idx = jnp.arange(cfg.n_logical, dtype=jnp.int32)
    hp_idx = jnp.arange(cfg.n_gpa_hp)
    for g in range(n_g):
        ids = mg.localize(g, accesses[g])
        slot, _, valid = asp.translate(cfg, state, ids)
        per_guest_near.append(jnp.where(valid & (slot < cfg.n_near), 1, 0).sum())
        per_guest_far.append(jnp.where(valid & (slot >= cfg.n_near), 1, 0).sum())
        state = asp.record_accesses(cfg, state, ids)
    if use_gpac:
        for g in range(n_g):
            lo, hi = mg.logical_range(g)
            allow = (logical_idx >= lo) & (logical_idx < hi)
            state = gpac.gpac_maintenance(
                cfg, state, backend, max_batches, cl, allow=allow,
                hp_range=mg.hp_range(g),
            )
    state = tiering.tick(cfg, state, policy, budget=budget)

    alloc = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    near_share = []
    for g in range(n_g):
        hp_lo, hp_hi = mg.hp_range(g)
        seg = (hp_idx >= hp_lo) & (hp_idx < hp_hi)
        near_share.append((seg & alloc & in_near).sum())
    out = dict(
        near_hits=jnp.stack(per_guest_near),
        far_hits=jnp.stack(per_guest_far),
        near_blocks=jnp.stack(near_share),
    )
    state = telemetry.end_window(cfg, state)
    return state, out


def run_multi_guest_reference(
    mg: MultiGuest,
    state: TieredState,
    traces: np.ndarray,  # int32[n_guests, n_windows, k] guest-local ids
    tier_pair: str = "dram_nvmm",
    **kw,
) -> tuple[TieredState, dict]:
    """Original per-window python driver (one host sync per window): the
    equivalence oracle for :func:`run_multi_guest`."""
    n_g, n_w, _ = traces.shape
    series = dict(
        near_blocks=np.zeros((n_w, n_g), np.int64),
        hit_rate=np.zeros((n_w, n_g)),
        throughput=np.zeros((n_w, n_g)),
    )
    for w in range(n_w):
        state, out = multi_guest_window_reference(
            mg, state, jnp.asarray(traces[:, w]), **kw
        )
        nh = np.asarray(out["near_hits"], np.float64)
        fh = np.asarray(out["far_hits"], np.float64)
        hit, tput = metrics.throughput_from_hits(nh, fh, tier_pair)
        series["near_blocks"][w] = np.asarray(out["near_blocks"])
        series["hit_rate"][w] = hit
        series["throughput"][w] = tput
    return state, series
