"""Trace-driven guest/host simulator (drives every paper-figure benchmark).

Single-guest runs use :func:`repro.core.gpac.window_step` directly. This module
adds the **multi-tenant** setting of paper §5.3: N symmetric guests share one
host block space; each guest runs its *own* GPAC daemon confined to its own
logical pages and GPA segment, while a single host tiering policy competes all
guests' huge pages for the shared near tier. Per-VM metrics (near share, hit
rate, modeled throughput) mirror Figs. 9, 10, 12.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import address_space as asp
from repro.core import gpac, metrics, telemetry, tiering
from repro.core.types import GpacConfig, TieredState, allocated_hp_mask, init_state


@dataclasses.dataclass(frozen=True)
class MultiGuest:
    """Geometry of N symmetric guests packed into one host block space."""

    cfg: GpacConfig  # combined space
    n_guests: int
    logical_per_guest: int
    hp_per_guest: int

    def logical_range(self, g: int) -> tuple[int, int]:
        return g * self.logical_per_guest, (g + 1) * self.logical_per_guest

    def hp_range(self, g: int) -> tuple[int, int]:
        return g * self.hp_per_guest, (g + 1) * self.hp_per_guest

    def localize(self, g: int, local_ids: jax.Array) -> jax.Array:
        """Guest-local logical page ids -> combined-space ids (-1 passthrough)."""
        lo, _ = self.logical_range(g)
        return jnp.where(local_ids >= 0, local_ids + lo, -1)


def make_multi_guest(
    n_guests: int,
    logical_per_guest: int,
    hp_ratio: int,
    near_fraction: float,
    gpa_slack: float = 0.25,
    **cfg_kw,
) -> tuple[MultiGuest, TieredState]:
    """Build N guests over one host space.

    ``near_fraction``: near-tier capacity as a fraction of *total allocated*
    huge pages across guests (the paper's DRAM:NVMM ratio knob, Fig. 17).
    """
    hp_need = -(-logical_per_guest // hp_ratio)
    hp_per_guest = hp_need + max(2, int(hp_need * gpa_slack))
    n_hp = n_guests * hp_per_guest
    n_near = max(1, int(near_fraction * n_guests * hp_need))
    cfg = GpacConfig(
        n_logical=n_guests * logical_per_guest,
        hp_ratio=hp_ratio,
        n_gpa_hp=n_hp,
        n_near=min(n_near, n_hp - 1),
        **cfg_kw,
    )
    mg = MultiGuest(cfg, n_guests, logical_per_guest, hp_per_guest)
    # Identity init maps guest g's logical pages into its own hp segment only
    # if segments are tight; with slack we must place pages per guest.
    gpt = np.full((cfg.n_logical,), -1, np.int64)
    rmap = np.full((cfg.n_gpa,), -1, np.int64)
    for g in range(n_guests):
        lo, hi = mg.logical_range(g)
        hp_lo, _ = mg.hp_range(g)
        gpa = hp_lo * hp_ratio + np.arange(logical_per_guest)
        gpt[lo:hi] = gpa
        rmap[gpa] = np.arange(lo, hi)
    state = init_state(cfg)
    state = asp.dataclasses_replace(
        state,
        gpt=jnp.asarray(gpt, jnp.int32),
        rmap=jnp.asarray(rmap, jnp.int32),
    )
    return mg, state


@partial(
    jax.jit,
    static_argnames=("mg", "policy", "backend", "use_gpac", "max_batches", "budget", "cl"),
)
def multi_guest_window(
    mg: MultiGuest,
    state: TieredState,
    accesses: jax.Array,  # int32[n_guests, k] guest-LOCAL page ids, -1 padded
    policy: str = "memtierd",
    backend: str = "ipt",
    use_gpac: bool = True,
    max_batches: int = 4,
    budget: int = 64,
    cl: int | None = None,
) -> tuple[TieredState, dict]:
    """One telemetry window for all guests + one host tier tick.

    Returns per-guest metrics computed *at access time* (hit tiers resolved
    against the placement in effect when the access happened, like PEBS).
    """
    cfg = mg.cfg
    n_g = mg.n_guests
    per_guest_near = []
    per_guest_far = []
    logical_idx = jnp.arange(cfg.n_logical, dtype=jnp.int32)
    for g in range(n_g):
        ids = mg.localize(g, accesses[g])
        slot, _, valid = asp.translate(cfg, state, ids)
        per_guest_near.append(jnp.where(valid & (slot < cfg.n_near), 1, 0).sum())
        per_guest_far.append(jnp.where(valid & (slot >= cfg.n_near), 1, 0).sum())
        state = asp.record_accesses(cfg, state, ids)
    if use_gpac:
        for g in range(n_g):
            lo, hi = mg.logical_range(g)
            allow = (logical_idx >= lo) & (logical_idx < hi)
            state = gpac.gpac_maintenance(
                cfg, state, backend, max_batches, cl, allow=allow,
                hp_range=mg.hp_range(g),
            )
    state = tiering.tick(cfg, state, policy, budget=budget)

    alloc = allocated_hpm = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    near_share = []
    for g in range(n_g):
        hp_lo, hp_hi = mg.hp_range(g)
        seg = (jnp.arange(cfg.n_gpa_hp) >= hp_lo) & (jnp.arange(cfg.n_gpa_hp) < hp_hi)
        near_share.append((seg & alloc & in_near).sum())
    out = dict(
        near_hits=jnp.stack(per_guest_near),
        far_hits=jnp.stack(per_guest_far),
        near_blocks=jnp.stack(near_share),
    )
    state = telemetry.end_window(cfg, state)
    return state, out


def run_multi_guest(
    mg: MultiGuest,
    state: TieredState,
    traces: np.ndarray,  # int32[n_guests, n_windows, k] guest-local ids
    tier_pair: str = "dram_nvmm",
    **kw,
) -> tuple[TieredState, dict]:
    """Drive all windows; return the per-guest time series the at-scale
    benchmarks plot (near blocks, hit rate, modeled throughput)."""
    n_g, n_w, _ = traces.shape
    series = dict(
        near_blocks=np.zeros((n_w, n_g), np.int64),
        hit_rate=np.zeros((n_w, n_g)),
        throughput=np.zeros((n_w, n_g)),
    )
    near_ns, far_ns = (
        metrics.TIER_LATENCY_NS[t] for t in metrics.TIER_PAIRS[tier_pair]
    )
    for w in range(n_w):
        state, out = multi_guest_window(mg, state, jnp.asarray(traces[:, w]), **kw)
        nh = np.asarray(out["near_hits"], np.float64)
        fh = np.asarray(out["far_hits"], np.float64)
        hit = nh / np.maximum(nh + fh, 1)
        amat = (nh * near_ns + fh * far_ns) / np.maximum(nh + fh, 1)
        series["near_blocks"][w] = np.asarray(out["near_blocks"])
        series["hit_rate"][w] = hit
        # same calibration as metrics.modeled_throughput (700 ns + 1 access)
        series["throughput"][w] = 1e9 / (700.0 + 1.0 * amat)
    return state, series
