"""Trace-driven guest/host simulator (drives every paper-figure benchmark).

Single-guest runs use :func:`repro.core.gpac.window_step` directly. This module
adds the **multi-tenant** setting of paper §5.3: N symmetric guests share one
host block space; each guest runs its *own* GPAC daemon confined to its own
logical pages and GPA segment, while a single host tiering policy competes all
guests' huge pages for the shared near tier. Per-VM metrics (near share, hit
rate, modeled throughput) mirror Figs. 9, 10, 12.

Batched engine architecture
---------------------------
The hot path is guest-vectorized and device-resident:

* ``multi_guest_window`` translates and records *all* guests' accesses in one
  batched ``asp.translate`` / ``asp.record_accesses`` call (guest-segmented
  hit reductions are row sums over the ``[n_guests, k]`` access matrix), runs
  all N GPAC daemons as one batched pass
  (:func:`repro.core.gpac.gpac_maintenance_batched`: one hot-mask
  classification, a row-wise per-guest filter, and ``max_batches`` guest-wide
  consolidation rounds -- trace/compile cost is O(1) in ``n_guests`` instead
  of O(n_guests) unrolled), and computes the per-guest near-share with one
  reshape-segmented reduction.
* ``run_multi_guest`` fuses the window loop into ``lax.scan`` over the window
  axis with device-side stacked metric series; the host sees one transfer per
  ``windows_per_step`` chunk (default: one transfer for the whole run) instead
  of a blocking sync every window.

``multi_guest_window_reference`` / ``run_multi_guest_reference`` preserve the
original per-guest / per-window formulation; equivalence tests pin the engine
bit-for-bit against them and ``benchmarks/bench_engine.py`` tracks the
speedup.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import address_space as asp
from repro.core import gpac, metrics, telemetry, tiering
from repro.core.types import GpacConfig, TieredState, allocated_hp_mask, init_state


@dataclasses.dataclass(frozen=True)
class MultiGuest:
    """Geometry of N symmetric guests packed into one host block space."""

    cfg: GpacConfig  # combined space
    n_guests: int
    logical_per_guest: int
    hp_per_guest: int

    def logical_range(self, g: int) -> tuple[int, int]:
        return g * self.logical_per_guest, (g + 1) * self.logical_per_guest

    def hp_range(self, g: int) -> tuple[int, int]:
        return g * self.hp_per_guest, (g + 1) * self.hp_per_guest

    def localize(self, g: int, local_ids: jax.Array) -> jax.Array:
        """Guest-local logical page ids -> combined-space ids (-1 passthrough)."""
        lo, _ = self.logical_range(g)
        return jnp.where(local_ids >= 0, local_ids + lo, -1)

    def localize_all(self, local_ids: jax.Array) -> jax.Array:
        """Batched :meth:`localize`: ``int32[n_guests, k]`` guest-local ids ->
        combined-space ids in one shot (-1 passthrough)."""
        lo = (
            jnp.arange(self.n_guests, dtype=local_ids.dtype)[:, None]
            * self.logical_per_guest
        )
        return jnp.where(local_ids >= 0, local_ids + lo, -1)


def make_multi_guest(
    n_guests: int,
    logical_per_guest: int,
    hp_ratio: int,
    near_fraction: float,
    gpa_slack: float = 0.25,
    **cfg_kw,
) -> tuple[MultiGuest, TieredState]:
    """Build N guests over one host space.

    ``near_fraction``: near-tier capacity as a fraction of *total allocated*
    huge pages across guests (the paper's DRAM:NVMM ratio knob, Fig. 17).
    """
    hp_need = -(-logical_per_guest // hp_ratio)
    hp_per_guest = hp_need + max(2, int(hp_need * gpa_slack))
    n_hp = n_guests * hp_per_guest
    n_near = max(1, int(near_fraction * n_guests * hp_need))
    cfg = GpacConfig(
        n_logical=n_guests * logical_per_guest,
        hp_ratio=hp_ratio,
        n_gpa_hp=n_hp,
        n_near=min(n_near, n_hp - 1),
        **cfg_kw,
    )
    mg = MultiGuest(cfg, n_guests, logical_per_guest, hp_per_guest)
    # Identity init maps guest g's logical pages into its own hp segment only
    # if segments are tight; with slack we must place pages per guest.
    gpt = np.full((cfg.n_logical,), -1, np.int64)
    rmap = np.full((cfg.n_gpa,), -1, np.int64)
    gpa = (
        np.arange(n_guests)[:, None] * (hp_per_guest * hp_ratio)
        + np.arange(logical_per_guest)[None, :]
    ).reshape(-1)
    gpt[:] = gpa
    rmap[gpa] = np.arange(cfg.n_logical)
    state = init_state(cfg)
    state = asp.dataclasses_replace(
        state,
        gpt=jnp.asarray(gpt, jnp.int32),
        rmap=jnp.asarray(rmap, jnp.int32),
    )
    return mg, state


# --------------------------------------------------------------------------
# vectorized engine
# --------------------------------------------------------------------------
def _window_core(
    mg: MultiGuest,
    state: TieredState,
    accesses: jax.Array,
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    cl: int | None,
) -> tuple[TieredState, dict]:
    """Traceable body of one multi-guest window (shared by the jitted
    single-window entry point and the scan-fused driver)."""
    cfg = mg.cfg
    n_g = mg.n_guests
    ids = mg.localize_all(accesses)  # int32[n_guests, k] combined-space ids
    # one batched translate over every guest's accesses; hit tiers resolve
    # against the placement in effect when the access happened (PEBS-like)
    slot, _, valid = asp.translate(cfg, state, ids)
    near_hits = (valid & (slot < cfg.n_near)).sum(axis=1)
    far_hits = (valid & (slot >= cfg.n_near)).sum(axis=1)
    state = asp.record_accesses(cfg, state, ids.reshape(-1))
    if use_gpac:
        # all N guest daemons in one batched GPAC pass: one hot-mask
        # classification, one row-wise per-guest filter, and max_batches
        # guest-wide consolidation rounds. Guests' logical/GPA segments are
        # disjoint, so this matches the sequential per-guest reference
        # bit-for-bit with O(1) trace cost in n_guests.
        state = gpac.gpac_maintenance_batched(
            cfg, state, backend, max_batches, cl,
            n_g, mg.logical_per_guest, mg.hp_per_guest,
        )
    state = tiering.tick(cfg, state, policy, budget=budget)

    # guest hp segments tile [0, n_gpa_hp), so the per-guest near share is one
    # reshape-segmented reduction instead of n_guests masked sums
    alloc = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    near_blocks = (alloc & in_near).reshape(n_g, mg.hp_per_guest).sum(axis=1)
    out = dict(near_hits=near_hits, far_hits=far_hits, near_blocks=near_blocks)
    state = telemetry.end_window(cfg, state)
    return state, out


@partial(
    jax.jit,
    static_argnames=("mg", "policy", "backend", "use_gpac", "max_batches", "budget", "cl"),
)
def multi_guest_window(
    mg: MultiGuest,
    state: TieredState,
    accesses: jax.Array,  # int32[n_guests, k] guest-LOCAL page ids, -1 padded
    policy: str = "memtierd",
    backend: str = "ipt",
    use_gpac: bool = True,
    max_batches: int = 4,
    budget: int = 64,
    cl: int | None = None,
) -> tuple[TieredState, dict]:
    """One telemetry window for all guests + one host tier tick (vectorized).

    Returns per-guest metrics computed *at access time* (hit tiers resolved
    against the placement in effect when the access happened, like PEBS).
    Bit-for-bit equivalent to :func:`multi_guest_window_reference`.
    """
    return _window_core(
        mg, state, accesses, policy, backend, use_gpac, max_batches, budget, cl
    )


@partial(
    jax.jit,
    static_argnames=("mg", "policy", "backend", "use_gpac", "max_batches", "budget", "cl"),
)
def _run_window_chunk(
    mg: MultiGuest,
    state: TieredState,
    chunk: jax.Array,  # int32[n_windows, n_guests, k]
    policy: str,
    backend: str,
    use_gpac: bool,
    max_batches: int,
    budget: int,
    cl: int | None,
) -> tuple[TieredState, dict]:
    """Scan-fused run over a chunk of windows; metric series stay stacked on
    device until the caller pulls them."""

    def body(st, acc):
        return _window_core(
            mg, st, acc, policy, backend, use_gpac, max_batches, budget, cl
        )

    return jax.lax.scan(body, state, chunk)


def run_multi_guest(
    mg: MultiGuest,
    state: TieredState,
    traces: np.ndarray,  # int32[n_guests, n_windows, k] guest-local ids
    tier_pair: str = "dram_nvmm",
    policy: str = "memtierd",
    backend: str = "ipt",
    use_gpac: bool = True,
    max_batches: int = 4,
    budget: int = 64,
    cl: int | None = None,
    windows_per_step: int = 0,
) -> tuple[TieredState, dict]:
    """Drive all windows; return the per-guest time series the at-scale
    benchmarks plot (near blocks, hit rate, modeled throughput).

    The window loop is a device-side ``lax.scan``; ``windows_per_step``
    bounds how many windows each jitted step fuses (0 = the whole run in one
    step). Metric series are transferred to the host once per chunk instead
    of once per window. Pick a ``windows_per_step`` that divides
    ``n_windows``: a shorter trailing chunk has a different scan shape and
    pays one extra trace/compile per fresh process.
    """
    n_g, n_w, _ = traces.shape
    if n_w == 0:
        return state, dict(
            near_blocks=np.zeros((0, n_g), np.int64),
            hit_rate=np.zeros((0, n_g)),
            throughput=np.zeros((0, n_g)),
        )
    by_window = np.ascontiguousarray(np.transpose(np.asarray(traces), (1, 0, 2)))
    wps = n_w if windows_per_step <= 0 else min(windows_per_step, n_w)
    outs = []
    for s in range(0, n_w, wps):
        state, out = _run_window_chunk(
            mg, state, jnp.asarray(by_window[s : s + wps]),
            policy, backend, use_gpac, max_batches, budget, cl,
        )
        outs.append(out)
    nh = np.concatenate([np.asarray(o["near_hits"]) for o in outs]).astype(np.float64)
    fh = np.concatenate([np.asarray(o["far_hits"]) for o in outs]).astype(np.float64)
    near_blocks = np.concatenate(
        [np.asarray(o["near_blocks"]) for o in outs]
    ).astype(np.int64)
    hit_rate, throughput = metrics.throughput_from_hits(nh, fh, tier_pair)
    series = dict(
        near_blocks=near_blocks, hit_rate=hit_rate, throughput=throughput
    )
    return state, series


# --------------------------------------------------------------------------
# seed-equivalent reference path (per-guest / per-window formulation)
# --------------------------------------------------------------------------
@partial(
    jax.jit,
    static_argnames=("mg", "policy", "backend", "use_gpac", "max_batches", "budget", "cl"),
)
def multi_guest_window_reference(
    mg: MultiGuest,
    state: TieredState,
    accesses: jax.Array,  # int32[n_guests, k] guest-LOCAL page ids, -1 padded
    policy: str = "memtierd",
    backend: str = "ipt",
    use_gpac: bool = True,
    max_batches: int = 4,
    budget: int = 64,
    cl: int | None = None,
) -> tuple[TieredState, dict]:
    """Original per-guest-loop window (the seed semantics): the equivalence
    oracle for :func:`multi_guest_window` and the baseline that
    ``benchmarks/bench_engine.py`` times the engine against. Its trace cost
    is O(n_guests) -- every guest's translate/record/GPAC pass is unrolled."""
    cfg = mg.cfg
    n_g = mg.n_guests
    per_guest_near = []
    per_guest_far = []
    logical_idx = jnp.arange(cfg.n_logical, dtype=jnp.int32)
    hp_idx = jnp.arange(cfg.n_gpa_hp)
    for g in range(n_g):
        ids = mg.localize(g, accesses[g])
        slot, _, valid = asp.translate(cfg, state, ids)
        per_guest_near.append(jnp.where(valid & (slot < cfg.n_near), 1, 0).sum())
        per_guest_far.append(jnp.where(valid & (slot >= cfg.n_near), 1, 0).sum())
        state = asp.record_accesses(cfg, state, ids)
    if use_gpac:
        for g in range(n_g):
            lo, hi = mg.logical_range(g)
            allow = (logical_idx >= lo) & (logical_idx < hi)
            state = gpac.gpac_maintenance(
                cfg, state, backend, max_batches, cl, allow=allow,
                hp_range=mg.hp_range(g),
            )
    state = tiering.tick(cfg, state, policy, budget=budget)

    alloc = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    near_share = []
    for g in range(n_g):
        hp_lo, hp_hi = mg.hp_range(g)
        seg = (hp_idx >= hp_lo) & (hp_idx < hp_hi)
        near_share.append((seg & alloc & in_near).sum())
    out = dict(
        near_hits=jnp.stack(per_guest_near),
        far_hits=jnp.stack(per_guest_far),
        near_blocks=jnp.stack(near_share),
    )
    state = telemetry.end_window(cfg, state)
    return state, out


def run_multi_guest_reference(
    mg: MultiGuest,
    state: TieredState,
    traces: np.ndarray,  # int32[n_guests, n_windows, k] guest-local ids
    tier_pair: str = "dram_nvmm",
    **kw,
) -> tuple[TieredState, dict]:
    """Original per-window python driver (one host sync per window): the
    equivalence oracle for :func:`run_multi_guest`."""
    n_g, n_w, _ = traces.shape
    series = dict(
        near_blocks=np.zeros((n_w, n_g), np.int64),
        hit_rate=np.zeros((n_w, n_g)),
        throughput=np.zeros((n_w, n_g)),
    )
    for w in range(n_w):
        state, out = multi_guest_window_reference(
            mg, state, jnp.asarray(traces[:, w]), **kw
        )
        nh = np.asarray(out["near_hits"], np.float64)
        fh = np.asarray(out["far_hits"], np.float64)
        hit, tput = metrics.throughput_from_hits(nh, fh, tier_pair)
        series["near_blocks"][w] = np.asarray(out["near_blocks"])
        series["hit_rate"][w] = hit
        series["throughput"][w] = tput
    return state, series
