"""Scattered Page Filter (paper §4.3.1) -- guest user-space policy layer.

Input: the telemetry hot mask. Output: fixed-shape batches of logical page
ids (each batch <= hp_ratio) to hand to ``consolidate_pages()``.

Selection rule (paper): a hot base page is a consolidation candidate iff the
huge page it currently occupies has fewer than CL hot subpages. Freshly
consolidated regions are exempt for ``reconsolidate_cooldown`` epochs to stop
ping-ponging of partially filled regions (implementation detail the paper
leaves open; documented in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import telemetry
from repro.core.types import GpacConfig, TieredState


def candidate_mask(
    cfg: GpacConfig,
    state: TieredState,
    hot: jax.Array,
    cl: int | jax.Array | None = None,
    allow: jax.Array | None = None,
) -> jax.Array:
    """bool[n_logical]: hot pages living in skewed (< CL hot subpages) huge
    pages that are not inside a cooldown region. ``allow`` optionally
    restricts candidates to one guest's logical pages (multi-tenant)."""
    cl = cfg.cl if cl is None else cl
    per_hp = telemetry.hot_subpages_per_hp(cfg, state, hot)
    hp_of = state.gpt // cfg.hp_ratio
    skewed = (per_hp[hp_of] > 0) & (per_hp[hp_of] < cl)
    cooling = (state.region_epoch[hp_of] >= 0) & (
        state.epoch - state.region_epoch[hp_of] < cfg.reconsolidate_cooldown
    )
    out = hot & skewed & ~cooling
    if allow is not None:
        out = out & allow
    return out


def select_batches(
    cfg: GpacConfig,
    state: TieredState,
    hot: jax.Array,
    max_batches: int,
    cl: int | jax.Array | None = None,
    allow: jax.Array | None = None,
):
    """Pick up to ``max_batches * hp_ratio`` candidates, hottest first, and
    shape them into ``(max_batches, hp_ratio)`` id batches padded with -1.

    Ordering matters: consolidating the hottest scattered pages first densifies
    the regions the host is most likely to promote. Candidates are ranked by
    (current-window count, history popcount).
    """
    cand = candidate_mask(cfg, state, hot, cl, allow)
    # rank: hotter first; stable by page id for determinism
    score = (
        state.guest_counts.astype(jnp.int32) * 256
        + telemetry._popcount_u8(state.ipt_hist).astype(jnp.int32)
    )
    score = jnp.where(cand, score, -1)
    k = max_batches * cfg.hp_ratio
    k = min(k, cfg.n_logical)
    _, top_ids = jax.lax.top_k(score, k)
    top_valid = score[top_ids] >= 0
    ids = jnp.where(top_valid, top_ids.astype(jnp.int32), -1)
    pad = max_batches * cfg.hp_ratio - k
    if pad:
        ids = jnp.concatenate([ids, jnp.full((pad,), -1, jnp.int32)])
    batches = ids.reshape(max_batches, cfg.hp_ratio)
    counts = (batches >= 0).sum(axis=1).astype(jnp.int32)
    return batches, counts
