"""Scattered Page Filter (paper §4.3.1) -- guest user-space policy layer.

Input: the telemetry hot mask. Output: fixed-shape batches of logical page
ids (each batch <= hp_ratio) to hand to ``consolidate_pages()``.

Selection rule (paper): a hot base page is a consolidation candidate iff the
huge page it currently occupies has fewer than CL hot subpages. Freshly
consolidated regions are exempt for ``reconsolidate_cooldown`` epochs to stop
ping-ponging of partially filled regions (implementation detail the paper
leaves open; documented in DESIGN.md).

``select_batches`` serves one daemon; ``select_batches_per_guest`` is the
batched multi-tenant form -- one row-wise top-k over the
``[n_guests, logical_per_guest]`` score matrix instead of N full-space sorts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import telemetry
from repro.core.types import GpacConfig, TieredState


def candidate_mask(
    cfg: GpacConfig,
    state: TieredState,
    hot: jax.Array,
    cl: int | jax.Array | None = None,
    allow: jax.Array | None = None,
) -> jax.Array:
    """bool[n_logical]: hot pages living in skewed (< CL hot subpages) huge
    pages that are not inside a cooldown region. ``allow`` optionally
    restricts candidates to one guest's logical pages (multi-tenant)."""
    cl = cfg.cl if cl is None else cl
    per_hp = telemetry.hot_subpages_per_hp(cfg, state, hot)
    hp_of = state.gpt // cfg.hp_ratio
    skewed = (per_hp[hp_of] > 0) & (per_hp[hp_of] < cl)
    cooling = (state.region_epoch[hp_of] >= 0) & (
        state.epoch - state.region_epoch[hp_of] < cfg.reconsolidate_cooldown
    )
    out = hot & skewed & ~cooling
    if allow is not None:
        out = out & allow
    return out


def select_batches(
    cfg: GpacConfig,
    state: TieredState,
    hot: jax.Array,
    max_batches: int,
    cl: int | jax.Array | None = None,
    allow: jax.Array | None = None,
):
    """Pick up to ``max_batches * hp_ratio`` candidates, hottest first, and
    shape them into ``(max_batches, hp_ratio)`` id batches padded with -1.

    Ordering matters: consolidating the hottest scattered pages first densifies
    the regions the host is most likely to promote. Candidates are ranked by
    (current-window count, history popcount).
    """
    cand = candidate_mask(cfg, state, hot, cl, allow)
    score = jnp.where(cand, _hotness_score(state), -1)
    k = max_batches * cfg.hp_ratio
    k = min(k, cfg.n_logical)
    _, top_ids = jax.lax.top_k(score, k)
    top_valid = score[top_ids] >= 0
    ids = jnp.where(top_valid, top_ids.astype(jnp.int32), -1)
    pad = max_batches * cfg.hp_ratio - k
    if pad:
        ids = jnp.concatenate([ids, jnp.full((pad,), -1, jnp.int32)])
    batches = ids.reshape(max_batches, cfg.hp_ratio)
    counts = (batches >= 0).sum(axis=1).astype(jnp.int32)
    return batches, counts


def _hotness_score(state: TieredState) -> jax.Array:
    """Candidate ranking: hotter first; stable by page id for determinism
    (current-window count dominates, history popcount breaks ties)."""
    return (
        state.guest_counts.astype(jnp.int32) * 256
        + telemetry._popcount_u8(state.ipt_hist).astype(jnp.int32)
    )


def select_batches_per_guest(
    cfg: GpacConfig,
    state: TieredState,
    hot: jax.Array,
    max_batches: int,
    cl: int | jax.Array | None,
    n_guests: int,
    logical_per_guest: int,
) -> jax.Array:
    """Batched :func:`select_batches` for N symmetric guests whose logical
    segments tile ``[0, n_logical)``: one row-wise ``top_k`` over the
    ``[n_guests, logical_per_guest]`` score matrix replaces ``n_guests``
    full-space sorts (each O(n_logical)), so the filter's work no longer grows
    quadratically with guest count.

    Returns ``int32[n_guests, max_batches, hp_ratio]`` logical-id batches,
    padded with -1 -- row ``g`` is exactly what ``select_batches(...,
    allow=guest g's segment)`` would produce, because a guest's candidate
    mask, score, and in-segment ordering are all unaffected by the other
    guests' segments.
    """
    assert n_guests * logical_per_guest == cfg.n_logical
    cand = candidate_mask(cfg, state, hot, cl)
    score = jnp.where(cand, _hotness_score(state), -1)
    per_guest = score.reshape(n_guests, logical_per_guest)
    k = min(max_batches * cfg.hp_ratio, logical_per_guest)
    vals, idx = jax.lax.top_k(per_guest, k)  # row-wise, ties -> lowest index
    offs = (
        jnp.arange(n_guests, dtype=jnp.int32)[:, None] * logical_per_guest
    )
    ids = jnp.where(vals >= 0, idx.astype(jnp.int32) + offs, -1)
    pad = max_batches * cfg.hp_ratio - k
    if pad:
        ids = jnp.concatenate(
            [ids, jnp.full((n_guests, pad), -1, jnp.int32)], axis=1
        )
    return ids.reshape(n_guests, max_batches, cfg.hp_ratio)
