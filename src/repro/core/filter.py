"""Scattered Page Filter (paper §4.3.1) -- guest user-space policy layer.

Input: the telemetry hot mask. Output: fixed-shape batches of logical page
ids (each batch <= hp_ratio) to hand to ``consolidate_pages()``.

Selection rule (paper): a hot base page is a consolidation candidate iff the
huge page it currently occupies has fewer than CL hot subpages. Freshly
consolidated regions are exempt for ``reconsolidate_cooldown`` epochs to stop
ping-ponging of partially filled regions (implementation detail the paper
leaves open; documented in DESIGN.md).

``select_batches`` serves one daemon; ``select_batches_ragged`` is the
batched multi-tenant form -- one row-wise top-k over the padded
``[n_guests, max_logical]`` score matrix built from the engine's
segment-offset tables (guests may have distinct sizes and CLs) instead of N
full-space sorts. ``select_batches_per_guest`` is the deprecated symmetric
wrapper kept for the old ``MultiGuest`` entry points.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import telemetry
from repro.core.types import GpacConfig, TieredState


def candidate_mask(
    cfg: GpacConfig,
    state: TieredState,
    hot: jax.Array,
    cl: int | jax.Array | None = None,
    allow: jax.Array | None = None,
    kernel_backend: str = "auto",
) -> jax.Array:
    """bool[n_logical]: hot pages living in skewed (< CL hot subpages) huge
    pages that are not inside a cooldown region. ``allow`` optionally
    restricts candidates to one guest's logical pages (multi-tenant)."""
    cl = cfg.cl if cl is None else cl
    per_hp = telemetry.hot_subpages_per_hp(cfg, state, hot, kernel_backend)
    hp_of = state.gpt // cfg.hp_ratio
    skewed = (per_hp[hp_of] > 0) & (per_hp[hp_of] < cl)
    cooling = (state.region_epoch[hp_of] >= 0) & (
        state.epoch - state.region_epoch[hp_of] < cfg.reconsolidate_cooldown
    )
    out = hot & skewed & ~cooling
    if allow is not None:
        out = out & allow
    return out


def select_batches(
    cfg: GpacConfig,
    state: TieredState,
    hot: jax.Array,
    max_batches: int,
    cl: int | jax.Array | None = None,
    allow: jax.Array | None = None,
):
    """Pick up to ``max_batches * hp_ratio`` candidates, hottest first, and
    shape them into ``(max_batches, hp_ratio)`` id batches padded with -1.

    Ordering matters: consolidating the hottest scattered pages first densifies
    the regions the host is most likely to promote. Candidates are ranked by
    (current-window count, history popcount).
    """
    cand = candidate_mask(cfg, state, hot, cl, allow)
    score = jnp.where(cand, _hotness_score(state), -1)
    k = max_batches * cfg.hp_ratio
    k = min(k, cfg.n_logical)
    _, top_ids = jax.lax.top_k(score, k)
    top_valid = score[top_ids] >= 0
    ids = jnp.where(top_valid, top_ids.astype(jnp.int32), -1)
    pad = max_batches * cfg.hp_ratio - k
    if pad:
        ids = jnp.concatenate([ids, jnp.full((pad,), -1, jnp.int32)])
    batches = ids.reshape(max_batches, cfg.hp_ratio)
    counts = (batches >= 0).sum(axis=1).astype(jnp.int32)
    return batches, counts


def _hotness_score(state: TieredState) -> jax.Array:
    """Candidate ranking: hotter first; stable by page id for determinism
    (current-window count dominates, history popcount breaks ties)."""
    return (
        state.guest_counts.astype(jnp.int32) * 256
        + telemetry._popcount_u8(state.ipt_hist).astype(jnp.int32)
    )


def select_batches_from_rows(
    cfg: GpacConfig,
    score: jax.Array,  # int32[n_logical] candidate score, -1 = not a candidate
    pad_idx: jax.Array,  # int32[n_rows, max_logical] segment table rows, -1 padded
    max_batches: int,
    kernel_backend: str = "auto",
) -> jax.Array:
    """Row-wise batch selection over any slice of segment-table rows: one
    ``top_k`` per row of the padded score matrix gathered from the global
    ``score``. This is the shared core of :func:`select_batches_ragged`
    (all guests at once) and the device-sharded engine (each device passes
    only its own guests' rows). Returns ``int32[n_rows, max_batches,
    hp_ratio]`` logical-id batches, -1 padded."""
    from repro.kernels import registry as kernels

    mat = jnp.where(pad_idx >= 0, score[jnp.maximum(pad_idx, 0)], -1)
    k = min(max_batches * cfg.hp_ratio, mat.shape[1])
    # row-wise, ties -> lowest column (lax.top_k semantics on both backends;
    # scores are >= -1, safely above the kernel's INT32_MIN mask value)
    vals, col = kernels.dispatch("topk_rows", kernel_backend, mat, k)
    ids = jnp.where(vals >= 0, jnp.take_along_axis(pad_idx, col, axis=1), -1)
    pad = max_batches * cfg.hp_ratio - k
    if pad:
        ids = jnp.concatenate(
            [ids, jnp.full((mat.shape[0], pad), -1, jnp.int32)], axis=1
        )
    return ids.reshape(mat.shape[0], max_batches, cfg.hp_ratio)


def candidate_score(
    cfg: GpacConfig,
    state: TieredState,
    hot: jax.Array,
    cl_per_logical: jax.Array,
    kernel_backend: str = "auto",
) -> jax.Array:
    """int32[n_logical] filter ranking: the hotness score where
    :func:`candidate_mask` holds (per-guest CLs via ``cl_per_logical``),
    -1 elsewhere."""
    cand = candidate_mask(
        cfg, state, hot, cl_per_logical, kernel_backend=kernel_backend)
    return jnp.where(cand, _hotness_score(state), -1)


def select_batches_ragged(
    spec,  # repro.core.engine.EngineSpec
    state: TieredState,
    hot: jax.Array,
    max_batches: int,
) -> jax.Array:
    """Batched :func:`select_batches` for N **ragged** guests: one row-wise
    ``top_k`` over the padded ``[n_guests, max_logical]`` score matrix built
    from the spec's segment-offset tables replaces ``n_guests`` full-space
    sorts (each O(n_logical)), so the filter's work no longer grows
    quadratically with guest count -- and guests may have distinct sizes and
    per-guest Consolidation Limits.

    Returns ``int32[n_guests, max_batches, hp_ratio]`` logical-id batches,
    padded with -1 -- row ``g`` is exactly what ``select_batches(...,
    cl=guest g's CL, allow=guest g's segment)`` would produce, because a
    guest's candidate mask, score, and in-segment ordering are all unaffected
    by the other guests' segments, and row-wise ``top_k`` tie-breaking by
    column index preserves the global id order inside each segment.
    """
    cfg = spec.cfg
    kb = spec.kernel_backend
    score = candidate_score(
        cfg, state, hot, jnp.asarray(spec.cl_per_logical()), kb
    )
    pad_idx = jnp.asarray(spec.logical_pad_index())  # [n_guests, max_logical]
    return select_batches_from_rows(cfg, score, pad_idx, max_batches, kb)


def select_batches_per_guest(
    cfg: GpacConfig,
    state: TieredState,
    hot: jax.Array,
    max_batches: int,
    cl: int | None,
    n_guests: int,
    logical_per_guest: int,
) -> jax.Array:
    """Deprecated symmetric wrapper over :func:`select_batches_ragged` (kept
    for the old ``MultiGuest`` entry points)."""
    from repro.core.engine import symmetric_spec

    if n_guests * logical_per_guest != cfg.n_logical:
        raise ValueError("guest logical segments must tile the logical space")
    return select_batches_ragged(
        symmetric_spec(cfg, n_guests, cl=cl), state, hot, max_batches
    )
