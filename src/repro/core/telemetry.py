"""Guest-side telemetry (paper §2.2) -- pluggable hotness classifiers.

GPAC is *telemetry-agnostic* (design goal 4): every backend here consumes raw
per-window access counts and produces the same artifact, a ``bool[n_logical]``
hot mask. The host never sees any of this -- it only gets huge-page counts.

Built-in backends:
  * ``ipt``   -- Idle Page Tracking-like: per-window accessed bit, hot if the
                 bit is set in >= ``ipt_min_hits`` of the last ``ipt_windows``
                 windows (the paper's prototype telemetry).
  * ``pebs``  -- PEBS-like sampling: Bernoulli-subsampled counts with a
                 threshold (hardware-counter flavour).
  * ``damon`` -- DAMON-like region estimate: hotness smeared over adaptive
                 power-of-two regions (cheap, coarse).

New hotness sources plug in without editing this module:
:func:`register_backend` adds a ``fn(cfg, state, **kw) -> bool[n_logical]``
to the registry and every ``hot_mask(...)`` call site (the engine, GPAC, the
benchmarks) can name it (DESIGN.md §8).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.address_space import dataclasses_replace
from repro.core.types import GpacConfig, TieredState

# builtin names (kept for back-compat; the live set is backends())
BACKENDS = ("ipt", "pebs", "damon")

_BACKENDS: dict[str, Callable] = {}


def register_backend(name: str, fn: Callable | None = None):
    """Register a hotness classifier ``fn(cfg, state, **kw) ->
    bool[n_logical]``; usable as ``@register_backend("name")``. The name
    becomes valid everywhere a ``backend=`` string is accepted."""
    if fn is None:
        return lambda f: register_backend(name, f)
    if name in _BACKENDS:
        raise ValueError(f"telemetry backend {name!r} already registered")
    _BACKENDS[name] = fn
    return fn


def backends() -> tuple[str, ...]:
    """Names of all registered telemetry backends."""
    return tuple(_BACKENDS)


def end_window(cfg: GpacConfig, state: TieredState) -> TieredState:
    """Roll the telemetry window: fold current counts into bit history and
    clear them (the paper's daemon clearing ACCESSED bits)."""
    accessed = (state.guest_counts > 0).astype(jnp.uint8)
    hist = ((state.ipt_hist << 1) | accessed).astype(jnp.uint8)
    h_accessed = (state.host_counts > 0).astype(jnp.uint8)
    h_hist = ((state.host_hist << 1) | h_accessed).astype(jnp.uint8)
    return dataclasses_replace(
        state,
        ipt_hist=hist,
        host_hist=h_hist,
        guest_counts=jnp.zeros_like(state.guest_counts),
        host_counts=jnp.zeros_like(state.host_counts),
        epoch=state.epoch + 1,
    )


def _popcount_u8(x: jax.Array) -> jax.Array:
    """Set bits per uint8 history word, as int32 (single hardware popcount
    instead of an 8-step shift/mask loop -- this runs on every window in both
    the IPT hot mask and the host block score)."""
    return jax.lax.population_count(x).astype(jnp.int32)


def hot_mask_ipt(cfg: GpacConfig, state: TieredState) -> jax.Array:
    """Hot iff accessed in >= ipt_min_hits of the last ipt_windows windows
    (including the in-flight window)."""
    mask = jnp.uint8((1 << min(cfg.ipt_windows, 8)) - 1)
    hits = _popcount_u8(state.ipt_hist & mask)
    hits = hits + (state.guest_counts > 0).astype(jnp.int32)
    return hits >= cfg.ipt_min_hits


def hot_mask_pebs(
    cfg: GpacConfig, state: TieredState, key: jax.Array | None = None, rate: float = 0.25
) -> jax.Array:
    """Sampled-counter hotness: subsample this window's counts and threshold.

    Deterministic given ``key``; defaults to a fold of the epoch so simulation
    runs are reproducible.
    """
    if key is None:
        key = jax.random.fold_in(jax.random.PRNGKey(0), state.epoch)
    sampled = jax.random.binomial(
        key, state.guest_counts.astype(jnp.float32), rate
    ).astype(jnp.int32)
    return sampled >= jnp.maximum(1, jnp.int32(cfg.hot_threshold * rate))


def hot_mask_damon(
    cfg: GpacConfig, state: TieredState, region_pages: int = 64
) -> jax.Array:
    """Region-granular estimate: a region is hot if its mean count crosses the
    threshold; every page inherits its region's verdict (DAMON's trade-off)."""
    n = state.guest_counts.shape[0]
    pad = (-n) % region_pages
    c = jnp.pad(state.guest_counts, (0, pad)).reshape(-1, region_pages)
    region_hot = c.mean(axis=1) >= cfg.hot_threshold
    return jnp.repeat(region_hot, region_pages)[:n]


register_backend("ipt", hot_mask_ipt)
register_backend("pebs", hot_mask_pebs)
register_backend("damon", hot_mask_damon)


def hot_mask(cfg: GpacConfig, state: TieredState, backend: str = "ipt", **kw) -> jax.Array:
    """Dispatch to a registered hotness classifier by name."""
    try:
        fn = _BACKENDS[backend]
    except KeyError:
        raise ValueError(
            f"unknown telemetry backend {backend!r} (have {backends()})"
        ) from None
    return fn(cfg, state, **kw)


# --------------------------------------------------------------------------
# skew statistics (paper Fig. 2 / Fig. 16) -- guest-side views
# --------------------------------------------------------------------------
def hot_subpages_per_hp(
    cfg: GpacConfig, state: TieredState, hot: jax.Array,
    kernel_backend: str = "auto",
) -> jax.Array:
    """int32[n_gpa_hp]: number of hot base pages inside each huge page.

    This is the quantity the Scattered Page Filter compares against CL, and
    the x-axis of the paper's skew CDFs. Computed via rmap so unallocated gpa
    pages never count. The strided reduction dispatches to the hotness_scan
    kernel through the registry (``kernel_backend``, DESIGN.md §16); tests
    pin kernel == jnp path bit-for-bit.
    """
    from repro.kernels import registry as kernels

    hot_gpa = jnp.where(state.rmap >= 0, hot[jnp.maximum(state.rmap, 0)], False)
    return kernels.dispatch(
        "hot_count", kernel_backend, hot_gpa, cfg.hp_ratio)


def accessed_subpages_per_hp(
    cfg: GpacConfig, state: TieredState, kernel_backend: str = "auto",
) -> jax.Array:
    """int32[n_gpa_hp]: accessed (count>0) base pages per huge page -- the
    exact statistic of paper Fig. 2. Dispatches through the same
    ``hot_count`` registry entry as :func:`hot_subpages_per_hp`."""
    from repro.kernels import registry as kernels

    acc = state.guest_counts > 0
    acc_gpa = jnp.where(state.rmap >= 0, acc[jnp.maximum(state.rmap, 0)], False)
    return kernels.dispatch(
        "hot_count", kernel_backend, acc_gpa, cfg.hp_ratio)
