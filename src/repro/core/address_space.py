"""Two-level address translation and the data read/write paths.

The translation chain (paper Fig. 1):

    logical page  --gpt-->  gpa page  --(block_table on gpa//hp_ratio)-->  slot

Slots ``< n_near`` resolve into ``near_pool``; the rest into ``far_pool``.
All paths are branch-free (predicated dual-pool gathers with ``mode='drop'``
scatters) so they jit and shard cleanly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import FREE, GpacConfig, TieredState
from repro.kernels import registry as kernels


# --------------------------------------------------------------------------
# translation helpers
# --------------------------------------------------------------------------
def translate(cfg: GpacConfig, state: TieredState, logical: jax.Array):
    """logical page ids -> (slot, offset-within-block, valid mask).

    Invalid ids (negative / >= n_logical) translate to an out-of-bounds slot
    so downstream ``mode='drop'`` scatters ignore them and gathers are
    clamped + masked.
    """
    valid = (logical >= 0) & (logical < cfg.n_logical)
    safe = jnp.where(valid, logical, 0)
    gpa = state.gpt[safe]
    hp, off = gpa // cfg.hp_ratio, gpa % cfg.hp_ratio
    slot = state.block_table[hp]
    return slot, off, valid


def fused_translation(cfg: GpacConfig, state: TieredState) -> jax.Array:
    """Pre-composed logical page -> flat physical row index (the beyond-paper
    'fused translation cache': one gather instead of two at access time).

    flat row index = slot * hp_ratio + off over the virtually concatenated
    [near_pool; far_pool] row space. Must be recomputed after consolidation
    or migration (the framework's analogue of a TLB shootdown).
    """
    gpa = state.gpt
    hp, off = gpa // cfg.hp_ratio, gpa % cfg.hp_ratio
    return state.block_table[hp] * cfg.hp_ratio + off


def _flat_rows(cfg: GpacConfig, state: TieredState) -> jax.Array:
    """View of both pools as one (n_slots*hp_ratio, base_elems) row space."""
    near = state.near_pool.reshape(-1, cfg.base_elems)
    far = state.far_pool.reshape(-1, cfg.base_elems)
    return jnp.concatenate([near, far], axis=0)


# --------------------------------------------------------------------------
# data paths
# --------------------------------------------------------------------------
def read_logical(cfg: GpacConfig, state: TieredState, logical: jax.Array) -> jax.Array:
    """Gather base-page payloads through the full two-level translation.

    Returns dtype[len(logical), base_elems]; invalid ids read zeros.
    """
    slot, off, valid = translate(cfg, state, logical)
    flat = slot * cfg.hp_ratio + off
    rows = _flat_rows(cfg, state)[jnp.where(valid, flat, 0)]
    return jnp.where(valid[:, None], rows, 0)


def write_logical(
    cfg: GpacConfig, state: TieredState, logical: jax.Array, values: jax.Array
) -> TieredState:
    """Scatter payloads through translation. Invalid ids are dropped."""
    slot, off, valid = translate(cfg, state, logical)
    near_idx = jnp.where(valid & (slot < cfg.n_near), slot, cfg.n_near)
    far_idx = jnp.where(valid & (slot >= cfg.n_near), slot - cfg.n_near, cfg.n_far)
    near = state.near_pool.at[near_idx, off].set(values, mode="drop")
    far = state.far_pool.at[far_idx, off].set(values, mode="drop")
    return dataclasses_replace(state, near_pool=near, far_pool=far)


def record_accesses(
    cfg: GpacConfig, state: TieredState, logical: jax.Array,
    counts: jax.Array | None = None, kernel_backend: str = "auto",
) -> TieredState:
    """Charge accesses to guest (base-page) and host (huge-page) telemetry.

    ``logical`` int32[k] page ids (pad with -1), ``counts`` optional weights.
    The host side only ever sees the huge-page aggregate -- this is the
    information asymmetry the paper exploits. The histogram path dispatches
    through the kernel registry (``kernel_backend``, DESIGN.md §16); the
    small-batch per-access scatter stays XLA.
    """
    valid = (logical >= 0) & (logical < cfg.n_logical)
    if counts is None and logical.size * 2 >= cfg.n_logical:
        # large batches (the guest-batched engine flattens all guests'
        # accesses into one call): histogram once, then update the host side
        # per logical page instead of per access -- bit-identical integer
        # sums, ~3x fewer scattered elements
        return apply_access_histogram(
            cfg, state,
            access_histogram(cfg, logical, valid, kernel_backend),
            kernel_backend,
        )
    if counts is None:
        counts = jnp.ones(logical.shape, jnp.int32)
    counts = jnp.where(valid, counts, 0)
    l_idx = jnp.where(valid, logical, cfg.n_logical)
    guest = state.guest_counts.at[l_idx].add(counts, mode="drop")

    gpa = state.gpt[jnp.where(valid, logical, 0)]
    hp = jnp.where(valid, gpa // cfg.hp_ratio, cfg.n_gpa_hp)
    host = state.host_counts.at[hp].add(counts, mode="drop")
    touch = state.last_touch_epoch.at[hp].max(
        jnp.broadcast_to(state.epoch, hp.shape), mode="drop"
    )

    # near/far hit accounting (slot of the huge page at access time)
    slot = state.block_table[jnp.where(valid, gpa // cfg.hp_ratio, 0)]
    near_hits = jnp.where(valid & (slot < cfg.n_near), counts, 0).sum()
    far_hits = jnp.where(valid & (slot >= cfg.n_near), counts, 0).sum()
    stats = dict(state.stats)
    stats["near_hits"] = stats["near_hits"] + near_hits.astype(jnp.int32)
    stats["far_hits"] = stats["far_hits"] + far_hits.astype(jnp.int32)
    return dataclasses_replace(
        state,
        guest_counts=guest,
        host_counts=host,
        last_touch_epoch=touch,
        stats=stats,
    )


def access_histogram(
    cfg: GpacConfig, logical: jax.Array, valid: jax.Array | None = None,
    kernel_backend: str = "auto",
) -> jax.Array:
    """int32[n_logical] per-page access counts of an unweighted id batch
    (invalid / padded ids fall off the end of the scatter). The sharded
    engine psums these per-device histograms into the global one -- integer
    sums, so the combined result is bit-identical to one global scatter."""
    if valid is None:
        valid = (logical >= 0) & (logical < cfg.n_logical)
    flat = jnp.where(valid, logical, cfg.n_logical).reshape(-1).astype(jnp.int32)
    ones = jnp.ones(flat.shape, jnp.int32)
    return kernels.dispatch(
        "bincount", kernel_backend, flat, ones, cfg.n_logical + 1
    )[: cfg.n_logical]


def host_histogram(
    cfg: GpacConfig, gpt: jax.Array, h: jax.Array,
    kernel_backend: str = "auto",
) -> jax.Array:
    """int32[n_gpa_hp]: the huge-page access counts a per-logical-page
    histogram ``h`` induces under the mapping ``gpt``. Shared by the
    replicated :func:`apply_access_histogram` and the host-partitioned engine
    (which gathers only its own block range from the result -- a device's
    histogram is nonzero only inside its own guests' segments)."""
    hp_of = gpt // cfg.hp_ratio
    return kernels.dispatch(
        "bincount", kernel_backend, hp_of, h, cfg.n_gpa_hp)


def apply_access_histogram(
    cfg: GpacConfig, state: TieredState, h: jax.Array,
    kernel_backend: str = "auto",
) -> TieredState:
    """Charge a full per-logical-page access histogram ``h`` to guest and host
    telemetry: every host-side quantity (huge-page counts, touch epochs, hit
    tiers) derives from ``h`` with per-logical-page work. All sums are exact
    int32, so the result is bit-identical to the per-access scatter path."""
    hp_of = state.gpt // cfg.hp_ratio
    host_inc = host_histogram(cfg, state.gpt, h, kernel_backend)
    touch = jnp.where(
        host_inc > 0,
        jnp.maximum(state.last_touch_epoch, state.epoch),
        state.last_touch_epoch,
    )
    slot_of = state.block_table[hp_of]
    near_hits = jnp.where(slot_of < cfg.n_near, h, 0).sum()
    far_hits = jnp.where(slot_of >= cfg.n_near, h, 0).sum()
    stats = dict(state.stats)
    stats["near_hits"] = stats["near_hits"] + near_hits.astype(jnp.int32)
    stats["far_hits"] = stats["far_hits"] + far_hits.astype(jnp.int32)
    return dataclasses_replace(
        state,
        guest_counts=state.guest_counts + h,
        host_counts=state.host_counts + host_inc,
        last_touch_epoch=touch,
        stats=stats,
    )


# --------------------------------------------------------------------------
# allocation
# --------------------------------------------------------------------------
def alloc_free_huge_region(
    cfg: GpacConfig,
    state: TieredState,
    hp_range: tuple[jax.Array | int, jax.Array | int] | None = None,
):
    """Find the first fully-free huge page (the consolidator's fresh region).

    Returns (hp_index | -1). A huge page is free iff all ``hp_ratio`` of its
    gpa pages are unmapped. ``hp_range=(lo, hi)`` restricts the search to one
    guest's GPA segment (multi-tenant simulation: each guest consolidates only
    within its own physical address space).
    """
    free = (state.rmap.reshape(cfg.n_gpa_hp, cfg.hp_ratio) == FREE).all(axis=1)
    if hp_range is not None:
        lo, hi = hp_range
        hp = jnp.arange(cfg.n_gpa_hp, dtype=jnp.int32)
        free = free & (hp >= lo) & (hp < hi)
    idx = jnp.argmax(free)
    return jnp.where(free.any(), idx.astype(jnp.int32), jnp.int32(-1))


def dataclasses_replace(state: TieredState, **kw) -> TieredState:
    import dataclasses

    return dataclasses.replace(state, **kw)
