"""Static configuration and pytree state types for the GPAC tiered-memory core.

Terminology maps 1:1 onto the paper (see DESIGN.md §2):

* logical page  == guest virtual (GVA) base page      -- what the workload addresses
* gpa page      == guest physical (GPA) base page     -- slot in the guest's paged space
* huge page     == ``hp_ratio`` contiguous gpa pages  -- the host's placement granule
* host slot     == physical block location; slots ``< n_near`` live in the near
  tier (HBM / DRAM), the rest in the far tier (host DRAM / CXL / NVMM).

Everything traced is fixed-shape; all state is a registered dataclass pytree so
the whole tiering state machine jits and shards.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

FREE = jnp.int32(-1)  # sentinel for unallocated rmap / owner entries


def static_field(**kw):
    return dataclasses.field(metadata=dict(static=True), **kw)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=(),
    meta_fields=(
        "n_logical",
        "hp_ratio",
        "n_gpa_hp",
        "n_near",
        "base_elems",
        "hot_threshold",
        "cl",
        "ipt_windows",
        "ipt_min_hits",
        "reconsolidate_cooldown",
        "dtype",
    ),
)
@dataclasses.dataclass(frozen=True)
class GpacConfig:
    """Static geometry + policy knobs of one guest's tiered address space.

    Defaults follow the paper: 4 KB base pages inside 2 MB huge pages gives
    ``hp_ratio=512``; ``cl`` is the paper's Consolidation Limit.
    """

    n_logical: int  # logical (GVA) base pages addressable by the workload
    hp_ratio: int = 512  # base pages per huge page (2 MB / 4 KB)
    n_gpa_hp: int = 0  # GPA huge pages (0 -> derived with 25% slack)
    n_near: int = 0  # near-tier blocks (0 -> half of n_gpa_hp)
    base_elems: int = 8  # payload elements per base page (simulation granularity)
    hot_threshold: int = 1  # accesses/window for a page to count as hot
    cl: int = 64  # Consolidation Limit (paper §4.3.1)
    ipt_windows: int = 8  # history depth of the IPT-like bit telemetry
    ipt_min_hits: int = 1  # windows-with-access required for hotness
    reconsolidate_cooldown: int = 2  # epochs a fresh region is filter-exempt
    dtype: Any = jnp.float32

    def __post_init__(self):
        if self.n_logical < 1:
            raise ValueError(f"n_logical must be >= 1, got {self.n_logical}")
        if self.hp_ratio < 1:
            raise ValueError(f"hp_ratio must be >= 1, got {self.hp_ratio}")
        need = -(-self.n_logical // self.hp_ratio)  # ceil
        if self.n_gpa_hp == 0:
            object.__setattr__(self, "n_gpa_hp", need + max(2, need // 4))
        if self.n_near == 0:
            object.__setattr__(self, "n_near", max(1, self.n_gpa_hp // 2))
        if self.n_gpa_hp * self.hp_ratio < self.n_logical:
            raise ValueError(
                f"GPA space smaller than logical space: n_gpa_hp={self.n_gpa_hp}"
                f" x hp_ratio={self.hp_ratio} = {self.n_gpa_hp * self.hp_ratio}"
                f" gpa pages cannot cover n_logical={self.n_logical}"
            )
        if not (0 < self.n_near < self.n_gpa_hp):
            raise ValueError(
                f"need 0 < n_near < n_gpa_hp (a non-empty far tier), got "
                f"n_near={self.n_near}, n_gpa_hp={self.n_gpa_hp}"
            )
        if not (1 <= self.cl <= self.hp_ratio):
            raise ValueError(
                f"Consolidation Limit must be in [1, hp_ratio={self.hp_ratio}]"
                f", got cl={self.cl}"
            )

    # ---- derived sizes -------------------------------------------------
    @property
    def n_gpa(self) -> int:
        return self.n_gpa_hp * self.hp_ratio

    @property
    def n_far(self) -> int:
        return self.n_gpa_hp - self.n_near

    @property
    def n_slots(self) -> int:
        return self.n_gpa_hp  # block_table is a permutation of slots

    @property
    def base_bytes(self) -> int:
        return self.base_elems * jnp.dtype(self.dtype).itemsize

    @property
    def hp_bytes(self) -> int:
        return self.base_bytes * self.hp_ratio


@partial(
    jax.tree_util.register_dataclass,
    data_fields=(
        "gpt",
        "rmap",
        "block_table",
        "slot_owner",
        "near_pool",
        "far_pool",
        "guest_counts",
        "ipt_hist",
        "host_counts",
        "host_hist",
        "last_touch_epoch",
        "region_epoch",
        "epoch",
        "stats",
    ),
    meta_fields=(),
)
@dataclasses.dataclass
class TieredState:
    """One guest's full two-level address-space + host-placement state.

    Invariants (enforced by tests/test_core_invariants.py):
      * ``gpt`` restricted to allocated logical pages is injective and
        ``rmap[gpt[l]] == l``; unallocated gpa pages have ``rmap == FREE``.
      * ``block_table`` is a permutation of ``[0, n_slots)`` and
        ``slot_owner[block_table[hp]] == hp``.
      * data read through the logical view is preserved by consolidation and
        by tier migrations (both only move bytes + rewrite mappings).
    """

    # guest level -------------------------------------------------------
    gpt: jax.Array  # int32[n_logical]  logical -> gpa page
    rmap: jax.Array  # int32[n_gpa]      gpa page -> logical | FREE
    # host level --------------------------------------------------------
    block_table: jax.Array  # int32[n_gpa_hp]  huge page -> slot (permutation)
    slot_owner: jax.Array  # int32[n_slots]   slot -> huge page (inverse)
    near_pool: jax.Array  # dtype[n_near, hp_ratio, base_elems]
    far_pool: jax.Array  # dtype[n_far,  hp_ratio, base_elems]
    # guest telemetry (base-page granularity; the host never reads these)
    guest_counts: jax.Array  # int32[n_logical] accesses this window
    ipt_hist: jax.Array  # uint8[n_logical] per-window accessed-bit history
    # host telemetry (huge-page granularity only -- the information asymmetry)
    host_counts: jax.Array  # int32[n_gpa_hp] accesses this window (EWMA'd by policies)
    host_hist: jax.Array  # uint8[n_gpa_hp] per-window accessed-bit history
    last_touch_epoch: jax.Array  # int32[n_gpa_hp] for LRU-style policies
    # consolidation bookkeeping ------------------------------------------
    region_epoch: jax.Array  # int32[n_gpa_hp] epoch a region was consolidated (-1 never)
    epoch: jax.Array  # int32[] telemetry window counter
    stats: dict  # running counters (see init_state)


def init_state(cfg: GpacConfig, fill: jax.Array | None = None) -> TieredState:
    """Fresh identity-mapped state.

    Logical page ``l`` starts at gpa page ``l``; huge page ``h`` starts at
    slot ``h`` (so huge pages ``< n_near`` begin in the near tier, the rest
    far -- benchmarks that model "start everything in far memory" permute
    this, see :func:`start_all_far`).

    ``fill``: optional dtype[n_logical, base_elems] initial payload.
    """
    gpt = jnp.arange(cfg.n_logical, dtype=jnp.int32)
    rmap = jnp.full((cfg.n_gpa,), FREE, dtype=jnp.int32)
    rmap = rmap.at[: cfg.n_logical].set(jnp.arange(cfg.n_logical, dtype=jnp.int32))
    block_table = jnp.arange(cfg.n_gpa_hp, dtype=jnp.int32)
    slot_owner = jnp.arange(cfg.n_slots, dtype=jnp.int32)
    near = jnp.zeros((cfg.n_near, cfg.hp_ratio, cfg.base_elems), cfg.dtype)
    far = jnp.zeros((cfg.n_far, cfg.hp_ratio, cfg.base_elems), cfg.dtype)
    state = TieredState(
        gpt=gpt,
        rmap=rmap,
        block_table=block_table,
        slot_owner=slot_owner,
        near_pool=near,
        far_pool=far,
        guest_counts=jnp.zeros((cfg.n_logical,), jnp.int32),
        ipt_hist=jnp.zeros((cfg.n_logical,), jnp.uint8),
        host_counts=jnp.zeros((cfg.n_gpa_hp,), jnp.int32),
        host_hist=jnp.zeros((cfg.n_gpa_hp,), jnp.uint8),
        last_touch_epoch=jnp.zeros((cfg.n_gpa_hp,), jnp.int32),
        region_epoch=jnp.full((cfg.n_gpa_hp,), -1, jnp.int32),
        epoch=jnp.zeros((), jnp.int32),
        stats=dict(
            consolidated_pages=jnp.zeros((), jnp.int32),
            consolidation_calls=jnp.zeros((), jnp.int32),
            consolidation_enomem=jnp.zeros((), jnp.int32),
            copied_bytes=jnp.zeros((), jnp.int32),
            promoted_blocks=jnp.zeros((), jnp.int32),
            demoted_blocks=jnp.zeros((), jnp.int32),
            near_hits=jnp.zeros((), jnp.int32),
            far_hits=jnp.zeros((), jnp.int32),
            tlb_shootdowns=jnp.zeros((), jnp.int32),
        ),
    )
    if fill is not None:
        from repro.core import address_space as asp

        state = asp.write_logical(
            cfg, state, jnp.arange(cfg.n_logical, dtype=jnp.int32), fill
        )
    return state


def start_all_far(cfg: GpacConfig, state: TieredState) -> TieredState:
    """Re-home every *allocated* huge page to the far tier (paper §5.2 starts
    guests with far memory preferred). Implemented as block-table swaps so all
    invariants hold; data moves with the blocks."""
    from repro.core import tiering

    # Demote allocated huge pages currently in near, swapping with unallocated
    # huge pages currently in far (which hold no data).
    hp_alloc = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    demote = hp_alloc & in_near
    victim = (~hp_alloc) & (~in_near)
    n = min(cfg.n_near, cfg.n_far)
    d_idx = jnp.nonzero(demote, size=n, fill_value=-1)[0].astype(jnp.int32)
    v_idx = jnp.nonzero(victim, size=n, fill_value=-1)[0].astype(jnp.int32)
    k = jnp.minimum((d_idx >= 0).sum(), (v_idx >= 0).sum())
    return tiering.swap_blocks(cfg, state, v_idx, d_idx, k)


def allocated_hp_mask(cfg: GpacConfig, state: TieredState) -> jax.Array:
    """bool[n_gpa_hp] -- huge page contains >=1 allocated base page."""
    return (state.rmap.reshape(cfg.n_gpa_hp, cfg.hp_ratio) != FREE).any(axis=1)
