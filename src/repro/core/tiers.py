"""N-tier memory hierarchies: software-defined tiers, inter-tier flows, TCO.

The paper's near/far split is the 2-tier special case of a general memory
hierarchy. Following *Taming Server Memory TCO with Multiple Software-Defined
Compressed Tiers* (arXiv 2404.13886) and *HybridTier* (arXiv 2312.04789),
this module generalizes the slot space into an ordered vector of tiers:

  * :class:`TierSpec`   -- one tier: capacity fraction, latency, bandwidth,
    compression factor (effective capacity = capacity x compression) and a
    $/GB cost weight (the TCO objective).
  * :class:`TierVector` -- a resolved hierarchy: the tier specs plus slot
    boundaries partitioning ``[0, n_slots)`` into contiguous tier ranges.
    Tier 0 is the fastest (the paper's "near" tier); the last tier is the
    capacity backstop. ``two_tier(cfg)`` reconstructs the legacy near/far
    split, so every existing code path is the 2-tier special case.

Placement generalizes from promote/demote pairs to **inter-tier flows**
between adjacent tiers: :func:`flow_tick` runs a pair policy top-down over
each adjacent (upper, lower) boundary pair, and :func:`swap_flow` is the
bounds-parameterized migration primitive (``tiering.swap_blocks`` with the
near/far constants replaced by tier ranges). With a 2-tier vector every
flow body below is **bit-for-bit identical** to the legacy tick it mirrors
(INV-TIER-2SPECIALCASE-EXACT): the extra range conjuncts are tautologies on
a slot permutation, and the generalized pool gather/scatter only changes
*dropped* rows (garbage gathered under ``~ok`` never lands because the
scatter row is the out-of-range sentinel).

Two new policies ride the flow machinery:

  * ``compressed`` -- demote-into-compressed (arXiv 2404.13886): each pair
    keeps a free-headroom watermark in the upper tier by demoting coldest
    blocks down, then promotes identified-hot blocks up; effective capacity
    per tier is already folded into the boundaries by :func:`resolve`.
    Registered on BOTH paths (replicated + host-sharded (prepare, apply)).
  * ``hybridtier`` -- adaptive placement (arXiv 2312.04789): each pair
    tracks a moving hot threshold (mean resident score of the upper tier)
    and promotes only blocks hotter than it, evicting colder-than-threshold
    residents. Replicated-only (``host_sharded=False``).

TCO metric: :func:`tco_metrics` prices the post-tick placement --
``tco = sum_t blocks_t * GB/block * cost_t / compression_t`` -- and an
AMAT charged per tier latency; ``engine.register_collector("tco")`` wires
it next to hit-rate on every driver path.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import metrics
from repro.core.address_space import dataclasses_replace
from repro.core.tiering import (
    NEG,
    _b,
    _cand_kw,
    _paired_ids,
    _pair_k,
    allocated_hp_mask,
    apply_swaps_local,
    block_score_arrays,
    nominate,
    rank_select,
    register_policy,
    register_sharded_tick,
    slots_after_swaps,
    _flat_cands,
)
from repro.core.types import GpacConfig, TieredState

# default $/GB weights per tier name (arXiv 2404.13886's TCO framing: the
# near tier is the expensive one; compressed/far tiers are the cheap ones)
DEFAULT_COST = {"hbm": 2.5, "dram": 1.0, "zram": 1.0, "cxl": 0.6, "nvmm": 0.4}


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One software-defined tier.

    ``capacity`` is a fraction of the allocated huge-page demand (mirrors
    ``HostSpec.near_fraction``); ``compression`` multiplies it into an
    effective block count (a zswap-style tier stores ``capacity x
    compression`` blocks in ``capacity`` worth of physical GB, and is
    priced on the *physical* GB). The last tier of a vector is the
    capacity backstop: its ``capacity`` is ignored and it absorbs every
    remaining slot.
    """

    name: str
    capacity: float
    latency_ns: float
    bandwidth_gbps: float = 100.0
    compression: float = 1.0
    cost_per_gb: float = 1.0

    def __post_init__(self):
        if not 0.0 < self.capacity <= 1.0:
            raise ValueError(
                f"TierSpec {self.name!r}: capacity must be in (0, 1], got "
                f"{self.capacity}")
        if self.latency_ns <= 0.0:
            raise ValueError(
                f"TierSpec {self.name!r}: latency_ns must be > 0, got "
                f"{self.latency_ns}")
        if self.bandwidth_gbps <= 0.0:
            raise ValueError(
                f"TierSpec {self.name!r}: bandwidth_gbps must be > 0, got "
                f"{self.bandwidth_gbps}")
        if self.compression < 1.0:
            raise ValueError(
                f"TierSpec {self.name!r}: compression must be >= 1, got "
                f"{self.compression}")
        if self.cost_per_gb < 0.0:
            raise ValueError(
                f"TierSpec {self.name!r}: cost_per_gb must be >= 0, got "
                f"{self.cost_per_gb}")


@dataclasses.dataclass(frozen=True)
class TierVector:
    """A resolved tier hierarchy over the slot space.

    ``boundaries`` has ``len(tiers) + 1`` entries: tier ``t`` owns slots
    ``[boundaries[t], boundaries[t+1])``; ``boundaries[0] == 0`` and
    ``boundaries[-1] == n_slots``. Hashable (tuples only) so it can ride
    ``EngineSpec`` as a static jit key.
    """

    tiers: tuple[TierSpec, ...]
    boundaries: tuple[int, ...]

    def __post_init__(self):
        if len(self.tiers) < 2:
            raise ValueError(
                f"TierVector needs >= 2 tiers, got {len(self.tiers)}")
        if len(self.boundaries) != len(self.tiers) + 1:
            raise ValueError(
                f"TierVector: {len(self.tiers)} tiers need "
                f"{len(self.tiers) + 1} boundaries, got "
                f"{len(self.boundaries)}")
        if self.boundaries[0] != 0:
            raise ValueError(
                f"TierVector: boundaries must start at 0, got "
                f"{self.boundaries[0]}")
        if any(b >= c for b, c in zip(self.boundaries, self.boundaries[1:])):
            raise ValueError(
                f"TierVector: boundaries must be strictly increasing, got "
                f"{self.boundaries}")

    @property
    def n_tiers(self) -> int:
        return len(self.tiers)

    def bounds(self, t: int) -> tuple[int, int]:
        """Slot range ``[lo, hi)`` of tier ``t``."""
        return self.boundaries[t], self.boundaries[t + 1]


def two_tier(cfg: GpacConfig) -> TierVector:
    """The legacy near/far split as a :class:`TierVector` (the 2-tier
    special case every existing path runs)."""
    return TierVector(
        tiers=(
            TierSpec("dram", 1.0, metrics.TIER_LATENCY_NS["dram"],
                     cost_per_gb=DEFAULT_COST["dram"]),
            TierSpec("nvmm", 1.0, metrics.TIER_LATENCY_NS["nvmm"],
                     cost_per_gb=DEFAULT_COST["nvmm"]),
        ),
        boundaries=(0, cfg.n_near, cfg.n_slots),
    )


def compressed_specs(
    near_fraction: float = 0.15,
    mid_fraction: float = 0.25,
    compression: float = 3.0,
) -> tuple[TierSpec, ...]:
    """A 3-tier DRAM / compressed-DRAM (zram) / NVMM hierarchy -- the
    canonical arXiv-2404.13886 shape the smoke script and benchmarks use.
    The middle tier stores ``mid_fraction x compression`` blocks in
    ``mid_fraction`` worth of DRAM; its latency adds a decompression charge
    on top of DRAM."""
    return (
        TierSpec("dram", near_fraction, metrics.TIER_LATENCY_NS["dram"],
                 cost_per_gb=DEFAULT_COST["dram"]),
        TierSpec("zram", mid_fraction,
                 metrics.TIER_LATENCY_NS["dram"] + 170.0,
                 compression=compression, cost_per_gb=DEFAULT_COST["zram"]),
        TierSpec("nvmm", 1.0, metrics.TIER_LATENCY_NS["nvmm"],
                 cost_per_gb=DEFAULT_COST["nvmm"]),
    )


def resolve(
    specs: tuple[TierSpec, ...], n_slots: int, total_need: int
) -> TierVector:
    """Resolve capacity fractions into slot boundaries over ``n_slots``.

    Each non-final tier gets ``int(capacity * total_need) * compression``
    effective slots (at least one), clamped so every later tier keeps at
    least one slot; the final tier absorbs the remainder. Mirrors how
    ``engine.build`` derives ``n_near`` from ``near_fraction``.
    """
    specs = tuple(specs)
    n = len(specs)
    if n < 2:
        raise ValueError(f"tier hierarchy needs >= 2 tiers, got {n}")
    if n_slots < n:
        raise ValueError(
            f"{n} tiers need at least {n} slots, got n_slots={n_slots}")
    bounds = [0]
    for t in range(n - 1):
        s = specs[t]
        eff = max(1, int(max(1, int(s.capacity * total_need)) * s.compression))
        bounds.append(min(bounds[-1] + eff, n_slots - (n - 1 - t)))
    bounds.append(n_slots)
    return TierVector(tiers=specs, boundaries=tuple(bounds))


def as_vector(cfg: GpacConfig, tiers: TierVector | None) -> TierVector:
    """``tiers`` if given, else the legacy 2-tier split."""
    return tiers if tiers is not None else two_tier(cfg)


def tier_of_slot(tv: TierVector, slots: jax.Array) -> jax.Array:
    """Tier index of each slot (int32; out-of-range sentinels land past the
    last tier -- callers mask them out)."""
    t = jnp.zeros(slots.shape, jnp.int32)
    for b in tv.boundaries[1:-1]:
        t = t + (slots >= b).astype(jnp.int32)
    return t


# --------------------------------------------------------------------------
# the flow migration primitive (tiering.swap_blocks with tier bounds)
# --------------------------------------------------------------------------
def _read_slots(cfg: GpacConfig, state: TieredState, slots, ok):
    """Gather block payloads by slot regardless of which pool holds them.
    Rows gathered under ``~ok`` are garbage; every caller scatters them to
    the drop sentinel, so they never land (the 2-tier bit-exactness relies
    on exactly this)."""
    s = jnp.where(ok, slots, 0)
    near = state.near_pool[jnp.clip(s, 0, cfg.n_near - 1)]
    far = state.far_pool[jnp.clip(s - cfg.n_near, 0, cfg.n_far - 1)]
    return jnp.where((s < cfg.n_near)[:, None, None], near, far)


def _write_slots(cfg: GpacConfig, near_pool, far_pool, slots, data, ok):
    near_row = jnp.where(ok & (slots < cfg.n_near), slots, cfg.n_near)
    far_row = jnp.where(
        ok & (slots >= cfg.n_near), slots - cfg.n_near, cfg.n_far)
    return (
        near_pool.at[near_row].set(data, mode="drop"),
        far_pool.at[far_row].set(data, mode="drop"),
    )


def swap_flow(
    cfg: GpacConfig,
    state: TieredState,
    lo_hps: jax.Array,
    hi_hps: jax.Array,
    k: jax.Array,
    hi_bounds: tuple[int, int],
    lo_bounds: tuple[int, int],
) -> TieredState:
    """Promote ``lo_hps[i]`` (lower tier) and demote ``hi_hps[i]`` (upper
    tier) for i < k -- :func:`tiering.swap_blocks` generalized to an
    adjacent tier pair. Pairs where either id is -1, i >= k, or the current
    slot is outside its claimed tier range are dropped."""
    u_lo, u_hi = hi_bounds
    d_lo, d_hi = lo_bounds
    m = lo_hps.shape[0]
    i = jnp.arange(m)
    lo_c = jnp.maximum(lo_hps, 0)
    hi_c = jnp.maximum(hi_hps, 0)
    s_lo = state.block_table[lo_c]
    s_hi = state.block_table[hi_c]
    ok = (
        (i < k)
        & (lo_hps >= 0)
        & (hi_hps >= 0)
        & (s_lo >= d_lo)
        & (s_lo < d_hi)
        & (s_hi >= u_lo)
        & (s_hi < u_hi)
    )
    data_lo = _read_slots(cfg, state, s_lo, ok)
    data_hi = _read_slots(cfg, state, s_hi, ok)
    near_pool, far_pool = _write_slots(
        cfg, state.near_pool, state.far_pool, s_hi, data_lo, ok)
    near_pool, far_pool = _write_slots(
        cfg, near_pool, far_pool, s_lo, data_hi, ok)

    bt = state.block_table
    bt = bt.at[jnp.where(ok, lo_hps, cfg.n_gpa_hp)].set(s_hi, mode="drop")
    bt = bt.at[jnp.where(ok, hi_hps, cfg.n_gpa_hp)].set(s_lo, mode="drop")
    so = state.slot_owner
    so = so.at[jnp.where(ok, s_hi, cfg.n_slots)].set(lo_c, mode="drop")
    so = so.at[jnp.where(ok, s_lo, cfg.n_slots)].set(hi_c, mode="drop")

    n_swaps = ok.sum().astype(jnp.int32)
    alloc = allocated_hp_mask(cfg, state)
    promoted = (ok & alloc[lo_c]).sum().astype(jnp.int32)
    demoted = (ok & alloc[hi_c]).sum().astype(jnp.int32)
    stats = dict(state.stats)
    stats["promoted_blocks"] = stats["promoted_blocks"] + promoted
    stats["demoted_blocks"] = stats["demoted_blocks"] + demoted
    stats["tlb_shootdowns"] = (
        stats["tlb_shootdowns"] + (n_swaps > 0).astype(jnp.int32))
    return dataclasses_replace(
        state,
        block_table=bt,
        slot_owner=so,
        near_pool=near_pool,
        far_pool=far_pool,
        stats=stats,
    )


def flow_tick(cfg, state, tiers: TierVector, pair_fn, **kw) -> TieredState:
    """Run ``pair_fn(cfg, state, upper_bounds, lower_bounds, **kw)`` over
    every adjacent tier pair, top-down (blocks move at most one tier per
    pair, so a hot block climbs one tier per tick -- HybridTier's staged
    promotion)."""
    for t in range(tiers.n_tiers - 1):
        state = pair_fn(cfg, state, tiers.bounds(t), tiers.bounds(t + 1), **kw)
    return state


# --------------------------------------------------------------------------
# the three builtin policies as adjacent-pair flows (2-tier == legacy tick,
# bit-for-bit: see module docstring)
# --------------------------------------------------------------------------
def _in_range(bt, bounds):
    lo, hi = bounds
    return (bt >= lo) & (bt < hi)


def memtierd_pair(cfg, state, u_bounds, d_bounds, budget: int = 64):
    """:func:`tiering.memtierd_tick` between one adjacent tier pair."""
    score = block_score_arrays(state.host_counts, state.host_hist)
    alloc = allocated_hp_mask(cfg, state)
    in_u = _in_range(state.block_table, u_bounds)
    in_d = _in_range(state.block_table, d_bounds)
    victim_score = jnp.where(alloc, score, NEG + 1)
    lo_ids, hi_ids, k = _paired_ids(
        alloc & in_d & (score > 0), score, in_u, victim_score, budget)
    gain = jnp.where(
        (lo_ids >= 0) & (hi_ids >= 0),
        score[jnp.maximum(lo_ids, 0)] > victim_score[jnp.maximum(hi_ids, 0)],
        False,
    )
    k = jnp.minimum(k, gain.astype(jnp.int32).cumprod().sum())
    state = swap_flow(cfg, state, lo_ids, hi_ids, k, u_bounds, d_bounds)

    alloc = allocated_hp_mask(cfg, state)
    in_u = _in_range(state.block_table, u_bounds)
    in_d = _in_range(state.block_table, d_bounds)
    score = block_score_arrays(state.host_counts, state.host_hist)
    cold_u = alloc & in_u & (score == 0)
    free_d = ~alloc & in_d
    lo_ids, hi_ids, k = _paired_ids(
        free_d, jnp.zeros_like(score), cold_u, score, budget)
    return swap_flow(cfg, state, lo_ids, hi_ids, k, u_bounds, d_bounds)


def autonuma_pair(
    cfg, state, u_bounds, d_bounds, budget: int = 16, pressure: float = 0.95
):
    """:func:`tiering.autonuma_tick` between one adjacent tier pair."""
    alloc = allocated_hp_mask(cfg, state)
    in_u = _in_range(state.block_table, u_bounds)
    in_d = _in_range(state.block_table, d_bounds)
    faulting = alloc & in_d & (state.host_counts >= 2)
    upper_used = (alloc & in_u).sum()
    pressured = upper_used >= jnp.int32(pressure * (u_bounds[1] - u_bounds[0]))
    lru = state.last_touch_epoch.astype(jnp.int32)
    victim_ok = in_u & (~alloc | pressured)
    victim_score = jnp.where(alloc, lru, NEG + 1)
    lo_ids, hi_ids, k = _paired_ids(
        faulting, state.host_counts.astype(jnp.int32), victim_ok,
        victim_score, budget)
    return swap_flow(cfg, state, lo_ids, hi_ids, k, u_bounds, d_bounds)


def tpp_pair(
    cfg, state, u_bounds, d_bounds, budget: int = 16, watermark: float = 0.1
):
    """:func:`tiering.tpp_tick` between one adjacent tier pair."""
    alloc = allocated_hp_mask(cfg, state)
    in_u = _in_range(state.block_table, u_bounds)
    in_d = _in_range(state.block_table, d_bounds)
    free_u = (in_u & ~alloc).sum()
    want_free = jnp.int32(watermark * (u_bounds[1] - u_bounds[0]))
    demand = (alloc & in_d & (state.host_counts >= 2)).sum()
    need = jnp.maximum(jnp.minimum(want_free, demand),
                       jnp.minimum(demand, budget))
    n_demote = jnp.clip(need - free_u, 0, budget)
    lru = state.last_touch_epoch.astype(jnp.int32)
    lo_free_ids, hi_cold_ids, k_d = _paired_ids(
        in_d & ~alloc, jnp.zeros_like(lru), in_u & alloc, lru, budget)
    state = swap_flow(
        cfg, state, lo_free_ids, hi_cold_ids, jnp.minimum(k_d, n_demote),
        u_bounds, d_bounds)
    alloc = allocated_hp_mask(cfg, state)
    in_u = _in_range(state.block_table, u_bounds)
    in_d = _in_range(state.block_table, d_bounds)
    faulting = alloc & in_d & (state.host_counts >= 2)
    lo_ids, hi_ids, k_p = _paired_ids(
        faulting, state.host_counts.astype(jnp.int32), in_u & ~alloc,
        jnp.zeros_like(lru), budget)
    return swap_flow(cfg, state, lo_ids, hi_ids, k_p, u_bounds, d_bounds)


_PAIR_FNS = {
    "memtierd": memtierd_pair,
    "autonuma": autonuma_pair,
    "tpp": tpp_pair,
}


# --------------------------------------------------------------------------
# per-tier pressure cascade (tiering.pressure_tick generalized)
# --------------------------------------------------------------------------
def pressure_cascade(
    cfg: GpacConfig,
    state: TieredState,
    tiers: TierVector,
    near_cap: jax.Array,
    pressure: jax.Array,
    budget: int = 64,
    slack: int = 1,
):
    """Per-tier watermark enforcement, top-down: each tier demotes into the
    one below when its allocated usage breaches its cap. Tier 0's cap is
    the injected ``near_cap`` (the churn engine's fault-shrunk capacity);
    deeper tiers enforce their physical size minus ``slack`` so a demote
    wave cascades down instead of overcommitting the middle. With a 2-tier
    vector only the tier-0 pair runs and the result is bit-identical to
    :func:`tiering.pressure_tick`. Returns ``(state, engaged0, pressure')``
    keyed on tier 0 -- the signal admission control reads.
    """
    engaged0 = None
    for t in range(tiers.n_tiers - 1):
        u_lo, u_hi = tiers.bounds(t)
        d_bounds = tiers.bounds(t + 1)
        cap = near_cap if t == 0 else jnp.int32(max(u_hi - u_lo - slack, 0))
        alloc = allocated_hp_mask(cfg, state)
        in_u = _in_range(state.block_table, (u_lo, u_hi))
        in_d = _in_range(state.block_table, d_bounds)
        usage = (alloc & in_u).sum().astype(jnp.int32)
        low = jnp.maximum(cap - slack, 0)
        engaged = usage > cap
        n_demote = jnp.where(engaged, jnp.clip(usage - low, 0, budget), 0)
        score = block_score_arrays(state.host_counts, state.host_hist)
        lo_ids, hi_ids, k = _paired_ids(
            ~alloc & in_d, jnp.zeros_like(score), alloc & in_u, score, budget)
        state = swap_flow(
            cfg, state, lo_ids, hi_ids, jnp.minimum(k, n_demote),
            (u_lo, u_hi), d_bounds)
        if t == 0:
            engaged0 = engaged
    pressure = jnp.where(engaged0, pressure + 1, 0).astype(jnp.int32)
    return state, engaged0, pressure


# --------------------------------------------------------------------------
# compressed-tier policy (arXiv 2404.13886) -- replicated + host-sharded
# --------------------------------------------------------------------------
def compressed_tick(
    cfg: GpacConfig,
    state: TieredState,
    budget: int = 64,
    tiers: TierVector | None = None,
    free_frac: float = 0.1,
) -> TieredState:
    """Demote-into-compressed placement over an N-tier vector.

    Per adjacent pair, top-down: (1) demote coldest allocated upper blocks
    into the lower tier until ``free_frac`` of the upper tier is free
    (zswap's writeback watermark -- headroom for incoming promotions);
    (2) promote identified-hot lower blocks (score > 0) over strictly
    colder upper victims. All candidate masks and scores come from the
    PRE-TICK snapshot; the swap predicates re-check the *current* slot
    range, so a block that already moved this tick simply drops out of a
    later pair -- the exact discipline the host-sharded apply uses, which
    is what keeps the two paths bit-identical.
    """
    tv = as_vector(cfg, tiers)
    score0 = block_score_arrays(state.host_counts, state.host_hist)
    alloc0 = allocated_hp_mask(cfg, state)
    bt0 = state.block_table
    vict0 = jnp.where(alloc0, score0, NEG + 1)
    zero = jnp.zeros_like(score0)
    for t in range(tv.n_tiers - 1):
        u_bounds, d_bounds = tv.bounds(t), tv.bounds(t + 1)
        in_u0 = _in_range(bt0, u_bounds)
        in_d0 = _in_range(bt0, d_bounds)
        # (1) watermark demotion: coldest allocated upper -> free lower
        free_u0 = (in_u0 & ~alloc0).sum()
        want = jnp.int32(free_frac * (u_bounds[1] - u_bounds[0]))
        n_demote = jnp.clip(want - free_u0, 0, budget)
        lo_ids, hi_ids, k = _paired_ids(
            in_d0 & ~alloc0, zero, in_u0 & alloc0, score0, budget)
        state = swap_flow(
            cfg, state, lo_ids, hi_ids, jnp.minimum(k, n_demote),
            u_bounds, d_bounds)
        # (2) promotion: identified-hot lower blocks over colder victims
        lo_ids, hi_ids, k = _paired_ids(
            alloc0 & in_d0 & (score0 > 0), score0, in_u0, vict0, budget)
        gain = jnp.where(
            (lo_ids >= 0) & (hi_ids >= 0),
            score0[jnp.maximum(lo_ids, 0)] > vict0[jnp.maximum(hi_ids, 0)],
            False,
        )
        k = jnp.minimum(k, gain.astype(jnp.int32).cumprod().sum())
        state = swap_flow(cfg, state, lo_ids, hi_ids, k, u_bounds, d_bounds)
    return state


def _compressed_prepare(
    cfg: GpacConfig, L: dict, budget: int, tiers: TierVector | None = None
) -> dict:
    tv = as_vector(cfg, tiers)
    b = _b(cfg, budget)
    kw = _cand_kw(L)
    valid = L["hp_ids"] >= 0
    score = block_score_arrays(L["hc"], L["hh"])
    alloc = L["alloc"]
    vict = jnp.where(alloc, score, NEG + 1)
    zero = jnp.zeros_like(score)
    cands, sums = {}, {}
    for t in range(tv.n_tiers - 1):
        in_u = _in_range(L["bt"], tv.bounds(t))
        in_d = _in_range(L["bt"], tv.bounds(t + 1))
        cands[f"df{t}"] = nominate(valid & in_d & ~alloc, zero, b, **kw)
        cands[f"dv{t}"] = nominate(valid & in_u & alloc, -score, b, **kw)
        cands[f"ph{t}"] = nominate(
            valid & alloc & in_d & (score > 0), score, b, **kw)
        cands[f"pv{t}"] = nominate(valid & in_u, -vict, b, **kw)
        sums[f"free{t}"] = (valid & in_u & ~alloc).sum()
    return dict(cands=cands, sums=sums)


def flow_outcome(
    cfg: GpacConfig, lo: dict, hi: dict, k: jax.Array,
    hi_bounds: tuple[int, int], lo_bounds: tuple[int, int],
):
    """:func:`tiering.swap_outcome` with tier bounds: which arbitrated
    pairs commit under :func:`swap_flow`'s predicate, plus stats deltas."""
    u_lo, u_hi = hi_bounds
    d_lo, d_hi = lo_bounds
    i = jnp.arange(lo["id"].shape[0])
    ok = (
        (i < k)
        & (lo["id"] >= 0)
        & (hi["id"] >= 0)
        & (lo["slot"] >= d_lo)
        & (lo["slot"] < d_hi)
        & (hi["slot"] >= u_lo)
        & (hi["slot"] < u_hi)
    )
    stats = dict(
        promoted_blocks=(ok & (lo["alloc"] > 0)).sum().astype(jnp.int32),
        demoted_blocks=(ok & (hi["alloc"] > 0)).sum().astype(jnp.int32),
        tlb_shootdowns=(ok.sum() > 0).astype(jnp.int32),
    )
    return ok, stats


def _compressed_apply(
    cfg: GpacConfig, L: dict, merged: dict, budget: int,
    tiers: TierVector | None = None, free_frac: float = 0.1,
):
    tv = as_vector(cfg, tiers)
    b = _b(cfg, budget)
    C = {k: _flat_cands(v) for k, v in merged["cands"].items()}
    rounds = []
    bt = L["bt"]

    def current(c):
        # chase each candidate's slot through every committed round so the
        # range predicates see the live placement, exactly like the
        # replicated tick's swap_flow reads state.block_table
        slot = c["slot"]
        for lo_r, hi_r, ok_r in rounds:
            slot = slots_after_swaps(c["id"], slot, lo_r, hi_r, ok_r)
        return {**c, "slot": slot}

    stats = dict(promoted_blocks=jnp.int32(0), demoted_blocks=jnp.int32(0),
                 tlb_shootdowns=jnp.int32(0))
    for t in range(tv.n_tiers - 1):
        u_bounds, d_bounds = tv.bounds(t), tv.bounds(t + 1)
        want = jnp.int32(free_frac * (u_bounds[1] - u_bounds[0]))
        n_demote = jnp.clip(want - merged["sums"][f"free{t}"], 0, budget)
        lo = current(rank_select(C[f"df{t}"], b))
        hi = current(rank_select(C[f"dv{t}"], b))
        ok, d = flow_outcome(
            cfg, lo, hi, jnp.minimum(_pair_k(lo, hi), n_demote),
            u_bounds, d_bounds)
        bt = apply_swaps_local(bt, L["hp_lo"], L["hp_hi"], lo, hi, ok)
        rounds.append((lo, hi, ok))
        stats = {s: stats[s] + d[s] for s in stats}

        lo = current(rank_select(C[f"ph{t}"], b))
        hi = current(rank_select(C[f"pv{t}"], b))
        gain = jnp.where(
            (lo["id"] >= 0) & (hi["id"] >= 0), lo["val"] > -hi["val"], False)
        k = jnp.minimum(
            _pair_k(lo, hi), gain.astype(jnp.int32).cumprod().sum())
        ok, d = flow_outcome(cfg, lo, hi, k, u_bounds, d_bounds)
        bt = apply_swaps_local(bt, L["hp_lo"], L["hp_hi"], lo, hi, ok)
        rounds.append((lo, hi, ok))
        stats = {s: stats[s] + d[s] for s in stats}
    return bt, stats, tuple(rounds)


# --------------------------------------------------------------------------
# HybridTier-style adaptive policy (arXiv 2312.04789) -- replicated only
# --------------------------------------------------------------------------
def hybridtier_tick(
    cfg: GpacConfig,
    state: TieredState,
    budget: int = 16,
    tiers: TierVector | None = None,
) -> TieredState:
    """Adaptive hot-threshold placement: per pair, the promotion bar is the
    mean score of the upper tier's resident blocks (a moving threshold that
    rises as the tier fills with hot data and falls as it cools --
    HybridTier's lightweight frequency-tracking, without per-page PEBS).
    Promotes lower blocks strictly above the bar over upper victims at or
    below it. No host-sharded form (run with ``host_sharded=False``)."""
    tv = as_vector(cfg, tiers)
    for t in range(tv.n_tiers - 1):
        u_bounds, d_bounds = tv.bounds(t), tv.bounds(t + 1)
        score = block_score_arrays(state.host_counts, state.host_hist)
        alloc = allocated_hp_mask(cfg, state)
        in_u = _in_range(state.block_table, u_bounds)
        in_d = _in_range(state.block_table, d_bounds)
        resident = alloc & in_u
        n_res = resident.sum().astype(jnp.int32)
        thr = (jnp.where(resident, score, 0).sum().astype(jnp.int32)
               // jnp.maximum(n_res, 1))
        vict = jnp.where(alloc, score, NEG + 1)
        lo_ids, hi_ids, k = _paired_ids(
            alloc & in_d & (score > thr), score,
            in_u & (~alloc | (score <= thr)), vict, budget)
        gain = jnp.where(
            (lo_ids >= 0) & (hi_ids >= 0),
            score[jnp.maximum(lo_ids, 0)] > vict[jnp.maximum(hi_ids, 0)],
            False,
        )
        k = jnp.minimum(k, gain.astype(jnp.int32).cumprod().sum())
        state = swap_flow(cfg, state, lo_ids, hi_ids, k, u_bounds, d_bounds)
    return state


# --------------------------------------------------------------------------
# TCO metric (priced placement + per-tier AMAT)
# --------------------------------------------------------------------------
def tier_hit_counts(tv: TierVector, slot: jax.Array, valid: jax.Array):
    """Per-tier access counts for one window's translated slots
    (int32[n_tiers]); invalid accesses count nowhere."""
    return jnp.stack([
        (valid & (slot >= lo) & (slot < hi)).sum().astype(jnp.int32)
        for lo, hi in (tv.bounds(t) for t in range(tv.n_tiers))
    ])


def tier_block_counts(tv: TierVector, bt: jax.Array, alloc: jax.Array):
    """Allocated-block count per tier from block_table rows (int32[n_tiers]);
    works on the full table or a device's local rows (padded rows carry the
    out-of-range sentinel and a False alloc bit, so they count nowhere)."""
    return jnp.stack([
        (alloc & (bt >= lo) & (bt < hi)).sum().astype(jnp.int32)
        for lo, hi in (tv.bounds(t) for t in range(tv.n_tiers))
    ])


def tier_alloc_counts(
    cfg: GpacConfig, state: TieredState, tv: TierVector
) -> jax.Array:
    return tier_block_counts(
        tv, state.block_table, allocated_hp_mask(cfg, state))


def tier_count_delta(tv: TierVector, swaps) -> jax.Array:
    """Per-tier allocated-block delta implied by arbitrated swap rounds --
    the host-sharded path's way to price the POST-tick placement from
    pre-tick counts plus the committed swaps (rides the same psum)."""
    d = jnp.zeros((tv.n_tiers,), jnp.int32)
    for lo, hi, ok in swaps:
        for side, other in ((lo, hi), (hi, lo)):
            w = (ok & (side["alloc"] > 0)).astype(jnp.int32)
            d = d.at[tier_of_slot(tv, side["slot"])].add(-w, mode="drop")
            d = d.at[tier_of_slot(tv, other["slot"])].add(w, mode="drop")
    return d


def amat_per_hit_ns(cfg: GpacConfig, s: TierSpec) -> float:
    """Per-hit AMAT cost of one tier: latency plus the base-page transfer
    time at the tier's bandwidth (1 GB/s moves one byte per ns, so
    ``base_bytes / bandwidth_gbps`` is already in ns -- a slow far tier pays
    per-byte, not just per-touch), quantized to sixteenth-ns.

    The quantization is load-bearing for bit-reproducibility, not cosmetic:
    XLA may contract ``hits * cost + acc`` into an FMA, and whether it does
    differs between compiled programs (``engine.run``'s scan vs the sharded
    drivers), so a full-mantissa fractional cost yields 1-ulp AMAT drift
    across paths. With ``cost = k / 16`` the product ``hits * cost`` and
    every fixed-order partial sum are exactly representable in float32
    (while ``hits * k < 2**24``, i.e. up to ~1M quantized ns-weighted hits
    per tier per window), and an FMA over exact operands equals the
    separate mul+add -- contraction becomes invisible."""
    return round(16.0 * (s.latency_ns + cfg.base_bytes / s.bandwidth_gbps)) / 16.0


def tco_metrics(
    cfg: GpacConfig, tv: TierVector,
    tier_blocks: jax.Array, tier_hits: jax.Array,
) -> dict:
    """The TCO objective: physical $-weighted resident GB plus the per-tier
    AMAT. ``tco = sum_t blocks_t * GB/block * cost_t / compression_t``
    (a compressed tier stores ``compression`` blocks per physical block's
    GB, so its blocks are cheap); ``amat_ns`` charges each tier's hits at
    :func:`amat_per_hit_ns` -- latency plus bandwidth-priced transfer,
    sixteenth-ns quantized so the fixed python-loop accumulation is exact
    in float32 and therefore bit-reproducible on every driver path (see
    the helper's docstring for why fixed order alone is not enough)."""
    gb_per_block = cfg.hp_bytes / float(1 << 30)
    tco = jnp.float32(0.0)
    amat = jnp.float32(0.0)
    for t in range(tv.n_tiers):
        s = tv.tiers[t]
        tco = tco + tier_blocks[t].astype(jnp.float32) * jnp.float32(
            gb_per_block * s.cost_per_gb / s.compression)
        amat = amat + tier_hits[t].astype(jnp.float32) * jnp.float32(
            amat_per_hit_ns(cfg, s))
    total = tier_hits.sum().astype(jnp.float32)
    return dict(
        tco=tco,
        amat_ns=amat / jnp.maximum(total, 1.0),
        tier_blocks=tier_blocks,
        tier_hits=tier_hits,
    )


register_policy("compressed", compressed_tick)
register_sharded_tick("compressed", _compressed_prepare, _compressed_apply)
register_policy("hybridtier", hybridtier_tick)
