"""GPAC core: guest physical address-space consolidation for memory tiering.

Public API re-exports. See DESIGN.md for the paper -> TPU mapping.
"""
from repro.core.types import (  # noqa: F401
    FREE,
    GpacConfig,
    TieredState,
    allocated_hp_mask,
    init_state,
    start_all_far,
)
from repro.core import (  # noqa: F401
    address_space,
    consolidator,
    engine,
    filter,
    gpac,
    metrics,
    sharding,
    telemetry,
    tiering,
    tiers,
)
from repro.core.engine import (  # noqa: F401
    EngineSpec,
    GuestSpec,
    HostSpec,
)
from repro.core.tiers import (  # noqa: F401
    TierSpec,
    TierVector,
)
