"""Host-side memory tiering (paper §2.3, §5) -- block-granular policies.

The host sees only huge-page-granular telemetry (``host_counts``,
``host_hist``, ``last_touch_epoch``) and moves whole blocks between the near
and far pools. GPAC never modifies anything here -- that is the paper's
host-agnosticism, and the test matrix runs every policy against the same
guest-side GPAC unchanged.

Three faithful built-in policy flavours:
  * ``memtierd`` -- proactive userspace ranking: keep the globally hottest
    blocks near, even without memory pressure (paper §5.2 uses this).
  * ``autonuma`` -- hint-fault-style promotion (>=2 touches while far) and
    demotion only under near-pool pressure, LRU victims.
  * ``tpp``      -- fault promotion with a free-page watermark: demote coldest
    blocks until a headroom fraction of near is kept free.

New placement policies plug in without editing this module:
:func:`register_policy` adds a ``fn(cfg, state, **kw) -> TieredState`` to the
registry and every ``policy=`` string (the engine driver, ``tick``, the
benchmarks) can name it (DESIGN.md §8).

Migration primitive: ``swap_blocks`` -- exchange the placement of a far block
and a near block (data + block_table + slot_owner), the functional analogue of
NUMA page migration at block granularity.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.address_space import dataclasses_replace
from repro.core.telemetry import _popcount_u8
from repro.core.types import GpacConfig, TieredState, allocated_hp_mask

# builtin names (kept for back-compat; the live set is policies())
POLICIES = ("memtierd", "autonuma", "tpp")
NEG = jnp.int32(-(2**31) + 1)

_POLICIES: dict[str, Callable] = {}


def register_policy(name: str, fn: Callable | None = None):
    """Register a host tiering policy ``fn(cfg, state, **kw) -> TieredState``
    (keyword args include at least ``budget``); usable as
    ``@register_policy("name")``. The name becomes valid everywhere a
    ``policy=`` string is accepted."""
    if fn is None:
        return lambda f: register_policy(name, f)
    if name in _POLICIES:
        raise ValueError(f"tiering policy {name!r} already registered")
    _POLICIES[name] = fn
    return fn


def policies() -> tuple[str, ...]:
    """Names of all registered tiering policies."""
    return tuple(_POLICIES)


def swap_blocks(
    cfg: GpacConfig,
    state: TieredState,
    far_hps: jax.Array,
    near_hps: jax.Array,
    k: jax.Array,
) -> TieredState:
    """Promote ``far_hps[i]`` and demote ``near_hps[i]`` for i < k.

    Pairs where either id is -1, i >= k, or tiers don't match are dropped.
    Vectorized: one gather + two drop-mode scatters per pool.
    """
    m = far_hps.shape[0]
    i = jnp.arange(m)
    fa = jnp.maximum(far_hps, 0)
    ne = jnp.maximum(near_hps, 0)
    s_far = state.block_table[fa]
    s_near = state.block_table[ne]
    ok = (
        (i < k)
        & (far_hps >= 0)
        & (near_hps >= 0)
        & (s_far >= cfg.n_near)
        & (s_near < cfg.n_near)
    )
    far_row = jnp.where(ok, s_far - cfg.n_near, cfg.n_far)
    near_row = jnp.where(ok, s_near, cfg.n_near)

    data_far = state.far_pool[jnp.where(ok, s_far - cfg.n_near, 0)]
    data_near = state.near_pool[jnp.where(ok, s_near, 0)]
    near_pool = state.near_pool.at[near_row].set(data_far, mode="drop")
    far_pool = state.far_pool.at[far_row].set(data_near, mode="drop")

    bt = state.block_table
    bt = bt.at[jnp.where(ok, far_hps, cfg.n_gpa_hp)].set(s_near, mode="drop")
    bt = bt.at[jnp.where(ok, near_hps, cfg.n_gpa_hp)].set(s_far, mode="drop")
    so = state.slot_owner
    so = so.at[jnp.where(ok, s_near, cfg.n_slots)].set(fa, mode="drop")
    so = so.at[jnp.where(ok, s_far, cfg.n_slots)].set(ne, mode="drop")

    n_swaps = ok.sum().astype(jnp.int32)
    alloc = allocated_hp_mask(cfg, state)
    promoted = (ok & alloc[fa]).sum().astype(jnp.int32)
    demoted = (ok & alloc[ne]).sum().astype(jnp.int32)
    stats = dict(state.stats)
    stats["promoted_blocks"] = stats["promoted_blocks"] + promoted
    stats["demoted_blocks"] = stats["demoted_blocks"] + demoted
    stats["tlb_shootdowns"] = stats["tlb_shootdowns"] + (n_swaps > 0).astype(jnp.int32)
    return dataclasses_replace(
        state,
        block_table=bt,
        slot_owner=so,
        near_pool=near_pool,
        far_pool=far_pool,
        stats=stats,
    )


def _block_score(cfg: GpacConfig, state: TieredState) -> jax.Array:
    """Host's only view: current-window count + access-bit history."""
    return (
        state.host_counts.astype(jnp.int32) * 256
        + _popcount_u8(state.host_hist).astype(jnp.int32)
    )


def _paired_ids(mask_a, score_a, mask_b, score_b, budget):
    """Top-``budget`` ids of a (desc score) paired with top ids of b
    (asc score); -1 padded. Returns (ids_a, ids_b, k)."""
    budget = min(budget, mask_a.shape[0])
    sa = jnp.where(mask_a, score_a, NEG)
    sb = jnp.where(mask_b, -score_b, NEG)
    va, ia = jax.lax.top_k(sa, budget)
    vb, ib = jax.lax.top_k(sb, budget)
    ids_a = jnp.where(va > NEG, ia.astype(jnp.int32), -1)
    ids_b = jnp.where(vb > NEG, ib.astype(jnp.int32), -1)
    k = jnp.minimum((ids_a >= 0).sum(), (ids_b >= 0).sum())
    return ids_a, ids_b, k


# --------------------------------------------------------------------------
# policies
# --------------------------------------------------------------------------
def memtierd_tick(cfg: GpacConfig, state: TieredState, budget: int = 64) -> TieredState:
    """Proactive ranking: the hottest allocated blocks belong near.

    Promote the hottest far blocks whose score beats the coldest near blocks
    (swap pairs), up to ``budget`` migrations per tick.
    """
    score = _block_score(cfg, state)
    alloc = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    # promotion candidates: *identified hot* far blocks only (score > 0) --
    # Memtierd migrates hot pages, it does not prefetch cold data near.
    # victims: near blocks, coldest first (unallocated near blocks score NEG+1
    # so they are always preferred victims)
    victim_score = jnp.where(alloc, score, NEG + 1)
    far_ids, near_ids, k = _paired_ids(
        alloc & ~in_near & (score > 0), score, in_near, victim_score, budget
    )
    # only swap pairs that strictly improve: promote score > victim score
    gain = jnp.where(
        (far_ids >= 0) & (near_ids >= 0),
        score[jnp.maximum(far_ids, 0)] > victim_score[jnp.maximum(near_ids, 0)],
        False,
    )
    # pairs are sorted best-first, so the improving prefix is contiguous
    k = jnp.minimum(k, gain.astype(jnp.int32).cumprod().sum())
    state = swap_blocks(cfg, state, far_ids, near_ids, k)

    # proactive demotion: cold allocated near blocks move out into free far
    # blocks even with no promotion pressure (Memtierd relocates cold data).
    alloc = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    score = _block_score(cfg, state)
    cold_near = alloc & in_near & (score == 0)
    free_far = ~alloc & ~in_near
    far_ids, near_ids, k = _paired_ids(
        free_far, jnp.zeros_like(score), cold_near, score, budget
    )
    return swap_blocks(cfg, state, far_ids, near_ids, k)


def autonuma_tick(
    cfg: GpacConfig,
    state: TieredState,
    budget: int = 16,
    pressure: float = 0.95,
) -> TieredState:
    """Hint-fault promotion; demote only under pressure (LRU victims)."""
    alloc = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    faulting = alloc & ~in_near & (state.host_counts >= 2)
    near_used = (alloc & in_near).sum()
    pressured = near_used >= jnp.int32(pressure * cfg.n_near)
    # victims: free near blocks always; allocated LRU blocks only if pressured
    lru = state.last_touch_epoch.astype(jnp.int32)
    victim_ok = in_near & (~alloc | pressured)
    victim_score = jnp.where(alloc, lru, NEG + 1)  # free blocks first, then LRU
    far_ids, near_ids, k = _paired_ids(
        faulting, state.host_counts.astype(jnp.int32), victim_ok, victim_score, budget
    )
    return swap_blocks(cfg, state, far_ids, near_ids, k)


def tpp_tick(
    cfg: GpacConfig,
    state: TieredState,
    budget: int = 16,
    watermark: float = 0.1,
) -> TieredState:
    """Fault promotion + watermark demotion under allocation pressure
    (TPP's two loops).

    1. if promotion demand exists, demote coldest allocated near blocks into
       free far blocks until >= watermark * n_near near blocks are free --
       demotion only runs under pressure (faulting blocks waiting), like
       TPP's wmark_demote path;
    2. promote blocks with >=2 faults this window into the freed space.
    """
    alloc = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    free_near = (in_near & ~alloc).sum()
    want_free = jnp.int32(watermark * cfg.n_near)
    demand = (alloc & ~in_near & (state.host_counts >= 2)).sum()
    # demotion keeps the free watermark AND keeps up with promotion demand
    # (TPP's wmark_demote runs ahead of the promotion path) -- but only under
    # pressure: with no faulting pages, nothing is demoted.
    need = jnp.maximum(jnp.minimum(want_free, demand),
                       jnp.minimum(demand, budget))
    n_demote = jnp.clip(need - free_near, 0, budget)
    lru = state.last_touch_epoch.astype(jnp.int32)
    # demotion: coldest allocated near <-> unallocated far
    far_free_ids, near_cold_ids, k_d = _paired_ids(
        ~in_near & ~alloc,
        jnp.zeros_like(lru),
        in_near & alloc,
        lru,
        budget,
    )
    state = swap_blocks(cfg, state, far_free_ids, near_cold_ids, jnp.minimum(k_d, n_demote))
    # promotion: 2-fault blocks <-> free near
    alloc = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    faulting = alloc & ~in_near & (state.host_counts >= 2)
    far_ids, near_ids, k_p = _paired_ids(
        faulting,
        state.host_counts.astype(jnp.int32),
        in_near & ~alloc,
        jnp.zeros_like(lru),
        budget,
    )
    return swap_blocks(cfg, state, far_ids, near_ids, k_p)


register_policy("memtierd", memtierd_tick)
register_policy("autonuma", autonuma_tick)
register_policy("tpp", tpp_tick)


def tick(cfg: GpacConfig, state: TieredState, policy: str, **kw) -> TieredState:
    """Dispatch to a registered host tiering policy by name."""
    try:
        fn = _POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown tiering policy {policy!r} (have {policies()})"
        ) from None
    return fn(cfg, state, **kw)
