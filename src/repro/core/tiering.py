"""Host-side memory tiering (paper §2.3, §5) -- block-granular policies.

The host sees only huge-page-granular telemetry (``host_counts``,
``host_hist``, ``last_touch_epoch``) and moves whole blocks between the near
and far pools. GPAC never modifies anything here -- that is the paper's
host-agnosticism, and the test matrix runs every policy against the same
guest-side GPAC unchanged.

Three faithful built-in policy flavours:
  * ``memtierd`` -- proactive userspace ranking: keep the globally hottest
    blocks near, even without memory pressure (paper §5.2 uses this).
  * ``autonuma`` -- hint-fault-style promotion (>=2 touches while far) and
    demotion only under near-pool pressure, LRU victims.
  * ``tpp``      -- fault promotion with a free-page watermark: demote coldest
    blocks until a headroom fraction of near is kept free.

New placement policies plug in without editing this module:
:func:`register_policy` adds a ``fn(cfg, state, **kw) -> TieredState`` to the
registry and every ``policy=`` string (the engine driver, ``tick``, the
benchmarks) can name it (DESIGN.md §8).

Migration primitive: ``swap_blocks`` -- exchange the placement of a far block
and a near block (data + block_table + slot_owner), the functional analogue of
NUMA page migration at block granularity.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.address_space import dataclasses_replace
from repro.core.telemetry import _popcount_u8
from repro.core.types import GpacConfig, TieredState, allocated_hp_mask

# builtin names (kept for back-compat; the live set is policies())
POLICIES = ("memtierd", "autonuma", "tpp")
NEG = jnp.int32(-(2**31) + 1)

_POLICIES: dict[str, Callable] = {}


def register_policy(name: str, fn: Callable | None = None):
    """Register a host tiering policy ``fn(cfg, state, **kw) -> TieredState``
    (keyword args include at least ``budget``); usable as
    ``@register_policy("name")``. The name becomes valid everywhere a
    ``policy=`` string is accepted."""
    if fn is None:
        return lambda f: register_policy(name, f)
    if name in _POLICIES:
        raise ValueError(f"tiering policy {name!r} already registered")
    _POLICIES[name] = fn
    return fn


def policies() -> tuple[str, ...]:
    """Names of all registered tiering policies."""
    return tuple(_POLICIES)


def swap_blocks(
    cfg: GpacConfig,
    state: TieredState,
    far_hps: jax.Array,
    near_hps: jax.Array,
    k: jax.Array,
) -> TieredState:
    """Promote ``far_hps[i]`` and demote ``near_hps[i]`` for i < k.

    Pairs where either id is -1, i >= k, or tiers don't match are dropped.
    Vectorized: one gather + two drop-mode scatters per pool.
    """
    m = far_hps.shape[0]
    i = jnp.arange(m)
    fa = jnp.maximum(far_hps, 0)
    ne = jnp.maximum(near_hps, 0)
    s_far = state.block_table[fa]
    s_near = state.block_table[ne]
    ok = (
        (i < k)
        & (far_hps >= 0)
        & (near_hps >= 0)
        & (s_far >= cfg.n_near)
        & (s_near < cfg.n_near)
    )
    far_row = jnp.where(ok, s_far - cfg.n_near, cfg.n_far)
    near_row = jnp.where(ok, s_near, cfg.n_near)

    data_far = state.far_pool[jnp.where(ok, s_far - cfg.n_near, 0)]
    data_near = state.near_pool[jnp.where(ok, s_near, 0)]
    near_pool = state.near_pool.at[near_row].set(data_far, mode="drop")
    far_pool = state.far_pool.at[far_row].set(data_near, mode="drop")

    bt = state.block_table
    bt = bt.at[jnp.where(ok, far_hps, cfg.n_gpa_hp)].set(s_near, mode="drop")
    bt = bt.at[jnp.where(ok, near_hps, cfg.n_gpa_hp)].set(s_far, mode="drop")
    so = state.slot_owner
    so = so.at[jnp.where(ok, s_near, cfg.n_slots)].set(fa, mode="drop")
    so = so.at[jnp.where(ok, s_far, cfg.n_slots)].set(ne, mode="drop")

    n_swaps = ok.sum().astype(jnp.int32)
    alloc = allocated_hp_mask(cfg, state)
    promoted = (ok & alloc[fa]).sum().astype(jnp.int32)
    demoted = (ok & alloc[ne]).sum().astype(jnp.int32)
    stats = dict(state.stats)
    stats["promoted_blocks"] = stats["promoted_blocks"] + promoted
    stats["demoted_blocks"] = stats["demoted_blocks"] + demoted
    stats["tlb_shootdowns"] = stats["tlb_shootdowns"] + (n_swaps > 0).astype(jnp.int32)
    return dataclasses_replace(
        state,
        block_table=bt,
        slot_owner=so,
        near_pool=near_pool,
        far_pool=far_pool,
        stats=stats,
    )


def block_score_arrays(host_counts: jax.Array, host_hist: jax.Array) -> jax.Array:
    """The host block score from its raw telemetry arrays (shared by the
    replicated tick and the host-partitioned tick, which scores only a
    device's local block range)."""
    return (
        host_counts.astype(jnp.int32) * 256
        + _popcount_u8(host_hist).astype(jnp.int32)
    )


def _block_score(cfg: GpacConfig, state: TieredState) -> jax.Array:
    """Host's only view: current-window count + access-bit history."""
    return block_score_arrays(state.host_counts, state.host_hist)


def _paired_ids(mask_a, score_a, mask_b, score_b, budget):
    """Top-``budget`` ids of a (desc score) paired with top ids of b
    (asc score); -1 padded. Returns (ids_a, ids_b, k)."""
    budget = min(budget, mask_a.shape[0])
    sa = jnp.where(mask_a, score_a, NEG)
    sb = jnp.where(mask_b, -score_b, NEG)
    va, ia = jax.lax.top_k(sa, budget)
    vb, ib = jax.lax.top_k(sb, budget)
    ids_a = jnp.where(va > NEG, ia.astype(jnp.int32), -1)
    ids_b = jnp.where(vb > NEG, ib.astype(jnp.int32), -1)
    k = jnp.minimum((ids_a >= 0).sum(), (ids_b >= 0).sum())
    return ids_a, ids_b, k


# --------------------------------------------------------------------------
# policies
# --------------------------------------------------------------------------
def _flow(cfg, state, tiers, pair_name, **kw):
    """Dispatch a builtin policy over an N-tier vector as adjacent-pair
    flows (``core.tiers``); the 2-tier vector is pinned bit-for-bit against
    the legacy body below (INV-TIER-2SPECIALCASE-EXACT)."""
    from repro.core import tiers as tiers_mod

    return tiers_mod.flow_tick(
        cfg, state, tiers, tiers_mod._PAIR_FNS[pair_name], **kw)


def memtierd_tick(
    cfg: GpacConfig, state: TieredState, budget: int = 64, tiers=None
) -> TieredState:
    """Proactive ranking: the hottest allocated blocks belong near.

    Promote the hottest far blocks whose score beats the coldest near blocks
    (swap pairs), up to ``budget`` migrations per tick. With an N-tier
    ``tiers`` vector, runs as adjacent-pair flows instead.
    """
    if tiers is not None:
        return _flow(cfg, state, tiers, "memtierd", budget=budget)
    score = _block_score(cfg, state)
    alloc = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    # promotion candidates: *identified hot* far blocks only (score > 0) --
    # Memtierd migrates hot pages, it does not prefetch cold data near.
    # victims: near blocks, coldest first (unallocated near blocks score NEG+1
    # so they are always preferred victims)
    victim_score = jnp.where(alloc, score, NEG + 1)
    far_ids, near_ids, k = _paired_ids(
        alloc & ~in_near & (score > 0), score, in_near, victim_score, budget
    )
    # only swap pairs that strictly improve: promote score > victim score
    gain = jnp.where(
        (far_ids >= 0) & (near_ids >= 0),
        score[jnp.maximum(far_ids, 0)] > victim_score[jnp.maximum(near_ids, 0)],
        False,
    )
    # pairs are sorted best-first, so the improving prefix is contiguous
    k = jnp.minimum(k, gain.astype(jnp.int32).cumprod().sum())
    state = swap_blocks(cfg, state, far_ids, near_ids, k)

    # proactive demotion: cold allocated near blocks move out into free far
    # blocks even with no promotion pressure (Memtierd relocates cold data).
    alloc = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    score = _block_score(cfg, state)
    cold_near = alloc & in_near & (score == 0)
    free_far = ~alloc & ~in_near
    far_ids, near_ids, k = _paired_ids(
        free_far, jnp.zeros_like(score), cold_near, score, budget
    )
    return swap_blocks(cfg, state, far_ids, near_ids, k)


def autonuma_tick(
    cfg: GpacConfig,
    state: TieredState,
    budget: int = 16,
    pressure: float = 0.95,
    tiers=None,
) -> TieredState:
    """Hint-fault promotion; demote only under pressure (LRU victims)."""
    if tiers is not None:
        return _flow(cfg, state, tiers, "autonuma", budget=budget,
                     pressure=pressure)
    alloc = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    faulting = alloc & ~in_near & (state.host_counts >= 2)
    near_used = (alloc & in_near).sum()
    pressured = near_used >= jnp.int32(pressure * cfg.n_near)
    # victims: free near blocks always; allocated LRU blocks only if pressured
    lru = state.last_touch_epoch.astype(jnp.int32)
    victim_ok = in_near & (~alloc | pressured)
    victim_score = jnp.where(alloc, lru, NEG + 1)  # free blocks first, then LRU
    far_ids, near_ids, k = _paired_ids(
        faulting, state.host_counts.astype(jnp.int32), victim_ok, victim_score, budget
    )
    return swap_blocks(cfg, state, far_ids, near_ids, k)


def tpp_tick(
    cfg: GpacConfig,
    state: TieredState,
    budget: int = 16,
    watermark: float = 0.1,
    tiers=None,
) -> TieredState:
    """Fault promotion + watermark demotion under allocation pressure
    (TPP's two loops).

    1. if promotion demand exists, demote coldest allocated near blocks into
       free far blocks until >= watermark * n_near near blocks are free --
       demotion only runs under pressure (faulting blocks waiting), like
       TPP's wmark_demote path;
    2. promote blocks with >=2 faults this window into the freed space.
    """
    if tiers is not None:
        return _flow(cfg, state, tiers, "tpp", budget=budget,
                     watermark=watermark)
    alloc = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    free_near = (in_near & ~alloc).sum()
    want_free = jnp.int32(watermark * cfg.n_near)
    demand = (alloc & ~in_near & (state.host_counts >= 2)).sum()
    # demotion keeps the free watermark AND keeps up with promotion demand
    # (TPP's wmark_demote runs ahead of the promotion path) -- but only under
    # pressure: with no faulting pages, nothing is demoted.
    need = jnp.maximum(jnp.minimum(want_free, demand),
                       jnp.minimum(demand, budget))
    n_demote = jnp.clip(need - free_near, 0, budget)
    lru = state.last_touch_epoch.astype(jnp.int32)
    # demotion: coldest allocated near <-> unallocated far
    far_free_ids, near_cold_ids, k_d = _paired_ids(
        ~in_near & ~alloc,
        jnp.zeros_like(lru),
        in_near & alloc,
        lru,
        budget,
    )
    state = swap_blocks(cfg, state, far_free_ids, near_cold_ids, jnp.minimum(k_d, n_demote))
    # promotion: 2-fault blocks <-> free near
    alloc = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    faulting = alloc & ~in_near & (state.host_counts >= 2)
    far_ids, near_ids, k_p = _paired_ids(
        faulting,
        state.host_counts.astype(jnp.int32),
        in_near & ~alloc,
        jnp.zeros_like(lru),
        budget,
    )
    return swap_blocks(cfg, state, far_ids, near_ids, k_p)


register_policy("memtierd", memtierd_tick)
register_policy("autonuma", autonuma_tick)
register_policy("tpp", tpp_tick)


def tick(
    cfg: GpacConfig, state: TieredState, policy: str, tiers=None, **kw
) -> TieredState:
    """Dispatch to a registered host tiering policy by name. ``tiers`` (a
    ``core.tiers.TierVector``) is forwarded only when set, so policies
    registered before the tier subsystem keep their signatures."""
    try:
        fn = _POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown tiering policy {policy!r} (have {policies()})"
        ) from None
    if tiers is not None:
        kw["tiers"] = tiers
    return fn(cfg, state, **kw)


def strided_tick(
    cfg: GpacConfig, state: TieredState, policy: str, *, stride: int,
    budget: int, tiers=None,
) -> TieredState:
    """:func:`tick`, gated by ``EngineSpec.arbitration_stride``: the
    arbitration runs only on windows whose post-window telemetry epoch is a
    multiple of ``stride`` (``(state.epoch + 1) % stride == 0`` at tick
    time, so the gate is chunking- and resume-invariant -- the epoch rides
    the carry). ``stride=1`` is a static branch compiling to exactly
    :func:`tick`, keeping the default path's program unchanged. The skipped
    branch is the identity, so telemetry keeps accumulating across the
    stride and the batched tick arbitrates on the longer history (DESIGN.md
    §17)."""
    if stride == 1:
        return tick(cfg, state, policy, budget=budget, tiers=tiers)
    return jax.lax.cond(
        (state.epoch + 1) % stride == 0,
        lambda s: tick(cfg, s, policy, budget=budget, tiers=tiers),
        lambda s: s,
        state,
    )


# --------------------------------------------------------------------------
# near-memory pressure controller (graceful degradation under churn/shrink)
# --------------------------------------------------------------------------
def pressure_tick(
    cfg: GpacConfig,
    state: TieredState,
    near_cap: jax.Array,  # int32[] effective near capacity (<= n_near)
    engaged: jax.Array,  # bool[]  hysteresis latch carried between windows
    pressure: jax.Array,  # int32[] consecutive engaged windows (backoff signal)
    budget: int = 64,
    slack: int = 1,
    tiers=None,
) -> tuple[TieredState, jax.Array, jax.Array]:
    """Enforce an injected effective near capacity with two watermarks.

    Runs after the policy tick (which only knows the physical ``n_near``):
    when allocated near usage breaches the **high watermark** ``near_cap``
    (a fault-injected shrink, or churn overcommitting the near tier) the
    controller engages and demotes coldest-first -- allocated near blocks
    paired with unallocated far blocks -- down to the **low watermark**
    ``near_cap - slack``, up to ``budget`` blocks per window (TPP's
    ``wmark_demote`` shape: reclaiming past the trigger point by ``slack``
    keeps small fluctuations from re-breaching every window). The previous
    window's ``engaged`` only feeds observability; engagement re-evaluates
    from this window's usage, so a capacity grow-back disengages
    immediately instead of latching into a demote/promote flap against the
    policy tick.

    Returns ``(state, engaged', pressure')``: ``pressure`` counts
    consecutive engaged windows -- the backoff signal the serving layer's
    admission control reads (``serve.scheduler.AdmissionQueue``). It keeps
    growing while demand exceeds the effective capacity: either the policy
    tick re-promotes a working set bigger than ``near_cap`` every window,
    or no free far block exists to demote into (the fleet genuinely
    overcommits the far tier) -- both are exactly the conditions under
    which admission should back off. The controller never promotes and
    never exceeds the physical ``n_near``, so with ``near_cap == n_near``
    (no fault injected) usage can never breach the cap and the whole
    function is a value-exact no-op (INV-CHURN-NOOP-EXACT relies on this).

    With an N-tier ``tiers`` vector the controller becomes a per-tier
    cascade (``core.tiers.pressure_cascade``): every tier enforces its own
    watermark by demoting into the tier below, and the returned
    ``engaged``/``pressure`` track tier 0 (the admission signal).
    """
    del engaged  # previous-window breach: carried for observers, not logic
    if tiers is not None:
        from repro.core import tiers as tiers_mod

        return tiers_mod.pressure_cascade(
            cfg, state, tiers, near_cap, pressure, budget=budget, slack=slack)
    alloc = allocated_hp_mask(cfg, state)
    in_near = state.block_table < cfg.n_near
    usage = (alloc & in_near).sum().astype(jnp.int32)
    low = jnp.maximum(near_cap - slack, 0)
    engaged = usage > near_cap
    n_demote = jnp.where(engaged, jnp.clip(usage - low, 0, budget), 0)
    score = _block_score(cfg, state)
    far_ids, near_ids, k = _paired_ids(
        ~alloc & ~in_near, jnp.zeros_like(score), alloc & in_near, score,
        budget,
    )
    state = swap_blocks(cfg, state, far_ids, near_ids,
                        jnp.minimum(k, n_demote))
    pressure = jnp.where(engaged, pressure + 1, 0).astype(jnp.int32)
    return state, engaged, pressure


# ==========================================================================
# host-partitioned tick (DESIGN.md §11)
#
# Each device holds one contiguous block range of the host state and runs the
# promotion/demotion *scoring* only over it; one global arbitration round per
# window -- a psum'd exchange of per-partition candidate sets plus a few
# scalar sums -- resolves cross-partition near-memory contention bit-for-bit
# against the replicated tick. A sharded tick is a (prepare, apply) pair:
#
#   prepare(cfg, L, budget) -> {"cands": {name: candidate dict}, "sums": {..}}
#       runs pre-collective on the local block range, nominating per-side
#       top-`budget` candidate sets (Nimble-style: placement decisions are
#       local, reconciliation is a small global exchange).
#   apply(cfg, L, merged, budget) -> (block_table', stats_delta, swaps)
#       runs post-collective: arbitrates the merged candidate sets with the
#       exact (score desc, block id asc) order `jax.lax.top_k` would give on
#       the full array, then writes the winning swaps into this device's own
#       block-table rows. `stats_delta` is replicated (the engine adds it on
#       one device only); `swaps` is the arbitrated ((far, near, ok), ...)
#       per-round tuple the collectors use to update per-guest block counts.
#
# `L` is the local-range context: {"hp_ids": int32[H] global block ids (-1
# padded), "hp_lo"/"hp_hi": this device's contiguous range, "bt": local
# block_table rows, "hc"/"hh"/"lt": local host telemetry, "alloc": bool[H]}.
#
# Bit-for-bit argument: the global top-k of any score contains at most k
# entries from one partition, so per-partition top-k nominations cover it;
# `rank_select` then reproduces top_k's tie order exactly because within a
# partition top_k breaks ties by ascending local row == ascending block id,
# and the pairwise rank uses (score desc, id asc) explicitly. Policies with
# two rounds (memtierd, tpp) nominate round-2 candidates pessimistically
# pre-swap; every block whose tier round 1 changed is itself an arbitrated
# candidate, so round-2 masks are recomputed replicated via
# `slots_after_swaps` -- still one collective per window.
# ==========================================================================
def _b(cfg: GpacConfig, budget: int) -> int:
    """Effective per-side budget (matches ``_paired_ids``'s shape clamp)."""
    return min(budget, cfg.n_gpa_hp)


def _check_two_tier(cfg: GpacConfig, tiers) -> None:
    """The builtin sharded ticks arbitrate exactly one near/far pair: they
    accept a tier vector only when it IS the legacy 2-tier split (the
    ``compressed`` policy in ``core.tiers`` handles N > 2 on this path)."""
    if tiers is None or tiers.boundaries == (0, cfg.n_near, cfg.n_slots):
        return
    raise ValueError(
        f"builtin sharded ticks support only the 2-tier split "
        f"(0, {cfg.n_near}, {cfg.n_slots}); got boundaries "
        f"{tiers.boundaries} -- use policy='compressed' or "
        f"host_sharded=False")


def _cand_kw(L: dict) -> dict:
    return dict(
        hp_ids=L["hp_ids"], slot=L["bt"],
        alloc=L["alloc"].astype(jnp.int32), cnt=L["hc"].astype(jnp.int32),
    )


def nominate(
    mask: jax.Array, val: jax.Array, b: int,
    *, hp_ids: jax.Array, slot: jax.Array, alloc: jax.Array, cnt: jax.Array,
) -> dict:
    """Local top-``b`` candidate nomination over this device's block range.

    Returns int32[b] fields: ``val`` (NEG past the valid tail), ``id``
    (global block id, -1 padded), and the per-candidate metadata the
    arbitration needs -- current ``slot``, ``alloc`` bit and raw ``cnt``
    (host_counts). Local rows are in ascending-block-id order, so top_k's
    tie-break by lowest row preserves the replicated tick's id order.
    """
    k = min(b, mask.shape[0])
    v, i = jax.lax.top_k(jnp.where(mask & (hp_ids >= 0), val, NEG), k)
    ok = v > NEG
    out = dict(
        val=v,
        id=jnp.where(ok, hp_ids[i], -1),
        slot=jnp.where(ok, slot[i], 0),
        alloc=jnp.where(ok, alloc[i], 0),
        cnt=jnp.where(ok, cnt[i], 0),
    )
    if k < b:
        fill = dict(val=NEG, id=-1, slot=0, alloc=0, cnt=0)
        out = {
            f: jnp.concatenate(
                [x, jnp.full((b - k,), fill[f], jnp.int32)]
            ) for f, x in out.items()
        }
    return out


def _flat_cands(c: dict) -> dict:
    """Merged candidate blocks ``[n_shards, b]`` -> one flat candidate set."""
    return {f: x.reshape(-1) for f, x in c.items()}


def _concat_cands(a: dict, b: dict) -> dict:
    return {f: jnp.concatenate([a[f], b[f]]) for f in a}


def rank_select(c: dict, b: int) -> dict:
    """Arbitrate a merged candidate set: the top-``b`` by (val desc, id asc).

    Reproduces ``jax.lax.top_k`` over the full per-block array bit-for-bit
    (top_k breaks ties by lowest index == lowest block id) provided the
    candidate ids are unique and the set covers the global top-``b`` --
    which per-partition top-``b`` nominations guarantee. Output slot ``j``
    holds the rank-``j`` candidate; invalid tail is (NEG, -1, 0, 0, 0).
    """
    val, cid = c["val"], c["id"]
    valid = (val > NEG) & (cid >= 0)
    beats = valid[None, :] & (
        (val[None, :] > val[:, None])
        | ((val[None, :] == val[:, None]) & (cid[None, :] < cid[:, None]))
    )
    rank = beats.sum(axis=1)
    pos = jnp.where(valid & (rank < b), rank, b)
    fill = dict(val=NEG, id=-1, slot=0, alloc=0, cnt=0)
    return {
        f: jnp.full((b,), fill[f], jnp.int32).at[pos].set(x, mode="drop")
        for f, x in c.items()
    }


def _pair_k(far: dict, near: dict) -> jax.Array:
    return jnp.minimum((far["id"] >= 0).sum(), (near["id"] >= 0).sum())


def swap_outcome(cfg: GpacConfig, far: dict, near: dict, k: jax.Array):
    """Replicated outcome of one arbitrated swap round: which pairs commit
    (same predicate as :func:`swap_blocks`) and the stats deltas."""
    i = jnp.arange(far["id"].shape[0])
    ok = (
        (i < k)
        & (far["id"] >= 0)
        & (near["id"] >= 0)
        & (far["slot"] >= cfg.n_near)
        & (near["slot"] < cfg.n_near)
    )
    stats = dict(
        promoted_blocks=(ok & (far["alloc"] > 0)).sum().astype(jnp.int32),
        demoted_blocks=(ok & (near["alloc"] > 0)).sum().astype(jnp.int32),
        tlb_shootdowns=(ok.sum() > 0).astype(jnp.int32),
    )
    return ok, stats


def slots_after_swaps(
    ids: jax.Array, slots: jax.Array, far: dict, near: dict, ok: jax.Array
) -> jax.Array:
    """Current slot of each candidate after a committed swap round (the
    replicated slot ledger two-round policies consult for round 2)."""
    fa = jnp.where(ok, far["id"], -2)
    ne = jnp.where(ok, near["id"], -2)
    mf = ids[:, None] == fa[None, :]
    mn = ids[:, None] == ne[None, :]
    out = jnp.where(mf.any(axis=1), (mf * near["slot"][None, :]).sum(axis=1), slots)
    return jnp.where(mn.any(axis=1), (mn * far["slot"][None, :]).sum(axis=1), out)


def apply_swaps_local(
    bt: jax.Array, hp_lo: jax.Array, hp_hi: jax.Array,
    far: dict, near: dict, ok: jax.Array,
) -> jax.Array:
    """Write an arbitrated swap round into this device's block-table rows.

    Only the slot labels move -- in the hp-owned payload layout the data
    already lives with its huge page, so cross-partition migration is free.
    """
    drop = bt.shape[0]

    def upd(bt, ids, new_slot):
        row = jnp.where(ok & (ids >= hp_lo) & (ids < hp_hi), ids - hp_lo, drop)
        return bt.at[row].set(new_slot, mode="drop")

    return upd(upd(bt, far["id"], near["slot"]), near["id"], far["slot"])


# --------------------------------------------------------------------------
# per-policy (prepare, apply) pairs
# --------------------------------------------------------------------------
def _memtierd_prepare(cfg: GpacConfig, L: dict, budget: int, tiers=None) -> dict:
    _check_two_tier(cfg, tiers)
    b = _b(cfg, budget)
    kw = _cand_kw(L)
    valid = L["hp_ids"] >= 0
    score = block_score_arrays(L["hc"], L["hh"])
    alloc, in_near = L["alloc"], L["bt"] < cfg.n_near
    victim = jnp.where(alloc, score, NEG + 1)
    zero = jnp.zeros_like(score)
    return dict(cands=dict(
        hot_far=nominate(valid & alloc & ~in_near & (score > 0), score, b, **kw),
        victim=nominate(valid & in_near, -victim, b, **kw),
        free_far=nominate(valid & ~alloc & ~in_near, zero, b, **kw),
        # round 1 can demote up to b cold blocks out of the near tier, so
        # nominate 2b to keep covering the post-swap global top-b
        cold_near=nominate(valid & alloc & in_near & (score == 0), zero, 2 * b, **kw),
    ), sums=dict())


def _memtierd_apply(
    cfg: GpacConfig, L: dict, merged: dict, budget: int, tiers=None
):
    _check_two_tier(cfg, tiers)
    b = _b(cfg, budget)
    C = {k: _flat_cands(v) for k, v in merged["cands"].items()}
    # round 1: hottest far vs coldest near, only strictly-improving pairs
    far = rank_select(C["hot_far"], b)
    near = rank_select(C["victim"], b)
    gain = jnp.where(
        (far["id"] >= 0) & (near["id"] >= 0), far["val"] > -near["val"], False
    )
    k = jnp.minimum(_pair_k(far, near), gain.astype(jnp.int32).cumprod().sum())
    ok1, d1 = swap_outcome(cfg, far, near, k)
    bt = apply_swaps_local(L["bt"], L["hp_lo"], L["hp_hi"], far, near, ok1)

    # round 2: proactive demotion of cold near blocks into free far blocks,
    # masks recomputed on the post-round-1 placement via the slot ledger
    def after(c):
        return {**c, "slot": slots_after_swaps(c["id"], c["slot"], far, near, ok1)}

    A2 = _concat_cands(after(C["free_far"]), after(near))
    A2 = {**A2, "val": jnp.where(
        (A2["id"] >= 0) & (A2["alloc"] == 0) & (A2["slot"] >= cfg.n_near), 0, NEG
    )}
    cn = after(C["cold_near"])
    B2 = {**cn, "val": jnp.where(
        (cn["id"] >= 0) & (cn["alloc"] > 0) & (cn["slot"] < cfg.n_near), 0, NEG
    )}
    far2 = rank_select(A2, b)
    near2 = rank_select(B2, b)
    ok2, d2 = swap_outcome(cfg, far2, near2, _pair_k(far2, near2))
    bt = apply_swaps_local(bt, L["hp_lo"], L["hp_hi"], far2, near2, ok2)
    return bt, {s: d1[s] + d2[s] for s in d1}, ((far, near, ok1), (far2, near2, ok2))


def _autonuma_prepare(cfg: GpacConfig, L: dict, budget: int, tiers=None) -> dict:
    _check_two_tier(cfg, tiers)
    b = _b(cfg, budget)
    kw = _cand_kw(L)
    valid = L["hp_ids"] >= 0
    alloc, in_near = L["alloc"], L["bt"] < cfg.n_near
    cnt = L["hc"].astype(jnp.int32)
    victim = jnp.where(alloc, L["lt"].astype(jnp.int32), NEG + 1)
    return dict(cands=dict(
        fault=nominate(valid & alloc & ~in_near & (cnt >= 2), cnt, b, **kw),
        # nominate under the pressured superset mask (free-near victims sort
        # first either way); `apply` re-filters once `pressured` is known
        victim=nominate(valid & in_near, -victim, b, **kw),
    ), sums=dict(near_used=(valid & alloc & in_near).sum()))


def _autonuma_apply(
    cfg: GpacConfig, L: dict, merged: dict, budget: int,
    pressure: float = 0.95, tiers=None,
):
    _check_two_tier(cfg, tiers)
    b = _b(cfg, budget)
    C = {k: _flat_cands(v) for k, v in merged["cands"].items()}
    pressured = merged["sums"]["near_used"] >= jnp.int32(pressure * cfg.n_near)
    far = rank_select(C["fault"], b)
    vic = C["victim"]
    vv = jnp.where(
        (vic["id"] >= 0) & ((vic["alloc"] == 0) | pressured), vic["val"], NEG
    )
    near = rank_select({**vic, "val": vv}, b)
    ok, d = swap_outcome(cfg, far, near, _pair_k(far, near))
    bt = apply_swaps_local(L["bt"], L["hp_lo"], L["hp_hi"], far, near, ok)
    return bt, d, ((far, near, ok),)


def _tpp_prepare(cfg: GpacConfig, L: dict, budget: int, tiers=None) -> dict:
    _check_two_tier(cfg, tiers)
    b = _b(cfg, budget)
    kw = _cand_kw(L)
    valid = L["hp_ids"] >= 0
    alloc, in_near = L["alloc"], L["bt"] < cfg.n_near
    cnt = L["hc"].astype(jnp.int32)
    lru = L["lt"].astype(jnp.int32)
    zero = jnp.zeros_like(cnt)
    return dict(cands=dict(
        free_far=nominate(valid & ~in_near & ~alloc, zero, b, **kw),
        near_lru=nominate(valid & in_near & alloc, -lru, b, **kw),
        fault=nominate(valid & alloc & ~in_near & (cnt >= 2), cnt, b, **kw),
        free_near=nominate(valid & in_near & ~alloc, zero, b, **kw),
    ), sums=dict(
        free_near=(valid & in_near & ~alloc).sum(),
        demand=(valid & alloc & ~in_near & (cnt >= 2)).sum(),
    ))


def _tpp_apply(
    cfg: GpacConfig, L: dict, merged: dict, budget: int,
    watermark: float = 0.1, tiers=None,
):
    _check_two_tier(cfg, tiers)
    b = _b(cfg, budget)
    C = {k: _flat_cands(v) for k, v in merged["cands"].items()}
    want_free = jnp.int32(watermark * cfg.n_near)
    demand = merged["sums"]["demand"]
    need = jnp.maximum(jnp.minimum(want_free, demand),
                       jnp.minimum(demand, budget))
    n_demote = jnp.clip(need - merged["sums"]["free_near"], 0, budget)
    # round 1: watermark demotion (coldest allocated near <-> free far)
    farD = rank_select(C["free_far"], b)
    nearD = rank_select(C["near_lru"], b)
    ok1, d1 = swap_outcome(
        cfg, farD, nearD, jnp.minimum(_pair_k(farD, nearD), n_demote)
    )
    bt = apply_swaps_local(L["bt"], L["hp_lo"], L["hp_hi"], farD, nearD, ok1)

    # round 2: fault promotion into the freed space (post-swap masks)
    def after(c):
        return {**c, "slot": slots_after_swaps(c["id"], c["slot"], farD, nearD, ok1)}

    A2 = _concat_cands(after(C["fault"]), after(nearD))
    A2 = {**A2, "val": jnp.where(
        (A2["id"] >= 0) & (A2["alloc"] > 0) & (A2["slot"] >= cfg.n_near)
        & (A2["cnt"] >= 2), A2["cnt"], NEG
    )}
    B2 = _concat_cands(after(C["free_near"]), after(farD))
    B2 = {**B2, "val": jnp.where(
        (B2["id"] >= 0) & (B2["alloc"] == 0) & (B2["slot"] < cfg.n_near), 0, NEG
    )}
    far2 = rank_select(A2, b)
    near2 = rank_select(B2, b)
    ok2, d2 = swap_outcome(cfg, far2, near2, _pair_k(far2, near2))
    bt = apply_swaps_local(bt, L["hp_lo"], L["hp_hi"], far2, near2, ok2)
    return bt, {s: d1[s] + d2[s] for s in d1}, ((farD, nearD, ok1), (far2, near2, ok2))


_SHARDED_TICKS: dict[str, tuple[Callable, Callable]] = {}


def register_sharded_tick(name: str, prepare: Callable, apply: Callable):
    """Register a host-partitioned (prepare, apply) tick for policy ``name``
    (see the section comment above for the contract). Policies without one
    still run everywhere except ``engine.run_sharded(host_sharded=True)``."""
    if name in _SHARDED_TICKS:
        raise ValueError(f"sharded tick for policy {name!r} already registered")
    _SHARDED_TICKS[name] = (prepare, apply)


def sharded_ticks() -> tuple[str, ...]:
    """Names of policies with a host-partitioned tick."""
    return tuple(_SHARDED_TICKS)


def sharded_tick_fns(name: str) -> tuple[Callable, Callable]:
    try:
        return _SHARDED_TICKS[name]
    except KeyError:
        raise ValueError(
            f"tiering policy {name!r} has no host-partitioned tick (have "
            f"{sharded_ticks()}); register one with tiering."
            f"register_sharded_tick or run with host_sharded=False"
        ) from None


register_sharded_tick("memtierd", _memtierd_prepare, _memtierd_apply)
register_sharded_tick("autonuma", _autonuma_prepare, _autonuma_apply)
register_sharded_tick("tpp", _tpp_prepare, _tpp_apply)
