"""Sharded, atomic, resumable checkpointing with elastic re-shard on restore.

Layout:
    <dir>/step_000123/
        manifest.json      # pytree structure, shapes, dtypes, step, mesh info
        <leaf-key>.npy     # one file per leaf (host-gathered)
    <dir>/LATEST           # atomic pointer (written last via os.replace)

Fault-tolerance contract (tested):
  * save is atomic -- a crash mid-save never corrupts LATEST;
  * restore re-shards to the *current* mesh (elastic: the saved mesh shape is
    metadata, not a constraint);
  * restore -> continue training is bit-identical to uninterrupted training.

Async: ``save(..., background=True)`` snapshots to host memory synchronously
(cheap) and writes files on a worker thread, overlapping the next step.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out[key] = leaf
    return out


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None,
         background: bool = False):
    """Snapshot ``tree`` (params/opt state/data state) at ``step``."""
    leaves = {k: np.asarray(v) for k, v in _flatten_with_paths(tree).items()}

    def write():
        step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
        tmp = step_dir + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "extra": extra or {}, "leaves": {}}
        for key, arr in leaves.items():
            fname = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(step_dir):
            shutil.rmtree(step_dir)
        os.replace(tmp, step_dir)  # atomic publish of the step dir
        latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(os.path.basename(step_dir))
        os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))  # atomic

    os.makedirs(ckpt_dir, exist_ok=True)
    if background:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(ckpt_dir: str) -> int | None:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip().split("_")[-1])


def restore(ckpt_dir: str, like_tree, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``like_tree``; ``shardings`` (same
    structure, NamedSharding leaves or None) re-shards onto the CURRENT mesh
    -- elastic restore: the checkpoint carries no device topology."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)

    like_flat = _flatten_with_paths(like_tree)
    shard_flat = _flatten_with_paths(shardings) if shardings is not None else {}
    out = {}
    for key, like in like_flat.items():
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(os.path.join(step_dir, meta["file"]))
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != expected {like.shape}")
        sh = shard_flat.get(key)
        out[key] = (jax.device_put(arr, sh) if sh is not None
                    else jax.numpy.asarray(arr, like.dtype))

    # rebuild the pytree in like_tree's structure
    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in flat]
    return treedef.unflatten([out[k] for k in keys]), manifest


def prune(ckpt_dir: str, keep: int = 3):
    """Retain only the newest ``keep`` step dirs."""
    steps = sorted(
        d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
