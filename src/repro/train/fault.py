"""Fault tolerance & straggler mitigation for 1000+-node runs.

Three mechanisms (all testable on CPU; the policies are pure functions over
observed health/timing data, independent of the transport that collects it):

1. **Checkpoint/restart** -- train/checkpoint.py provides atomic saves and
   elastic restore; ``Supervisor`` wires periodic saves + restore-on-start.
2. **Straggler mitigation** -- deadline-based microbatch drop: given per-host
   step-time EWMAs, hosts slower than ``deadline_factor x median`` get their
   microbatches rebalanced to the fastest hosts; a host dropped repeatedly is
   marked suspect and excluded at the next elastic boundary.
3. **Elastic resize** -- on node loss, training resumes from the last
   checkpoint on the surviving mesh (restore re-shards; the data pipeline
   state is part of the checkpoint, so no sample is skipped or repeated).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StragglerConfig:
    deadline_factor: float = 1.5  # x median EWMA step time
    ewma: float = 0.8
    suspect_after: int = 3  # consecutive deadline misses -> suspect


@dataclasses.dataclass
class HostHealth:
    n_hosts: int
    cfg: StragglerConfig
    ewma_ms: np.ndarray = None
    misses: np.ndarray = None

    def __post_init__(self):
        if self.ewma_ms is None:
            self.ewma_ms = np.zeros(self.n_hosts)
        if self.misses is None:
            self.misses = np.zeros(self.n_hosts, np.int64)


def observe_step(h: HostHealth, step_ms: np.ndarray) -> HostHealth:
    """Fold one step's per-host times into the EWMAs."""
    a = h.cfg.ewma
    init = h.ewma_ms == 0
    h.ewma_ms = np.where(init, step_ms, a * h.ewma_ms + (1 - a) * step_ms)
    deadline = h.cfg.deadline_factor * np.median(h.ewma_ms)
    missed = step_ms > deadline
    h.misses = np.where(missed, h.misses + 1, 0)
    return h


def straggler_plan(h: HostHealth, micro_per_host: int) -> dict:
    """Rebalance microbatches away from hosts past the deadline.

    Returns {"shares": int[n_hosts] microbatches per host (sum preserved),
             "suspects": host ids to exclude at the next elastic boundary}.
    """
    deadline = h.cfg.deadline_factor * np.median(h.ewma_ms)
    slow = h.ewma_ms > deadline
    shares = np.full(h.n_hosts, micro_per_host, np.int64)
    if slow.any() and not slow.all():
        freed = shares[slow].sum() // 2  # halve slow hosts' load
        shares[slow] -= shares[slow] // 2
        fast_order = np.argsort(h.ewma_ms)
        fast = fast_order[~slow[fast_order]]
        for i in range(int(freed)):  # round-robin the freed microbatches
            shares[fast[i % len(fast)]] += 1
    suspects = np.nonzero(h.misses >= h.cfg.suspect_after)[0]
    return {"shares": shares, "suspects": suspects}


def surviving_mesh_shape(n_hosts_alive: int, chips_per_host: int,
                         model_parallel: int) -> tuple:
    """Largest (data, model) mesh on the survivors: model-parallel groups must
    stay whole, so data shrinks to the largest multiple that fits."""
    chips = n_hosts_alive * chips_per_host
    data = chips // model_parallel
    if data == 0:
        raise RuntimeError(
            f"{chips} chips cannot host model_parallel={model_parallel}")
    return (data, model_parallel)


class Supervisor:
    """Restart-on-failure training wrapper (single-process simulation of the
    cluster control plane; the policy logic above is what production reuses).
    """

    def __init__(self, ckpt_dir: str, save_every: int = 50, keep: int = 3):
        from repro.train import checkpoint as ckpt

        self.ckpt = ckpt
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.keep = keep

    def resume_step(self) -> int:
        s = self.ckpt.latest_step(self.ckpt_dir)
        return 0 if s is None else s

    def maybe_save(self, step: int, tree, extra=None, background=True):
        if step % self.save_every == 0 and step > 0:
            t = self.ckpt.save(self.ckpt_dir, step, tree, extra,
                               background=background)
            self.ckpt.prune(self.ckpt_dir, self.keep)
            return t
        return None
