from repro.train import checkpoint, compression, fault, optimizer, trainer  # noqa: F401
