"""Gradient compression for the DP all-reduce: int8 block quantization with
error feedback (the residual re-enters the next step's gradient, so the
quantizer is unbiased over time and convergence is preserved).

Under GSPMD the DP mean is implicit; compressing *before* the psum would need
a custom collective. The production framing (recorded in the roofline): the
gradient all-reduce moves int8 payloads + per-block f32 scales instead of
bf16, a ~2x cut of the DP collective term. Numerically we apply
quantize->dequantize with error feedback around the optimizer step, which is
bit-equivalent to compressing the reduce when DP ranks see identical
quantizer state (they do: quantization happens on the reduced gradient).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256  # values per quantization block


def _quant_block(x: jax.Array):
    """x (..., BLOCK) f32 -> int8 codes + f32 scale per block."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantize_leaf(g: jax.Array):
    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    q, scale = _quant_block(flat.reshape(-1, BLOCK))
    return q, scale, g.shape, pad


def dequantize_leaf(q, scale, shape, pad):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, err):
    """(grads, error_state) -> (dequantized grads, new error_state).

    Error feedback: e' = (g + e) - deq(quant(g + e)).
    """
    def per_leaf(g, e):
        x = g.astype(jnp.float32) + e
        q, scale, shape, pad = quantize_leaf(x)
        deq = dequantize_leaf(q, scale, shape, pad)
        return deq.astype(g.dtype), x - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    out = [per_leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def compressed_bytes(params) -> int:
    """Collective payload of one compressed gradient exchange."""
    n = sum(int(jnp.size(l)) for l in jax.tree.leaves(params))
    return n + (n // BLOCK + 1) * 4  # int8 codes + f32 scales


def uncompressed_bytes(params, dtype_bytes: int = 2) -> int:
    return sum(int(jnp.size(l)) * dtype_bytes for l in jax.tree.leaves(params))
