"""Optimizers (functional, pytree-based): AdamW and Adafactor.

Optimizer state is kept in f32 regardless of param dtype (mixed-precision
training); under the production mesh the state is additionally ZeRO-1 sharded
by ``launch.sharding.zero1_specs`` (each DP rank owns a slice of m/v).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"  # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw_init(params) -> dict:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: OptConfig, grads, state: dict, params):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment -- the memory-frugal option for 1T MoE)
# ---------------------------------------------------------------------------
def adafactor_init(params) -> dict:
    def per_leaf(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"f": jax.tree.map(per_leaf, params,
                              is_leaf=lambda x: isinstance(x, jax.Array)),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptConfig, grads, state: dict, params):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    decay = 1.0 - step.astype(jnp.float32) ** -0.8

    def upd(g, f, p):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if g.ndim >= 2:
            vr = decay * f["vr"] + (1 - decay) * g2.mean(-1)
            vc = decay * f["vc"] + (1 - decay) * g2.mean(-2)
            denom = (vr[..., None] * vc[..., None, :]) / jnp.maximum(
                vr.mean(-1, keepdims=True)[..., None], 1e-30)
            upd_ = g / jnp.sqrt(denom + 1e-30)
            newf = {"vr": vr, "vc": vc}
        else:
            v = decay * f["v"] + (1 - decay) * g2
            upd_ = g / jnp.sqrt(v + 1e-30)
            newf = {"v": v}
        # update clipping (Adafactor's RMS trick)
        rms = jnp.sqrt(jnp.mean(upd_ ** 2) + 1e-30)
        upd_ = upd_ / jnp.maximum(1.0, rms)
        newp = (p.astype(jnp.float32)
                - lr * (upd_ + cfg.weight_decay * p.astype(jnp.float32)))
        return newp.astype(p.dtype), newf

    is_state = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_f = treedef.flatten_up_to(state["f"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, f, p) for g, f, p in zip(flat_g, flat_f, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_f = treedef.unflatten([o[1] for o in out])
    return new_p, {"f": new_f, "step": step}, {"lr": lr, "grad_norm": gnorm}


def init(cfg: OptConfig, params):
    return adamw_init(params) if cfg.name == "adamw" else adafactor_init(params)


def update(cfg: OptConfig, grads, state, params):
    fn = adamw_update if cfg.name == "adamw" else adafactor_update
    return fn(cfg, grads, state, params)
