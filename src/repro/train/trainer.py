"""Training step factory: gradient accumulation, optional int8 gradient
compression with error feedback, AdamW/Adafactor, metrics.

``make_train_step`` returns one jitted function
    (params, opt_state, batch) -> (params, opt_state, metrics)
whose in/out shardings the launcher assigns (launch/sharding.py); the trainer
itself is mesh-agnostic. Gradient accumulation scans over microbatches so
activation memory is bounded by one microbatch (the standard big-model
recipe; kimi-k2's MoE dispatch buffer needs it -- DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.dist import NO_DIST, Dist
from repro.models.registry import Model
from repro.train import compression, optimizer


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    micro_batches: int = 1  # gradient-accumulation factor
    compress_grads: bool = False  # int8 + error feedback on the DP reduce
    opt: optimizer.OptConfig = dataclasses.field(default_factory=optimizer.OptConfig)


def init_train_state(tcfg: TrainConfig, params) -> dict:
    state = {"opt": optimizer.init(tcfg.opt, params)}
    if tcfg.compress_grads:
        state["err"] = compression.init_error(params)
    return state


def _split_micro(batch: dict, n: int) -> dict:
    """(B, ...) -> (n, B/n, ...) for scanning; mrope positions keep axis 0."""
    def split(key, x):
        if key == "positions":  # (3, B, S)
            return x.reshape(x.shape[0], n, -1, *x.shape[2:]).swapaxes(0, 1)
        return x.reshape(n, -1, *x.shape[1:])

    return {k: split(k, v) for k, v in batch.items()}


def make_train_step(model: Model, tcfg: TrainConfig, dist: Dist = NO_DIST):
    n_micro = tcfg.micro_batches

    def loss_for_grad(params, mb):
        loss, metrics = model.loss_fn(params, mb, dist)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for_grad, has_aux=True)

    def train_step(params, train_state, batch):
        if n_micro == 1:
            (loss, mets), grads = grad_fn(params, batch)
        else:
            micro = _split_micro(batch, n_micro)

            def body(carry, mb):
                acc, loss_acc = carry
                (loss, _), grads = grad_fn(params, mb)
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                   acc, grads)
                return (acc, loss_acc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss_sum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = loss_sum / n_micro
            mets = {}

        new_state = dict(train_state)
        if tcfg.compress_grads:
            grads, new_state["err"] = compression.compress_grads(
                grads, train_state["err"])
        params, new_state["opt"], opt_mets = optimizer.update(
            tcfg.opt, grads, train_state["opt"], params)
        metrics = {"loss": loss, **opt_mets, **mets}
        return params, new_state, metrics

    return train_step


def train_loop(model: Model, tcfg: TrainConfig, data_spec, steps: int,
               params=None, train_state=None, data_state=None,
               supervisor=None, key=None, jit: bool = True):
    """Reference single-host loop (examples + tests); the production driver
    with mesh shardings lives in launch/train.py."""
    from repro.data import pipeline

    key = key if key is not None else jax.random.PRNGKey(0)
    params = params if params is not None else model.init(key)
    train_state = train_state or init_train_state(tcfg, params)
    data_state = data_state or pipeline.DataState()
    step_fn = make_train_step(model, tcfg)
    if jit:
        step_fn = jax.jit(step_fn)

    history = []
    start = int(train_state["opt"]["step"])
    for _ in range(start, steps):
        batch, data_state = pipeline.next_batch(data_spec, data_state)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, train_state, mets = step_fn(params, train_state, batch)
        history.append({k: float(v) for k, v in mets.items()})
        if supervisor is not None:
            supervisor.maybe_save(
                int(train_state["opt"]["step"]),
                {"params": params, "train_state": train_state,
                 "data_step": jnp.asarray(data_state.step)},
            )
    return params, train_state, data_state, history
