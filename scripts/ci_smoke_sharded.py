#!/usr/bin/env python
"""Sharded-engine CI smoke on a forced multi-device CPU mesh.

Requires `XLA_FLAGS=--xla_force_host_platform_device_count=8` (device count
is fixed at jax init). Exercises a non-dividing guest count (padding path)
through BOTH sharded drivers -- the replicated-host path
(`host_sharded=False`) and the host-partitioned near tier
(`host_sharded=True`, DESIGN.md §11) -- each pinned bit-for-bit against
`engine.run` on BOTH trace sources: the packed-array path and on-device
`SynthTrace` synthesis (DESIGN.md §12, where each device generates only its
local guests' accesses inside the scan). Also reports the measured
per-device host-state scaling.

Shared entry point for CI (`python scripts/ci_smoke_sharded.py`) and the
test suite (`pytest -m smoke`, tests/test_ci_smoke.py) so the smoke code
cannot drift from the library API.
"""
import sys

N_DEVICES = 8


def main() -> int:
    import jax
    import numpy as np

    from repro.core import engine, sharding

    assert jax.local_device_count() == N_DEVICES, (
        f"need XLA_FLAGS=--xla_force_host_platform_device_count={N_DEVICES}, "
        f"have {jax.local_device_count()} device(s)")
    guests = tuple(
        engine.GuestSpec(n_logical=64 + 16 * (g % 4),
                         cl=(None if g % 3 == 0 else 3 + g % 5),
                         workload=["redis", "masim", "hash"][g % 3],
                         seed=g)
        for g in range(6))  # 6 guests on 8 shards: padding path
    spec, state = engine.build(
        guests, engine.HostSpec(hp_ratio=16, near_fraction=0.4,
                                base_elems=2, cl=6))
    mesh = sharding.guest_mesh(N_DEVICES)
    sources = dict(
        array=engine.ArrayTrace(
            engine.guest_traces(spec, n_windows=4, accesses_per_window=192)),
        synth=engine.SynthTrace(n_windows=4, accesses_per_window=192),
    )
    for src_name, source in sources.items():
        s_ref, a = engine.run(spec, state, source)
        for host_sharded in (False, True):
            s_sh, b = engine.run_sharded(spec, state, source, mesh=mesh,
                                         host_sharded=host_sharded)
            for k in a:
                np.testing.assert_array_equal(
                    a[k], b[k],
                    err_msg=f"{src_name}, host_sharded={host_sharded}: {k}")
            for x, y in zip(jax.tree_util.tree_leaves(s_ref),
                            jax.tree_util.tree_leaves(s_sh)):
                np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y),
                    err_msg=f"{src_name}, host_sharded={host_sharded}")
    part = sharding.host_partition(spec, N_DEVICES)
    scaling = (sharding.host_state_bytes_sharded(spec.cfg, part)
               / sharding.host_state_bytes(spec.cfg))
    print(f"sharded engine smoke OK ({N_DEVICES}-device mesh, bit-for-bit, "
          f"replicated + host-partitioned, array + on-device synth traces; "
          f"per-device host state {scaling:.2f}x of replicated)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
