#!/usr/bin/env python
"""Churn + fault-injection CI smoke (ISSUE 6).

A small mixed fleet runs the steady-state stepper under a fixed fault
schedule -- guest crashes, a restart, a near-capacity shrink with grow-back,
and a telemetry-dropout window -- and the run is checked for the two §13
invariants:

* INV-CHURN-NOOP-EXACT: the no-fault control run is bit-identical to
  ``engine.run`` (final state and every collector series), and the faulted
  run is bit-identical across ``windows_per_step`` chunkings.
* INV-CRASH-RECLAIM-COMPLETE: every crashed guest's near blocks are
  reclaimed within the crash window, its rmap segment is FREE, no allocated
  huge page is left in an inactive guest's segment, and the pressure
  controller never overcommits the physical near tier.

Shared entry point for CI (`python scripts/ci_smoke_churn.py`) and the test
suite (`pytest -m smoke`, tests/test_ci_smoke.py) so the smoke code cannot
drift from the library API. Single-device: the multi-device churn matrix
rides tests/test_churn.py's forced-8-device subprocess.
"""
import sys


def main() -> int:
    import jax
    import numpy as np

    from repro.core import engine, faults
    from repro.core.types import FREE, allocated_hp_mask

    guests = tuple(
        engine.GuestSpec(n_logical=64 + 16 * (g % 3),
                         cl=(None if g % 3 == 0 else 3 + g),
                         workload=["redis", "redis_drift", "hash_drift"][g % 3],
                         seed=g)
        for g in range(4))
    spec, s0 = engine.build(
        guests, engine.HostSpec(hp_ratio=16, near_fraction=0.4,
                                base_elems=2, cl=6))
    n_windows = 8
    synth = engine.SynthTrace(n_windows=n_windows, accesses_per_window=192)
    sched = (faults.FaultSchedule(spec.n_guests)
             .crash(1, 0).restart(4, 0).crash(3, 2)
             .shrink(2, max(1, spec.cfg.n_near - 2))
             .shrink(6, spec.cfg.n_near)
             .dropout(5))

    # INV-CHURN-NOOP-EXACT: no-fault control vs engine.run
    ref_state, ref = engine.run(spec, s0, synth)
    ctrl, ctrl_se = engine.run_churn(spec, engine.init_churn(spec), synth)
    for a, b in zip(jax.tree_util.tree_leaves(ref_state),
                    jax.tree_util.tree_leaves(ctrl.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg="no-fault churn diverged")
    for k in ref:
        np.testing.assert_array_equal(ref[k], ctrl_se[k], err_msg=k)

    # the faulted run, bit-identical across chunkings
    cs, se = engine.run_churn(spec, engine.init_churn(spec), synth,
                              faults=sched)
    cs2, se2 = engine.run_churn(spec, engine.init_churn(spec), synth,
                                faults=sched, windows_per_step=4,
                                strict_wps=True)
    for a, b in zip(jax.tree_util.tree_leaves(cs),
                    jax.tree_util.tree_leaves(cs2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg="chunking changed faulted run")
    for k in se:
        np.testing.assert_array_equal(se[k], se2[k], err_msg=k)

    # INV-CRASH-RECLAIM-COMPLETE: guest 2 stays crashed at the end
    blocks = np.asarray(se["near_blocks"])
    active = np.asarray(se["active"])
    assert blocks[1, 0] == 0 and blocks[3, 2] == 0, (
        "crash window still holds near blocks")
    assert (blocks[~active] == 0).all(), "inactive lane holds near blocks"
    hp_lo, hp_hi = spec.hp_range(2)
    r = spec.cfg.hp_ratio
    rmap = np.asarray(cs.state.rmap)
    assert (rmap[hp_lo * r:hp_hi * r] == int(FREE)).all(), (
        "crashed guest's gpa segment not FREE")
    _, hp_owner, _, _ = faults.segment_tables(spec.canonical())
    owner = np.asarray(hp_owner)
    act = np.asarray(cs.active)
    alloc = np.asarray(allocated_hp_mask(spec.cfg, cs.state))
    orphans = alloc & (owner >= 0) & ~act[np.clip(owner, 0, None)]
    assert not orphans.any(), f"orphaned huge pages: {np.nonzero(orphans)}"

    # the pressure controller honors the physical tier and the shrink shows
    # up in the series
    usage = blocks.sum(axis=1)
    assert (usage <= spec.cfg.n_near).all(), "near tier overcommitted"
    caps = np.asarray(se["near_cap"])
    assert caps[2] == max(1, spec.cfg.n_near - 2) and caps[7] == spec.cfg.n_near

    print(f"churn engine smoke OK ({spec.n_guests} guests, {n_windows} "
          f"windows, {sched.n_events} fault events: noop-exact, "
          f"chunking-invariant, crash reclaim complete, near tier never "
          f"overcommitted)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
