#!/usr/bin/env python
"""Bench regression gate: fail CI when the engine's at-scale speedup drops.

Compares a freshly written BENCH_engine.json against the committed baseline
(CI snapshots it with `git show HEAD:BENCH_engine.json` before the bench
runs) and fails when the minimum engine-vs-seed speedup at n_guests >= 8
falls below TOLERANCE x the baseline's. The tolerance absorbs shared-CI
wall-clock noise; since every (case, runner) pair now times in its own
fresh subprocess (benchmarks/bench_engine.py --worker), the cross-runner
pollution that forced the old 0.8x slack is gone and the gate tightens to
0.85x. A real regression in the scan-fused driver shows up as a >15% drop
across every at-scale case.

Also gates the steady-state churn engine: `churn_vs_engine` (the fault
machinery's overhead ratio vs the plain driver) must hold the same
tolerance against the baseline, and `reclaim_complete`
(INV-CRASH-RECLAIM-COMPLETE on the benchmark's final carry) must be true
outright -- a correctness bit, not a wall-clock number.

Usage: check_bench_regression.py <baseline.json> <fresh.json>
"""
import json
import sys

TOLERANCE = 0.85
AT_SCALE_GUESTS = 8


def min_at_scale_speedup(payload: dict) -> float:
    # pod-size rows run only the SynthTrace path (the seed reference would
    # need a host-materialized trace) and carry no "speedup"; the gate
    # compares the cases that time both paths
    cases = [c["speedup"] for c in payload["cases"]
             if c["n_guests"] >= AT_SCALE_GUESTS and "speedup" in c]
    if not cases:
        raise SystemExit("no at-scale (n_guests >= 8) cases in payload")
    return min(cases)


def main(baseline_path: str, fresh_path: str) -> int:
    with open(baseline_path) as f:
        baseline = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)
    base = min_at_scale_speedup(baseline)
    new = min_at_scale_speedup(fresh)
    floor = TOLERANCE * base
    print(f"engine-vs-seed speedup at n_guests >= {AT_SCALE_GUESTS}: "
          f"baseline {base:.2f}x, fresh {new:.2f}x, "
          f"floor {floor:.2f}x ({TOLERANCE}x baseline)")
    failed = False
    if new < floor:
        print(f"FAIL: at-scale speedup regressed below {TOLERANCE}x baseline")
        failed = True
    if fresh.get("reclaim_complete") is False:
        print("FAIL: churn benchmark left orphaned near blocks "
              "(INV-CRASH-RECLAIM-COMPLETE violated)")
        failed = True
    if "churn_vs_engine" in baseline and "churn_vs_engine" in fresh:
        cb, cf = baseline["churn_vs_engine"], fresh["churn_vs_engine"]
        cfloor = TOLERANCE * cb
        print(f"churn-vs-engine overhead ratio: baseline {cb:.2f}x, "
              f"fresh {cf:.2f}x, floor {cfloor:.2f}x")
        if cf < cfloor:
            print(f"FAIL: churn driver overhead regressed below "
                  f"{TOLERANCE}x baseline")
            failed = True
    if "tco" in fresh:
        # informational only: the TCO column (ISSUE 7) tracks the churn
        # fleet's $-weighted placement; baselines from before the tier
        # subsystem have no such column, so never gate on it
        if "tco" in baseline:
            print(f"churn fleet TCO: baseline {baseline['tco']:.4g}, "
                  f"fresh {fresh['tco']:.4g} (informational)")
        else:
            print(f"churn fleet TCO: fresh {fresh['tco']:.4g} "
                  f"(baseline predates the tco column)")
    if "min_overlap_speedup_at_scale" in fresh:
        # informational only: the stride-4 overlapped-exchange column
        # (DESIGN.md §17). Shared-CI wall clock of a collective-heavy path
        # is too noisy to gate; the bit-exactness pin is
        # INV-MULTIHOST-EXACT, and baselines from before the multi-host
        # runtime have no such column
        ov = fresh["min_overlap_speedup_at_scale"]
        if "min_overlap_speedup_at_scale" in baseline:
            print(f"overlap (stride-4) speedup at scale: baseline "
                  f"{baseline['min_overlap_speedup_at_scale']:.2f}x, fresh "
                  f"{ov:.2f}x (informational)")
        else:
            print(f"overlap (stride-4) speedup at scale: fresh {ov:.2f}x "
                  f"(baseline predates the overlap column)")
    if "multihost_s" in fresh:
        # informational only: 2-process coordinated-launch wall clock --
        # dominated by the workers' cold jit compiles
        if "multihost_s" in baseline:
            print(f"multihost launch wall: baseline "
                  f"{baseline['multihost_s']:.1f} s, fresh "
                  f"{fresh['multihost_s']:.1f} s (informational)")
        else:
            print(f"multihost launch wall: fresh {fresh['multihost_s']:.1f} s "
                  f"(baseline predates the multihost column)")
    if "pallas_vs_engine" in fresh:
        # informational only: the pallas-interpret cost ratio (DESIGN.md
        # §16) on the smallest grid row; interpret-mode wall clock says
        # nothing about TPU lowering, and baselines from before the kernel
        # registry have no such column, so never gate on it
        if "pallas_vs_engine" in baseline:
            print(f"pallas-vs-engine interpret ratio: baseline "
                  f"{baseline['pallas_vs_engine']:.1f}x, fresh "
                  f"{fresh['pallas_vs_engine']:.1f}x (informational)")
        else:
            print(f"pallas-vs-engine interpret ratio: fresh "
                  f"{fresh['pallas_vs_engine']:.1f}x "
                  f"(baseline predates the pallas column)")
    if failed:
        return 1
    print("OK: no bench regression")
    return 0


if __name__ == "__main__":
    if len(sys.argv) != 3:
        raise SystemExit(__doc__)
    sys.exit(main(sys.argv[1], sys.argv[2]))
