#!/usr/bin/env python
"""Bench regression gate: fail CI when the engine's at-scale speedup drops.

Compares a freshly written BENCH_engine.json against the committed baseline
(CI snapshots it with `git show HEAD:BENCH_engine.json` before the bench
runs) and fails when the minimum engine-vs-seed speedup at n_guests >= 8
falls below TOLERANCE x the baseline's. The 0.8x tolerance absorbs shared-CI
wall-clock noise (the bench itself is best-of-N with `block_until_ready`
timing, so dispatch-async credit is already excluded); a real regression in
the scan-fused driver shows up as a >20% drop across every at-scale case.

Usage: check_bench_regression.py <baseline.json> <fresh.json>
"""
import json
import sys

TOLERANCE = 0.8
AT_SCALE_GUESTS = 8


def min_at_scale_speedup(payload: dict) -> float:
    # pod-size rows run only the SynthTrace path (the seed reference would
    # need a host-materialized trace) and carry no "speedup"; the gate
    # compares the cases that time both paths
    cases = [c["speedup"] for c in payload["cases"]
             if c["n_guests"] >= AT_SCALE_GUESTS and "speedup" in c]
    if not cases:
        raise SystemExit("no at-scale (n_guests >= 8) cases in payload")
    return min(cases)


def main(baseline_path: str, fresh_path: str) -> int:
    with open(baseline_path) as f:
        baseline = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)
    base = min_at_scale_speedup(baseline)
    new = min_at_scale_speedup(fresh)
    floor = TOLERANCE * base
    print(f"engine-vs-seed speedup at n_guests >= {AT_SCALE_GUESTS}: "
          f"baseline {base:.2f}x, fresh {new:.2f}x, "
          f"floor {floor:.2f}x ({TOLERANCE}x baseline)")
    if new < floor:
        print(f"FAIL: at-scale speedup regressed below {TOLERANCE}x baseline")
        return 1
    print("OK: no at-scale speedup regression")
    return 0


if __name__ == "__main__":
    if len(sys.argv) != 3:
        raise SystemExit(__doc__)
    sys.exit(main(sys.argv[1], sys.argv[2]))
