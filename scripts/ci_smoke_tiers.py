#!/usr/bin/env python
"""N-tier hierarchy CI smoke on a forced multi-device CPU mesh (ISSUE 7).

Requires `XLA_FLAGS=--xla_force_host_platform_device_count=8` (device count
is fixed at jax init). Two checks ride one mesh:

* INV-TIER-2SPECIALCASE-EXACT at mesh scale: an explicit
  ``tiers=two_tier(cfg)`` engine is bit-for-bit equal to the legacy 2-tier
  engine through BOTH sharded drivers (replicated host and host-partitioned
  near tier), final state and every collector series.
* The 3-tier compressed hierarchy (dram + zram + nvmm, DESIGN.md §14) runs
  the ``compressed`` policy with the TCO collector through both host paths,
  pinned against ``engine.run`` -- and the 2-tier-only builtin partitioned
  ticks refuse the 3-tier spec loudly instead of mis-tiering.

Shared entry point for CI (`python scripts/ci_smoke_tiers.py`) and the test
suite (`pytest -m smoke`, tests/test_ci_smoke.py) so the smoke code cannot
drift from the library API.
"""
import sys

N_DEVICES = 8


def main() -> int:
    import dataclasses

    import jax
    import numpy as np

    from repro.core import engine, sharding, tiers

    assert jax.local_device_count() == N_DEVICES, (
        f"need XLA_FLAGS=--xla_force_host_platform_device_count={N_DEVICES}, "
        f"have {jax.local_device_count()} device(s)")

    def check_equal(ref, got, label):
        s_ref, a = ref
        s_got, b = got
        assert set(a) == set(b), (label, sorted(a), sorted(b))
        for k in a:
            np.testing.assert_array_equal(a[k], b[k], err_msg=f"{label}: {k}")
        for x, y in zip(jax.tree_util.tree_leaves(s_ref),
                        jax.tree_util.tree_leaves(s_got)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=label)

    guests = tuple(
        engine.GuestSpec(n_logical=64 + 16 * (g % 4),
                         cl=(None if g % 3 == 0 else 3 + g % 5),
                         workload=["redis", "masim", "hash"][g % 3],
                         seed=g)
        for g in range(6))  # 6 guests on 8 shards: padding path
    mesh = sharding.guest_mesh(N_DEVICES)
    synth = engine.SynthTrace(n_windows=4, accesses_per_window=192)

    # -- 2-tier special case: explicit tier vector == legacy, bit-for-bit --
    spec, state = engine.build(
        guests, engine.HostSpec(hp_ratio=16, near_fraction=0.4,
                                base_elems=2, cl=6))
    spec_tv = dataclasses.replace(spec, tiers=tiers.two_tier(spec.cfg))
    ref = engine.run(spec, state, synth, collect=("hits", "tco"))
    check_equal(ref, engine.run(spec_tv, state, synth,
                                collect=("hits", "tco")), "two_tier run")
    for host_sharded in (False, True):
        check_equal(
            engine.run_sharded(spec, state, synth, mesh=mesh,
                               host_sharded=host_sharded,
                               collect=("hits", "tco")),
            engine.run_sharded(spec_tv, state, synth, mesh=mesh,
                               host_sharded=host_sharded,
                               collect=("hits", "tco")),
            f"two_tier host_sharded={host_sharded}")

    # -- 3-tier compressed hierarchy through both host paths --
    host3 = engine.HostSpec(
        hp_ratio=16, base_elems=2, cl=6,
        tiers=tiers.compressed_specs(near_fraction=0.2, mid_fraction=0.2,
                                     compression=2.0))
    spec3, state3 = engine.build(guests, host3)
    tv = spec3.tiers
    assert tv is not None and tv.n_tiers == 3, tv
    ref3 = engine.run(spec3, state3, synth, policy="compressed",
                      collect=("hits", "tco"))
    for host_sharded in (False, True):
        check_equal(
            ref3,
            engine.run_sharded(spec3, state3, synth, mesh=mesh,
                               policy="compressed",
                               host_sharded=host_sharded,
                               collect=("hits", "tco")),
            f"compressed host_sharded={host_sharded}")
    tco = np.asarray(ref3[1]["tco"])
    assert (tco > 0).all(), tco

    # the 2-tier-only builtin partitioned ticks must refuse the 3-tier spec
    try:
        engine.run_sharded(spec3, state3, synth, mesh=mesh,
                           policy="memtierd", host_sharded=True)
    except ValueError as e:
        assert "tier" in str(e), e
    else:
        raise AssertionError(
            "memtierd host-partitioned tick accepted a 3-tier spec")

    print(f"tiers smoke OK ({N_DEVICES}-device mesh: 2-tier special case "
          f"bit-exact on both host paths, 3-tier compressed + TCO pinned, "
          f"boundaries={tv.boundaries})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
