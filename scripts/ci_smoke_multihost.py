#!/usr/bin/env python
"""Multi-host CI smoke: a real 2-process x 2-devices-each coordinated CPU
job (DESIGN.md §17) pinned bit-for-bit against the single-process engine.

The script is BOTH the launcher and the worker. Run standalone (no
``REPRO_*`` environment) it spawns itself twice via
``repro.launch.multihost.launch_check`` -- two OS processes joined through
``jax.distributed`` with gloo CPU collectives, four global devices. Each
worker then:

* runs the engine matrix (array + synth sources x both host paths) on the
  global mesh and asserts bit-equality with ``engine.run`` on the same
  process (INV-MULTIHOST-EXACT);
* drives the churn stepper with crash/restart/shrink faults across the
  mesh, performs a LIVE MIGRATION between chunks
  (``repro.launch.migration``), and asserts the continued run matches the
  single-process reference doing the same protocol;
* exercises ``arbitration_stride > 1`` cross-process (the overlapped
  exchange batches the only cross-host collective).

Shared entry point for CI (``python scripts/ci_smoke_multihost.py``), the
test suite (``pytest -m smoke``, tests/test_ci_smoke.py) and the
INV-MULTIHOST-EXACT contract harness.
"""
import os
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

NUM_PROCESSES = 2
DEVICES_PER_PROCESS = 2
MARKER = "MULTIHOST SMOKE OK"


def worker_main() -> int:
    from repro.launch import multihost

    info = multihost.initialize()

    import jax
    import numpy as np

    from repro.core import engine, faults, sharding
    from repro.launch import migration

    assert jax.process_count() == NUM_PROCESSES, jax.process_count()
    # lane 5 is the migration spare: same geometry/CL as source lane 0
    guests = tuple(
        engine.GuestSpec(
            n_logical=64,
            cl=(None if g % 3 == 0 or g == 5 else 3 + g % 5),
            workload=["redis", "masim", "hash"][g % 3], seed=g)
        for g in range(6))
    spec, state = engine.build(
        guests, engine.HostSpec(hp_ratio=16, near_fraction=0.4,
                                base_elems=2, cl=6))
    mesh = multihost.global_guest_mesh()
    assert sharding.mesh_size(mesh) == NUM_PROCESSES * DEVICES_PER_PROCESS

    sources = dict(
        array=engine.ArrayTrace(
            engine.guest_traces(spec, n_windows=4, accesses_per_window=128)),
        synth=engine.SynthTrace(n_windows=4, accesses_per_window=128),
    )
    for src_name, source in sources.items():
        s_ref, a = engine.run(spec, state, source)
        for host_sharded in (False, True):
            s_sh, b = engine.run_sharded(spec, state, source, mesh=mesh,
                                         host_sharded=host_sharded)
            for k in a:
                np.testing.assert_array_equal(
                    a[k], b[k],
                    err_msg=f"{src_name}, host_sharded={host_sharded}: {k}")
            for x, y in zip(jax.tree_util.tree_leaves(s_ref),
                            jax.tree_util.tree_leaves(s_sh)):
                np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y),
                    err_msg=f"{src_name}, host_sharded={host_sharded}")
            print(f"[{info.process_id}] OK {src_name} "
                  f"host_sharded={host_sharded}", flush=True)

    # overlapped arbitration exchange across processes (stride > 1)
    synth = engine.SynthTrace(n_windows=4, accesses_per_window=128)
    _, a = engine.run(spec, state, synth, arbitration_stride=2)
    _, b = engine.run_sharded(spec, state, synth, mesh=mesh,
                              arbitration_stride=2)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=f"stride: {k}")
    print(f"[{info.process_id}] OK stride=2", flush=True)

    # churn stepper + live migration between chunks, mesh vs single-process
    fs = faults.no_faults(len(guests)).crash(2, 1).restart(3, 1)
    active = np.ones(len(guests), bool)
    active[5] = False  # vacant spare lane, migration destination
    cs0 = engine.init_churn(spec, state, active=active)

    def protocol(mesh):
        cs, head = engine.run_churn(spec, cs0, synth, faults=fs, mesh=mesh)
        cs, man = migration.migrate_guest(spec, cs, src=0, dst=5)
        tail_src = engine.SynthTrace(n_windows=4, accesses_per_window=128)
        cs, tail = engine.run_churn(spec, cs, tail_src, faults=fs, mesh=mesh)
        return cs, head, tail, man

    ref_cs, ref_h, ref_t, man = protocol(None)
    sh_cs, sh_h, sh_t, man2 = protocol(mesh)
    assert man == man2, (man, man2)
    for a, b, what in ((ref_h, sh_h, "head"), (ref_t, sh_t, "tail")):
        for k in a:
            np.testing.assert_array_equal(a[k], b[k],
                                          err_msg=f"migration {what}: {k}")
    for x, y in zip(jax.tree_util.tree_leaves(ref_cs),
                    jax.tree_util.tree_leaves(sh_cs)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg="post-migration churn state")
    print(f"[{info.process_id}] OK migration "
          f"({man['total_bytes']} bytes moved)", flush=True)
    print(f"[{info.process_id}] {MARKER}", flush=True)
    return 0


def main() -> int:
    from repro.launch import multihost

    if os.environ.get(multihost.ENV_NUM_PROCESSES):
        return worker_main()  # launched: we are one coordinated worker
    import time

    t0 = time.perf_counter()
    results = multihost.launch_check(
        str(pathlib.Path(__file__).resolve()), marker=MARKER,
        num_processes=NUM_PROCESSES,
        devices_per_process=DEVICES_PER_PROCESS, cwd=str(ROOT))
    dt = time.perf_counter() - t0
    for r in results:
        sys.stdout.write(r.stdout)
    print(f"launched {len(results)} workers, wall {dt:.1f}s")
    print("multihost smoke: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
