"""Worker entry for ``fig9_at_scale.run_pod_multihost`` (DESIGN.md §17).

``jax.distributed.initialize`` must run before the first jax computation,
and importing the engine stack builds ``jnp`` constants at module import --
so this entry joins the coordinated job FIRST (``repro.launch.multihost``
is jax-free at import time) and only then imports the benchmark.

Usage (spawned by :func:`multihost.launch` with the rendezvous env):
    python scripts/pod_multihost_worker.py <n_guests> <migrations>
"""
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))
sys.path.insert(0, str(ROOT / "src"))

from repro.launch import multihost  # noqa: E402

MARKER = "POD MULTIHOST OK"


def main() -> None:
    n_guests = int(sys.argv[1]) if len(sys.argv) > 1 else 1024
    migrations = int(sys.argv[2]) if len(sys.argv) > 2 else 2
    info = multihost.initialize()

    from benchmarks import fig9_at_scale

    out = fig9_at_scale.run_pod(n_guests=n_guests, migrations=migrations)
    res = out["memtierd"]
    print(f"{MARKER} p{info.process_id}: {out['n_guests']} guests + "
          f"{out['n_migrations']} live handoffs on {out['n_devices']} "
          f"global devices ({info.num_processes} processes); "
          f"hit tail {res['hit_rate_tail']:.3f}; "
          f"migration bytes {[m['total_bytes'] for m in res['migrations']]}; "
          f"collective {out['collective']['bytes_per_run']} B/run")


if __name__ == "__main__":
    main()
