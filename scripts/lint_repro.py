#!/usr/bin/env python
"""Run the repo-specific AST lints (repro.analysis.lint) over src/repro/.

Exit 0 iff there are no violations outside the tracked allowlist AND no
stale (unused) allowlist entries. CI runs this in the ``lint`` job next to
the invariant-ledger drift check; ``pytest -m smoke`` shares the entry
point via tests/test_ci_smoke.py.

Usage:
    python scripts/lint_repro.py              # lint src/repro/
    python scripts/lint_repro.py --list       # show the lint catalogue
    python scripts/lint_repro.py --self-test  # prove each lint fires on
                                              # its seeded violation fixture
"""
from __future__ import annotations

import argparse
import sys
import tempfile
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis import lint  # noqa: E402


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--list", action="store_true", help="list registered lints")
    ap.add_argument(
        "--self-test", action="store_true",
        help="verify every lint trips on its seeded violation fixture")
    args = ap.parse_args(argv)

    if args.list:
        for entry in lint.all_lints():
            print(f"{entry.name}: {entry.description}")
        return 0

    if args.self_test:
        with tempfile.TemporaryDirectory() as td:
            failures = lint.self_test(Path(td))
        for f in failures:
            print(f"SELF-TEST FAIL {f}", file=sys.stderr)
        print(f"lint self-test: {len(lint.all_lints())} lints, "
              f"{len(failures)} silent")
        return 1 if failures else 0

    violations, unused = lint.run(ROOT)
    for v in violations:
        print(v.format(), file=sys.stderr)
        if v.source_line:
            print(f"    {v.source_line}", file=sys.stderr)
    for e in unused:
        print(
            f"stale allowlist entry: ({e.lint}, {e.path}, {e.match!r}) "
            f"matched nothing — remove it (reason was: {e.reason})",
            file=sys.stderr)
    n_files = len(lint.default_targets(ROOT))
    print(f"linted {n_files} files with {len(lint.all_lints())} lints: "
          f"{len(violations)} violations, {len(unused)} stale allowlist entries")
    return 1 if (violations or unused) else 0


if __name__ == "__main__":
    raise SystemExit(main())
