#!/usr/bin/env python
"""Ragged-engine CI smoke: 2 asymmetric guests on the shared scan-fused
driver, pinned bit-for-bit against the sequential per-guest reference.

Shared entry point for CI (`python scripts/ci_smoke_ragged.py`) and the test
suite (`pytest -m smoke`, tests/test_ci_smoke.py) so the smoke code cannot
drift from the library API.
"""
import sys


def main() -> int:
    import jax
    import numpy as np

    from repro.core import engine

    spec, state = engine.build(
        (engine.GuestSpec(n_logical=96, cl=4, workload="redis", seed=0),
         engine.GuestSpec(n_logical=160, cl=10, workload="masim", seed=1)),
        engine.HostSpec(hp_ratio=16, near_fraction=0.4, base_elems=2, cl=8))
    traces = engine.guest_traces(spec, n_windows=4, accesses_per_window=256)
    s_new, a = engine.run(spec, state, traces)
    s_ref, b = engine.run_reference(spec, state, traces)
    for k in b:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    for x, y in zip(jax.tree_util.tree_leaves(s_new),
                    jax.tree_util.tree_leaves(s_ref)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    print("ragged engine smoke OK:", {k: v.shape for k, v in a.items()})
    return 0


if __name__ == "__main__":
    sys.exit(main())
