"""Multi-host runtime (DESIGN.md §17): distributed launch, the
2-process x 2-devices-each coordinated CPU matrix, and live guest
migration.

The acceptance invariant is INV-MULTIHOST-EXACT: an engine run spanning
OS processes (``jax.distributed`` + gloo CPU collectives) is bit-identical
to the single-process run on the same global mesh -- both host paths, both
trace sources, and through the churn stepper. The multi-process matrix
runs via ``repro.launch.multihost.launch`` because device count and the
collectives implementation are fixed at jax init, exactly like the forced
8-device matrix in tests/test_engine_sharded.py.
"""
import dataclasses
import os
import sys
import textwrap

import numpy as np
import pytest

from repro.core import engine, faults
from repro.core.types import FREE
from repro.launch import migration, multihost

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------
# launcher plumbing (in-process, no coordinated job)
# --------------------------------------------------------------------------
class TestLaunchUtilities:
    def test_initialize_is_noop_single_process(self):
        info = multihost.initialize(num_processes=1)
        assert info.num_processes == 1
        assert info.process_id == 0
        assert info.is_coordinator
        assert info.coordinator_address is None

    def test_initialize_requires_coordinator(self):
        with pytest.raises(ValueError, match="coordinator"):
            multihost.initialize(num_processes=2, process_id=0)

    def test_initialize_rejects_bad_process_id(self):
        with pytest.raises(ValueError, match="process_id"):
            multihost.initialize(coordinator_address="127.0.0.1:1",
                                 num_processes=2, process_id=7)

    def test_worker_env_exports_rendezvous(self):
        env = multihost.worker_env(
            {}, coordinator="127.0.0.1:9999", num_processes=2, process_id=1,
            devices_per_process=3)
        assert env[multihost.ENV_COORDINATOR] == "127.0.0.1:9999"
        assert env[multihost.ENV_NUM_PROCESSES] == "2"
        assert env[multihost.ENV_PROCESS_ID] == "1"
        assert env[multihost.ENV_CPU_COLLECTIVES] == "gloo"
        assert "device_count=3" in env["XLA_FLAGS"]
        assert env["JAX_PLATFORMS"] == "cpu"
        assert env["PYTHONPATH"].split(os.pathsep)[0] == "src"

    def test_launch_rejects_zero_processes(self):
        with pytest.raises(ValueError, match="num_processes"):
            multihost.launch("worker.py", num_processes=0)

    def test_launch_check_flags_missing_marker(self, tmp_path):
        worker = tmp_path / "w.py"
        worker.write_text("print('hello')\n")
        with pytest.raises(AssertionError, match="marker"):
            multihost.launch_check(str(worker), marker="NOPE",
                                   num_processes=1, devices_per_process=1,
                                   timeout=60)

    def test_global_guest_mesh_matches_core(self):
        import jax

        from repro.core import sharding

        a = multihost.global_guest_mesh()
        b = sharding.guest_mesh()
        if jax.device_count() == 1:
            assert a is None and b is None
        else:
            assert a.shape == b.shape


# --------------------------------------------------------------------------
# live migration (in-process: host-side protocol on replicated state)
# --------------------------------------------------------------------------
def migration_engine():
    # identical lane geometry so any pair is migration-compatible
    guests = tuple(
        engine.GuestSpec(n_logical=48, cl=4,
                         workload=["redis", "masim", "hash"][g % 3], seed=g)
        for g in range(4))
    return engine.build(
        guests, engine.HostSpec(hp_ratio=8, near_fraction=0.4,
                                base_elems=2, cl=6))


def logical_rows(spec, state, g):
    """The data guest ``g`` sees: one row per logical page via
    ``gpt -> block_table -> pools`` (the layout invariant)."""
    cfg = spec.cfg
    lo, hi = spec.logical_range(g)
    gpa = np.asarray(state.gpt[lo:hi])
    hp, sub = gpa // cfg.hp_ratio, gpa % cfg.hp_ratio
    slots = np.asarray(state.block_table)[hp]
    near, far = np.asarray(state.near_pool), np.asarray(state.far_pool)
    return np.where((slots < cfg.n_near)[:, None],
                    near[np.minimum(slots, cfg.n_near - 1), sub],
                    far[np.maximum(slots - cfg.n_near, 0), sub])


class TestMigration:
    def test_extract_release_inject_roundtrip(self):
        """A full handoff back into the same lane restores every field of
        the state bit-for-bit (payload included)."""
        spec, s0 = migration_engine()
        warm, _ = engine.run(spec, s0, engine.SynthTrace(
            n_windows=3, accesses_per_window=96))
        pkg = migration.extract_guest(spec, warm, 1)
        man = pkg.manifest
        assert man["total_bytes"] == (man["payload_bytes"]
                                      + man["mapping_bytes"]
                                      + man["telemetry_bytes"])
        rel = migration.release_guest(spec, warm, 1)
        hp_lo, hp_hi = spec.hp_range(1)
        r = spec.cfg.hp_ratio
        assert (np.asarray(rel.rmap[hp_lo * r:hp_hi * r]) == int(FREE)).all()
        back = migration.inject_guest(spec, rel, 1, pkg)
        for f in dataclasses.fields(type(warm)):
            a, b = getattr(warm, f.name), getattr(back, f.name)
            items = a.items() if isinstance(a, dict) else [(f.name, a)]
            for k, x in items:
                y = b[k] if isinstance(b, dict) else b
                np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y), err_msg=f"roundtrip {k}")

    def test_migrate_preserves_logical_view_and_reclaims_source(self):
        spec, s0 = migration_engine()
        active = np.array([True, True, True, False])  # lane 3 is the spare
        cs = engine.init_churn(spec, s0, active=active)
        cs, _ = engine.run_churn(spec, cs, engine.SynthTrace(
            n_windows=3, accesses_per_window=96))
        before = logical_rows(spec, cs.state, 1)
        cs2, man = migration.migrate_guest(spec, cs, src=1, dst=3)
        np.testing.assert_array_equal(
            before, logical_rows(spec, cs2.state, 3),
            err_msg="guest-visible data changed across migration")
        act = np.asarray(cs2.active)
        assert not act[1] and act[3]
        hp_lo, hp_hi = spec.hp_range(1)
        r = spec.cfg.hp_ratio
        assert (np.asarray(cs2.state.rmap[hp_lo * r:hp_hi * r])
                == int(FREE)).all(), "source lane not reclaimed"
        assert man["total_bytes"] > 0
        # the stepper continues on the migrated carry (any mesh; the smoke
        # pins mesh-vs-single-process equality of this continuation)
        fs = faults.no_faults(4).crash(1, 0)
        cs3, se = engine.run_churn(spec, cs2, engine.SynthTrace(
            n_windows=3, accesses_per_window=96), faults=fs)
        assert np.asarray(se["active"])[:, 3].all()

    def test_migrate_rejects_busy_or_idle_lanes(self):
        spec, s0 = migration_engine()
        cs = engine.init_churn(spec, s0,
                               active=np.array([True, True, True, False]))
        with pytest.raises(ValueError, match="vacant"):
            migration.migrate_guest(spec, cs, src=0, dst=1)
        with pytest.raises(ValueError, match="not active"):
            migration.migrate_guest(spec, cs, src=3, dst=0)
        with pytest.raises(ValueError, match="both lane"):
            migration.migrate_guest(spec, cs, src=0, dst=0)
        with pytest.raises(TypeError, match="ChurnState"):
            migration.migrate_guest(spec, s0, src=0, dst=3)

    def test_migrate_rejects_geometry_mismatch(self):
        guests = tuple(engine.GuestSpec(n_logical=32 + 16 * (g % 2), cl=4)
                       for g in range(4))
        spec, s0 = engine.build(
            guests, engine.HostSpec(hp_ratio=8, base_elems=2, cl=6))
        cs = engine.init_churn(spec, s0,
                               active=np.array([True, False, True, False]))
        with pytest.raises(ValueError, match="geometry"):
            migration.migrate_guest(spec, cs, src=0, dst=1)

    def test_inject_requires_vacant_destination(self):
        spec, s0 = migration_engine()
        warm, _ = engine.run(spec, s0, engine.SynthTrace(
            n_windows=2, accesses_per_window=96))
        pkg = migration.extract_guest(spec, warm, 0)
        with pytest.raises(ValueError, match="vacant|holds allocated"):
            migration.inject_guest(spec, warm, 2, pkg)

    def test_quiesce_resume_flip_only_the_mask(self):
        spec, s0 = migration_engine()
        cs = engine.init_churn(spec, s0)
        q = migration.quiesce(cs, 2)
        assert not bool(np.asarray(q.active)[2])
        np.testing.assert_array_equal(np.asarray(q.state.rmap),
                                      np.asarray(cs.state.rmap))
        back = migration.resume(q, 2)
        np.testing.assert_array_equal(np.asarray(back.active),
                                      np.asarray(cs.active))


# --------------------------------------------------------------------------
# the coordinated 2-process x 2-devices matrix (INV-MULTIHOST-EXACT)
# --------------------------------------------------------------------------
MULTIPROCESS_CHECK = textwrap.dedent("""
    from repro.launch import multihost

    info = multihost.initialize()

    import jax
    import numpy as np

    from repro.core import engine, faults, sharding

    assert jax.process_count() == 2, jax.process_count()
    guests = tuple(
        engine.GuestSpec(n_logical=48 + 16 * (g % 2),
                         cl=(None if g % 3 == 0 else 3 + g % 5),
                         workload=["redis", "masim", "hash"][g % 3], seed=g)
        for g in range(5))  # 5 guests on 4 shards: padding + raggedness
    spec, state = engine.build(
        guests, engine.HostSpec(hp_ratio=8, near_fraction=0.4,
                                base_elems=2, cl=6))
    mesh = multihost.global_guest_mesh()
    assert sharding.mesh_size(mesh) == 4, mesh

    sources = dict(
        array=engine.ArrayTrace(
            engine.guest_traces(spec, n_windows=3, accesses_per_window=96)),
        synth=engine.SynthTrace(n_windows=3, accesses_per_window=96),
    )
    for src_name, source in sources.items():
        s_ref, a = engine.run(spec, state, source)
        for host_sharded in (False, True):
            s_sh, b = engine.run_sharded(spec, state, source, mesh=mesh,
                                         host_sharded=host_sharded)
            for k in a:
                np.testing.assert_array_equal(
                    a[k], b[k],
                    err_msg=f"{src_name}, host_sharded={host_sharded}: {k}")
            for x, y in zip(jax.tree_util.tree_leaves(s_ref),
                            jax.tree_util.tree_leaves(s_sh)):
                np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y),
                    err_msg=f"{src_name}, host_sharded={host_sharded}")
            print("OK", src_name, host_sharded, flush=True)

    # churn stepper with faults across the two processes
    fs = faults.no_faults(5).crash(1, 1).restart(2, 1)
    synth = engine.SynthTrace(n_windows=4, accesses_per_window=96)
    cs0 = engine.init_churn(spec, state)
    ref_cs, ref = engine.run_churn(spec, cs0, synth, faults=fs)
    sh_cs, sh = engine.run_churn(spec, cs0, synth, faults=fs, mesh=mesh)
    for k in ref:
        np.testing.assert_array_equal(ref[k], sh[k], err_msg=f"churn: {k}")
    for x, y in zip(jax.tree_util.tree_leaves(ref_cs),
                    jax.tree_util.tree_leaves(sh_cs)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg="churn state")
    print("OK churn", flush=True)
    print(f"[{info.process_id}] MATRIX OK", flush=True)
""")


class TestMultiprocessMatrix:
    def test_two_process_mesh_matches_single_process(self, tmp_path):
        """INV-MULTIHOST-EXACT acceptance matrix: ragged guests on a
        2-process x 2-device global mesh, array + synth sources, both host
        paths, and the churn stepper -- every check asserted inside each
        coordinated worker against that worker's own single-process run."""
        worker = tmp_path / "matrix_worker.py"
        worker.write_text(MULTIPROCESS_CHECK)
        results = multihost.launch_check(
            str(worker), marker="MATRIX OK", num_processes=2,
            devices_per_process=2, cwd=ROOT, timeout=600)
        assert len(results) == 2
        for r in results:
            assert r.stdout.count("OK") >= 6, r.stdout


# --------------------------------------------------------------------------
# collective-volume accounting + the pod migration protocol (§17)
# --------------------------------------------------------------------------
class TestCollectiveAccounting:
    def test_replicated_run_records_merge_window_bytes(self):
        from repro.core import sharding

        jax = pytest.importorskip("jax")
        if jax.local_device_count() < 2:
            pytest.skip("needs >= 2 devices for a mesh")
        spec, state = migration_engine()
        mesh = sharding.guest_mesh(2)
        synth = engine.SynthTrace(n_windows=2, accesses_per_window=64)
        sharding.reset_collective_bytes()
        assert sharding.collective_bytes() == {}
        engine.run_sharded(spec, state, synth, mesh=mesh,
                           host_sharded=False)
        rec = sharding.collective_bytes()
        assert rec.get("merge_window", 0) > 0
        # the ownership-merge payload carries at least the mapping arrays
        cfg = spec.cfg
        assert rec["merge_window"] >= 4 * (cfg.n_logical + cfg.n_gpa)

    def test_host_sharded_run_records_exchange_and_exit(self):
        from repro.core import sharding

        jax = pytest.importorskip("jax")
        if jax.local_device_count() < 2:
            pytest.skip("needs >= 2 devices for a mesh")
        spec, state = migration_engine()
        mesh = sharding.guest_mesh(2)
        synth = engine.SynthTrace(n_windows=2, accesses_per_window=64)
        sharding.reset_collective_bytes()
        engine.run_sharded(spec, state, synth, mesh=mesh, host_sharded=True)
        rec = sharding.collective_bytes()
        assert rec.get("host_exchange", 0) > 0
        assert rec.get("host_chunk_exit", 0) > 0
        sharding.reset_collective_bytes()
        assert sharding.collective_bytes() == {}


class TestPodMigration:
    def test_run_pod_migrations_payload(self, tmp_path, monkeypatch):
        """fig9_at_scale.run_pod(migrations=...) drives the §17 protocol:
        manifests, host-state report and collective accounting ride the
        payload, and every lane is active after the handoffs."""
        benchmarks = pytest.importorskip("benchmarks.fig9_at_scale")
        monkeypatch.chdir(tmp_path)  # common.save writes experiments/ here
        out = benchmarks.run_pod(n_guests=4, logical_per_guest=64,
                                 n_windows=4, accesses=64, migrations=1,
                                 mesh=None)
        assert out["n_migrations"] == 1
        res = out["memtierd"]
        assert len(res["migrations"]) == 1
        man = res["migrations"][0]
        assert man["src"] == 0 and man["dst"] == 4
        assert man["total_bytes"] == (man["payload_bytes"]
                                      + man["mapping_bytes"]
                                      + man["telemetry_bytes"])
        # the handoff preserves fleet occupancy: 4 lanes active throughout
        assert res["active_per_window"] == [4, 4, 4, 4]
        assert res["active_final"] == 4
        assert out["host_state"]["n_devices"] == 1
        assert (tmp_path / "experiments" / "benchmarks"
                / "fig9_at_pod_scale_migration.json").exists()
