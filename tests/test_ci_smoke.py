"""The CI smoke checks, de-inlined from .github/workflows/ci.yml.

CI and `pytest -m smoke` invoke the SAME `scripts/ci_smoke_*.py` entry
points, so the smoke code cannot drift from the library API: if a rename or
signature change breaks the workflow's smoke steps, it breaks these tests
first, locally.
"""
import importlib.util
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.smoke

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCRIPTS = os.path.join(ROOT, "scripts")


def load_script(name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(SCRIPTS, f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_ragged_smoke_runs_in_process():
    assert load_script("ci_smoke_ragged").main() == 0


def test_churn_smoke_runs_in_process():
    assert load_script("ci_smoke_churn").main() == 0


def test_sharded_smoke_runs_on_forced_mesh():
    """The 8-device smoke needs its own process: device count is fixed at
    jax init, exactly like CI's smoke step sets XLA_FLAGS for it."""
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        JAX_PLATFORMS="cpu",
        PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""),
    )
    proc = subprocess.run(
        [sys.executable, os.path.join(SCRIPTS, "ci_smoke_sharded.py")],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, (
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
    assert "sharded engine smoke OK" in proc.stdout


def test_tiers_smoke_runs_on_forced_mesh():
    """The N-tier smoke also needs the forced 8-device mesh (same reason as
    the sharded smoke: device count is fixed at jax init)."""
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        JAX_PLATFORMS="cpu",
        PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""),
    )
    proc = subprocess.run(
        [sys.executable, os.path.join(SCRIPTS, "ci_smoke_tiers.py")],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, (
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
    assert "tiers smoke OK" in proc.stdout


def test_multihost_smoke_launches_coordinated_job():
    """The multi-host smoke self-launches its 2-process x 2-device job; run
    it from a clean parent process exactly as CI's smoke step does (the
    launcher must not inherit a forced device count or live jax client)."""
    proc = subprocess.run(
        [sys.executable, os.path.join(SCRIPTS, "ci_smoke_multihost.py")],
        env=dict(os.environ), cwd=ROOT, capture_output=True, text=True,
        timeout=600,
    )
    assert proc.returncode == 0, (
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
    assert "multihost smoke: OK" in proc.stdout


def test_tiers_smoke_refuses_wrong_device_count():
    import jax

    if jax.local_device_count() != 1:
        pytest.skip("needs the suite's single-device environment")
    with pytest.raises(AssertionError, match="device_count"):
        load_script("ci_smoke_tiers").main()


def test_sharded_smoke_refuses_wrong_device_count():
    """Run in-process (single device): the script must fail loudly rather
    than silently smoke-test a 1-device mesh."""
    import jax

    if jax.local_device_count() != 1:
        pytest.skip("needs the suite's single-device environment")
    with pytest.raises(AssertionError, match="device_count"):
        load_script("ci_smoke_sharded").main()
