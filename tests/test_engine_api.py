"""Ragged multi-tenancy + the unified engine API.

The engine (``repro.core.engine``) must reproduce the sequential per-guest /
per-window formulation bit-for-bit even when guests are *asymmetric*
(distinct ``n_logical``, slack and per-guest CL), across every registered
policy, with gpac on and off, and independently of driver chunking. Also
covers the policy/telemetry/collector registries and GpacConfig validation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, gpac, telemetry, tiering
from repro.core.types import GpacConfig, init_state
from repro.data import traces as tr


def assert_states_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


HP = 16


def ragged_engine():
    """Three asymmetric guests: distinct sizes, slacks, CLs and workloads."""
    guests = (
        engine.GuestSpec(n_logical=96, cl=3, gpa_slack=0.5, workload="redis", seed=0),
        engine.GuestSpec(n_logical=176, cl=8, gpa_slack=0.25, workload="masim", seed=1),
        engine.GuestSpec(n_logical=64, cl=None, gpa_slack=1.0, workload="hash", seed=2),
    )
    host = engine.HostSpec(hp_ratio=HP, near_fraction=0.4, base_elems=2, cl=6)
    return engine.build(guests, host)


def ragged_traces(spec, n_windows=5, k=192):
    return engine.guest_traces(spec, n_windows=n_windows, accesses_per_window=k)


class TestRaggedGeometry:
    def test_segment_tables_tile_the_spaces(self):
        spec, state = ragged_engine()
        cfg = spec.cfg
        assert spec.logical_offsets[-1] == cfg.n_logical
        assert spec.hp_offsets[-1] == cfg.n_gpa_hp
        lp = spec.logical_pad_index()
        hp = spec.hp_pad_index()
        # every id appears exactly once; padding is -1
        np.testing.assert_array_equal(
            np.sort(lp[lp >= 0]), np.arange(cfg.n_logical))
        np.testing.assert_array_equal(
            np.sort(hp[hp >= 0]), np.arange(cfg.n_gpa_hp))
        cl = spec.cl_per_logical()
        assert cl.shape == (cfg.n_logical,)
        for g in range(spec.n_guests):
            lo, hi = spec.logical_range(g)
            assert (cl[lo:hi] == spec.guest_cl(g)).all()
        assert spec.guest_cl(2) == cfg.cl  # cl=None inherits the host default

    def test_localize_matches_per_guest_offsets(self):
        spec, _ = ragged_engine()
        k = 32
        rng = np.random.default_rng(0)
        acc = np.stack([
            rng.integers(-1, g.n_logical, size=k) for g in spec.guests
        ]).astype(np.int32)
        out = np.asarray(spec.localize(jnp.asarray(acc)))
        for g in range(spec.n_guests):
            lo, _ = spec.logical_range(g)
            ref = np.where(acc[g] >= 0, acc[g] + lo, -1)
            np.testing.assert_array_equal(out[g], ref)

    def test_pack_traces_pads_ragged_k(self):
        a = np.zeros((4, 8), np.int32)
        b = np.ones((4, 13), np.int32)
        packed = engine.pack_traces([a, b])
        assert packed.shape == (2, 4, 13)
        assert (packed[0, :, 8:] == -1).all()
        with pytest.raises(ValueError, match="n_windows"):
            engine.pack_traces([a, np.zeros((3, 8), np.int32)])


class TestRaggedEquivalence:
    @pytest.mark.parametrize("use_gpac", [False, True])
    @pytest.mark.parametrize("policy", sorted(tiering.POLICIES))
    def test_engine_matches_sequential_reference(self, policy, use_gpac):
        spec, s0 = ragged_engine()
        traces = ragged_traces(spec)
        ref_state, ref_series = engine.run_reference(
            spec, s0, traces, policy=policy, use_gpac=use_gpac)
        new_state, new_series = engine.run(
            spec, s0, traces, policy=policy, use_gpac=use_gpac)
        assert_states_equal(ref_state, new_state)
        assert set(ref_series) == set(new_series)
        for k in ref_series:
            np.testing.assert_array_equal(ref_series[k], new_series[k], err_msg=k)

    def test_single_window_matches_reference(self):
        spec, s0 = ragged_engine()
        acc = jnp.asarray(ragged_traces(spec, n_windows=1)[:, 0])
        ref_state, ref_out = engine.step_reference(spec, s0, acc)
        new_state, new_out = engine.step(spec, s0, acc)
        assert_states_equal(ref_state, new_state)
        for k in ref_out:
            np.testing.assert_array_equal(
                np.asarray(ref_out[k]), np.asarray(new_out[k]), err_msg=k)

    def test_chunking_is_invisible_on_shared_driver(self):
        spec, s0 = ragged_engine()
        traces = ragged_traces(spec, n_windows=7)
        full_state, full_series = engine.run(spec, s0, traces)
        for wps in (1, 3, 100):
            st, series = engine.run(spec, s0, traces, windows_per_step=wps)
            assert_states_equal(full_state, st)
            for k in full_series:
                np.testing.assert_array_equal(full_series[k], series[k], err_msg=k)

    def test_guests_confined_to_own_segments(self):
        spec, s0 = ragged_engine()
        state, _ = engine.run(spec, s0, ragged_traces(spec), use_gpac=True)
        gpt = np.asarray(state.gpt)
        for g in range(spec.n_guests):
            lo, hi = spec.logical_range(g)
            hp_lo, hp_hi = spec.hp_range(g)
            hp_of = gpt[lo:hi] // spec.cfg.hp_ratio
            assert (hp_of >= hp_lo).all() and (hp_of < hp_hi).all(), (
                f"guest {g} pages escaped its GPA segment")

    def test_single_guest_spec_matches_reference(self):
        cfg = GpacConfig(n_logical=256, hp_ratio=HP, base_elems=2, cl=6)
        spec = engine.spec_from_config(cfg)
        trace = tr.generate(tr.TraceSpec("redis", 256, HP, 5, 128, seed=3))[None]
        ref_state, ref_series = engine.run_reference(spec, init_state(cfg), trace)
        new_state, new_series = engine.run(spec, init_state(cfg), trace)
        assert_states_equal(ref_state, new_state)
        for k in ref_series:
            np.testing.assert_array_equal(ref_series[k], new_series[k], err_msg=k)

    def test_zero_windows(self):
        spec, s0 = ragged_engine()
        empty = np.zeros((spec.n_guests, 0, 64), np.int32)
        state, series = engine.run(spec, s0, empty)
        assert_states_equal(state, s0)
        assert series == {}
        _, vm = engine.run_series(spec, s0, empty)
        assert vm["near_blocks"].shape == (0, spec.n_guests)


class TestRunCollectArgs:
    def test_empty_collect_still_advances_state(self):
        spec, s0 = ragged_engine()
        traces = ragged_traces(spec, n_windows=3)
        state, series = engine.run(spec, s0, traces, collect=())
        assert series == {}
        # collectors only observe; disabling them must not change the run
        ref_state, _ = engine.run(spec, s0, traces)
        assert_states_equal(state, ref_state)

    def test_unknown_collector_fails_fast(self):
        spec, s0 = ragged_engine()
        traces = ragged_traces(spec, n_windows=2)
        with pytest.raises(ValueError, match="unknown metric collector"):
            engine.run(spec, s0, traces, collect=("hits", "nope"))
        # fail-fast: the bad name must raise before any window runs, even
        # when it follows valid collectors
        with pytest.raises(ValueError, match="nope"):
            engine.run(spec, s0, np.zeros((spec.n_guests, 0, 8), np.int32),
                       collect=("nope",))


class TestWindowsPerStepRounding:
    def wps_engine(self, n_logical):
        cfg = GpacConfig(n_logical=n_logical, hp_ratio=HP, base_elems=2, cl=6)
        spec = engine.spec_from_config(cfg)
        trace = tr.generate(
            tr.TraceSpec("redis", n_logical, HP, 10, 64, seed=0))[None]
        return spec, init_state(cfg), trace

    def test_round_wps_picks_largest_divisor(self):
        assert engine._round_wps(10, 4, strict=False) == 2
        assert engine._round_wps(10, 5, strict=False) == 5
        assert engine._round_wps(10, 0, strict=False) == 10
        assert engine._round_wps(10, 100, strict=False) == 10
        assert engine._round_wps(10, 4, strict=True) == 4

    def test_round_wps_guards_against_chunk_blowup(self):
        # coprime request: the only divisor is 1, which would mean one
        # dispatch per window -- keep the requested size instead (the
        # trailing chunk's one extra compile is the lesser cost)
        assert engine._round_wps(7, 3, strict=False) == 3
        assert engine._round_wps(23, 12, strict=False) == 12
        # mild rounding (chunk count grows < 2x) still prefers one shape
        assert engine._round_wps(24, 9, strict=False) == 8

    def test_strict_wps_pays_extra_compile_rounding_does_not(self):
        # a non-dividing wps leaves a shorter trailing chunk -> a second scan
        # shape -> one extra trace/compile; the rounded default keeps one
        spec_a, s_a, tr_a = self.wps_engine(192)
        before = engine._run_chunk._cache_size()
        engine.run(spec_a, s_a, tr_a, windows_per_step=4, strict_wps=True)
        assert engine._run_chunk._cache_size() == before + 2  # chunks 4,4,2

        spec_b, s_b, tr_b = self.wps_engine(208)  # fresh static key
        before = engine._run_chunk._cache_size()
        engine.run(spec_b, s_b, tr_b, windows_per_step=4)  # rounds to 2
        assert engine._run_chunk._cache_size() == before + 1

    def test_rounded_and_strict_chunking_agree_bitwise(self):
        spec, s0, trace = self.wps_engine(176)
        st_r, se_r = engine.run(spec, s0, trace, windows_per_step=4)
        st_s, se_s = engine.run(spec, s0, trace, windows_per_step=4,
                                strict_wps=True)
        assert_states_equal(st_r, st_s)
        for k in se_r:
            np.testing.assert_array_equal(se_r[k], se_s[k], err_msg=k)


class TestDeprecationShims:
    """The pre-engine entry points must say they are shims."""

    def small_mg(self):
        from repro.core import simulate

        with pytest.warns(DeprecationWarning, match="make_multi_guest"):
            return simulate.make_multi_guest(
                n_guests=2, logical_per_guest=64, hp_ratio=HP,
                near_fraction=0.5, base_elems=2, cl=6)

    def test_make_multi_guest_warns(self):
        self.small_mg()

    def test_multi_guest_window_warns(self):
        from repro.core import simulate

        mg, state = self.small_mg()
        acc = np.zeros((2, 16), np.int32)
        with pytest.warns(DeprecationWarning, match="multi_guest_window"):
            simulate.multi_guest_window(mg, state, jnp.asarray(acc))

    def test_run_multi_guest_warns(self):
        from repro.core import simulate

        mg, state = self.small_mg()
        traces = np.zeros((2, 2, 16), np.int32)
        with pytest.warns(DeprecationWarning, match="run_multi_guest"):
            simulate.run_multi_guest(mg, state, traces)

    def test_gpac_run_windows_warns(self):
        cfg = GpacConfig(n_logical=64, hp_ratio=HP, base_elems=2, cl=6)
        trace = np.zeros((2, 16), np.int32)
        with pytest.warns(DeprecationWarning, match="run_windows"):
            gpac.run_windows(cfg, init_state(cfg), trace)


class TestRegistries:
    def test_unknown_policy_and_backend_list_registered(self):
        cfg = GpacConfig(n_logical=64, hp_ratio=16, base_elems=2, cl=4)
        state = init_state(cfg)
        with pytest.raises(ValueError, match="memtierd"):
            tiering.tick(cfg, state, "nope")
        with pytest.raises(ValueError, match="ipt"):
            telemetry.hot_mask(cfg, state, "nope")
        with pytest.raises(ValueError, match="snapshot"):
            engine.get_collector("nope")

    def test_duplicate_registration_raises(self):
        with pytest.raises(ValueError, match="already registered"):
            tiering.register_policy("memtierd", tiering.memtierd_tick)
        with pytest.raises(ValueError, match="already registered"):
            telemetry.register_backend("ipt", telemetry.hot_mask_ipt)
        with pytest.raises(ValueError, match="already registered"):
            engine.register_collector("hits", lambda *a: {})

    def test_custom_policy_plugs_into_engine(self):
        if "frozen" not in tiering.policies():
            @tiering.register_policy("frozen")
            def _frozen_tick(cfg, state, budget=0, **kw):
                return state  # placement never changes

        assert "frozen" in tiering.policies()
        spec, s0 = ragged_engine()
        traces = ragged_traces(spec, n_windows=3)
        state, series = engine.run(spec, s0, traces, policy="frozen")
        ref_state, ref_series = engine.run_reference(spec, s0, traces, policy="frozen")
        assert_states_equal(state, ref_state)
        for k in ref_series:
            np.testing.assert_array_equal(ref_series[k], series[k], err_msg=k)
        # a frozen host never migrates; with gpac off nothing moves at all,
        # so the per-guest near-block series is constant
        _, still = engine.run(spec, s0, traces, policy="frozen", use_gpac=False)
        assert (still["near_blocks"] == still["near_blocks"][0]).all()

    def test_custom_backend_plugs_into_engine(self):
        if "cold" not in telemetry.backends():
            @telemetry.register_backend("cold")
            def _cold(cfg, state, **kw):
                return jnp.zeros((cfg.n_logical,), bool)  # nothing is hot

        spec, s0 = ragged_engine()
        traces = ragged_traces(spec, n_windows=3)
        state, _ = engine.run(spec, s0, traces, backend="cold", use_gpac=True)
        # no hot pages -> the filter selects nothing -> no pages consolidated
        assert int(state.stats["consolidated_pages"]) == 0

    def test_colliding_collector_keys_raise(self):
        spec, s0 = ragged_engine()
        traces = ragged_traces(spec, n_windows=2)
        # 'hits' emits per-guest near_hits/far_hits; 'snapshot' emits the
        # cumulative host-wide counters under the same names
        with pytest.raises(ValueError, match="already produced"):
            engine.run(spec, s0, traces, collect=("hits", "snapshot"))

    def test_custom_collector_runs_on_device(self):
        if "rss" not in engine.collectors():
            @engine.register_collector("rss")
            def _rss(spec, state, window):
                from repro.core.types import allocated_hp_mask
                return dict(rss_blocks=allocated_hp_mask(spec.cfg, state).sum())

        spec, s0 = ragged_engine()
        traces = ragged_traces(spec, n_windows=4)
        _, series = engine.run(spec, s0, traces, collect=("hits", "rss"))
        assert series["rss_blocks"].shape == (4,)
        assert (series["rss_blocks"] > 0).all()
        assert set(series) == {"near_hits", "far_hits", "rss_blocks"}


class TestGpacConfigValidation:
    def test_near_tier_must_leave_far_capacity(self):
        with pytest.raises(ValueError, match="n_near"):
            GpacConfig(n_logical=64, hp_ratio=16, n_gpa_hp=8, n_near=8)

    def test_gpa_space_must_cover_logical(self):
        with pytest.raises(ValueError, match="cover"):
            GpacConfig(n_logical=1024, hp_ratio=16, n_gpa_hp=4, n_near=2)

    def test_cl_bounded_by_hp_ratio(self):
        with pytest.raises(ValueError, match="Consolidation Limit"):
            GpacConfig(n_logical=64, hp_ratio=16, cl=17)

    def test_degenerate_sizes(self):
        with pytest.raises(ValueError, match="n_logical"):
            GpacConfig(n_logical=0)
        with pytest.raises(ValueError, match="hp_ratio"):
            GpacConfig(n_logical=64, hp_ratio=0)

    def test_valid_config_unaffected(self):
        cfg = GpacConfig(n_logical=64, hp_ratio=16, base_elems=2, cl=4)
        assert cfg.n_near < cfg.n_gpa_hp
