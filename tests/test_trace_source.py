"""TraceSource API: ArrayTrace/SynthTrace sources, the deprecated ``traces=``
shim, on-device synthesis invariants, and the JAX generators' distributional
equivalence against their numpy references.

The synthesis invariants are the load-bearing ones (ISSUE 5 acceptance):
SynthTrace runs must be bit-identical across ``windows_per_step`` chunkings
and between ``engine.run`` and ``engine.run_sharded`` (the multi-device
matrix rides the forced-8-device subprocess in tests/test_host_sharding.py
and scripts/ci_smoke_sharded.py), because the per-window accesses are
derived from counter-based RNG keyed only on (seed, global guest id,
absolute window index).
"""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.core import engine, sharding
from repro.data import traces as tr


def assert_states_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def mixed_engine():
    guests = (
        engine.GuestSpec(n_logical=96, cl=3, gpa_slack=0.5, workload="redis", seed=0),
        engine.GuestSpec(n_logical=176, cl=8, gpa_slack=0.25, workload="masim", seed=1),
        engine.GuestSpec(n_logical=64, cl=None, gpa_slack=1.0, workload="hash", seed=2),
    )
    host = engine.HostSpec(hp_ratio=16, near_fraction=0.4, base_elems=2, cl=6)
    return engine.build(guests, host)


class TestTraceSourceAPI:
    def test_array_wraps_and_matches_array_trace(self):
        spec, s0 = mixed_engine()
        traces = engine.guest_traces(spec, n_windows=3, accesses_per_window=64)
        st_raw, se_raw = engine.run(spec, s0, traces)
        st_src, se_src = engine.run(spec, s0, engine.ArrayTrace(traces))
        assert_states_equal(st_raw, st_src)
        for k in se_raw:
            np.testing.assert_array_equal(se_raw[k], se_src[k], err_msg=k)

    def test_traces_keyword_warns_and_wraps(self):
        spec, s0 = mixed_engine()
        traces = engine.guest_traces(spec, n_windows=3, accesses_per_window=64)
        st_pos, se_pos = engine.run(spec, s0, traces)
        with pytest.warns(DeprecationWarning, match="traces="):
            st_kw, se_kw = engine.run(spec, s0, traces=traces)
        assert_states_equal(st_pos, st_kw)
        for k in se_pos:
            np.testing.assert_array_equal(se_pos[k], se_kw[k], err_msg=k)

    def test_both_source_and_traces_raises(self):
        spec, s0 = mixed_engine()
        traces = engine.guest_traces(spec, n_windows=2, accesses_per_window=32)
        with pytest.raises(TypeError, match="not both"):
            engine.run(spec, s0, traces, traces=traces)

    def test_missing_source_raises(self):
        spec, s0 = mixed_engine()
        with pytest.raises(TypeError, match="trace source"):
            engine.run(spec, s0)

    def test_as_trace_source_rejects_garbage(self):
        with pytest.raises(TypeError, match="TraceSource"):
            engine.as_trace_source(object())

    def test_synth_trace_validation(self):
        with pytest.raises(ValueError, match="accesses_per_window"):
            engine.SynthTrace(n_windows=4, accesses_per_window=0)
        with pytest.raises(ValueError, match="n_windows"):
            engine.SynthTrace(n_windows=-1, accesses_per_window=8)

    def test_unknown_workload_lists_live_set(self):
        spec, s0 = mixed_engine()
        synth = engine.SynthTrace(
            n_windows=2, accesses_per_window=32,
            workloads=("redis", "nope", "hash"))
        with pytest.raises(ValueError, match="masim"):
            engine.run(spec, s0, synth)

    def test_wrong_length_workloads_raises(self):
        spec, s0 = mixed_engine()
        synth = engine.SynthTrace(
            n_windows=2, accesses_per_window=32, workloads=("redis",))
        with pytest.raises(ValueError, match="one entry per guest"):
            engine.run(spec, s0, synth)

    def test_register_workload_duplicate_raises(self):
        with pytest.raises(ValueError, match="already registered"):
            tr.register_workload("redis", tr.redis)

    def test_get_workload_unknown_raises(self):
        with pytest.raises(ValueError, match="unknown workload"):
            tr.get_workload("nope")

    def test_empty_synth_returns_empty_series(self):
        spec, s0 = mixed_engine()
        state, series = engine.run(
            spec, s0, engine.SynthTrace(n_windows=0, accesses_per_window=8))
        assert series == {}
        assert_states_equal(state, s0)


class TestSynthEngine:
    def test_chunking_invariance(self):
        spec, s0 = mixed_engine()
        synth = engine.SynthTrace(n_windows=6, accesses_per_window=128)
        ref_state, ref = engine.run(spec, s0, synth)
        for wps in (1, 2, 3):
            st, se = engine.run(spec, s0, synth, windows_per_step=wps)
            assert_states_equal(ref_state, st)
            for k in ref:
                np.testing.assert_array_equal(ref[k], se[k], err_msg=(wps, k))

    @pytest.mark.parametrize("host_sharded", [False, True])
    def test_sharded_bit_equal_on_1_device_mesh(self, host_sharded):
        spec, s0 = mixed_engine()
        synth = engine.SynthTrace(n_windows=5, accesses_per_window=128)
        mesh = sharding.guest_mesh(1)
        ref_state, ref = engine.run(spec, s0, synth)
        sh_state, sh = engine.run_sharded(
            spec, s0, synth, mesh=mesh, host_sharded=host_sharded)
        assert_states_equal(ref_state, sh_state)
        assert set(ref) == set(sh)
        for k in ref:
            np.testing.assert_array_equal(ref[k], sh[k], err_msg=k)

    def test_explicit_workload_seed_overrides(self):
        """SynthTrace workloads/seeds override the GuestSpec identities:
        overriding to guest identities of a differently-built spec must
        reproduce that spec's synthesis."""
        spec, s0 = mixed_engine()
        base = engine.SynthTrace(n_windows=4, accesses_per_window=64)
        over = engine.SynthTrace(
            n_windows=4, accesses_per_window=64,
            workloads=tuple(g.workload for g in spec.guests),
            seeds=tuple(g.seed for g in spec.guests))
        st_a, se_a = engine.run(spec, s0, base)
        st_b, se_b = engine.run(spec, s0, over)
        assert_states_equal(st_a, st_b)
        for k in se_a:
            np.testing.assert_array_equal(se_a[k], se_b[k], err_msg=k)
        # a different seed assignment must change the run
        other = engine.SynthTrace(
            n_windows=4, accesses_per_window=64,
            seeds=tuple(g.seed + 101 for g in spec.guests))
        _, se_c = engine.run(spec, s0, other)
        assert any(
            not np.array_equal(se_a[k], se_c[k]) for k in se_a
        ), "seed override did not change the synthesized run"

    def test_seed_sweep_does_not_recompile(self):
        """Seeds are traced table entries, not static jit keys: sweeping
        them reuses the compiled synth chunk (the same discipline
        spec.canonical() enforces for the array path)."""
        spec, s0 = mixed_engine()
        engine.run(spec, s0, engine.SynthTrace(n_windows=2, accesses_per_window=32))
        before = engine._run_chunk_synth._cache_size()
        for ds in (7, 21, 42):
            engine.run(spec, s0, engine.SynthTrace(
                n_windows=2, accesses_per_window=32,
                seeds=tuple(g.seed + ds for g in spec.guests)))
        assert engine._run_chunk_synth._cache_size() == before

    def test_run_series_accepts_synth(self):
        spec, s0 = mixed_engine()
        synth = engine.SynthTrace(n_windows=4, accesses_per_window=64)
        state, series = engine.run_series(spec, s0, synth)
        assert set(series) == {"near_blocks", "hit_rate", "throughput"}
        assert series["hit_rate"].shape == (4, spec.n_guests)

    def test_run_series_traces_keyword_warns_and_wraps(self):
        spec, s0 = mixed_engine()
        arr = engine.guest_traces(spec, n_windows=3, accesses_per_window=32)
        _, pos = engine.run_series(spec, s0, arr)
        with pytest.warns(DeprecationWarning, match="traces="):
            _, kw = engine.run_series(spec, s0, traces=arr)
        for k in pos:
            np.testing.assert_array_equal(pos[k], kw[k], err_msg=k)

    def test_run_series_malformed_array_raises_value_error(self):
        spec, s0 = mixed_engine()
        with pytest.raises(ValueError, match="n_guests"):
            engine.run_series(spec, s0, np.zeros((5,), np.int32))

    def test_n_windows_sweep_does_not_recompile(self):
        """SynthPlan deliberately excludes n_windows: sweeping the trace
        length at a fixed chunk shape reuses the compiled scan."""
        spec, s0 = mixed_engine()
        engine.run(spec, s0, engine.SynthTrace(n_windows=2, accesses_per_window=32),
                   windows_per_step=2)
        before = engine._run_chunk_synth._cache_size()
        for n_w in (4, 6, 8):
            engine.run(spec, s0,
                       engine.SynthTrace(n_windows=n_w, accesses_per_window=32),
                       windows_per_step=2)
        assert engine._run_chunk_synth._cache_size() == before


class TestGuestTracesMemoized:
    def _count_calls(self, monkeypatch):
        calls = []
        real = tr.generate

        def counting(spec, **kw):
            calls.append(spec)
            return real(spec, **kw)

        monkeypatch.setattr(tr, "generate", counting)
        return calls

    def test_symmetric_fleet_generates_once(self, monkeypatch):
        calls = self._count_calls(monkeypatch)
        guests = tuple(
            engine.GuestSpec(n_logical=64, workload="redis", seed=0)
            for _ in range(5))
        spec, _ = engine.build(
            guests, engine.HostSpec(hp_ratio=16, near_fraction=0.5,
                                    base_elems=2, cl=6))
        traces = engine.guest_traces(spec, n_windows=2, accesses_per_window=32)
        assert len(calls) == 1
        assert traces.shape == (5, 2, 32)
        for g in range(1, 5):
            np.testing.assert_array_equal(traces[0], traces[g])

    def test_distinct_guests_generate_separately(self, monkeypatch):
        calls = self._count_calls(monkeypatch)
        guests = (
            engine.GuestSpec(n_logical=64, workload="redis", seed=0),
            engine.GuestSpec(n_logical=64, workload="redis", seed=1),
            engine.GuestSpec(n_logical=64, workload="redis", seed=0),  # dup of 0
            engine.GuestSpec(n_logical=96, workload="redis", seed=0),  # size differs
        )
        spec, _ = engine.build(
            guests, engine.HostSpec(hp_ratio=16, near_fraction=0.5,
                                    base_elems=2, cl=6))
        engine.guest_traces(spec, n_windows=2, accesses_per_window=32)
        assert len(calls) == 3  # seeds {0,1} at 64 pages + seed 0 at 96


def synth_profile(workload, n_logical=4096, hp_ratio=64, k=8192, seed=0):
    spec = tr.TraceSpec(workload, n_logical, hp_ratio, n_windows=4,
                        accesses_per_window=k, seed=seed)
    t = tr.synth_generate(spec)
    assert t.shape == (4, k) and t.dtype == np.int32
    assert (t >= 0).all() and (t < n_logical).all()
    pages = np.unique(t)
    per_hp = np.bincount(pages // hp_ratio, minlength=n_logical // hp_ratio)
    return per_hp[per_hp > 0]


def numpy_profile(workload, n_logical=4096, hp_ratio=64, k=8192, seed=0):
    spec = tr.TraceSpec(workload, n_logical, hp_ratio, n_windows=4,
                        accesses_per_window=k, seed=seed)
    t = tr.generate(spec)
    pages = np.unique(t)
    per_hp = np.bincount(pages // hp_ratio, minlength=n_logical // hp_ratio)
    return per_hp[per_hp > 0]


class TestSynthDistributionalEquivalence:
    """Each JAX window generator reproduces its numpy reference's skew
    structure: the same Fig. 2/16-style per-huge-page hot-subpage profile
    (medians within tolerance), plus the workload-specific shape assertions
    the numpy generators are pinned by in test_traces_and_simulate."""

    @pytest.mark.parametrize("workload", sorted(tr.workloads()))
    def test_per_hp_profile_matches_numpy(self, workload):
        a = numpy_profile(workload)
        b = synth_profile(workload)
        med_a, med_b = np.median(a), np.median(b)
        assert abs(med_a - med_b) <= max(2, 0.2 * med_a), (
            f"{workload}: numpy median {med_a}, jax median {med_b}")
        q_a, q_b = np.quantile(a, 0.75), np.quantile(b, 0.75)
        assert abs(q_a - q_b) <= max(3, 0.25 * q_a), (
            f"{workload}: numpy q75 {q_a}, jax q75 {q_b}")

    def test_masim_maximal_skew(self):
        assert (synth_profile("masim") == 1).all()

    def test_redis_scattered(self):
        assert np.quantile(synth_profile("redis"), 0.75) < 0.25 * 64

    def test_liblinear_dense(self):
        assert np.median(synth_profile("liblinear")) > 0.9 * 64

    def test_hash_moderate(self):
        med = np.median(synth_profile("hash")) / 64
        assert 0.1 < med < 0.9

    def test_determinism_per_workload_and_seed(self):
        for workload in tr.workloads():
            spec = tr.TraceSpec(workload, 1024, 16, 2, 256, seed=7)
            np.testing.assert_array_equal(
                tr.synth_generate(spec), tr.synth_generate(spec),
                err_msg=workload)

    def test_seed_and_gid_change_streams(self):
        spec7 = tr.TraceSpec("redis", 1024, 16, 2, 256, seed=7)
        spec8 = dataclasses.replace(spec7, seed=8)
        assert not np.array_equal(tr.synth_generate(spec7),
                                  tr.synth_generate(spec8))
        # the global guest id folds into the key: clones with one seed get
        # decorrelated streams, but the same (seed, gid) is reproducible
        assert not np.array_equal(tr.synth_generate(spec7, gid=0),
                                  tr.synth_generate(spec7, gid=1))

    def test_large_guest_no_int32_overflow(self):
        """The stride workloads multiply arange(k) by O(n_logical) values;
        at paper-scale guests (~1M base pages) the direct int32 product
        wraps. liblinear is RNG-free, so the JAX window must equal the
        (int64) numpy reference exactly; ocean_ncp must still span its
        ~60%-of-space window rather than the wrapped prefix."""
        n = 1_000_000
        spec = tr.TraceSpec("liblinear", n, 512, 1, 2048, seed=0)
        np.testing.assert_array_equal(tr.generate(spec), tr.synth_generate(spec))
        spec_o = tr.TraceSpec("ocean_ncp", n, 512, 2, 2048, seed=0)
        t = tr.synth_generate(spec_o)
        assert (t >= 0).all() and (t < n).all()
        for w in range(t.shape[0]):
            width = t[w].max() - t[w].min()
            assert width > 0.5 * n, f"window {w} spans only {width} pages"

    def test_plan_requires_window_fn(self):
        name = "_test_numpy_only_workload"
        tr.register_workload(name, tr.liblinear)
        try:
            with pytest.raises(ValueError, match="no on-device window"):
                tr.SynthPlan(
                    workload_set=(name,),
                    accesses_per_window=8, hp_ratio=16, max_logical=64)
        finally:
            tr._WORKLOADS.pop(name, None)
