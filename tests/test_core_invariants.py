"""Invariant + property tests for the GPAC core (DESIGN.md §10).

The invariants mirror what the paper's kernel code must maintain:
  * page tables stay bijective on allocated pages (gpt/rmap, block_table/slot_owner);
  * Algorithm 1 (consolidate_pages) and tier migration (swap_blocks) preserve
    every logical page's payload byte-for-byte;
  * consolidation monotonically reduces the number of skewed-hot huge pages;
  * tier policies never exceed near-tier capacity (structurally impossible,
    checked anyway) and never touch guest-level state.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import strategies  # central hypothesis gate + shared geometry draws
from hypothesis import given, settings, strategies as st

from repro.core import (
    GpacConfig,
    address_space as asp,
    consolidator,
    filter as pfilter,
    gpac,
    init_state,
    start_all_far,
    telemetry,
    tiering,
)
from repro.core.types import FREE, allocated_hp_mask


def small_cfg(**kw):
    d = dict(n_logical=96, hp_ratio=16, n_gpa_hp=10, n_near=4, base_elems=4, cl=8)
    d.update(kw)
    return GpacConfig(**d)


def payload(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(cfg.n_logical, cfg.base_elems)), jnp.float32)


def check_invariants(cfg, state):
    gpt = np.asarray(state.gpt)
    rmap = np.asarray(state.rmap)
    bt = np.asarray(state.block_table)
    so = np.asarray(state.slot_owner)
    # gpt injective, rmap is its inverse
    assert len(np.unique(gpt)) == cfg.n_logical, "gpt not injective"
    assert (rmap[gpt] == np.arange(cfg.n_logical)).all(), "rmap∘gpt != id"
    mapped = np.zeros(cfg.n_gpa, bool)
    mapped[gpt] = True
    assert (rmap[~mapped] == -1).all(), "unmapped gpa pages must have rmap FREE"
    # block table is a permutation and slot_owner is its inverse
    assert sorted(bt) == list(range(cfg.n_slots)), "block_table not a permutation"
    assert (so[bt] == np.arange(cfg.n_gpa_hp)).all(), "slot_owner∘block_table != id"


class TestInitAndTranslation:
    def test_identity_init(self):
        cfg = small_cfg()
        state = init_state(cfg)
        check_invariants(cfg, state)
        assert int(state.epoch) == 0

    def test_read_write_roundtrip(self):
        cfg = small_cfg()
        data = payload(cfg)
        state = init_state(cfg, fill=data)
        got = asp.read_logical(cfg, state, jnp.arange(cfg.n_logical, dtype=jnp.int32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(data))

    def test_invalid_ids_read_zero_and_drop_writes(self):
        cfg = small_cfg()
        state = init_state(cfg, fill=payload(cfg))
        bad = jnp.asarray([-1, cfg.n_logical, 5], jnp.int32)
        out = asp.read_logical(cfg, state, bad)
        assert (np.asarray(out[:2]) == 0).all()
        before = np.asarray(asp.read_logical(cfg, state, jnp.arange(cfg.n_logical)))
        state2 = asp.write_logical(cfg, state, bad[:2], jnp.ones((2, cfg.base_elems)))
        after = np.asarray(asp.read_logical(cfg, state2, jnp.arange(cfg.n_logical)))
        np.testing.assert_array_equal(before, after)

    def test_fused_translation_matches_two_level(self):
        cfg = small_cfg()
        state = init_state(cfg, fill=payload(cfg))
        state = start_all_far(cfg, state)
        ids = jnp.arange(cfg.n_logical, dtype=jnp.int32)
        slot, off, _ = asp.translate(cfg, state, ids)
        fused = asp.fused_translation(cfg, state)
        np.testing.assert_array_equal(
            np.asarray(slot * cfg.hp_ratio + off), np.asarray(fused)
        )

    def test_start_all_far_moves_all_allocated(self):
        cfg = small_cfg()
        state = start_all_far(cfg, init_state(cfg, fill=payload(cfg)))
        check_invariants(cfg, state)
        alloc = np.asarray(allocated_hp_mask(cfg, state))
        in_near = np.asarray(state.block_table) < cfg.n_near
        assert not (alloc & in_near).any(), "allocated blocks must start far"
        got = asp.read_logical(cfg, state, jnp.arange(cfg.n_logical, dtype=jnp.int32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(payload(cfg)))


class TestConsolidator:
    def test_algorithm1_preserves_data_and_invariants(self):
        cfg = small_cfg()
        data = payload(cfg)
        state = init_state(cfg, fill=data)
        # scatter: one hot page inside each of the first 6 huge pages
        pages = jnp.asarray(
            [h * cfg.hp_ratio + 3 for h in range(6)] + [-1] * (cfg.hp_ratio - 6),
            jnp.int32,
        )
        state = consolidator.consolidate_pages(cfg, state, pages)
        check_invariants(cfg, state)
        got = asp.read_logical(cfg, state, jnp.arange(cfg.n_logical, dtype=jnp.int32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(data))
        # the 6 pages now live in one huge page
        hp = np.asarray(state.gpt)[np.asarray(pages[:6])] // cfg.hp_ratio
        assert len(set(hp.tolist())) == 1
        assert int(state.stats["consolidated_pages"]) == 6
        assert int(state.stats["consolidation_calls"]) == 1

    def test_enomem_when_no_free_region(self):
        # n_logical == n_gpa -> no fully free huge page exists
        cfg = GpacConfig(
            n_logical=64, hp_ratio=16, n_gpa_hp=4, n_near=2, base_elems=4, cl=8
        )
        state = init_state(cfg, fill=payload(cfg))
        pages = jnp.asarray([1, 17] + [-1] * 14, jnp.int32)
        st2 = consolidator.consolidate_pages(cfg, state, pages)
        check_invariants(cfg, st2)
        assert int(st2.stats["consolidation_enomem"]) == 1
        np.testing.assert_array_equal(np.asarray(st2.gpt), np.asarray(state.gpt))

    def test_empty_batch_is_noop(self):
        cfg = small_cfg()
        state = init_state(cfg, fill=payload(cfg))
        st2 = consolidator.consolidate_pages(
            cfg, state, jnp.full((cfg.hp_ratio,), -1, jnp.int32)
        )
        assert int(st2.stats["consolidation_calls"]) == 0
        np.testing.assert_array_equal(np.asarray(st2.gpt), np.asarray(state.gpt))


class TestTiering:
    def test_swap_preserves_data(self):
        cfg = small_cfg()
        data = payload(cfg)
        state = init_state(cfg, fill=data)
        far_ids = jnp.asarray([4, 5, -1], jnp.int32)  # hp 4,5 start far (n_near=4)
        near_ids = jnp.asarray([0, 1, -1], jnp.int32)
        state = tiering.swap_blocks(cfg, state, far_ids, near_ids, jnp.int32(2))
        check_invariants(cfg, state)
        got = asp.read_logical(cfg, state, jnp.arange(cfg.n_logical, dtype=jnp.int32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(data))
        assert int(state.stats["promoted_blocks"]) == 2
        assert int(state.stats["demoted_blocks"]) == 2

    def test_swap_rejects_mismatched_tiers(self):
        cfg = small_cfg()
        state = init_state(cfg, fill=payload(cfg))
        # both already near -> dropped
        st2 = tiering.swap_blocks(
            cfg, state, jnp.asarray([0], jnp.int32), jnp.asarray([1], jnp.int32), 1
        )
        np.testing.assert_array_equal(
            np.asarray(st2.block_table), np.asarray(state.block_table)
        )

    @pytest.mark.parametrize("policy", tiering.POLICIES)
    def test_policies_preserve_data_and_never_touch_guest_state(self, policy):
        cfg = small_cfg()
        data = payload(cfg)
        state = start_all_far(cfg, init_state(cfg, fill=data))
        # make huge pages 0 and 1 hot in the host view
        hot_pages = jnp.arange(2 * cfg.hp_ratio, dtype=jnp.int32)
        for _ in range(3):
            state = asp.record_accesses(cfg, state, hot_pages)
            state = tiering.tick(cfg, state, policy)
            gpt_before = np.asarray(state.gpt)
            state = telemetry.end_window(cfg, state)
            np.testing.assert_array_equal(np.asarray(state.gpt), gpt_before)
        check_invariants(cfg, state)
        got = asp.read_logical(cfg, state, jnp.arange(cfg.n_logical, dtype=jnp.int32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(data))
        # hot blocks should have been promoted by every policy
        bt = np.asarray(state.block_table)
        assert (bt[:2] < cfg.n_near).all(), f"{policy} failed to promote hot blocks"


class TestGpacEndToEnd:
    def test_consolidation_densifies_and_reduces_near_usage(self):
        """The paper's headline mechanism: scattered hot pages -> GPAC -> fewer
        hot huge pages at host -> less near memory used at equal hit rate."""
        from repro.core import metrics

        cfg = GpacConfig(
            n_logical=512, hp_ratio=16, n_gpa_hp=48, n_near=16, base_elems=4, cl=8,
            ipt_min_hits=1,
        )
        # one hot base page per huge page (maximally skewed, like Masim)
        hot = jnp.asarray(
            [h * cfg.hp_ratio for h in range(cfg.n_logical // cfg.hp_ratio)],
            jnp.int32,
        )
        results = {}
        for use_gpac in (False, True):
            state = start_all_far(cfg, init_state(cfg, fill=payload(cfg)))
            # 12 windows: the 8-deep access-bit history must age out before
            # memtierd's proactive demotion classifies a block as cold.
            for _ in range(12):
                state = gpac.window_step(
                    cfg, state, hot, policy="memtierd", use_gpac=use_gpac
                )
            check_invariants(cfg, state)
            # data survival
            got = asp.read_logical(cfg, state, jnp.arange(cfg.n_logical, dtype=jnp.int32))
            np.testing.assert_allclose(np.asarray(got), np.asarray(payload(cfg)))
            alloc = np.asarray(allocated_hp_mask(cfg, state))
            in_near = np.asarray(state.block_table) < cfg.n_near
            results[use_gpac] = dict(
                near_blocks=int((alloc & in_near).sum()),
                hit=float(metrics.hit_rate(state)),
            )
        # GPAC must serve the hot set from strictly fewer near blocks
        assert results[True]["near_blocks"] < results[False]["near_blocks"]
        # and with a hit rate at least as good at steady state
        assert results[True]["hit"] >= results[False]["hit"] - 0.05

    def test_skewed_hot_count_decreases(self):
        cfg = small_cfg(n_logical=128, n_gpa_hp=12)
        state = init_state(cfg, fill=payload(cfg))
        hot_ids = jnp.asarray([0, 17, 33, 49, 65], jnp.int32)  # 1 per huge page
        state = asp.record_accesses(cfg, state, hot_ids)
        hot = telemetry.hot_mask(cfg, state, "ipt")
        before = np.asarray(telemetry.hot_subpages_per_hp(cfg, state, hot))
        skew_before = int(((before > 0) & (before < cfg.cl)).sum())
        state = gpac.gpac_maintenance(cfg, state, "ipt", max_batches=2)
        hot = telemetry.hot_mask(cfg, state, "ipt")
        after = np.asarray(telemetry.hot_subpages_per_hp(cfg, state, hot))
        skew_after = int(((after > 0) & (after < cfg.cl)).sum())
        assert skew_after < skew_before
        assert skew_after <= 1  # at most the (possibly partial) fresh region

    @pytest.mark.parametrize("backend", telemetry.BACKENDS)
    @pytest.mark.parametrize("policy", tiering.POLICIES)
    def test_agnosticism_matrix(self, backend, policy):
        """Design goals 2 & 4: same GPAC core under any telemetry x any policy."""
        cfg = small_cfg(n_logical=128, n_gpa_hp=12, hot_threshold=1)
        state = start_all_far(cfg, init_state(cfg, fill=payload(cfg)))
        hot_ids = jnp.asarray([0, 17, 33, 49], jnp.int32)
        for _ in range(4):
            state = gpac.window_step(
                cfg, state, hot_ids, policy=policy, backend=backend, use_gpac=True
            )
        check_invariants(cfg, state)
        got = asp.read_logical(cfg, state, jnp.arange(cfg.n_logical, dtype=jnp.int32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(payload(cfg)))


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------
@st.composite
def cfg_and_ops(draw):
    cfg = draw(strategies.gpac_cfg())  # shared geometry (DESIGN.md §15)
    n_ops = draw(st.integers(1, 5))
    ops = []
    for _ in range(n_ops):
        kind = draw(st.sampled_from(["access", "consolidate", "tier", "window"]))
        if kind == "access":
            ids = draw(
                st.lists(
                    st.integers(-2, cfg.n_logical + 2), min_size=1, max_size=16
                )
            )
            ops.append(("access", ids))
        elif kind == "consolidate":
            ids = draw(
                st.lists(
                    st.integers(0, cfg.n_logical - 1),
                    min_size=1,
                    max_size=cfg.hp_ratio,
                    unique=True,
                )
            )
            ops.append(("consolidate", ids))
        elif kind == "tier":
            ops.append(("tier", draw(strategies.policies())))
        else:
            ops.append(("window", None))
    return cfg, ops


@given(cfg_and_ops())
@settings(max_examples=25, deadline=None)
def test_random_op_sequences_hold_invariants(cfg_ops):
    """Any interleaving of accesses, Algorithm-1 calls, tier ticks and window
    rolls keeps the address space bijective and the payload intact."""
    cfg, ops = cfg_ops
    data = payload(cfg, seed=1)
    state = init_state(cfg, fill=data)
    for kind, arg in ops:
        if kind == "access":
            state = asp.record_accesses(cfg, state, jnp.asarray(arg, jnp.int32))
        elif kind == "consolidate":
            pages = np.full((cfg.hp_ratio,), -1, np.int32)
            pages[: len(arg)] = arg
            state = consolidator.consolidate_pages(cfg, state, jnp.asarray(pages))
        elif kind == "tier":
            state = tiering.tick(cfg, state, arg)
        else:
            state = telemetry.end_window(cfg, state)
    check_invariants(cfg, state)
    got = asp.read_logical(cfg, state, jnp.arange(cfg.n_logical, dtype=jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(data), rtol=0, atol=0)


@given(st.integers(1, 16), st.integers(0, 3))
@settings(max_examples=20, deadline=None)
def test_filter_respects_cl(cl, seed):
    """No selected candidate may live in a huge page with >= CL hot subpages."""
    cfg = GpacConfig(
        n_logical=128, hp_ratio=16, n_gpa_hp=12, n_near=4, base_elems=2, cl=cl
    )
    rng = np.random.default_rng(seed)
    state = init_state(cfg, fill=payload(cfg))
    ids = jnp.asarray(rng.integers(0, cfg.n_logical, size=64), jnp.int32)
    state = asp.record_accesses(cfg, state, ids)
    hot = telemetry.hot_mask(cfg, state, "ipt")
    cand = np.asarray(pfilter.candidate_mask(cfg, state, hot))
    per_hp = np.asarray(telemetry.hot_subpages_per_hp(cfg, state, hot))
    hp_of = np.asarray(state.gpt) // cfg.hp_ratio
    assert not cand[per_hp[hp_of] >= cl].any()
    batches, counts = pfilter.select_batches(cfg, state, hot, max_batches=2)
    b = np.asarray(batches)
    assert b.shape == (2, cfg.hp_ratio)
    valid = b[b >= 0]
    assert len(np.unique(valid)) == len(valid)  # no duplicates across batches
    assert (np.asarray(counts) == (b >= 0).sum(axis=1)).all()
