"""Self-tests for the repo-specific AST lints (DESIGN.md §15).

Two halves: (1) every registered lint must fire on its seeded violation
fixture — a lint that silently stops matching is dead weight; (2) the real
repo must be clean under the full lint set with no stale allowlist
entries, which is the same gate ``scripts/lint_repro.py`` gives CI.
"""
from pathlib import Path

import pytest

from repro.analysis import lint

REPO_ROOT = Path(__file__).resolve().parent.parent


# --------------------------------------------------------------------------
# each lint catches its seeded fixture
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", lint.lint_names())
def test_lint_fires_on_its_fixture(name, tmp_path):
    entry = lint.get_lint(name)
    target = tmp_path / entry.fixture_path
    target.parent.mkdir(parents=True, exist_ok=True)
    target.write_text(entry.fixture)
    violations = lint.lint_file(target, tmp_path, lints=[entry])
    assert any(v.lint == name for v in violations), (
        f"{name} went silent on its own fixture")
    # and every violation self-locates: real line, real source text
    for v in violations:
        assert v.line > 0 and v.source_line.strip()
        assert name in v.format()


def test_self_test_driver_passes(tmp_path):
    assert lint.self_test(tmp_path) == []


# --------------------------------------------------------------------------
# the repo itself is clean (the day-one sweep stays done)
# --------------------------------------------------------------------------
def test_repo_is_lint_clean():
    violations, unused = lint.run(REPO_ROOT)
    assert violations == [], "\n".join(v.format() for v in violations)
    assert unused == [], (
        "stale allowlist entries: "
        + ", ".join(f"({e.lint}, {e.path}, {e.match!r})" for e in unused))


def test_allowlist_entries_all_have_reasons():
    for e in lint.ALLOWLIST:
        assert e.reason.strip(), f"({e.lint}, {e.path}) missing reason"
    with pytest.raises(ValueError, match="reason"):
        lint.AllowlistEntry(lint="REPRO-L001", path="x.py", match="y", reason=" ")


# --------------------------------------------------------------------------
# lint registry follows the PR-2 idiom
# --------------------------------------------------------------------------
class TestLintRegistry:
    def test_catalogue(self):
        assert lint.lint_names() == (
            "REPRO-L001", "REPRO-L002", "REPRO-L003", "REPRO-L004",
            "REPRO-L005", "REPRO-L006",
        )

    def test_duplicate_registration_raises(self, monkeypatch):
        monkeypatch.setattr(lint, "_LINTS", dict(lint._LINTS))
        with pytest.raises(ValueError, match="already registered"):
            @lint.register_lint(
                "REPRO-L001", "dup", fixture="x = 1\n",
                fixture_path="src/repro/data/f.py")
            def fn(tree, rel, lines):
                return []

    def test_unknown_lint_lists_live_set(self):
        with pytest.raises(ValueError, match="REPRO-L001"):
            lint.get_lint("REPRO-L999")

    def test_fixture_required(self, monkeypatch):
        monkeypatch.setattr(lint, "_LINTS", dict(lint._LINTS))
        with pytest.raises(ValueError, match="fixture"):
            @lint.register_lint(
                "REPRO-L900", "no fixture", fixture="",
                fixture_path="src/repro/data/f.py")
            def fn(tree, rel, lines):
                return []
