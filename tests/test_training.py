"""Training stack: optimization descends, checkpoint/restart is bit-identical,
compression keeps convergence, straggler/elastic policies behave."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as config_lib
from repro.data import pipeline
from repro.models import registry
from repro.train import checkpoint, compression, fault, optimizer, trainer


def tiny_model():
    cfg = config_lib.reduced("qwen2-0.5b").replace(dtype=jnp.float32, vocab=64)
    return registry.build(cfg)


def tiny_spec(model, B=8, S=32):
    return pipeline.DataSpec(vocab=model.cfg.vocab, seq_len=S, global_batch=B,
                             seed=3)


class TestOptimizers:
    @pytest.mark.parametrize("name", ["adamw", "adafactor"])
    def test_quadratic_descends(self, name):
        params = {"w": jnp.ones((4, 8)) * 3.0}
        cfg = optimizer.OptConfig(name=name, lr=0.1, warmup_steps=0,
                                  weight_decay=0.0, total_steps=100)
        state = optimizer.init(cfg, params)
        for _ in range(60):
            grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state, mets = optimizer.update(cfg, grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 1.0
        assert np.isfinite(mets["grad_norm"])

    def test_schedule_warmup_and_decay(self):
        cfg = optimizer.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                  min_lr_frac=0.1)
        lrs = [float(optimizer.schedule(cfg, jnp.asarray(s)))
               for s in [0, 5, 10, 100]]
        assert lrs[0] == 0.0 and abs(lrs[1] - 0.5) < 1e-6
        assert abs(lrs[2] - 1.0) < 1e-6 and abs(lrs[3] - 0.1) < 1e-6


class TestTraining:
    def test_loss_decreases(self):
        model = tiny_model()
        tcfg = trainer.TrainConfig(opt=optimizer.OptConfig(
            lr=1e-3, warmup_steps=5, total_steps=60))
        *_, hist = trainer.train_loop(model, tcfg, tiny_spec(model), steps=60)
        first = np.mean([h["loss"] for h in hist[:5]])
        last = np.mean([h["loss"] for h in hist[-5:]])
        assert last < first - 0.2, (first, last)

    def test_grad_accumulation_matches_full_batch(self):
        model = tiny_model()
        spec = tiny_spec(model)
        batch, _ = pipeline.next_batch(spec, pipeline.DataState())
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params = model.init(jax.random.PRNGKey(0))
        outs = {}
        for n_micro in (1, 4):
            tcfg = trainer.TrainConfig(
                micro_batches=n_micro,
                opt=optimizer.OptConfig(lr=1e-3, warmup_steps=0, total_steps=10))
            state = trainer.init_train_state(tcfg, params)
            step = trainer.make_train_step(model, tcfg)
            p2, _, mets = jax.jit(step)(params, state, batch)
            outs[n_micro] = (p2, float(mets["loss"]))
        # same data => same loss and near-identical update
        assert abs(outs[1][1] - outs[4][1]) < 1e-3
        for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-3, atol=2e-4)

    def test_compressed_training_still_descends(self):
        model = tiny_model()
        tcfg = trainer.TrainConfig(
            compress_grads=True,
            opt=optimizer.OptConfig(lr=1e-3, warmup_steps=5, total_steps=60))
        *_, hist = trainer.train_loop(model, tcfg, tiny_spec(model), steps=60)
        first = np.mean([h["loss"] for h in hist[:5]])
        last = np.mean([h["loss"] for h in hist[-5:]])
        assert last < first - 0.2, (first, last)


class TestCheckpoint:
    def test_restart_bit_identical(self, tmp_path):
        model = tiny_model()
        spec = tiny_spec(model)
        tcfg = trainer.TrainConfig(opt=optimizer.OptConfig(
            lr=1e-3, warmup_steps=0, total_steps=30))

        # uninterrupted 12 steps
        p_full, ts_full, _, _ = trainer.train_loop(model, tcfg, spec, steps=12)

        # 6 steps -> checkpoint -> fresh process state -> restore -> 6 more
        p6, ts6, ds6, _ = trainer.train_loop(model, tcfg, spec, steps=6)
        ckpt_dir = str(tmp_path / "ckpt")
        checkpoint.save(ckpt_dir, 6, {
            "params": p6, "train_state": ts6,
            "data_step": jnp.asarray(ds6.step)})
        like = {"params": p6, "train_state": ts6,
                "data_step": jnp.asarray(ds6.step)}
        restored, manifest = checkpoint.restore(ckpt_dir, like)
        assert manifest["step"] == 6
        p_res, ts_res, _, _ = trainer.train_loop(
            model, tcfg, spec, steps=12,
            params=restored["params"], train_state=restored["train_state"],
            data_state=pipeline.DataState(step=int(restored["data_step"])))
        for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_latest_pointer(self, tmp_path):
        d = str(tmp_path / "c")
        checkpoint.save(d, 1, {"w": jnp.ones(3)})
        checkpoint.save(d, 2, {"w": jnp.ones(3) * 2})
        assert checkpoint.latest_step(d) == 2
        restored, _ = checkpoint.restore(d, {"w": jnp.zeros(3)})
        np.testing.assert_array_equal(np.asarray(restored["w"]), 2 * np.ones(3))

    def test_shape_mismatch_rejected(self, tmp_path):
        d = str(tmp_path / "c")
        checkpoint.save(d, 1, {"w": jnp.ones(3)})
        with pytest.raises(ValueError):
            checkpoint.restore(d, {"w": jnp.zeros(4)})

    def test_prune_keeps_newest(self, tmp_path):
        d = str(tmp_path / "c")
        for s in range(5):
            checkpoint.save(d, s, {"w": jnp.ones(2) * s})
        checkpoint.prune(d, keep=2)
        steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert len(steps) == 2 and checkpoint.latest_step(d) == 4


class TestCompression:
    def test_error_feedback_is_unbiased_over_time(self):
        """Sum of dequantized grads converges to sum of true grads."""
        rng = np.random.default_rng(0)
        g_true = jnp.asarray(rng.normal(size=(1000,)) * 1e-3, jnp.float32)
        err = compression.init_error({"g": g_true})["g"]
        total = jnp.zeros_like(g_true)
        for _ in range(50):
            (deq,), (err,) = (lambda t: (jax.tree.leaves(t[0]),
                                         jax.tree.leaves(t[1])))(
                compression.compress_grads({"g": g_true}, {"g": err}))
            total = total + deq
        np.testing.assert_allclose(
            np.asarray(total), np.asarray(g_true) * 50, rtol=0, atol=2e-5)

    def test_byte_savings(self):
        params = {"w": jnp.zeros((1024, 1024), jnp.bfloat16)}
        c = compression.compressed_bytes(params)
        u = compression.uncompressed_bytes(params)
        assert c < 0.55 * u  # ~2x reduction


class TestStragglers:
    def test_rebalance_moves_load_off_slow_host(self):
        cfg = fault.StragglerConfig(deadline_factor=1.5)
        h = fault.HostHealth(n_hosts=4, cfg=cfg)
        for _ in range(5):
            h = fault.observe_step(h, np.asarray([100.0, 100.0, 100.0, 400.0]))
        plan = fault.straggler_plan(h, micro_per_host=4)
        assert plan["shares"].sum() == 16  # work conserved
        assert plan["shares"][3] < 4  # slow host sheds load
        assert plan["shares"][:3].max() > 4  # fast hosts absorb it
        assert 3 in plan["suspects"]

    def test_healthy_cluster_untouched(self):
        cfg = fault.StragglerConfig()
        h = fault.HostHealth(n_hosts=4, cfg=cfg)
        h = fault.observe_step(h, np.asarray([100.0, 101.0, 99.0, 102.0]))
        plan = fault.straggler_plan(h, micro_per_host=4)
        assert (plan["shares"] == 4).all()
        assert plan["suspects"].size == 0

    def test_surviving_mesh(self):
        assert fault.surviving_mesh_shape(31, 8, 16) == (15, 16)
        with pytest.raises(RuntimeError):
            fault.surviving_mesh_shape(1, 8, 16)
