"""Guest-axis device sharding: ``engine.run_sharded`` vs ``engine.run``.

The sharded driver must be bit-for-bit equal to the unsharded engine on any
mesh size, for ragged guests, with GPAC on and off, including guest counts
that do not divide the mesh (no-op padding rows). In-process tests exercise
the full shard_map path on a 1-device mesh (the suite normally sees one CPU
device); the multi-device matrix runs in one subprocess
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``, the same forced
mesh CI uses.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import engine, sharding


def assert_states_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def ragged_engine():
    guests = (
        engine.GuestSpec(n_logical=96, cl=3, gpa_slack=0.5, workload="redis", seed=0),
        engine.GuestSpec(n_logical=176, cl=8, gpa_slack=0.25, workload="masim", seed=1),
        engine.GuestSpec(n_logical=64, cl=None, gpa_slack=1.0, workload="hash", seed=2),
    )
    host = engine.HostSpec(hp_ratio=16, near_fraction=0.4, base_elems=2, cl=6)
    return engine.build(guests, host)


class TestMeshAndPadding:
    def test_guest_mesh_degrades_without_devices(self):
        # normally the suite sees one CPU device; skip rather than fail if
        # the environment leaks XLA_FLAGS=--xla_force_host_platform_...
        if jax.local_device_count() != 1:
            pytest.skip("needs a single-device host to test degradation")
        assert sharding.guest_mesh() is None
        with pytest.raises(ValueError, match="devices"):
            sharding.guest_mesh(jax.local_device_count() + 1)

    def test_padded_guest_count(self):
        assert sharding.padded_guest_count(8, 8) == 8
        assert sharding.padded_guest_count(6, 8) == 8
        assert sharding.padded_guest_count(9, 4) == 12
        assert sharding.padded_guest_count(1, 1) == 1

    def test_pad_guest_rows_appends_noop_rows(self):
        rows = np.arange(6, dtype=np.int32).reshape(3, 2)
        padded = sharding.pad_guest_rows(rows, 4)
        assert padded.shape == (4, 2)
        np.testing.assert_array_equal(padded[:3], rows)
        assert (padded[3] == -1).all()
        # already-dividing counts pass through untouched
        assert sharding.pad_guest_rows(rows, 3) is rows

    def test_guest_tables_cover_segments_and_pad(self):
        spec, _ = ragged_engine()
        tables = sharding.guest_tables(spec, 2)
        assert tables["logical_pad"].shape[0] == 4
        assert (tables["logical_pad"][3] == -1).all()
        assert (tables["hp_pad"][3] == -1).all()
        covered = tables["logical_pad"][tables["logical_pad"] >= 0]
        np.testing.assert_array_equal(
            np.sort(covered), np.arange(spec.cfg.n_logical))


class TestShardedSingleDevice:
    """The full shard_map path on a 1-device mesh (collectives are trivial
    but every phase -- psum histogram, local GPAC, ownership merge,
    replicated tick -- executes)."""

    @pytest.mark.parametrize("use_gpac", [False, True])
    def test_bitwise_equal_to_run(self, use_gpac):
        spec, s0 = ragged_engine()
        traces = engine.guest_traces(spec, n_windows=5, accesses_per_window=192)
        mesh = sharding.guest_mesh(1)
        ref_state, ref = engine.run(spec, s0, traces, use_gpac=use_gpac)
        sh_state, sh = engine.run_sharded(
            spec, s0, traces, mesh=mesh, use_gpac=use_gpac)
        assert_states_equal(ref_state, sh_state)
        assert set(ref) == set(sh)
        for k in ref:
            np.testing.assert_array_equal(ref[k], sh[k], err_msg=k)

    def test_chunking_and_collectors_match(self):
        # the snapshot collector reads host-global state, so it only runs on
        # the replicated-host path (host_sharded=False); the host-sharded
        # default rejects it upfront (tests/test_host_sharding.py)
        spec, s0 = ragged_engine()
        traces = engine.guest_traces(spec, n_windows=6, accesses_per_window=128)
        mesh = sharding.guest_mesh(1)
        ref_state, ref = engine.run(spec, s0, traces, collect=("snapshot",),
                                    windows_per_step=3)
        sh_state, sh = engine.run_sharded(
            spec, s0, traces, mesh=mesh, collect=("snapshot",),
            windows_per_step=3, host_sharded=False)
        assert_states_equal(ref_state, sh_state)
        for k in ref:
            np.testing.assert_array_equal(ref[k], sh[k], err_msg=k)

    def test_mesh_none_falls_back_to_run(self):
        spec, s0 = ragged_engine()
        traces = engine.guest_traces(spec, n_windows=3, accesses_per_window=128)
        ref_state, ref = engine.run(spec, s0, traces)
        fb_state, fb = engine.run_sharded(spec, s0, traces, mesh=None)
        assert_states_equal(ref_state, fb_state)
        for k in ref:
            np.testing.assert_array_equal(ref[k], fb[k], err_msg=k)

    def test_run_series_threads_the_mesh(self):
        spec, s0 = ragged_engine()
        traces = engine.guest_traces(spec, n_windows=4, accesses_per_window=128)
        mesh = sharding.guest_mesh(1)
        ref_state, ref = engine.run_series(spec, s0, traces)
        sh_state, sh = engine.run_series(spec, s0, traces, mesh=mesh)
        assert_states_equal(ref_state, sh_state)
        for k in ref:
            np.testing.assert_array_equal(ref[k], sh[k], err_msg=k)


MULTI_DEVICE_CHECK = """
import numpy as np, jax
from repro.core import engine, sharding

assert jax.local_device_count() == 8, jax.local_device_count()

def check(n_guests, mesh_n, use_gpac, policy):
    guests = tuple(
        engine.GuestSpec(
            n_logical=64 + 16 * (g % 4),
            cl=(None if g % 3 == 0 else 3 + g % 5),
            gpa_slack=0.25 + 0.25 * (g % 3),
            workload=["redis", "masim", "hash"][g % 3], seed=g)
        for g in range(n_guests))
    spec, state = engine.build(
        guests,
        engine.HostSpec(hp_ratio=16, near_fraction=0.4, base_elems=2, cl=6))
    traces = engine.guest_traces(spec, n_windows=4, accesses_per_window=192)
    mesh = sharding.guest_mesh(mesh_n)
    s_ref, a = engine.run(spec, state, traces, use_gpac=use_gpac, policy=policy)
    # host_sharded=False: this matrix pins the replicated-host path; the
    # host-partitioned default is pinned by tests/test_host_sharding.py
    s_sh, b = engine.run_sharded(
        spec, state, traces, mesh=mesh, use_gpac=use_gpac, policy=policy,
        host_sharded=False)
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    for x, y in zip(jax.tree_util.tree_leaves(s_ref),
                    jax.tree_util.tree_leaves(s_sh)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    print("OK", n_guests, mesh_n, use_gpac, policy, flush=True)

check(8, 8, True, "memtierd")   # ragged guests, dividing count
check(8, 8, False, "memtierd")  # gpac off: pure access + host tick
check(6, 8, True, "memtierd")   # padding: 6 guests on 8 shards
check(8, 4, True, "tpp")        # multi-guest-per-shard, second policy
"""


class TestShardedMultiDevice:
    def test_forced_8_device_mesh_matches_run(self):
        """The acceptance matrix: ragged guests x gpac on/off on a forced
        8-device CPU mesh, plus a guest count that does not divide it. Runs
        in a subprocess because device count is fixed at jax init."""
        env = dict(
            os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            JAX_PLATFORMS="cpu",
            PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""),
        )
        proc = subprocess.run(
            [sys.executable, "-c", MULTI_DEVICE_CHECK],
            env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            capture_output=True,
            text=True,
            timeout=600,
        )
        assert proc.returncode == 0, (
            f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
        assert proc.stdout.count("OK") == 4, proc.stdout
