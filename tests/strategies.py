"""Shared hypothesis strategies for the property suites (DESIGN.md §15).

One canonical definition of "random GPAC geometry" serves three suites:
``tests/test_core_invariants.py`` (op sequences over a raw GpacConfig),
``tests/test_tiers_properties.py`` (tick-level tier invariants) and the
contract harness ``tests/test_contracts.py`` (full ContractDraw bundles).
Before this module each suite drew its own slightly different geometry, so
a pin could pass in one suite's corner of the space and fail in another's.

hypothesis is a hard CI dependency (requirements-ci.txt). The ONE gate
below replaces the per-suite ``importorskip`` guards the property modules
used to carry: containers without hypothesis skip every suite that imports
this module (the contract harness separately falls back to the fixed
smoke draws in ``repro.contracts.draws.fallback_draws`` so each contract
still runs once in tier-1 there).
"""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import strategies as st

from repro.core import GpacConfig, tiering
from repro.contracts.draws import ContractDraw, GuestDraw

WORKLOADS = ("redis", "masim", "liblinear", "hash")


def policies():
    """Every registered tier policy (registry-driven, never hand-listed)."""
    return st.sampled_from(tuple(tiering.POLICIES))


@st.composite
def gpac_cfg(draw, min_hp=4, max_hp=12, near_slack=1):
    """A random small GpacConfig: ragged logical sizes, any CL, any split.

    ``near_slack`` keeps at least that many huge-page slots in the far tier
    (the tier suites need a non-empty far pool to demote into).
    """
    hp_ratio = draw(st.sampled_from([4, 8, 16]))
    n_hp = draw(st.integers(min_hp, max_hp))
    n_logical = draw(st.integers(hp_ratio, (n_hp - 2) * hp_ratio))
    n_near = draw(st.integers(1, n_hp - near_slack))
    cl = draw(st.integers(1, hp_ratio))
    return GpacConfig(
        n_logical=n_logical, hp_ratio=hp_ratio, n_gpa_hp=n_hp, n_near=n_near,
        base_elems=2, cl=cl,
    )


@st.composite
def tier_cfg(draw):
    """(cfg, seed, policy) for the tick-level tier properties."""
    cfg = draw(gpac_cfg(min_hp=6, max_hp=14, near_slack=2))
    seed = draw(st.integers(0, 7))
    policy = draw(policies())
    return cfg, seed, policy


@st.composite
def guest_draws(draw, hp_ratio):
    """One guest's geometry: ragged size, optional per-guest CL override."""
    n_logical = draw(st.integers(hp_ratio, 4 * hp_ratio))
    cl = draw(st.one_of(st.none(), st.integers(1, hp_ratio)))
    gpa_slack = draw(st.sampled_from([0.25, 0.5]))
    workload = draw(st.sampled_from(WORKLOADS))
    seed = draw(st.integers(0, 5))
    return GuestDraw(
        n_logical=n_logical, cl=cl, gpa_slack=gpa_slack,
        workload=workload, seed=seed,
    )


@st.composite
def contract_draws(draw):
    """The full contract parameter space (kept small: every distinct
    geometry is a fresh XLA compile for the engine-level contracts)."""
    hp_ratio = draw(st.sampled_from([4, 8]))
    n_guests = draw(st.integers(1, 3))
    guests = tuple(draw(guest_draws(hp_ratio)) for _ in range(n_guests))
    n_windows = draw(st.integers(3, 5))
    return ContractDraw(
        guests=guests,
        hp_ratio=hp_ratio,
        near_fraction=draw(st.sampled_from([0.25, 0.5])),
        host_cl=draw(st.integers(1, hp_ratio)),
        policy=draw(policies()),
        use_gpac=draw(st.booleans()),
        synth=draw(st.booleans()),
        n_windows=n_windows,
        accesses_per_window=draw(st.integers(8, 32)),
        windows_per_step=draw(st.integers(2, n_windows)),  # incl. non-dividing
        host_sharded=draw(st.booleans()),
        cap=draw(st.integers(0, 6)),
        budget=draw(st.integers(1, 8)),
        slack=draw(st.integers(0, 2)),
        seed=draw(st.integers(0, 1023)),
    )
