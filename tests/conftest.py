"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must see
exactly one (CPU) device; only launch/dryrun.py forces 512 placeholder devices.
"""
import os

# Keep CPU compilation light and deterministic for the test suite.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("JAX_ENABLE_X64", "0")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
