"""Sharding-spec assignment rules (stub mesh -- no devices needed) and
roofline analysis arithmetic."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs as config_lib
from repro.launch import sharding
from repro.models import registry
from repro.models.dist import Dist


class StubMesh:
    """Quacks like jax.sharding.Mesh for spec logic (shape dict only)."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


def dist16():
    return Dist(mesh=StubMesh(pod=2, data=16, model=16),
                dp=("pod", "data"), tp="model")


def specs_for(arch: str, fsdp=None):
    cfg = config_lib.reduced(arch)  # shapes don't matter for rule selection
    full = config_lib.get(arch)
    model = registry.build(full)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return full, sharding.param_specs(full, params, dist16(),
                                      fsdp_threshold=fsdp)


class TestParamSpecs:
    def test_dense_tp_rules(self):
        cfg, specs = specs_for("internlm2-20b", fsdp=None)
        g = specs["groups"]["layer0"]
        assert g["attn"]["wq"] == P(None, None, "model")  # leading group axis
        assert g["attn"]["wo"] == P(None, "model", None)
        assert g["ffn"]["wi_gate"] == P(None, None, "model")
        assert g["ffn"]["wo"] == P(None, "model", None)
        assert specs["embed"]["tok"] == P("model", None)
        # KVH=8 does not divide model=16 -> KV replicated
        assert g["attn"]["wk"] == P(None, None, None)

    def test_indivisible_heads_replicate(self):
        cfg, specs = specs_for("smollm-360m", fsdp=None)
        g = specs["groups"]["layer0"]
        # 15 heads don't divide 16 -> attention replicated, MLP still sharded
        assert g["attn"]["wq"] == P(None, None, None)
        assert g["ffn"]["wi_gate"] == P(None, None, "model")

    def test_moe_expert_parallelism(self):
        cfg, specs = specs_for("kimi-k2-1t-a32b", fsdp=None)
        g = specs["groups"]["layer0"]
        assert g["ffn"]["experts"]["wi_gate"][1] == "model"  # (G, E, d, ff)
        assert g["ffn"]["router"] == P(None, None, "model")

    def test_fsdp_extends_big_leaves(self):
        cfg, specs = specs_for("internlm2-20b", fsdp=8 * 1024 * 1024)
        g = specs["groups"]["layer0"]
        # big MLP weights get an extra DP axis on a free dim
        spec = g["ffn"]["wi_gate"]
        assert "model" in spec and ("pod", "data") in spec
        # small norm scales stay replicated
        assert g["norm1"]["scale"] == P(None, None)

    def test_zero1_opt_specs_shard_something(self):
        from repro.train import optimizer, trainer

        full = config_lib.get("qwen2-0.5b")
        model = registry.build(full)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        tcfg = trainer.TrainConfig()
        state = jax.eval_shape(lambda p: trainer.init_train_state(tcfg, p),
                               params)
        d = dist16()
        p_spec = sharding.param_specs(full, params, d)
        o_spec = sharding.opt_specs(full, state, p_spec, d)
        m_spec = o_spec["opt"]["m"]["groups"]["layer0"]["ffn"]["wi_gate"]
        flat = [a for a in jax.tree.leaves(m_spec, is_leaf=lambda x: x is not None)]
        assert any(a is not None for a in m_spec), m_spec  # ZeRO-1 sharded
        assert o_spec["opt"]["step"] == P()  # scalars replicate

    def test_cache_specs_decode(self):
        full = config_lib.get("internlm2-20b")
        cache = registry.cache_specs(full, B=128, max_seq=32768)
        d = dist16()
        specs = sharding.cache_specs(full, cache, d)
        kv = specs["layers"]["layer0"]["k_pages"]
        # (G, B, KVH=8, pool, page, hd): KVH indivisible -> page dim sharded
        assert kv == P(None, ("pod", "data"), None, None, "model", None)
        assert specs["lens"] == P(("pod", "data"))

    def test_divisibility_fallback_batch1(self):
        full = config_lib.get("jamba-1.5-large-398b")
        cache = registry.cache_specs(full, B=1, max_seq=1024)
        specs = sharding.cache_specs(full, cache, dist16())
        kv = specs["layers"]["layer0"]["k_pages"]
        assert kv[1] is None  # batch=1 cannot shard over dp


class TestRoofline:
    def test_terms_and_dominance(self):
        from repro.roofline import analysis

        rec = dict(
            arch="gemma-7b", shape="train_4k", mesh="single", n_devices=256,
            cost_analysis={"flops": 1e15, "bytes accessed": 1e12},
            collectives={"bytes": {"all-reduce": 1e10, "all-gather": 0,
                                   "reduce-scatter": 0, "all-to-all": 0,
                                   "collective-permute": 0},
                         "counts": {}},
            memory_analysis={},
        )
        out = analysis.analyze_cell(rec)
        # gemma recipe has micro_batches=2
        assert out["micro_batches"] == 2
        np.testing.assert_allclose(out["t_compute_s"], 2e15 / 197e12)
        np.testing.assert_allclose(out["t_memory_s"], 2e12 / 819e9)
        np.testing.assert_allclose(out["t_collective_s"], 2 * 1e10 / 50e9)
        assert out["dominant"] == "compute"
        assert 0 < out["useful_flops_ratio"]

    def test_time_scan_correction_only_for_ssm(self):
        from repro.roofline import analysis

        assert analysis.time_scan_correction("gemma-7b", "train_4k") == 0
        assert analysis.time_scan_correction("xlstm-1.3b", "train_4k") > 0
        assert analysis.time_scan_correction("jamba-1.5-large-398b",
                                             "train_4k") > 0
        assert analysis.time_scan_correction("xlstm-1.3b", "long_500k") == 0

    def test_model_flops_moe_uses_active(self):
        from repro.roofline import analysis

        dense = analysis.model_flops("internlm2-20b", "train_4k")
        assert dense == 6.0 * config_lib.get("internlm2-20b").param_count() \
            * 256 * 4096
        kimi = analysis.model_flops("kimi-k2-1t-a32b", "train_4k")
        assert kimi < 6.0 * config_lib.get("kimi-k2-1t-a32b").param_count() \
            * 256 * 4096
