"""Host-partition edge cases: the arbitration round vs the replicated tick.

``repro.core.tiering``'s host-partitioned ticks are pure (prepare, apply)
pairs, so the whole multi-partition arbitration -- nominations, the psum'd
candidate exchange, rank_select ordering, per-partition block-table writes --
can be emulated on one device for ANY partition layout by stacking the
per-partition payloads exactly like the mesh collective would. That pins the
bit-for-bit contract against ``tiering.tick`` for the layouts a real mesh
makes awkward to construct:

* a near-tier size that no partition count divides,
* partitions whose block range holds zero near blocks (or no blocks at all),
* arbitration ties: equal scores in different partitions must resolve to the
  lowest block id, exactly like ``jax.lax.top_k`` on the full score array.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tiering
from repro.core.types import GpacConfig, allocated_hp_mask, init_state

POLICIES = ("memtierd", "autonuma", "tpp")


def make_cfg(n_gpa_hp=23, n_near=7):
    # n_near=7: not divisible by 2, 3 or 4 partitions
    return GpacConfig(
        n_logical=n_gpa_hp * 4, hp_ratio=4, n_gpa_hp=n_gpa_hp,
        n_near=n_near, base_elems=2, cl=3,
    )


def random_state(cfg, rng, scramble=True):
    """A structurally valid state with randomized placement, allocation and
    host telemetry (the only fields the tick reads)."""
    state = init_state(cfg)
    perm = rng.permutation(cfg.n_gpa_hp).astype(np.int32)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(cfg.n_gpa_hp, dtype=np.int32)
    rmap = np.asarray(state.rmap).copy()
    # deallocate random huge pages wholesale + random single gpa pages
    for hp in rng.choice(cfg.n_gpa_hp, size=cfg.n_gpa_hp // 3, replace=False):
        rmap[hp * cfg.hp_ratio: (hp + 1) * cfg.hp_ratio] = -1
    state = dataclasses.replace(
        state,
        block_table=jnp.asarray(perm if scramble else np.asarray(state.block_table)),
        slot_owner=jnp.asarray(inv if scramble else np.asarray(state.slot_owner)),
        rmap=jnp.asarray(rmap),
        host_counts=jnp.asarray(
            rng.integers(0, 5, cfg.n_gpa_hp).astype(np.int32)),
        host_hist=jnp.asarray(
            rng.integers(0, 256, cfg.n_gpa_hp).astype(np.uint8)),
        last_touch_epoch=jnp.asarray(
            rng.integers(0, 9, cfg.n_gpa_hp).astype(np.int32)),
        epoch=jnp.int32(rng.integers(1, 10)),
    )
    return state


def emulate_sharded_tick(cfg, state, policy, bounds, budget=8):
    """Run the host-partitioned tick over an explicit partition layout,
    emulating the mesh collective by stacking per-partition payloads.

    Returns (block_table, stats_delta) of the partitioned run; asserts every
    partition arbitrates to identical replicated decisions.
    """
    prepare, apply = tiering.sharded_tick_fns(policy)
    h_loc = max(1, max(hi - lo for lo, hi in bounds))
    alloc_full = np.asarray(allocated_hp_mask(cfg, state))

    def local(x, fill, hp_ids):
        x = np.asarray(x)
        return jnp.asarray(
            np.where(hp_ids >= 0, x[np.clip(hp_ids, 0, None)], fill).astype(x.dtype)
        )

    Ls, payloads = [], []
    for lo, hi in bounds:
        hp_ids = np.full(h_loc, -1, np.int32)
        hp_ids[: hi - lo] = np.arange(lo, hi, dtype=np.int32)
        L = dict(
            hp_ids=jnp.asarray(hp_ids),
            hp_lo=jnp.int32(lo),
            hp_hi=jnp.int32(hi),
            bt=local(state.block_table, cfg.n_gpa_hp, hp_ids),
            hc=local(state.host_counts, 0, hp_ids),
            hh=local(state.host_hist, 0, hp_ids),
            lt=local(state.last_touch_epoch, 0, hp_ids),
            alloc=local(alloc_full, False, hp_ids),
        )
        Ls.append(L)
        payloads.append(prepare(cfg, L, budget))

    merged = dict(
        cands=jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[p["cands"] for p in payloads]),
        sums=jax.tree_util.tree_map(
            lambda *xs: sum(xs), *[p["sums"] for p in payloads]),
    )
    bt_full = np.asarray(state.block_table).copy()
    deltas = []
    for L, (lo, hi) in zip(Ls, bounds):
        bt_new, delta, _ = apply(cfg, L, merged, budget)
        bt_full[lo:hi] = np.asarray(bt_new)[: hi - lo]
        deltas.append({k: int(v) for k, v in delta.items()})
    # the arbitration is replicated: every partition must agree on the stats
    assert all(d == deltas[0] for d in deltas), deltas
    return bt_full, deltas[0]


def assert_matches_replicated(cfg, state, policy, bounds, budget=8):
    ref = tiering.tick(cfg, state, policy, budget=budget)
    bt, delta = emulate_sharded_tick(cfg, state, policy, bounds, budget)
    np.testing.assert_array_equal(bt, np.asarray(ref.block_table),
                                  err_msg=f"{policy} bounds={bounds}")
    for k in delta:
        assert delta[k] == int(ref.stats[k]) - int(state.stats[k]), (
            policy, bounds, k)


def even_bounds(n, parts):
    cut = np.linspace(0, n, parts + 1).astype(int)
    return list(zip(cut[:-1], cut[1:]))


class TestArbitrationVsReplicatedTick:
    @pytest.mark.parametrize("policy", POLICIES)
    @pytest.mark.parametrize("parts", [1, 2, 3, 4])
    def test_random_states_any_partition_count(self, policy, parts):
        """n_near=7 is not divisible by any of these partition counts."""
        cfg = make_cfg()
        rng = np.random.default_rng(hash((policy, parts)) % 2**32)
        for trial in range(4):
            state = random_state(cfg, rng)
            assert_matches_replicated(
                cfg, state, policy, even_bounds(cfg.n_gpa_hp, parts))

    @pytest.mark.parametrize("policy", POLICIES)
    def test_partition_with_zero_near_blocks(self, policy):
        """Identity placement: the second partition's range sits entirely in
        the far tier, so it nominates no victims and only promotion sources."""
        cfg = make_cfg()
        rng = np.random.default_rng(7)
        state = random_state(cfg, rng, scramble=False)
        bounds = [(0, cfg.n_near), (cfg.n_near, cfg.n_gpa_hp)]
        assert np.all(np.asarray(state.block_table)[cfg.n_near:] >= cfg.n_near)
        assert_matches_replicated(cfg, state, policy, bounds)

    @pytest.mark.parametrize("policy", POLICIES)
    def test_empty_and_tiny_partitions(self, policy):
        """Empty ranges (padding devices) and single-block ranges."""
        cfg = make_cfg()
        rng = np.random.default_rng(11)
        state = random_state(cfg, rng)
        bounds = [(0, 0), (0, 1), (1, cfg.n_gpa_hp), (cfg.n_gpa_hp, cfg.n_gpa_hp)]
        assert_matches_replicated(cfg, state, policy, bounds)

    @pytest.mark.parametrize("policy", POLICIES)
    def test_budget_edges(self, policy):
        cfg = make_cfg()
        rng = np.random.default_rng(13)
        state = random_state(cfg, rng)
        for budget in (1, cfg.n_gpa_hp, cfg.n_gpa_hp + 50):
            assert_matches_replicated(
                cfg, state, policy, even_bounds(cfg.n_gpa_hp, 3), budget)


class TestArbitrationTies:
    def test_cross_partition_tie_resolves_to_lowest_block_id(self):
        """Two far blocks in different partitions with identical scores
        compete for one near slot: the winner is pinned to the lower block
        id, bit-for-bit with the replicated top_k tie-break."""
        cfg = make_cfg(n_gpa_hp=12, n_near=4)
        state = init_state(cfg)  # identity: blocks 0-3 near, 4-11 far
        counts = np.zeros(cfg.n_gpa_hp, np.int32)
        counts[[5, 9]] = 3  # equal hot scores, partitions (4,8) and (8,12)
        state = dataclasses.replace(
            state, host_counts=jnp.asarray(counts))
        bounds = [(0, 4), (4, 8), (8, 12)]
        ref = tiering.tick(cfg, state, "memtierd", budget=1)
        bt, _ = emulate_sharded_tick(cfg, state, "memtierd", bounds, budget=1)
        np.testing.assert_array_equal(bt, np.asarray(ref.block_table))
        # the deterministic winner: the lower id (5) was promoted into near
        assert bt[5] < cfg.n_near
        assert bt[9] >= cfg.n_near

    @pytest.mark.parametrize("policy", POLICIES)
    def test_mass_tie_states(self, policy):
        """Every block same score / same lru: selection order degenerates to
        pure block-id order everywhere -- maximal tie pressure."""
        cfg = make_cfg()
        rng = np.random.default_rng(17)
        for fill in (0, 3):
            state = random_state(cfg, rng)
            state = dataclasses.replace(
                state,
                host_counts=jnp.full((cfg.n_gpa_hp,), fill, jnp.int32),
                host_hist=jnp.zeros((cfg.n_gpa_hp,), jnp.uint8),
                last_touch_epoch=jnp.full((cfg.n_gpa_hp,), 2, jnp.int32),
            )
            assert_matches_replicated(
                cfg, state, policy, even_bounds(cfg.n_gpa_hp, 3))
