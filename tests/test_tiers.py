"""N-tier memory hierarchy (DESIGN.md §14): tier vectors, inter-tier flows,
compressed tiers and the TCO objective.

The load-bearing invariant is INV-TIER-2SPECIALCASE-EXACT: the flow-based
generalization with ``tiers=two_tier(cfg)`` must be bit-for-bit equal to the
legacy 2-tier tick on every driver (``run``, ``run_sharded`` on both host
paths, ``run_churn``) -- same int sums, same float divisions. The second is
INV-PRESSURE-NO-OVERCOMMIT: the pressure controller never demotes more than
its budget and never leaves the near tier above the watermark target while
demotion candidates remain.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    GpacConfig,
    address_space as asp,
    engine,
    faults,
    init_state,
    metrics,
    sharding,
    start_all_far,
    tiering,
    tiers,
)
from repro.core.types import allocated_hp_mask


def small_cfg(**kw):
    d = dict(n_logical=96, hp_ratio=16, n_gpa_hp=10, n_near=4, base_elems=4, cl=8)
    d.update(kw)
    return GpacConfig(**d)


def payload(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(cfg.n_logical, cfg.base_elems)), jnp.float32)


def assert_states_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def assert_series_equal(ref, sh):
    assert set(ref) == set(sh)
    for k in ref:
        np.testing.assert_array_equal(ref[k], sh[k], err_msg=k)


def ragged_engine(**host_kw):
    guests = (
        engine.GuestSpec(n_logical=96, cl=3, gpa_slack=0.5, workload="redis", seed=0),
        engine.GuestSpec(n_logical=176, cl=8, gpa_slack=0.25, workload="masim", seed=1),
        engine.GuestSpec(n_logical=64, cl=None, gpa_slack=1.0, workload="hash", seed=2),
    )
    d = dict(hp_ratio=16, near_fraction=0.4, base_elems=2, cl=6)
    d.update(host_kw)
    return engine.build(guests, engine.HostSpec(**d))


def three_tier_engine(**kw):
    specs = tiers.compressed_specs(
        near_fraction=kw.pop("near_fraction", 0.2),
        mid_fraction=kw.pop("mid_fraction", 0.2),
        compression=kw.pop("compression", 2.0),
    )
    return ragged_engine(near_fraction=0.4, tiers=specs, **kw)


def check_permutation(cfg, state):
    bt = np.asarray(state.block_table)
    so = np.asarray(state.slot_owner)
    assert sorted(bt) == list(range(cfg.n_slots)), "block_table not a permutation"
    assert (so[bt] == np.arange(cfg.n_gpa_hp)).all(), "slot_owner∘block_table != id"


# ---------------------------------------------------------------------------
# spec validation (satellite: HostSpec/TierSpec fail fast with the offending
# value in the message, mirroring GpacConfig)
# ---------------------------------------------------------------------------
class TestTierSpecValidation:
    @pytest.mark.parametrize(
        "kw,needle",
        [
            (dict(capacity=0.0), "capacity"),
            (dict(capacity=1.5), "capacity"),
            (dict(latency_ns=0.0), "latency"),
            (dict(bandwidth_gbps=-1.0), "bandwidth"),
            (dict(compression=0.5), "compression"),
            (dict(cost_per_gb=-0.1), "cost"),
        ],
    )
    def test_bad_fields_raise_with_value(self, kw, needle):
        base = dict(name="dram", capacity=0.3, latency_ns=90.0)
        base.update(kw)
        with pytest.raises(ValueError) as e:
            tiers.TierSpec(**base)
        msg = str(e.value)
        assert needle in msg
        (bad,) = kw.values()
        assert str(bad) in msg, f"offending value missing from: {msg}"

    def test_vector_needs_two_tiers(self):
        dram = tiers.TierSpec("dram", 0.5, 90.0)
        with pytest.raises(ValueError, match="2"):
            tiers.TierVector(tiers=(dram,), boundaries=(0, 4))

    @pytest.mark.parametrize("bounds", [(0, 4), (1, 4, 8), (0, 4, 4)])
    def test_bad_boundaries_raise(self, bounds):
        dram = tiers.TierSpec("dram", 0.5, 90.0)
        nvmm = tiers.TierSpec("nvmm", 1.0, 350.0)
        with pytest.raises(ValueError):
            tiers.TierVector(tiers=(dram, nvmm), boundaries=bounds)

    def test_two_tier_matches_cfg(self):
        cfg = small_cfg()
        tv = tiers.two_tier(cfg)
        assert tv.n_tiers == 2
        assert tv.boundaries == (0, cfg.n_near, cfg.n_slots)
        assert tv.bounds(0) == (0, cfg.n_near)
        assert tv.bounds(1) == (cfg.n_near, cfg.n_slots)

    def test_resolve_compression_widens_middle_tier(self):
        """A compressed middle tier holds compression x more blocks than the
        same fraction uncompressed (effective capacity)."""
        plain = tiers.resolve(
            tiers.compressed_specs(0.2, 0.2, compression=1.0), 40, 40)
        comp = tiers.resolve(
            tiers.compressed_specs(0.2, 0.2, compression=3.0), 40, 40)
        w_plain = plain.boundaries[2] - plain.boundaries[1]
        w_comp = comp.boundaries[2] - comp.boundaries[1]
        assert w_comp == 3 * w_plain
        assert comp.boundaries[0] == 0 and comp.boundaries[-1] == 40

    def test_tier_of_slot(self):
        cfg = small_cfg()
        tv = tiers.resolve(tiers.compressed_specs(0.2, 0.2, 2.0),
                           cfg.n_slots, cfg.n_gpa_hp)
        slots = jnp.arange(cfg.n_slots, dtype=jnp.int32)
        t = np.asarray(tiers.tier_of_slot(tv, slots))
        for k in range(tv.n_tiers):
            lo, hi = tv.bounds(k)
            assert (t[lo:hi] == k).all()


class TestHostSpecValidation:
    @pytest.mark.parametrize(
        "kw,needle",
        [
            (dict(hp_ratio=0), "hp_ratio"),
            (dict(near_fraction=0.0), "near_fraction"),
            (dict(near_fraction=1.5), "near_fraction"),
            (dict(n_near=-1), "n_near"),
            (dict(base_elems=0), "base_elems"),
            (dict(cl=0), "cl"),
            (dict(cl=32), "cl"),
        ],
    )
    def test_bad_fields_raise_with_value(self, kw, needle):
        base = dict(hp_ratio=16)
        base.update(kw)
        with pytest.raises(ValueError) as e:
            engine.HostSpec(**base)
        msg = str(e.value)
        assert needle in msg
        (bad,) = kw.values()
        assert str(bad) in msg, f"offending value missing from: {msg}"

    def test_tiers_and_n_near_are_exclusive(self):
        with pytest.raises(ValueError, match="n_near"):
            engine.HostSpec(n_near=4, tiers=tiers.compressed_specs())

    def test_tiers_needs_two_entries(self):
        with pytest.raises(ValueError, match="2"):
            engine.HostSpec(tiers=(tiers.TierSpec("dram", 0.3, 90.0),))

    def test_tiers_entries_must_be_tierspecs(self):
        with pytest.raises(ValueError, match="TierSpec"):
            engine.HostSpec(tiers=("dram", "nvmm"))

    def test_tiers_coerced_to_tuple(self):
        host = engine.HostSpec(tiers=list(tiers.compressed_specs()))
        assert isinstance(host.tiers, tuple)

    def test_build_derives_near_from_first_tier(self):
        spec, _ = three_tier_engine()
        tv = spec.tiers
        assert tv is not None and tv.n_tiers == 3
        assert spec.cfg.n_near == tv.boundaries[1]
        assert tv.boundaries[-1] == spec.cfg.n_slots
        # default builds keep tiers unset (every existing path untouched)
        spec2, _ = ragged_engine()
        assert spec2.tiers is None
        assert spec2.tier_vector.boundaries == (
            0, spec2.cfg.n_near, spec2.cfg.n_slots)


# ---------------------------------------------------------------------------
# INV-TIER-2SPECIALCASE-EXACT: explicit two_tier == legacy on every driver
# ---------------------------------------------------------------------------
class TestTwoTierSpecialCase:
    @pytest.mark.parametrize("policy", ["memtierd", "autonuma", "tpp"])
    def test_tick_bit_identical(self, policy):
        cfg = small_cfg()
        state = start_all_far(cfg, init_state(cfg, fill=payload(cfg)))
        hot = jnp.arange(2 * cfg.hp_ratio, dtype=jnp.int32)
        for _ in range(3):
            state = asp.record_accesses(cfg, state, hot)
            legacy = tiering.tick(cfg, state, policy)
            flow = tiering.tick(cfg, state, policy, tiers=tiers.two_tier(cfg))
            assert_states_equal(legacy, flow)
            state = legacy

    def test_pressure_tick_bit_identical(self):
        cfg = small_cfg(n_gpa_hp=12, n_near=6)
        state = start_all_far(cfg, init_state(cfg, fill=payload(cfg)))
        state = tiering.tick(cfg, state, "memtierd")  # put blocks near
        cap = jnp.asarray(2, jnp.int32)
        eng = jnp.zeros((), bool)
        press = jnp.zeros((), jnp.int32)
        a = tiering.pressure_tick(cfg, state, cap, eng, press)
        b = tiering.pressure_tick(cfg, state, cap, eng, press,
                                  tiers=tiers.two_tier(cfg))
        assert_states_equal(a, b)

    @pytest.mark.parametrize("policy", ["memtierd", "autonuma", "tpp"])
    def test_run_bit_identical(self, policy):
        spec, s0 = ragged_engine()
        spec2 = dataclasses.replace(spec, tiers=tiers.two_tier(spec.cfg))
        traces = engine.guest_traces(spec, n_windows=4, accesses_per_window=192)
        ref_state, ref = engine.run(spec, s0, traces, policy=policy)
        tv_state, tv = engine.run(spec2, s0, traces, policy=policy)
        assert_states_equal(ref_state, tv_state)
        assert_series_equal(ref, tv)

    @pytest.mark.parametrize("host_sharded", [False, True])
    def test_run_sharded_bit_identical(self, host_sharded):
        spec, s0 = ragged_engine()
        spec2 = dataclasses.replace(spec, tiers=tiers.two_tier(spec.cfg))
        traces = engine.guest_traces(spec, n_windows=4, accesses_per_window=128)
        mesh = sharding.guest_mesh(1)
        ref_state, ref = engine.run_sharded(
            spec, s0, traces, mesh=mesh, host_sharded=host_sharded)
        tv_state, tv = engine.run_sharded(
            spec2, s0, traces, mesh=mesh, host_sharded=host_sharded)
        assert_states_equal(ref_state, tv_state)
        assert_series_equal(ref, tv)

    def test_run_churn_bit_identical(self):
        """Churn exercises pressure_tick's tier path: a mid-run near-tier
        shrink engages the controller under both parameterizations."""
        spec, s0 = ragged_engine()
        spec2 = dataclasses.replace(spec, tiers=tiers.two_tier(spec.cfg))
        fs = faults.no_faults(len(spec.guests)).shrink(2, 3).crash(3, 1)
        synth = engine.SynthTrace(n_windows=6, accesses_per_window=128)
        ref_cs, ref = engine.run_churn(
            spec, engine.init_churn(spec, s0), synth, faults=fs)
        tv_cs, tv = engine.run_churn(
            spec2, engine.init_churn(spec2, s0), synth, faults=fs)
        assert_states_equal(ref_cs, tv_cs)
        assert_series_equal(ref, tv)


# ---------------------------------------------------------------------------
# 3-tier behavior: compressed + hybridtier policies, guard rails
# ---------------------------------------------------------------------------
class TestCompressedTiers:
    def test_compressed_policy_preserves_data_and_permutation(self):
        cfg = small_cfg(n_gpa_hp=12, n_near=3)
        tv = tiers.resolve(tiers.compressed_specs(0.25, 0.25, 2.0),
                           cfg.n_slots, cfg.n_gpa_hp)
        data = payload(cfg)
        state = start_all_far(cfg, init_state(cfg, fill=data))
        hot = jnp.arange(2 * cfg.hp_ratio, dtype=jnp.int32)
        for _ in range(4):
            state = asp.record_accesses(cfg, state, hot)
            state = tiering.tick(cfg, state, "compressed", tiers=tv)
        check_permutation(cfg, state)
        got = asp.read_logical(cfg, state, jnp.arange(cfg.n_logical, dtype=jnp.int32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(data))
        # hot blocks end in the top tier
        bt = np.asarray(state.block_table)
        assert (bt[:2] < tv.boundaries[1]).all(), "hot blocks not in tier 0"

    def test_hybridtier_policy_preserves_data_and_permutation(self):
        cfg = small_cfg(n_gpa_hp=12, n_near=3)
        tv = tiers.resolve(tiers.compressed_specs(0.25, 0.25, 2.0),
                           cfg.n_slots, cfg.n_gpa_hp)
        data = payload(cfg)
        state = start_all_far(cfg, init_state(cfg, fill=data))
        hot = jnp.arange(2 * cfg.hp_ratio, dtype=jnp.int32)
        for _ in range(4):
            state = asp.record_accesses(cfg, state, hot)
            state = tiering.tick(cfg, state, "hybridtier", tiers=tv)
        check_permutation(cfg, state)
        got = asp.read_logical(cfg, state, jnp.arange(cfg.n_logical, dtype=jnp.int32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(data))

    @pytest.mark.parametrize("host_sharded", [False, True])
    def test_compressed_engine_sharded_matches_replicated(self, host_sharded):
        spec, s0 = three_tier_engine()
        traces = engine.guest_traces(spec, n_windows=4, accesses_per_window=128)
        mesh = sharding.guest_mesh(1)
        ref_state, ref = engine.run(
            spec, s0, traces, policy="compressed", collect=("hits", "tco"))
        sh_state, sh = engine.run_sharded(
            spec, s0, traces, mesh=mesh, policy="compressed",
            host_sharded=host_sharded, collect=("hits", "tco"))
        assert_states_equal(ref_state, sh_state)
        assert_series_equal(ref, sh)

    def test_builtin_sharded_ticks_refuse_n_tier(self):
        """memtierd/autonuma/tpp host-partitioned ticks are 2-tier only:
        an n-tier spec must fail fast, naming the way out."""
        spec, s0 = three_tier_engine()
        traces = engine.guest_traces(spec, n_windows=2, accesses_per_window=64)
        mesh = sharding.guest_mesh(1)
        with pytest.raises(ValueError, match="compressed|host_sharded"):
            engine.run_sharded(
                spec, s0, traces, mesh=mesh, policy="memtierd",
                host_sharded=True)
        # the replicated-host path runs the flow generalization fine
        engine.run_sharded(spec, s0, traces, mesh=mesh, policy="memtierd",
                           host_sharded=False)

    def test_hybridtier_has_no_sharded_tick(self):
        with pytest.raises(ValueError, match="host-partitioned tick"):
            tiering.sharded_tick_fns("hybridtier")

    def test_pressure_cascade_three_tiers(self):
        """Cascaded watermarks: after a shrink every tier but the last sits
        at or under its cap, and no block vanishes."""
        cfg = small_cfg(n_gpa_hp=12, n_near=4)
        tv = tiers.resolve(tiers.compressed_specs(0.3, 0.3, 1.5),
                           cfg.n_slots, cfg.n_gpa_hp)
        state = start_all_far(cfg, init_state(cfg, fill=payload(cfg)))
        hot = jnp.arange(4 * cfg.hp_ratio, dtype=jnp.int32)
        for _ in range(3):
            state = asp.record_accesses(cfg, state, hot)
            state = tiering.tick(cfg, state, "compressed", tiers=tv)
        cap = jnp.asarray(1, jnp.int32)
        state2, engaged, press = tiering.pressure_tick(
            cfg, state, cap, jnp.zeros((), bool), jnp.zeros((), jnp.int32),
            tiers=tv)
        check_permutation(cfg, state2)
        alloc = np.asarray(allocated_hp_mask(cfg, state2))
        bt = np.asarray(state2.block_table)
        used0 = int((alloc & (bt < tv.boundaries[1])).sum())
        assert used0 <= max(int(cap) - 1, 0) or not bool(engaged)


# ---------------------------------------------------------------------------
# TCO collector
# ---------------------------------------------------------------------------
class TestTcoCollector:
    def test_run_emits_tco_series(self):
        spec, s0 = three_tier_engine()
        traces = engine.guest_traces(spec, n_windows=3, accesses_per_window=128)
        _, out = engine.run(spec, s0, traces, policy="compressed",
                            collect=("hits", "tco"))
        tv = spec.tier_vector
        assert out["tco"].shape == (3,)
        assert out["amat_ns"].shape == (3,)
        assert out["tier_blocks"].shape == (3, tv.n_tiers)
        assert out["tier_hits"].shape == (3, tv.n_tiers)
        assert (out["tco"] > 0).all()
        # per-hit cost per tier = latency + base-page transfer at bandwidth
        costs = [tiers.amat_per_hit_ns(spec.cfg, s) for s in tv.tiers]
        live = out["amat_ns"][out["tier_hits"].sum(axis=1) > 0]
        assert (live >= min(costs)).all() and (live <= max(costs)).all()
        # per-tier hit split sums to the total hit count (hits are per-guest)
        np.testing.assert_array_equal(
            out["tier_hits"].sum(axis=1),
            (out["near_hits"] + out["far_hits"]).sum(axis=1))

    def test_bandwidth_prices_amat_transfer_term(self):
        """Halving one tier's bandwidth raises AMAT by exactly that tier's
        share of the extra base-page transfer time; tco (a capacity price,
        not a traffic price) is untouched."""
        cfg = small_cfg()
        fast = tiers.compressed_specs(0.2, 0.2)
        slow = tuple(
            dataclasses.replace(s, bandwidth_gbps=s.bandwidth_gbps / 2)
            if t == 2 else s for t, s in enumerate(fast))
        tvf = tiers.resolve(fast, cfg.n_slots, cfg.n_gpa_hp)
        tvs = tiers.TierVector(tiers=slow, boundaries=tvf.boundaries)
        blocks = jnp.asarray([3, 4, 3], jnp.int32)
        hits = jnp.asarray([50, 30, 20], jnp.int32)
        mf = tiers.tco_metrics(cfg, tvf, blocks, hits)
        ms = tiers.tco_metrics(cfg, tvs, blocks, hits)
        extra = (int(hits[2]) / int(hits.sum())
                 * (tiers.amat_per_hit_ns(cfg, slow[2])
                    - tiers.amat_per_hit_ns(cfg, fast[2])))
        np.testing.assert_allclose(
            float(ms["amat_ns"]) - float(mf["amat_ns"]), extra, rtol=2e-3)
        assert float(ms["tco"]) == float(mf["tco"])

    def test_compression_lowers_tco_at_equal_capacity(self):
        """The TCO objective orders configurations: compressing the middle
        tier (same $/GB, same block span) divides its cost contribution."""
        cfg = small_cfg()
        specs1 = tiers.compressed_specs(0.2, 0.2, compression=1.0)
        tv1 = tiers.resolve(specs1, cfg.n_slots, cfg.n_gpa_hp)
        # same boundaries, compressed middle tier
        tv3 = tiers.TierVector(
            tiers=tiers.compressed_specs(0.2, 0.2, compression=3.0),
            boundaries=tv1.boundaries)
        blocks = jnp.asarray([3, 4, 3], jnp.int32)
        hits = jnp.asarray([50, 30, 20], jnp.int32)
        m1 = tiers.tco_metrics(cfg, tv1, blocks, hits)
        m3 = tiers.tco_metrics(cfg, tv3, blocks, hits)
        assert float(m3["tco"]) < float(m1["tco"])

    def test_two_tier_default_spec_tco(self):
        """tco composes with the default (tiers=None) engine: blocks split
        near/far, replicated == guest-sharded == host-sharded."""
        spec, s0 = ragged_engine()
        traces = engine.guest_traces(spec, n_windows=4, accesses_per_window=128)
        mesh = sharding.guest_mesh(1)
        ref_state, ref = engine.run(spec, s0, traces, collect=("hits", "tco"))
        for hs in (False, True):
            sh_state, sh = engine.run_sharded(
                spec, s0, traces, mesh=mesh, host_sharded=hs,
                collect=("hits", "tco"))
            assert_states_equal(ref_state, sh_state)
            assert_series_equal(ref, sh)

    def test_churn_emits_tco(self):
        spec, s0 = three_tier_engine()
        fs = faults.no_faults(len(spec.guests)).shrink(1, 2)
        synth = engine.SynthTrace(n_windows=4, accesses_per_window=96)
        _, out = engine.run_churn(
            spec, engine.init_churn(spec, s0), synth, faults=fs,
            policy="compressed", collect=("hits", "tco"))
        assert out["tco"].shape == (4,)
        assert (out["tco"] > 0).all()


# The hypothesis property forms of INV-TIER-2SPECIALCASE-EXACT and
# INV-PRESSURE-NO-OVERCOMMIT live in test_tiers_properties.py so that
# containers without hypothesis skip only those (same gate as
# test_core_invariants.py), not this module.
