"""The vectorized multi-tenant engine is a pure perf refactor: these tests pin
it bit-for-bit against the seed per-guest/per-window reference formulation
(kept as ``*_reference``), and pin ``consolidate_pages`` against the seed
full-pool-concatenation data copy it replaced."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import address_space as asp
from repro.core import consolidator, gpac, simulate, telemetry
from repro.core.address_space import dataclasses_replace
from repro.core.types import FREE, GpacConfig, init_state
from repro.data import traces as tr


def assert_states_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def small_multi_guest(n_guests=3):
    return simulate.make_multi_guest(
        n_guests=n_guests, logical_per_guest=128, hp_ratio=16,
        near_fraction=0.3, base_elems=2, cl=8,
    )


def guest_traces(n_guests=3, n_windows=6, k=256):
    return np.stack([
        tr.generate(tr.TraceSpec("redis", 128, 16, n_windows, k, seed=g))
        for g in range(n_guests)
    ])


class TestMultiGuestEngineEquivalence:
    @pytest.mark.parametrize("use_gpac", [False, True])
    @pytest.mark.parametrize("policy", ["memtierd", "autonuma", "tpp"])
    def test_engine_matches_reference(self, policy, use_gpac):
        traces = guest_traces()
        mg, s0 = small_multi_guest()
        ref_state, ref_series = simulate.run_multi_guest_reference(
            mg, s0, traces, policy=policy, use_gpac=use_gpac)
        new_state, new_series = simulate.run_multi_guest(
            mg, s0, traces, policy=policy, use_gpac=use_gpac)
        assert_states_equal(ref_state, new_state)
        assert set(ref_series) == set(new_series)
        for k in ref_series:
            np.testing.assert_array_equal(ref_series[k], new_series[k], err_msg=k)

    def test_single_window_matches_reference(self):
        traces = guest_traces(n_windows=1)
        mg, s0 = small_multi_guest()
        acc = jnp.asarray(traces[:, 0])
        ref_state, ref_out = simulate.multi_guest_window_reference(mg, s0, acc)
        new_state, new_out = simulate.multi_guest_window(mg, s0, acc)
        assert_states_equal(ref_state, new_state)
        for k in ref_out:
            np.testing.assert_array_equal(
                np.asarray(ref_out[k]), np.asarray(new_out[k]), err_msg=k)

    def test_windows_per_step_chunking_is_invisible(self):
        traces = guest_traces(n_windows=7)
        mg, s0 = small_multi_guest()
        full_state, full_series = simulate.run_multi_guest(mg, s0, traces)
        for wps in (1, 3, 100):
            st, series = simulate.run_multi_guest(
                mg, s0, traces, windows_per_step=wps)
            assert_states_equal(full_state, st)
            for k in full_series:
                np.testing.assert_array_equal(full_series[k], series[k], err_msg=k)

    def test_zero_windows_returns_empty_series(self):
        mg, s0 = small_multi_guest()
        empty = np.zeros((mg.n_guests, 0, 256), np.int32)
        ref_state, ref_series = simulate.run_multi_guest_reference(mg, s0, empty)
        new_state, new_series = simulate.run_multi_guest(mg, s0, empty)
        assert_states_equal(ref_state, new_state)
        for k in ref_series:
            np.testing.assert_array_equal(ref_series[k], new_series[k], err_msg=k)
        cfg = GpacConfig(n_logical=256, hp_ratio=16, base_elems=2, cl=8)
        st, series = gpac.run_windows(
            cfg, init_state(cfg), jnp.zeros((0, 64), jnp.int32))
        assert series == []

    def test_localize_all_matches_per_guest(self):
        mg, _ = small_multi_guest()
        acc = jnp.asarray(guest_traces(n_windows=1)[:, 0])
        acc = acc.at[:, :5].set(-1)  # padding passthrough
        batched = mg.localize_all(acc)
        for g in range(mg.n_guests):
            np.testing.assert_array_equal(
                np.asarray(batched[g]), np.asarray(mg.localize(g, acc[g])))


class TestRunWindowsEquivalence:
    @pytest.mark.parametrize("use_gpac", [False, True])
    def test_fused_matches_reference(self, use_gpac):
        cfg = GpacConfig(n_logical=512, hp_ratio=16, base_elems=2, cl=8)
        trace = jnp.asarray(tr.generate(tr.TraceSpec("redis", 512, 16, 7, 256, seed=1)))
        ref_state, ref_series = gpac.run_windows_reference(
            cfg, init_state(cfg), trace, use_gpac=use_gpac)
        new_state, new_series = gpac.run_windows(
            cfg, init_state(cfg), trace, use_gpac=use_gpac)
        assert_states_equal(ref_state, new_state)
        assert ref_series == new_series  # identical dicts incl. python types
        chunk_state, chunk_series = gpac.run_windows(
            cfg, init_state(cfg), trace, use_gpac=use_gpac, windows_per_step=3)
        assert_states_equal(ref_state, chunk_state)
        assert ref_series == chunk_series


# --------------------------------------------------------------------------
# consolidate_pages: zero-copy dual-pool gather vs the seed concat data copy
# --------------------------------------------------------------------------
def _seed_consolidate_pages(cfg, state, pages, hp_range=None):
    """The seed data-copy formulation: materializes [near_pool; far_pool] as
    one row space per call. Kept here as the regression oracle."""
    pages = pages.astype(jnp.int32)
    valid = (pages >= 0) & (pages < cfg.n_logical)
    region = asp.alloc_free_huge_region(cfg, state, hp_range)
    ok = region >= 0
    n_sel = valid.sum()
    safe_pages = jnp.where(valid, pages, 0)
    old_gpa = state.gpt[safe_pages]
    new_gpa = region * cfg.hp_ratio + jnp.arange(cfg.hp_ratio, dtype=jnp.int32)
    do_move = valid & ok
    src_slot = state.block_table[old_gpa // cfg.hp_ratio]
    src_off = old_gpa % cfg.hp_ratio
    rows = jnp.concatenate(
        [state.near_pool.reshape(-1, cfg.base_elems),
         state.far_pool.reshape(-1, cfg.base_elems)], axis=0)
    payload = rows[jnp.where(do_move, src_slot * cfg.hp_ratio + src_off, 0)]
    dst_slot = state.block_table[jnp.maximum(region, 0)]
    dst_off = jnp.arange(cfg.hp_ratio, dtype=jnp.int32)
    near_idx = jnp.where(do_move & (dst_slot < cfg.n_near), dst_slot, cfg.n_near)
    far_idx = jnp.where(
        do_move & (dst_slot >= cfg.n_near), dst_slot - cfg.n_near, cfg.n_far)
    near_pool = state.near_pool.at[near_idx, dst_off].set(payload, mode="drop")
    far_pool = state.far_pool.at[far_idx, dst_off].set(payload, mode="drop")
    gpt = state.gpt.at[jnp.where(do_move, pages, cfg.n_logical)].set(
        new_gpa, mode="drop")
    rmap = state.rmap.at[jnp.where(do_move, old_gpa, cfg.n_gpa)].set(FREE, mode="drop")
    rmap = rmap.at[jnp.where(do_move, new_gpa, cfg.n_gpa)].set(
        safe_pages, mode="drop")
    region_epoch = state.region_epoch.at[jnp.maximum(region, 0)].set(
        jnp.where(ok, state.epoch, state.region_epoch[jnp.maximum(region, 0)]))
    moved = do_move.sum()
    stats = dict(state.stats)
    stats["consolidated_pages"] = stats["consolidated_pages"] + moved.astype(jnp.int32)
    stats["consolidation_calls"] = stats["consolidation_calls"] + jnp.where(
        n_sel > 0, 1, 0).astype(jnp.int32)
    stats["consolidation_enomem"] = stats["consolidation_enomem"] + jnp.where(
        (n_sel > 0) & ~ok, 1, 0).astype(jnp.int32)
    stats["copied_bytes"] = stats["copied_bytes"] + (
        moved.astype(jnp.int32) * cfg.base_bytes)
    stats["tlb_shootdowns"] = stats["tlb_shootdowns"] + jnp.where(
        moved > 0, 1, 0).astype(jnp.int32)
    return dataclasses_replace(
        state, gpt=gpt, rmap=rmap, near_pool=near_pool, far_pool=far_pool,
        region_epoch=region_epoch, stats=stats)


class TestConsolidateNoPoolConcat:
    def _state(self, cfg, seed=0):
        fill = jax.random.normal(
            jax.random.PRNGKey(seed), (cfg.n_logical, cfg.base_elems), cfg.dtype)
        state = init_state(cfg, fill=fill)
        # scatter some placement so sources span both tiers
        from repro.core import tiering
        far = jnp.arange(cfg.n_near, cfg.n_gpa_hp, dtype=jnp.int32)[: cfg.n_near]
        near = jnp.arange(cfg.n_near, dtype=jnp.int32)[: far.shape[0]]
        return tiering.swap_blocks(cfg, state, far, near, jnp.int32(far.shape[0] // 2))

    @pytest.mark.parametrize("hp_range", [None, (30, 40)])
    def test_output_unchanged_vs_seed_concat_path(self, hp_range):
        cfg = GpacConfig(n_logical=512, hp_ratio=16, base_elems=2, cl=8)
        state = self._state(cfg)
        pages = jnp.asarray(
            list(range(3, 512, 37)) + [-1, 600, -1], jnp.int32)[: cfg.hp_ratio]
        pages = jnp.pad(pages, (0, cfg.hp_ratio - pages.shape[0]), constant_values=-1)
        ref = _seed_consolidate_pages(cfg, state, pages, hp_range)
        new = consolidator.consolidate_pages(cfg, state, pages, hp_range)
        assert_states_equal(ref, new)
        assert int(new.stats["consolidated_pages"]) > 0  # the move happened

    def test_batches_unchanged_vs_seed_concat_path(self):
        cfg = GpacConfig(n_logical=512, hp_ratio=16, base_elems=2, cl=8)
        state = self._state(cfg, seed=3)
        batches = jnp.stack([
            jnp.arange(0, 512, 33, jnp.int32)[: cfg.hp_ratio],
            jnp.full((cfg.hp_ratio,), -1, jnp.int32),
        ])
        ref = state
        for row in batches:
            ref = _seed_consolidate_pages(cfg, ref, row)
        new = consolidator.consolidate_batches(cfg, state, batches)
        assert_states_equal(ref, new)

    def test_no_pool_sized_concatenate_in_jaxpr(self):
        cfg = GpacConfig(n_logical=512, hp_ratio=16, base_elems=2, cl=8)
        state = init_state(cfg)
        pages = jnp.full((cfg.hp_ratio,), -1, jnp.int32)
        jaxpr = jax.make_jaxpr(
            lambda st, p: consolidator.consolidate_pages(cfg, st, p))(state, pages)
        pool_rows = cfg.n_near * cfg.hp_ratio  # smaller pool's row count

        def walk(jx):
            for eqn in jx.eqns:
                if eqn.primitive.name == "concatenate":
                    for v in eqn.outvars:
                        rows = v.aval.shape[0] if v.aval.shape else 0
                        assert rows < pool_rows, (
                            f"pool-sized concatenate resurfaced: {v.aval.shape}")
                for v in eqn.params.values():
                    if isinstance(v, jax.core.ClosedJaxpr):
                        walk(v.jaxpr)
                    elif isinstance(v, jax.core.Jaxpr):
                        walk(v)

        walk(jaxpr.jaxpr)


# --------------------------------------------------------------------------
# satellite pins: popcount + Fig. 2 statistic kernel dispatch
# --------------------------------------------------------------------------
class TestRecordAccessesAggregated:
    def test_large_batch_matches_chunked_small_batches(self):
        cfg = GpacConfig(n_logical=1024, hp_ratio=16, base_elems=2, cl=8)
        rng = np.random.default_rng(0)
        ids = rng.integers(-8, cfg.n_logical, size=4096).astype(np.int32)
        # one big call takes the aggregated histogram path...
        assert ids.size * 2 >= cfg.n_logical
        big = asp.record_accesses(cfg, init_state(cfg), jnp.asarray(ids))
        # ...many small calls take the per-access scatter path
        small = init_state(cfg)
        for chunk in ids.reshape(32, 128):
            assert chunk.size * 2 < cfg.n_logical
            small = asp.record_accesses(cfg, small, jnp.asarray(chunk))
        assert_states_equal(big, small)


class TestTelemetrySatellites:
    def test_popcount_u8_matches_bit_loop(self):
        x = jnp.arange(256, dtype=jnp.uint8)
        ref = np.array([bin(i).count("1") for i in range(256)], np.int32)
        got = telemetry._popcount_u8(x)
        assert got.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(got), ref)

    def test_accessed_subpages_matches_reshape_sum(self):
        cfg = GpacConfig(n_logical=256, hp_ratio=16, base_elems=2, cl=8)
        state = init_state(cfg)
        state = asp.record_accesses(
            cfg, state, jnp.arange(0, 256, 5, dtype=jnp.int32))
        got = telemetry.accessed_subpages_per_hp(cfg, state)
        acc = state.guest_counts > 0
        acc_gpa = jnp.where(state.rmap >= 0, acc[jnp.maximum(state.rmap, 0)], False)
        ref = acc_gpa.reshape(cfg.n_gpa_hp, cfg.hp_ratio).sum(axis=1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
